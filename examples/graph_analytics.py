"""Sage graph-analytics pipeline: the paper's workflow end to end, through
the planner API the benchmarks measure.

1. build the immutable CSR (large memory) + an ExecutionPlan
2. maximal matching via graphFilter rounds (edge deletions = bit clears)
3. orient the remaining graph low→high degree through a second filter
4. triangle counting over the filtered view
5. k-core through the same plan (bucketed peeling, filtered edgeMaps)
6. PSAM cost report: Sage (0 large-memory writes) vs modeled GBBS (ω=4)

    PYTHONPATH=src python examples/graph_analytics.py
"""
import jax
import jax.numpy as jnp

from repro.algorithms import kcore, maximal_matching, triangle_count
from repro.algorithms.substructure import orientation_filter
from repro.core import PSAMCost, make_plan
from repro.data import rmat_graph


def main():
    key = jax.random.PRNGKey(7)
    g = rmat_graph(n=1024, m=8192, seed=7, block_size=64)
    plan = make_plan(g, strategy="auto")
    print(f"graph: n={g.n} m={g.m}; {plan.describe()}")

    partner = maximal_matching(g, key)
    matched = int(jnp.sum(partner >= 0))
    print(f"maximal matching: {matched // 2} pairs ({matched}/{g.n} vertices)")

    f, keep = orientation_filter(g)
    print(
        f"orientation filter: {int(f.num_active_edges)} directed edges kept "
        f"(bits = {f.bits.size * 4} bytes, CSR untouched)"
    )

    tri = triangle_count(g)
    print(f"triangles: {tri}")

    core = kcore(g, plan=plan)
    print(f"k-core through the plan: max coreness {int(jnp.max(core))}")

    cost = PSAMCost(omega=4.0)
    # matching: ~8 filter rounds; triangles: one orientation + intersections
    live = int(jnp.sum(f.block_live))
    for _ in range(8):
        cost.charge_edgemap_planned(g, filter_live_blocks=live)
        cost.charge_filter_pack(g, g.num_blocks)
    print(
        f"PSAM work (Sage, zero NVRAM writes): {cost.work:.0f}\n"
        f"GBBS-equivalent (in-place edge packing, omega=4): "
        f"{cost.gbbs_equivalent_work(8 * g.m):.0f}  "
        f"→ {cost.gbbs_equivalent_work(8 * g.m) / cost.work:.2f}x more work"
    )


if __name__ == "__main__":
    main()
