"""Quickstart: the Sage PSAM engine in five minutes.

Builds an RMAT graph (the immutable large-memory structure), makes an
ExecutionPlan (the planner API every benchmark measures), runs a handful of
the 18 algorithms through it, shows the graphFilter in action, and serves a
batch of concurrent queries through the QueryEngine.

    PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp

from repro.algorithms import bfs, connectivity, kcore, pagerank, triangle_count
from repro.core import PSAMCost, filter_edges_pred, make_filter, make_plan
from repro.data import rmat_graph
from repro.serving import QueryEngine


def main():
    key = jax.random.PRNGKey(0)
    g = rmat_graph(n=2048, m=16384, weighted=True, seed=42, block_size=64)
    print(f"graph: n={g.n} m={g.m} blocks={g.num_blocks} (F_B={g.block_size})")

    # one plan, every algorithm: the same calls run sharded over a mesh by
    # passing mesh=... here — algorithm code never picks an engine
    plan = make_plan(g)
    print(f"plan: {plan.describe()}")

    parents, levels = bfs(g, 0, plan=plan)
    reached = int(jnp.sum(levels >= 0))
    print(f"BFS from 0: reached {reached} vertices, max level {int(jnp.max(levels))}")

    labels = connectivity(g, key, plan=plan)
    n_comp = len(set(labels.tolist()))
    print(f"connectivity: {n_comp} components")

    pr, iters = pagerank(g, plan=plan)
    top = jnp.argsort(-pr)[:5]
    print(f"pagerank converged in {int(iters)} iters; top-5 vertices: {top.tolist()}")

    core = kcore(g, plan=plan)
    print(f"k-core: max coreness {int(jnp.max(core))}")

    print(f"triangles: {triangle_count(g)}")

    # graphFilter: delete light edges WITHOUT touching the CSR (PSAM rule)
    f = make_filter(g)
    f2, remaining = filter_edges_pred(g, f, lambda s, d, w: w >= 2.0)
    print(
        f"filter: kept {int(remaining)}/{g.m} edges (w>=2) — "
        f"bits={f2.bits.size * 4} bytes of small memory, zero large-memory writes"
    )

    # serving: coalesce concurrent requests into one edge sweep per round
    eng = QueryEngine(g, plan=plan, max_batch=8)
    handles = [eng.submit("bfs", src=s) for s in [0, 17, 99, 512]]
    eng.submit("ppr", src=0, max_rounds=50)
    results = eng.flush()
    print(
        f"served {eng.stats['served']} queries in {eng.stats['batches']} "
        f"batches; BFS(17) reached "
        f"{int(jnp.sum(results[handles[1]][1] >= 0))} vertices"
    )

    cost = PSAMCost()
    cost.charge_edgemap_batched(g, 4)  # one batched sweep, 4 queries
    cost.charge_filter_pack(g, g.num_blocks)
    print(
        f"PSAM accounting for one batched round: work={cost.work:.0f} "
        f"(GBBS-equivalent with in-place packing at omega=4: "
        f"{cost.gbbs_equivalent_work(g.m):.0f})"
    )


if __name__ == "__main__":
    main()
