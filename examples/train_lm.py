"""End-to-end LM training driver: a ~100M-parameter qwen2-family model for a
few hundred steps, with checkpoint/restart.

Default runs a reduced model so the example finishes on this CPU container;
pass --full for the 100M × 300-step configuration (sized for a real
accelerator), --arch to pick any assigned architecture's smoke config.

    PYTHONPATH=src python examples/train_lm.py --steps 50
"""
import argparse

import jax

from repro.launch.train import TrainConfig, Trainer
from repro.models import transformer_lm as lm


def model_100m():
    # ~100M params: 12L, d=768, 12H, ff=2048, vocab=32768
    return lm.LMConfig(
        name="lm-100m", n_layers=12, d_model=768, n_heads=12, n_kv_heads=4,
        d_head=64, d_ff=2048, vocab=32768, dtype="bfloat16",
    )


def model_tiny():
    return lm.LMConfig(
        name="lm-tiny", n_layers=4, d_model=128, n_heads=4, n_kv_heads=2,
        d_head=32, d_ff=512, vocab=1024, dtype="float32", kv_block=64,
    )


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--ckpt-dir", default=None)
    args = ap.parse_args()

    cfg = model_100m() if args.full else model_tiny()
    steps = 300 if args.full else args.steps
    n_params = None

    def make_batch(step):
        # synthetic "shifted-window" language data: next token = (t*7+3) % V,
        # learnable structure so the loss visibly drops
        k = jax.random.PRNGKey(step)
        toks = jax.random.randint(k, (args.batch, args.seq), 0, cfg.vocab)
        targets = (toks * 7 + 3) % cfg.vocab
        return {"tokens": toks, "targets": targets}

    tc = TrainConfig(steps=steps, ckpt_every=max(steps // 4, 10), warmup=10,
                     log_every=max(steps // 10, 1))
    trainer = Trainer(lm, cfg, train_cfg=tc)
    params, _, hist = trainer.fit(make_batch, ckpt_dir=args.ckpt_dir, steps=steps)
    n_params = sum(x.size for x in jax.tree.leaves(params))
    print(f"model: {cfg.name}  params={n_params/1e6:.1f}M")
    for h in hist:
        print(f"step {h['step']:4d}  loss {h['loss']:.4f}  "
              f"gnorm {h['grad_norm']:.2f}  {h['sec_per_step']:.2f}s/step")
    assert hist[-1]["loss"] < hist[0]["loss"], "loss should decrease"
    print("done — loss decreased from "
          f"{hist[0]['loss']:.3f} to {hist[-1]['loss']:.3f}")


if __name__ == "__main__":
    main()
