"""LM serving driver: batched prefill + decode loop with a KV cache —
the serve-side counterpart of examples/train_lm.py.

    PYTHONPATH=src python examples/serve_lm.py --new-tokens 16
"""
import argparse
import time

import jax
import jax.numpy as jnp

from repro.models import transformer_lm as lm


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--new-tokens", type=int, default=16)
    args = ap.parse_args()

    cfg = lm.LMConfig(
        name="serve-tiny", n_layers=4, d_model=128, n_heads=4, n_kv_heads=2,
        d_head=32, d_ff=512, vocab=1024, dtype="float32", kv_block=64,
    )
    key = jax.random.PRNGKey(0)
    params = lm.init(key, cfg)
    max_seq = args.prompt_len + args.new_tokens

    prompts = jax.random.randint(
        jax.random.PRNGKey(1), (args.batch, args.prompt_len), 0, cfg.vocab
    )

    prefill = jax.jit(
        lambda p, t: lm.prefill(p, t, cfg, max_seq=max_seq)
    )
    decode = jax.jit(
        lambda p, c, t, pos: lm.decode_step(p, c, t, pos, cfg)
    )

    t0 = time.perf_counter()
    logits, cache = prefill(params, prompts)
    jax.block_until_ready(logits)
    t_prefill = time.perf_counter() - t0
    print(f"prefill: batch={args.batch} len={args.prompt_len}  {t_prefill:.3f}s")

    tokens = jnp.argmax(logits, axis=-1)[:, None]
    generated = [tokens]
    t0 = time.perf_counter()
    for i in range(args.new_tokens - 1):
        pos = args.prompt_len + i
        logits, cache = decode(params, cache, tokens, pos)
        tokens = jnp.argmax(logits, axis=-1)[:, None]
        generated.append(tokens)
    jax.block_until_ready(tokens)
    t_decode = time.perf_counter() - t0
    out = jnp.concatenate(generated, axis=1)
    tps = args.batch * (args.new_tokens - 1) / max(t_decode, 1e-9)
    print(f"decode: {args.new_tokens - 1} steps  {t_decode:.3f}s  ({tps:.1f} tok/s)")
    print("sample continuation (request 0):", out[0].tolist())

    # greedy decode is deterministic: teacher-forcing the generated tokens
    # reproduces the same argmax choices
    full = jnp.concatenate([prompts, out], axis=1)
    h, _ = lm.forward(params, full[:, :-1], cfg)
    logits_tf = lm.logits_from_hidden(params, h, cfg)
    redo = jnp.argmax(logits_tf[:, args.prompt_len - 1 :], axis=-1)
    assert bool(jnp.all(redo == out)), "KV-cache decode diverged from teacher-forced"
    print("KV-cache decode verified against teacher-forced forward ✓")


if __name__ == "__main__":
    main()
