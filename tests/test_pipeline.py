"""Lowering seam + gather tiles + round-pipelined shard combine.

Three contracts from the raw-speed pass:

* **Lowering parity** — the `interpret` knob is pure scheduling: every
  kernel path (both packages, dense/chunked/streamed, single and batched,
  filtered and not) returns bit-identical results under a pinned
  ``interpret=True`` and under every lowering this host can run.  The
  parametrization enumerates only runnable lowerings, so the suite adds no
  skips on CPU-only hosts.
* **Gather-tile parity** — the ``(TB, F_B)`` pre-gathered DMA tiles of the
  chunked streamed kernel decode exactly what the row-steered ``(1, F_B)``
  scalar-prefetch grid decodes, for every tile width and filter setting.
* **Pipelined-round parity** — ``plan.pipeline_rounds=True`` moves the
  cross-shard combine of round r next to round r+1's local sweep; results
  stay bit-identical per lane for BFS / wBFS / PageRank (single and
  batched) on 2- and 4-shard meshes.  Runs in a subprocess with fake CPU
  devices, like the rest of the mesh suite.
"""
import os
import subprocess
import sys

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import compress, edgemap_reduce, make_filter, make_plan
from repro.core.edgemap import edgemap_reduce_batched
from repro.data import rmat_graph
from repro.kernels.lowering import (
    LOWERINGS,
    native_lowering_supported,
    resolve_interpret,
    resolve_lowering,
)

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

# every lowering THIS process can execute — native only where Mosaic is;
# enumerating runnables (instead of skipping) keeps the CPU suite skip-free
RUNNABLE = ["interpret"] + (["native"] if native_lowering_supported() else [])


def _run(code: str) -> str:
    r = subprocess.run(
        [sys.executable, "-c", code],
        capture_output=True,
        text=True,
        env={**os.environ, "PYTHONPATH": "src"},
        cwd=ROOT,
        timeout=420,
    )
    assert r.returncode == 0, r.stderr[-3000:]
    return r.stdout


def _graph():
    return rmat_graph(256, 1024, weighted=True, seed=3, block_size=64)


# ----------------------------------------------------------------------
# Lowering resolution
# ----------------------------------------------------------------------
def test_resolve_lowering():
    assert resolve_lowering("native") == "native"
    assert resolve_lowering("interpret") == "interpret"
    assert resolve_lowering("auto") in ("native", "interpret")
    expect = "native" if native_lowering_supported() else "interpret"
    assert resolve_lowering() == expect
    assert resolve_lowering(None) == expect
    with pytest.raises(ValueError):
        resolve_lowering("mosaic")
    assert set(RUNNABLE) <= set(LOWERINGS)


def test_resolve_interpret():
    # an explicit bool always wins over the lowering knob
    assert resolve_interpret(True) is True
    assert resolve_interpret(False) is False
    assert resolve_interpret(True, "native") is True
    assert resolve_interpret(None, "interpret") is True
    assert resolve_interpret(None, "native") is False
    assert resolve_interpret(None, "auto") == (not native_lowering_supported())


def test_plan_records_lowering():
    g = _graph()
    plan = make_plan(g)
    assert plan.decisions.lowering in ("native", "interpret")
    assert plan.interpret == (plan.decisions.lowering == "interpret")
    pinned = make_plan(g, lowering="interpret")
    assert pinned.interpret is True
    assert pinned.decisions.lowering == "interpret"
    forced = make_plan(g, lowering="native")
    assert forced.interpret is False
    assert forced.decisions.lowering == "native"
    with pytest.raises(ValueError):
        make_plan(g, lowering="bogus")


def test_tuning_key_covers_lowering_and_pipeline():
    g = _graph()
    base = make_plan(g)
    assert base.tuning_key != make_plan(g, lowering=
        "native" if base.interpret else "interpret").tuning_key
    assert base.tuning_key != make_plan(g, pipeline_rounds=True).tuning_key
    # same knobs -> same key: the serving executable cache stays warm
    assert base.tuning_key == make_plan(g).tuning_key


def test_constants_decision_defaults_auto():
    from repro.tuning import constants_decision

    assert constants_decision("csr").lowering == "auto"
    assert constants_decision("compressed").lowering == "auto"


# ----------------------------------------------------------------------
# Lowering parity — both kernel packages, every edgeMap mode, B ∈ {1, 8},
# filtered and unfiltered
# ----------------------------------------------------------------------
_MODES = [
    ("csr", "dense"),
    ("csr", "sparse"),
    ("compressed", "dense"),
    ("compressed", "sparse"),
    ("compressed", "sparse_streamed"),
]


def _assert_same(a, b):
    np.testing.assert_array_equal(np.asarray(a[0]), np.asarray(b[0]))
    np.testing.assert_array_equal(np.asarray(a[1]), np.asarray(b[1]))


@pytest.mark.parametrize("backend,mode", _MODES)
@pytest.mark.parametrize("B", [1, 8])
@pytest.mark.parametrize("filtered", [False, True])
def test_lowering_parity(backend, mode, B, filtered):
    g = _graph()
    gb = compress(g) if backend == "compressed" else g
    edge_active = make_filter(g) if filtered else None
    rng = np.random.default_rng(7)
    n = g.n
    if B == 1:
        fr = jnp.asarray(rng.random(n) < 0.1)
        x = jnp.arange(n, dtype=jnp.int32)
        run = lambda **kw: edgemap_reduce(
            gb, fr, x, monoid="min", mode=mode, edge_active=edge_active, **kw
        )
    else:
        fr = jnp.asarray(rng.random((B, n)) < 0.1)
        x = jnp.broadcast_to(jnp.arange(n, dtype=jnp.int32), (B, n))
        run = lambda **kw: edgemap_reduce_batched(
            gb, fr, x, monoid="min", mode=mode, edge_active=edge_active, **kw
        )
    ref = run(interpret=True)
    _assert_same(run(), ref)  # the resolved default
    for low in RUNNABLE:
        _assert_same(run(interpret=resolve_interpret(None, low)), ref)


# ----------------------------------------------------------------------
# Gather-tile parity — (1, F_B) scalar-prefetch grid vs (TB, F_B) tiles
# ----------------------------------------------------------------------
@pytest.mark.parametrize("filtered", [False, True])
@pytest.mark.parametrize("tile_blocks", [1, 4, 8, 16])
def test_stream_tile_gather_parity(filtered, tile_blocks):
    from repro.kernels.compressed_spmv.ops import compressed_chunked_stream_tile

    g = _graph()
    c = compress(g)
    f = make_filter(g) if filtered else None
    rng = np.random.default_rng(11)
    frontier = jnp.asarray(rng.random(g.n) < 0.1)
    blk_live = jnp.take(frontier, c.block_src, mode="fill", fill_value=False)
    ids = jnp.nonzero(blk_live)[0].astype(jnp.int32)
    row = compressed_chunked_stream_tile(
        c, ids, f, gather_tiles=False, tile_blocks=tile_blocks
    )
    til = compressed_chunked_stream_tile(
        c, ids, f, gather_tiles=True, tile_blocks=tile_blocks
    )
    np.testing.assert_array_equal(np.asarray(row[0]), np.asarray(til[0]))
    np.testing.assert_array_equal(np.asarray(row[1]), np.asarray(til[1]))


@pytest.mark.parametrize("filtered", [False, True])
def test_vertex_chunked_gather_parity(filtered):
    from repro.kernels import compressed_spmv_vertex_chunked

    g = _graph()
    c = compress(g)
    f = make_filter(g) if filtered else None
    rng = np.random.default_rng(13)
    frontier = jnp.asarray(rng.random(g.n) < 0.1)
    x = jnp.asarray(rng.standard_normal(g.n), jnp.float32)
    row = compressed_spmv_vertex_chunked(c, x, frontier, f, gather_tiles=False)
    til = compressed_spmv_vertex_chunked(c, x, frontier, f, gather_tiles=True)
    np.testing.assert_array_equal(np.asarray(row), np.asarray(til))


# ----------------------------------------------------------------------
# Pipelined rounds — bit parity vs the sequential schedule, mesh {2, 4}
# ----------------------------------------------------------------------
_PIPELINE_CODE = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import jax.numpy as jnp
from repro.compat import make_mesh, use_mesh
from repro.core import make_plan
from repro.data import rmat_graph
from repro.algorithms.traversal import bfs, bfs_batched, wbfs, wbfs_batched
from repro.algorithms.eigen import pagerank

g = rmat_graph(256, 1024, weighted=True, seed=3, block_size=64)
mesh = make_mesh(({K},), ("data",))
seq = make_plan(g, mesh=mesh, shard_axes=("data",))
pipe = make_plan(g, mesh=mesh, shard_axes=("data",), pipeline_rounds=True)
assert pipe.pipeline_rounds and not seq.pipeline_rounds
with use_mesh(mesh):
    p1, l1 = bfs(g, 0, plan=seq)
    p2, l2 = bfs(g, 0, plan=pipe)
    assert (p1 == p2).all() and (l1 == l2).all(), "bfs"
    d1 = wbfs(g, 0, plan=seq)
    d2 = wbfs(g, 0, plan=pipe)
    assert (d1 == d2).all(), "wbfs"
    r1, i1 = pagerank(g, plan=seq)
    r2, i2 = pagerank(g, plan=pipe)
    assert (r1 == r2).all() and i1 == i2, "pagerank"
    b1, bl1 = bfs_batched(g, jnp.arange(4), plan=seq)
    b2, bl2 = bfs_batched(g, jnp.arange(4), plan=pipe)
    assert (b1 == b2).all() and (bl1 == bl2).all(), "bfs_batched"
    w1 = wbfs_batched(g, jnp.arange(4), plan=seq)
    w2 = wbfs_batched(g, jnp.arange(4), plan=pipe)
    assert (w1 == w2).all(), "wbfs_batched"
print("OK")
"""


@pytest.mark.parametrize("k", [2, 4])
def test_pipelined_rounds_bit_parity(k):
    assert "OK" in _run(_PIPELINE_CODE.format(K=k))


def test_pipeline_off_mesh1_matches_single_device():
    code = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import jax.numpy as jnp
from repro.compat import make_mesh, use_mesh
from repro.core import make_plan
from repro.data import rmat_graph
from repro.algorithms.traversal import bfs

g = rmat_graph(256, 1024, weighted=True, seed=3, block_size=64)
mesh = make_mesh((1,), ("data",))
pipe = make_plan(g, mesh=mesh, shard_axes=("data",), pipeline_rounds=True)
with use_mesh(mesh):
    p1, l1 = bfs(g, 0, plan=pipe)
p2, l2 = bfs(g, 0)
assert (p1 == p2).all() and (l1 == l2).all()
print("OK")
"""
    assert "OK" in _run(code)
