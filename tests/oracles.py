"""Pure-numpy/scipy reference implementations + validity predicates for the
18 Sage algorithms.  These are the ground truth the JAX engine is tested
against."""
from __future__ import annotations

import numpy as np
import scipy.sparse as sp
import scipy.sparse.csgraph as csg


def to_scipy(g):
    n = g.n
    src = np.asarray(g.edge_src)
    dst = np.asarray(g.edge_dst)
    w = np.asarray(g.edge_w)
    valid = dst < n
    return sp.csr_matrix(
        (w[valid], (src[valid], dst[valid])), shape=(n, n)
    )


def edges_of(g):
    n = g.n
    src = np.asarray(g.edge_src)
    dst = np.asarray(g.edge_dst)
    valid = dst < n
    return src[valid], dst[valid], np.asarray(g.edge_w)[valid]


def adj_sets(g):
    s, d, _ = edges_of(g)
    adj = [set() for _ in range(g.n)]
    for a, b in zip(s, d):
        adj[a].add(int(b))
    return adj


# ---------------- shortest paths ----------------
def bfs_levels(g, src):
    A = to_scipy(g)
    A.data[:] = 1.0
    dist = csg.shortest_path(A, method="BF", unweighted=True, indices=src)
    lev = np.where(np.isinf(dist), -1, dist).astype(np.int64)
    return lev


def dijkstra_int(g, src):
    A = to_scipy(g)
    dist = csg.dijkstra(A, indices=src)
    return dist


def bellman_ford_ref(g, src):
    A = to_scipy(g)
    return csg.shortest_path(A, method="BF", indices=src)


def widest_path_ref(g, src):
    # max-min path: binary-search-free O(n m) DP
    s, d, w = edges_of(g)
    width = np.full(g.n, -np.inf)
    width[src] = np.inf
    for _ in range(g.n):
        nw = np.minimum(width[s], w)
        best = width.copy()
        np.maximum.at(best, d, nw)
        if np.array_equal(best, width):
            break
        width = best
    return width


def betweenness_ref(g, src):
    adj = adj_sets(g)
    n = g.n
    from collections import deque

    sigma = np.zeros(n)
    dist = np.full(n, -1)
    preds = [[] for _ in range(n)]
    sigma[src] = 1
    dist[src] = 0
    q = deque([src])
    order = []
    while q:
        v = q.popleft()
        order.append(v)
        for u in adj[v]:
            if dist[u] < 0:
                dist[u] = dist[v] + 1
                q.append(u)
            if dist[u] == dist[v] + 1:
                sigma[u] += sigma[v]
                preds[u].append(v)
    delta = np.zeros(n)
    for v in reversed(order):
        for p in preds[v]:
            delta[p] += sigma[p] / sigma[v] * (1 + delta[v])
    delta[src] = 0
    return delta


# ---------------- connectivity ----------------
def components_ref(g):
    A = to_scipy(g)
    _, labels = csg.connected_components(A, directed=False)
    # canonicalize: min vertex id per component
    mins = {}
    for v, l in enumerate(labels):
        mins.setdefault(l, v)
    return np.array([mins[l] for l in labels])


def check_spanning_forest(g, parents, labels):
    n = g.n
    parents = np.asarray(parents)
    labels = np.asarray(labels)
    ref = components_ref(g)
    if not np.array_equal(labels, ref):
        return False, "labels mismatch"
    adj = adj_sets(g)
    n_comp = len(set(ref.tolist()))
    n_edges = int(np.sum(parents != np.arange(n)))
    if n_edges != n - n_comp:
        return False, f"edge count {n_edges} != {n - n_comp}"
    for v in range(n):
        p = parents[v]
        if p == v:
            continue
        if p < 0 or int(p) not in adj[v]:
            return False, f"parent edge ({v},{p}) not in graph"
    # acyclicity: follow parents to root
    for v in range(n):
        seen = set()
        u = v
        while parents[u] != u:
            if u in seen:
                return False, "cycle"
            seen.add(u)
            u = parents[u]
    return True, "ok"


def bicomp_ref(g):
    """Iterative Tarjan; returns dict {frozenset((u,v)): comp_id}."""
    adj = [[] for _ in range(g.n)]
    s, d, _ = edges_of(g)
    for a, b in zip(s, d):
        if a < b:
            adj[a].append(int(b))
            adj[b].append(int(a))
    n = g.n
    visited = [False] * n
    disc = [0] * n
    low = [0] * n
    timer = [1]
    comp_of = {}
    cid = [0]
    for root in range(n):
        if visited[root]:
            continue
        stack = [(root, -1, iter(adj[root]))]
        estack = []
        visited[root] = True
        disc[root] = low[root] = timer[0]
        timer[0] += 1
        while stack:
            v, parent, it = stack[-1]
            advanced = False
            for u in it:
                if not visited[u]:
                    estack.append((v, u))
                    visited[u] = True
                    disc[u] = low[u] = timer[0]
                    timer[0] += 1
                    stack.append((u, v, iter(adj[u])))
                    advanced = True
                    break
                elif u != parent and disc[u] < disc[v]:
                    estack.append((v, u))
                    low[v] = min(low[v], disc[u])
            if not advanced:
                stack.pop()
                if stack:
                    pv = stack[-1][0]
                    low[pv] = min(low[pv], low[v])
                    if low[v] >= disc[pv]:
                        # pop bicomp
                        comp = cid[0]
                        cid[0] += 1
                        while estack:
                            e = estack.pop()
                            comp_of[frozenset(e)] = comp
                            if frozenset(e) == frozenset((pv, v)):
                                break
    return comp_of


def check_bicomp(g, slot_labels):
    """slot_labels int[slots]; same undirected edge → same label; partition
    must match Tarjan's."""
    ref = bicomp_ref(g)
    n = g.n
    src = np.asarray(g.edge_src)
    dst = np.asarray(g.edge_dst)
    lab = np.asarray(slot_labels)
    valid = dst < n
    ours = {}
    for a, b, l in zip(src[valid], dst[valid], lab[valid]):
        k = frozenset((int(a), int(b)))
        if k in ours and ours[k] != l:
            return False, f"direction mismatch on {k}"
        ours[k] = l
    if set(ours.keys()) != set(ref.keys()):
        return False, "edge set mismatch"
    # bijection between label sets
    fwd, bwd = {}, {}
    for k, r in ref.items():
        o = ours[k]
        if r in fwd and fwd[r] != o:
            return False, f"ref comp {r} split"
        if o in bwd and bwd[o] != r:
            return False, f"our comp {o} merged"
        fwd[r] = o
        bwd[o] = r
    return True, "ok"


# ---------------- covering ----------------
def check_mis(g, in_set):
    in_set = np.asarray(in_set)
    s, d, _ = edges_of(g)
    if np.any(in_set[s] & in_set[d]):
        return False, "not independent"
    # maximal: every out vertex has an in neighbor
    covered = np.zeros(g.n, dtype=bool)
    np.logical_or.at(covered, d, in_set[s])
    if np.any(~in_set & ~covered):
        bad = np.flatnonzero(~in_set & ~covered)
        # isolated vertices must be in the set
        return False, f"not maximal at {bad[:5]}"
    return True, "ok"


def check_matching(g, partner):
    partner = np.asarray(partner)
    adj = adj_sets(g)
    for v, p in enumerate(partner):
        if p >= 0:
            if partner[p] != v:
                return False, f"asymmetric at {v}"
            if p not in adj[v]:
                return False, f"non-edge match ({v},{p})"
    matched = partner >= 0
    s, d, _ = edges_of(g)
    exposed = ~matched[s] & ~matched[d]
    if np.any(exposed):
        return False, "not maximal"
    return True, "ok"


def check_coloring(g, color):
    color = np.asarray(color)
    if np.any(color < 0):
        return False, "uncolored vertices"
    s, d, _ = edges_of(g)
    if np.any(color[s] == color[d]):
        return False, "adjacent same color"
    deg = np.asarray(g.degrees)
    if np.any(color > deg):
        return False, "color > degree"
    return True, "ok"


def greedy_set_cover_size(g, sets_mask):
    sets_mask = np.asarray(sets_mask)
    adj = adj_sets(g)
    elems = set(
        v
        for v in range(g.n)
        if not sets_mask[v] and any(sets_mask[u] for u in adj[v])
    )
    uncovered = set(elems)
    size = 0
    while uncovered:
        best, gain = -1, 0
        for v in range(g.n):
            if sets_mask[v]:
                gn = len(adj[v] & uncovered)
                if gn > gain:
                    best, gain = v, gn
        if best < 0:
            break
        uncovered -= adj[best]
        size += 1
    return size


def check_set_cover(g, sets_mask, in_cover):
    sets_mask = np.asarray(sets_mask)
    in_cover = np.asarray(in_cover)
    adj = adj_sets(g)
    if np.any(in_cover & ~sets_mask):
        return False, "non-set in cover"
    for v in range(g.n):
        if sets_mask[v]:
            continue
        nbr_sets = [u for u in adj[v] if sets_mask[u]]
        if nbr_sets and not any(in_cover[u] for u in nbr_sets):
            return False, f"element {v} uncovered"
    return True, "ok"


# ---------------- substructure ----------------
def triangles_ref(g):
    A = to_scipy(g)
    A.data[:] = 1.0
    A = ((A + A.T) > 0).astype(np.float64)
    return int(round((A @ A).multiply(A).sum() / 6.0))


def kcore_ref(g):
    adj = adj_sets(g)
    n = g.n
    deg = np.array([len(a) for a in adj])
    core = np.zeros(n, dtype=np.int64)
    alive = np.ones(n, dtype=bool)
    k = 0
    while alive.any():
        mn = deg[alive].min()
        k = max(k, mn)
        peel = [v for v in range(n) if alive[v] and deg[v] <= k]
        while peel:
            nxt = []
            for v in peel:
                if not alive[v]:
                    continue
                core[v] = k
                alive[v] = False
                for u in adj[v]:
                    if alive[u]:
                        deg[u] -= 1
                        if deg[u] <= k:
                            nxt.append(u)
            peel = nxt
    return core


def densest_ref_lower_bound(g):
    """Best density over sequential Charikar peel (≥ ρ*/2)."""
    adj = adj_sets(g)
    n = g.n
    deg = np.array([len(a) for a in adj], dtype=np.float64)
    alive = np.ones(n, dtype=bool)
    m2 = deg.sum()
    best = 0.0
    for _ in range(n):
        na = alive.sum()
        if na == 0:
            break
        best = max(best, m2 / 2.0 / na)
        v = int(np.argmin(np.where(alive, deg, np.inf)))
        alive[v] = False
        m2 -= 2 * deg[v]
        for u in adj[v]:
            if alive[u]:
                deg[u] -= 1
        deg[v] = 0
    return best


def pagerank_ref(g, damping=0.85, iters=100, eps=1e-6):
    s, d, _ = edges_of(g)
    n = g.n
    deg = np.bincount(s, minlength=n).astype(np.float64)
    pr = np.full(n, 1.0 / n)
    for _ in range(iters):
        contrib = np.where(deg > 0, pr / np.maximum(deg, 1), 0.0)
        agg = np.zeros(n)
        np.add.at(agg, d, contrib[s])
        dangling = pr[deg == 0].sum()
        new = (1 - damping) / n + damping * (agg + dangling / n)
        if np.abs(new - pr).sum() < eps:
            pr = new
            break
        pr = new
    return pr


# ---------------- validity for randomized decompositions ----------------
def check_ldd(g, cluster, beta, slack=6.0):
    cluster = np.asarray(cluster)
    if np.any(cluster < 0):
        return False, "unclustered vertices"
    s, d, _ = edges_of(g)
    inter = cluster[s] != cluster[d]
    m = len(s)
    if m and inter.sum() > max(slack * beta * m, 32):
        return False, f"too many inter-cluster edges: {inter.sum()}/{m}"
    # clusters connected: BFS within cluster from center
    adj = adj_sets(g)
    for c in set(cluster.tolist()):
        members = set(np.flatnonzero(cluster == c).tolist())
        if int(c) not in members:
            return False, f"center {c} not in own cluster"
        seen = {int(c)}
        stack = [int(c)]
        while stack:
            v = stack.pop()
            for u in adj[v]:
                if u in members and u not in seen:
                    seen.add(u)
                    stack.append(u)
        if seen != members:
            return False, f"cluster {c} disconnected"
    return True, "ok"


def check_spanner(g, edge_mask, k, slack=4.0):
    n = g.n
    src = np.asarray(g.edge_src)
    dst = np.asarray(g.edge_dst)
    em = np.asarray(edge_mask)
    valid = dst < n
    Hs, Hd = src[valid & em], dst[valid & em]
    A = to_scipy(g)
    A.data[:] = 1.0
    H = sp.csr_matrix((np.ones(len(Hs)), (Hs, Hd)), shape=(n, n))
    dg = csg.shortest_path(A, unweighted=True)
    dh = csg.shortest_path(H, unweighted=True) if len(Hs) else np.full((n, n), np.inf)
    finite = np.isfinite(dg) & (dg > 0)
    if not np.all(np.isfinite(dh[finite])):
        return False, "spanner disconnects"
    stretch = dh[finite] / dg[finite]
    if stretch.max() > slack * max(k, 1) + 2:
        return False, f"stretch {stretch.max()} too large"
    return True, "ok"
