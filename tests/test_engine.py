"""Distributed graph-engine tests (fake CPU devices via subprocess so the
main test process keeps its single-device view).

The engine functions are thin planner specializations: both backends
(CSRGraph and CompressedCSR) must flow through the same shard_map'd edgeMap
bodies, so every test here runs raw *and* compressed inputs sharded across
a ≥2-device mesh."""
import os
import subprocess
import sys

import pytest

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _run(code: str) -> str:
    r = subprocess.run(
        [sys.executable, "-c", code],
        capture_output=True,
        text=True,
        env={**os.environ, "PYTHONPATH": "src"},
        cwd=ROOT,
        timeout=420,
    )
    assert r.returncode == 0, r.stderr[-3000:]
    return r.stdout


def test_distributed_pagerank_modes_agree():
    out = _run(
        r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import jax, jax.numpy as jnp, numpy as np
from repro.compat import make_mesh, use_mesh
from repro.data import rmat_graph
from repro.core import compress
from repro.distributed.engine import distributed_pagerank_step, prepare_sharded

mesh = make_mesh((2, 2), ("pod", "data"))
g = rmat_graph(128, 512, seed=3, block_size=32)
pr = jnp.full(g.n, 1.0 / g.n)
inv = jnp.where(g.degrees > 0, 1.0 / jnp.maximum(g.degrees, 1).astype(jnp.float32), 0.0)

# numpy oracle: one push-style PageRank round
ref = np.zeros(g.n + 1)
src = np.asarray(g.edge_src); dst = np.asarray(g.edge_dst)
valid = dst < g.n
contrib = np.asarray(pr * inv)
np.add.at(ref, dst[valid], contrib[src[valid]])
expect = 0.15 / g.n + 0.85 * ref[:g.n]

for backend in [g, compress(g)]:
    gs = prepare_sharded(mesh, backend)
    outs = {}
    with use_mesh(mesh):
        for mode in ["flat", "hierarchical"]:
            fn = distributed_pagerank_step(mesh, n=g.n, mode=mode)
            outs[mode] = np.asarray(jax.jit(fn)(gs, pr, inv))
    name = type(backend).__name__
    assert np.allclose(outs["flat"], outs["hierarchical"], atol=1e-6), \
        (name, np.abs(outs["flat"] - outs["hierarchical"]).max())
    assert np.allclose(outs["flat"], expect, atol=1e-6), name
print("OK")
"""
    )
    assert "OK" in out


def test_distributed_frontier_min_matches_edgemap():
    out = _run(
        r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import jax, jax.numpy as jnp, numpy as np
from repro.compat import make_mesh, use_mesh
from repro.data import rmat_graph
from repro.core import compress, edgemap_dense, from_indices
from repro.distributed.engine import distributed_frontier_min, prepare_sharded

mesh = make_mesh((4,), ("data",))
g = rmat_graph(128, 512, seed=5, block_size=32)
fr = from_indices(g.n, [0, 5, 9]).mask
x = jnp.arange(g.n, dtype=jnp.int32)
want, touched = edgemap_dense(g, fr, x, monoid="min")
w = np.asarray(want); t = np.asarray(touched)
fn = distributed_frontier_min(mesh, n=g.n)
for backend in [g, compress(g)]:
    gs = prepare_sharded(mesh, backend)
    with use_mesh(mesh):
        got = np.asarray(jax.jit(fn)(gs, x, fr))
    assert np.array_equal(got[t], w[t]), type(backend).__name__
    assert np.all(got[~t] >= 2**31 - 1), type(backend).__name__
print("OK")
"""
    )
    assert "OK" in out


def test_shard_blocks_for_mesh_pads_up():
    """Non-dividing block counts pad with empty blocks, never truncate."""
    out = _run(
        r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
from repro.compat import make_mesh
from repro.distributed.engine import shard_blocks_for_mesh

mesh = make_mesh((4,), ("data",))
assert shard_blocks_for_mesh(mesh, 8) == 8
assert shard_blocks_for_mesh(mesh, 9) == 12   # ceil, not floor
assert shard_blocks_for_mesh(mesh, 1) == 4
mesh2 = make_mesh((2, 2), ("pod", "data"))
assert shard_blocks_for_mesh(mesh2, 9) == 12
assert shard_blocks_for_mesh(mesh2, 9, shard_axes=("pod",)) == 10
print("OK")
"""
    )
    assert "OK" in out


def test_dryrun_artifacts_complete():
    """The 40-cell × 2-mesh dry-run must be complete and all-green."""
    import glob
    import json

    results = glob.glob(os.path.join(ROOT, "results", "dryrun", "*.json"))
    if not results:
        pytest.skip("dry-run results not generated in this environment")
    cells = {}
    for p in results:
        with open(p) as fh:
            r = json.load(fh)
        if r["arch"] == "sage-graph" or "+" in r["shape"]:
            continue  # engine/perf variants tracked separately
        cells[(r["arch"], r["shape"], r["mesh"])] = r
    meshes = {m for _, _, m in cells}
    assert "single_pod_16x16" in meshes and "multi_pod_2x16x16" in meshes
    per_mesh = {}
    for (a, s, m), r in cells.items():
        per_mesh.setdefault(m, []).append(r)
        assert r.get("ok"), (a, s, m, r.get("error", "")[:200])
    for m, rs in per_mesh.items():
        assert len(rs) == 40, (m, len(rs))
