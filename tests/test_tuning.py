"""Measured-cost autotuner: table persistence, crossover math, plan
decisions, auto-vs-explicit parity, executable-cache keys, admission EWMA.

The tuning contracts (ISSUE 7):

* **Persistence** — TuningTable round-trips through JSON bit-for-bit;
  stale ``schema_version`` and missing keys are rejected loudly, never
  silently reinterpreted.
* **Crossover math** — the dense/sparse and streamed/plain flips are
  log-density-interpolated from hand-built sweeps, clamped at degenerate
  sweeps; lookups interpolate and end-clamp.
* **Plan decisions** — ``make_plan(strategy="auto")`` consults the table
  and records a ``TuningDecision`` (source, crossover, host) on the plan;
  explicit knob arguments always win; ``tuning=None`` pins the constants.
* **Parity** — auto is bit-identical to the explicit strategy it selects,
  single and batched (B ∈ {1, 8}), both backends, meshes {1, 2, 4}
  (subprocess leg) — tuning changes WHICH body runs, never what it
  computes.
* **Serving** — the engine's executable cache key includes the plan's
  ``tuning_key`` (zero steady-state retraces, distinct keys per decision);
  ``max_batch`` sizes from the table; admission prices cold requests at
  the flat ``est_rounds`` and warm ones at EWMA-settled observed rounds.
"""
import json
import os
import subprocess
import sys

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import compress, edgemap_reduce, edgemap_reduce_batched, make_plan
from repro.core.plan import ExecutionPlan
from repro.data import rmat_graph
from repro.serving import QueryEngine, ServiceConfig, ServingService
from repro.tuning import (
    DEFAULT_CHUNK_BLOCKS,
    DEFAULT_DENSE_FRAC,
    DEFAULT_EST_ROUNDS,
    DEFAULT_MAX_BATCH,
    SCHEMA_VERSION,
    TuningTable,
    constants_decision,
    crossover_from_sweep,
    default_table,
    dense_frac_from_crossover,
    flavor_crossover_from_sweep,
    hardware_model,
)

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _run(code: str) -> str:
    r = subprocess.run(
        [sys.executable, "-c", code],
        capture_output=True,
        text=True,
        env={**os.environ, "PYTHONPATH": "src"},
        cwd=ROOT,
        timeout=420,
    )
    assert r.returncode == 0, r.stderr[-3000:]
    return r.stdout


def _sweep():
    # dense loses at low density, wins above ~0.3 (sign change in the
    # middle interval)
    return [
        {"density": 0.01, "dense_us": 100.0, "sparse_us": 10.0},
        {"density": 0.1, "dense_us": 100.0, "sparse_us": 60.0},
        {"density": 1.0, "dense_us": 100.0, "sparse_us": 500.0},
    ]


def _table_data(**over):
    entry = {
        "density_sweep": _sweep(),
        "crossover_density": crossover_from_sweep(_sweep()),
        "dense_frac": dense_frac_from_crossover(crossover_from_sweep(_sweep())),
        "chunk_blocks": 64,
        "auto_sparse": "sparse",
        "max_batch": 4,
        **over,
    }
    return {
        "schema_version": SCHEMA_VERSION,
        "host": {"platform": "cpu", "device_kind": "testhost"},
        "hardware": {"peak_flops": 1e12, "hbm_bw": 1e9, "ici_bw": 1e8},
        "backends": {"csr": entry},
    }


# ----------------------------------------------------------------------
# Persistence: JSON round-trip + schema rejection
# ----------------------------------------------------------------------
def test_table_json_roundtrip(tmp_path):
    t = TuningTable.from_dict(_table_data())
    again = TuningTable.loads(t.dumps())
    assert again.to_dict() == t.to_dict()
    path = tmp_path / "table.json"
    t.save(str(path))
    loaded = TuningTable.load(str(path))
    assert loaded.to_dict() == t.to_dict()
    assert loaded.host_key == "cpu/testhost"
    assert loaded.backends() == ["csr"]


def test_stale_schema_rejected(tmp_path):
    data = _table_data()
    data["schema_version"] = SCHEMA_VERSION + 1
    with pytest.raises(ValueError, match="schema_version"):
        TuningTable.from_dict(data)
    path = tmp_path / "stale.json"
    path.write_text(json.dumps(data))
    with pytest.raises(ValueError, match="schema_version"):
        TuningTable.load(str(path))


def test_missing_keys_rejected():
    data = _table_data()
    del data["backends"]["csr"]["dense_frac"]
    with pytest.raises(ValueError, match="missing keys"):
        TuningTable.from_dict(data)
    with pytest.raises(ValueError, match="missing keys"):
        TuningTable.from_dict({"schema_version": SCHEMA_VERSION})
    bad = _table_data()
    bad["backends"]["csr"]["density_sweep"] = []
    with pytest.raises(ValueError, match="empty density sweep"):
        TuningTable.from_dict(bad)


# ----------------------------------------------------------------------
# Crossover math + interpolating lookup
# ----------------------------------------------------------------------
def test_crossover_interpolation_and_clamps():
    d = crossover_from_sweep(_sweep())
    assert 0.1 < d < 1.0  # flips in the top interval
    assert dense_frac_from_crossover(d) == pytest.approx(1.0 / d)
    # dense cheaper everywhere -> lowest measured density
    all_dense = [{"density": x, "dense_us": 1.0, "sparse_us": 9.0} for x in (0.01, 1.0)]
    assert crossover_from_sweep(all_dense) == 0.01
    # sparse cheaper everywhere -> 1.0 (never dense)
    all_sparse = [{"density": x, "dense_us": 9.0, "sparse_us": 1.0} for x in (0.01, 1.0)]
    assert crossover_from_sweep(all_sparse) == 1.0
    assert dense_frac_from_crossover(1e-9) == 1e4  # clamped
    assert dense_frac_from_crossover(2.0) == 1.0


def test_flavor_crossover_from_sweep():
    rows = [
        {"density": 0.001, "sparse_us": 40.0, "sparse_streamed_us": 10.0},
        {"density": 0.05, "sparse_us": 30.0, "sparse_streamed_us": 35.0},
    ]
    d = flavor_crossover_from_sweep(rows)
    assert 0.001 < d < 0.05  # streamed wins below, plain above
    assert flavor_crossover_from_sweep([{"density": 0.01, "sparse_us": 1.0}]) is None
    plain = [{"density": 0.01, "sparse_us": 1.0, "sparse_streamed_us": 2.0}]
    assert flavor_crossover_from_sweep(plain) == 0.0
    streamed = [{"density": 0.01, "sparse_us": 2.0, "sparse_streamed_us": 1.0}]
    assert flavor_crossover_from_sweep(streamed) == 1.0


def test_strategy_us_interpolates_and_clamps():
    t = TuningTable.from_dict(_table_data())
    assert t.strategy_us("csr", "sparse", 1e-5) == 10.0  # end-clamped
    assert t.strategy_us("csr", "sparse", 5.0) == 500.0
    mid = t.strategy_us("csr", "sparse", 0.0316)  # log-midpoint of 0.01, 0.1
    assert mid == pytest.approx(35.0, rel=1e-3)
    assert t.best_strategy("csr", 0.01) == "sparse"
    assert t.best_strategy("csr", 1.0) == "dense"
    with pytest.raises(KeyError):
        t.strategy_us("compressed", "sparse", 0.1)


# ----------------------------------------------------------------------
# Plan decisions: table -> plan knobs, source recorded, overrides win
# ----------------------------------------------------------------------
def test_make_plan_records_measured_decision():
    g = rmat_graph(64, 256, seed=5, block_size=32)
    t = TuningTable.from_dict(_table_data())
    plan = make_plan(g, tuning=t)
    d = plan.decisions
    assert d.source == "measured" and d.table_host == "cpu/testhost"
    assert plan.dense_frac == t.dense_frac("csr") == d.dense_frac
    assert plan.chunk_blocks == 64
    assert d.crossover_density == pytest.approx(t.crossover_density("csr"))
    # the batched threshold falls back to the single-query one when the
    # table has no batched sweep
    assert plan.dense_frac_batched == plan.dense_frac
    # unmeasured backend -> constants decision
    cplan = make_plan(compress(g), tuning=t)
    assert cplan.decisions.source == "constants"
    assert cplan.dense_frac == DEFAULT_DENSE_FRAC


def test_make_plan_explicit_args_beat_table():
    g = rmat_graph(64, 256, seed=5, block_size=32)
    t = TuningTable.from_dict(_table_data())
    plan = make_plan(g, tuning=t, dense_frac=7.0, chunk_blocks=32)
    assert plan.dense_frac == 7.0 and plan.chunk_blocks == 32
    assert plan.dense_frac_batched == 7.0  # explicit pins both predicates
    assert plan.decisions.dense_frac == 7.0
    off = make_plan(g, tuning=None)
    assert off.decisions.source == "constants"
    assert off.dense_frac == DEFAULT_DENSE_FRAC
    assert off.chunk_blocks == DEFAULT_CHUNK_BLOCKS
    with pytest.raises(ValueError, match="tuning"):
        make_plan(g, tuning="bogus")


def test_default_table_ships_and_plans_measured():
    t = default_table()
    assert t.schema_version == SCHEMA_VERSION
    assert set(t.backends()) >= {"csr", "compressed"}
    g = rmat_graph(64, 256, seed=5, block_size=32)
    for backend in (g, compress(g)):
        plan = make_plan(backend)
        assert plan.decisions.source == "measured"
        assert plan.decisions.table_host == t.host_key
        # the calibrated knobs reach the plan AND its cache-key summary
        assert plan.tuning_key[4] == plan.dense_frac
        assert plan.dense_frac == t.dense_frac(plan.backend)
    # hardware model is the table's section over the defaults
    hw = hardware_model()
    assert set(hw) >= {"peak_flops", "hbm_bw", "ici_bw"}


def test_constants_decision_matches_defaults():
    d = constants_decision("csr")
    assert d.source == "constants"
    assert d.dense_frac == DEFAULT_DENSE_FRAC
    assert d.chunk_blocks == DEFAULT_CHUNK_BLOCKS
    assert d.max_batch == DEFAULT_MAX_BATCH
    # the plan dataclass defaults are the same single source of truth
    p = ExecutionPlan()
    assert p.dense_frac == DEFAULT_DENSE_FRAC
    assert p.chunk_blocks == DEFAULT_CHUNK_BLOCKS


# ----------------------------------------------------------------------
# Parity: auto == the explicit strategy it selects, single + batched
# ----------------------------------------------------------------------
@pytest.mark.parametrize("compressed", [False, True], ids=["csr", "compressed"])
def test_auto_bit_identical_to_selected_strategy(compressed):
    g = rmat_graph(128, 512, seed=11, block_size=32)
    backend = compress(g) if compressed else g
    plan = make_plan(backend)  # shipped measured table
    x0 = jnp.arange(backend.n, dtype=jnp.float32)
    deg = np.asarray(backend.degrees)
    for frac, seed in [(0.01, 0), (1.0, 1)]:
        rng = np.random.default_rng(seed)
        mask_np = np.zeros(backend.n, bool)
        k = max(1, int(frac * backend.n))
        mask_np[rng.choice(backend.n, size=k, replace=False)] = True
        mask = jnp.asarray(mask_np)
        # the strategy auto's predicate selects at this density
        want_mode = (
            "dense"
            if float(mask_np @ deg) * plan.dense_frac > backend.m
            else plan.auto_sparse
        )
        got = edgemap_reduce(backend, mask, x0, monoid="min", plan=plan)
        want = edgemap_reduce(backend, mask, x0, monoid="min", mode=want_mode,
                              chunk_blocks=plan.chunk_blocks)
        for a, b in zip(got, want):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


@pytest.mark.parametrize("B", [1, 8])
@pytest.mark.parametrize("compressed", [False, True], ids=["csr", "compressed"])
def test_batched_auto_bit_identical(compressed, B):
    g = rmat_graph(128, 512, seed=13, block_size=32)
    backend = compress(g) if compressed else g
    plan = make_plan(backend)
    xb = jnp.broadcast_to(
        jnp.arange(backend.n, dtype=jnp.float32)[None, :], (B, backend.n)
    )
    deg = np.asarray(backend.degrees)
    for frac, seed in [(0.01, 0), (1.0, 1)]:
        rng = np.random.default_rng(seed)
        masks_np = np.zeros((B, backend.n), bool)
        k = max(1, int(frac * backend.n))
        for i in range(B):
            masks_np[i, rng.choice(backend.n, size=k, replace=False)] = True
        masks = jnp.asarray(masks_np)
        # all lanes share the density, so batched auto runs one branch:
        # the batched-calibrated threshold and sparse flavor decide it
        dense_lane = float(masks_np[0] @ deg) * plan.dense_frac_batched > backend.m
        want_mode = "dense" if dense_lane else plan.auto_sparse_batched
        got = edgemap_reduce_batched(backend, masks, xb, monoid="min", plan=plan)
        want = edgemap_reduce_batched(
            backend, masks, xb, monoid="min", mode=want_mode,
            chunk_blocks=plan.chunk_blocks,
        )
        for a, b in zip(got, want):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_auto_parity_on_meshes_subprocess():
    """Auto under measured tuning == untuned single-device truth, for mesh
    sizes {1, 2, 4} x both backends x B in {1, 8}."""
    out = _run(
        r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import jax, jax.numpy as jnp, numpy as np
from repro.compat import make_mesh, use_mesh
from repro.data import rmat_graph
from repro.core import compress, make_plan, edgemap_reduce, edgemap_reduce_batched

g = rmat_graph(128, 512, seed=17, block_size=32)
c = compress(g)
n = g.n
x0 = jnp.arange(n, dtype=jnp.float32)
rng = np.random.default_rng(0)
mask_np = rng.random(n) < 0.05
mask = jnp.asarray(mask_np)
want = edgemap_reduce(g, mask, x0, monoid="min", mode="sparse")
for B in (1, 8):
    masks = jnp.broadcast_to(mask[None, :], (B, n))
    xb = jnp.broadcast_to(x0[None, :], (B, n))
    want_b = edgemap_reduce_batched(g, masks, xb, monoid="min", mode="sparse")
    for shape in [(1,), (2,), (4,)]:
        mesh = make_mesh(shape, ("data",))
        for backend in (g, c):
            plan = make_plan(backend, mesh=mesh)
            assert plan.decisions.source == "measured", plan.decisions
            gs = plan.prepare(backend)
            with use_mesh(mesh):
                out = edgemap_reduce(gs, mask, x0, monoid="min", plan=plan)
                out_b = edgemap_reduce_batched(gs, masks, xb, monoid="min", plan=plan)
            name = (B, shape, type(backend).__name__)
            for a, b in zip(out, want):
                assert np.array_equal(np.asarray(a), np.asarray(b)), name
            for a, b in zip(out_b, want_b):
                assert np.array_equal(np.asarray(a), np.asarray(b)), name
print("OK")
"""
    )
    assert "OK" in out


# ----------------------------------------------------------------------
# Serving: cache keys, max_batch sizing, admission EWMA
# ----------------------------------------------------------------------
def test_engine_cache_key_includes_tuning_and_never_retraces():
    g = rmat_graph(128, 512, seed=7, block_size=32)
    plan = make_plan(g)
    eng = QueryEngine(g, plan=plan)
    for _ in range(3):  # steady state: same decision -> zero retraces
        eng.submit("bfs", src=0)
        eng.submit("bfs", src=3)
        eng.flush()
    assert all(v == 1 for v in eng.trace_counts.values())
    assert all(k[2] == plan.tuning_key for k in eng.trace_counts)
    # a different tuning decision is a different executable cache key
    plan2 = make_plan(g, tuning=None)
    assert plan2.tuning_key != plan.tuning_key
    eng2 = QueryEngine(g, plan=plan2)
    eng2.submit("bfs", src=0)
    eng2.flush()
    assert all(k[2] == plan2.tuning_key for k in eng2.trace_counts)


def test_engine_max_batch_sized_from_table():
    g = rmat_graph(128, 512, seed=7, block_size=32)
    t = TuningTable.from_dict(_table_data())  # max_batch = 4
    plan = make_plan(g, tuning=t)
    assert plan.decisions.max_batch == 4
    assert QueryEngine(g, plan=plan).max_batch == 4
    assert QueryEngine(g, plan=plan, max_batch=2).max_batch == 2  # arg wins
    # a measured plan carries the table's knee; plan-less engines and
    # constants-only plans stay at the static default
    assert make_plan(g).decisions.max_batch == default_table().max_batch("csr")
    assert QueryEngine(g).max_batch == DEFAULT_MAX_BATCH
    assert QueryEngine(g, plan=make_plan(g, tuning=None)).max_batch == (
        DEFAULT_MAX_BATCH
    )


def test_admission_prices_cold_flat_and_warm_ewma():
    g = rmat_graph(128, 512, seed=7, block_size=32)
    svc = ServingService(g, config=ServiceConfig(slo=0.05))
    cold = svc._estimate_words("bfs")
    assert cold == pytest.approx(
        svc._round_words * DEFAULT_EST_ROUNDS / svc.max_batch
    )
    t = svc.submit("bfs", src=0, now=0.0)
    assert t.est_words == pytest.approx(cold)
    svc.tick(0.06)  # drain: rounds observed, EWMA seeded
    key = ("bfs", svc.engine._backend_key)
    assert key in svc.observed_rounds
    warm = svc._estimate_words("bfs")
    assert warm == pytest.approx(
        svc._round_words * svc.observed_rounds[key] / svc.max_batch
    )
    assert warm != pytest.approx(cold)  # a real BFS is not 8 rounds deep
    # EWMA: a second identical drain keeps the settled value stable
    before = svc.observed_rounds[key]
    svc.submit("bfs", src=0, now=1.0)
    svc.tick(1.06)
    after = svc.observed_rounds[key]
    assert after == pytest.approx(before, rel=0.5)
    # ...and an unseen op is still priced flat
    assert svc._estimate_words("wbfs") == pytest.approx(cold)
