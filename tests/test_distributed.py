"""Distributed runtime tests: trainer, checkpoint/restart, elastic restore,
gradient compression, sharding rules.  Single-device (mesh 1×1) so the pjit
code paths run on CPU."""
import dataclasses
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import latest_step, restore_latest, save
from repro.distributed.shardings import (
    LM_RULES,
    axis_rules,
    logical_to_spec,
    spec_tree,
)
from repro.launch.mesh import single_device_mesh
from repro.launch.train import TrainConfig, Trainer
from repro.models import transformer_lm as lm
from repro.optim import dequantize_int8, quantize_int8

CFG = lm.LMConfig(
    name="tiny", n_layers=2, d_model=32, n_heads=4, n_kv_heads=2, d_head=8,
    d_ff=64, vocab=101, dtype="float32", kv_block=8,
)


def make_batch(step):
    k = jax.random.PRNGKey(1000 + step)
    toks = jax.random.randint(k, (4, 16), 0, 101)
    return {"tokens": toks, "targets": jnp.roll(toks, -1, axis=1)}


def test_trainer_loss_decreases():
    tc = TrainConfig(steps=30, warmup=3, log_every=1,
                     adamw=dataclasses.replace(TrainConfig().adamw, lr=3e-3))
    tr = Trainer(lm, CFG, train_cfg=tc)

    def fixed_batch(step):
        return make_batch(0)  # overfit one batch

    _, _, hist = tr.fit(fixed_batch, steps=30)
    assert hist[-1]["loss"] < hist[0]["loss"] - 0.05


def test_restart_bit_identical():
    with tempfile.TemporaryDirectory() as d:
        tc = TrainConfig(steps=12, ckpt_every=5, warmup=2, fail_at_step=7)
        with pytest.raises(RuntimeError, match="injected failure"):
            Trainer(lm, CFG, train_cfg=tc).fit(make_batch, ckpt_dir=d)
        assert latest_step(d) == 5
        tc2 = TrainConfig(steps=12, ckpt_every=5, warmup=2)
        p_resumed, _, _ = Trainer(lm, CFG, train_cfg=tc2).fit(make_batch, ckpt_dir=d)
    with tempfile.TemporaryDirectory() as d2:
        p_clean, _, _ = Trainer(lm, CFG, train_cfg=tc2).fit(make_batch, ckpt_dir=d2)
    for a, b in zip(jax.tree.leaves(p_resumed), jax.tree.leaves(p_clean)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_checkpoint_gc_and_atomicity():
    with tempfile.TemporaryDirectory() as d:
        tree = {"a": jnp.arange(10), "b": {"c": jnp.ones((3, 3))}}
        for s in [10, 20, 30, 40]:
            save(d, s, tree, keep=2)
        assert latest_step(d) == 40
        restored, step = restore_latest(d, tree)
        assert step == 40
        np.testing.assert_array_equal(np.asarray(restored["a"]), np.arange(10))
        import os

        kept = [x for x in os.listdir(d) if x.startswith("step_")]
        assert len(kept) == 2  # gc keeps trailing 2


def test_trainer_with_mesh_and_accum():
    mesh = single_device_mesh()
    tc = TrainConfig(steps=4, warmup=1, accum=2, log_every=1)
    tr = Trainer(lm, CFG, mesh=mesh, rules=LM_RULES, train_cfg=tc)
    params, opt, hist = tr.fit(make_batch, steps=4)
    assert np.isfinite(hist[-1]["loss"])


def test_accum_matches_full_batch():
    """2-microbatch accumulation == full-batch gradients (same update)."""
    tc1 = TrainConfig(steps=1, warmup=1, accum=1)
    tc2 = TrainConfig(steps=1, warmup=1, accum=2)
    p1, _, _ = Trainer(lm, CFG, train_cfg=tc1).fit(make_batch, steps=1)
    p2, _, _ = Trainer(lm, CFG, train_cfg=tc2).fit(make_batch, steps=1)
    for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p2)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=2e-5)


def test_gradient_compression_roundtrip():
    x = jax.random.normal(jax.random.PRNGKey(0), (128, 64)) * 3.0
    q, s = quantize_int8(x)
    assert q.dtype == jnp.int8
    y = dequantize_int8(q, s)
    # max quantization error = scale/2
    assert float(jnp.max(jnp.abs(y - x))) <= float(s) * 0.51 + 1e-6


def test_compressed_psum_multidevice():
    """int8-compressed mean over a fake 4-device axis."""
    import os
    import subprocess
    import sys

    code = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import jax, jax.numpy as jnp, numpy as np
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P
from repro.compat import make_mesh, use_mesh
from repro.optim import compressed_psum
mesh = make_mesh((4,), ("pod",))
x = jax.random.normal(jax.random.PRNGKey(0), (4, 32))
f = shard_map(lambda a: compressed_psum(a[0], "pod")[None],
              mesh=mesh, in_specs=P("pod"), out_specs=P("pod"))
with use_mesh(mesh):
    got = f(x)
want = jnp.mean(x, axis=0)
err = float(jnp.max(jnp.abs(got - want[None])))
scale = float(jnp.max(jnp.abs(x)))/127.0
assert err <= scale * 1.01, (err, scale)
print("OK")
"""
    r = subprocess.run(
        [sys.executable, "-c", code],
        capture_output=True, text=True,
        env={**os.environ, "PYTHONPATH": "src"},
        cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
    )
    assert "OK" in r.stdout, r.stderr[-2000:]


def test_elastic_carve():
    from repro.distributed.elastic import carve_mesh

    mesh = carve_mesh(1)
    assert mesh.devices.size == 1


def test_axis_rules_resolution():
    mesh = single_device_mesh()  # axes: data, model
    with axis_rules(LM_RULES, mesh):
        spec = logical_to_spec("batch", "seq", "act_embed")
        # 'pod' is not in this mesh → dropped from the batch axes
        assert spec == jax.sharding.PartitionSpec(("data",), None, None)
        tree = spec_tree({"w": ("embed", "ff")})
        assert tree["w"] == jax.sharding.PartitionSpec("data", "model")
    # rules inactive → replicated
    spec = logical_to_spec("batch")
    assert spec == jax.sharding.PartitionSpec(None)
