"""ServingService: deadline/depth drain loop, cohorts, admission, ledgers.

The always-on tier's contracts (ISSUE 6):

* **Trigger edge cases** — empty-queue ticks are free no-ops; a single
  lane hitting its deadline flushes the WHOLE queue (later arrivals ride
  the same sweep); queue depth ≥ depth_trigger flushes immediately; an
  oversize bucket splits at ``max_batch`` under deadline pressure.
* **Parity** — every served lane, BFS or wBFS, mixed into one fused
  cohort, across round quanta and early-exit repacking, is bit-identical
  to its single-query run (same plan, same backend).
* **Early-exit accounting** — a drained lane stops being charged: its
  round count freezes, and the per-round edge-read words split across
  only the still-active lanes, conserving the total exactly.
* **Admission control** — per-tenant PSAM token buckets reject or defer
  work, reserve estimates at submit, and settle against actuals at
  drain (overdrafts repay out of future refills).
* **map_lanes** — the cross-op hook in the batched edgeMap: unselected
  lanes take the identity map bit-exactly, in every execution mode.

The mesh leg runs in a subprocess over fake CPU devices, like
``test_serving``'s.
"""
import os
import subprocess
import sys

import jax.numpy as jnp
import numpy as np
import pytest

from repro.algorithms import (
    bfs,
    traversal_cohort_init,
    traversal_cohort_rounds,
    wbfs,
)
from repro.core import edgemap_reduce_batched
from repro.data import rmat_graph
from repro.serving import ServiceConfig, ServingService

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _run(code: str) -> str:
    r = subprocess.run(
        [sys.executable, "-c", code],
        capture_output=True,
        text=True,
        env={**os.environ, "PYTHONPATH": "src"},
        cwd=ROOT,
        timeout=420,
    )
    assert r.returncode == 0, r.stderr[-3000:]
    return r.stdout


def _graph(weighted=True):
    return rmat_graph(128, 512, weighted=weighted, seed=7, block_size=32)


def _svc(g, **cfg):
    return ServingService(g, config=ServiceConfig(**cfg))


# ----------------------------------------------------------------------
# Flush-trigger edge cases
# ----------------------------------------------------------------------
def test_empty_queue_ticks_are_noops():
    svc = _svc(_graph())
    for now in (0.0, 1.0, 5.0):
        assert svc.tick(now) == []
    assert svc.stats["ticks"] == 3
    assert svc.stats["flushes"] == 0
    assert svc.cost.large_reads == 0


def test_deadline_flush_pulls_in_later_arrivals():
    g = _graph()
    svc = _svc(g, slo=0.05, max_batch=8)
    first = svc.submit("bfs", src=0, now=0.0)
    assert svc.tick(0.02) == []  # neither trigger fired
    late = svc.submit("wbfs", src=9, now=0.04)  # deadline 0.09, not due
    done = svc.tick(0.05)  # first's deadline due -> whole queue drains
    assert {t.id for t in done} == {first.id, late.id}
    assert svc.stats["deadline_flushes"] == 1
    assert svc.stats["depth_flushes"] == 0
    assert late.finished_at == 0.05  # served 40ms before its own deadline


def test_depth_trigger_fires_before_deadline():
    g = _graph()
    svc = _svc(g, slo=10.0, max_batch=4, depth_trigger=4)
    for i in range(4):
        svc.submit("bfs", src=i, now=0.0)
    done = svc.tick(0.0)  # deadlines are 10s away; depth fires
    assert len(done) == 4
    assert svc.stats["depth_flushes"] == 1
    assert svc.stats["deadline_flushes"] == 0


def test_oversize_bucket_splits_at_max_batch_under_deadline():
    g = _graph()
    svc = _svc(g, slo=0.01, max_batch=4, depth_trigger=100)
    tickets = [svc.submit("bfs", src=i, now=0.0) for i in range(6)]
    done = svc.tick(0.011)  # deadline pressure, depth never reached
    assert len(done) == 6
    assert svc.stats["deadline_flushes"] == 1
    # 6 traversal lanes under max_batch=4 -> cohorts of 4 and 2
    for t, s in zip(tickets, range(6)):
        wp, wl = bfs(g, s)
        np.testing.assert_array_equal(np.asarray(t.result[0]), np.asarray(wp))
        np.testing.assert_array_equal(np.asarray(t.result[1]), np.asarray(wl))


def test_single_lane_deadline_while_others_mid_round():
    # one queued lane goes overdue while a prior flush's lanes were long
    # running: the next tick drains it regardless of queue depth 1
    g = _graph()
    svc = _svc(g, slo=0.03, max_batch=8)
    a = svc.submit("wbfs", src=3, now=0.0)
    svc.tick(0.03)  # drains a (deadline)
    b = svc.submit("bfs", src=5, now=0.1)
    assert svc.tick(0.12) == []  # not due
    done = svc.tick(0.13)
    assert [t.id for t in done] == [b.id]
    assert a.status == "done" and b.status == "done"
    assert svc.stats["deadline_flushes"] == 2


# ----------------------------------------------------------------------
# Parity: mixed cohorts, early exit, repacking
# ----------------------------------------------------------------------
@pytest.mark.parametrize("quantum", [1, 3])
def test_mixed_cohort_bit_identical_to_singles(quantum):
    g = _graph(weighted=True)
    svc = _svc(g, slo=0.01, max_batch=8, round_quantum=quantum)
    reqs = [("bfs", 0), ("wbfs", 5), ("bfs", 9), ("wbfs", 17), ("bfs", 33)]
    tickets = [svc.submit(op, src=s, now=0.0) for op, s in reqs]
    done = svc.tick(0.02)
    assert len(done) == len(reqs)
    for t, (op, s) in zip(tickets, reqs):
        if op == "bfs":
            wp, wl = bfs(g, s)
            np.testing.assert_array_equal(np.asarray(t.result[0]), np.asarray(wp))
            np.testing.assert_array_equal(np.asarray(t.result[1]), np.asarray(wl))
        else:
            np.testing.assert_array_equal(
                np.asarray(t.result), np.asarray(wbfs(g, s))
            )


def test_early_exit_freezes_rounds_and_repacks():
    g = _graph(weighted=True)
    # quantum=1 repacks at every opportunity: short BFS lanes exit while
    # the wBFS lanes grind on, and the batch narrows behind them
    svc = _svc(g, slo=0.01, max_batch=8, round_quantum=1)
    ts = [
        svc.submit("bfs", src=0, now=0.0),
        svc.submit("wbfs", src=5, now=0.0),
        svc.submit("bfs", src=9, now=0.0),
        svc.submit("wbfs", src=17, now=0.0),
    ]
    done = svc.tick(0.02)
    assert len(done) == 4
    b_rounds = [t.rounds for t in ts if t.op == "bfs"]
    w_rounds = [t.rounds for t in ts if t.op == "wbfs"]
    assert max(b_rounds) < min(w_rounds)  # BFS exits earlier on this graph
    assert svc.stats["repacks"] >= 1
    assert 0 < svc.occupancy < 1
    # and the early exit is invisible in the results
    np.testing.assert_array_equal(
        np.asarray(ts[1].result), np.asarray(wbfs(g, 5))
    )


def test_word_attribution_conserved_and_early_exit_uncharged():
    g = _graph(weighted=True)
    svc = _svc(g, slo=0.01, max_batch=4, round_quantum=2)
    ts = [
        svc.submit("bfs", src=0, now=0.0, tenant="a"),
        svc.submit("wbfs", src=5, now=0.0, tenant="b"),
        svc.submit("bfs", src=9, now=0.0, tenant="a"),
    ]
    done = svc.tick(0.02)
    total = sum(t.words for t in done)
    expect = svc.stats["cohort_rounds"] * svc._round_words
    assert abs(total - expect) < 1e-6  # every streamed word lands on a lane
    # the long lane pays for the rounds it ran alone
    short = min(ts, key=lambda t: t.rounds)
    long = max(ts, key=lambda t: t.rounds)
    assert long.words > short.words
    assert abs(svc.ledgers.total_charged() - total) < 1e-6


# ----------------------------------------------------------------------
# Admission control
# ----------------------------------------------------------------------
def test_admission_rejects_over_budget_tenant():
    g = _graph()
    svc = _svc(g, budgets={"small": (10.0, 0.0)})
    r = svc.submit("bfs", src=0, tenant="small", now=0.0)
    assert r.status == "rejected"
    assert svc.stats["rejected"] == 1
    ok = svc.submit("bfs", src=0, tenant="other", now=0.0)  # unlimited
    assert ok.status == "queued"
    assert svc.queue_depth == 1  # rejected ticket never queued


def test_admission_defers_until_refill_covers():
    g = _graph(weighted=True)
    cap = 7000.0
    svc = _svc(
        g, budgets={"t": (cap, 2000.0)}, admission="defer", slo=0.1
    )
    a = svc.submit("wbfs", src=1, tenant="t", now=0.0)
    assert a.status == "queued"
    d = svc.submit("wbfs", src=3, tenant="t", now=0.0)
    assert d.status == "deferred"  # reserve holds a's estimate
    out = svc.tick(0.101)
    assert [t.id for t in out] == [a.id]
    # a's actual cost overdrew the bucket; d stays deferred until refills
    # repay the overdraft AND cover d's estimate
    assert svc.ledgers.ledger("t").available < 0
    assert svc.tick(1.0) == [] and d.status == "deferred"
    out = svc.tick(100.0)  # long refill; d admitted, new deadline 100.1
    assert out == [] and d.status == "queued"
    out = svc.tick(100.11)
    assert [t.id for t in out] == [d.id]
    np.testing.assert_array_equal(np.asarray(d.result), np.asarray(wbfs(g, 3)))
    led = svc.ledgers.ledger("t")
    assert abs(led.charged - (a.words + d.words)) < 1e-6


def test_reserve_settles_to_actuals():
    g = _graph()
    svc = _svc(g, budgets={"t": (1e9, 0.0)})
    t = svc.submit("bfs", src=0, tenant="t", now=0.0)
    led = svc.ledgers.ledger("t")
    assert led.available == 1e9 - t.est_words  # estimate reserved
    svc.tick(1.0)
    assert abs(led.available - (1e9 - t.words)) < 1e-6  # settled to actual
    assert abs(led.charged - t.words) < 1e-6


# ----------------------------------------------------------------------
# Engine delegation, occupancy stats
# ----------------------------------------------------------------------
def test_non_traversal_ops_drain_through_engine():
    g = _graph()
    svc = _svc(g, slo=0.01)
    t1 = svc.submit("bfs", src=0, now=0.0)
    t2 = svc.submit("ppr", src=4, now=0.0)
    done = svc.tick(0.02)
    assert {t.id for t in done} == {t1.id, t2.id}
    assert svc.engine.stats["served"] == 1  # only the ppr went engine-side
    assert t2.words > 0
    assert t2.result[0].shape == (g.n,)


def test_engine_stats_track_padded_lanes():
    from repro.serving import QueryEngine

    g = _graph()
    eng = QueryEngine(g, max_batch=8)
    for s in (0, 1, 2):  # k=3 pads to B=4
        eng.submit("bfs", src=s)
    eng.flush()
    assert eng.stats["lanes"] == 4
    assert eng.stats["padded"] == 1
    assert eng.stats["served"] == 3
    assert eng.occupancy == 0.75


def test_service_occupancy_counts_inert_lane_slots():
    g = _graph()
    svc = _svc(g, slo=0.01, max_batch=8, round_quantum=100)
    # quantum too deep to repack: 3 lanes pad to 4, and drained lanes
    # keep occupying columns -> occupancy strictly below 1
    for s in (0, 9, 33):
        svc.submit("bfs", src=s, now=0.0)
    svc.tick(0.02)
    assert svc.stats["repacks"] == 0
    assert 0 < svc.occupancy < 1
    total = svc.stats["lane_rounds_total"]
    active = svc.stats["active_lane_rounds"]
    assert total == 4 * svc.stats["cohort_rounds"]
    assert active < total


# ----------------------------------------------------------------------
# map_lanes + cohort primitives
# ----------------------------------------------------------------------
@pytest.mark.parametrize("mode", ["dense", "chunked", "auto"])
def test_map_lanes_identity_on_unselected(mode):
    g = _graph(weighted=True)
    B, n = 4, g.n
    fm = np.zeros((B, n), bool)
    fm[0, :5] = True
    fm[1, 10:20] = True
    fm[2, 3] = True
    fm[3, 40:60] = True
    fm = jnp.asarray(fm)
    xs = jnp.arange(B * n, dtype=jnp.int32).reshape(B, n) % 97
    add1 = lambda x, w: x + 1
    ml = jnp.asarray([True, False, True, False])
    out, touched = edgemap_reduce_batched(
        g, fm, xs, map_fn=add1, map_lanes=ml, monoid="min", mode=mode
    )
    on, t_on = edgemap_reduce_batched(g, fm, xs, map_fn=add1, monoid="min", mode=mode)
    off, _ = edgemap_reduce_batched(g, fm, xs, monoid="min", mode=mode)
    for b in (0, 2):
        np.testing.assert_array_equal(np.asarray(out[b]), np.asarray(on[b]))
    for b in (1, 3):
        np.testing.assert_array_equal(np.asarray(out[b]), np.asarray(off[b]))
    np.testing.assert_array_equal(np.asarray(touched), np.asarray(t_on))


def test_cohort_pad_lanes_inert_and_uncharged():
    g = _graph(weighted=True)
    state, weighted = traversal_cohort_init(g, ["bfs", "wbfs", "bfs"], [0, 5, -1])
    state, lane_rounds, active = traversal_cohort_rounds(
        g, state, weighted, quantum=64
    )
    lr = np.asarray(lane_rounds)
    assert lr[2] == 0  # src=-1 pad never active
    assert not bool(np.any(np.asarray(active)))
    wp, wl = bfs(g, 0)
    np.testing.assert_array_equal(np.asarray(state["parents"][0]), np.asarray(wp))
    np.testing.assert_array_equal(np.asarray(state["levels"][0]), np.asarray(wl))
    np.testing.assert_array_equal(np.asarray(state["dist"][1]), np.asarray(wbfs(g, 5)))


def test_service_steady_state_never_retraces():
    g = _graph(weighted=True)
    svc = _svc(g, slo=0.01, max_batch=4)
    for rep in range(3):
        now = float(rep)
        for op, s in [("bfs", 0), ("wbfs", 5), ("bfs", 9)]:
            svc.submit(op, src=s, now=now)
        done = svc.tick(now + 0.02)
        assert len(done) == 3
    assert all(c == 1 for c in svc.trace_counts.values())


# ----------------------------------------------------------------------
# Mesh leg (subprocess over fake CPU devices)
# ----------------------------------------------------------------------
def test_service_on_sharded_plan_subprocess():
    out = _run(
        """
import os
os.environ["XLA_FLAGS"] = (
    os.environ.get("XLA_FLAGS", "") + " --xla_force_host_platform_device_count=2"
)
import numpy as np
from repro.core import make_plan
from repro.compat import make_mesh
from repro.data import rmat_graph
from repro.algorithms import bfs, wbfs
from repro.serving import ServingService, ServiceConfig

g = rmat_graph(128, 512, weighted=True, seed=7, block_size=32)
plan = make_plan(g, mesh=make_mesh((2,), ("data",)))
svc = ServingService(g, plan=plan, config=ServiceConfig(slo=0.01, max_batch=4))
t1 = svc.submit("bfs", src=0, now=0.0)
t2 = svc.submit("wbfs", src=5, now=0.0)
done = svc.tick(0.02)
assert len(done) == 2
wp, wl = bfs(g, 0, plan=plan)
np.testing.assert_array_equal(np.asarray(t1.result[0]), np.asarray(wp))
np.testing.assert_array_equal(np.asarray(t1.result[1]), np.asarray(wl))
np.testing.assert_array_equal(np.asarray(t2.result), np.asarray(wbfs(g, 5, plan=plan)))
assert svc.cost.large_reads > 0
print("MESH_SERVICE_OK")
"""
    )
    assert "MESH_SERVICE_OK" in out
