"""Frontier-sparse NVRAM streaming (the sparse_streamed execution mode).

Locks in the PR's three claims:

* the chunked-mode Pallas kernel (PrefetchScalarGridSpec, compacted live-id
  list as the scalar-prefetched operand) is exact — parity with the masked
  full stream on any frontier, filter, weight and exception pattern, single
  and batched;
* ``sparse_streamed`` edgeMap / BFS parity with the un-streamed paths, on
  both backends, single-device and mesh {1, 2, 4};
* live-block-compacted sharding (``compact_live_blocks`` /
  ``prepare(compact_live=True)``) changes which bytes stream, never any
  result, and ``PSAMCost.charge_edgemap_sparse`` charges the streamed
  (live) blocks only — ≤ 1.2× the live-block bytes at 10% frontier density.
"""
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.algorithms import bfs
from repro.algorithms.traversal import bfs_batched
from repro.core import (
    PSAMCost,
    build_csr,
    compact_live_blocks,
    compress,
    edge_active_words,
    edgemap_reduce,
    edgemap_reduce_batched,
    filter_edges_pred,
    make_filter,
)
from repro.core.compressed import decode_block_tile, exception_dense
from repro.core.psam import _block_read_words
from repro.data import rmat_graph
from repro.kernels import (
    compressed_chunked_stream_tile,
    compressed_spmv_vertex_chunked,
)
from repro.kernels.compressed_spmv.ref import compressed_chunked_spmv_ref

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _run(code: str) -> str:
    r = subprocess.run(
        [sys.executable, "-c", code],
        capture_output=True,
        text=True,
        env={**os.environ, "PYTHONPATH": "src"},
        cwd=ROOT,
        timeout=420,
    )
    assert r.returncode == 0, r.stderr[-3000:]
    return r.stdout


def wide_delta_graph(weighted: bool = False):
    """Graph whose encoding needs the ≥2¹⁶-delta COO exception path."""
    n = 70000
    src = np.array([0, 0, 0, 0, 0, 0, 1, 1], np.int64)
    dst = np.array([1, 2, 66000, 66001, 69998, 69999, 3, 69000], np.int64)
    w = np.arange(1, 9, dtype=np.float32) if weighted else None
    return build_csr(n, src, dst, w, block_size=32)


# ----------------------------------------------------------------------
# The chunked-mode kernel: tile decode and per-block sums
# ----------------------------------------------------------------------
def test_chunked_stream_tile_matches_decode_block_tile():
    g = rmat_graph(128, 1024, seed=9, block_size=32)
    c = compress(g)
    rng = np.random.default_rng(0)
    ids = jnp.asarray(
        np.concatenate(
            [
                rng.choice(c.num_blocks, size=6, replace=False),
                [c.num_blocks, c.num_blocks],  # chunk pad → all-sentinel rows
            ]
        ).astype(np.int32)
    )
    dst, w = compressed_chunked_stream_tile(c, ids)
    np.testing.assert_array_equal(
        np.asarray(dst), np.asarray(decode_block_tile(c, ids))
    )
    assert w.shape == dst.shape


def test_chunked_stream_tile_folds_edge_active():
    g = rmat_graph(128, 1024, seed=10, block_size=32)
    c = compress(g)
    rng = np.random.default_rng(1)
    keep = jnp.asarray(rng.random(c.num_blocks * c.block_size) < 0.5)
    words = edge_active_words(keep, c.block_size)
    ids = jnp.arange(8, dtype=jnp.int32)
    dst, _ = compressed_chunked_stream_tile(c, ids, words)
    base = np.asarray(decode_block_tile(c, ids))
    mask = np.asarray(keep).reshape(c.num_blocks, c.block_size)[np.asarray(ids)]
    want = np.where(mask, base, c.n)
    np.testing.assert_array_equal(np.asarray(dst), want)


def test_chunked_stream_tile_patches_exceptions():
    c = compress(wide_delta_graph())
    assert c.n_exceptions > 0 and not exception_dense(c)
    ids = jnp.arange(c.num_blocks + 2, dtype=jnp.int32)  # all blocks + pad
    dst, _ = compressed_chunked_stream_tile(c, ids)
    np.testing.assert_array_equal(
        np.asarray(dst), np.asarray(decode_block_tile(c, ids))
    )


@pytest.mark.parametrize("weighted", [False, True])
@pytest.mark.parametrize("density", [0.05, 0.3, 1.0])
def test_chunked_spmv_matches_masked_full_stream(weighted, density):
    g = rmat_graph(256, 2048, weighted=weighted, seed=11, block_size=32)
    c = compress(g)
    f = make_filter(g)
    rng = np.random.default_rng(int(density * 100))
    frontier = jnp.asarray(rng.random(g.n) < density)
    x = jax.random.normal(jax.random.PRNGKey(0), (g.n,), jnp.float32)
    got = compressed_spmv_vertex_chunked(c, x, frontier, f)
    want = compressed_chunked_spmv_ref(
        c, x, frontier, f.bits, c.block_weights if weighted else None
    )
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-5)


def test_chunked_spmv_filtered_and_batched():
    g = rmat_graph(256, 2048, seed=12, block_size=32)
    c = compress(g)
    f = make_filter(g)
    rng = np.random.default_rng(2)
    frontier = jnp.asarray(rng.random(g.n) < 0.25)
    keep = jnp.asarray(rng.random(c.num_blocks * c.block_size) < 0.6)
    aw = edge_active_words(keep, c.block_size)
    xb = jax.random.normal(jax.random.PRNGKey(1), (3, g.n), jnp.float32)
    got = compressed_spmv_vertex_chunked(c, xb, frontier, f, edge_active=keep)
    want = compressed_chunked_spmv_ref(c, xb, frontier, f.bits, None, aw)
    assert got.shape == (3, g.n)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-5)
    # each batch lane == its own single-query chunked run
    for i in range(3):
        solo = compressed_spmv_vertex_chunked(
            c, xb[i], frontier, f, edge_active=keep
        )
        np.testing.assert_allclose(
            np.asarray(got[i]), np.asarray(solo), rtol=1e-6, atol=1e-6
        )


def test_chunked_spmv_exception_fixup_live_and_dead():
    """Exception blocks patch only when live; dead ones never stream."""
    gw = wide_delta_graph(weighted=True)
    c = compress(gw)
    assert c.n_exceptions > 0
    f = make_filter(gw)
    x = jax.random.normal(jax.random.PRNGKey(2), (gw.n,), jnp.float32)
    for live_vertices in ([0, 1, 5], [5, 7], [0], []):
        frontier = jnp.zeros(gw.n, bool)
        if live_vertices:
            frontier = frontier.at[jnp.array(live_vertices)].set(True)
        got = compressed_spmv_vertex_chunked(c, x, frontier, f)
        want = compressed_chunked_spmv_ref(c, x, frontier, f.bits, c.block_weights)
        np.testing.assert_allclose(
            np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-5
        )


def test_chunked_spmv_exception_dense_falls_back_exact():
    """Exception-dense graphs skip the kernel — same verdict rule as the
    dense-grid wrapper: a function of exception density only."""
    # 20 vertices, each with one true ≥2¹⁶ adjacency gap → 20 exceptions
    # against a 20-block graph: well past the exception_dense threshold
    n = 70000
    src = np.repeat(np.arange(20), 2).astype(np.int64)
    dst = np.stack(
        [np.arange(20) + 1, np.arange(20) + 67000], axis=1
    ).reshape(-1).astype(np.int64)
    g = build_csr(n, src, dst, block_size=32)
    c = compress(g)
    assert exception_dense(c), (c.n_exceptions, c.num_blocks)
    frontier = jnp.zeros(n, bool).at[jnp.array([0, 1, 7])].set(True)
    x = jax.random.normal(jax.random.PRNGKey(3), (n,), jnp.float32)
    got = compressed_spmv_vertex_chunked(c, x, frontier, make_filter(g))
    want = compressed_chunked_spmv_ref(c, x, frontier, make_filter(g).bits)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-5)


# ----------------------------------------------------------------------
# sparse_streamed edgeMap: parity with the un-streamed paths
# ----------------------------------------------------------------------
@pytest.mark.parametrize("compressed", [False, True])
@pytest.mark.parametrize("monoid", ["min", "sum"])
def test_sparse_streamed_edgemap_matches_sparse(compressed, monoid):
    g0 = rmat_graph(256, 2048, weighted=True, seed=13, block_size=32)
    g = compress(g0) if compressed else g0
    rng = np.random.default_rng(4)
    frontier = jnp.asarray(rng.random(g.n) < 0.3)
    if monoid == "min":
        x = jnp.arange(g.n, dtype=jnp.int32)
        map_fn = lambda xs, w: xs  # noqa: E731
    else:
        x = jax.random.normal(jax.random.PRNGKey(4), (g.n,), jnp.float32)
        map_fn = lambda xs, w: xs * w  # noqa: E731
    keep = jnp.asarray(rng.random(g.num_blocks * g.block_size) < 0.7)
    for ea in (None, keep):
        o1, t1 = edgemap_reduce(
            g, frontier, x, monoid=monoid, map_fn=map_fn, edge_active=ea,
            mode="sparse",
        )
        o2, t2 = edgemap_reduce(
            g, frontier, x, monoid=monoid, map_fn=map_fn, edge_active=ea,
            mode="sparse_streamed",
        )
        np.testing.assert_array_equal(np.asarray(t1), np.asarray(t2))
        if monoid == "min":
            np.testing.assert_array_equal(np.asarray(o1), np.asarray(o2))
        else:
            np.testing.assert_allclose(
                np.asarray(o1), np.asarray(o2), rtol=1e-5, atol=1e-5
            )


def test_bfs_frontier_sweep_parity_single_device():
    """BFS through sparse_streamed == BFS through sparse, both backends."""
    g = rmat_graph(256, 1024, seed=7, block_size=32)
    c = compress(g)
    want_p, want_l = bfs(g, 0, mode="sparse")
    for backend in (g, c):
        p, l = bfs(backend, 0, mode="sparse_streamed")
        np.testing.assert_array_equal(np.asarray(p), np.asarray(want_p))
        np.testing.assert_array_equal(np.asarray(l), np.asarray(want_l))


def test_batched_streamed_per_lane_parity():
    """B lanes through one union-live sweep == B single streamed runs."""
    g = rmat_graph(256, 2048, seed=14, block_size=32)
    c = compress(g)
    srcs = [0, 5, 9, 17]
    pb, lb = bfs_batched(c, jnp.array(srcs, jnp.int32), mode="sparse_streamed")
    for i, s in enumerate(srcs):
        ps, ls = bfs(c, s, mode="sparse_streamed")
        np.testing.assert_array_equal(np.asarray(pb[i]), np.asarray(ps))
        np.testing.assert_array_equal(np.asarray(lb[i]), np.asarray(ls))
    # raw edgeMap: int min monoid is exact under identity contributions
    rng = np.random.default_rng(5)
    frm = jnp.asarray(rng.random((3, g.n)) < 0.2)
    xb = jnp.broadcast_to(jnp.arange(g.n, dtype=jnp.int32), (3, g.n))
    ob, tb = edgemap_reduce_batched(c, frm, xb, monoid="min", mode="sparse_streamed")
    for i in range(3):
        o, t = edgemap_reduce(c, frm[i], xb[i], monoid="min", mode="sparse_streamed")
        np.testing.assert_array_equal(np.asarray(ob[i]), np.asarray(o))
        np.testing.assert_array_equal(np.asarray(tb[i]), np.asarray(t))


def test_bfs_frontier_sweep_parity_mesh():
    """The acceptance gate: chunked-mode BFS parity across mesh {1,2,4},
    both backends, under a sparse_streamed-strategy plan."""
    out = _run(
        r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import jax, jax.numpy as jnp, numpy as np
from repro.compat import make_mesh, use_mesh
from repro.data import rmat_graph
from repro.core import compress, make_plan
from repro.algorithms import bfs

g = rmat_graph(256, 1024, seed=7, block_size=32)
c = compress(g)
want_p, want_l = bfs(g, 0, mode="sparse")
for shape in [(1,), (2,), (4,)]:
    mesh = make_mesh(shape, ("data",))
    for backend in [g, c]:
        plan = make_plan(backend, mesh=mesh, strategy="sparse_streamed")
        with use_mesh(mesh):
            p, l = bfs(backend, 0, plan=plan)
        name = (shape, type(backend).__name__)
        assert np.array_equal(np.asarray(p), np.asarray(want_p)), (name, "parents")
        assert np.array_equal(np.asarray(l), np.asarray(want_l)), (name, "levels")
print("OK")
"""
    )
    assert "OK" in out


def test_sharded_streamed_padded_exception_lists():
    """Sharding pads stacked exception lists with sentinel rows whose block
    id equals the shard's block count — the same fill value the streamed
    chunk pad uses, so a pad exception row *matches* a chunk's pad slot.
    ``_rows_for_ids`` guards on ``exc_block < num_blocks`` so that match
    never patches anything (without the guard, correctness would hang on
    ``decode_block``'s out-of-range take filling ``valid_count`` with 0 —
    an accident of jnp.take's fill semantics, not a contract).  This locks
    edgeMap parity on shards whose exception list is pure padding, the
    exact layout ``CompressedCSR.shard`` produces on exception-free
    shards of an exception-carrying graph."""
    out = _run(
        r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import jax.numpy as jnp, numpy as np
from repro.compat import make_mesh, use_mesh
from repro.core import build_csr, compress, make_plan, edgemap_reduce

# 4 blocks: vertex 0 carries the only true >=2^16 gap (1 exception in
# block 0); vertices 1-3 own one ordinary block each.  Sharded over 2,
# shard 1 = {block2, block3} gets a PURE-PADDING exception list (row with
# block id per == 2, the same value the chunk pad uses as fill).
n = 70000
src = np.array([0, 0, 1, 1, 2, 2, 3, 3], np.int64)
dst = np.array([1, 67000, 2, 3, 4, 5, 6, 7], np.int64)
c = compress(build_csr(n, src, dst, block_size=32))
assert c.n_exceptions == 1 and c.num_blocks == 4
x = jnp.arange(n, dtype=jnp.int32)
# frontier {2}: shard 1's live set is {block2} alone, so its single
# 2-wide chunk is [block2, fill] — the fill position matches the pad
# exception row unless the fixup guards on exc_block < num_blocks, and a
# ghost patch would resurrect block3's targets (vertices 6, 7) in touched
fr = jnp.zeros(n, bool).at[jnp.array([2])].set(True)
want_o, want_t = edgemap_reduce(c, fr, x, monoid="min", mode="sparse")
assert not bool(want_t[6]) and not bool(want_t[7])
for shape in [(2,), (4,)]:
    mesh = make_mesh(shape, ("data",))
    plan = make_plan(c, mesh=mesh, strategy="sparse_streamed")
    gs = plan.prepare(c)
    with use_mesh(mesh):
        o, t = edgemap_reduce(gs, fr, x, monoid="min", plan=plan)
    assert np.array_equal(np.asarray(o), np.asarray(want_o)), shape
    assert np.array_equal(np.asarray(t), np.asarray(want_t)), shape
# the wide-gap block itself still patches correctly when live and sharded
fr0 = jnp.zeros(n, bool).at[jnp.array([0, 2])].set(True)
want_o, want_t = edgemap_reduce(c, fr0, x, monoid="min", mode="sparse")
mesh = make_mesh((2,), ("data",))
plan = make_plan(c, mesh=mesh, strategy="sparse_streamed")
gs = plan.prepare(c)
with use_mesh(mesh):
    o, t = edgemap_reduce(gs, fr0, x, monoid="min", plan=plan)
assert np.array_equal(np.asarray(o), np.asarray(want_o))
assert np.array_equal(np.asarray(t), np.asarray(want_t))
print("OK")
"""
    )
    assert "OK" in out


# ----------------------------------------------------------------------
# Live-block-compacted sharding
# ----------------------------------------------------------------------
def _partial_filter(g0):
    f = make_filter(g0)
    f2, _ = filter_edges_pred(g0, f, lambda s, d, w: (d % 4 == 0))
    return f2


@pytest.mark.parametrize("compressed", [False, True])
def test_compact_live_blocks_structure(compressed):
    g0 = rmat_graph(128, 1024, weighted=True, seed=15, block_size=32)
    g = compress(g0) if compressed else g0
    f2 = _partial_filter(g0)
    gl, wl, live = compact_live_blocks(g, f2)
    live_np = np.asarray(live)
    want_live = np.nonzero(np.asarray(f2.bits).any(axis=1))[0]
    np.testing.assert_array_equal(live_np, want_live)
    assert gl.num_blocks == live_np.size == wl.shape[0]
    assert gl.n == g.n and gl.m == g.m
    np.testing.assert_array_equal(
        np.asarray(gl.block_src), np.asarray(g.block_src)[live_np]
    )
    np.testing.assert_array_equal(
        np.asarray(wl), np.asarray(f2.bits)[live_np]
    )
    if compressed:
        # surviving exceptions re-key to compacted positions
        assert gl.n_exceptions <= g.n_exceptions
        eb = np.asarray(gl.exc_block)
        assert ((eb >= 0) & (eb < gl.num_blocks)).all()


def test_compact_live_blocks_dead_filter_degenerates():
    g = rmat_graph(32, 96, seed=1, block_size=32)
    dead = jnp.zeros(g.num_blocks * g.block_size, bool)
    gl, wl, live = compact_live_blocks(g, dead)
    assert gl.num_blocks == 1
    assert int(np.asarray(wl).sum()) == 0  # the survivor block is fully masked


@pytest.mark.parametrize("compressed", [False, True])
@pytest.mark.parametrize("mode", ["dense", "sparse", "sparse_streamed"])
def test_compacted_equals_masked_full_streaming(compressed, mode):
    """The tentpole property, single-device: an edgeMap over the compacted
    live block set equals the filtered edgeMap over the full block set."""
    g0 = rmat_graph(128, 1024, weighted=True, seed=16, block_size=32)
    g = compress(g0) if compressed else g0
    f2 = _partial_filter(g0)
    gl, wl, _ = compact_live_blocks(g, f2)
    x = jnp.arange(g.n, dtype=jnp.int32)
    rng = np.random.default_rng(6)
    frontier = jnp.asarray(rng.random(g.n) < 0.4)
    o1, t1 = edgemap_reduce(g, frontier, x, monoid="min", mode=mode, edge_active=f2)
    o2, t2 = edgemap_reduce(gl, frontier, x, monoid="min", mode=mode, edge_active=wl)
    np.testing.assert_array_equal(np.asarray(o1), np.asarray(o2))
    np.testing.assert_array_equal(np.asarray(t1), np.asarray(t2))


def test_prepare_compact_live_sharded_parity():
    """prepare(compact_live=True): dead blocks never enter a shard's stream
    — fewer blocks per shard, identical results, live_ids audit intact."""
    out = _run(
        r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import jax, jax.numpy as jnp, numpy as np
from repro.compat import make_mesh, use_mesh
from repro.data import rmat_graph
from repro.core import (compress, make_plan, make_filter, filter_edges_pred,
                        edgemap_reduce)

g0 = rmat_graph(256, 1024, seed=17, block_size=32)
c = compress(g0)
f = make_filter(g0)
f2, _ = filter_edges_pred(g0, f, lambda s, d, w: (d % 3 != 1))
live_total = int(np.asarray(f2.bits).any(axis=1).sum())
x = jnp.arange(g0.n, dtype=jnp.int32)
fr = jnp.asarray(np.random.default_rng(3).random(g0.n) < 0.3)
want_o, want_t = edgemap_reduce(c, fr, x, monoid="min", mode="sparse", edge_active=f2)
for shape in [(1,), (2,), (4,)]:
    mesh = make_mesh(shape, ("data",))
    for backend in [g0, c]:
        for strategy in ["dense", "sparse", "sparse_streamed"]:
            plan = make_plan(backend, mesh=mesh, strategy=strategy)
            gs, fa = plan.prepare(backend, edge_active=f2, compact_live=True)
            # the compacted shard ranges partition the LIVE blocks only
            assert gs.blocks_per_shard == -(-live_total // plan.num_shards), (
                shape, strategy, gs.blocks_per_shard, live_total)
            assert fa.live_ids is not None
            assert fa.live_ids.shape == (plan.num_shards, gs.blocks_per_shard)
            ids = np.asarray(fa.live_ids).reshape(-1)
            assert np.array_equal(
                ids[:live_total],
                np.nonzero(np.asarray(f2.bits).any(axis=1))[0])
            assert (ids[live_total:] == backend.num_blocks).all()
            with use_mesh(mesh):
                o, t = edgemap_reduce(gs, fr, x, monoid="min",
                                      edge_active=fa, plan=plan)
            name = (shape, type(backend).__name__, strategy)
            assert np.array_equal(np.asarray(o), np.asarray(want_o)), name
            assert np.array_equal(np.asarray(t), np.asarray(want_t)), name
print("OK")
"""
    )
    assert "OK" in out


# ----------------------------------------------------------------------
# Property test: compacted-id streaming == masked full streaming, random
# filters (hypothesis when installed, fixed-seed sweep otherwise)
# ----------------------------------------------------------------------
def _check_random_filter_streaming(seed, compressed, density):
    g0 = rmat_graph(96, 700, weighted=True, seed=seed % 97, block_size=32)
    g = compress(g0) if compressed else g0
    rng = np.random.default_rng(seed)
    keep = jnp.asarray(rng.random(g.num_blocks * g.block_size) < density)
    frontier = jnp.asarray(rng.random(g.n) < 0.5)
    x = jnp.arange(g.n, dtype=jnp.int32)
    o_ref, t_ref = edgemap_reduce(
        g, frontier, x, monoid="min", mode="sparse", edge_active=keep
    )
    o_s, t_s = edgemap_reduce(
        g, frontier, x, monoid="min", mode="sparse_streamed", edge_active=keep
    )
    np.testing.assert_array_equal(np.asarray(o_ref), np.asarray(o_s))
    np.testing.assert_array_equal(np.asarray(t_ref), np.asarray(t_s))
    gl, wl, _ = compact_live_blocks(g, keep)
    o_c, t_c = edgemap_reduce(
        gl, frontier, x, monoid="min", mode="sparse_streamed", edge_active=wl
    )
    np.testing.assert_array_equal(np.asarray(o_ref), np.asarray(o_c))
    np.testing.assert_array_equal(np.asarray(t_ref), np.asarray(t_c))


try:
    from hypothesis import HealthCheck, given, settings
    from hypothesis import strategies as st

    @settings(
        max_examples=10,
        deadline=None,
        suppress_health_check=[HealthCheck.too_slow, HealthCheck.data_too_large],
    )
    @given(
        seed=st.integers(0, 2**31 - 1),
        compressed=st.booleans(),
        density=st.sampled_from([0.05, 0.3, 0.8]),
    )
    def test_random_filter_streaming_property(seed, compressed, density):
        _check_random_filter_streaming(seed, compressed, density)

except ImportError:  # hypothesis not installed: fixed-seed sweep, no skip

    @pytest.mark.parametrize(
        "seed,compressed,density",
        [
            (0, False, 0.05),
            (1, True, 0.3),
            (2, True, 0.05),
            (3, False, 0.8),
            (4, True, 0.8),
        ],
    )
    def test_random_filter_streaming_property(seed, compressed, density):
        _check_random_filter_streaming(seed, compressed, density)


# ----------------------------------------------------------------------
# PSAM accounting: bytes for streamed (live) blocks only
# ----------------------------------------------------------------------
def test_psam_charge_edgemap_sparse_exact():
    g = rmat_graph(256, 2048, seed=18, block_size=64)
    c = compress(g)
    live, TB = 37, 8
    cost = PSAMCost()
    cost.charge_edgemap_sparse(c, live, tile_blocks=TB)
    streamed = -(-live // TB) * TB  # 40 — the padded chunk count × TB
    assert cost.large_reads == _block_read_words(c, streamed)
    assert cost.small_ops == c.num_blocks + 3 * c.n
    # sharded: each shard rounds its own live range up to whole chunks
    cost4 = PSAMCost()
    cost4.charge_edgemap_sparse(c, live, num_shards=4, tile_blocks=TB)
    per_live = -(-live // 4)                       # 10 live per shard
    per_streamed = -(-per_live // TB) * TB         # 16 streamed per shard
    assert cost4.large_reads == _block_read_words(c, per_streamed * 4)
    assert cost4.small_ops == c.num_blocks + (3 * c.n + 3 * c.n)
    # batch shares the stream: NVRAM side unchanged, DRAM side scales
    costb = PSAMCost()
    costb.charge_edgemap_sparse(c, live, batch=8, tile_blocks=TB)
    assert costb.large_reads == cost.large_reads
    assert costb.small_ops == c.num_blocks + 8 * 3 * c.n


def test_serving_on_sparse_streamed_plan():
    """The QueryEngine drains through the streamed sparse rounds unchanged:
    per-lane parity holds, and the PSAM ledger charges the streamed model
    (a whole BFS costs ~one dense sweep's edge bytes, not sweeps × NB)."""
    from repro.core import make_plan
    from repro.serving import QueryEngine

    g = rmat_graph(128, 512, seed=21, block_size=32)
    c = compress(g)
    eng = QueryEngine(c, plan=make_plan(c, strategy="sparse_streamed"), max_batch=4)
    srcs = (0, 3, 5)
    hs = [eng.submit("bfs", src=s) for s in srcs]
    res = eng.flush()
    for h, s in zip(hs, srcs):
        p, l = res[h]
        wp, wl = bfs(c, s, mode="sparse_streamed")
        np.testing.assert_array_equal(np.asarray(p), np.asarray(wp))
        np.testing.assert_array_equal(np.asarray(l), np.asarray(wl))
    dense_eng = QueryEngine(c, plan=make_plan(c), max_batch=4)
    for s in srcs:
        dense_eng.submit("bfs", src=s)
    dense_eng.flush()
    # the streamed ledger is bounded by min(B, sweeps) dense sweeps — never
    # worse than the dense model, and strictly cheaper once sweeps > B
    assert eng.cost.large_reads <= dense_eng.cost.large_reads
    solo = QueryEngine(c, plan=make_plan(c, strategy="sparse_streamed"), max_batch=1)
    solo_dense = QueryEngine(c, plan=make_plan(c), max_batch=1)
    solo.submit("bfs", src=0)
    solo_dense.submit("bfs", src=0)
    solo.flush()
    solo_dense.flush()
    # B=1: each block streams at most once across the whole drain → a
    # multi-round BFS must charge strictly less than sweeps dense sweeps
    assert solo.cost.large_reads < solo_dense.cost.large_reads


def test_psam_sparse_streamed_bytes_track_live_blocks():
    """The acceptance ratio: at 10% frontier density the streamed bytes are
    ≤ 1.2× the live blocks' bytes — and far below the dense NB charge."""
    g = rmat_graph(1024, 8192, weighted=True, seed=1, block_size=64)
    c = compress(g)
    rng = np.random.default_rng(0)
    frontier = jnp.asarray(rng.random(g.n) < 0.10)
    k = int(jnp.take(frontier, c.block_src, mode="fill", fill_value=False).sum())
    assert k > 0
    streamed, live, dense = PSAMCost(), PSAMCost(), PSAMCost()
    streamed.charge_edgemap_sparse(c, k, tile_blocks=8)
    live.charge_edgemap_sparse(c, k, tile_blocks=1)
    dense.charge_edgemap_dense(c)
    assert streamed.large_reads <= 1.2 * live.large_reads
    assert streamed.large_reads < dense.large_reads / 5
