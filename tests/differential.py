"""Differential edit-script harness for ``repro.delta``.

The contract it checks: after ANY edit script, a :class:`DeltaGraph`
snapshot must answer queries **bit-identically** to a graph rebuilt from
scratch out of the surviving edge set.  This module owns the pieces every
delta test composes:

* ``base_edge_dict`` / ``reference_edges`` — the reference semantics: an
  edit log applied to a plain ``{(u, v): w}`` dict (insert upserts,
  delete pops, self-loops dropped, unweighted graphs pin ``w = 1``) —
  deliberately implemented WITHOUT the overlay, so the two sides of the
  differential share no code.
* ``random_script`` — adversarial scripts: fresh inserts, duplicate
  upserts, self-loops, deletes of base/patch/nonexistent edges, and
  re-inserts of previously deleted edges.
* ``rebuild`` — the from-scratch side (``build_csr`` [+ ``compress``]).
* ``query_results`` — the probe set: BFS parents+levels (int32 min
  monoid — order-insensitive), wBFS distances on integer-valued weights,
  and a full-frontier sum ``edgemap_reduce`` over integer-valued float32
  (totals ≪ 2^24, so float addition is exact regardless of association).
  Only order-insensitive reductions qualify for bit-identity across two
  different block layouts.
"""
import numpy as np

from repro.algorithms import bfs, wbfs
from repro.core import build_csr, compress, edgemap_reduce
from repro.delta import DeltaOverlay


def base_edge_dict(g) -> dict:
    """{(u, v): w} for the live edge slots of a built graph."""
    src = np.asarray(g.edge_src)
    dst = np.asarray(g.edge_dst)
    w = np.asarray(g.edge_w)
    valid = np.asarray(g.edge_valid)
    return {
        (int(u), int(v)): float(x)
        for u, v, x in zip(src[valid], dst[valid], w[valid])
    }


def reference_edges(edges: dict, script, *, weighted: bool) -> dict:
    """Apply an edit script to a plain edge dict (the reference model)."""
    out = dict(edges)
    for e in script:
        kind, u, v = e[0], int(e[1]), int(e[2])
        if kind == "insert":
            if u == v:
                continue
            w = float(e[3]) if len(e) > 3 and weighted else 1.0
            out[(u, v)] = w
        elif kind == "delete":
            out.pop((u, v), None)
        else:
            raise ValueError(f"unknown edit kind {kind!r}")
    return out


def random_script(rng, n: int, edges: dict, num_edits: int, *, weighted: bool):
    """Adversarial edit script exercising every overlay transition."""

    def _w():
        return float(rng.integers(1, 8)) if weighted else 1.0

    keys = list(edges)
    deleted: list[tuple[int, int]] = []
    script = []
    for _ in range(num_edits):
        r = rng.random()
        if r < 0.30:  # fresh insert
            script.append(
                ("insert", int(rng.integers(n)), int(rng.integers(n)), _w())
            )
        elif r < 0.45 and keys:  # duplicate upsert of a live edge
            u, v = keys[int(rng.integers(len(keys)))]
            script.append(("insert", u, v, _w()))
        elif r < 0.50:  # self-loop (must be dropped, like build_csr)
            u = int(rng.integers(n))
            script.append(("insert", u, u, _w()))
        elif r < 0.75 and keys:  # delete a live edge
            k = keys.pop(int(rng.integers(len(keys))))
            deleted.append(k)
            script.append(("delete", *k))
        elif r < 0.90 and deleted:  # re-insert a previously deleted edge
            k = deleted.pop(int(rng.integers(len(deleted))))
            keys.append(k)
            script.append(("insert", *k, _w()))
        else:  # delete an edge that (probably) doesn't exist
            script.append(("delete", int(rng.integers(n)), int(rng.integers(n))))
    return script


def overlay_from_script(base, script) -> DeltaOverlay:
    ov = DeltaOverlay(base)
    ov.apply(script)
    return ov


def rebuild(n, edges: dict, *, block_size: int, weighted: bool, compressed: bool):
    """From-scratch graph over the surviving edge set."""
    items = sorted(edges.items())
    src = np.array([u for (u, _), _ in items], np.int32)
    dst = np.array([v for (_, v), _ in items], np.int32)
    w = np.array([x for _, x in items], np.float32)
    g = build_csr(
        n, src, dst, w if weighted else None,
        block_size=block_size, symmetrize=False,
    )
    return compress(g) if compressed else g


def query_results(g, srcs, *, weighted: bool, mode: str = "auto", plan=None):
    """The probe set as a flat list of numpy arrays (exact reductions only)."""
    out = []
    for s in srcs:
        p, lv = bfs(g, int(s), mode=mode, plan=plan)
        out += [np.asarray(p), np.asarray(lv)]
        if weighted:
            out.append(np.asarray(wbfs(g, int(s), mode=mode, plan=plan)))
    fr = np.ones(g.n, dtype=bool)
    x = (np.arange(g.n) % 7 + 1).astype(np.float32)  # integer-valued, exact
    s, touched = edgemap_reduce(g, fr, x, monoid="sum", mode=mode, plan=plan)
    out += [np.asarray(s), np.asarray(touched)]
    return out


def assert_bit_identical(got, want, context=""):
    assert len(got) == len(want), context
    for i, (a, b) in enumerate(zip(got, want)):
        assert np.array_equal(a, b), (context, i)
