"""Unit tests for the PSAM core engine: CSR build, edgeMap modes,
graphFilter, bucketing, primitives."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    NULL_BUCKET,
    build_csr,
    edge_active_flat,
    edgemap_chunked,
    edgemap_dense,
    edgemap_reduce,
    filter_edges,
    from_indices,
    full,
    make_buckets,
    make_filter,
    pack_vertices,
)
from repro.core.primitives import (
    compact_mask,
    exclusive_scan,
    lowest_set_bit,
    mex_from_forbidden,
    popcount32,
)
from repro.data import rmat_graph, structured_graph


@pytest.fixture(scope="module")
def g():
    return rmat_graph(64, 256, weighted=True, seed=7, block_size=32)


def test_csr_build_roundtrip(g):
    src = np.asarray(g.edge_src)
    dst = np.asarray(g.edge_dst)
    valid = dst < g.n
    assert valid.sum() == g.m
    # every vertex's slots are within its block range
    off = np.asarray(g.offsets)
    deg = np.asarray(g.degrees)
    for v in [0, 1, g.n // 2, g.n - 1]:
        span = src[off[v] : off[v + 1]]
        real = span[span < g.n]
        assert np.all(real == v)
        assert (span == v).sum() == deg[v]


def test_block_structure(g):
    assert g.edge_src.shape[0] == g.num_blocks * g.block_size
    bs = np.asarray(g.block_src)
    bd = np.asarray(g.block_dst)
    owner_ok = (bd < g.n) <= (bs[:, None] < g.n)
    assert owner_ok.all()


def test_edgemap_dense_vs_chunked_all_monoids(g):
    x = jnp.arange(g.n, dtype=jnp.int32)
    xf = jnp.asarray(np.random.default_rng(0).normal(size=g.n), jnp.float32)
    fr = from_indices(g.n, [0, 3, 11]).mask
    for monoid, xx in [("min", x), ("max", x), ("sum", xf)]:
        d, dt = edgemap_dense(g, fr, xx, monoid=monoid)
        c, ct = edgemap_chunked(g, fr, xx, monoid=monoid)
        np.testing.assert_allclose(np.asarray(d), np.asarray(c), rtol=1e-6)
        assert bool(jnp.all(dt == ct))


def test_edgemap_auto_matches(g):
    x = jnp.arange(g.n, dtype=jnp.int32)
    for frontier in [from_indices(g.n, [5]), full(g.n)]:
        a, _ = edgemap_reduce(g, frontier.mask, x, monoid="min", mode="auto")
        d, _ = edgemap_dense(g, frontier.mask, x, monoid="min")
        assert bool(jnp.all(a == d))


def test_edgemap_weighted_map_fn(g):
    x = jnp.zeros(g.n, jnp.float32)
    out, touched = edgemap_dense(
        g, full(g.n).mask, x, monoid="min", map_fn=lambda xs, w: xs + w
    )
    # min over incoming weights
    dst = np.asarray(g.edge_dst)
    w = np.asarray(g.edge_w)
    valid = dst < g.n
    ref = np.full(g.n, np.inf)
    np.minimum.at(ref, dst[valid], w[valid])
    got = np.asarray(out)
    mask = np.asarray(touched)
    np.testing.assert_allclose(got[mask], ref[mask], rtol=1e-6)


def test_filter_roundtrip(g):
    f = make_filter(g)
    assert int(f.num_active_edges) == g.m
    keep = g.edge_valid & (g.edge_dst % 2 == 0)
    f2, remaining = filter_edges(g, f, keep)
    dst = np.asarray(g.edge_dst)
    valid = dst < g.n
    expect = (dst[valid] % 2 == 0).sum()
    assert int(remaining) == expect
    # unpack agrees
    active = np.asarray(edge_active_flat(f2))
    assert active.sum() == expect
    assert not np.any(active & ~np.asarray(keep))


def test_filter_subset_pack(g):
    f = make_filter(g)
    subset = jnp.arange(g.n) < 10
    keep = jnp.zeros(g.edge_src.shape[0], bool)  # delete all edges of subset
    f2 = pack_vertices(g, f, subset, keep)
    deg2 = np.asarray(f2.active_deg)
    deg = np.asarray(g.degrees)
    assert np.all(deg2[:10] == 0)
    assert np.all(deg2[10:] == deg[10:])
    # dirty bits set on neighbors of subset vertices
    src = np.asarray(g.edge_src)
    dst = np.asarray(g.edge_dst)
    valid = dst < g.n
    nbrs = set(dst[valid & (src < 10)].tolist())
    dirty = np.asarray(f2.dirty)
    for v in nbrs:
        assert dirty[v]


def test_filter_edgemap_consistency(g):
    """edgeMap over a filtered graph == edgeMap over the subgraph."""
    f = make_filter(g)
    keep = g.edge_valid & (g.edge_w > 2.0)
    f2, _ = filter_edges(g, f, keep)
    x = jnp.arange(g.n, dtype=jnp.int32)
    got, _ = edgemap_dense(
        g, full(g.n).mask, x, monoid="min", edge_active=edge_active_flat(f2)
    )
    # build the subgraph directly
    src = np.asarray(g.edge_src)
    dst = np.asarray(g.edge_dst)
    w = np.asarray(g.edge_w)
    sel = (dst < g.n) & (w > 2.0)
    g2 = build_csr(g.n, src[sel], dst[sel], w[sel], block_size=32)
    want, _ = edgemap_dense(g2, full(g.n).mask, x, monoid="min")
    assert bool(jnp.all(got == want))


def test_bucketing():
    b = make_buckets(jnp.asarray([3, 1, 1, 7, NULL_BUCKET], dtype=jnp.int32))
    bid, mask, more = b.next_bucket()
    assert int(bid) == 1 and bool(more)
    assert np.array_equal(np.asarray(mask), [False, True, True, False, False])
    b = b.retire(mask)
    bid, mask, more = b.next_bucket()
    assert int(bid) == 3
    b = b.update(mask, jnp.full(5, 9))
    bid, _, _ = b.next_bucket()
    assert int(bid) == 7


def test_primitives():
    pre, tot = exclusive_scan(jnp.asarray([1, 2, 3, 4]))
    assert np.array_equal(np.asarray(pre), [0, 1, 3, 6]) and int(tot) == 10
    idx, cnt = compact_mask(jnp.asarray([True, False, True, True]))
    assert int(cnt) == 3 and np.array_equal(np.asarray(idx)[:3], [0, 2, 3])
    assert int(popcount32(jnp.uint32(0xF0F0F0F0))) == 16
    assert int(lowest_set_bit(jnp.uint32(0b101000))) == 3
    words = jnp.asarray([[0xFFFFFFFF, 0b111]], dtype=jnp.uint32)
    assert int(mex_from_forbidden(words)[0]) == 35


def test_structured_graphs_build():
    for kind in ["path", "star", "cycle", "grid", "two_triangles", "barbell"]:
        g = structured_graph(kind)
        assert g.m > 0 and g.n > 0
