import os
import sys

import pytest

# tests see the real single CPU device (the 512-device XLA flag is set ONLY
# inside launch/dryrun.py, never globally)
sys.path.insert(0, os.path.dirname(__file__))


@pytest.fixture(scope="module", autouse=True)
def _drop_compiled_executables():
    """Release jit caches at every module boundary.

    The suite compiles thousands of executables in one process; XLA:CPU's
    jit code eventually corrupts under that accumulation and segfaults a
    late compile (reproducibly in whichever module runs near the end once
    the suite grows past ~400 tests).  Dropping the pjit caches between
    modules keeps the live-executable population bounded; each module pays
    its own warm-up compiles, which it must survive anyway under -p
    no:randomly orderings.
    """
    yield
    import jax

    jax.clear_caches()
