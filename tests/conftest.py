import os
import sys

# tests see the real single CPU device (the 512-device XLA flag is set ONLY
# inside launch/dryrun.py, never globally)
sys.path.insert(0, os.path.dirname(__file__))
