"""Observability layer (ISSUE 9): registry math, exact PSAM mirroring,
and the locked contract that instrumentation NEVER changes results.

Four contract groups:

* **Registry semantics** — get-or-create idempotence, schema-mismatch
  rejection, label filtering, gauge-NaN-when-unset, prefix reset, and the
  two exposition formats (snapshot dict, Prometheus text).
* **Histogram extraction** — bucket-walk p50/p99 pinned against
  ``numpy.quantile`` to within one bucket's relative width, across
  lognormal/uniform/single-sample shapes.
* **Exact mirroring** — every ``PSAMCost.charge_*`` lands word-for-word in
  the ``sage_psam_*_words_total`` counters; the engine's cache hit/miss
  counters ARE the zero-steady-state-retrace contract.
* **Bit-exactness** — dense / sparse_streamed / pipelined plans, meshes
  {1, 2, 4}, batch widths {1, 8}: identical results under an enabled
  registry and under ``noop_registry()`` (mesh > 1 runs in a subprocess
  with fake CPU devices, like the rest of the mesh suite).
"""
import math
import os
import subprocess
import sys

import numpy as np
import pytest

from repro.core import make_plan
from repro.core.psam import PSAMCost
from repro.data import rmat_graph
from repro.obs import (
    Registry,
    exp_buckets,
    get_registry,
    noop_registry,
    use_registry,
)
from repro.serving import QueryEngine, ServiceConfig, ServingService

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _run(code: str) -> str:
    r = subprocess.run(
        [sys.executable, "-c", code],
        capture_output=True,
        text=True,
        env={**os.environ, "PYTHONPATH": "src"},
        cwd=ROOT,
        timeout=420,
    )
    assert r.returncode == 0, r.stderr[-3000:]
    return r.stdout


def _graph(weighted=True):
    return rmat_graph(256, 1024, weighted=weighted, seed=3, block_size=32)


# ----------------------------------------------------------------------
# Registry semantics
# ----------------------------------------------------------------------
def test_counter_get_or_create_and_labels():
    reg = Registry()
    c = reg.counter("t_total", "help", labels=("op",))
    assert reg.counter("t_total", labels=("op",)) is c
    c.inc(op="bfs")
    c.inc(2, op="bfs")
    c.inc(5, op="wbfs")
    assert c.value(op="bfs") == 3
    assert c.value(op="wbfs") == 5
    assert c.value() == 8  # no filter aggregates every series
    with pytest.raises(ValueError):
        c.inc(-1, op="bfs")
    with pytest.raises(ValueError):
        c.inc()  # missing the declared label
    with pytest.raises(ValueError):
        reg.gauge("t_total")  # kind mismatch on an existing name
    with pytest.raises(ValueError):
        reg.counter("t_total", labels=("tenant",))  # label mismatch


def test_gauge_nan_when_unset():
    reg = Registry()
    ga = reg.gauge("t_g")
    assert math.isnan(ga.value())
    ga.set(2.5)
    ga.add(-1.0)
    assert ga.value() == 1.5


def test_registry_prefix_reset():
    reg = Registry()
    reg.counter("sage_engine_x_total").inc(4)
    reg.counter("sage_service_y_total").inc(7)
    reg.reset(prefix="sage_engine_")
    assert reg.counter("sage_engine_x_total").value() == 0
    assert reg.counter("sage_service_y_total").value() == 7
    reg.reset()
    assert reg.counter("sage_service_y_total").value() == 0


def test_snapshot_and_prometheus_text():
    reg = Registry()
    reg.counter("t_total", "a counter", labels=("op",)).inc(3, op="bfs")
    h = reg.histogram("t_sec", "a hist", buckets=(1.0, 2.0, 4.0))
    h.observe(0.5)
    h.observe(3.0)
    snap = reg.snapshot()
    assert snap["t_total"]["series"]["bfs"] == 3
    hs = snap["t_sec"]["series"][""]
    assert hs["count"] == 2 and hs["sum"] == 3.5
    assert hs["min"] == 0.5 and hs["max"] == 3.0
    text = reg.to_prometheus_text()
    assert '# TYPE t_total counter' in text
    assert 't_total{op="bfs"} 3' in text
    # cumulative buckets: 0.5 ≤ 1.0, 3.0 ≤ 4.0, +Inf carries the total
    assert 't_sec_bucket{le="1"} 1' in text
    assert 't_sec_bucket{le="4"} 2' in text
    assert 't_sec_bucket{le="+Inf"} 2' in text
    assert 't_sec_count 2' in text


def test_noop_registry_reads():
    reg = noop_registry()
    assert reg.enabled is False
    c = reg.counter("anything", labels=("op",))
    c.inc(99, op="bfs")  # discarded
    assert math.isnan(c.value())
    assert c.count() == 0
    assert reg.snapshot() == {}
    assert reg.to_prometheus_text() == ""


def test_use_registry_scopes_the_default():
    outer = get_registry()
    mine = Registry()
    with use_registry(mine):
        assert get_registry() is mine
    assert get_registry() is outer


# ----------------------------------------------------------------------
# Histogram percentile extraction vs numpy
# ----------------------------------------------------------------------
@pytest.mark.parametrize("dist", ["lognormal", "uniform"])
def test_histogram_percentiles_match_numpy(dist):
    rng = np.random.default_rng(11)
    if dist == "lognormal":
        samples = rng.lognormal(mean=-6.0, sigma=1.0, size=4000)
    else:
        samples = rng.uniform(1e-4, 5e-2, size=4000)
    reg = Registry()
    h = reg.histogram("t_sec", buckets=exp_buckets(1e-6, 100.0, per_decade=24))
    for v in samples:
        h.observe(float(v))
    # bucket-walk extraction is exact to one bucket's relative width:
    # 24/decade → ≤ 10% per bucket; allow 2 bucket widths of slack
    ratio = 10.0 ** (1 / 24.0)
    for q in (50.0, 99.0):
        want = float(np.quantile(samples, q / 100.0))
        got = h.percentile(q)
        assert want / ratio**2 <= got <= want * ratio**2, (q, want, got)
    assert h.count() == len(samples)
    assert h.sum() == pytest.approx(samples.sum(), rel=1e-9)


def test_histogram_single_sample_exact():
    reg = Registry()
    h = reg.histogram("t_sec")
    h.observe(0.0123)
    # min/max clamping makes a single-sample series exact at any q
    assert h.percentile(50) == pytest.approx(0.0123)
    assert h.percentile(99) == pytest.approx(0.0123)
    assert math.isnan(reg.histogram("t_empty").percentile(99))


def test_histogram_label_filter_aggregates():
    reg = Registry()
    h = reg.histogram("t_sec", labels=("op",), buckets=(1.0, 10.0))
    for v in (0.5, 0.5, 5.0):
        h.observe(v, op="bfs")
    h.observe(5.0, op="wbfs")
    assert h.count(op="bfs") == 3
    assert h.count() == 4
    with pytest.raises(ValueError):
        h.count(bogus="x")


# ----------------------------------------------------------------------
# Exact PSAM counter mirroring
# ----------------------------------------------------------------------
def test_psam_charges_mirror_exactly():
    g = _graph()
    reg = Registry()
    cost = PSAMCost(registry=reg)
    cost.charge_edgemap_dense(g)
    cost.charge_edgemap_batched(g, 8)
    cost.charge_filter_pack(g, touched_blocks=4)
    cost.charge_small(123)
    reads = reg.counter("sage_psam_large_read_words_total", labels=("charge",))
    small = reg.counter("sage_psam_small_ops_words_total", labels=("charge",))
    writes = reg.counter("sage_psam_large_write_words_total", labels=("charge",))
    # the unlabeled aggregate equals the dataclass fields word for word
    assert reads.value() == cost.large_reads
    assert small.value() == cost.small_ops
    assert writes.value() == cost.large_writes
    # and the per-charge-kind split is disjoint and complete
    kinds = {k for (k,), _ in reads.series()} | {k for (k,), _ in small.series()}
    assert {"edgemap_dense", "edgemap_batched", "filter_pack", "small"} <= kinds
    assert small.value(charge="small") == 123


def test_psam_default_registry_routing():
    g = _graph()
    reg = Registry()
    with use_registry(reg):
        cost = PSAMCost()  # no injected registry → resolves the default
        cost.charge_edgemap_dense(g)
    assert (
        reg.counter("sage_psam_large_read_words_total", labels=("charge",)).value()
        == cost.large_reads
    )


# ----------------------------------------------------------------------
# Engine + service instrumentation
# ----------------------------------------------------------------------
def test_engine_occupancy_nan_when_idle_and_reset_stats():
    g = _graph()
    reg = Registry()
    eng = QueryEngine(g, registry=reg)
    assert math.isnan(eng.occupancy)  # idle engine: no occupancy, not 1.0
    hs = [eng.submit("bfs", src=i) for i in range(3)]
    res = eng.flush()
    assert len(res) == len(hs)
    assert eng.occupancy == pytest.approx(3 / 4)  # 3 real lanes, padded to 4
    assert reg.gauge("sage_engine_occupancy").value() == pytest.approx(3 / 4)
    assert reg.counter("sage_engine_padded_lanes_total").value() == 1
    assert (
        reg.histogram("sage_engine_batch_size", labels=("op",)).count(op="bfs") == 1
    )
    eng.reset_stats()
    assert math.isnan(eng.occupancy)
    assert reg.counter("sage_engine_padded_lanes_total").value() == 0
    # engine-scoped reset leaves other families (PSAM mirror) alone
    assert (
        reg.counter("sage_psam_large_read_words_total", labels=("charge",)).value()
        > 0
    )


def test_engine_cache_counters_are_the_retrace_contract():
    g = _graph()
    reg = Registry()
    eng = QueryEngine(g, registry=reg)
    hits = reg.counter("sage_engine_cache_hits_total", labels=("cache",))
    misses = reg.counter("sage_engine_cache_misses_total", labels=("cache",))
    eng.serve([("bfs", {"src": 0}), ("bfs", {"src": 1})])  # one bucket: B=2
    assert misses.value(cache="engine") == 1
    assert hits.value(cache="engine") == 0
    eng.serve([("bfs", {"src": 2}), ("bfs", {"src": 3})])  # same (op, B) key
    assert misses.value(cache="engine") == 1  # zero steady-state retraces
    assert hits.value(cache="engine") == 1
    assert sum(eng.trace_counts.values()) == 1


def test_service_metrics_populate():
    g = _graph()
    reg = Registry()
    svc = ServingService(
        g,
        config=ServiceConfig(slo=0.01, max_batch=4, budgets={"t1": (1.0, 1.0)}),
        registry=reg,
    )
    assert math.isnan(svc.occupancy)  # idle service: NaN, not 1.0
    svc.submit("bfs", tenant="a", src=0, now=0.0)
    svc.submit("wbfs", tenant="a", src=1, now=0.001)
    svc.submit("bfs", tenant="t1", src=2, now=0.002)  # over budget → rejected
    done = svc.tick(0.02)  # past the deadline
    assert len(done) == 2
    assert reg.counter(
        "sage_service_submitted_total", labels=("op", "tenant")
    ).value() == 3
    adm = reg.counter("sage_service_admission_total", labels=("outcome", "tenant"))
    assert adm.value(outcome="admitted") == 2
    assert adm.value(outcome="rejected", tenant="t1") == 1
    assert reg.counter("sage_service_flushes_total", labels=("cause",)).value(
        cause="deadline"
    ) == 1
    lat = reg.histogram("sage_service_latency_seconds", labels=("op", "tenant"))
    assert lat.count() == 2
    assert lat.count(op="bfs", tenant="a") == 1
    # latency = virtual queue wait + real drain wall: ≥ the virtual wait
    assert lat.percentile(50, op="bfs", tenant="a") >= 0.02 - 0.0
    assert reg.gauge("sage_service_queue_depth").value() == 0
    drift = reg.gauge("sage_psam_drift_words_per_second").value()
    assert drift > 0 and not math.isnan(drift)
    assert 0 < svc.occupancy <= 1
    assert reg.gauge("sage_service_occupancy").value() == pytest.approx(
        svc.occupancy
    )


# ----------------------------------------------------------------------
# Bit-exactness: instrumentation on vs noop, all plan shapes
# ----------------------------------------------------------------------
@pytest.mark.parametrize("strategy", ["dense", "sparse_streamed"])
@pytest.mark.parametrize("B", [1, 8])
def test_results_bit_identical_enabled_vs_noop(strategy, B):
    from repro.algorithms import bfs_batched

    g = _graph()
    plan = make_plan(g, strategy=strategy)
    srcs = list(range(B))

    def run():
        eng = QueryEngine(g, plan=plan)
        return eng.serve([("bfs", {"src": s}) for s in srcs])

    with use_registry(Registry()):
        res_on = run()
    with use_registry(noop_registry()):
        res_off = run()
    direct = bfs_batched(g, np.asarray(srcs, np.int32), plan=plan)
    for i, ((p_on, l_on), (p_off, l_off)) in enumerate(zip(res_on, res_off)):
        assert np.array_equal(np.asarray(p_on), np.asarray(p_off)), (strategy, B, i)
        assert np.array_equal(np.asarray(l_on), np.asarray(l_off)), (strategy, B, i)
        assert np.array_equal(np.asarray(p_on), np.asarray(direct[0][i]))
        assert np.array_equal(np.asarray(l_on), np.asarray(direct[1][i]))


def test_results_bit_identical_sharded_and_pipelined():
    # mesh {2, 4} × pipelined needs fake CPU devices → subprocess, like the
    # rest of the mesh suite
    out = _run(
        """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import numpy as np
from repro.compat import make_mesh, use_mesh
from repro.core import make_plan
from repro.data import rmat_graph
from repro.obs import Registry, noop_registry, use_registry
from repro.serving import QueryEngine

g = rmat_graph(256, 1024, weighted=True, seed=3, block_size=32)
for shape in [(2,), (4,)]:
    for pipe in (False, True):
        mesh = make_mesh(shape, ("data",))
        plan = make_plan(g, mesh=mesh, pipeline_rounds=pipe)
        results = []
        for reg in (Registry(), noop_registry()):
            with use_registry(reg):
                eng = QueryEngine(g, plan=plan)
                results.append(
                    eng.serve([("bfs", {"src": s}) for s in range(8)]
                              + [("wbfs", {"src": 5})])
                )
        on, off = results
        for i, (a, b) in enumerate(zip(on, off)):
            fa, fb = np.asarray(a[0] if isinstance(a, tuple) else a), \
                     np.asarray(b[0] if isinstance(b, tuple) else b)
            assert np.array_equal(fa, fb), (shape, pipe, i)
            if isinstance(a, tuple):
                assert np.array_equal(np.asarray(a[1]), np.asarray(b[1]))
print("OK")
"""
    )
    assert "OK" in out


def test_round_loop_metrics_record_eagerly():
    from repro.algorithms import bfs

    g = _graph(weighted=False)
    reg = Registry()
    with use_registry(reg):
        bfs(g, 0)
    h = reg.get("sage_round_loop_seconds")
    assert h is not None and h.count(path="sequential") >= 1
    rounds = reg.get("sage_round_loop_rounds")
    assert rounds is not None and rounds.count() >= 1
    # BFS on a connected-ish rmat graph runs a plausible round count
    assert 1 <= rounds.percentile(50) <= 256


def test_dump_cli_smoke():
    out = _run(
        "import sys; from repro.obs.dump import main; "
        "sys.exit(main(['--requests', '6', '--n', '128', '--m', '512']))"
    )
    assert "sage_service_latency_seconds" in out
    assert "sage_psam_large_read_words_total" in out
