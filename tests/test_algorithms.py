"""Correctness of the 18 Sage algorithms against numpy/scipy oracles, on
RMAT + structured graphs."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

import oracles as O
from repro.algorithms import (
    bellman_ford,
    betweenness,
    bfs,
    biconnectivity,
    coloring,
    connectivity,
    densest_subgraph,
    kcore,
    ldd,
    maximal_matching,
    mis,
    pagerank,
    pagerank_iteration,
    set_cover,
    spanner,
    spanning_forest,
    triangle_count,
    wbfs,
    widest_path,
)
from repro.data import rmat_graph, structured_graph

KEY = jax.random.PRNGKey(0)


def graphs():
    out = [
        ("rmat48", rmat_graph(48, 160, weighted=True, seed=2, block_size=32)),
        ("rmat96", rmat_graph(96, 420, weighted=True, seed=5, block_size=32)),
    ]
    for kind in ["path", "grid", "two_triangles", "barbell"]:
        out.append((kind, structured_graph(kind, weighted=True)))
    return out


GRAPHS = graphs()


@pytest.mark.parametrize("name,g", GRAPHS, ids=[n for n, _ in GRAPHS])
class TestTraversal:
    def test_bfs(self, name, g):
        p, lev = bfs(g, 0)
        assert np.array_equal(np.asarray(lev), O.bfs_levels(g, 0))
        pa, la = np.asarray(p), np.asarray(lev)
        adj = O.adj_sets(g)
        for v in range(g.n):
            if la[v] > 0:
                assert pa[v] in adj[v] and la[pa[v]] == la[v] - 1

    def test_wbfs(self, name, g):
        d = np.asarray(wbfs(g, 0)).astype(float)
        d[d == 2**31 - 1] = np.inf
        np.testing.assert_allclose(d, O.dijkstra_int(g, 0))

    def test_bellman_ford(self, name, g):
        d, neg = bellman_ford(g, 0)
        assert not bool(neg)
        np.testing.assert_allclose(np.asarray(d), O.bellman_ford_ref(g, 0))

    def test_widest_path(self, name, g):
        np.testing.assert_allclose(
            np.asarray(widest_path(g, 0)), O.widest_path_ref(g, 0)
        )

    def test_betweenness(self, name, g):
        np.testing.assert_allclose(
            np.asarray(betweenness(g, 0)), O.betweenness_ref(g, 0), atol=1e-3
        )


@pytest.mark.parametrize("name,g", GRAPHS, ids=[n for n, _ in GRAPHS])
class TestConnectivity:
    def test_connectivity(self, name, g):
        assert np.array_equal(np.asarray(connectivity(g, KEY)), O.components_ref(g))

    def test_spanning_forest(self, name, g):
        p, lab = spanning_forest(g, KEY)
        ok, msg = O.check_spanning_forest(g, p, lab)
        assert ok, msg

    def test_ldd(self, name, g):
        cl = ldd(g, 0.2, KEY)
        ok, msg = O.check_ldd(g, cl, 0.2)
        assert ok, msg

    def test_spanner(self, name, g):
        em, okflag = spanner(g, 4, KEY)
        assert bool(okflag)
        ok, msg = O.check_spanner(g, em, 4)
        assert ok, msg

    def test_biconnectivity(self, name, g):
        ok, msg = O.check_bicomp(g, biconnectivity(g))
        assert ok, msg


@pytest.mark.parametrize("name,g", GRAPHS, ids=[n for n, _ in GRAPHS])
class TestCovering:
    def test_mis(self, name, g):
        ok, msg = O.check_mis(g, mis(g, KEY))
        assert ok, msg

    def test_matching(self, name, g):
        ok, msg = O.check_matching(g, maximal_matching(g, KEY))
        assert ok, msg

    def test_coloring(self, name, g):
        ok, msg = O.check_coloring(g, coloring(g, num_colors=64))
        assert ok, msg

    def test_set_cover(self, name, g):
        sets_mask = jnp.arange(g.n) < max(4, g.n // 3)
        cov = set_cover(g, sets_mask, KEY)
        ok, msg = O.check_set_cover(g, sets_mask, cov)
        assert ok, msg
        greedy = O.greedy_set_cover_size(g, sets_mask)
        assert int(jnp.sum(cov)) <= max(4 * greedy, greedy + 4)


@pytest.mark.parametrize("name,g", GRAPHS, ids=[n for n, _ in GRAPHS])
class TestSubstructure:
    def test_kcore(self, name, g):
        assert np.array_equal(np.asarray(kcore(g)), O.kcore_ref(g))

    def test_triangles(self, name, g):
        assert triangle_count(g) == O.triangles_ref(g)

    def test_densest(self, name, g):
        mask, rho = densest_subgraph(g)
        lb = O.densest_ref_lower_bound(g)
        assert float(rho) >= lb / 2.002 - 1e-5
        # reported density is achievable by the reported subgraph
        m_sub = 0
        s, d, _ = O.edges_of(g)
        mk = np.asarray(mask)
        m_sub = (mk[s] & mk[d]).sum() / 2
        n_sub = mk.sum()
        assert abs(m_sub / max(n_sub, 1) - float(rho)) < 1e-3


@pytest.mark.parametrize("name,g", GRAPHS, ids=[n for n, _ in GRAPHS])
def test_pagerank(name, g):
    pr, iters = pagerank(g)
    np.testing.assert_allclose(np.asarray(pr), O.pagerank_ref(g), atol=1e-5)
    pr1 = pagerank_iteration(g, jnp.full(g.n, 1.0 / g.n))
    np.testing.assert_allclose(
        np.asarray(pr1), O.pagerank_ref(g, iters=1), atol=1e-6
    )


def test_wbfs_distances_past_bucket_clamp():
    """Bucket ids clamp at NULL_BUCKET-1 (2^30), but distances keep exact
    Dijkstra semantics past that: the body settles only the true minimum
    among the clamped bucket's members."""
    import numpy as np

    from repro.core import build_csr

    n = 10  # path graph, weights 2^27: dist crosses 2^30 at hop 8
    g = build_csr(
        n,
        np.arange(n - 1),
        np.arange(1, n),
        np.full(n - 1, float(1 << 27), np.float32),
        block_size=32,
    )
    d = np.asarray(wbfs(g, 0)).astype(np.int64)
    want = np.arange(n, dtype=np.int64) * (1 << 27)
    assert want[-1] > 2**30
    np.testing.assert_array_equal(d, want)


def test_bellman_ford_negative_cycle():
    import numpy as np

    from repro.core import build_csr

    # 0→1→2→0 with total negative weight, plus 3 connected to 0
    src = np.array([0, 1, 2, 0])
    dst = np.array([1, 2, 0, 3])
    w = np.array([-1.0, -1.0, -1.0, 1.0], dtype=np.float32)
    g = build_csr(4, src, dst, w, block_size=32)
    d, neg = bellman_ford(g, 0)
    assert bool(neg)
    assert np.asarray(d)[1] == -np.inf
