"""Per-architecture smoke tests: REDUCED configs of the same families run
one forward/train step on CPU, asserting output shapes + no NaNs.  The FULL
configs are exercised only via the dry-run (ShapeDtypeStruct, no alloc)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, all_cells

KEY = jax.random.PRNGKey(0)


def _finite_tree(t):
    return all(bool(jnp.all(jnp.isfinite(x))) for x in jax.tree.leaves(t)
               if jnp.issubdtype(x.dtype, jnp.floating))


LM_ARCHS = ["mistral-large-123b", "qwen2-1.5b", "qwen1.5-4b", "dbrx-132b",
            "deepseek-v2-lite-16b"]
GNN_ARCHS = ["pna", "gin-tu", "dimenet", "equiformer-v2"]


@pytest.mark.parametrize("arch", LM_ARCHS)
def test_lm_smoke_train_step(arch):
    m = ARCHS[arch]
    cfg = m.smoke_config()
    batch = m.smoke_batch(KEY)
    mod = m.MODULE
    params = mod.init(KEY, cfg)
    loss, grads = jax.value_and_grad(lambda p: mod.loss_fn(p, batch, cfg))(params)
    assert jnp.isfinite(loss), arch
    assert _finite_tree(grads), arch
    # loss near ln(vocab) at init
    assert abs(float(loss) - np.log(cfg.vocab)) < 1.0


@pytest.mark.parametrize("arch", LM_ARCHS)
def test_lm_smoke_serve_path(arch):
    m = ARCHS[arch]
    cfg = m.smoke_config()
    # no-drop capacity so decode == teacher-forced forward exactly
    if cfg.moe:
        cfg = dataclasses.replace(cfg, capacity_factor=8.0)
    mod = m.MODULE
    params = mod.init(KEY, cfg)
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 12), 0, cfg.vocab)
    logits_p, cache = mod.prefill(params, toks[:, :8], cfg, max_seq=12)
    assert logits_p.shape == (2, cfg.vocab)
    h, _ = mod.forward(params, toks[:, :10], cfg)
    ref = mod.logits_from_hidden(params, h, cfg)
    np.testing.assert_allclose(
        np.asarray(logits_p, np.float32), np.asarray(ref[:, 7], np.float32),
        rtol=2e-3, atol=2e-3,
    )
    lg, cache = mod.decode_step(params, cache, toks[:, 8:9], 8, cfg)
    np.testing.assert_allclose(
        np.asarray(lg, np.float32), np.asarray(ref[:, 8], np.float32),
        rtol=2e-3, atol=2e-3,
    )


@pytest.mark.parametrize("arch", GNN_ARCHS)
def test_gnn_smoke_train_step(arch):
    m = ARCHS[arch]
    cfg = m.smoke_config()
    batch = m.smoke_batch(0)
    mod = m.MODULE
    params = mod.init(KEY, cfg)
    loss, grads = jax.value_and_grad(lambda p: mod.loss_fn(p, batch, cfg))(params)
    assert jnp.isfinite(loss), arch
    assert _finite_tree(grads), arch
    out = mod.forward(params, batch, cfg)
    assert not bool(jnp.any(jnp.isnan(out)))


def test_sasrec_smoke_all_paths():
    m = ARCHS["sasrec"]
    cfg = m.smoke_config()
    mod = m.MODULE
    batch = m.smoke_batch(0)
    params = mod.init(KEY, cfg)
    loss, grads = jax.value_and_grad(lambda p: mod.loss_fn(p, batch, cfg))(params)
    assert jnp.isfinite(loss) and _finite_tree(grads)
    s = mod.serve_scores(params, batch, cfg)
    assert s.shape == (4, cfg.vocab) and not bool(jnp.any(jnp.isnan(s)))
    r = mod.retrieval_scores(params, batch, cfg)
    assert r.shape == batch["candidates"].shape
    # retrieval scores agree with full-catalog scores at the same items
    cand = np.asarray(batch["candidates"])
    sn = np.asarray(s)
    rn = np.asarray(r)
    for b in range(4):
        np.testing.assert_allclose(rn[b], sn[b, cand[b]], rtol=1e-5, atol=1e-5)


def test_cell_grid_complete():
    cells = all_cells()
    assert len(cells) == 40
    archs = {a for a, _ in cells}
    assert len(archs) == 10
    for (a, s), c in cells.items():
        assert c.batch_specs, (a, s)
        assert c.rules, (a, s)
        assert c.kind in ("train", "prefill", "decode", "serve", "retrieval")


def test_gnn_shape_padding_divisible():
    from repro.configs.gnn_common import gnn_shape_dims

    for shape in ["full_graph_sm", "minibatch_lg", "ogb_products", "molecule"]:
        n, e, _ = gnn_shape_dims(shape)
        assert n % 32 == 0, shape
        assert e % 512 == 0, shape


def test_neighbor_sampler_minibatch_lg_shapes():
    from repro.data.neighbor_sampler import padded_sizes, sample_fanout
    from repro.data import rmat_graph

    g = rmat_graph(256, 2048, seed=1, block_size=32)
    offsets = np.zeros(g.n + 1, dtype=np.int64)
    src = np.asarray(g.edge_src)
    dst = np.asarray(g.edge_dst)
    valid = dst < g.n
    deg = np.bincount(src[valid], minlength=g.n)
    np.cumsum(deg, out=offsets[1:])
    order = np.argsort(src[valid], kind="stable")
    tgt = dst[valid][order]
    seeds = np.arange(8)
    nodes, es, ed, nr, er = sample_fanout(offsets, tgt, seeds, (3, 2))
    mn, me = padded_sizes(8, (3, 2))
    assert nodes.shape == (mn,) and es.shape == (me,)
    assert nr <= mn and er <= me
    # all sampled edges reference real local nodes
    assert np.all(es[:er] < nr) and np.all(ed[:er] < nr)
    # sampled edges exist in the original graph
    pairs = set(zip(src[valid].tolist(), dst[valid].tolist()))
    for a, b in zip(es[:er], ed[:er]):
        assert (int(nodes[a]), int(nodes[b])) in pairs
