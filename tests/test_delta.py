"""repro.delta — differential harness, crash recovery, PSAM accounting.

The locked contract (ISSUE 10): serving a mutated graph through the
DRAM delta overlay is **bit-identical** to rebuilding the graph from
scratch — across base backends, execution strategies, batch widths and
meshes — and folding the overlay (``compact``) is the subsystem's ONLY
large-memory write, persisted atomically.  The mesh legs and the crash
injections run in subprocesses (fake devices / real kills), the rest
in-process.
"""
import os
import subprocess
import sys

import numpy as np
import pytest

import differential as dh
from repro.core import PSAMCost, compress
from repro.core.csr import sharded_block_counts
from repro.core.psam import _block_read_words
from repro.data import rmat_graph
from repro.delta import (
    DeltaOverlay,
    compact,
    compact_write_words,
    load_compacted,
)
from repro.obs import Registry, noop_registry
from repro.serving import QueryEngine, ServiceConfig, ServingService
from repro.tuning import OverlayTrigger, constants_overlay_trigger

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _run(code: str, *, expect_rc: int = 0) -> subprocess.CompletedProcess:
    r = subprocess.run(
        [sys.executable, "-c", code],
        capture_output=True,
        text=True,
        env={**os.environ, "PYTHONPATH": f"src{os.pathsep}tests"},
        cwd=ROOT,
        timeout=420,
    )
    assert r.returncode == expect_rc, (r.returncode, r.stderr[-3000:])
    return r


def _scripted(seed, *, weighted, compressed, n=96, m=400, bs=32, edits=120):
    """(overlay, snapshot, rebuilt-graph, surviving-edge-dict) for one seed."""
    g = rmat_graph(n, m, seed=seed, block_size=bs, weighted=weighted)
    base = compress(g) if compressed else g
    edges = dh.base_edge_dict(base)
    rng = np.random.default_rng(seed + 1000)
    script = dh.random_script(rng, n, edges, edits, weighted=weighted)
    ref = dh.reference_edges(edges, script, weighted=weighted)
    ov = dh.overlay_from_script(base, script)
    rb = dh.rebuild(n, ref, block_size=bs, weighted=weighted, compressed=compressed)
    return ov, ov.snapshot(), rb, ref


# ----------------------------------------------------------------------
# overlay semantics
# ----------------------------------------------------------------------
def test_overlay_edit_semantics():
    g = rmat_graph(32, 96, seed=0, block_size=16, weighted=True)
    u0, v0 = int(np.asarray(g.edge_src)[0]), int(np.asarray(g.edge_dst)[0])
    w0 = float(np.asarray(g.edge_w)[0])
    ov = DeltaOverlay(g)
    assert ov.num_patch_edges == 0 and ov.num_tombstones == 0

    ov.insert(5, 5)  # self-loop: dropped, like build_csr
    assert ov.num_patch_edges == 0

    ov.delete(u0, v0)
    assert ov.num_tombstones == 1
    ov.insert(u0, v0, w0)  # re-insert same weight: revives the base slot
    assert ov.num_tombstones == 0 and ov.num_patch_edges == 0

    ov.delete(u0, v0)
    ov.insert(u0, v0, w0 + 3.0)  # different weight: slot stays dead, patch wins
    assert ov.num_tombstones == 1 and ov.num_patch_edges == 1
    assert dict(zip(*[x.tolist() for x in ov.live_edges()[:2]]))  # still coherent

    before = ov.num_patch_edges
    ov.insert(1, 2)
    ov.insert(1, 2)  # duplicate insert upserts, never double-counts
    assert ov.num_patch_edges == before + 1
    ov.delete(1, 2)
    assert ov.num_patch_edges == before

    with pytest.raises(ValueError):
        ov.insert(-1, 2)
    with pytest.raises(ValueError):
        ov.apply([("frobnicate", 1, 2)])


# ----------------------------------------------------------------------
# differential harness: backends x strategies, engine batch widths, mesh
# ----------------------------------------------------------------------
@pytest.mark.parametrize("compressed", [False, True], ids=["csr", "compressed"])
@pytest.mark.parametrize("mode", ["dense", "sparse", "sparse_streamed"])
def test_differential_bit_identity(compressed, mode):
    for seed, weighted in [(3, False), (7, True)]:
        _, dg, rb, _ = _scripted(seed, weighted=weighted, compressed=compressed)
        dh.assert_bit_identical(
            dh.query_results(dg, [0, 5, 11], weighted=weighted, mode=mode),
            dh.query_results(rb, [0, 5, 11], weighted=weighted, mode=mode),
            (compressed, mode, seed),
        )


@pytest.mark.parametrize("max_batch", [1, 8])
def test_differential_batched_engine(max_batch):
    _, dg, rb, _ = _scripted(11, weighted=True, compressed=True)
    reqs = [("bfs", {"src": s}) for s in [0, 3, 9, 14, 21]] + [
        ("wbfs", {"src": s}) for s in [1, 6]
    ]
    got = QueryEngine(dg, max_batch=max_batch, registry=noop_registry()).serve(reqs)
    want = QueryEngine(rb, max_batch=max_batch, registry=noop_registry()).serve(reqs)
    for a, b in zip(got, want):
        fa = a if isinstance(a, tuple) else (a,)
        fb = b if isinstance(b, tuple) else (b,)
        for x, y in zip(fa, fb):
            assert np.array_equal(np.asarray(x), np.asarray(y))


def test_differential_mesh_parity():
    out = _run(
        r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import numpy as np
import differential as dh
from repro.compat import make_mesh, use_mesh
from repro.core import compress, make_plan
from repro.data import rmat_graph

for compressed in (False, True):
    g = rmat_graph(96, 400, seed=5, block_size=32, weighted=True)
    base = compress(g) if compressed else g
    edges = dh.base_edge_dict(base)
    rng = np.random.default_rng(99)
    script = dh.random_script(rng, 96, edges, 120, weighted=True)
    ref = dh.reference_edges(edges, script, weighted=True)
    dg = dh.overlay_from_script(base, script).snapshot()
    rb = dh.rebuild(96, ref, block_size=32, weighted=True, compressed=compressed)
    want = dh.query_results(rb, [0, 7], weighted=True)
    for shape in [(1,), (2,), (4,)]:
        mesh = make_mesh(shape, ("data",))
        plan = make_plan(dg, mesh=mesh)
        assert plan.backend == "delta", plan.backend
        with use_mesh(mesh):
            got = dh.query_results(dg, [0, 7], weighted=True, plan=plan)
        dh.assert_bit_identical(got, want, (compressed, shape))
print("OK")
"""
    )
    assert "OK" in out.stdout


def test_delta_shard_structure():
    _, dg, _, _ = _scripted(2, weighted=False, compressed=True)
    for k in [1, 2, 4]:
        shards = dg.shard(k)
        assert len(shards) == k
        per_b, _ = sharded_block_counts(dg.num_base_blocks, k)
        per_p, _ = sharded_block_counts(dg.num_patch_blocks, k)
        for s in shards:
            assert s.num_base_blocks == per_b
            assert s.num_blocks == per_b + per_p
            assert s.n == dg.n and s.block_size == dg.block_size
        # every live (src, dst) pair survives the partition exactly once
        def live_pairs(d):
            src = np.asarray(d.edge_src)
            dst = np.asarray(d.edge_dst)
            v = np.asarray(d.edge_valid)
            return sorted(zip(src[v].tolist(), dst[v].tolist()))

        merged = sorted(sum((live_pairs(s) for s in shards), []))
        assert merged == live_pairs(dg)


# ----------------------------------------------------------------------
# compaction: bit-identity, rebase, atomic persistence, crash recovery
# ----------------------------------------------------------------------
def test_compact_bit_identity_and_rebase(tmp_path):
    ov, dg, rb, ref = _scripted(13, weighted=True, compressed=True)
    cost = PSAMCost()
    c = compact(ov, cost=cost, ckpt_dir=str(tmp_path), step=0)
    dh.assert_bit_identical(
        dh.query_results(c, [0, 5], weighted=True),
        dh.query_results(rb, [0, 5], weighted=True),
    )
    assert cost.large_writes == compact_write_words(c)
    loaded, step = load_compacted(str(tmp_path))
    assert step == 0
    dh.assert_bit_identical(
        dh.query_results(loaded, [0, 5], weighted=True),
        dh.query_results(c, [0, 5], weighted=True),
    )
    ov2 = DeltaOverlay(c)  # rebase: fresh overlay over the new NVRAM base
    assert ov2.num_patch_edges == 0 and ov2.num_tombstones == 0


_CRASH_SETUP = r"""
import os, sys
import numpy as np
import differential as dh
import repro.checkpoint.ckpt as ck
from repro.core import compress
from repro.data import rmat_graph
from repro.delta import DeltaOverlay, compact

D = sys.argv[-1] if False else os.environ["CKPT_DIR"]
g = rmat_graph(64, 256, seed=21, block_size=32, weighted=False)
base = compress(g)
ov = DeltaOverlay(base)
ov.apply([("insert", 1, 2), ("insert", 3, 4), ("delete",
          int(np.asarray(base.edge_src)[0]), int(np.asarray(base.edge_dst)[0]))])
c0 = compact(ov, ckpt_dir=D, step=0)   # pre-state: published cleanly
ov1 = DeltaOverlay(c0)
ov1.apply([("insert", 5, 6), ("insert", 7, 8)])
"""

_CRASH_MODES = {
    "during_arrays": r"""
def boom(path, **arrs):
    with open(path, "wb") as fh:
        fh.write(b"torn partial garbage")
    os._exit(42)
ck.np.savez = boom
""",
    "before_manifest": r"""
ck.json.dump = lambda *a, **k: os._exit(42)
""",
    "before_publish": r"""
ck.os.replace = lambda *a, **k: os._exit(42)
""",
    "after_publish": r"""
_orig = ck.os.replace
def pub(src, dst):
    _orig(src, dst)
    os._exit(42)
ck.os.replace = pub
""",
}


@pytest.mark.parametrize("mode", sorted(_CRASH_MODES))
def test_crash_recovery_between_checkpoint_writes(mode, tmp_path):
    """Kill the process at each write boundary inside the step-1 save;
    recovery must load EXACTLY the pre- (step 0) or post- (step 1)
    compaction graph — never a torn hybrid."""
    code = (
        _CRASH_SETUP
        + _CRASH_MODES[mode]
        + "\ncompact(ov1, ckpt_dir=D, step=1)\nraise SystemExit('unreachable')\n"
    )
    r = subprocess.run(
        [sys.executable, "-c", code],
        capture_output=True,
        text=True,
        env={
            **os.environ,
            "PYTHONPATH": f"src{os.pathsep}tests",
            "CKPT_DIR": str(tmp_path),
        },
        cwd=ROOT,
        timeout=420,
    )
    assert r.returncode == 42, (r.returncode, r.stderr[-3000:])

    # expected pre/post states, rebuilt deterministically in-process
    g = rmat_graph(64, 256, seed=21, block_size=32, weighted=False)
    base = compress(g)
    ov = DeltaOverlay(base)
    ov.apply([
        ("insert", 1, 2), ("insert", 3, 4),
        ("delete", int(np.asarray(base.edge_src)[0]),
         int(np.asarray(base.edge_dst)[0])),
    ])
    c0 = compact(ov)
    ov1 = DeltaOverlay(c0)
    ov1.apply([("insert", 5, 6), ("insert", 7, 8)])
    c1 = compact(ov1)

    loaded, step = load_compacted(str(tmp_path))
    assert loaded is not None
    want, want_step = (c1, 1) if mode == "after_publish" else (c0, 0)
    assert step == want_step, (mode, step)
    for f in ("block_first", "deltas", "valid_count", "exc_block", "exc_slot",
              "exc_value", "block_src", "degrees"):
        np.testing.assert_array_equal(
            np.asarray(getattr(loaded, f)), np.asarray(getattr(want, f)), err_msg=f
        )
    assert (loaded.n, loaded.m, loaded.num_blocks, loaded.block_size) == (
        want.n, want.m, want.num_blocks, want.block_size
    )


# ----------------------------------------------------------------------
# engine: reset_stats vs in-flight flush (the double-count fix)
# ----------------------------------------------------------------------
def test_reset_stats_mid_flush_defers_until_drain_completes():
    g = rmat_graph(64, 256, seed=4, block_size=32)
    reg = Registry()
    eng = QueryEngine(g, max_batch=4, registry=reg)
    for s in range(6):  # two buckets of (4, 2) lanes
        eng.submit("bfs", src=s)

    orig = eng._run_bucket
    fired = []

    def hijack(op, scalars, chunk):
        out = orig(op, scalars, chunk)
        if not fired:
            fired.append(True)
            eng.reset_stats()  # mid-flush: must defer, not zero under us
            assert eng._reset_deferred  # still pending while draining
        return out

    eng._run_bucket = hijack
    res = eng.flush()
    assert len(res) == 6  # every query still served
    assert not eng._reset_deferred
    # the deferred reset applied AFTER the drain: one clean zero, no
    # straddle where bucket 2's lanes landed in a half-reset window
    for k, v in eng.stats.items():
        assert v == 0, (k, v)
    assert reg.counter(
        "sage_engine_served_total", labels=("op",)
    ).value(op="bfs") == 0.0
    assert reg.counter("sage_engine_lanes_total").value() == 0.0

    # and the engine keeps counting correctly afterwards
    eng._run_bucket = orig
    eng.submit("bfs", src=9)
    eng.flush()
    assert eng.stats["served"] == 1
    assert reg.counter(
        "sage_engine_served_total", labels=("op",)
    ).value(op="bfs") == 1.0


def test_reset_stats_outside_flush_recounts_pending():
    g = rmat_graph(64, 256, seed=4, block_size=32)
    reg = Registry()
    eng = QueryEngine(g, max_batch=4, registry=reg)
    eng.submit("bfs", src=0)
    eng.submit("bfs", src=1)
    eng.submit("bfs", src=2)
    eng.reset_stats()  # immediate — but pending queries stay accounted
    assert eng.stats["submitted"] == 3
    assert reg.counter(
        "sage_engine_submitted_total", labels=("op",)
    ).value(op="bfs") == 3.0
    res = eng.flush()
    assert len(res) == 3
    assert eng.stats["served"] == 3  # submitted == served + pending holds


# ----------------------------------------------------------------------
# PSAM accounting: overlay surcharge exact, compact() the only ω write
# ----------------------------------------------------------------------
@pytest.mark.parametrize("batch,shards", [(1, 1), (8, 1), (4, 2)])
def test_psam_overlay_charge_exact(batch, shards):
    _, dg, _, _ = _scripted(17, weighted=False, compressed=True)
    reg = Registry()
    cost = PSAMCost(registry=reg)
    cost.charge_edgemap_overlay(dg, batch=batch, num_shards=shards)
    _, base_padded = sharded_block_counts(dg.num_base_blocks, shards)
    exp_reads = _block_read_words(dg.base, base_padded)
    exp_small = dg.overlay_small_words + batch * (
        3 * dg.n + (shards - 1) * dg.n
    )
    assert cost.large_reads == exp_reads
    assert cost.small_ops == exp_small
    assert cost.large_writes == 0
    # mirrored exactly into the labeled sage_psam_* counters
    assert reg.counter(
        "sage_psam_large_read_words_total", labels=("charge",)
    ).value(charge="edgemap_overlay") == float(exp_reads)
    assert reg.counter(
        "sage_psam_small_ops_words_total", labels=("charge",)
    ).value(charge="edgemap_overlay") == float(exp_small)


def test_compact_is_the_only_large_write():
    ov, dg, _, _ = _scripted(19, weighted=False, compressed=True)
    reg = Registry()
    cost = PSAMCost(registry=reg)
    # a whole serving day of overlay queries: still zero NVRAM writes
    for b in (1, 4, 8):
        cost.charge_edgemap_overlay(dg, batch=b)
    assert cost.large_writes == 0
    c = compact(ov, cost=cost, registry=reg)
    w = compact_write_words(c)
    assert cost.large_writes == w
    mirror = reg.counter("sage_psam_large_write_words_total", labels=("charge",))
    assert mirror.value(charge="compact") == float(w)
    assert mirror.value() == float(w)  # no other write label exists
    assert reg.counter("sage_delta_compactions_total").value() == 1.0


def test_engine_charges_overlay_not_batched_for_delta():
    _, dg, _, _ = _scripted(23, weighted=False, compressed=True)
    reg = Registry()
    eng = QueryEngine(dg, max_batch=4, registry=reg)
    eng.serve([("bfs", {"src": 0}), ("bfs", {"src": 1})])
    assert eng.cost.large_writes == 0
    assert reg.counter(
        "sage_psam_small_ops_words_total", labels=("charge",)
    ).value(charge="edgemap_overlay") > 0.0
    assert reg.counter(
        "sage_psam_large_read_words_total", labels=("charge",)
    ).value(charge="edgemap_batched") == 0.0


# ----------------------------------------------------------------------
# serving: edit admission, trigger scheduling, persisted compaction
# ----------------------------------------------------------------------
def test_service_edit_admission_reject_only():
    g = compress(rmat_graph(64, 256, seed=6, block_size=32))
    svc = ServingService(
        DeltaOverlay(g),
        config=ServiceConfig(
            admission="defer", budgets={"poor": (1e-6, 0.0)}
        ),
        registry=noop_registry(),
    )
    # edits are never deferred, even under admission="defer"
    assert svc.submit_edit("insert", 1, 2, tenant="poor") is False
    assert svc.stats["edits_rejected"] == 1
    assert svc.stats["edits_applied"] == 0
    assert svc.submit_edit("insert", 1, 2, tenant="rich") is True
    svc.tick(0.0)
    assert svc.stats["edits_applied"] == 1
    with pytest.raises(ValueError):
        svc.submit_edit("upsert", 1, 2)


def test_service_plain_graph_rejects_edits():
    g = compress(rmat_graph(64, 256, seed=6, block_size=32))
    svc = ServingService(g, registry=noop_registry())
    with pytest.raises(TypeError):
        svc.submit_edit("insert", 1, 2)


def test_service_triggered_compaction_persists_and_stays_exact(tmp_path):
    g = compress(rmat_graph(96, 400, seed=8, block_size=32))
    reg = Registry()
    svc = ServingService(
        DeltaOverlay(g),
        config=ServiceConfig(
            slo=0.0,
            compact_trigger=OverlayTrigger(hysteresis=1e-6),
            ckpt_dir=str(tmp_path),
        ),
        registry=reg,
    )
    edges = dh.base_edge_dict(g)
    rng = np.random.default_rng(55)
    script = dh.random_script(rng, 96, edges, 60, weighted=False)
    for e in script:
        svc.submit_edit(e[0], e[1], e[2], now=0.0)
    t = svc.submit("bfs", src=0, now=0.0)
    svc.drain(0.0)
    assert svc.stats["compactions"] >= 1
    assert svc.overlay.num_patch_edges == 0 and svc.overlay.num_tombstones == 0
    # post-compaction service answers == from-scratch rebuild
    ref = dh.reference_edges(edges, script, weighted=False)
    rb = dh.rebuild(96, ref, block_size=32, weighted=False, compressed=True)
    from repro.algorithms import bfs

    want = bfs(rb, 0)
    for a, b in zip(t.result, want):
        assert np.array_equal(np.asarray(a), np.asarray(b))
    # the published checkpoint IS the served base
    loaded, step = load_compacted(str(tmp_path))
    assert loaded is not None and step == svc._compact_step - 1
    got = bfs(loaded, 0)
    for a, b in zip(got, want):
        assert np.array_equal(np.asarray(a), np.asarray(b))
    assert reg.gauge("sage_delta_patch_edges").value() == 0.0
    assert reg.counter("sage_delta_compactions_total").value() >= 1.0


def test_constants_trigger_breakeven_arithmetic():
    _, dg, _, _ = _scripted(29, weighted=False, compressed=True)
    trig = constants_overlay_trigger()
    w = float(dg.compact_write_words)
    ov_words = float(dg.overlay_small_words)
    breakeven = 4.0 * w / ov_words
    assert not trig.should_compact(
        dg, sweeps_since_compact=breakeven * 0.5, omega=4.0
    ) or breakeven * 0.5 <= 1.0
    assert trig.should_compact(
        dg, sweeps_since_compact=breakeven * 2.0 + 1.0, omega=4.0
    )
