"""Compressed execution backend: encode/decode round-trips, edgeMap
equivalence in every mode, algorithm end-to-end parity, the fused
decode+SpMV Pallas kernel, graphFilter composition, and PSAM accounting."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.algorithms import bfs, connectivity, pagerank, pagerank_iteration
from repro.core import (
    CompressedCSR,
    PSAMCost,
    build_csr,
    compress,
    decode_block,
    decode_block_tile,
    decode_blocks,
    edgemap_reduce,
    from_indices,
    full,
    make_filter,
    pack_vertices,
)
from repro.data import rmat_graph
from repro.kernels import compressed_spmv_vertex, spmv_vertex
from repro.kernels.compressed_spmv.compressed_spmv import compressed_block_spmv_pallas
from repro.kernels.compressed_spmv.ref import (
    compressed_block_spmv_ref,
    compressed_spmv_vertex_ref,
)


@pytest.fixture(scope="module")
def g():
    return rmat_graph(64, 256, seed=7, block_size=32)


@pytest.fixture(scope="module")
def c(g):
    return compress(g)


def wide_delta_graph(weighted: bool = False):
    """Graph whose encoding needs the ≥2¹⁶-delta COO exception path."""
    n = 70000
    src = np.array([0, 0, 0, 0, 0, 0, 1, 1], np.int64)
    dst = np.array([1, 2, 66000, 66001, 69998, 69999, 3, 69000], np.int64)
    w = np.arange(1, 9, dtype=np.float32) if weighted else None
    return build_csr(n, src, dst, w, block_size=32)


# ----------------------------------------------------------------------
# Encode/decode round-trips
# ----------------------------------------------------------------------
@pytest.mark.parametrize("n,m,bs", [(32, 96, 32), (64, 256, 32), (128, 700, 64)])
def test_roundtrip_rmat(n, m, bs):
    g = rmat_graph(n, m, seed=n + m, block_size=bs)
    c = compress(g)
    np.testing.assert_array_equal(
        np.asarray(decode_blocks(c)), np.asarray(g.block_dst)
    )
    assert c.compressed_bytes < c.uncompressed_bytes


def test_roundtrip_exception_path():
    g = wide_delta_graph()
    c = compress(g)
    assert c.n_exceptions > 0  # the ≥2^16 gaps must escape
    np.testing.assert_array_equal(
        np.asarray(decode_blocks(c)), np.asarray(g.block_dst)
    )
    # single-block decode agrees too, including on exception blocks
    for bid in [0, int(np.asarray(c.exc_block)[0])]:
        np.testing.assert_array_equal(
            np.asarray(decode_block(c, bid)), np.asarray(g.block_dst)[bid]
        )


def test_decode_block_tile_matches_rows():
    g = wide_delta_graph()
    c = compress(g)
    # unique real bids (the decode_block_tile precondition): both blocks of
    # this graph carry an exception, plus one fill row
    assert set(np.asarray(c.exc_block).tolist()) == {0, 1}
    bids = jnp.asarray([0, 1, c.num_blocks], jnp.int32)
    tile = np.asarray(decode_block_tile(c, bids))
    np.testing.assert_array_equal(tile[0], np.asarray(g.block_dst)[0])
    np.testing.assert_array_equal(tile[1], np.asarray(g.block_dst)[1])
    assert np.all(tile[2] == g.n)  # fill rows decode to all-sentinel


def test_backend_views_match_csr(g, c):
    np.testing.assert_array_equal(np.asarray(c.edge_dst), np.asarray(g.edge_dst))
    np.testing.assert_array_equal(np.asarray(c.edge_valid), np.asarray(g.edge_valid))
    assert c.compression_ratio > 1.5


# ----------------------------------------------------------------------
# edgeMap equivalence: compressed vs uncompressed in all three modes
# ----------------------------------------------------------------------
@pytest.mark.parametrize("mode", ["dense", "sparse", "auto"])
def test_edgemap_int_bit_identical(g, c, mode):
    x = jnp.arange(g.n, dtype=jnp.int32)
    for frontier in [from_indices(g.n, [0, 3, 11]), full(g.n)]:
        a, at = edgemap_reduce(g, frontier.mask, x, monoid="min", mode=mode)
        b, bt = edgemap_reduce(c, frontier.mask, x, monoid="min", mode=mode)
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        np.testing.assert_array_equal(np.asarray(at), np.asarray(bt))


@pytest.mark.parametrize("mode", ["dense", "sparse", "auto"])
def test_edgemap_float_allclose(g, c, mode):
    xf = jnp.asarray(np.random.default_rng(0).normal(size=g.n), jnp.float32)
    fr = from_indices(g.n, [0, 3, 11]).mask
    a, _ = edgemap_reduce(g, fr, xf, monoid="sum", mode=mode)
    b, _ = edgemap_reduce(c, fr, xf, monoid="sum", mode=mode)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-6)


def test_edgemap_weighted_backend():
    gw = rmat_graph(64, 256, weighted=True, seed=3, block_size=32)
    cw = compress(gw)
    assert cw.weighted and cw.block_weights is not None
    xf = jnp.asarray(np.random.default_rng(1).normal(size=gw.n), jnp.float32)
    fr = from_indices(gw.n, [0, 5, 9]).mask
    for mode in ["dense", "sparse"]:
        a, _ = edgemap_reduce(
            gw, fr, xf, monoid="sum", map_fn=lambda xs, w: xs * w, mode=mode
        )
        b, _ = edgemap_reduce(
            cw, fr, xf, monoid="sum", map_fn=lambda xs, w: xs * w, mode=mode
        )
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-6)


def test_edgemap_exception_graph_equivalence():
    g = wide_delta_graph()
    c = compress(g)
    x = jnp.arange(g.n, dtype=jnp.int32)
    fr = from_indices(g.n, [0, 1]).mask
    for mode in ["dense", "sparse"]:
        a, at = edgemap_reduce(g, fr, x, monoid="min", mode=mode)
        b, bt = edgemap_reduce(c, fr, x, monoid="min", mode=mode)
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        np.testing.assert_array_equal(np.asarray(at), np.asarray(bt))


# ----------------------------------------------------------------------
# Algorithms end-to-end on the compressed backend
# ----------------------------------------------------------------------
def test_bfs_end_to_end(g, c):
    pg, lg = bfs(g, 0)
    pc, lc = bfs(c, 0)
    np.testing.assert_array_equal(np.asarray(pg), np.asarray(pc))
    np.testing.assert_array_equal(np.asarray(lg), np.asarray(lc))


def test_pagerank_end_to_end(g, c):
    pr_g, it_g = pagerank(g)
    pr_c, it_c = pagerank(c)
    assert int(it_g) == int(it_c)
    np.testing.assert_allclose(np.asarray(pr_g), np.asarray(pr_c), atol=1e-7)
    pr1g = pagerank_iteration(g, pr_g)
    pr1c = pagerank_iteration(c, pr_c)
    np.testing.assert_allclose(np.asarray(pr1g), np.asarray(pr1c), atol=1e-7)


def test_connectivity_end_to_end(g, c):
    np.testing.assert_array_equal(
        np.asarray(connectivity(g)), np.asarray(connectivity(c))
    )
    key = jax.random.PRNGKey(0)
    np.testing.assert_array_equal(
        np.asarray(connectivity(g, key)), np.asarray(connectivity(c, key))
    )


# ----------------------------------------------------------------------
# graphFilter composes over the compressed backend (§4.2.1)
# ----------------------------------------------------------------------
def test_filter_composes_with_compressed(g, c):
    fg = make_filter(g)
    fc = make_filter(c)
    np.testing.assert_array_equal(np.asarray(fg.bits), np.asarray(fc.bits))
    keep = g.edge_valid & (g.edge_dst % 3 != 0)
    f2g = pack_vertices(g, fg, jnp.ones(g.n, bool), keep)
    f2c = pack_vertices(c, fc, jnp.ones(g.n, bool), keep)
    np.testing.assert_array_equal(np.asarray(f2g.bits), np.asarray(f2c.bits))
    np.testing.assert_array_equal(
        np.asarray(f2g.active_deg), np.asarray(f2c.active_deg)
    )


# ----------------------------------------------------------------------
# Fused decode+SpMV Pallas kernel
# ----------------------------------------------------------------------
@pytest.mark.parametrize("n,m,bs,tile", [(32, 96, 32, 2), (64, 256, 32, 8), (128, 700, 64, 4)])
def test_compressed_spmv_kernel_sweep(n, m, bs, tile):
    g = rmat_graph(n, m, seed=n + m, block_size=bs)
    c = compress(g)
    f = make_filter(g)
    x = jax.random.normal(jax.random.PRNGKey(1), (g.n,), jnp.float32)
    got = compressed_spmv_vertex(c, x, f, tile_blocks=tile)
    want = compressed_spmv_vertex_ref(c, x, f.bits)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-5)
    # and against the uncompressed kernel on identical (unweighted) work
    unc = spmv_vertex(g, x, f)
    np.testing.assert_allclose(np.asarray(got), np.asarray(unc), rtol=1e-5, atol=1e-5)


def test_compressed_spmv_kernel_exception_fixup():
    g = wide_delta_graph()
    c = compress(g)
    assert c.n_exceptions > 0
    f = make_filter(g)
    x = jax.random.normal(jax.random.PRNGKey(2), (g.n,), jnp.float32)
    got = compressed_spmv_vertex(c, x, f)
    want = compressed_spmv_vertex_ref(c, x, f.bits)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-5)
    # the raw kernel (no fixup) must disagree on the escaped blocks' owners —
    # proving the fixup is actually exercised
    raw = compressed_block_spmv_pallas(
        x, c.block_first, c.deltas, c.valid_count, f.bits, n=c.n
    )
    ref = compressed_block_spmv_ref(c, x, f.bits)
    eb = np.asarray(c.exc_block)
    assert not np.allclose(np.asarray(raw)[eb], np.asarray(ref)[eb])


def test_padding_never_escapes_at_scale():
    """On a locality-friendly graph with n >> 2^16, padding must not land on
    the exception list (the rare path has to stay rare — the whole §5.1.3
    design premise).  A path graph has only delta-1 gaps, so any exception
    would come from padding."""
    n = 200_000
    src = np.arange(n - 1, dtype=np.int64)
    dst = np.arange(1, n, dtype=np.int64)
    g = build_csr(n, src, dst, block_size=32)
    c = compress(g)
    assert c.n_exceptions == 0
    np.testing.assert_array_equal(np.asarray(decode_blocks(c)), np.asarray(g.block_dst))
    f = make_filter(g)
    x = jnp.ones(n, jnp.float32)
    got = compressed_spmv_vertex(c, x, f)
    np.testing.assert_allclose(
        np.asarray(got), np.asarray(compressed_spmv_vertex_ref(c, x, f.bits))
    )


def test_exception_heavy_graph_falls_back_exact():
    """A graph with no id-locality: every vertex's two neighbors sit >= 2^16
    apart, the exception list is dense, and the wrapper must route to the
    exact decode (static choice on n_exceptions) and still agree with the
    oracle."""
    n = 200_000
    k = 2000
    src = np.repeat(np.arange(k, dtype=np.int64), 2)
    dst = np.stack(
        [np.arange(k, dtype=np.int64) + 1, np.arange(k, dtype=np.int64) + 150_000],
        axis=1,
    ).reshape(-1)
    g = build_csr(n, src, dst, block_size=32)
    c = compress(g)
    from repro.core.compressed import exception_dense

    assert exception_dense(c)  # fallback regime
    np.testing.assert_array_equal(np.asarray(decode_blocks(c)), np.asarray(g.block_dst))
    f = make_filter(g)
    x = jax.random.normal(jax.random.PRNGKey(3), (n,), jnp.float32)
    got = compressed_spmv_vertex(c, x, f)
    np.testing.assert_allclose(
        np.asarray(got),
        np.asarray(compressed_spmv_vertex_ref(c, x, f.bits)),
        rtol=1e-5,
        atol=1e-5,
    )
    # sparse/chunked edgeMap routes through the exact-decode tile fallback
    # in this regime — must still match the uncompressed backend
    xi = jnp.arange(n, dtype=jnp.int32)
    fr = from_indices(n, [0, 1, k - 1]).mask
    a, at = edgemap_reduce(g, fr, xi, monoid="min", mode="sparse")
    b, bt = edgemap_reduce(c, fr, xi, monoid="min", mode="sparse")
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    np.testing.assert_array_equal(np.asarray(at), np.asarray(bt))


def test_edge_src_padding_contract(g, c):
    """CompressedCSR.edge_src must return sentinel n on padding slots —
    the exact CSRGraph contract."""
    np.testing.assert_array_equal(np.asarray(c.edge_src), np.asarray(g.edge_src))


@pytest.mark.parametrize("n,m,bs,tile", [(32, 96, 32, 2), (64, 256, 32, 8)])
def test_compressed_spmv_weighted_fast_path(n, m, bs, tile):
    """Weighted graphs run the fused kernel with weights riding as a parallel
    uncompressed stream aligned to the decoded block tiles — same answers as
    the weighted uncompressed kernel and the exact-decode oracle."""
    gw = rmat_graph(n, m, weighted=True, seed=n + 1, block_size=bs)
    cw = compress(gw)
    assert cw.weighted and cw.block_weights is not None
    f = make_filter(gw)
    x = jax.random.normal(jax.random.PRNGKey(4), (gw.n,), jnp.float32)
    got = compressed_spmv_vertex(cw, x, f, tile_blocks=tile)
    want = compressed_spmv_vertex_ref(cw, x, f.bits, cw.block_weights)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-5)
    unc = spmv_vertex(gw, x, f)
    np.testing.assert_allclose(np.asarray(got), np.asarray(unc), rtol=1e-5, atol=1e-5)


def test_compressed_spmv_weighted_exception_fixup():
    """Exception blocks on weighted graphs get their weights applied in the
    exact recompute path too."""
    gw = wide_delta_graph(weighted=True)
    cw = compress(gw)
    assert cw.n_exceptions > 0 and cw.weighted
    f = make_filter(gw)
    x = jax.random.normal(jax.random.PRNGKey(5), (gw.n,), jnp.float32)
    got = compressed_spmv_vertex(cw, x, f)
    want = compressed_spmv_vertex_ref(cw, x, f.bits, cw.block_weights)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-5)


# ----------------------------------------------------------------------
# PSAM accounting charges compressed-byte reads
# ----------------------------------------------------------------------
def test_psam_charges_compressed_reads(g, c):
    cost_u, cost_c = PSAMCost(), PSAMCost()
    cost_u.charge_edgemap_dense(g)
    cost_c.charge_edgemap_dense(c)
    assert cost_c.large_reads < cost_u.large_reads
    # fixed-width packing reads just over half the words of dst+w streaming
    assert cost_c.large_reads <= cost_u.large_reads // 2 + 3 * c.n_exceptions + c.num_blocks


def test_compressed_is_jit_compatible(c):
    """CompressedCSR is a registered pytree: it can cross jit boundaries."""

    @jax.jit
    def deg_sum(graph: CompressedCSR):
        return jnp.sum(graph.degrees) + jnp.sum(graph.block_first) * 0

    assert int(deg_sum(c)) == int(jnp.sum(c.degrees))
