"""Serving subsystem: batched-vs-sequential parity + QueryEngine behavior.

The parity contract (acceptance): every batched op is **bit-identical, per
query**, to B independent single-query runs — across ragged batch widths
B ∈ {1, 3, 8} (the engine pads 3 → 4), both storage backends, and mesh
{1, 2, 4} (the mesh legs run in a subprocess over fake CPU devices, like
test_plan's).  Comparisons are eager-vs-eager / same-plan-vs-same-plan:
jit and eager execution fuse float arithmetic differently (≈1e-9), which
is orthogonal to batching.
"""
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.algorithms import (
    bfs,
    bfs_batched,
    multi_source_bfs,
    pagerank_iteration,
    pagerank_iteration_batched,
    personalized_pagerank,
    personalized_pagerank_batched,
    wbfs,
    wbfs_batched,
)
from repro.core import PSAMCost, compress
from repro.data import rmat_graph
from repro.serving import QueryEngine

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _run(code: str) -> str:
    r = subprocess.run(
        [sys.executable, "-c", code],
        capture_output=True,
        text=True,
        env={**os.environ, "PYTHONPATH": "src"},
        cwd=ROOT,
        timeout=420,
    )
    assert r.returncode == 0, r.stderr[-3000:]
    return r.stdout


def _graph(weighted=False):
    return rmat_graph(128, 512, weighted=weighted, seed=7, block_size=32)


def _sources(B, n, seed=11):
    return np.random.default_rng(seed).integers(0, n, B).tolist()


# ----------------------------------------------------------------------
# Single-device batched-vs-sequential parity, B ∈ {1, 3, 8} x backends
# ----------------------------------------------------------------------
@pytest.mark.parametrize("B", [1, 3, 8])
@pytest.mark.parametrize("compressed", [False, True])
def test_bfs_batched_parity(B, compressed):
    g = _graph()
    backend = compress(g) if compressed else g
    srcs = _sources(B, g.n)
    pb, lb = bfs_batched(backend, jnp.asarray(srcs))
    assert pb.shape == (B, g.n) and lb.shape == (B, g.n)
    for i, s in enumerate(srcs):
        wp, wl = bfs(backend, s)
        np.testing.assert_array_equal(np.asarray(pb[i]), np.asarray(wp))
        np.testing.assert_array_equal(np.asarray(lb[i]), np.asarray(wl))


@pytest.mark.parametrize("B", [1, 3, 8])
@pytest.mark.parametrize("compressed", [False, True])
def test_wbfs_batched_parity(B, compressed):
    g = _graph(weighted=True)
    backend = compress(g) if compressed else g
    srcs = _sources(B, g.n, seed=B)
    db = wbfs_batched(backend, jnp.asarray(srcs))
    for i, s in enumerate(srcs):
        np.testing.assert_array_equal(np.asarray(db[i]), np.asarray(wbfs(backend, s)))


@pytest.mark.parametrize("B", [1, 3, 8])
@pytest.mark.parametrize("compressed", [False, True])
def test_ppr_batched_parity(B, compressed):
    g = _graph()
    backend = compress(g) if compressed else g
    srcs = _sources(B, g.n, seed=B + 50)
    pB, rB, roB = personalized_pagerank_batched(
        backend, jnp.asarray(srcs), max_rounds=40
    )
    for i, s in enumerate(srcs):
        p1, r1, ro1 = personalized_pagerank(backend, s, max_rounds=40)
        # bit-identical, floats included: the batch shares the sweep but
        # every lane's arithmetic is the single-query arithmetic
        np.testing.assert_array_equal(np.asarray(pB[i]), np.asarray(p1))
        np.testing.assert_array_equal(np.asarray(rB[i]), np.asarray(r1))
        assert int(roB[i]) == int(ro1)


@pytest.mark.parametrize("compressed", [False, True])
def test_pagerank_iteration_batched_parity(compressed):
    g = _graph()
    backend = compress(g) if compressed else g
    prs = jax.random.uniform(jax.random.PRNGKey(0), (3, g.n), jnp.float32)
    ob = pagerank_iteration_batched(backend, prs)
    for i in range(3):
        np.testing.assert_array_equal(
            np.asarray(ob[i]), np.asarray(pagerank_iteration(backend, prs[i]))
        )


def test_multi_source_bfs_is_batched_row():
    """The rebased multi_source_bfs (B=1 row of bfs_batched) keeps its
    forest semantics: every root is its own parent at level 0."""
    g = _graph()
    roots = jnp.zeros(g.n, bool).at[jnp.asarray([0, 5, 17])].set(True)
    parents, levels = multi_source_bfs(g, roots)
    ids = np.arange(g.n)
    rn = np.asarray(roots)
    np.testing.assert_array_equal(np.asarray(parents)[rn], ids[rn])
    np.testing.assert_array_equal(np.asarray(levels)[rn], 0)
    # rows of a 2-query batch reproduce the per-mask forests
    roots2 = jnp.zeros(g.n, bool).at[jnp.asarray([3, 40])].set(True)
    pb, lb = bfs_batched(g, jnp.stack([roots, roots2]))
    w0 = multi_source_bfs(g, roots)
    w1 = multi_source_bfs(g, roots2)
    np.testing.assert_array_equal(np.asarray(pb[0]), np.asarray(w0[0]))
    np.testing.assert_array_equal(np.asarray(lb[1]), np.asarray(w1[1]))


# ----------------------------------------------------------------------
# Mesh parity: batched == per-query single runs ON THE SAME PLAN,
# mesh {1, 2, 4} x both backends, ragged B=3
# ----------------------------------------------------------------------
def test_batched_sharded_parity():
    out = _run(
        r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import jax, jax.numpy as jnp, numpy as np
from repro.compat import make_mesh, use_mesh
from repro.data import rmat_graph
from repro.core import compress, make_plan
from repro.algorithms import (bfs, bfs_batched, wbfs, wbfs_batched,
    personalized_pagerank, personalized_pagerank_batched,
    pagerank_iteration, pagerank_iteration_batched)

g = rmat_graph(128, 512, weighted=True, seed=7, block_size=32)
c = compress(g)
srcs = [0, 9, 33]
prs = jax.random.uniform(jax.random.PRNGKey(1), (3, g.n), jnp.float32)
for shape in [(1,), (2,), (4,)]:
    mesh = make_mesh(shape, ("data",))
    for backend in [g, c]:
        plan = make_plan(backend, mesh=mesh)
        name = (shape, type(backend).__name__)
        with use_mesh(mesh):
            pb, lb = bfs_batched(backend, jnp.asarray(srcs), plan=plan)
            db = wbfs_batched(backend, jnp.asarray(srcs), plan=plan)
            pB, rB, roB = personalized_pagerank_batched(
                backend, jnp.asarray(srcs), max_rounds=30, plan=plan)
            ob = pagerank_iteration_batched(backend, prs, plan=plan)
            for i, s in enumerate(srcs):
                wp, wl = bfs(backend, s, plan=plan)
                assert np.array_equal(np.asarray(pb[i]), np.asarray(wp)), (name, "bfs p")
                assert np.array_equal(np.asarray(lb[i]), np.asarray(wl)), (name, "bfs l")
                wd = wbfs(backend, s, plan=plan)
                assert np.array_equal(np.asarray(db[i]), np.asarray(wd)), (name, "wbfs")
                p1, r1, ro1 = personalized_pagerank(backend, s, max_rounds=30, plan=plan)
                assert np.array_equal(np.asarray(pB[i]), np.asarray(p1)), (name, "ppr p")
                assert np.array_equal(np.asarray(rB[i]), np.asarray(r1)), (name, "ppr r")
                assert int(roB[i]) == int(ro1), (name, "ppr rounds")
                w1 = pagerank_iteration(backend, prs[i], plan=plan)
                assert np.array_equal(np.asarray(ob[i]), np.asarray(w1)), (name, "pr iter")
print("OK")
"""
    )
    assert "OK" in out


def test_batched_hierarchical_reduce_parity():
    """Sum-monoid batched edgeMap on a 2x2 hierarchical-reduce mesh keeps
    per-lane bit-identity with the single-query run on the same plan: the
    (B, n) output reduce-scatters each lane's row along the vertex dim,
    exactly the 1-D combine per lane."""
    out = _run(
        r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import jax, jax.numpy as jnp, numpy as np
from repro.compat import make_mesh, use_mesh
from repro.data import rmat_graph
from repro.core import compress, edgemap_reduce, edgemap_reduce_batched, make_plan

g = rmat_graph(96, 400, seed=5, block_size=32)
rng = np.random.default_rng(0)
fms = jnp.asarray(rng.random((3, g.n)) < 0.3)
xb = jnp.asarray(rng.normal(size=(3, g.n)), jnp.float32)
mesh = make_mesh((2, 2), ("pod", "data"))
for backend in [g, compress(g)]:
    plan = make_plan(backend, mesh=mesh, reduce_mode="hierarchical")
    gs = plan.prepare(backend)
    with use_mesh(mesh):
        out, t = edgemap_reduce_batched(gs, fms, xb, monoid="sum", mode="dense", plan=plan)
        for i in range(3):
            w, wt = edgemap_reduce(gs, fms[i], xb[i], monoid="sum", mode="dense", plan=plan)
            assert np.array_equal(np.asarray(out[i]), np.asarray(w)), i
            assert np.array_equal(np.asarray(t[i]), np.asarray(wt)), i
print("OK")
"""
    )
    assert "OK" in out


def test_root_masks_rank_dispatch():
    """An int 0/1 roots mask (2-D) is a mask, never vertex ids; 1-D bool is
    ambiguous and rejected loudly."""
    g = _graph()
    mask_int = jnp.zeros(g.n, jnp.int32).at[jnp.asarray([0, 5])].set(1)
    p1, l1 = multi_source_bfs(g, mask_int)
    p2, l2 = multi_source_bfs(g, mask_int.astype(bool))
    np.testing.assert_array_equal(np.asarray(p1), np.asarray(p2))
    np.testing.assert_array_equal(np.asarray(l1), np.asarray(l2))
    with pytest.raises(ValueError, match="root masks|sources"):
        bfs_batched(g, jnp.asarray([True, False]))


# ----------------------------------------------------------------------
# QueryEngine: coalescing, ragged padding, executable cache, accounting
# ----------------------------------------------------------------------
def test_engine_results_match_singles():
    """Engine-served results are bit-identical to the same computation run
    single-query under jit with the graph as an argument — exactly the
    engine's execution regime (jit fuses closure-captured constants
    differently, which is orthogonal to batching)."""
    g = _graph(weighted=True)
    eng = QueryEngine(g, max_batch=8)
    srcs = [0, 3, 9]  # ragged: pads to B=4
    hb = [eng.submit("bfs", src=s) for s in srcs]
    hw = [eng.submit("wbfs", src=s) for s in srcs]
    hp = eng.submit("ppr", src=5, max_rounds=30)
    pr0 = jnp.full(g.n, 1.0 / g.n, jnp.float32)
    hpr = eng.submit("pagerank_iteration", pr=pr0)
    res = eng.flush()
    assert eng.stats["submitted"] == eng.stats["served"] == 8
    jit_bfs = jax.jit(lambda gg, s: bfs(gg, s))
    jit_wbfs = jax.jit(lambda gg, s: wbfs(gg, s))
    for h, s in zip(hb, srcs):
        wp, wl = jit_bfs(g, jnp.int32(s))
        np.testing.assert_array_equal(np.asarray(res[h][0]), np.asarray(wp))
        np.testing.assert_array_equal(np.asarray(res[h][1]), np.asarray(wl))
    for h, s in zip(hw, srcs):
        np.testing.assert_array_equal(
            np.asarray(res[h]), np.asarray(jit_wbfs(g, jnp.int32(s)))
        )
    p1, r1, ro1 = jax.jit(
        lambda gg, s: personalized_pagerank(gg, s, max_rounds=30)
    )(g, jnp.int32(5))
    np.testing.assert_array_equal(np.asarray(res[hp][0]), np.asarray(p1))
    assert int(res[hp][2]) == int(ro1)
    w = jax.jit(lambda gg, p: pagerank_iteration(gg, p))(g, pr0)
    np.testing.assert_array_equal(np.asarray(res[hpr]), np.asarray(w))


def test_engine_cache_zero_retrace():
    """Acceptance: a repeated (op, B) bucket re-enters the cached executable
    — the per-key trace count stays at 1 across flushes."""
    g = _graph()
    eng = QueryEngine(g, max_batch=8)
    for round_srcs in [[1, 2, 3], [4, 5, 6], [7, 8, 9]]:
        for s in round_srcs:
            eng.submit("bfs", src=s)
        eng.flush()
    assert eng.stats["batches"] == 3
    (key, traces), = eng.trace_counts.items()
    # key layout: (backend, mesh, tuning_key, op, B, scalars)
    assert key[0] == "CSRGraph" and key[3] == "bfs" and key[4] == 4
    assert traces == 1  # zero retraces after the first
    # a different B is a different executable, again traced once
    eng.submit("bfs", src=11)
    eng.flush()
    assert sorted(k[4] for k in eng.trace_counts) == [1, 4]
    assert all(t == 1 for t in eng.trace_counts.values())


def test_engine_pads_pow2_and_splits_oversize():
    g = _graph()
    eng = QueryEngine(g, max_batch=4)
    for s in range(6):  # 6 queries, max_batch 4 → buckets of 4 and 2
        eng.submit("bfs", src=s)
    res = eng.flush()
    assert len(res) == 6 and eng.stats["batches"] == 2
    assert sorted(k[4] for k in eng.trace_counts) == [2, 4]


def test_engine_scalar_params_bucket_separately():
    """Different trace-constant params must not coalesce into one batch."""
    g = _graph()
    eng = QueryEngine(g)
    h1 = eng.submit("ppr", src=1, max_rounds=10)
    h2 = eng.submit("ppr", src=2, max_rounds=20)
    res = eng.flush()
    assert eng.stats["batches"] == 2
    p1, _, _ = jax.jit(
        lambda gg, s: personalized_pagerank(gg, s, max_rounds=10)
    )(g, jnp.int32(1))
    np.testing.assert_array_equal(np.asarray(res[h1][0]), np.asarray(p1))
    assert res[h2][0].shape == (g.n,)


def test_engine_rejects_unknown_op():
    with pytest.raises(ValueError, match="unknown op"):
        QueryEngine(_graph()).submit("triangle_count")


def test_engine_sharded_mesh():
    """The same engine serves a 4-shard mesh: results equal the single-query
    runs on the same plan, and the cache key records the mesh."""
    out = _run(
        r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import jax, jax.numpy as jnp, numpy as np
from repro.compat import make_mesh, use_mesh
from repro.data import rmat_graph
from repro.core import compress, make_plan
from repro.algorithms import bfs
from repro.serving import QueryEngine

g = rmat_graph(128, 512, seed=7, block_size=32)
for backend in [g, compress(g)]:
    mesh = make_mesh((4,), ("data",))
    plan = make_plan(backend, mesh=mesh)
    eng = QueryEngine(backend, plan=plan, max_batch=4)
    srcs = [0, 9, 33]
    hs = [eng.submit("bfs", src=s) for s in srcs]
    res = eng.flush()       # engine enters the mesh context itself
    with use_mesh(mesh):
        jit_bfs = jax.jit(lambda gg, sv: bfs(gg, sv, plan=plan))
        for h, s in zip(hs, srcs):
            wp, wl = jit_bfs(eng.prepared, jnp.int32(s))
            assert np.array_equal(np.asarray(res[h][0]), np.asarray(wp)), s
            assert np.array_equal(np.asarray(res[h][1]), np.asarray(wl)), s
    (key,) = eng.trace_counts
    # key layout: (backend, mesh, tuning_key, op, B, scalars)
    assert key[1] == (("data", 4),) and key[4] == 4
    assert key[2] == plan.tuning_key
print("OK")
"""
    )
    assert "OK" in out


# ----------------------------------------------------------------------
# PSAM accounting: the amortization is real (acceptance criterion)
# ----------------------------------------------------------------------
def test_psam_batched_amortization_bfs8():
    """B=8 batched BFS on RMAT reads ≥4x fewer edge bytes than 8 sequential
    runs: per round the batch charges one edge sweep; sequential serving
    charges one per query per round."""
    g = rmat_graph(2048, 16384, seed=1, block_size=32)
    srcs = _sources(8, g.n, seed=3)
    # per-query round counts = deepest level + 1 (the drain round)
    seq_rounds = [int(jnp.max(bfs(g, s)[1])) + 1 for s in srcs]
    _, lb = bfs_batched(g, jnp.asarray(srcs))
    batched_rounds = int(jnp.max(lb)) + 1
    assert batched_rounds == max(seq_rounds)  # lockstep runs to the slowest

    batched, sequential = PSAMCost(), PSAMCost()
    for _ in range(batched_rounds):
        batched.charge_edgemap_batched(g, 8)
    for rounds in seq_rounds:
        for _ in range(rounds):
            sequential.charge_edgemap_planned(g)
    ratio = sequential.large_reads / batched.large_reads
    assert ratio >= 4.0, ratio
    # the O(B·n) small-memory side does NOT amortize: per round the batch
    # pays B times the single-query state
    assert batched.small_ops == 8 * g.n * 3 * batched_rounds


def test_psam_batched_matches_planned_at_b1():
    g = _graph()
    c = compress(g)
    for backend in [g, c]:
        a, b = PSAMCost(), PSAMCost()
        a.charge_edgemap_planned(backend, num_shards=4)
        b.charge_edgemap_batched(backend, 1, num_shards=4)
        assert a.large_reads == b.large_reads and a.small_ops == b.small_ops
        # edge reads are batch-invariant; small ops scale linearly
        b8 = PSAMCost()
        b8.charge_edgemap_batched(backend, 8, num_shards=4)
        assert b8.large_reads == b.large_reads
        assert b8.small_ops == 8 * b.small_ops


def test_engine_cost_tracks_batches():
    g = _graph()
    eng = QueryEngine(g, max_batch=8)
    for s in range(8):
        eng.submit("bfs", src=s)
    eng.flush()
    assert eng.cost.large_reads > 0
    # one edge sweep per round for the whole batch, never per query
    solo = PSAMCost()
    solo.charge_edgemap_planned(g)
    assert eng.cost.large_reads % solo.large_reads == 0
    assert eng.cost.large_reads // solo.large_reads < 8 * 2  # « 8 x rounds
