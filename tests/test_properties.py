"""Hypothesis property tests on system invariants."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import HealthCheck, given, settings  # noqa: E402
from hypothesis import strategies as st  # noqa: E402

import oracles as O
from repro.algorithms import bfs, connectivity, kcore, mis, pagerank_iteration
from repro.core import (
    build_csr,
    edge_active_flat,
    edgemap_chunked,
    edgemap_dense,
    filter_edges,
    from_indices,
    full,
    make_filter,
)
from repro.core.primitives import mex_from_forbidden, popcount32

SETTINGS = dict(
    max_examples=15,
    deadline=None,
    suppress_health_check=[HealthCheck.too_slow, HealthCheck.data_too_large],
)


@st.composite
def random_graph(draw, max_n=24, max_m=60):
    n = draw(st.integers(min_value=2, max_value=max_n))
    m = draw(st.integers(min_value=1, max_value=max_m))
    src = draw(
        st.lists(st.integers(0, n - 1), min_size=m, max_size=m)
    )
    dst = draw(
        st.lists(st.integers(0, n - 1), min_size=m, max_size=m)
    )
    return build_csr(
        n, np.array(src), np.array(dst), symmetrize=True, block_size=32
    )


@given(random_graph(), st.integers(0, 2**31 - 1))
@settings(**SETTINGS)
def test_edgemap_dense_equals_chunked(g, seed):
    rng = np.random.default_rng(seed)
    frontier = from_indices(
        g.n, rng.integers(0, g.n, size=max(1, g.n // 3))
    ).mask
    x = jnp.asarray(rng.integers(0, 1000, g.n), jnp.int32)
    d, dt = edgemap_dense(g, frontier, x, monoid="min")
    c, ct = edgemap_chunked(g, frontier, x, monoid="min")
    assert bool(jnp.all(d == c)) and bool(jnp.all(dt == ct))


@given(random_graph(), st.integers(0, 2**31 - 1))
@settings(**SETTINGS)
def test_filter_commutes_with_subgraph(g, seed):
    """edgeMap∘filter == edgeMap over the materialized subgraph (the PSAM
    immutability invariant: a filter is semantically a subgraph)."""
    rng = np.random.default_rng(seed)
    keep_np = rng.random(g.edge_src.shape[0]) < 0.6
    keep = jnp.asarray(keep_np) & g.edge_valid
    f, _ = filter_edges(g, make_filter(g), keep)
    x = jnp.arange(g.n, dtype=jnp.int32)
    got, gt = edgemap_dense(
        g, full(g.n).mask, x, monoid="min", edge_active=edge_active_flat(f)
    )
    src = np.asarray(g.edge_src)
    dst = np.asarray(g.edge_dst)
    sel = np.asarray(keep)
    if sel.sum() == 0:
        assert not bool(jnp.any(gt))
        return
    g2 = build_csr(g.n, src[sel], dst[sel], block_size=32)
    want, wt = edgemap_dense(g2, full(g.n).mask, x, monoid="min")
    assert bool(jnp.all(gt == wt))
    assert bool(jnp.all(jnp.where(gt, got, 0) == jnp.where(wt, want, 0)))


@given(random_graph(), st.integers(0, 2**31 - 1))
@settings(**SETTINGS)
def test_connectivity_isomorphism_invariant(g, seed):
    """Component PARTITION is invariant under vertex relabeling."""
    labels = np.asarray(connectivity(g))
    rng = np.random.default_rng(seed)
    perm = rng.permutation(g.n)
    src = np.asarray(g.edge_src)
    dst = np.asarray(g.edge_dst)
    valid = dst < g.n
    g2 = build_csr(g.n, perm[src[valid]], perm[dst[valid]], block_size=32)
    labels2 = np.asarray(connectivity(g2))
    # same partition up to the permutation
    for u in range(g.n):
        for v in range(u + 1, g.n):
            assert (labels[u] == labels[v]) == (labels2[perm[u]] == labels2[perm[v]])


@given(random_graph())
@settings(**SETTINGS)
def test_bfs_triangle_inequality(g):
    _, lev = bfs(g, 0)
    la = np.asarray(lev)
    s, d, _ = O.edges_of(g)
    for a, b in zip(s, d):
        if la[a] >= 0 and la[b] >= 0:
            assert abs(la[a] - la[b]) <= 1
        else:
            assert la[a] == la[b] == -1 or (la[a] < 0) == (la[b] < 0)


@given(random_graph(), st.integers(0, 2**31 - 1))
@settings(**SETTINGS)
def test_mis_validity(g, seed):
    ok, msg = O.check_mis(g, mis(g, jax.random.PRNGKey(seed)))
    assert ok, msg


@given(random_graph())
@settings(**SETTINGS)
def test_kcore_degeneracy_bounds(g):
    core = np.asarray(kcore(g))
    deg = np.asarray(g.degrees)
    assert np.all(core <= deg)
    assert np.all(core >= 0)


@given(random_graph())
@settings(**SETTINGS)
def test_pagerank_mass_conservation(g):
    pr0 = jnp.full(g.n, 1.0 / g.n)
    pr1 = pagerank_iteration(g, pr0)
    # total mass stays 1 (dangling mass redistributed)
    assert abs(float(jnp.sum(pr1)) - 1.0) < 1e-4
    assert bool(jnp.all(pr1 >= 0))


@given(st.lists(st.integers(0, 2**32 - 1), min_size=1, max_size=8))
@settings(**SETTINGS)
def test_popcount_and_mex(words):
    w = jnp.asarray(np.array(words, dtype=np.uint32))
    got = np.asarray(popcount32(w))
    want = np.array([bin(x).count("1") for x in words])
    assert np.array_equal(got, want)
    mex = int(mex_from_forbidden(w[None, :])[0])
    bits = []
    for x in words:
        bits.extend((x >> i) & 1 for i in range(32))
    want_mex = next((i for i, b in enumerate(bits) if b == 0), len(bits))
    assert mex == want_mex
