"""Tests for the beyond-deliverable extensions: compressed CSR, personalized
PageRank, decode-attention kernel."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.algorithms.local import personalized_pagerank, ppr_matrix_oracle
from repro.core import (
    compress,
    decode_block,
    decode_blocks,
    edge_active_flat,
    edgemap_dense,
    edgemap_sum_compressed,
    filter_edges,
    full,
    make_filter,
)
from repro.data import rmat_graph, structured_graph
from repro.kernels import decode_attention
from repro.kernels.decode_attention.ref import decode_attention_ref


# ---------------- compressed CSR ----------------
@pytest.mark.parametrize("n,m,bs", [(128, 1024, 32), (256, 2048, 64), (512, 3000, 128)])
def test_compressed_roundtrip(n, m, bs):
    g = rmat_graph(n, m, seed=n, block_size=bs)
    c = compress(g)
    dec = np.asarray(decode_blocks(c))
    orig = np.asarray(g.edge_dst).reshape(g.num_blocks, g.block_size)
    assert np.array_equal(dec, orig)
    # single-block decode path (the filter iterator)
    for bid in [0, g.num_blocks // 2, g.num_blocks - 1]:
        assert np.array_equal(np.asarray(decode_block(c, jnp.int32(bid))), orig[bid])


def test_compressed_saves_space():
    g = rmat_graph(512, 4096, seed=1, block_size=64)
    c = compress(g)
    assert c.compressed_bytes < 0.6 * c.uncompressed_bytes


def test_compressed_exceptions_path():
    """Force wide deltas (> 2^16) and check the escape path."""
    import numpy as np

    from repro.core import build_csr

    n = 200_000
    # star-ish: vertex 0 connects to far-apart targets → huge deltas
    dst = np.arange(1, 129) * 1500  # deltas of 1500… fine, make them wide:
    dst = np.concatenate([[5], [70000], [190000]])
    src = np.zeros(dst.shape[0], dtype=np.int64)
    g = build_csr(n, src, dst, block_size=32)
    c = compress(g)
    assert c.n_exceptions >= 1
    dec = np.asarray(decode_blocks(c))
    orig = np.asarray(g.edge_dst).reshape(g.num_blocks, g.block_size)
    assert np.array_equal(dec, orig)


def test_compressed_edgemap_with_filter():
    g = rmat_graph(128, 1024, seed=9, block_size=32)
    c = compress(g)
    f, _ = filter_edges(g, make_filter(g), g.edge_valid & (g.edge_dst % 2 == 0))
    x = jax.random.normal(jax.random.PRNGKey(0), (g.n,), jnp.float32)
    got = edgemap_sum_compressed(c, x, edge_active=edge_active_flat(f))
    want, _ = edgemap_dense(
        g, full(g.n).mask, x, monoid="sum", edge_active=edge_active_flat(f)
    )
    # symmetric graph: per-src sums == per-dst sums of the symmetric subgraph?
    # the filter here is NOT symmetric, so compare against an explicit per-src sum
    src = np.asarray(g.edge_src)
    dst = np.asarray(g.edge_dst)
    act = np.asarray(edge_active_flat(f))
    xs = np.asarray(x)
    ref = np.zeros(g.n + 1)
    sel = act & (dst < g.n)
    np.add.at(ref, src[sel], xs[dst[sel]])
    np.testing.assert_allclose(np.asarray(got), ref[: g.n], rtol=1e-5, atol=1e-5)


# ---------------- personalized PageRank ----------------
@pytest.mark.parametrize("kind", ["rmat", "grid"])
def test_ppr_acl_guarantee(kind):
    g = (
        rmat_graph(96, 512, seed=3, block_size=32)
        if kind == "rmat"
        else structured_graph("grid")
    )
    eps = 1e-6
    p, r, rounds = personalized_pagerank(g, 0, eps=eps)
    pi = ppr_matrix_oracle(g, 0)
    deg = np.maximum(np.asarray(g.degrees), 1)
    err = np.abs(np.asarray(p) - pi)
    # ACL: residual-bounded approximation
    assert np.all(err <= eps * deg + np.asarray(r) + 1e-7)
    assert float(jnp.sum(p)) <= 1.0 + 1e-5
    assert int(rounds) < 200


def test_ppr_mass_split():
    """p + remaining residual mass == 1 (push conserves probability)."""
    g = rmat_graph(64, 256, seed=7, block_size=32)
    p, r, _ = personalized_pagerank(g, 5, eps=1e-4)
    # pushed mass α·Σpushed went to p; (1-α) spread; total = p + r·(correction)
    # loose conservation: within eps·m slack
    assert 0.9 <= float(jnp.sum(p)) + float(jnp.sum(r)) <= 1.0 + 1e-4


# ---------------- decode attention kernel ----------------
@pytest.mark.parametrize("B,S,Hq,Hkv,D", [(2, 64, 4, 4, 8), (6, 300, 8, 2, 16), (3, 128, 6, 1, 32)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_decode_attention_sweep(B, S, Hq, Hkv, D, dtype):
    k0 = jax.random.PRNGKey(B * S)
    q = jax.random.normal(k0, (B, Hq, D), jnp.float32).astype(dtype)
    k = jax.random.normal(jax.random.fold_in(k0, 1), (B, S, Hkv, D), jnp.float32).astype(dtype)
    v = jax.random.normal(jax.random.fold_in(k0, 2), (B, S, Hkv, D), jnp.float32).astype(dtype)
    pos = jax.random.randint(jax.random.fold_in(k0, 3), (B,), 1, S)
    got = decode_attention(q, k, v, pos, seq_tile=64, tile_batch=2)
    kr = jnp.repeat(k, Hq // Hkv, axis=2)
    vr = jnp.repeat(v, Hq // Hkv, axis=2)
    want = decode_attention_ref(q, kr, vr, pos)
    tol = 1e-5 if dtype == jnp.float32 else 3e-2
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32), rtol=tol, atol=tol
    )


def test_decode_attention_matches_model_decode():
    """The kernel agrees with the model's (blockwise) decode attention."""
    from repro.nn.attention import gqa_attention

    k0 = jax.random.PRNGKey(0)
    B, S, Hq, Hkv, D = 2, 96, 4, 2, 16
    q = jax.random.normal(k0, (B, 1, Hq, D))
    k = jax.random.normal(jax.random.fold_in(k0, 1), (B, S, Hkv, D))
    v = jax.random.normal(jax.random.fold_in(k0, 2), (B, S, Hkv, D))
    pos = 57
    model_out = gqa_attention(q, k, v, causal=True, q_offset=pos, kv_block=32)[:, 0]
    kern_out = decode_attention(
        q[:, 0], k, v, jnp.full((B,), pos + 1, jnp.int32), seq_tile=32
    )
    np.testing.assert_allclose(
        np.asarray(model_out), np.asarray(kern_out), rtol=1e-5, atol=1e-5
    )
