"""Unified execution planner: shard() partitioning + sharded-vs-single-device
parity for the ported algorithms.

The mesh parity suite runs in a subprocess (fake CPU devices via XLA_FLAGS)
so the main pytest process keeps its single-device view; the shard()
structure tests run in-process (no mesh required — a shard is just another
GraphBackend)."""
import os
import subprocess
import sys

import jax.numpy as jnp
import numpy as np

from repro.core import (
    PSAMCost,
    compress,
    decode_blocks,
    edgemap_reduce,
    from_indices,
    make_plan,
)
from repro.data import rmat_graph

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _run(code: str) -> str:
    r = subprocess.run(
        [sys.executable, "-c", code],
        capture_output=True,
        text=True,
        env={**os.environ, "PYTHONPATH": "src"},
        cwd=ROOT,
        timeout=420,
    )
    assert r.returncode == 0, r.stderr[-3000:]
    return r.stdout


# ----------------------------------------------------------------------
# shard(): block-range partitioning, both backends
# ----------------------------------------------------------------------
def test_csr_shard_roundtrip_and_padding():
    g = rmat_graph(64, 256, seed=2, block_size=32)
    for k in [1, 2, 3, 4, 7]:  # 3 and 7 won't divide most block counts
        shards = g.shard(k)
        assert len(shards) == k
        per = -(-g.num_blocks // k)
        assert all(s.num_blocks == per for s in shards)
        # concatenated shard views == original + empty padding
        dst = np.concatenate([np.asarray(s.block_dst) for s in shards])
        src = np.concatenate([np.asarray(s.block_src) for s in shards])
        np.testing.assert_array_equal(dst[: g.num_blocks], np.asarray(g.block_dst))
        np.testing.assert_array_equal(src[: g.num_blocks], np.asarray(g.block_src))
        assert np.all(dst[g.num_blocks:] == g.n)  # padding = empty sentinel blocks
        assert np.all(src[g.num_blocks:] == g.n)
        # vertex metadata replicated, global n/m kept
        for s in shards:
            assert s.n == g.n and s.m == g.m
            np.testing.assert_array_equal(np.asarray(s.degrees), np.asarray(g.degrees))


def test_compressed_shard_roundtrip_and_exceptions():
    # wide deltas force a non-empty exception list
    from repro.core import build_csr

    n = 70000
    src = np.array([0, 0, 0, 0, 0, 0, 1, 1, 2, 3], np.int64)
    dst = np.array([1, 2, 66000, 66001, 69998, 69999, 3, 69000, 69500, 68000], np.int64)
    g = build_csr(n, src, dst, block_size=32)
    c = compress(g)
    assert c.n_exceptions > 0
    for k in [1, 2, 3]:
        shards = c.shard(k)
        per = -(-c.num_blocks // k)
        # per-shard exception lists pad to a common length with droppable ids
        ne = shards[0].n_exceptions
        assert all(s.n_exceptions == ne for s in shards)
        total_real = sum(
            int((np.asarray(s.exc_block) < per).sum()) for s in shards
        )
        assert total_real == c.n_exceptions
        # decoded shard blocks == decoded original + sentinel padding
        dec = np.concatenate([np.asarray(decode_blocks(s)) for s in shards])
        np.testing.assert_array_equal(
            dec[: c.num_blocks], np.asarray(decode_blocks(c))
        )
        assert np.all(dec[c.num_blocks:] == c.n)


def test_shard_is_a_backend():
    """Each shard satisfies GraphBackend: edgeMap runs on it unchanged, and
    shard-wise results combine to the whole-graph result."""
    g = rmat_graph(64, 256, seed=4, block_size=32)
    x = jnp.arange(g.n, dtype=jnp.int32)
    fr = from_indices(g.n, [0, 3, 7]).mask
    want, wt = edgemap_reduce(g, fr, x, monoid="min", mode="dense")
    for backend in [g, compress(g)]:
        parts = [
            edgemap_reduce(s, fr, x, monoid="min", mode="dense")
            for s in backend.shard(3)
        ]
        got = np.minimum.reduce([np.asarray(o) for o, _ in parts])
        touched = np.logical_or.reduce([np.asarray(t) for _, t in parts])
        np.testing.assert_array_equal(got, np.asarray(want))
        np.testing.assert_array_equal(touched, np.asarray(wt))


def test_plan_single_device_resolves_strategy():
    g = rmat_graph(64, 256, seed=5, block_size=32)
    plan = make_plan(g, strategy="dense")
    assert not plan.is_sharded and plan.backend == "csr"
    assert plan.prepare(g) is g
    x = jnp.arange(g.n, dtype=jnp.int32)
    fr = from_indices(g.n, [0, 1]).mask
    a, _ = edgemap_reduce(g, fr, x, monoid="min", mode="dense")
    b, _ = edgemap_reduce(g, fr, x, monoid="min", plan=plan)  # mode from plan
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_plan_knobs_reach_edgemap(monkeypatch):
    """plan.chunk_blocks / plan.dense_frac actually reach the edgeMap bodies
    (explicit call-site arguments still win)."""
    import repro.core.edgemap as em

    g = rmat_graph(64, 256, seed=8, block_size=32)
    x = jnp.arange(g.n, dtype=jnp.int32)
    fr = from_indices(g.n, [0]).mask
    seen = {}
    orig = em.edgemap_chunked

    def spy(*a, **k):
        seen.update(k)
        return orig(*a, **k)

    monkeypatch.setattr(em, "edgemap_chunked", spy)
    plan = make_plan(g, strategy="sparse", chunk_blocks=7)
    em.edgemap_reduce(g, fr, x, monoid="min", plan=plan)
    assert seen["chunk_blocks"] == 7
    em.edgemap_reduce(g, fr, x, monoid="min", plan=plan, chunk_blocks=3)
    assert seen["chunk_blocks"] == 3


def test_compressed_shard_keeps_decode_strategy():
    """A shard's padded exception list must not flip the whole-graph
    exception-density verdict (it would force exact decode per shard)."""
    from repro.core import build_csr
    from repro.core.compressed import exception_dense

    # locality-friendly graph with a handful of wide deltas: not dense
    n = 70000
    src = np.concatenate([np.arange(400, dtype=np.int64), [0, 1, 2]])
    dst = np.concatenate([np.arange(1, 401, dtype=np.int64), [69999, 69998, 69997]])
    c = compress(build_csr(n, src, dst, block_size=4))
    assert c.n_exceptions > 0 and not exception_dense(c)
    for s in c.shard(8):
        assert s.exception_dense_hint is False
        assert not exception_dense(s)


def test_psam_planned_charges():
    g = rmat_graph(64, 600, seed=6, block_size=32)
    c = compress(g)
    flat, planned = PSAMCost(), PSAMCost()
    flat.charge_edgemap_dense(c)
    planned.charge_edgemap_planned(c, num_shards=4)
    # sharding never reads fewer bytes (padding) and pays O(n)/shard combine
    assert planned.large_reads >= flat.large_reads
    assert planned.small_ops == flat.small_ops + 3 * g.n
    # compressed stays cheaper than raw in the distributed path too
    planned_raw = PSAMCost()
    planned_raw.charge_edgemap_planned(g, num_shards=4)
    assert planned.large_reads < planned_raw.large_reads
    # non-dividing block counts charge the padded tail
    a, b = PSAMCost(), PSAMCost()
    a.charge_edgemap_planned(g, num_shards=1)
    b.charge_edgemap_planned(g, num_shards=7)
    assert b.large_reads >= a.large_reads


# ----------------------------------------------------------------------
# Sharded-vs-single-device parity: BFS / PageRank / connectivity,
# mesh in {(1,), (2,), (4,)} x {CSRGraph, CompressedCSR}
# ----------------------------------------------------------------------
def test_sharded_parity_algorithms():
    out = _run(
        r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import jax, jax.numpy as jnp, numpy as np
from repro.compat import make_mesh, use_mesh
from repro.data import rmat_graph
from repro.core import compress, make_plan
from repro.algorithms import bfs, pagerank, connectivity

g = rmat_graph(256, 1024, seed=7, block_size=32)
c = compress(g)
want_p, want_l = bfs(g, 0)
want_pr, _ = pagerank(g, max_iters=30)
want_cc = connectivity(g, jax.random.PRNGKey(0))
for shape in [(1,), (2,), (4,)]:
    mesh = make_mesh(shape, ("data",))
    for backend in [g, c]:
        plan = make_plan(backend, mesh=mesh)
        with use_mesh(mesh):
            p, l = bfs(backend, 0, plan=plan)
            pr, _ = pagerank(backend, max_iters=30, plan=plan)
            cc = connectivity(backend, jax.random.PRNGKey(0), plan=plan)
        name = (shape, type(backend).__name__)
        assert np.array_equal(np.asarray(p), np.asarray(want_p)), (name, "bfs parents")
        assert np.array_equal(np.asarray(l), np.asarray(want_l)), (name, "bfs levels")
        assert np.allclose(np.asarray(pr), np.asarray(want_pr), atol=1e-5), (name, "pagerank")
        assert np.array_equal(np.asarray(cc), np.asarray(want_cc)), (name, "connectivity")
print("OK")
"""
    )
    assert "OK" in out


def test_sharded_modes_and_monoids():
    """dense/sparse/auto strategies and sum/min monoids all agree with the
    single-device engine on a 2D mesh, both backends, incl. hierarchical."""
    out = _run(
        r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import jax, jax.numpy as jnp, numpy as np
from repro.compat import make_mesh, use_mesh
from repro.data import rmat_graph
from repro.core import compress, make_plan, edgemap_reduce, from_indices

g = rmat_graph(128, 512, seed=3, block_size=32)
c = compress(g)
x = jnp.arange(g.n, dtype=jnp.int32)
xf = jnp.asarray(np.random.default_rng(0).normal(size=g.n), jnp.float32)
fr = from_indices(g.n, [0, 5, 9]).mask
full = jnp.ones(g.n, bool)
mesh = make_mesh((2, 2), ("pod", "data"))
for backend in [g, c]:
    want_min, wt = edgemap_reduce(backend, fr, x, monoid="min", mode="dense")
    want_sum, _ = edgemap_reduce(backend, full, xf, monoid="sum", mode="dense")
    for rm in ["flat", "hierarchical"]:
        plan = make_plan(backend, mesh=mesh, reduce_mode=rm)
        gs = plan.prepare(backend)
        with use_mesh(mesh):
            for mode in ["dense", "sparse", "auto"]:
                got, t = edgemap_reduce(gs, fr, x, monoid="min", mode=mode, plan=plan)
                assert np.array_equal(np.asarray(got), np.asarray(want_min)), (rm, mode)
                assert np.array_equal(np.asarray(t), np.asarray(wt)), (rm, mode)
            s, _ = edgemap_reduce(gs, full, xf, monoid="sum", mode="dense", plan=plan)
            assert np.allclose(np.asarray(s), np.asarray(want_sum), atol=1e-5), rm
print("OK")
"""
    )
    assert "OK" in out
