"""Unified execution planner: shard() partitioning + sharded-vs-single-device
parity for the ported algorithms.

The mesh parity suite runs in a subprocess (fake CPU devices via XLA_FLAGS)
so the main pytest process keeps its single-device view; the shard()
structure tests run in-process (no mesh required — a shard is just another
GraphBackend)."""
import os
import subprocess
import sys

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    PSAMCost,
    compress,
    decode_blocks,
    edgemap_reduce,
    from_indices,
    make_filter,
    make_plan,
    shard_edge_active,
    unpack_word_bits,
)
from repro.data import rmat_graph

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _run(code: str) -> str:
    r = subprocess.run(
        [sys.executable, "-c", code],
        capture_output=True,
        text=True,
        env={**os.environ, "PYTHONPATH": "src"},
        cwd=ROOT,
        timeout=420,
    )
    assert r.returncode == 0, r.stderr[-3000:]
    return r.stdout


# ----------------------------------------------------------------------
# shard(): block-range partitioning, both backends
# ----------------------------------------------------------------------
def test_csr_shard_roundtrip_and_padding():
    g = rmat_graph(64, 256, seed=2, block_size=32)
    for k in [1, 2, 3, 4, 7]:  # 3 and 7 won't divide most block counts
        shards = g.shard(k)
        assert len(shards) == k
        per = -(-g.num_blocks // k)
        assert all(s.num_blocks == per for s in shards)
        # concatenated shard views == original + empty padding
        dst = np.concatenate([np.asarray(s.block_dst) for s in shards])
        src = np.concatenate([np.asarray(s.block_src) for s in shards])
        np.testing.assert_array_equal(dst[: g.num_blocks], np.asarray(g.block_dst))
        np.testing.assert_array_equal(src[: g.num_blocks], np.asarray(g.block_src))
        assert np.all(dst[g.num_blocks:] == g.n)  # padding = empty sentinel blocks
        assert np.all(src[g.num_blocks:] == g.n)
        # vertex metadata replicated, global n/m kept
        for s in shards:
            assert s.n == g.n and s.m == g.m
            np.testing.assert_array_equal(np.asarray(s.degrees), np.asarray(g.degrees))


def test_compressed_shard_roundtrip_and_exceptions():
    # wide deltas force a non-empty exception list
    from repro.core import build_csr

    n = 70000
    src = np.array([0, 0, 0, 0, 0, 0, 1, 1, 2, 3], np.int64)
    dst = np.array([1, 2, 66000, 66001, 69998, 69999, 3, 69000, 69500, 68000], np.int64)
    g = build_csr(n, src, dst, block_size=32)
    c = compress(g)
    assert c.n_exceptions > 0
    for k in [1, 2, 3]:
        shards = c.shard(k)
        per = -(-c.num_blocks // k)
        # per-shard exception lists pad to a common length with droppable ids
        ne = shards[0].n_exceptions
        assert all(s.n_exceptions == ne for s in shards)
        total_real = sum(
            int((np.asarray(s.exc_block) < per).sum()) for s in shards
        )
        assert total_real == c.n_exceptions
        # decoded shard blocks == decoded original + sentinel padding
        dec = np.concatenate([np.asarray(decode_blocks(s)) for s in shards])
        np.testing.assert_array_equal(
            dec[: c.num_blocks], np.asarray(decode_blocks(c))
        )
        assert np.all(dec[c.num_blocks:] == c.n)


def test_shard_is_a_backend():
    """Each shard satisfies GraphBackend: edgeMap runs on it unchanged, and
    shard-wise results combine to the whole-graph result."""
    g = rmat_graph(64, 256, seed=4, block_size=32)
    x = jnp.arange(g.n, dtype=jnp.int32)
    fr = from_indices(g.n, [0, 3, 7]).mask
    want, wt = edgemap_reduce(g, fr, x, monoid="min", mode="dense")
    for backend in [g, compress(g)]:
        parts = [
            edgemap_reduce(s, fr, x, monoid="min", mode="dense")
            for s in backend.shard(3)
        ]
        got = np.minimum.reduce([np.asarray(o) for o, _ in parts])
        touched = np.logical_or.reduce([np.asarray(t) for _, t in parts])
        np.testing.assert_array_equal(got, np.asarray(want))
        np.testing.assert_array_equal(touched, np.asarray(wt))


def test_plan_single_device_resolves_strategy():
    g = rmat_graph(64, 256, seed=5, block_size=32)
    plan = make_plan(g, strategy="dense")
    assert not plan.is_sharded and plan.backend == "csr"
    assert plan.prepare(g) is g
    x = jnp.arange(g.n, dtype=jnp.int32)
    fr = from_indices(g.n, [0, 1]).mask
    a, _ = edgemap_reduce(g, fr, x, monoid="min", mode="dense")
    b, _ = edgemap_reduce(g, fr, x, monoid="min", plan=plan)  # mode from plan
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_plan_knobs_reach_edgemap(monkeypatch):
    """plan.chunk_blocks / plan.dense_frac actually reach the edgeMap bodies
    (explicit call-site arguments still win)."""
    import repro.core.edgemap as em

    g = rmat_graph(64, 256, seed=8, block_size=32)
    x = jnp.arange(g.n, dtype=jnp.int32)
    fr = from_indices(g.n, [0]).mask
    seen = {}
    orig = em.edgemap_chunked

    def spy(*a, **k):
        seen.update(k)
        return orig(*a, **k)

    monkeypatch.setattr(em, "edgemap_chunked", spy)
    plan = make_plan(g, strategy="sparse", chunk_blocks=7)
    em.edgemap_reduce(g, fr, x, monoid="min", plan=plan)
    assert seen["chunk_blocks"] == 7
    em.edgemap_reduce(g, fr, x, monoid="min", plan=plan, chunk_blocks=3)
    assert seen["chunk_blocks"] == 3


def test_compressed_shard_keeps_decode_strategy():
    """A shard's padded exception list must not flip the whole-graph
    exception-density verdict (it would force exact decode per shard)."""
    from repro.core import build_csr
    from repro.core.compressed import exception_dense

    # locality-friendly graph with a handful of wide deltas: not dense
    n = 70000
    src = np.concatenate([np.arange(400, dtype=np.int64), [0, 1, 2]])
    dst = np.concatenate([np.arange(1, 401, dtype=np.int64), [69999, 69998, 69997]])
    c = compress(build_csr(n, src, dst, block_size=4))
    assert c.n_exceptions > 0 and not exception_dense(c)
    for s in c.shard(8):
        assert s.exception_dense_hint is False
        assert not exception_dense(s)


def test_filter_shard_composes_with_graph_shard():
    """GraphFilter.shard splits the bit words along the same block ranges as
    GraphBackend.shard, zero-padding the tail — shard s's bits line up 1:1
    with shard s's blocks."""
    g = rmat_graph(64, 256, seed=11, block_size=32)
    f = make_filter(g)
    for k in [1, 2, 3, 4, 7]:
        fshards = f.shard(k)
        gshards = g.shard(k)
        assert len(fshards) == k
        bits = np.concatenate([np.asarray(s.bits) for s in fshards])
        np.testing.assert_array_equal(
            bits[: g.num_blocks], np.asarray(f.bits)
        )
        assert np.all(bits[g.num_blocks :] == 0)  # padded tail: nothing active
        for fs, gs in zip(fshards, gshards):
            assert fs.num_blocks == gs.num_blocks
            np.testing.assert_array_equal(
                np.asarray(fs.active_deg), np.asarray(f.active_deg)
            )


def test_psam_planned_charges():
    g = rmat_graph(64, 600, seed=6, block_size=32)
    c = compress(g)
    flat, planned = PSAMCost(), PSAMCost()
    flat.charge_edgemap_dense(c)
    planned.charge_edgemap_planned(c, num_shards=4)
    # sharding never reads fewer bytes (padding) and pays O(n)/shard combine
    assert planned.large_reads >= flat.large_reads
    assert planned.small_ops == flat.small_ops + 3 * g.n
    # compressed stays cheaper than raw in the distributed path too
    planned_raw = PSAMCost()
    planned_raw.charge_edgemap_planned(g, num_shards=4)
    assert planned.large_reads < planned_raw.large_reads
    # non-dividing block counts charge the padded tail
    a, b = PSAMCost(), PSAMCost()
    a.charge_edgemap_planned(g, num_shards=1)
    b.charge_edgemap_planned(g, num_shards=7)
    assert b.large_reads >= a.large_reads


def test_shard_edge_active_rejects_foreign_filter():
    """A filter built for a smaller graph must fail loudly, not be silently
    zero-padded (which would deactivate real blocks shard-side while the
    single-device path raises on the shape mismatch)."""
    g = rmat_graph(64, 256, seed=3, block_size=32)
    small = make_filter(rmat_graph(16, 32, seed=3, block_size=32))
    assert small.num_blocks < g.num_blocks
    per = -(-g.num_blocks // 4)
    with pytest.raises(ValueError, match="different graph"):
        shard_edge_active(
            small, block_size=32, blocks_per_shard=per, num_shards=4
        )
    # the genuine filter (with its < num_shards pad rows) still shards fine
    sea = shard_edge_active(
        make_filter(g), block_size=32, blocks_per_shard=per, num_shards=4
    )
    assert sea.words.shape == (4, per, 1)
    # with the graph's true block count known (ShardedGraph.orig_num_blocks)
    # the check is exact: a filter 1 block short of an 11-block graph sits
    # inside the pad-range heuristic's window (pad=2 < 4 shards) but fails
    short = jnp.zeros((10, 1), jnp.uint32)
    with pytest.raises(ValueError, match="different graph"):
        shard_edge_active(
            short, block_size=32, blocks_per_shard=3, num_shards=4,
            num_blocks=11,
        )


def test_psam_planned_filtered_charges():
    """A filtered round charges only the live blocks (plus the packed filter
    words); a mostly-dead filter reads far less large memory than the dense
    pass, and an all-live filter costs exactly the filter-word overhead."""
    g = rmat_graph(64, 600, seed=9, block_size=32)
    f = make_filter(g)  # all real edges live
    dense, live_all = PSAMCost(), PSAMCost()
    dense.charge_edgemap_planned(g, num_shards=4)
    live_all.charge_edgemap_planned(g, num_shards=4, filter_live_blocks=f)
    words = -(-g.num_blocks // 4) * 4 * (g.block_size // 32)
    assert live_all.large_reads == dense.large_reads + words
    # kill all but 2 blocks: reads collapse toward the filter-word floor
    sparse = PSAMCost()
    sparse.charge_edgemap_planned(g, num_shards=4, filter_live_blocks=2)
    assert sparse.large_reads < dense.large_reads
    assert sparse.large_reads >= words
    # the combine cost is unchanged by filtering
    assert sparse.small_ops == dense.small_ops
    # numpy integer counts (the natural popcount result) work like ints
    np_count = PSAMCost()
    np_count.charge_edgemap_planned(
        g, num_shards=4, filter_live_blocks=np.int64(2)
    )
    assert np_count.large_reads == sparse.large_reads


# ----------------------------------------------------------------------
# Sharded-vs-single-device parity: BFS / PageRank / connectivity,
# mesh in {(1,), (2,), (4,)} x {CSRGraph, CompressedCSR}
# ----------------------------------------------------------------------
def test_sharded_parity_algorithms():
    out = _run(
        r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import jax, jax.numpy as jnp, numpy as np
from repro.compat import make_mesh, use_mesh
from repro.data import rmat_graph
from repro.core import compress, make_plan
from repro.algorithms import bfs, pagerank, connectivity

g = rmat_graph(256, 1024, seed=7, block_size=32)
c = compress(g)
want_p, want_l = bfs(g, 0)
want_pr, _ = pagerank(g, max_iters=30)
want_cc = connectivity(g, jax.random.PRNGKey(0))
for shape in [(1,), (2,), (4,)]:
    mesh = make_mesh(shape, ("data",))
    for backend in [g, c]:
        plan = make_plan(backend, mesh=mesh)
        with use_mesh(mesh):
            p, l = bfs(backend, 0, plan=plan)
            pr, _ = pagerank(backend, max_iters=30, plan=plan)
            cc = connectivity(backend, jax.random.PRNGKey(0), plan=plan)
        name = (shape, type(backend).__name__)
        assert np.array_equal(np.asarray(p), np.asarray(want_p)), (name, "bfs parents")
        assert np.array_equal(np.asarray(l), np.asarray(want_l)), (name, "bfs levels")
        assert np.allclose(np.asarray(pr), np.asarray(want_pr), atol=1e-5), (name, "pagerank")
        assert np.array_equal(np.asarray(cc), np.asarray(want_cc)), (name, "connectivity")
print("OK")
"""
    )
    assert "OK" in out


def test_filtered_edgemap_sharded_matches_masked_oracle():
    """Acceptance: edge_map(..., edge_active=..., plan=mesh_plan) on a
    4-shard mesh, both backends, must be bit-identical to a single-device
    oracle built from the *unfiltered* machinery with the mask applied —
    for a raw bool mask, a prepared ShardedEdgeActive, and a GraphFilter."""
    out = _run(
        r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import jax, jax.numpy as jnp, numpy as np
from repro.compat import make_mesh, use_mesh
from repro.data import rmat_graph
from repro.core import compress, make_plan, edge_map, from_indices, make_filter

g = rmat_graph(128, 512, seed=21, block_size=32)
c = compress(g)
n = g.n
x0 = jnp.arange(n, dtype=jnp.int32)
fr = from_indices(n, [0, 3, 5, 9])
rng = np.random.default_rng(0)
mask = jnp.asarray(np.asarray(g.edge_valid) & (rng.random(g.num_blocks * 32) < 0.6))

# single-device unfiltered-then-masked oracle (plain numpy over the block view)
dst = np.asarray(g.block_dst)
src = np.asarray(g.block_src)
frm = np.asarray(fr.mask)
act = frm[np.minimum(src, n - 1)][:, None] & (src < n)[:, None] & (dst < n)
act = act & np.asarray(mask).reshape(dst.shape)          # mask applied last
out_o = np.full(n, np.iinfo(np.int32).max, np.int64)
touched_o = np.zeros(n, bool)
xs = np.asarray(x0)
for b in range(dst.shape[0]):
    for s in range(dst.shape[1]):
        if act[b, s]:
            v = dst[b, s]
            out_o[v] = min(out_o[v], xs[src[b]])
            touched_o[v] = True
want_x = np.where(touched_o, np.minimum(xs, out_o), xs)

mesh = make_mesh((4,), ("data",))
for backend in [g, c]:
    plan = make_plan(backend, mesh=mesh)
    gs, sea = plan.prepare(backend, edge_active=mask)
    with use_mesh(mesh):
        for ea in [mask, sea]:
            for mode in ["dense", "sparse"]:
                new_x, nf = edge_map(
                    gs, fr, x0, monoid="min", update="min",
                    edge_active=ea, mode=mode, plan=plan,
                )
                name = (type(backend).__name__, mode, type(ea).__name__)
                assert np.array_equal(np.asarray(new_x), want_x), name
                assert np.array_equal(
                    np.asarray(nf.mask), touched_o & (out_o < xs)
                ), name
print("OK")
"""
    )
    assert "OK" in out


def test_weighted_bucketed_plan_parity_algorithms():
    """wBFS / Bellman-Ford / k-core / set-cover through the planner:
    mesh {(1,), (2,), (4,)} x {CSRGraph, CompressedCSR} must reproduce the
    single-device results exactly (weighted tiles stream uncompressed next
    to the compressed targets; set-cover's per-round filter words shard
    in-trace)."""
    out = _run(
        r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import jax, jax.numpy as jnp, numpy as np
from repro.compat import make_mesh, use_mesh
from repro.data import rmat_graph
from repro.core import compress, make_plan
from repro.algorithms import wbfs, bellman_ford, kcore, set_cover

g = rmat_graph(192, 768, weighted=True, seed=17, block_size=32)
c = compress(g)
sets_mask = jnp.arange(g.n) % 2 == 0
want_w = np.asarray(wbfs(g, 0))
want_b = np.asarray(bellman_ford(g, 0)[0])
want_k = np.asarray(kcore(g))
want_s = np.asarray(set_cover(g, sets_mask, jax.random.PRNGKey(0)))
for shape in [(1,), (2,), (4,)]:
    mesh = make_mesh(shape, ("data",))
    for backend in [g, c]:
        plan = make_plan(backend, mesh=mesh)
        with use_mesh(mesh):
            w = wbfs(backend, 0, plan=plan)
            b, neg = bellman_ford(backend, 0, plan=plan)
            k = kcore(backend, plan=plan)
            s = set_cover(backend, sets_mask, jax.random.PRNGKey(0), plan=plan)
        name = (shape, type(backend).__name__)
        assert np.array_equal(np.asarray(w), want_w), (name, "wbfs")
        assert np.allclose(np.asarray(b), want_b, atol=1e-5), (name, "bellman_ford")
        assert not bool(neg), (name, "neg cycle")
        assert np.array_equal(np.asarray(k), want_k), (name, "kcore")
        assert np.array_equal(np.asarray(s), want_s), (name, "set_cover")
print("OK")
"""
    )
    assert "OK" in out


# ----------------------------------------------------------------------
# Property: filter ∘ shard == shard ∘ filter (no mesh needed — a shard is
# just another GraphBackend, and the filter words split block-range-wise).
# Hypothesis drives the search when installed (CI); otherwise a fixed-seed
# sweep keeps the property exercised without skipping.
# ----------------------------------------------------------------------
def _check_filter_before_vs_after_shard(seed, num_shards, compressed, monoid):
    """A random edge filter applied before shard() (single-device filtered
    edgeMap) equals the filter sharded alongside the blocks and applied
    per shard, shard-wise combined."""
    rng = np.random.default_rng(seed)
    g = rmat_graph(48, 200, seed=seed % 97, block_size=32)
    backend = compress(g) if compressed else g
    mask = jnp.asarray(
        np.asarray(g.edge_valid) & (rng.random(g.num_blocks * 32) < 0.5)
    )
    x = jnp.asarray(rng.integers(0, 100, g.n), jnp.int32)
    fr = jnp.asarray(rng.random(g.n) < 0.4)
    want, wt = edgemap_reduce(
        backend, fr, x, monoid=monoid, edge_active=mask, mode="dense"
    )
    per = -(-g.num_blocks // num_shards)
    sea = shard_edge_active(
        mask, block_size=32, blocks_per_shard=per, num_shards=num_shards
    )
    parts = [
        edgemap_reduce(
            s, fr, x, monoid=monoid,
            edge_active=unpack_word_bits(sea.words[i]), mode="dense",
        )
        for i, s in enumerate(backend.shard(num_shards))
    ]
    combine = np.minimum.reduce if monoid == "min" else np.add.reduce
    got = combine([np.asarray(o, np.int64) for o, _ in parts])
    touched = np.logical_or.reduce([np.asarray(t) for _, t in parts])
    np.testing.assert_array_equal(touched, np.asarray(wt))
    # min identity is int32 max, sum identity 0 — both combine exactly
    np.testing.assert_array_equal(got, np.asarray(want, np.int64))


try:
    from hypothesis import HealthCheck, given, settings
    from hypothesis import strategies as st

    @settings(
        max_examples=12,
        deadline=None,
        suppress_health_check=[HealthCheck.too_slow, HealthCheck.data_too_large],
    )
    @given(
        seed=st.integers(0, 2**31 - 1),
        num_shards=st.integers(1, 5),
        compressed=st.booleans(),
        monoid=st.sampled_from(["min", "sum"]),
    )
    def test_filter_before_vs_after_shard(seed, num_shards, compressed, monoid):
        _check_filter_before_vs_after_shard(seed, num_shards, compressed, monoid)

except ImportError:  # hypothesis not installed: fixed-seed sweep, no skip

    @pytest.mark.parametrize(
        "seed,num_shards,compressed,monoid",
        [
            (0, 1, False, "min"),
            (1, 2, True, "min"),
            (2, 3, False, "sum"),
            (3, 4, True, "sum"),
            (4, 5, True, "min"),
        ],
    )
    def test_filter_before_vs_after_shard(seed, num_shards, compressed, monoid):
        _check_filter_before_vs_after_shard(seed, num_shards, compressed, monoid)


def test_planner_straggler_parity_algorithms():
    """The last bypassers take plan=: personalized PageRank, widest path and
    betweenness route their edgeMaps through ExecutionPlan dispatch — mesh
    {(1,), (2,), (4,)} x {CSRGraph, CompressedCSR} reproduces the
    single-device results (min/max monoids exactly; sum-monoid scores to
    reduction tolerance, as in the PageRank parity suite)."""
    out = _run(
        r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import jax, jax.numpy as jnp, numpy as np
from repro.compat import make_mesh, use_mesh
from repro.data import rmat_graph
from repro.core import compress, make_plan
from repro.algorithms import betweenness, personalized_pagerank, widest_path

g = rmat_graph(192, 768, weighted=True, seed=23, block_size=32)
c = compress(g)
want_p, want_r, want_ro = personalized_pagerank(g, 0, max_rounds=40)
want_w = np.asarray(widest_path(g, 0))
want_b = np.asarray(betweenness(g, 0))
for shape in [(1,), (2,), (4,)]:
    mesh = make_mesh(shape, ("data",))
    for backend in [g, c]:
        plan = make_plan(backend, mesh=mesh)
        with use_mesh(mesh):
            p, r, ro = personalized_pagerank(backend, 0, max_rounds=40, plan=plan)
            w = widest_path(backend, 0, plan=plan)
            b = betweenness(backend, 0, plan=plan)
        name = (shape, type(backend).__name__)
        assert np.allclose(np.asarray(p), np.asarray(want_p), atol=1e-5), (name, "ppr p")
        assert np.allclose(np.asarray(r), np.asarray(want_r), atol=1e-5), (name, "ppr r")
        assert int(ro) == int(want_ro), (name, "ppr rounds")
        assert np.array_equal(np.asarray(w), want_w), (name, "widest_path")
        assert np.allclose(np.asarray(b), want_b, atol=1e-4), (name, "betweenness")
print("OK")
"""
    )
    assert "OK" in out


def test_sharded_modes_and_monoids():
    """dense/sparse/auto strategies and sum/min monoids all agree with the
    single-device engine on a 2D mesh, both backends, incl. hierarchical."""
    out = _run(
        r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import jax, jax.numpy as jnp, numpy as np
from repro.compat import make_mesh, use_mesh
from repro.data import rmat_graph
from repro.core import compress, make_plan, edgemap_reduce, from_indices

g = rmat_graph(128, 512, seed=3, block_size=32)
c = compress(g)
x = jnp.arange(g.n, dtype=jnp.int32)
xf = jnp.asarray(np.random.default_rng(0).normal(size=g.n), jnp.float32)
fr = from_indices(g.n, [0, 5, 9]).mask
full = jnp.ones(g.n, bool)
mesh = make_mesh((2, 2), ("pod", "data"))
for backend in [g, c]:
    want_min, wt = edgemap_reduce(backend, fr, x, monoid="min", mode="dense")
    want_sum, _ = edgemap_reduce(backend, full, xf, monoid="sum", mode="dense")
    for rm in ["flat", "hierarchical"]:
        plan = make_plan(backend, mesh=mesh, reduce_mode=rm)
        gs = plan.prepare(backend)
        with use_mesh(mesh):
            for mode in ["dense", "sparse", "auto"]:
                got, t = edgemap_reduce(gs, fr, x, monoid="min", mode=mode, plan=plan)
                assert np.array_equal(np.asarray(got), np.asarray(want_min)), (rm, mode)
                assert np.array_equal(np.asarray(t), np.asarray(wt)), (rm, mode)
            s, _ = edgemap_reduce(gs, full, xf, monoid="sum", mode="dense", plan=plan)
            assert np.allclose(np.asarray(s), np.asarray(want_sum), atol=1e-5), rm
print("OK")
"""
    )
    assert "OK" in out
