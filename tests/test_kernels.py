"""Per-kernel interpret=True validation against the pure-jnp ref.py oracles,
swept over shapes and dtypes."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import compress, edge_active_words, make_filter, pack_vertices
from repro.data import rmat_graph
from repro.kernels import (
    compressed_spmv_vertex,
    compressed_spmv_vertex_batched,
    embedding_bag,
    filter_pack,
    spmv_vertex,
    spmv_vertex_batched,
)
from repro.kernels.compressed_spmv.compressed_spmv import compressed_block_spmv_pallas
from repro.kernels.compressed_spmv.ref import (
    compressed_block_spmv_ref,
    compressed_spmv_vertex_ref,
)
from repro.kernels.edge_block_spmv.edge_block_spmv import edge_block_spmv_pallas
from repro.kernels.edge_block_spmv.ref import edge_block_spmv_ref, spmv_vertex_ref
from repro.kernels.embedding_bag.ref import embedding_bag_ref
from repro.kernels.filter_pack.filter_pack import filter_pack_pallas
from repro.kernels.filter_pack.ref import filter_pack_ref


@pytest.mark.parametrize("n,m,bs", [(32, 96, 32), (64, 256, 32), (128, 700, 64)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("tile", [2, 8])
def test_edge_block_spmv_sweep(n, m, bs, dtype, tile):
    g = rmat_graph(n, m, weighted=True, seed=n + m, block_size=bs)
    f = make_filter(g)
    x = jax.random.normal(jax.random.PRNGKey(1), (g.n,), jnp.float32).astype(dtype)
    bw = g.block_w.astype(dtype)
    got = edge_block_spmv_pallas(x, g.block_dst, bw, f.bits, n=g.n, tile_blocks=tile)
    want = edge_block_spmv_ref(x, g.block_dst, bw, f.bits, n=g.n)
    tol = 1e-5 if dtype == jnp.float32 else 3e-2
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32), rtol=tol, atol=tol
    )


@pytest.mark.parametrize("n,m,bs,tile", [(32, 96, 32, 2), (64, 256, 32, 8)])
def test_edge_block_spmv_edge_active_operand(n, m, bs, tile):
    """The packed edge_active operand is ANDed into the validity mask
    in-kernel — parity with the oracle and with pre-ANDed filter bits."""
    g = rmat_graph(n, m, weighted=True, seed=m, block_size=bs)
    f = make_filter(g)
    x = jax.random.normal(jax.random.PRNGKey(2), (g.n,), jnp.float32)
    keep = jax.random.bernoulli(jax.random.PRNGKey(3), 0.6, (g.num_blocks * bs,))
    aw = edge_active_words(keep, bs)
    got = edge_block_spmv_pallas(
        x, g.block_dst, g.block_w, f.bits, aw, n=g.n, tile_blocks=tile
    )
    want = edge_block_spmv_ref(x, g.block_dst, g.block_w, f.bits, aw, n=g.n)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-5)
    # streaming two masks ≡ one pre-ANDed mask (the HBM-round-trip variant)
    pre = edge_block_spmv_pallas(
        x, g.block_dst, g.block_w, f.bits & aw, n=g.n, tile_blocks=tile
    )
    np.testing.assert_allclose(np.asarray(got), np.asarray(pre), rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("n,m,bs,tile", [(32, 96, 32, 2), (64, 256, 32, 8)])
def test_compressed_spmv_edge_active_operand(n, m, bs, tile):
    """The compressed kernel consumes the packed bitmask in-kernel, fused
    with the delta decode — parity with the exact-decode oracle, weighted
    and unweighted."""
    for weighted in [False, True]:
        g = rmat_graph(n, m, weighted=weighted, seed=n + m, block_size=bs)
        c = compress(g)
        f = make_filter(g)
        x = jax.random.normal(jax.random.PRNGKey(4), (g.n,), jnp.float32)
        keep = jax.random.bernoulli(jax.random.PRNGKey(5), 0.5, (c.num_blocks * bs,))
        aw = edge_active_words(keep, bs)
        got = compressed_block_spmv_pallas(
            x, c.block_first, c.deltas, c.valid_count, f.bits, aw,
            c.block_weights, n=c.n, tile_blocks=tile,
        )
        want = compressed_block_spmv_ref(c, x, f.bits, c.block_weights, aw)
        if c.n_exceptions:  # escaped blocks decode wrong pre-fixup by design
            rows = np.setdiff1d(np.arange(c.num_blocks), np.asarray(c.exc_block))
        else:
            rows = np.arange(c.num_blocks)
        np.testing.assert_allclose(
            np.asarray(got)[rows], np.asarray(want)[rows], rtol=1e-5, atol=1e-5
        )


def test_compressed_filtered_fast_path_no_full_decode(monkeypatch):
    """A filtered edgeMap on a compressed graph with a sparse exception list
    must stay on the fused kernel path: the exact-decode fallback is a
    function of exception density only, never of the filter.  The oracle is
    stubbed to fail, so any full-decode fallback would raise."""
    import test_compressed as tc

    import repro.kernels.compressed_spmv.ops as ops
    from repro.core.compressed import exception_dense

    g = tc.wide_delta_graph(weighted=True)
    c = compress(g)
    assert c.n_exceptions > 0 and not exception_dense(c)
    f = make_filter(g)
    x = jax.random.normal(jax.random.PRNGKey(6), (g.n,), jnp.float32)
    keep = jax.random.bernoulli(
        jax.random.PRNGKey(7), 0.7, (c.num_blocks * c.block_size,)
    )
    want = compressed_spmv_vertex_ref(
        c, x, f.bits, c.block_weights, edge_active_words(keep, c.block_size)
    )

    def boom(*a, **k):
        raise AssertionError("filtered fast path fell back to full decode")

    monkeypatch.setattr(ops, "compressed_block_spmv_ref", boom)
    got = compressed_spmv_vertex(c, x, f, edge_active=keep)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-5)


def test_spmv_vertex_edge_active_forms_agree():
    """GraphFilter | packed words | bool slot mask are one representation:
    spmv_vertex accepts each and returns identical sums."""
    g = rmat_graph(64, 256, weighted=True, seed=13, block_size=32)
    f = make_filter(g)
    x = jax.random.normal(jax.random.PRNGKey(8), (g.n,), jnp.float32)
    keep = g.edge_valid & (g.edge_dst % 3 != 0)
    aw = edge_active_words(keep, g.block_size)
    f2 = pack_vertices(g, f, jnp.ones(g.n, bool), keep)
    a = spmv_vertex(g, x, f, edge_active=keep)
    b = spmv_vertex(g, x, f, edge_active=aw)
    d = spmv_vertex(g, x, f, edge_active=f2)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-6)
    np.testing.assert_allclose(np.asarray(a), np.asarray(d), rtol=1e-6)


@pytest.mark.parametrize("B,tile", [(1, 2), (3, 8), (8, 4)])
def test_edge_block_spmv_batched_sweep(B, tile):
    """The query-batch dimension: one (TB, FB) tile load serves B columns.
    The batched kernel must match the vectorized oracle AND be bit-identical
    per query to B single-query kernel calls."""
    g = rmat_graph(64, 256, weighted=True, seed=B + tile, block_size=32)
    f = make_filter(g)
    xb = jax.random.normal(jax.random.PRNGKey(B), (B, g.n), jnp.float32)
    keep = jax.random.bernoulli(jax.random.PRNGKey(1), 0.6, (g.num_blocks * 32,))
    aw = edge_active_words(keep, 32)
    got = edge_block_spmv_pallas(
        xb, g.block_dst, g.block_w, f.bits, aw, n=g.n, tile_blocks=tile
    )
    assert got.shape == (g.num_blocks, B)
    want = edge_block_spmv_ref(xb, g.block_dst, g.block_w, f.bits, aw, n=g.n)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-5)
    for i in range(B):
        solo = edge_block_spmv_pallas(
            xb[i], g.block_dst, g.block_w, f.bits, aw, n=g.n, tile_blocks=tile
        )
        np.testing.assert_array_equal(np.asarray(got[:, i]), np.asarray(solo))


@pytest.mark.parametrize("B,tile", [(1, 2), (3, 8), (8, 4)])
@pytest.mark.parametrize("weighted", [False, True])
def test_compressed_spmv_batched_sweep(B, tile, weighted):
    """Batched compressed kernel: the delta tile is decoded once per grid
    step and fanned across B columns — parity with the exact-decode oracle
    on non-exception rows, bit-identical per query to single calls."""
    g = rmat_graph(64, 256, weighted=weighted, seed=B + tile, block_size=32)
    c = compress(g)
    f = make_filter(g)
    xb = jax.random.normal(jax.random.PRNGKey(B + 7), (B, g.n), jnp.float32)
    got = compressed_block_spmv_pallas(
        xb, c.block_first, c.deltas, c.valid_count, f.bits, None,
        c.block_weights, n=c.n, tile_blocks=tile,
    )
    assert got.shape == (c.num_blocks, B)
    want = compressed_block_spmv_ref(c, xb, f.bits, c.block_weights)
    if c.n_exceptions:
        rows = np.setdiff1d(np.arange(c.num_blocks), np.asarray(c.exc_block))
    else:
        rows = np.arange(c.num_blocks)
    np.testing.assert_allclose(
        np.asarray(got)[rows], np.asarray(want)[rows], rtol=1e-5, atol=1e-5
    )
    for i in range(B):
        solo = compressed_block_spmv_pallas(
            xb[i], c.block_first, c.deltas, c.valid_count, f.bits, None,
            c.block_weights, n=c.n, tile_blocks=tile,
        )
        np.testing.assert_array_equal(np.asarray(got[:, i]), np.asarray(solo))


def test_spmv_vertex_batched_matches_singles():
    """Wrapper-level parity, exception fixup included: the batched vertex
    sums equal B stacked single-query calls on both kernel packages."""
    import test_compressed as tc

    g = rmat_graph(64, 256, weighted=True, seed=13, block_size=32)
    c = compress(g)
    f = make_filter(g)
    keep = g.edge_valid & (g.edge_dst % 3 != 0)
    xb = jax.random.normal(jax.random.PRNGKey(2), (3, g.n), jnp.float32)
    got = spmv_vertex_batched(g, xb, f, edge_active=keep)
    want = jnp.stack([spmv_vertex(g, xb[i], f, edge_active=keep) for i in range(3)])
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
    gotc = compressed_spmv_vertex_batched(c, xb, f, edge_active=keep)
    wantc = jnp.stack(
        [compressed_spmv_vertex(c, xb[i], f, edge_active=keep) for i in range(3)]
    )
    np.testing.assert_array_equal(np.asarray(gotc), np.asarray(wantc))
    # the COO-exception fixup is vectorized to match (wide-delta graph)
    gw = tc.wide_delta_graph(weighted=True)
    cw = compress(gw)
    assert cw.n_exceptions > 0
    xw = jax.random.normal(jax.random.PRNGKey(3), (2, gw.n), jnp.float32)
    got_e = compressed_spmv_vertex_batched(cw, xw)
    want_e = jnp.stack([compressed_spmv_vertex(cw, xw[i]) for i in range(2)])
    np.testing.assert_array_equal(np.asarray(got_e), np.asarray(want_e))


def test_spmv_vertex_matches_ref_and_filter():
    g = rmat_graph(64, 256, weighted=True, seed=3, block_size=32)
    f = make_filter(g)
    keep = g.edge_valid & (g.edge_dst % 3 != 0)
    f2 = pack_vertices(g, f, jnp.ones(g.n, bool), keep)
    x = jax.random.normal(jax.random.PRNGKey(0), (g.n,), jnp.float32)
    got = spmv_vertex(g, x, f2)
    want = spmv_vertex_ref(x, g.block_dst, g.block_w, f2.bits, g.block_src, n=g.n)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("nb,fb,tile", [(8, 32, 2), (46, 32, 8), (17, 64, 4)])
def test_filter_pack_sweep(nb, fb, tile):
    key = jax.random.PRNGKey(nb * fb)
    bits = jax.random.randint(key, (nb, fb // 32), 0, 2**31 - 1).astype(jnp.uint32)
    keep = jax.random.bernoulli(jax.random.fold_in(key, 1), 0.5, (nb, fb))
    subset = jax.random.bernoulli(jax.random.fold_in(key, 2), 0.6, (nb,))
    got_bits, got_cnt = filter_pack_pallas(bits, keep, subset, tile_blocks=tile)
    want_bits, want_cnt = filter_pack_ref(bits, keep, subset)
    assert bool(jnp.all(got_bits == want_bits))
    assert bool(jnp.all(got_cnt == want_cnt))


def test_filter_pack_matches_core():
    g = rmat_graph(64, 256, seed=9, block_size=32)
    f = make_filter(g)
    keep = g.edge_valid & (g.edge_w >= 0)  # all
    keep = keep & (g.edge_dst % 2 == 1)
    subset = jnp.arange(g.n) % 2 == 0
    f_kernel = filter_pack(g, f, subset, keep)
    f_core = pack_vertices(g, f, subset, keep)
    assert bool(jnp.all(f_kernel.bits == f_core.bits))
    assert bool(jnp.all(f_kernel.active_deg == f_core.active_deg))


@pytest.mark.parametrize("V,D,B,L", [(50, 8, 16, 4), (100, 16, 37, 5), (200, 32, 64, 9)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_embedding_bag_sweep(V, D, B, L, dtype):
    k = jax.random.PRNGKey(V + B)
    table = jax.random.normal(k, (V, D), jnp.float32).astype(dtype)
    idx = jax.random.randint(jax.random.fold_in(k, 1), (B, L), -1, V)
    w = jax.random.normal(jax.random.fold_in(k, 2), (B, L), jnp.float32).astype(dtype)
    got = embedding_bag(table, idx, w)
    want = embedding_bag_ref(table, idx, w)
    tol = 1e-5 if dtype == jnp.float32 else 3e-2
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32), rtol=tol, atol=tol
    )


def test_embedding_bag_mean_mode():
    table = jnp.eye(8, dtype=jnp.float32)
    idx = jnp.asarray([[0, 1, -1, -1], [2, 2, 2, -1]], jnp.int32)
    out = embedding_bag(table, idx, mode="mean")
    assert np.isclose(out[0, 0], 0.5) and np.isclose(out[1, 2], 1.0)
