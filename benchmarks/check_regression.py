"""Gate a bench CSV against the committed baseline JSON.

    PYTHONPATH=src python -m benchmarks.check_regression bench_full.csv \
        benchmarks/baseline_full.json [--threshold 1.25] [--trend trend.csv]

Fails (exit 1) when any benchmark present in both files regressed in
``us_per_call`` by more than the threshold factor, or when any row errored.
Rows below ``--floor`` microseconds in the baseline are skipped — timer
noise dominates there — as are derived-only rows (us_per_call <= 0).

``--trend PATH`` appends this run's rows to a rolling CSV
(``timestamp,sha,name,us_per_call``) *before* gating, so regressed runs
leave a trace too.  The nightly workflow carries the file across runs via
the actions cache and uploads it as an artifact — per-PR trend lines for
every benchmark, the filtered-edgeMap rows included.

Alongside the CSV, ``--trend`` maintains a schema-versioned JSON sibling
(``<PATH minus .csv>.json``): one object per run keyed by git SHA +
timestamp with the full row dict — the machine-readable series dashboards
ingest without re-parsing CSV (schema_version 1:
``{"schema_version": 1, "runs": [{"sha", "timestamp", "rows": {...}}]}``).
A corrupt or pre-schema file is restarted, never crashed on.

``BENCH_REGRESSION_FACTOR`` (env) scales the threshold for known-slower
runners without editing the workflow.

Regenerate the baseline on a quiet machine with:
    PYTHONPATH=src python -m benchmarks.run --full > bench_full.csv
    PYTHONPATH=src python -m benchmarks.check_regression bench_full.csv \
        benchmarks/baseline_full.json --write-baseline
"""
from __future__ import annotations

import argparse
import datetime
import json
import os
import sys


def read_csv(path: str) -> tuple[dict[str, float], list[str]]:
    rows, errors = {}, []
    with open(path) as fh:
        for line in fh:
            line = line.strip()
            if not line or line.startswith("name,"):
                continue
            parts = line.split(",", 2)
            if len(parts) < 3:
                continue  # continuation of a multi-line error message
            name, us, derived = parts
            try:
                us_val = float(us)
            except ValueError:
                continue  # not a bench row (stray output on stdout)
            if us_val < 0 or derived.startswith(("ERROR:", "FAILED:")):
                errors.append(f"{name}: {derived.splitlines()[0]}")
                continue
            rows[name] = us_val
    return rows, errors


TREND_SCHEMA_VERSION = 1


def trend_json_path(csv_path: str) -> str:
    """The JSON sibling of a trend CSV path (``bench_trend.csv`` →
    ``bench_trend.json``)."""
    return os.path.splitext(csv_path)[0] + ".json"


def append_trend(path: str, rows: dict[str, float]) -> None:
    """Append one line per benchmark to the rolling trend CSV (header on
    first write) AND one run object to the JSON sibling.  ``GITHUB_SHA``
    tags the rows with the commit when run in CI, so the artifacts read as
    a per-PR time series."""
    fresh = not os.path.exists(path) or os.path.getsize(path) == 0
    ts = datetime.datetime.now(datetime.timezone.utc).strftime("%Y-%m-%dT%H:%M:%SZ")
    sha = os.environ.get("GITHUB_SHA", "local")[:12]
    with open(path, "a") as fh:
        if fresh:
            fh.write("timestamp,sha,name,us_per_call\n")
        for name, us in sorted(rows.items()):
            fh.write(f"{ts},{sha},{name},{us:.0f}\n")
    jpath = trend_json_path(path)
    doc = {"schema_version": TREND_SCHEMA_VERSION, "runs": []}
    if os.path.exists(jpath):
        try:
            with open(jpath) as fh:
                loaded = json.load(fh)
            if (
                isinstance(loaded, dict)
                and loaded.get("schema_version") == TREND_SCHEMA_VERSION
                and isinstance(loaded.get("runs"), list)
            ):
                doc = loaded
        except (json.JSONDecodeError, OSError):
            pass  # corrupt cache entry: restart the series, don't crash CI
    doc["runs"].append({"sha": sha, "timestamp": ts, "rows": dict(sorted(rows.items()))})
    with open(jpath, "w") as fh:
        json.dump(doc, fh, indent=1, sort_keys=True)
    print(
        f"trend: appended {len(rows)} rows to {path} "
        f"(+ run {len(doc['runs'])} in {jpath})"
    )


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("csv")
    ap.add_argument("baseline")
    ap.add_argument("--threshold", type=float, default=1.25,
                    help="fail when us_per_call > baseline * threshold")
    ap.add_argument("--floor", type=float, default=200.0,
                    help="skip rows whose baseline is below this (us)")
    ap.add_argument("--write-baseline", action="store_true",
                    help="overwrite the baseline JSON from the CSV and exit")
    ap.add_argument("--trend", default=None, metavar="PATH",
                    help="append this run's us_per_call rows to a rolling CSV")
    args = ap.parse_args()

    rows, errors = read_csv(args.csv)
    if args.trend:
        append_trend(args.trend, rows)
    if args.write_baseline:
        if errors:
            # an errored row silently vanishing from the baseline would
            # exempt that benchmark from the gate forever — refuse
            print("refusing to write baseline from a run with errors:",
                  file=sys.stderr)
            for e in errors:
                print(f"  {e}", file=sys.stderr)
            return 1
        with open(args.baseline, "w") as fh:
            json.dump(rows, fh, indent=1, sort_keys=True)
        print(f"wrote {len(rows)} baseline rows to {args.baseline}")
        return 0

    threshold = args.threshold * float(os.environ.get("BENCH_REGRESSION_FACTOR", 1.0))
    with open(args.baseline) as fh:
        base = json.load(fh)

    failures = list(errors)
    for name, base_us in sorted(base.items()):
        if base_us <= 0 or base_us < args.floor:
            continue
        if name not in rows:
            failures.append(f"{name}: missing from bench run (baseline {base_us:.0f}us)")
            continue
        now = rows[name]
        ratio = now / base_us
        flag = "REGRESSED" if ratio > threshold else "ok"
        print(f"{name}: {base_us:.0f}us -> {now:.0f}us ({ratio:.2f}x) {flag}")
        if ratio > threshold:
            failures.append(f"{name}: {ratio:.2f}x > {threshold:.2f}x threshold")
    unbaselined = sorted(set(rows) - set(base))
    if unbaselined:
        print(
            f"note: {len(unbaselined)} rows have no baseline entry and are "
            f"ungated (regenerate with --write-baseline): "
            + ", ".join(unbaselined)
        )
    if failures:
        print("\nFAIL:", file=sys.stderr)
        for f in failures:
            print(f"  {f}", file=sys.stderr)
        return 1
    print(f"\nall {len(rows)} rows within {threshold:.2f}x of baseline")
    return 0


if __name__ == "__main__":
    sys.exit(main())
