"""Benchmark harness — one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV lines.

  fig1_suite    — Fig. 1 / Fig. 6: the 18-algorithm suite + PSAM work model
  table4_filter — Table 4: filter block size F_B ↔ triangle-count work
  table4_filter_planned — filtered edgeMap via the kernel edge_active
                  operand (raw + compressed) and a 4-shard mesh
  table5_edgemap— Table 5: edgeMap variant ↔ peak intermediate memory
  table_compression — §5.1.3: compression ratio + compressed edgeMap throughput
  table_distributed — planner: per-shard PageRank throughput, compressed vs raw
  table_serving — QueryEngine: queries/sec vs batch size B, both backends,
                  + PSAM edge-read amortization at B=8
  table_latency — ServingService: p50/p99 latency over Poisson + bursty
                  arrival traces, qps-vs-SLO curve, saturated-B8 vs engine
  table_streaming — delta overlay: edit-plus-query trace replay, per-edit
                  and compaction costs, PSAM amortization vs recompress-
                  per-edit (in-bench asserted >= 10x at batch 1000)
  table_autotune— tuning: strategy="auto" vs every fixed strategy across a
                  frontier-density sweep (in-bench asserted) + BFS/wBFS/
                  PageRank replays under an in-run calibrated table
  fig_layout    — §5.2: pod-replicated layout ↔ collective bytes
  kernels_micro — Pallas kernels vs jnp oracles
  roofline      — §Roofline terms from the dry-run artifacts (if present)
"""
from __future__ import annotations

import argparse


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None, help="comma list of benches")
    ap.add_argument("--full", action="store_true", help="paper-scale sizes")
    ap.add_argument(
        "--profile",
        default=None,
        metavar="DIR",
        help="capture a jax.profiler trace of the selected benches into DIR "
        "(one TraceAnnotation span per bench; the traced rounds carry the "
        "sage.round / sage.shard_combine named scopes)",
    )
    args = ap.parse_args()

    from . import (fig1_suite, fig7_dram_nvram, fig_layout, kernels_micro,
                   table4_filter, table5_edgemap, table_autotune,
                   table_compression, table_distributed, table_latency,
                   table_serving, table_streaming)

    benches = {
        "fig1_suite": lambda: fig1_suite.run(
            n=4096 if args.full else 1024, m=32768 if args.full else 8192
        ),
        "table4_filter": lambda: table4_filter.run(
            n=2048 if args.full else 512, m=16384 if args.full else 4096
        ),
        # planner-native filter columns: Pallas edge_active operand (raw +
        # compressed) and the 4-shard sharded-filter path
        "table4_filter_planned": lambda: table4_filter.run_planned(
            n=2048 if args.full else 512, m=16384 if args.full else 4096
        ),
        "table5_edgemap": lambda: table5_edgemap.run(
            n=4096 if args.full else 1024, m=65536 if args.full else 8192
        ),
        "table_compression": lambda: table_compression.run(
            n=4096 if args.full else 1024, m=65536 if args.full else 8192
        ),
        # --full is RMAT-20: 2^20 vertices, the paper-scale stand-in
        "table_distributed": lambda: table_distributed.run(
            n=(1 << 20) if args.full else 4096,
            m=(1 << 22) if args.full else 16384,
        ),
        # queries/sec vs batch size through the QueryEngine (both backends)
        "table_serving": lambda: table_serving.run(
            n=4096 if args.full else 1024, m=32768 if args.full else 8192
        ),
        # deadline-driven drain loop: latency percentiles over replayed
        # arrival traces + the saturated-B8 qps parity with the engine
        "table_latency": lambda: table_latency.run(
            n=4096 if args.full else 1024, m=32768 if args.full else 8192
        ),
        # mutable serving: delta-overlay edit replay + compaction
        # amortization vs recompress-per-edit (PSAM words, asserted)
        "table_streaming": lambda: table_streaming.run(
            n=4096 if args.full else 1024, m=32768 if args.full else 8192
        ),
        # auto-vs-fixed strategy spread with an in-run calibrated table;
        # always the calibration-default workload — the in-bench tolerance
        # asserts were validated at this size, smaller graphs compress the
        # strategy spread below the asserted margins
        "table_autotune": lambda: table_autotune.run(
            n=2048, m=16384, reps=3 if args.full else 2
        ),
        "kernels_micro": kernels_micro.run,
        "fig_layout": fig_layout.run,
        "fig7_dram_nvram": fig7_dram_nvram.run,
    }
    try:
        from . import roofline

        if roofline.load_records():
            benches["roofline"] = roofline.run
    except Exception:
        pass

    only = set(args.only.split(",")) if args.only else None
    import contextlib

    if args.profile:
        # the shared tracing session (repro.obs) — same capture the serving
        # tier uses, so bench traces and service traces read identically
        from repro.obs import annotate, trace_session

        session = trace_session(args.profile)
    else:
        annotate = None
        session = contextlib.nullcontext()
    with session:
        print("name,us_per_call,derived")
        for name, fn in benches.items():
            if only and name not in only:
                continue
            try:
                if annotate is not None:
                    with annotate(f"bench.{name}"):
                        rows = fn()
                else:
                    rows = fn()
                for r in rows:
                    print(
                        f"{r['name']},{r['us_per_call']:.0f},{r['derived']}",
                        flush=True,
                    )
            except Exception as e:  # noqa: BLE001
                print(f"{name},-1,ERROR: {type(e).__name__}: {e}", flush=True)


if __name__ == "__main__":
    main()
