"""Paper Table 5: sparse-traversal variant vs intermediate memory + time.

Three variants over the same BFS workload:
  edgeMapSparse  — materializes an output slot per incident edge: O(Σdeg(F))
  edgeMapBlocked — per-block output arrays: O(#active blocks · F_B)
  edgeMapChunked — fixed chunk pool: O(chunk_blocks · F_B)  ← Sage (§4.1)

Peak intermediate words are computed exactly from the frontier trace (the
same quantity the paper measures as DRAM usage), times are measured on the
chunked/dense executable paths.
"""
from __future__ import annotations

import time

import jax
import numpy as np

from repro.algorithms import bfs
from repro.core.edgemap import DEFAULT_CHUNK_BLOCKS
from repro.data import rmat_graph


def run(n=4096, m=65536):
    g = rmat_graph(n, m, seed=2, block_size=64)
    # frontier trace from levels
    _, lev = bfs(g, 0)
    lev = np.asarray(lev)
    deg = np.asarray(g.degrees)
    rows = []
    peak_sparse = 0
    peak_blocked = 0
    for l in range(lev.max() + 1):
        frontier = lev == l
        sum_deg = int(deg[frontier].sum())
        nblocks = int(np.ceil(deg[frontier] / g.block_size).sum())
        peak_sparse = max(peak_sparse, sum_deg)
        peak_blocked = max(peak_blocked, nblocks * g.block_size)
    peak_chunked = DEFAULT_CHUNK_BLOCKS * g.block_size + g.num_blocks  # pool + index

    for mode, peak in [
        ("edgeMapSparse", peak_sparse),
        ("edgeMapBlocked", peak_blocked),
        ("edgeMapChunked", peak_chunked),
    ]:
        run_mode = "sparse" if mode == "edgeMapChunked" else "auto"
        fn = jax.jit(lambda s: bfs(g, s, mode=run_mode)[1])
        fn(0)[0].block_until_ready()
        t0 = time.perf_counter()
        fn(0).block_until_ready()
        dt = time.perf_counter() - t0
        rows.append(
            dict(
                name=f"table5_{mode}",
                us_per_call=dt * 1e6,
                derived=f"peak_intermediate_words={peak} n={g.n} m={g.m}",
            )
        )
    return rows


if __name__ == "__main__":
    for r in run():
        print(f"{r['name']},{r['us_per_call']:.0f},{r['derived']}")
