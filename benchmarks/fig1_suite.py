"""Paper Figure 1 / Figure 6: the full 18-algorithm suite on a large RMAT
graph (stand-in for Hyperlink/ClueWeb), with the PSAM work accounting that
reproduces Table 1's Sage-vs-GBBS contrast.

For every problem we report:
  wall-time (this container's CPU — relative numbers are what matter),
  PSAM work (large reads + small ops; Sage performs 0 large-memory writes),
  modeled GBBS work (the same algorithm writing its mutations to large
  memory at ω=4, per Table 1's O(ωm) column).
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro.algorithms import (
    bellman_ford, betweenness, bfs, biconnectivity, coloring, connectivity,
    densest_subgraph, kcore, ldd, maximal_matching, mis, pagerank, set_cover,
    spanner, spanning_forest, triangle_count, wbfs, widest_path,
)
from repro.core import PSAMCost
from repro.data import rmat_graph

KEY = jax.random.PRNGKey(0)


def _timed(fn):
    t0 = time.perf_counter()
    out = fn()
    jax.block_until_ready(out)
    return out, time.perf_counter() - t0


def run(n=4096, m=32768, seed=0):
    g = rmat_graph(n, m, weighted=True, seed=seed, block_size=64)
    rows = []

    def bench(name, fn, *, rounds_hint=1, mutated_words=0):
        # warmup (compile) then measure
        _timed(fn)
        out, dt = _timed(fn)
        cost = PSAMCost()
        for _ in range(rounds_hint):
            cost.charge_edgemap_dense(g)
        gbbs = cost.gbbs_equivalent_work(mutated_words or g.m)
        rows.append(
            dict(
                name=name,
                us_per_call=dt * 1e6,
                sage_work=cost.work,
                gbbs_work_w4=gbbs,
                derived=f"work_ratio={gbbs / max(cost.work, 1):.2f}",
            )
        )
        return out

    diam_hint = 8
    bench("bfs", lambda: bfs(g, 0), rounds_hint=diam_hint)
    bench("wbfs", lambda: wbfs(g, 0), rounds_hint=3 * diam_hint)
    bench("bellman_ford", lambda: bellman_ford(g, 0), rounds_hint=diam_hint)
    bench("widest_path", lambda: widest_path(g, 0), rounds_hint=diam_hint)
    bench("betweenness", lambda: betweenness(g, 0), rounds_hint=2 * diam_hint)
    bench("spanner", lambda: spanner(g, 8, KEY), rounds_hint=diam_hint)
    bench("ldd", lambda: ldd(g, 0.2, KEY), rounds_hint=diam_hint)
    bench("connectivity", lambda: connectivity(g, KEY), rounds_hint=diam_hint)
    bench("spanning_forest", lambda: spanning_forest(g, KEY), rounds_hint=diam_hint)
    bench("biconnectivity", lambda: biconnectivity(g), rounds_hint=3 * diam_hint)
    bench("coloring", lambda: coloring(g, num_colors=512), rounds_hint=12)
    bench("mis", lambda: mis(g, KEY), rounds_hint=8)
    bench("maximal_matching", lambda: maximal_matching(g, KEY), rounds_hint=8)
    sets_mask = jnp.arange(g.n) < g.n // 3
    bench("set_cover", lambda: set_cover(g, sets_mask, KEY), rounds_hint=12)
    bench("triangle_count", lambda: jnp.asarray(triangle_count(g)), rounds_hint=2)
    bench("kcore", lambda: kcore(g), rounds_hint=30)
    bench("densest_subgraph", lambda: densest_subgraph(g), rounds_hint=15)
    bench("pagerank", lambda: pagerank(g), rounds_hint=25)
    return rows


if __name__ == "__main__":
    for r in run():
        print(f"{r['name']},{r['us_per_call']:.0f},{r['derived']}")
