"""Paper §5.2: graph layout / replication microbenchmark, at pod scale.

The paper found cross-socket NVRAM reads 3.7× slower and fixed it by
replicating the graph per socket.  The pod-scale analogue: cross-pod edge
traffic must be avoided by making the 'pod' axis a pure replica axis.  We
compare the collective bytes (from compiled HLO) of one distributed
vertex-reduce round under (a) edges sharded across ALL axes including pod —
cross-pod psum carries the O(n) vertex vector per axis; (b) the engine's
layout where the pod axis only ever reduces O(n) words once.

On 8 fake CPU devices (2 pods × 4); the metric is compile-derived bytes,
not wall time.
"""
from __future__ import annotations

import json
import os
import subprocess
import sys
import time


def run():
    code = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp
from repro.data import rmat_graph
from repro.distributed.engine import distributed_vertex_reduce, prepare_sharded
from repro.launch.dryrun import collective_bytes_from_hlo
from repro.compat import make_mesh, use_mesh
import json

g = rmat_graph(1024, 8192, seed=0, block_size=64)
out = {}
for name, shape, axes in [
    ("edges_sharded_all_axes", (2, 4), ("pod", "data")),
    ("single_axis_flat", (8,), ("data",)),
]:
    mesh = make_mesh(shape, axes)
    gs = prepare_sharded(mesh, g)
    fn = distributed_vertex_reduce(mesh, n=g.n)
    x = jnp.ones(g.n, jnp.float32)
    with use_mesh(mesh):
        compiled = jax.jit(fn).lower(gs, x).compile()
    coll = collective_bytes_from_hlo(compiled.as_text())
    out[name] = coll["total"]
print(json.dumps(out))
"""
    t0 = time.perf_counter()
    r = subprocess.run(
        [sys.executable, "-c", code],
        capture_output=True,
        text=True,
        env={**os.environ, "PYTHONPATH": "src"},
        cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
    )
    dt = time.perf_counter() - t0
    line = [l for l in r.stdout.splitlines() if l.startswith("{")]
    if not line:
        return [dict(name="fig_layout", us_per_call=dt * 1e6,
                     derived="FAILED: " + r.stderr[-200:])]
    data = json.loads(line[-1])
    return [
        dict(
            name=f"fig_layout_{k}",
            us_per_call=dt * 1e6 / max(len(data), 1),
            derived=f"collective_bytes_per_round={v}",
        )
        for k, v in data.items()
    ]


if __name__ == "__main__":
    for r in run():
        print(f"{r['name']},{r['us_per_call']:.0f},{r['derived']}")
