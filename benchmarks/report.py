"""Generate EXPERIMENTS.md from the dry-run / perf-variant records.

    PYTHONPATH=src python -m benchmarks.report > EXPERIMENTS.md
"""
from __future__ import annotations

import glob
import json
import os

from .roofline import HBM_BW, ICI_BW, PEAK_FLOPS, roofline_terms

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
BASE_DIR = os.path.join(ROOT, "results", "dryrun")
PERF_DIR = os.path.join(ROOT, "results", "perf_variants")


def _load(d):
    recs = []
    for p in sorted(glob.glob(os.path.join(d, "*.json"))):
        with open(p) as fh:
            recs.append(json.load(fh))
    return recs


def _fmt_row(r):
    t = roofline_terms(r)
    return (
        f"| {t['arch']} | {t['shape']} | {t['compute_s']:.3e} | {t['memory_s']:.3e} | "
        f"{t['collective_s']:.3e} | {t['dominant']} | {t['useful_flops_ratio']:.2f} | "
        f"{t['roofline_fraction']:.2f} | {t['peak_gb']:.2f} |"
    )


def _find(recs, arch, shape, mesh="single_pod_16x16"):
    for r in recs:
        if r["arch"] == arch and r["shape"] == shape and r["mesh"] == mesh:
            return r
    return None


def _terms_str(r):
    if r is None or not r.get("ok"):
        return "FAILED"
    t = roofline_terms(r)

    def f(x):
        return f"{x:.2f}" if x >= 0.01 else f"{x:.2e}"

    return (
        f"compute {f(t['compute_s'])}s / memory {f(t['memory_s'])}s / "
        f"collective {f(t['collective_s'])}s / peak {t['peak_gb']:.2f} GB"
    )


def main():
    base = _load(BASE_DIR)
    perf = _load(PERF_DIR) + [r for r in base if "+" in r["shape"]]
    ok = [r for r in base if r.get("ok") and "+" not in r["shape"]
          and r["arch"] != "sage-graph"]
    fail = [r for r in base if not r.get("ok")]
    per_mesh = {}
    for r in ok:
        per_mesh.setdefault(r["mesh"], []).append(r)

    out = []
    w = out.append
    w("# EXPERIMENTS — Sage (PSAM) on TPU: dry-run, roofline, perf\n")
    w("Companion to DESIGN.md.  All numbers regenerate with:\n")
    w("```\nPYTHONPATH=src python -m repro.launch.dryrun --mesh both")
    w("PYTHONPATH=src python -m repro.launch.dryrun --graph-engine --mesh both")
    w("PYTHONPATH=src python -m repro.launch.perf --variant all")
    w("PYTHONPATH=src python -m benchmarks.report > EXPERIMENTS.md\n```\n")

    # ------------------------------------------------------------------
    w("## §Dry-run\n")
    w("Every (architecture × input-shape) cell lowers **and compiles** with")
    w("`jax.jit(step).lower(specs).compile()` on both production meshes —")
    w("`(data=16, model=16)` = 256 chips and `(pod=2, data=16, model=16)` =")
    w("512 chips.  Train cells compile the full step (loss → grad → clip →")
    w("AdamW); serve cells compile prefill / KV-cache decode / catalog")
    w("scoring exactly as served.  `memory_analysis()` is per-device and")
    w("sharding-aware (calibrated against known shardings).\n")
    for mesh in sorted(per_mesh):
        rs = per_mesh[mesh]
        worst = max(rs, key=lambda r: r["memory"]["peak_bytes"] or 0)
        over = [r for r in rs if (r["memory"]["peak_bytes"] or 0) > 16e9]
        w(f"* **{mesh}**: {len(rs)}/40 cells compile OK; worst per-device peak "
          f"{(worst['memory']['peak_bytes'] or 0)/1e9:.2f} GB "
          f"({worst['arch']} × {worst['shape']}).")
        if over:
            w(f"  - {len(over)} cell(s) exceed the 16 GB HBM budget under the "
              f"paper-faithful baseline sharding: "
              f"{sorted(set((r['arch'], r['shape'])) for r in over) and [(r['arch'], r['shape']) for r in over]} "
              f"— fixed by the 2-axis cache sharding adopted in §Perf D2 "
              f"(peaks 1.8–5.8 GB with LM_DECODE_LONG_RULES_V2).")
    if fail:
        w(f"* FAILURES: {[(r['arch'], r['shape'], r['mesh']) for r in fail]}")
    w("")
    w("The Sage graph engine itself (edge-partitioned PageRank round and")
    w("frontier-min round over n=2²⁰ vertices / 2¹⁸ blocks of 128 slots)")
    w("also compiles on both meshes (`--graph-engine`): blocks shard over")
    w("every axis, the O(n) vertex vector is replicated and psum-combined —")
    w("cross-pod traffic is O(n) words per round, never O(m) (the paper's")
    w("§5.2 NUMA rule at pod scale).\n")
    w("FLOP accounting note: XLA `cost_analysis` counts while-loop bodies")
    w("once, so LM cells re-measure exact per-layer cost from UNROLLED 1-")
    w("vs 2-layer variants of the same cell and extrapolate")
    w("(F(Lmin) + (L−Lmin)·ΔF); collective bytes parsed from compiled HLO")
    w("are multiplied by the known scan trip count.\n")

    # ------------------------------------------------------------------
    w("## §Roofline (single-pod 16×16 baseline, all 40 cells)\n")
    w(f"Hardware model per chip: {PEAK_FLOPS/1e12:.0f} TFLOP/s bf16, "
      f"{HBM_BW/1e9:.0f} GB/s HBM, {ICI_BW/1e9:.0f} GB/s ICI link.")
    w("Terms are seconds per step per device; *dominant* = the bottleneck;")
    w("*useful* = MODEL_FLOPS (6·N·D / 6·N_active·D + attention) ÷ compiled")
    w("HLO FLOPs — <1 captures remat recompute and redundancy; *roofline")
    w("frac* = compute-term ÷ dominant-term (upper bound on achievable MFU")
    w("against the measured bottleneck).\n")
    w("| arch | shape | compute s | memory s | collective s | dominant | useful | frac | peak GB/dev |")
    w("|---|---|---|---|---|---|---|---|---|")
    for r in sorted(ok, key=lambda r: (r["arch"], r["shape"])):
        if r["mesh"] == "single_pod_16x16":
            w(_fmt_row(r))
    w("")
    ge = [r for r in base if r["arch"] == "sage-graph" and r.get("ok")
          and "baseline" in r["shape"]]
    if ge:
        w("Graph engine (per round, n=2²⁰):\n")
        w("| round | mesh | compute s | memory s | collective s | dominant |")
        w("|---|---|---|---|---|---|")
        for r in sorted(ge, key=lambda r: (r["shape"], r["mesh"])):
            t = roofline_terms(r)
            w(f"| {r['shape']} | {r['mesh']} | {t['compute_s']:.2e} | "
              f"{t['memory_s']:.2e} | {t['collective_s']:.2e} | {t['dominant']} |")
        w("")
    w("Reading the table: LM train/prefill cells are **memory-term-bound**")
    w("under XLA's per-op byte accounting (score tensors + remat recompute")
    w("traffic); on a real TPU much of that fuses, so the compute term is")
    w("the achievable bound — which is why §Perf attacks bytes first.  GNN")
    w("full-graph cells and bulk recsys scoring are **collective-bound**")
    w("(node-feature gathers across edge shards / score resharding).  Decode")
    w("cells are cache-bandwidth-bound, as expected at batch≤128.\n")

    # ------------------------------------------------------------------
    w("## §Perf — hillclimb log (hypothesis → change → before → after)\n")
    w("Three pairs: **mistral-large-123b × train_4k** (largest dominant term")
    w("among trains, flagship), **equiformer-v2 × ogb_products** (most")
    w("collective-bound), **sage-graph engine** (the paper's own technique).")
    w("Plus one runnability fix (long_500k exceeded HBM).  The paper-faithful")
    w("BASELINE rows above are never edited; variants are separate records.\n")

    def pair(title, rows):
        w(f"### {title}\n")
        for hypo, rec_before, rec_after, verdict in rows:
            w(f"* **{hypo}**")
            w(f"  - before: {_terms_str(rec_before)}")
            w(f"  - after:  {_terms_str(rec_after)}")
            w(f"  - **{verdict}**")
        w("")

    allrecs = base + perf
    mt = _find(allrecs, "mistral-large-123b", "train_4k")
    pair("A. mistral-large-123b × train_4k (memory-dominated)", [
        ("A1 sequence-parallel residual (res_seq→model): hypothesis — saved "
         "per-layer activations shard 16×, memory term ↓",
         mt, _find(allrecs, "mistral-large-123b", "train_4k+sp"),
         "REFUTED: memory 89.7→176.0 s — the per-block all-gather/reduce-"
         "scatter pairs around attention/FFN cost more bytes than the "
         "sharded saves recover at this batch; collective ×3.4. Lesson: SP "
         "pays off only when activation memory, not byte traffic, binds."),
        ("A2 remat policy 'dots' (save matmul outputs): hypothesis — "
         "backward recompute flops ↓ at small memory cost",
         mt, _find(allrecs, "mistral-large-123b", "train_4k+dots"),
         "NO CHANGE on measured terms (XLA DCEs the difference in the "
         "costing graphs); kept as a runtime knob."),
        ("A3 flash-style causal block skipping (+cbs): hypothesis — visiting "
         "only visible kv blocks cuts attention einsum flops and score "
         "traffic ~½ (at 4k/1024 blocks: 10/16 visible)",
         mt, _find(allrecs, "mistral-large-123b", "train_4k+cbs"),
         "CONFIRMED: memory 89.7→81.7 s (−9.0%), compute 20.68→20.12 s, "
         "useful-flops 0.75→0.78. Attention is ~5% of flops at 4k; gain "
         "scales with context (see prefill below)."),
        ("A4 MXU-native attention einsums (+mp): bf16 operands with fp32 "
         "accumulation instead of f32×f32 dots",
         mt, _find(allrecs, "mistral-large-123b", "train_4k+mp"),
         "Logical terms unchanged (expected — same flops); the win is "
         "machine peak: f32 dots run at ~¼ bf16 MXU rate on TPU, so the "
         "attention share of step time drops ~4× on hardware. Adopted "
         "together with +cbs as the optimized configuration."),
        ("A-extra prefill_32k with +cbs_mp: same levers where attention is "
         "a large flops fraction (~36% at 32k); napkin: visiting 51.5% of "
         "kv blocks saves ~17% of total compute",
         _find(allrecs, "mistral-large-123b", "prefill_32k"),
         _find(allrecs, "mistral-large-123b", "prefill_32k+cbs_mp"),
         "CONFIRMED, napkin-exact: compute 8.06→6.67 s (−17.2%), memory "
         "68.2→61.3 s (−10%). (Also fixed en route: the skip guard "
         "wrongly disabled itself on the cached prefill path.)"),
    ])

    eq = _find(allrecs, "equiformer-v2", "ogb_products")
    pair("B. equiformer-v2 × ogb_products (collective-dominated)", [
        ("B1 channel-TP (+tp): hypothesis — shard hidden dim over 'model' "
         "instead of 512-way edge sharding; node-aggregation all-reduce ÷16",
         eq, _find(allrecs, "equiformer-v2", "ogb_products+tp"),
         "REFUTED: collective 59.6→80.7 s. The (N,49,d) spherical stacks "
         "now reshard between node- and edge-layout every layer; the edge "
         "tensors got 16× bigger per device. Lesson: the gather of node "
         "features TO edge shards, not the scatter back, dominates."),
        ("B2 eSCN-compact messages (+compact): hypothesis — only the "
         "|m|≤m_max coefficients (29/49) participate in messages (the eSCN "
         "truncation applied to communication); predicted collective ×0.59",
         eq, _find(allrecs, "equiformer-v2", "ogb_products+compact"),
         "CONFIRMED, napkin math exact: collective 59.6→35.6 s (×0.60 "
         "predicted 0.59), memory 21.4→14.2 s (−33%). Also a fidelity "
         "improvement — high-m coefficients evolve node-locally as in "
         "eSCN proper."),
    ])

    pair("C. sage-graph engine (the paper's technique, collective-bound)", [
        ("C1 hierarchical reduction (+hier): hypothesis — reduce-scatter on "
         "'model', psum the 1/16 shard across 'data'/'pod', all-gather back; "
         "slow-axis bytes ÷16",
         _find(allrecs, "sage-graph", "pagerank_round_baseline"),
         _find(allrecs, "sage-graph", "pagerank_round_hier"),
         "CONFIRMED: collective bytes 8.39→4.72 MB/round single-pod (−44%) "
         "and 12.6→4.98 MB multi-pod (−60%); the all-reduce component "
         "(the latency-critical slow-axis part) drops 32×."),
        ("C2 bf16 vertex state on the wire (+bf16): hypothesis — halve "
         "collective bytes like gradient compression",
         _find(allrecs, "sage-graph", "pagerank_round_flat_bf16"),
         _find(allrecs, "sage-graph", "pagerank_round_hier_bf16"),
         "REFUTED on this backend: XLA:CPU upcasts to f32 before the "
         "collective, wire bytes unchanged. On TPU bf16 all-reduce is "
         "native; the int8 path in optim/compression.py (tested on 4 fake "
         "devices) is the production fallback."),
    ])

    pair("D. runnability fix — long_500k exceeded HBM", [
        ("D1 pin out_shardings everywhere: hypothesis — XLA propagation "
         "replicates large outputs when unspecified (before/after: n/a — "
         "peak unchanged at 26.87 GB)",
         _find(allrecs, "qwen1.5-4b", "long_500k"),
         _find(allrecs, "qwen1.5-4b", "long_500k"),
         "PARTIAL: pinning is now standard in launch/steps.py (defense in "
         "depth) but was not the root cause — the cache itself is 215 GB "
         "global and was only 16-way sharded."),
        ("D2 2-axis cache sharding: the qwen1.5-4b 500k MHA cache is 215 GB "
         "global; 16-way seq sharding leaves 13.4 GB/device. Shard "
         "cache_seq→data AND head_dim→model (256-way)",
         _find(allrecs, "qwen1.5-4b", "long_500k"),
         _find(allrecs, "qwen1.5-4b", "long_500k+v2"),
         "CONFIRMED: peak 26.9→1.8 GB/device (qwen1.5-4b), 24.6→5.8 GB "
         "(mistral-large). Every long_500k cell now fits the 16 GB budget."),
    ])

    w("### Stopping note\n")
    w("Pair A: A1/A2 gave <5% (one refuted), A3+A4 adopted; further context-")
    w("length-independent levers (fused flash kernel in Pallas, fp8) are")
    w("listed in DESIGN.md as future work.  Pair B: B1 refuted, B2 adopted;")
    w("a third idea (bf16 message aggregation) mirrors C2's backend caveat.")
    w("Pair C: C1 adopted, C2 refuted-on-backend.  Baseline (paper-faithful)")
    w("and optimized configurations are both recorded above, separately.\n")

    # variant table
    w("### All variant records\n")
    w("| arch | variant | mesh | compute s | memory s | collective s | peak GB |")
    w("|---|---|---|---|---|---|---|")
    for r in sorted(perf, key=lambda r: (r["arch"], r["shape"], r["mesh"])):
        if not r.get("ok"):
            continue
        t = roofline_terms(r)
        w(f"| {r['arch']} | {r['shape']} | {r['mesh']} | {t['compute_s']:.3e} | "
          f"{t['memory_s']:.3e} | {t['collective_s']:.3e} | {t['peak_gb']:.2f} |")
    w("")
    print("\n".join(out))


if __name__ == "__main__":
    main()
