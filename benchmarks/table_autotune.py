"""Autotuner validation: ``strategy="auto"`` vs every fixed strategy.

The tentpole claim of the measured-cost autotuner: a plan built from a
calibrated :class:`~repro.tuning.TuningTable` picks, at every frontier
density, a strategy whose measured round time is within 1.1x of the best
fixed choice — and at the density extremes, the *worst* fixed choice is
at least 1.5x slower than auto.  Both are asserted in-bench, so a tuning
regression turns the rows into ERROR lines and ``check_regression`` fails
the nightly gate.

The asserted sweep runs the BATCHED (B=8) edgeMap round — the serving
path — because that is where strategy choice has real spread on every
host: fixed sparse vmaps B chunk loops (catastrophic at full density,
where the shared dense sweep serves all lanes at once), fixed dense scans
every block for a near-empty frontier, and on streaming backends the
batched streamed union beats vmapped plain sparse at low density (the
``auto_sparse_batched`` knob).  Single-query replays of BFS / wBFS /
PageRank ride along as unasserted rows: auto vs each fixed strategy, end
to end.

Each run quick-calibrates a fresh table on the bench workload itself
(same R-MAT generator / size as ``calibrate``'s default), so the
crossover the auto plan uses was measured minutes earlier on this very
host — the whole point of replacing the hand-picked ``dense_frac = 20``.

``--smoke`` is the CI leg: tiny graph, shipped default table, one batched
auto round per backend verified bit-identical to the strategy the plan
selected, print OK.
"""
from __future__ import annotations

import time

import numpy as np

_AUTO_TOL = 1.10   # auto <= 1.10x the best fixed strategy, every point
_WORST_MIN = 1.5   # worst fixed >= 1.5x auto at the density extremes
_FRACS = (0.002, 0.05, 1.0)  # frontier vertex fractions: lonely -> saturated


def _time_us(fn, *args, reps=3):
    import jax

    out = fn(*args)
    jax.block_until_ready(out)  # compile + warmup excluded
    best = float("inf")
    for _ in range(reps):
        t0 = time.perf_counter()
        out = fn(*args)
        jax.block_until_ready(out)
        best = min(best, time.perf_counter() - t0)
    return best * 1e6


def _frontier(n, frac, seed):
    k = max(1, min(n, int(round(frac * n))))
    rng = np.random.default_rng(seed)
    mask = np.zeros(n, dtype=bool)
    mask[rng.choice(n, size=k, replace=False)] = True
    return mask


def _batched_round_legs(g, plan, frac, *, b=8, seed=0, reps=3):
    """us per batched B=8 round: auto (plan) + each fixed strategy."""
    import jax
    import jax.numpy as jnp

    from repro.core import edgemap_reduce_batched
    from repro.core.edgemap import _streaming_decoder

    masks = jnp.asarray(
        np.stack([_frontier(g.n, frac, seed + i) for i in range(b)])
    )
    xb = jnp.broadcast_to(jnp.arange(g.n, dtype=jnp.float32)[None, :], (b, g.n))
    fixed = ["dense", "sparse"]
    if _streaming_decoder(g, None) is not None:
        fixed.append("sparse_streamed")
    legs = {}
    for mode in fixed:
        fn = jax.jit(
            lambda masks, xb, mode=mode: edgemap_reduce_batched(
                g, masks, xb, monoid="min", mode=mode,
                chunk_blocks=plan.chunk_blocks,
            )
        )
        legs[mode] = _time_us(fn, masks, xb, reps=reps)
    fn = jax.jit(
        lambda masks, xb: edgemap_reduce_batched(
            g, masks, xb, monoid="min", mode="auto", plan=plan
        )
    )
    legs["auto"] = _time_us(fn, masks, xb, reps=reps)
    return legs


def _density_rows(label, g, plan, *, reps=3):
    rows = []
    extremes = (_FRACS[0], _FRACS[-1])
    for frac in _FRACS:
        legs = _batched_round_legs(g, plan, frac, reps=reps)
        auto = legs["auto"]
        fixed = {m: us for m, us in legs.items() if m != "auto"}
        best_mode = min(fixed, key=fixed.get)
        worst_mode = max(fixed, key=fixed.get)
        auto_vs_best = auto / fixed[best_mode]
        worst_vs_auto = fixed[worst_mode] / auto
        assert auto_vs_best <= _AUTO_TOL, (
            f"{label} frac={frac}: auto {auto:.0f}us is "
            f"{auto_vs_best:.2f}x best fixed ({best_mode} "
            f"{fixed[best_mode]:.0f}us) > {_AUTO_TOL}x"
        )
        if frac in extremes:
            assert worst_vs_auto >= _WORST_MIN, (
                f"{label} frac={frac}: worst fixed ({worst_mode} "
                f"{fixed[worst_mode]:.0f}us) only {worst_vs_auto:.2f}x "
                f"auto {auto:.0f}us < {_WORST_MIN}x"
            )
        rows.append(
            dict(
                name=f"table_autotune_{label}_d{frac}",
                us_per_call=auto,
                derived=(
                    f"B=8 auto/best={auto_vs_best:.2f}x (best={best_mode}) "
                    f"worst/auto={worst_vs_auto:.2f}x (worst={worst_mode})"
                ),
            )
        )
    return rows


def _replay_rows(label, g, plan, *, reps=2):
    """BFS / wBFS / PageRank end to end, auto vs each fixed strategy."""
    import dataclasses

    import jax

    from repro.algorithms import bfs, pagerank, wbfs

    rows = []
    for name, call in [
        ("bfs", lambda p: jax.jit(lambda: bfs(g, 1, plan=p))),
        ("wbfs", lambda p: jax.jit(lambda: wbfs(g, 1, plan=p))),
        ("pagerank", lambda p: jax.jit(lambda: pagerank(g, max_iters=20, plan=p))),
    ]:
        times = {}
        for strat in ("auto", "dense", "sparse"):
            p = plan if strat == "auto" else dataclasses.replace(
                plan, strategy=strat
            )
            times[strat] = _time_us(call(p), reps=reps)
        rows.append(
            dict(
                name=f"table_autotune_{label}_{name}_auto",
                us_per_call=times["auto"],
                derived=(
                    f"dense={times['dense']:.0f}us sparse={times['sparse']:.0f}us "
                    f"auto/best={times['auto'] / min(times.values()):.2f}x"
                ),
            )
        )
    return rows


def run(n=2048, m=16384, *, reps=3):
    from repro.core import compress, make_plan
    from repro.data import rmat_graph
    from repro.tuning import calibrate

    # calibrate on this workload, on this host, right now — the table the
    # auto legs run under is minutes-old measurement, not a shipped guess
    table = calibrate(n=n, m=m, quick=True, seed=0, reps=reps)
    g = rmat_graph(n, m, seed=0, block_size=128)
    c = compress(g)

    rows = []
    for label, backend in [("csr", g), ("compressed", c)]:
        plan = make_plan(backend, tuning=table)
        d = plan.decisions
        rows.append(
            dict(
                name=f"table_autotune_{label}_decision",
                us_per_call=0,
                derived=(
                    f"source={d.source} d*={d.crossover_density:.3f} "
                    f"dense_frac={d.dense_frac:.2f} chunk={d.chunk_blocks} "
                    f"sparse={d.auto_sparse}/{d.auto_sparse_batched} "
                    f"max_batch={d.max_batch}"
                ),
            )
        )
        rows.extend(_density_rows(label, backend, plan, reps=reps))
        rows.extend(_replay_rows(label, backend, plan))
    return rows


def smoke():
    """Tiny-graph CI leg: auto == the strategy the plan selected, bit-exact."""
    import jax.numpy as jnp

    from repro.core import compress, edgemap_reduce, make_plan
    from repro.data import rmat_graph

    g = rmat_graph(256, 1024, seed=3, block_size=32)
    for label, backend in [("csr", g), ("compressed", compress(g))]:
        plan = make_plan(backend)  # shipped default table (or constants)
        mask = jnp.asarray(_frontier(backend.n, 1.0, 0))
        x0 = jnp.arange(backend.n, dtype=jnp.float32)
        # full frontier: auto's Beamer predicate picks dense for any sane
        # dense_frac — compare bit for bit against the explicit strategy
        auto_out, auto_t = edgemap_reduce(
            backend, mask, x0, monoid="min", plan=plan
        )
        dense_out, dense_t = edgemap_reduce(
            backend, mask, x0, monoid="min", mode="dense"
        )
        assert bool(jnp.all(auto_out == dense_out))
        assert bool(jnp.all(auto_t == dense_t))
        d = plan.decisions
        print(
            f"autotune smoke OK [{label}]: source={d.source} "
            f"dense_frac={d.dense_frac:.2f} auto==dense bit-exact"
        )


if __name__ == "__main__":
    import sys

    if "--smoke" in sys.argv:
        smoke()
    else:
        for r in run():
            print(f"{r['name']},{r['us_per_call']:.0f},{r['derived']}")
