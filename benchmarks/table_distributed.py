"""Distributed planner benchmark: PageRank over sharded edges, raw vs
compressed (§5.2 at pod scale).

One PageRank round through the unified planner on a 4-way (fake CPU) mesh,
for both execution backends.  Reports per-shard edge throughput, the
compressed/raw wall-time ratio, and the PSAM per-shard read model
(``charge_edgemap_planned``) — the honest bytes-off-large-memory contrast
for the distributed path.  ``--full`` runs RMAT-20 (n = 2²⁰).

Runs in a subprocess so the fake-device XLA flag doesn't leak into the
parent process.
"""
from __future__ import annotations

import json
import os
import subprocess
import sys
import time

CODE = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import json, sys, time
import jax, jax.numpy as jnp
from repro.compat import make_mesh, use_mesh
from repro.core import PSAMCost, compress, make_plan
from repro.data import rmat_graph
from repro.distributed.engine import distributed_pagerank_step, prepare_sharded

n, m = int(sys.argv[1]), int(sys.argv[2])
mesh = make_mesh((4,), ("data",))
S = int(mesh.devices.size)
g = rmat_graph(n, m, seed=20, block_size=32)
c = compress(g)
pr = jnp.full(g.n, 1.0 / g.n)
inv = jnp.where(g.degrees > 0, 1.0 / jnp.maximum(g.degrees, 1).astype(jnp.float32), 0.0)
step = distributed_pagerank_step(mesh, n=g.n)

out = {"n": g.n, "m": g.m, "shards": S, "ratio": c.compression_ratio}
with use_mesh(mesh):
    for label, backend in [("raw", g), ("compressed", c)]:
        gs = prepare_sharded(mesh, backend)
        fn = jax.jit(step)
        fn(gs, pr, inv).block_until_ready()  # compile + warmup
        reps = 3
        t0 = time.perf_counter()
        for _ in range(reps):
            fn(gs, pr, inv).block_until_ready()
        us = (time.perf_counter() - t0) * 1e6 / reps
        cost = PSAMCost()
        cost.charge_edgemap_planned(backend, num_shards=S)
        out[label] = {
            "us": us,
            "edges_per_s_per_shard": g.m / (us * 1e-6) / S,
            "psam_read_words": cost.large_reads,
        }
print(json.dumps(out))
"""


def run(n=4096, m=16384):
    t0 = time.perf_counter()
    r = subprocess.run(
        [sys.executable, "-c", CODE, str(n), str(m)],
        capture_output=True,
        text=True,
        env={**os.environ, "PYTHONPATH": "src"},
        cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
    )
    dt = time.perf_counter() - t0
    lines = [ln for ln in r.stdout.splitlines() if ln.startswith("{")]
    if not lines:
        return [dict(name="table_distributed", us_per_call=dt * 1e6,
                     derived="FAILED: " + r.stderr[-200:])]
    d = json.loads(lines[-1])
    rows = []
    for label in ["raw", "compressed"]:
        rows.append(
            dict(
                name=f"table_distributed_pagerank_{label}",
                us_per_call=d[label]["us"],
                derived=(
                    f"edges_per_s_per_shard={d[label]['edges_per_s_per_shard']:.0f} "
                    f"psam_read_words={d[label]['psam_read_words']} "
                    f"shards={d['shards']} n={d['n']} m={d['m']}"
                ),
            )
        )
    rows.append(
        dict(
            name="table_distributed_compressed_vs_raw",
            us_per_call=0,
            derived=(
                f"us_ratio={d['compressed']['us'] / max(d['raw']['us'], 1e-9):.2f} "
                f"psam_read_saving="
                f"{d['raw']['psam_read_words'] / max(d['compressed']['psam_read_words'], 1):.2f}x "
                f"compression_ratio={d['ratio']:.2f}x"
            ),
        )
    )
    return rows


if __name__ == "__main__":
    for r in run():
        print(f"{r['name']},{r['us_per_call']:.0f},{r['derived']}")
