"""Paper Figure 7: Sage-DRAM vs Sage-NVRAM vs GBBS-NVRAM(libvmmalloc),
as a PSAM cost model sweep.

The paper's headline: Sage on NVRAM is only ~1.05× slower than Sage on DRAM,
while GBBS naively on NVRAM (libvmmalloc) is 6.69× slower.  The PSAM cost
model with the paper's ratios (NVRAM read = 3× DRAM read, write = 12×)
reproduces the ORDERING and gives a LOWER BOUND on the gaps: pure
access-count modeling cannot capture that (a) Sage's NVRAM reads overlap
compute (hence the paper's 1.05×, vs our bandwidth-only 2.9×) and (b) real
NVRAM writes also stall concurrent reads and trigger wear-leveling (hence
the paper's 6.69×, vs our write-cost-only bound).  The qualitative claim —
zero-large-memory-writes beats write-heavy ports, growing with ω — is what
the model verifies.
"""
from __future__ import annotations

from repro.core import PSAMCost
from repro.data import rmat_graph

NVRAM_READ = 3.0    # vs DRAM read = 1 (paper §1: combined read throughput)
NVRAM_WRITE = 12.0  # paper §1: writes 4x slower than NVRAM reads


def run(n=4096, m=32768, rounds=10):
    g = rmat_graph(n, m, seed=0, block_size=64)
    cost = PSAMCost()
    for _ in range(rounds):
        cost.charge_edgemap_dense(g)
        cost.charge_filter_pack(g, g.num_blocks)

    large_reads, small = cost.large_reads, cost.small_ops
    mutated = rounds * g.m  # GBBS packs edges in place each round

    sage_dram = large_reads * 1.0 + small * 1.0
    sage_nvram = large_reads * NVRAM_READ + small * 1.0
    gbbs_nvram = large_reads * NVRAM_READ + small * 1.0 + mutated * NVRAM_WRITE

    rows = []
    for name, t in [
        ("sage_dram", sage_dram),
        ("sage_nvram", sage_nvram),
        ("gbbs_nvram_libvmmalloc", gbbs_nvram),
    ]:
        rows.append(
            dict(
                name=f"fig7_{name}",
                us_per_call=t / 1e3,  # model units
                derived=(
                    f"relative={t / sage_dram:.2f}x (cost-model lower bound; "
                    f"see module docstring vs paper's measured gaps)"
                ),
            )
        )
    rows.append(
        dict(
            name="fig7_gbbs_over_sage_nvram",
            us_per_call=0,
            derived=(
                f"ratio={gbbs_nvram / sage_nvram:.2f}x lower bound "
                f"(paper measures 6.69x: writes also stall reads)"
            ),
        )
    )
    return rows


if __name__ == "__main__":
    for r in run():
        print(f"{r['name']},{r['us_per_call']:.0f},{r['derived']}")
