"""Streaming edits: delta-overlay amortization vs recompress-per-edit.

The mutable-graph acceptance table (ISSUE 10).  A strawman mutable graph
under the PSAM re-encodes the whole compressed edge array on EVERY edit —
``ω × compact_write_words`` NVRAM words per edit.  The delta overlay
(``repro.delta``) batches edits in DRAM and pays the ω write ONCE per
compaction, so the per-edit write cost divides by the batch while queries
between compactions pay only the overlay's small-op surcharge.

Rows (replaying an edit-plus-query trace through the ServingService):

* ``query_us_base`` / ``query_us_overlay`` — per-BFS latency over the
  clean base vs over an overlay carrying the full edit batch (the DRAM
  patch-gather rent queries pay between compactions).
* ``edit_us`` — amortized wall time per edit through ``submit_edit`` +
  tick-boundary apply, including every snapshot rebuild.
* ``compact_us`` — wall time of one ``compact()`` fold (build + compress
  + ω charge).
* ``amortization`` — the acceptance row, in PSAM words (the model, not
  the clock): amortized per-edit cost of the delta path (one compaction
  + the batch's query surcharge, split over E edits) vs recompress-per-
  edit.  **In-bench asserted ≥ 10× cheaper at E = 1000.**

``--smoke`` replays a tiny edit trace through the service, forces a
compaction, and verifies one post-compaction query bit-exactly against a
from-scratch rebuild.
"""
from __future__ import annotations

import time

import numpy as np


def _edit_stream(rng, n: int, count: int):
    """("insert"|"delete", u, v) tuples — 3:1 inserts to deletes."""
    out = []
    for i in range(count):
        u, v = int(rng.integers(0, n)), int(rng.integers(0, n))
        out.append(("delete" if i % 4 == 3 else "insert", u, v))
    return out


def _time_us(fn, reps: int = 3) -> float:
    import jax

    jax.block_until_ready(fn())  # warmup: compile excluded
    best = float("inf")
    for _ in range(reps):
        t0 = time.perf_counter()
        jax.block_until_ready(fn())
        best = min(best, time.perf_counter() - t0)
    return best * 1e6


def run(n=1024, m=8192, edits=1000, queries=32):
    from repro.algorithms import bfs
    from repro.data import rmat_graph
    from repro.delta import DeltaOverlay, compact, compact_write_words
    from repro.obs import noop_registry
    from repro.serving import ServiceConfig, ServingService

    g = rmat_graph(n, m, seed=9, block_size=32)
    rows = []
    rng = np.random.default_rng(17)
    stream = _edit_stream(rng, n, edits)

    # --- query latency: clean base vs loaded overlay --------------------
    base_us = _time_us(lambda: bfs(g, 0, mode="dense"))
    svc = ServingService(
        DeltaOverlay(g),
        config=ServiceConfig(compact_trigger=None),  # hold the overlay open
        registry=noop_registry(),
    )
    svc.compact_trigger = None  # never fold: measure the loaded-overlay rent
    for kind, u, v in stream:
        svc.submit_edit(kind, u, v, now=0.0)
    t0 = time.perf_counter()
    svc.tick(0.0)  # applies the whole batch + snapshots
    apply_s = time.perf_counter() - t0
    dg = svc.engine.graph
    over_us = _time_us(lambda: bfs(dg, 0, mode="dense"))
    rows.append(
        dict(
            name="table_streaming_query_us_base",
            us_per_call=base_us,
            derived=f"dense bfs, clean base n={n} m={m}",
        )
    )
    rows.append(
        dict(
            name="table_streaming_query_us_overlay",
            us_per_call=over_us,
            derived=(
                f"dense bfs over base+{edits}-edit overlay "
                f"ratio={over_us / max(base_us, 1e-9):.2f} "
                f"patch_edges={svc.overlay.num_patch_edges} "
                f"tombstones={svc.overlay.num_tombstones}"
            ),
        )
    )
    rows.append(
        dict(
            name="table_streaming_edit_us",
            us_per_call=apply_s / edits * 1e6,
            derived=f"amortized apply+snapshot per edit, batch={edits}",
        )
    )

    # --- compaction wall time ------------------------------------------
    t0 = time.perf_counter()
    c = compact(svc.overlay)
    compact_us = (time.perf_counter() - t0) * 1e6
    w = compact_write_words(c)
    rows.append(
        dict(
            name="table_streaming_compact_us",
            us_per_call=compact_us,
            derived=f"fold {edits} edits -> fresh CompressedCSR, write_words={w}",
        )
    )

    # --- the acceptance row: PSAM words, delta vs recompress-per-edit ---
    omega = 4.0
    surcharge = float(dg.overlay_small_words) * queries
    delta_per_edit = (omega * w + surcharge) / edits
    recompress_per_edit = omega * w  # strawman: full ω write EVERY edit
    ratio = recompress_per_edit / delta_per_edit
    assert ratio >= 10.0, (
        f"amortization bar failed: {ratio:.1f}x < 10x at batch={edits}"
    )
    rows.append(
        dict(
            name="table_streaming_amortization",
            us_per_call=delta_per_edit,
            derived=(
                f"PSAM words/edit: delta={delta_per_edit:.1f} "
                f"recompress={recompress_per_edit:.1f} ratio={ratio:.1f}x "
                f"(edits={edits} queries={queries} omega={omega:.0f} "
                f"asserted >=10x)"
            ),
        )
    )
    return rows


def smoke():
    """Tiny edit-trace replay (CI): edits + queries through the service,
    forced compaction, one post-compaction query bit-exact vs rebuild."""
    import jax.numpy as jnp

    from repro.algorithms import bfs
    from repro.core import build_csr, compress
    from repro.data import rmat_graph
    from repro.delta import DeltaOverlay
    from repro.serving import ServiceConfig, ServingService

    n = 256
    g = compress(rmat_graph(n, 1024, seed=12, block_size=32))
    svc = ServingService(
        DeltaOverlay(g), config=ServiceConfig(slo=0.01, max_batch=8)
    )
    # reference edge dict replays the same stream independently
    src, dst, valid = (np.asarray(g.edge_src), np.asarray(g.edge_dst),
                       np.asarray(g.edge_valid))
    edges = {(int(u), int(v)): 1.0 for u, v in zip(src[valid], dst[valid])}
    stream = _edit_stream(np.random.default_rng(23), n, 40)
    admitted = 0
    for i, (kind, u, v) in enumerate(stream):
        admitted += bool(svc.submit_edit(kind, u, v, now=i * 1e-4))
        if kind == "insert" and u != v:
            edges[(u, v)] = 1.0
        else:
            edges.pop((u, v), None)
        if i % 10 == 9:  # interleave queries with the edit stream
            svc.submit("bfs", src=0, now=i * 1e-4)
            svc.drain(i * 1e-4)
    assert admitted == len(stream), "unbudgeted edits must all admit"
    svc.force_compact(1.0)
    assert svc.stats["compactions"] >= 1, "no compaction ran"
    assert svc.overlay.num_patch_edges == 0 and svc.overlay.num_tombstones == 0
    t = svc.submit("bfs", src=0, now=2.0)
    svc.drain(2.0)
    items = sorted(edges)
    rb = compress(build_csr(
        n, np.array([u for u, _ in items], np.int32),
        np.array([v for _, v in items], np.int32),
        block_size=32, symmetrize=False,
    ))
    want_p, want_l = bfs(rb, 0)
    assert bool(jnp.all(t.result[0] == want_p)), "post-compaction parents differ"
    assert bool(jnp.all(t.result[1] == want_l)), "post-compaction levels differ"
    print(
        f"streaming smoke OK: {len(stream)} edits, "
        f"{svc.stats['compactions']} compaction(s), "
        f"post-compaction query bit-exact"
    )


if __name__ == "__main__":
    import sys

    if "--smoke" in sys.argv:
        smoke()
    else:
        for r in run():
            print(f"{r['name']},{r['us_per_call']:.0f},{r['derived']}")
