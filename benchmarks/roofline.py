"""§Roofline: three-term roofline per (arch × shape × mesh) from the
dry-run's compiled artifacts.

  compute term    = per-device HLO FLOPs / peak FLOP/s
  memory term     = per-device HLO bytes / HBM bandwidth
  collective term = per-device collective bytes / ICI link bandwidth

Hardware model (TPU v5e-class, per chip): 197 TFLOP/s bf16, 819 GB/s HBM,
~50 GB/s/link ICI (one effective link per collective hop — conservative).
The numbers come from the tuning table's ``hardware`` section
(``repro.tuning.hardware_model``) — the SAME description the calibration
pass records — so roofline terms and measured-cost autotuning can never
drift onto two divergent hardware models.

Also reported: MODEL_FLOPS / HLO_FLOPs ("useful fraction" — catches remat
and redundancy waste) and the dominant bottleneck term.
"""
from __future__ import annotations

import glob
import json
import os

from repro.tuning import hardware_model

_HW = hardware_model()
PEAK_FLOPS = _HW["peak_flops"]
HBM_BW = _HW["hbm_bw"]
ICI_BW = _HW["ici_bw"]

RESULTS_DIR = os.path.join(
    os.path.dirname(os.path.dirname(os.path.abspath(__file__))), "results", "dryrun"
)


def load_records(results_dir=RESULTS_DIR):
    recs = []
    for path in sorted(glob.glob(os.path.join(results_dir, "*.json"))):
        with open(path) as fh:
            recs.append(json.load(fh))
    return recs


def roofline_terms(rec):
    if not rec.get("ok"):
        return None
    nd = rec["n_devices"]
    compute_s = rec["flops_per_device"] / PEAK_FLOPS
    memory_s = rec["bytes_per_device"] / HBM_BW
    coll_s = rec["collective_bytes"]["total"] / ICI_BW
    dom = max(
        [("compute", compute_s), ("memory", memory_s), ("collective", coll_s)],
        key=lambda kv: kv[1],
    )[0]
    total_flops = rec["flops_per_device"] * nd
    useful = rec["model_flops"] / total_flops if total_flops > 0 else 0.0
    # roofline fraction: compute time / critical-path bound (max of terms)
    bound = max(compute_s, memory_s, coll_s, 1e-30)
    frac = compute_s / bound
    return dict(
        arch=rec["arch"],
        shape=rec["shape"],
        mesh=rec["mesh"],
        kind=rec["kind"],
        compute_s=compute_s,
        memory_s=memory_s,
        collective_s=coll_s,
        dominant=dom,
        useful_flops_ratio=useful,
        roofline_fraction=frac,
        peak_gb=(rec["memory"]["peak_bytes"] or 0) / 1e9,
    )


def table(records=None, mesh_filter="single_pod_16x16"):
    records = records or load_records()
    rows = []
    for rec in records:
        if mesh_filter and rec.get("mesh") != mesh_filter:
            continue
        t = roofline_terms(rec)
        if t:
            rows.append(t)
    rows.sort(key=lambda r: (r["arch"], r["shape"]))
    return rows


def markdown(rows):
    hdr = (
        "| arch | shape | compute s | memory s | collective s | dominant | "
        "useful flops | roofline frac | peak GB/dev |\n"
        "|---|---|---|---|---|---|---|---|---|"
    )
    lines = [hdr]
    for r in rows:
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['compute_s']:.3e} | "
            f"{r['memory_s']:.3e} | {r['collective_s']:.3e} | {r['dominant']} | "
            f"{r['useful_flops_ratio']:.2f} | {r['roofline_fraction']:.2f} | "
            f"{r['peak_gb']:.2f} |"
        )
    return "\n".join(lines)


def run():
    rows = table()
    out = []
    for r in rows:
        out.append(
            dict(
                name=f"roofline_{r['arch']}_{r['shape']}",
                us_per_call=r["compute_s"] * 1e6,
                derived=(
                    f"dominant={r['dominant']} frac={r['roofline_fraction']:.2f} "
                    f"useful={r['useful_flops_ratio']:.2f}"
                ),
            )
        )
    return out


if __name__ == "__main__":
    print(markdown(table()))
