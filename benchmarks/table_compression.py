"""Compressed-graph backend: compression ratio + edgeMap throughput (§5.1.3).

Reports, for an RMAT graph:
  * the fixed-width delta-packing compression ratio (paper: 2.7–2.9× with
    byte codes on web graphs; ~2× is the fixed-width ceiling),
  * compressed-vs-uncompressed edgeMap wall time in the dense and sparse
    (chunked) modes — the decode rides inside the fused jit graph,
  * the fused decode+SpMV Pallas kernel against the uncompressed SpMV
    kernel on identical work,
  * the PSAM large-memory read model for both backends (the paper's
    bytes-off-NVRAM contrast).
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import PSAMCost, compress, edgemap_reduce, from_indices, make_filter
from repro.data import rmat_graph
from repro.kernels import compressed_spmv_vertex, spmv_vertex


def _time_us(fn, *args) -> float:
    def first_leaf(r):
        return jax.tree.leaves(r)[0]

    first_leaf(fn(*args)).block_until_ready()  # warmup / compile
    t0 = time.perf_counter()
    first_leaf(fn(*args)).block_until_ready()
    return (time.perf_counter() - t0) * 1e6


def run(n=1024, m=8192, block_size=64):
    g = rmat_graph(n, m, seed=11, block_size=block_size)
    c = compress(g)
    rows = [
        dict(
            name="table_compression_ratio",
            us_per_call=0,
            derived=(
                f"ratio={c.compression_ratio:.2f}x "
                f"compressed_bytes={c.compressed_bytes} "
                f"uncompressed_bytes={c.uncompressed_bytes} "
                f"exceptions={c.n_exceptions} n={c.n} m={c.m}"
            ),
        )
    ]

    x = jnp.arange(g.n, dtype=jnp.int32)
    full = jnp.ones(g.n, dtype=bool)
    sparse_fr = from_indices(g.n, [0, 3, 11, 17]).mask
    for mode, fr in [("dense", full), ("sparse", sparse_fr)]:
        for label, graph in [("csr", g), ("compressed", c)]:
            fn = jax.jit(
                lambda frm, graph=graph, mode=mode: edgemap_reduce(
                    graph, frm, x, monoid="min", mode=mode
                )
            )
            rows.append(
                dict(
                    name=f"table_compression_edgemap_{mode}_{label}",
                    us_per_call=_time_us(fn, fr),
                    derived=f"mode={mode} backend={label}",
                )
            )

    # frontier sweep, streamed: a 10%-dense frontier through the chunked-mode
    # Pallas decode — the wall-clock row next to the PSAM read-model row
    # below, which is the actual claim (streamed bytes ∝ live blocks, not NB)
    TB = 8
    fr10 = jnp.asarray(np.random.default_rng(5).random(g.n) < 0.10)
    k_live = int(jnp.take(fr10, c.block_src, mode="fill", fill_value=False).sum())
    fn_str = jax.jit(
        lambda frm: edgemap_reduce(
            c, frm, x, monoid="min", mode="sparse_streamed", chunk_blocks=TB
        )
    )
    from .kernels_micro import frontier_stream_derived

    rows.append(
        dict(
            name="table_compression_edgemap_frontier_streamed",
            us_per_call=_time_us(fn_str, fr10),
            derived=frontier_stream_derived(c, k_live, TB),
        )
    )

    xf = jax.random.normal(jax.random.PRNGKey(0), (g.n,), jnp.float32)
    f = make_filter(g)
    us_unc = _time_us(lambda xv: spmv_vertex(g, xv, f), xf)
    us_cmp = _time_us(lambda xv: compressed_spmv_vertex(c, xv, f), xf)
    rows.append(
        dict(
            name="table_compression_kernel_spmv",
            us_per_call=us_cmp,
            derived=f"fused_decode_spmv_us={us_cmp:.0f} uncompressed_spmv_us={us_unc:.0f}",
        )
    )

    cost_u, cost_c = PSAMCost(), PSAMCost()
    cost_u.charge_edgemap_dense(g)
    cost_c.charge_edgemap_dense(c)
    rows.append(
        dict(
            name="table_compression_psam_reads",
            us_per_call=0,
            derived=(
                f"large_read_words_csr={cost_u.large_reads} "
                f"large_read_words_compressed={cost_c.large_reads} "
                f"saving={cost_u.large_reads / max(cost_c.large_reads, 1):.2f}x"
            ),
        )
    )
    return rows


if __name__ == "__main__":
    for r in run():
        print(f"{r['name']},{r['us_per_call']:.0f},{r['derived']}")
