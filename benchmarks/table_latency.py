"""Serving latency: p50/p99 and qps-vs-SLO through the ServingService.

``table_serving`` measures the batching substrate with hand-placed
``flush()`` calls; this table measures the ALWAYS-ON tier (ISSUE 6): the
``ServingService`` drain loop replaying recorded arrival traces, flushing
on deadline or queue depth — whichever fires first — with fused
BFS+wBFS cohorts and early-exit repacking.

Replay runs in **virtual time**: the trace supplies arrival timestamps,
the service's deadline/depth triggers decide flush times in the same
clock, and each flush's *service* time is measured on the wall.  A
request's reported latency is its queueing delay (virtual: flush time −
arrival) plus the wall-clock drain it rode — the decomposition that makes
open-loop replay deterministic while still charging real compute.

The p50/p99 rows read straight out of the service's OWN
``sage_service_latency_seconds`` histograms (``repro.obs`` — ISSUE 9's
one-source-of-truth satellite): each leg injects a fresh registry, the
warmup replay's samples are reset away, and the measured replay's
percentiles come from the same bucket-walk extraction a live scrape would
use — the bench no longer maintains private percentile code, so a
dashboard over the exported histograms reproduces this table by
construction.

Rows:

* ``poisson_p50`` / ``poisson_p99`` — latency percentiles over a seeded
  Poisson trace (exponential inter-arrivals, mixed 2:1 bfs:wbfs).
* ``bursty_p50`` / ``bursty_p99`` — the same over a bursty trace (request
  clumps at intervals), the depth-trigger stress case.
* ``slo_<ms>ms`` — the qps-vs-SLO curve: the Poisson trace replayed under
  tighter/looser SLOs; derived reports the SLO hit rate and achieved qps.
  Tighter SLOs flush earlier and shallower (lower latency, more flushes,
  smaller batches); looser SLOs coalesce deeper.
* ``saturated_B8`` — 8 simultaneous arrivals drain as one depth-triggered
  B=8 cohort; derived compares achieved qps against the hand-flushed
  engine on the identical workload (the acceptance bar: within 10%).

Derived columns also surface batch occupancy (``ServingService.occupancy``
— the round-weighted share of lane-slots doing real work, the padding
waste ``QueryEngine.stats`` now tracks per batch).

``--smoke`` runs the tiny-graph CI leg: a Poisson trace drains with at
least one deadline-triggered flush and one lane is verified bit-exactly
against its single-query run.
"""
from __future__ import annotations

import time

import numpy as np


def _poisson_trace(rng, qps: float, count: int, n: int):
    """(arrival, op, src) tuples with exponential inter-arrivals."""
    t, out = 0.0, []
    for i in range(count):
        t += rng.exponential(1.0 / qps)
        op = "wbfs" if i % 3 == 2 else "bfs"
        out.append((t, op, int(rng.integers(0, n))))
    return out


def _bursty_trace(rng, burst: int, bursts: int, gap: float, n: int):
    """(arrival, op, src): ``bursts`` clumps of ``burst`` requests."""
    out = []
    for b in range(bursts):
        t = b * gap
        for i in range(burst):
            op = "wbfs" if i % 3 == 2 else "bfs"
            out.append((t, op, int(rng.integers(0, n))))
    return out


def _replay(svc, trace):
    """Event-driven replay; returns per-request latencies (seconds).

    Advances the virtual clock to each arrival and each pending deadline,
    ticking the service at every event; wall-clocks each drain and adds
    it to the drained tickets' queueing delay.
    """
    latencies = []
    i = 0
    while i < len(trace) or svc.queue_depth:
        next_arr = trace[i][0] if i < len(trace) else None
        nd = svc.next_deadline()
        if next_arr is not None and (nd is None or next_arr <= nd):
            now, op, src = trace[i]
            i += 1
            svc.submit(op, src=src, now=now)
        else:
            now = nd
        t0 = time.perf_counter()
        done = svc.tick(now)
        dt = time.perf_counter() - t0 if done else 0.0
        for t in done:
            latencies.append((now - t.arrival) + dt)
    return latencies


def _service(g, *, registry=None, **cfg):
    from repro.serving import ServiceConfig, ServingService

    return ServingService(g, config=ServiceConfig(**cfg), registry=registry)


def _fresh_registry():
    from repro.obs import Registry

    return Registry()


def run(n=1024, m=8192, trace_len=48):
    from repro.data import rmat_graph
    from repro.serving import QueryEngine

    g = rmat_graph(n, m, weighted=True, seed=1, block_size=32)
    rows = []

    # --- latency percentiles: Poisson + bursty traces -------------------
    traces = {
        "poisson": _poisson_trace(
            np.random.default_rng(0), qps=400.0, count=trace_len, n=n
        ),
        "bursty": _bursty_trace(
            np.random.default_rng(1), burst=6, bursts=trace_len // 6, gap=0.03, n=n
        ),
    }
    for label, trace in traces.items():
        reg = _fresh_registry()
        svc = _service(g, registry=reg, slo=0.02, max_batch=8, mode="dense")
        _replay(svc, trace)  # warmup: compiles every cohort layout
        reg.reset()  # warmup samples out of the histograms
        _replay(svc, trace)
        assert all(c == 1 for c in svc.trace_counts.values()), "service retraced"
        # percentiles from the service's own exported latency histogram —
        # the same numbers a Prometheus scrape of this service would show
        hist = reg.get("sage_service_latency_seconds")
        p50, p99 = hist.percentile(50), hist.percentile(99)
        occ = svc.occupancy
        flushes = svc.stats["deadline_flushes"] + svc.stats["depth_flushes"]
        for pct, us in [("p50", p50 * 1e6), ("p99", p99 * 1e6)]:
            rows.append(
                dict(
                    name=f"table_latency_{label}_{pct}",
                    us_per_call=us,
                    derived=(
                        f"{pct}={us / 1e3:.2f}ms slo=20ms "
                        f"flushes={flushes} occupancy={occ:.2f}"
                    ),
                )
            )

    # --- qps vs SLO curve ----------------------------------------------
    for slo in (0.02, 0.1, 0.3):
        reg = _fresh_registry()
        svc = _service(g, registry=reg, slo=slo, max_batch=8, mode="dense")
        _replay(svc, traces["poisson"])
        reg.reset()
        t0 = time.perf_counter()
        lat = _replay(svc, traces["poisson"])
        wall = time.perf_counter() - t0
        hit = float(np.mean(np.asarray(lat) <= slo))
        qps = len(lat) / wall
        rows.append(
            dict(
                name=f"table_latency_slo_{int(slo * 1e3)}ms",
                us_per_call=reg.get("sage_service_latency_seconds").percentile(99)
                * 1e6,
                derived=(
                    f"slo={slo * 1e3:.0f}ms hit_rate={hit:.2f} qps={qps:.1f} "
                    f"occupancy={svc.occupancy:.2f}"
                ),
            )
        )

    # --- saturated B=8 vs the hand-flushed engine -----------------------
    rng = np.random.default_rng(2)
    srcs = [int(s) for s in rng.integers(0, n, 8)]
    sat = [(0.0, "bfs", s) for s in srcs]
    # throughput-tuned config: a deep round quantum makes the saturated
    # drain one long jitted call, like the engine's single while_loop —
    # deadline legs keep the short quantum that buys early-exit repacking
    svc = _service(
        g, slo=1.0, max_batch=8, depth_trigger=8, mode="dense", round_quantum=16
    )
    _replay(svc, sat)
    reps = 3
    t0 = time.perf_counter()
    for _ in range(reps):
        _replay(svc, sat)
    svc_us = (time.perf_counter() - t0) / reps * 1e6
    assert svc.stats["depth_flushes"] >= reps, "saturated leg must depth-flush"

    eng = QueryEngine(g, max_batch=8)

    def hand_flush():
        for s in srcs:
            eng.submit("bfs", src=s, mode="dense")
        eng.flush()

    hand_flush()
    t0 = time.perf_counter()
    for _ in range(reps):
        hand_flush()
    eng_us = (time.perf_counter() - t0) / reps * 1e6
    rows.append(
        dict(
            name="table_latency_saturated_B8",
            us_per_call=svc_us,
            derived=(
                f"qps={8 / (svc_us / 1e6):.1f} engine_qps={8 / (eng_us / 1e6):.1f} "
                f"ratio={svc_us / eng_us:.2f} occupancy={svc.occupancy:.2f}"
            ),
        )
    )
    return rows


def smoke():
    """Tiny-graph service smoke (CI): Poisson trace, deadline flush, one
    lane verified bit-exactly against its single-query run."""
    import jax.numpy as jnp

    from repro.algorithms import bfs, wbfs
    from repro.data import rmat_graph
    from repro.serving import ServiceConfig, ServingService

    g = rmat_graph(256, 1024, weighted=True, seed=3, block_size=32)
    svc = ServingService(g, config=ServiceConfig(slo=0.01, max_batch=8))
    trace = _poisson_trace(np.random.default_rng(7), qps=300.0, count=9, n=g.n)
    tickets, done = [], []
    i = 0
    while i < len(trace) or svc.queue_depth:
        next_arr = trace[i][0] if i < len(trace) else None
        nd = svc.next_deadline()
        if next_arr is not None and (nd is None or next_arr <= nd):
            now, op, src = trace[i]
            i += 1
            tickets.append(svc.submit(op, src=src, now=now))
        else:
            now = nd
        done += svc.tick(now)
    assert len(done) == len(trace), "trace must drain fully"
    assert svc.stats["deadline_flushes"] >= 1, "no deadline-triggered flush"
    # the drain reported into the process-global registry: the latency
    # histogram the full table reads its percentiles from is live here too
    from repro.obs import get_registry

    hist = get_registry().get("sage_service_latency_seconds")
    assert hist is not None and hist.count() >= len(done), "latency histogram empty"
    t = tickets[0]
    if t.op == "bfs":
        p, lv = bfs(g, int(trace[0][2]))
        assert bool(jnp.all(t.result[0] == p)) and bool(jnp.all(t.result[1] == lv))
    else:
        assert bool(jnp.all(t.result == wbfs(g, int(trace[0][2]))))
    print(
        f"latency smoke OK: {len(done)} served, "
        f"{svc.stats['deadline_flushes']} deadline flush(es), "
        f"occupancy={svc.occupancy:.2f}, lane bit-exact"
    )


if __name__ == "__main__":
    import sys

    if "--smoke" in sys.argv:
        smoke()
    else:
        for r in run():
            print(f"{r['name']},{r['us_per_call']:.0f},{r['derived']}")
