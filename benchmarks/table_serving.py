"""Serving throughput: queries/sec vs batch size through the QueryEngine.

The serving subsystem's claim (ISSUE 4 / the semi-external lesson of
Graphyti & FlashGraph): when the edge medium is the bottleneck, sharing one
sequential scan across B concurrent queries is the biggest throughput
lever.  This table measures it end to end — B concurrent BFS requests
drained as ONE batched edgeMap sweep per round — for both storage
backends, sweeping B ∈ {1, 2, 4, 8}.

The workload is FIXED — the same 8 BFS requests — and only the batching
policy varies (``max_batch`` = B drains them as 8/B flushes of width B),
so the sweep isolates what batching buys: at B=8 the whole workload is one
lockstep loop whose per-round edge sweep serves every query.  Columns
(derived): queries/sec, and the PSAM edge-read amortization at B=8 (one
batched sweep charges the edge bytes once; 8 sequential runs charge them
8×) — the acceptance bar is ≥4×.  Requests pin ``mode="dense"`` (the
serving fast path: the batched dense body is one shared sweep + one
m-row × B-column segment reduce; ``auto`` additionally pays the per-lane
sparse branch for the lanes' direction choice).

``--smoke`` runs the tiny-graph B=4 serving invocation CI uses: submit a
mixed bucket, flush, verify a lane bit-exactly against its single-query
run, print OK.  ``--dump-metrics PATH`` (with ``--smoke``) writes the
process-global registry's Prometheus text after the smoke — the artifact
CI uploads, proving the full engine metric surface populates on every
commit.
"""
from __future__ import annotations

import time

import numpy as np


def _qps(engine_factory, srcs, reps=3):
    """Serve the fixed ``srcs`` workload in width-B batches; us per drain."""
    eng = engine_factory()

    def drain():
        for s in srcs:
            eng.submit("bfs", src=int(s), mode="dense")
        eng.flush()

    drain()  # compile + warmup (populates the executable cache)
    t0 = time.perf_counter()
    for _ in range(reps):
        drain()
    dt = (time.perf_counter() - t0) / reps
    assert all(t == 1 for t in eng.trace_counts.values()), "serving retraced"
    return dt * 1e6, eng


def run(n=2048, m=16384, batch_sizes=(1, 2, 4, 8)):
    import jax.numpy as jnp

    from repro.core import PSAMCost, compress
    from repro.data import rmat_graph
    from repro.serving import QueryEngine

    g = rmat_graph(n, m, seed=1, block_size=32)
    c = compress(g)
    rng = np.random.default_rng(0)
    all_srcs = rng.integers(0, n, max(batch_sizes))

    rows = []
    for label, backend in [("csr", g), ("compressed", c)]:
        for B in batch_sizes:
            us, eng = _qps(
                lambda b=backend, bb=B: QueryEngine(b, max_batch=bb), all_srcs
            )
            qps = len(all_srcs) / (us / 1e6)
            rows.append(
                dict(
                    name=f"table_serving_{label}_B{B}",
                    us_per_call=us,
                    derived=f"B={B} qps={qps:.1f} (8 queries, {-(-8 // B)} flushes)",
                )
            )
        # PSAM amortization at B=8: edge bytes once per batched sweep vs
        # once per query per sweep (rounds measured off the real queries)
        from repro.algorithms import bfs, bfs_batched

        seq_rounds = [
            int(jnp.max(bfs(backend, int(s), mode="dense")[1])) + 1
            for s in all_srcs
        ]
        _, lb = bfs_batched(backend, jnp.asarray(all_srcs, jnp.int32), mode="dense")
        batched_rounds = int(jnp.max(lb)) + 1
        batched, sequential = PSAMCost(), PSAMCost()
        for _ in range(batched_rounds):
            batched.charge_edgemap_batched(backend, len(all_srcs))
        for r in seq_rounds:
            for _ in range(r):
                sequential.charge_edgemap_planned(backend)
        ratio = sequential.large_reads / batched.large_reads
        rows.append(
            dict(
                name=f"table_serving_{label}_psam_amortization",
                us_per_call=0,
                derived=(
                    f"B=8 edge_read_ratio={ratio:.2f}x "
                    f"(seq={sequential.large_reads} batched={batched.large_reads} "
                    f"rounds={batched_rounds})"
                ),
            )
        )
    return rows


def smoke():
    """Tiny-graph serving smoke (CI): mixed B=4 bucket, bit-exact lane."""
    import jax
    import jax.numpy as jnp

    from repro.algorithms import bfs
    from repro.data import rmat_graph
    from repro.serving import QueryEngine

    g = rmat_graph(256, 1024, seed=3, block_size=32)
    eng = QueryEngine(g, max_batch=4)
    handles = [eng.submit("bfs", src=s) for s in [0, 7, 11, 42]]
    res = eng.flush()
    assert eng.stats["served"] == 4 and eng.stats["batches"] == 1
    wp, wl = jax.jit(lambda gg, s: bfs(gg, s))(g, jnp.int32(7))
    assert bool(jnp.all(res[handles[1]][0] == wp))
    assert bool(jnp.all(res[handles[1]][1] == wl))
    assert eng.cost.large_reads > 0
    print(
        f"serving smoke OK: B=4 batch served, {eng.stats['batches']} batch, "
        f"psam_edge_words={eng.cost.large_reads}"
    )


if __name__ == "__main__":
    import sys

    if "--smoke" in sys.argv:
        smoke()
    else:
        for r in run():
            print(f"{r['name']},{r['us_per_call']:.0f},{r['derived']}")
    if "--dump-metrics" in sys.argv:
        from repro.obs import get_registry

        path = sys.argv[sys.argv.index("--dump-metrics") + 1]
        text = get_registry().to_prometheus_text()
        assert "sage_engine_served_total" in text, "engine metrics missing"
        with open(path, "w") as fh:
            fh.write(text)
        print(f"metrics: wrote {len(text.splitlines())} series lines to {path}")
