"""Paper Table 4: graph-filter block size F_B vs triangle-counting work.

The paper measures intersection work (fixed per ordering) against total
block-decode work, which grows with F_B because fetching one active edge
decodes the whole block.  We reproduce both columns analytically from the
filter structure plus the measured running time.
"""
from __future__ import annotations

import time

import numpy as np

from repro.algorithms.substructure import orientation_filter, triangle_count
from repro.data import rmat_graph


def run(n=2048, m=16384, block_sizes=(32, 64, 128, 256)):
    rows = []
    for fb in block_sizes:
        g = rmat_graph(n, m, seed=1, block_size=fb)
        f, keep = orientation_filter(g)
        # intersection work: sum over directed edges of min(d+(u), d+(v))
        src = np.asarray(g.edge_src)
        dst = np.asarray(g.edge_dst)
        deg_or = np.asarray(f.active_deg)
        us, vs = src[keep], dst[keep]
        inter_work = int(np.minimum(deg_or[us], deg_or[vs]).sum())
        # total decode work: every touched block decodes F_B slots
        blocks_live = int(np.asarray(f.block_live).sum())
        total_work = blocks_live * fb
        t0 = time.perf_counter()
        tri = triangle_count(g)
        dt = time.perf_counter() - t0
        rows.append(
            dict(
                name=f"table4_fb{fb}",
                us_per_call=dt * 1e6,
                derived=(
                    f"F_B={fb} intersection_work={inter_work} "
                    f"decode_work={total_work} triangles={tri}"
                ),
            )
        )
    return rows


if __name__ == "__main__":
    for r in run():
        print(f"{r['name']},{r['us_per_call']:.0f},{r['derived']}")
