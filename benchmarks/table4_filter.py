"""Paper Table 4: graph-filter block size F_B vs triangle-counting work,
plus the planner-native filtered-edgeMap columns.

The paper measures intersection work (fixed per ordering) against total
block-decode work, which grows with F_B because fetching one active edge
decodes the whole block.  We reproduce both columns analytically from the
filter structure plus the measured running time.

``run_planned`` adds the columns the filter story gained with the unified
planner: the same filtered aggregation through (a) the raw-CSR Pallas
kernel with the packed ``edge_active`` operand, (b) the compressed kernel
(bitmask ANDed in-VMEM next to the fused delta decode), and (c) a 4-shard
fake-CPU mesh where the filter words shard block-range-wise alongside the
edge blocks.  Derived columns report the live-block count and the PSAM
filtered read model (``charge_edgemap_planned(filter_live_blocks=...)``).
"""
from __future__ import annotations

import json
import os
import subprocess
import sys
import time

import numpy as np

from repro.algorithms.substructure import orientation_filter, triangle_count
from repro.data import rmat_graph


def run(n=2048, m=16384, block_sizes=(32, 64, 128, 256)):
    rows = []
    for fb in block_sizes:
        g = rmat_graph(n, m, seed=1, block_size=fb)
        f, keep = orientation_filter(g)
        # intersection work: sum over directed edges of min(d+(u), d+(v))
        src = np.asarray(g.edge_src)
        dst = np.asarray(g.edge_dst)
        deg_or = np.asarray(f.active_deg)
        us, vs = src[keep], dst[keep]
        inter_work = int(np.minimum(deg_or[us], deg_or[vs]).sum())
        # total decode work: every touched block decodes F_B slots
        blocks_live = int(np.asarray(f.block_live).sum())
        total_work = blocks_live * fb
        t0 = time.perf_counter()
        tri = triangle_count(g)
        dt = time.perf_counter() - t0
        rows.append(
            dict(
                name=f"table4_fb{fb}",
                us_per_call=dt * 1e6,
                derived=(
                    f"F_B={fb} intersection_work={inter_work} "
                    f"decode_work={total_work} triangles={tri}"
                ),
            )
        )
    return rows


_MESH_CODE = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import json, sys, time
import jax, jax.numpy as jnp, numpy as np
from repro.compat import make_mesh, use_mesh
from repro.core import compress, make_plan, edgemap_reduce
from repro.algorithms.substructure import orientation_filter
from repro.data import rmat_graph

n, m = int(sys.argv[1]), int(sys.argv[2])
g = rmat_graph(n, m, seed=1, block_size=32)
c = compress(g)
f, _ = orientation_filter(g)
x = jnp.ones(g.n, jnp.float32)
full = jnp.ones(g.n, bool)
mesh = make_mesh((4,), ("data",))
out = {}
with use_mesh(mesh):
    for label, backend in [("csr", g), ("compressed", c)]:
        plan = make_plan(backend, mesh=mesh)
        gs, sea = plan.prepare(backend, edge_active=f)

        @jax.jit
        def step(gss, xv, ea):
            o, _ = edgemap_reduce(
                gss, full, xv, monoid="sum", edge_active=ea,
                mode="dense", plan=plan,
            )
            return o

        step(gs, x, sea).block_until_ready()  # compile + warmup
        reps = 5
        t0 = time.perf_counter()
        for _ in range(reps):
            step(gs, x, sea).block_until_ready()
        out[label] = (time.perf_counter() - t0) * 1e6 / reps
print(json.dumps(out))
"""


def run_planned(n=512, m=4096):
    """Planner-native filtered-edgeMap columns (kernel operand + 4-shard mesh)."""
    import jax
    import jax.numpy as jnp

    from repro.core import PSAMCost, compress, make_filter
    from repro.kernels import compressed_spmv_vertex, spmv_vertex

    g = rmat_graph(n, m, seed=1, block_size=32)
    c = compress(g)
    f, _ = orientation_filter(g)
    live = int(np.asarray(f.block_live).sum())
    base = make_filter(g)
    x = jnp.ones(g.n, jnp.float32)

    def timed(fn):
        jax.block_until_ready(fn())  # compile + warmup
        reps = 5
        t0 = time.perf_counter()
        for _ in range(reps):
            jax.block_until_ready(fn())
        return (time.perf_counter() - t0) * 1e6 / reps

    rows = []
    for label, fn, backend in [
        ("csr", lambda: spmv_vertex(g, x, base, edge_active=f), g),
        (
            "compressed",
            lambda: compressed_spmv_vertex(c, x, base, edge_active=f),
            c,
        ),
    ]:
        cost = PSAMCost()
        cost.charge_edgemap_planned(backend, num_shards=1, filter_live_blocks=live)
        rows.append(
            dict(
                name=f"table4_filtered_kernel_{label}",
                us_per_call=timed(fn),
                derived=(
                    f"live_blocks={live}/{g.num_blocks} "
                    f"psam_filtered_read_words={cost.large_reads}"
                ),
            )
        )

    # 4-shard mesh columns run in a subprocess so the fake-device XLA flag
    # doesn't leak into this process
    r = subprocess.run(
        [sys.executable, "-c", _MESH_CODE, str(n), str(m)],
        capture_output=True,
        text=True,
        env={
            **os.environ,
            "PYTHONPATH": "src" + os.pathsep + os.environ.get("PYTHONPATH", ""),
        },
        cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
    )
    lines = [ln for ln in r.stdout.splitlines() if ln.startswith("{")]
    if not lines:
        rows.append(
            dict(
                name="table4_filtered_mesh4",
                us_per_call=-1,
                derived="FAILED: " + r.stderr[-200:].replace("\n", " "),
            )
        )
        return rows
    mesh_us = json.loads(lines[-1])
    for label in ["csr", "compressed"]:
        cost = PSAMCost()
        backend = c if label == "compressed" else g
        cost.charge_edgemap_planned(backend, num_shards=4, filter_live_blocks=live)
        rows.append(
            dict(
                name=f"table4_filtered_mesh4_{label}",
                us_per_call=mesh_us[label],
                derived=(
                    f"shards=4 live_blocks={live}/{g.num_blocks} "
                    f"psam_filtered_read_words={cost.large_reads}"
                ),
            )
        )
    return rows


if __name__ == "__main__":
    for r in run() + run_planned():
        print(f"{r['name']},{r['us_per_call']:.0f},{r['derived']}")
