"""Kernel microbenchmarks: the three Pallas kernels (interpret mode on this
CPU container; on TPU the same call sites compile natively) against their
pure-jnp references."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro.core import make_filter
from repro.data import rmat_graph
from repro.kernels import embedding_bag, spmv_vertex
from repro.kernels.edge_block_spmv.ref import spmv_vertex_ref
from repro.kernels.embedding_bag.ref import embedding_bag_ref


def _timeit(fn, *args):
    out = fn(*args)
    jax.block_until_ready(out)
    t0 = time.perf_counter()
    out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) * 1e6


def run():
    rows = []
    g = rmat_graph(1024, 8192, weighted=True, seed=1, block_size=64)
    f = make_filter(g)
    x = jax.random.normal(jax.random.PRNGKey(0), (g.n,), jnp.float32)
    rows.append(
        dict(name="spmv_pallas_interp", us_per_call=_timeit(lambda: spmv_vertex(g, x, f)),
             derived=f"NB={g.num_blocks} FB={g.block_size}")
    )
    ref = jax.jit(
        lambda xx: spmv_vertex_ref(xx, g.block_dst, g.block_w, f.bits, g.block_src, n=g.n)
    )
    rows.append(dict(name="spmv_jnp_ref", us_per_call=_timeit(ref, x), derived="oracle"))

    table = jax.random.normal(jax.random.PRNGKey(1), (4096, 64), jnp.float32)
    idx = jax.random.randint(jax.random.PRNGKey(2), (512, 16), -1, 4096)
    w = jnp.ones((512, 16), jnp.float32)
    rows.append(
        dict(name="embedding_bag_pallas_interp",
             us_per_call=_timeit(lambda: embedding_bag(table, idx, w)),
             derived="V=4096 D=64 B=512 L=16")
    )
    refb = jax.jit(lambda t, i, ww: embedding_bag_ref(t, i, ww))
    rows.append(
        dict(name="embedding_bag_jnp_ref", us_per_call=_timeit(refb, table, idx, w),
             derived="oracle")
    )
    return rows


if __name__ == "__main__":
    for r in run():
        print(f"{r['name']},{r['us_per_call']:.0f},{r['derived']}")
