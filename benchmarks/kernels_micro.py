"""Kernel microbenchmarks: the Pallas kernels (interpret mode on this CPU
container; on TPU the same call sites compile natively) against their
pure-jnp references, plus the frontier-sweep row demonstrating the
chunked-mode kernel's PSAM read model: streamed bytes proportional to the
live (frontier-owned) blocks, never to NB."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import PSAMCost, compress, make_filter
from repro.data import rmat_graph
from repro.kernels import (
    compressed_spmv_vertex,
    compressed_spmv_vertex_chunked,
    embedding_bag,
    spmv_vertex,
)
from repro.kernels.compressed_spmv.ops import compressed_chunked_stream_tile
from repro.kernels.compressed_spmv.ref import compressed_chunked_spmv_ref
from repro.kernels.edge_block_spmv.ref import spmv_vertex_ref
from repro.kernels.embedding_bag.ref import embedding_bag_ref
from repro.kernels.lowering import resolve_lowering


def _timeit(fn, *args):
    out = fn(*args)
    jax.block_until_ready(out)
    t0 = time.perf_counter()
    out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) * 1e6


def frontier_stream_derived(c, k: int, tile_blocks: int) -> str:
    """PSAM read model of one frontier-sparse streamed round, as a derived
    string: streamed (chunk-padded live), exactly-live and dense-NB words.

    Shared by the `kernels_micro` and `table_compression` frontier-sweep
    rows so the acceptance ratio (streamed ≤ 1.2× live at 10% density) is
    computed exactly one way.
    """
    streamed, live, dense = PSAMCost(), PSAMCost(), PSAMCost()
    streamed.charge_edgemap_sparse(c, k, tile_blocks=tile_blocks)
    live.charge_edgemap_sparse(c, k, tile_blocks=1)
    dense.charge_edgemap_dense(c)
    return (
        f"live_blocks={k}/{c.num_blocks} "
        f"streamed_words={streamed.large_reads} "
        f"live_words={live.large_reads} "
        f"dense_words={dense.large_reads} "
        f"streamed_vs_live={streamed.large_reads / max(live.large_reads, 1):.3f}x "
        f"dense_vs_streamed={dense.large_reads / max(streamed.large_reads, 1):.1f}x"
    )


def obs_overhead_row(reps: int = 7):
    """Instrumented-vs-disabled overhead of one eager dense edgeMap round.

    The ISSUE 9 acceptance bar as a bench row: the same
    ``edgemap_reduce(mode='dense')`` call timed (min over ``reps``) under
    an enabled ``Registry`` and under ``noop_registry()``.  The recording
    cost per eager round is one registry lookup + a counter inc, so the
    ratio must stay under 1.03 — asserted HERE, in the bench, so any hot-
    path instrumentation creep fails CI rather than drifting the trend.
    """
    from repro.core.edgemap import edgemap_reduce
    from repro.obs import Registry, noop_registry, use_registry

    g = rmat_graph(1024, 8192, weighted=True, seed=1, block_size=64)
    frontier = jnp.ones(g.n, dtype=bool)
    x = jnp.arange(g.n, dtype=jnp.int32)

    def leg(reg):
        with use_registry(reg):
            jax.block_until_ready(
                edgemap_reduce(g, frontier, x, monoid="min", mode="dense")
            )  # warmup: op caches hot before either leg times
            best = float("inf")
            for _ in range(reps):
                t0 = time.perf_counter()
                jax.block_until_ready(
                    edgemap_reduce(g, frontier, x, monoid="min", mode="dense")
                )
                best = min(best, time.perf_counter() - t0)
        return best * 1e6

    on = leg(Registry())
    off = leg(noop_registry())
    ratio = on / max(off, 1e-9)
    assert ratio < 1.03, (
        f"obs overhead {ratio:.3f}x >= 1.03x on eager dense edgeMap "
        f"(enabled {on:.0f}us vs disabled {off:.0f}us)"
    )
    return dict(
        name="edgemap_obs_overhead",
        us_per_call=on,
        derived=(
            f"enabled={on:.0f}us disabled={off:.0f}us ratio={ratio:.3f}x "
            f"(<1.03x enforced in-bench)"
        ),
    )


def run():
    rows = []
    g = rmat_graph(1024, 8192, weighted=True, seed=1, block_size=64)
    f = make_filter(g)
    x = jax.random.normal(jax.random.PRNGKey(0), (g.n,), jnp.float32)
    rows.append(
        dict(name="spmv_pallas_interp", us_per_call=_timeit(lambda: spmv_vertex(g, x, f)),
             derived=f"NB={g.num_blocks} FB={g.block_size}")
    )
    ref = jax.jit(
        lambda xx: spmv_vertex_ref(xx, g.block_dst, g.block_w, f.bits, g.block_src, n=g.n)
    )
    rows.append(dict(name="spmv_jnp_ref", us_per_call=_timeit(ref, x), derived="oracle"))

    # ------------------------------------------------------------------
    # Lowering seam: the same kernel under forced interpret mode vs the
    # per-backend resolved default (identical on CPU, native on TPU) — the
    # trend pair that shows what the auto decision buys on each host
    # ------------------------------------------------------------------
    rows.append(
        dict(
            name="spmv_lowering_forced_interp",
            us_per_call=_timeit(lambda: spmv_vertex(g, x, f, interpret=True)),
            derived="interpret pinned",
        )
    )
    rows.append(
        dict(
            name="spmv_lowering_resolved",
            us_per_call=_timeit(lambda: spmv_vertex(g, x, f, interpret=None)),
            derived=f"resolved={resolve_lowering()}",
        )
    )

    # ------------------------------------------------------------------
    # Frontier sweep (the chunked PrefetchScalarGridSpec mode): a 10%-dense
    # frontier must stream ≤ 1.2× the live blocks' bytes — the read volume
    # tracks the compacted live-id list the kernel's index_maps walk, not NB
    # ------------------------------------------------------------------
    TB = 8
    c = compress(g)
    rng = np.random.default_rng(0)
    frontier = jnp.asarray(rng.random(g.n) < 0.10)
    blk_live = jnp.take(frontier, c.block_src, mode="fill", fill_value=False)
    k = int(blk_live.sum())
    us_chunk = _timeit(
        lambda: compressed_spmv_vertex_chunked(c, x, frontier, f, tile_blocks=TB)
    )
    rows.append(
        dict(
            name="spmv_chunked_frontier_sweep",
            us_per_call=us_chunk,
            derived=frontier_stream_derived(c, k, TB),
        )
    )
    # gather-tile shape: the (1, F_B) row-wise PrefetchScalarGridSpec walk
    # vs the default (TB, F_B) pre-gathered DMA tiles, on ONE streamed
    # decode of the 10%-frontier's live blocks — same rows read, same PSAM
    # charge, batched HBM→VMEM transfers (acceptance: tiled ≥ 1.3×)
    live_ids = jnp.nonzero(blk_live)[0].astype(jnp.int32)
    us_rowwise = _timeit(
        lambda: compressed_chunked_stream_tile(
            c, live_ids, f, tile_blocks=TB, gather_tiles=False
        )
    )
    rows.append(
        dict(
            name="stream_tile_rowwise_gather",
            us_per_call=us_rowwise,
            derived="(1,FB) scalar-prefetch rows",
        )
    )
    us_tiled = _timeit(
        lambda: compressed_chunked_stream_tile(
            c, live_ids, f, tile_blocks=TB, gather_tiles=True
        )
    )
    rows.append(
        dict(
            name="stream_tile_tiled_gather",
            us_per_call=us_tiled,
            derived=(
                f"(TB,FB) pre-gathered tiles TB={TB} "
                f"speedup_vs_rowwise={us_rowwise / max(us_tiled, 1e-9):.2f}x"
            ),
        )
    )
    ref_chunk = jax.jit(
        lambda xx: compressed_chunked_spmv_ref(c, xx, frontier, f.bits, c.block_weights)
    )
    rows.append(
        dict(
            name="spmv_chunked_frontier_jnp_ref",
            us_per_call=_timeit(ref_chunk, x),
            derived="oracle (masked full stream)",
        )
    )
    rows.append(
        dict(
            name="spmv_compressed_dense_grid",
            us_per_call=_timeit(lambda: compressed_spmv_vertex(c, x, f)),
            derived="every block streams (the dense-mode kernel, for contrast)",
        )
    )

    table = jax.random.normal(jax.random.PRNGKey(1), (4096, 64), jnp.float32)
    idx = jax.random.randint(jax.random.PRNGKey(2), (512, 16), -1, 4096)
    w = jnp.ones((512, 16), jnp.float32)
    rows.append(
        dict(name="embedding_bag_pallas_interp",
             us_per_call=_timeit(lambda: embedding_bag(table, idx, w)),
             derived="V=4096 D=64 B=512 L=16")
    )
    refb = jax.jit(lambda t, i, ww: embedding_bag_ref(t, i, ww))
    rows.append(
        dict(name="embedding_bag_jnp_ref", us_per_call=_timeit(refb, table, idx, w),
             derived="oracle")
    )
    rows.append(obs_overhead_row())
    return rows


if __name__ == "__main__":
    import sys

    if "--obs-overhead" in sys.argv:
        # CI's dedicated overhead gate: just the instrumented-vs-disabled
        # row (its <1.03x assert IS the check), no other kernels timed
        r = obs_overhead_row()
        print(f"{r['name']},{r['us_per_call']:.0f},{r['derived']}")
    else:
        for r in run():
            print(f"{r['name']},{r['us_per_call']:.0f},{r['derived']}")
