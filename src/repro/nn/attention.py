"""Attention: GQA (grouped KV) and MLA (DeepSeek latent KV), with a
blockwise (flash-style) softmax so the S×S score matrix is never fully
materialized — mandatory for the 32k prefill cells to fit HBM.

Shapes: q (B,S,Hq,D), k/v (B,S,Hkv,D).  GQA repeats KV groups logically via
einsum reshape, never materializing repeated KV.

``mixed=True`` keeps q/k/v in their storage dtype (bf16) for the two einsums
with fp32 accumulation (preferred_element_type) — the MXU-native mode; the
softmax statistics stay fp32 either way.  ``mixed=False`` reproduces the
all-fp32 baseline.
"""
from __future__ import annotations

import jax.numpy as jnp
from jax import lax

NEG_INF = -1e30


def _block_attn(
    q, k, v, *, causal: bool, q_offset, kv_block: int, window: int | None,
    mixed: bool = False, unroll_kv: bool = False,
):
    """Blockwise softmax attention.

    q: (B, Sq, G, Hg, D) — G kv-groups × Hg q-heads per group
    k: (B, Skv, G, D); v: (B, Skv, G, Dv) — Dv may differ (MLA)
    q_offset: scalar — absolute position of q[0] (for causal masks in decode)
    Returns (B, Sq, G, Hg, Dv).
    """
    B, Sq, G, Hg, D = q.shape
    Skv = k.shape[1]
    Dv = v.shape[-1]
    kb = min(kv_block, Skv)
    nblk = -(-Skv // kb)
    pad = nblk * kb - Skv
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    scale = (1.0 / jnp.sqrt(D)).astype(jnp.float32)
    if mixed:
        qs = (q.astype(jnp.float32) * scale).astype(q.dtype)
    else:
        qs = q.astype(jnp.float32) * scale

    q_pos = q_offset + jnp.arange(Sq)

    def body(carry, blk):
        acc, m, l = carry
        kb_i, vb_i, base = blk  # (B, kb, G, D), (B, kb, G, Dv), scalar
        if mixed:
            s = jnp.einsum(
                "bqghd,bkgd->bqghk", qs, kb_i,
                preferred_element_type=jnp.float32,
            )
        else:
            s = jnp.einsum("bqghd,bkgd->bqghk", qs, kb_i.astype(jnp.float32))
        kv_pos = base + jnp.arange(kb)
        mask = kv_pos[None, :] <= q_pos[:, None] if causal else jnp.ones(
            (Sq, kb), bool
        )
        if window is not None:
            mask = mask & (kv_pos[None, :] > q_pos[:, None] - window)
        mask = mask & (kv_pos < Skv)[None, :]
        s = jnp.where(mask[None, :, None, None, :], s, NEG_INF)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m - m_new)
        l = l * corr + jnp.sum(p, axis=-1)
        if mixed:
            pv = jnp.einsum(
                "bqghk,bkgd->bqghd", p.astype(v.dtype), vb_i,
                preferred_element_type=jnp.float32,
            )
        else:
            pv = jnp.einsum("bqghk,bkgd->bqghd", p, vb_i.astype(jnp.float32))
        acc = acc * corr[..., None] + pv
        return (acc, m_new, l), None

    k_blocks = k.reshape(B, nblk, kb, G, D).transpose(1, 0, 2, 3, 4)
    v_blocks = v.reshape(B, nblk, kb, G, Dv).transpose(1, 0, 2, 3, 4)
    bases = jnp.arange(nblk) * kb
    acc0 = jnp.zeros((B, Sq, G, Hg, Dv), jnp.float32)
    m0 = jnp.full((B, Sq, G, Hg), NEG_INF, jnp.float32)
    l0 = jnp.zeros((B, Sq, G, Hg), jnp.float32)
    if unroll_kv:
        carry = (acc0, m0, l0)
        for j in range(nblk):
            carry, _ = body(carry, (k_blocks[j], v_blocks[j], bases[j]))
        acc, m, l = carry
    else:
        (acc, m, l), _ = lax.scan(body, (acc0, m0, l0), (k_blocks, v_blocks, bases))
    out = acc / jnp.maximum(l[..., None], 1e-30)
    return out.astype(q.dtype)


def _block_attn_causal_skip(
    q, k, v, *, kv_block: int, window, mixed: bool, unroll_kv: bool = False
):
    """Flash-style 2D blocking for CAUSAL full-sequence attention
    (Sq == Skv, q_offset == 0): q is chunked, and each q chunk only visits
    kv blocks that intersect its visible (lower-triangular) range — ~½ the
    einsum FLOPs and ~½ the score-tensor traffic of the mask-after-compute
    baseline.  The q loop is a static Python unroll so every inner scan has
    a static trip count (reverse-mode safe).
    """
    B, Sq, G, Hg, D = q.shape
    qb = min(kv_block, Sq)
    nqb = -(-Sq // qb)
    outs = []
    for i in range(nqb):
        lo = i * qb
        hi = min(Sq, lo + qb)
        q_i = q[:, lo:hi]
        kv_hi = -(-hi // kv_block) * kv_block
        kv_hi = min(kv_hi, Sq)
        o = _block_attn(
            q_i,
            k[:, :kv_hi],
            v[:, :kv_hi],
            causal=True,
            q_offset=lo,
            kv_block=kv_block,
            window=window,
            mixed=mixed,
            unroll_kv=unroll_kv,
        )
        outs.append(o)
    return jnp.concatenate(outs, axis=1)


def gqa_attention(
    q: jnp.ndarray,  # (B, Sq, Hq, D)
    k: jnp.ndarray,  # (B, Skv, Hkv, D)
    v: jnp.ndarray,
    *,
    causal: bool = True,
    q_offset=0,
    kv_block: int = 1024,
    window: int | None = None,
    mixed: bool = False,
    causal_skip: bool = False,
    unroll_kv: bool = False,
) -> jnp.ndarray:
    B, Sq, Hq, D = q.shape
    Hkv = k.shape[2]
    assert Hq % Hkv == 0, (Hq, Hkv)
    Hg = Hq // Hkv
    qg = q.reshape(B, Sq, Hkv, Hg, D)
    if (
        causal_skip
        and causal
        and Sq > 1
        and Sq == k.shape[1]
        and isinstance(q_offset, int)
        and q_offset == 0
    ):
        out = _block_attn_causal_skip(
            qg, k, v, kv_block=kv_block, window=window, mixed=mixed,
            unroll_kv=unroll_kv,
        )
    else:
        out = _block_attn(
            qg, k, v, causal=causal, q_offset=q_offset, kv_block=kv_block,
            window=window, mixed=mixed, unroll_kv=unroll_kv,
        )
    return out.reshape(B, Sq, Hq, v.shape[-1])
