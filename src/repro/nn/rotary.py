"""Rotary position embeddings (RoPE)."""
from __future__ import annotations

import jax.numpy as jnp


def rope_freqs(dim: int, theta: float = 10000.0) -> jnp.ndarray:
    return 1.0 / (theta ** (jnp.arange(0, dim, 2, dtype=jnp.float32) / dim))


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float = 10000.0):
    """x: (..., S, H, D) or (..., S, D); positions: (..., S)."""
    d = x.shape[-1]
    inv = rope_freqs(d, theta)
    ang = positions.astype(jnp.float32)[..., None] * inv  # (..., S, D/2)
    if x.ndim == ang.ndim + 1:  # head dim present
        ang = ang[..., None, :]
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    x1, x2 = x[..., ::2], x[..., 1::2]
    xr1 = x1 * cos - x2 * sin
    xr2 = x1 * sin + x2 * cos
    out = jnp.stack([xr1, xr2], axis=-1).reshape(x.shape)
    return out.astype(x.dtype)
