"""Normalization layers (pure-function, params as dicts)."""
from __future__ import annotations

import jax.numpy as jnp


def rms_norm(x: jnp.ndarray, scale: jnp.ndarray, eps: float = 1e-6) -> jnp.ndarray:
    dt = x.dtype
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    out = xf * jnp.reciprocal(jnp.sqrt(var + eps))
    return (out * scale.astype(jnp.float32)).astype(dt)


def layer_norm(x, scale, bias, eps: float = 1e-5):
    dt = x.dtype
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.mean((xf - mu) ** 2, axis=-1, keepdims=True)
    out = (xf - mu) * jnp.reciprocal(jnp.sqrt(var + eps))
    return (out * scale.astype(jnp.float32) + bias.astype(jnp.float32)).astype(dt)
