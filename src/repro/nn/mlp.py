"""Feed-forward blocks: SwiGLU (LLaMA/Mistral/Qwen/DBRX style) and plain MLP."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def swiglu(params: dict, x: jnp.ndarray) -> jnp.ndarray:
    """params: w_gate (d, f), w_up (d, f), w_down (f, d)."""
    g = x @ params["w_gate"]
    u = x @ params["w_up"]
    h = jax.nn.silu(g.astype(jnp.float32)).astype(x.dtype) * u
    return h @ params["w_down"]


def mlp2(params: dict, x: jnp.ndarray, act=jax.nn.gelu) -> jnp.ndarray:
    h = act((x @ params["w1"] + params.get("b1", 0)).astype(jnp.float32)).astype(
        x.dtype
    )
    return h @ params["w2"] + params.get("b2", 0)


def init_swiglu(key, d: int, f: int, dtype=jnp.float32) -> dict:
    k1, k2, k3 = jax.random.split(key, 3)
    s_in = 1.0 / jnp.sqrt(d)
    s_f = 1.0 / jnp.sqrt(f)
    return {
        "w_gate": jax.random.normal(k1, (d, f), dtype) * s_in,
        "w_up": jax.random.normal(k2, (d, f), dtype) * s_in,
        "w_down": jax.random.normal(k3, (f, d), dtype) * s_f,
    }


def init_mlp2(key, d_in: int, d_hidden: int, d_out: int, dtype=jnp.float32, bias=True):
    k1, k2 = jax.random.split(key)
    p = {
        "w1": jax.random.normal(k1, (d_in, d_hidden), dtype) / jnp.sqrt(d_in),
        "w2": jax.random.normal(k2, (d_hidden, d_out), dtype) / jnp.sqrt(d_hidden),
    }
    if bias:
        p["b1"] = jnp.zeros((d_hidden,), dtype)
        p["b2"] = jnp.zeros((d_out,), dtype)
    return p
