from .attention import gqa_attention
from .mlp import init_mlp2, init_swiglu, mlp2, swiglu
from .moe import MoECfg, init_moe, moe_ffn
from .norms import layer_norm, rms_norm
from .rotary import apply_rope

__all__ = [
    "gqa_attention", "init_mlp2", "init_swiglu", "mlp2", "swiglu",
    "MoECfg", "init_moe", "moe_ffn", "layer_norm", "rms_norm", "apply_rope",
]
