"""Mixture-of-Experts FFN with sort-based (linear-memory) dispatch.

GShard's one-hot dispatch tensor is quadratic in the token-group size; here
tokens are *sorted by expert id* (count → scan → scatter, the same primitive
family as edgeMapChunked) and packed into an (E, C, d) capacity buffer —
O(topk · T · d) memory.  The batched expert GEMM shards on the expert axis
(EP over the 'model' mesh axis); GSPMD inserts the token all-to-alls.

Router: softmax over the selected top-k logits (DBRX/Mixtral convention).
Tokens overflowing an expert's capacity are dropped for that expert
(standard capacity-factor semantics).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
from jax import lax

from .mlp import init_swiglu, swiglu


@dataclasses.dataclass(frozen=True)
class MoECfg:
    num_experts: int
    top_k: int
    d_model: int
    d_ff_expert: int
    n_shared: int = 0
    capacity_factor: float = 1.25


def init_moe(key, cfg: MoECfg, dtype=jnp.float32) -> dict:
    kr, ke, ks = jax.random.split(key, 3)
    d, f, E = cfg.d_model, cfg.d_ff_expert, cfg.num_experts
    s_in, s_f = 1.0 / jnp.sqrt(d), 1.0 / jnp.sqrt(f)
    params = {
        "router": jax.random.normal(kr, (d, E), dtype) * s_in,
        "w_gate": jax.random.normal(jax.random.fold_in(ke, 0), (E, d, f), dtype) * s_in,
        "w_up": jax.random.normal(jax.random.fold_in(ke, 1), (E, d, f), dtype) * s_in,
        "w_down": jax.random.normal(jax.random.fold_in(ke, 2), (E, f, d), dtype) * s_f,
    }
    if cfg.n_shared:
        params["shared"] = init_swiglu(ks, d, cfg.n_shared * f, dtype)
    return params


def capacity(cfg: MoECfg, T: int) -> int:
    c = int(cfg.capacity_factor * cfg.top_k * T / cfg.num_experts) + 1
    return max(8, -(-c // 8) * 8)


def moe_ffn(params: dict, x: jnp.ndarray, cfg: MoECfg) -> jnp.ndarray:
    """x: (T, d) → (T, d).  Sort-based capacity dispatch."""
    T, d = x.shape
    E, K = cfg.num_experts, cfg.top_k
    C = capacity(cfg, T)

    logits = (x @ params["router"]).astype(jnp.float32)  # (T, E)
    topv, topi = lax.top_k(logits, K)                    # (T, K)
    gates = jax.nn.softmax(topv, axis=-1).astype(x.dtype)

    flat_e = topi.reshape(-1).astype(jnp.int32)          # (T·K,)
    flat_t = jnp.repeat(jnp.arange(T, dtype=jnp.int32), K)
    order = jnp.argsort(flat_e, stable=True)             # count→scan→scatter
    se = flat_e[order]
    st = flat_t[order]
    starts = jnp.searchsorted(se, jnp.arange(E, dtype=jnp.int32))
    pos = jnp.arange(T * K, dtype=jnp.int32) - jnp.take(starts, se)
    keep = pos < C
    slot = jnp.where(keep, se * C + pos, E * C)          # E·C = overflow bin

    token_of_slot = jnp.full(E * C + 1, T, jnp.int32).at[slot].set(
        st, mode="drop"
    )[: E * C]
    xg = jnp.take(x, token_of_slot, axis=0, mode="fill", fill_value=0).reshape(
        E, C, d
    )

    # batched expert SwiGLU — shards on E (expert parallelism)
    g = jnp.einsum("ecd,edf->ecf", xg, params["w_gate"])
    u = jnp.einsum("ecd,edf->ecf", xg, params["w_up"])
    h = jax.nn.silu(g.astype(jnp.float32)).astype(x.dtype) * u
    y = jnp.einsum("ecf,efd->ecd", h, params["w_down"]).reshape(E * C, d)

    # combine: map each (t, k) back to its slot
    slot_of_flat = jnp.full(T * K, E * C, jnp.int32).at[order].set(
        jnp.where(keep, slot, E * C)
    )
    yk = jnp.take(y, slot_of_flat, axis=0, mode="fill", fill_value=0).reshape(
        T, K, d
    )
    out = jnp.sum(yk * gates[..., None], axis=1)

    if "shared" in params:
        out = out + swiglu(params["shared"], x)
    return out


def moe_aux_loss(logits_f32: jnp.ndarray, topi: jnp.ndarray, E: int) -> jnp.ndarray:
    """Switch-style load-balance loss (fraction·probability dot)."""
    probs = jax.nn.softmax(logits_f32, axis=-1)
    frac = jnp.mean(
        jax.nn.one_hot(topi[..., 0], E, dtype=jnp.float32), axis=0
    )
    return E * jnp.sum(frac * jnp.mean(probs, axis=0))
