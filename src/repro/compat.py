"""JAX version-compat shims.

The codebase targets the explicit-axis-types mesh API (``jax.sharding.AxisType``
+ ``jax.set_mesh``); the pinned install (0.4.37, see requirements.txt)
predates both while already providing ``jax.make_mesh`` and the legacy Mesh
context manager.  Every mesh construction and mesh-context entry goes
through these helpers so a single module carries the version split.
"""
from __future__ import annotations

import contextlib

import jax

try:  # jax >= 0.5: explicit axis types on meshes
    from jax.sharding import AxisType

    HAS_AXIS_TYPES = True
except ImportError:  # jax 0.4.x
    AxisType = None
    HAS_AXIS_TYPES = False


def auto_axis_types(n: int):
    """``axis_types`` tuple for n Auto axes, or None when unsupported."""
    if HAS_AXIS_TYPES:
        return (AxisType.Auto,) * n
    return None


def make_mesh(shape, axes, *, devices=None):
    """``jax.make_mesh`` with Auto axis types where the API supports them."""
    shape, axes = tuple(shape), tuple(axes)
    kwargs = {}
    if devices is not None:
        kwargs["devices"] = devices
    if HAS_AXIS_TYPES:
        kwargs["axis_types"] = auto_axis_types(len(axes))
    return jax.make_mesh(shape, axes, **kwargs)


@contextlib.contextmanager
def use_mesh(mesh):
    """``jax.set_mesh`` when available, else the legacy Mesh context manager."""
    if hasattr(jax, "set_mesh"):
        with jax.set_mesh(mesh):
            yield mesh
    else:
        with mesh:
            yield mesh
