"""repro.serving — the batched query-serving subsystem.

Turns the planner stack from a one-shot algorithm runner into a serving
system, in two layers:

* :class:`QueryEngine` — the batching substrate.  Coalesces concurrent
  BFS / wBFS / PPR / PageRank-iteration requests into per-op batch
  buckets, pads them to power-of-two widths, and drains each bucket
  through ONE batched edgeMap sweep per round — the NVRAM-modeled
  edge-byte reads are paid once per sweep instead of once per query
  (``PSAMCost.charge_edgemap_batched``), while compiled executables are
  cached per (backend, mesh, op, B) so steady-state serving never
  retraces.  Callers flush by hand.
* :class:`ServingService` — the always-on control loop.  Wraps the
  engine with a deadline/depth-triggered drain loop in virtual time,
  fuses BFS+wBFS lanes into cross-op cohorts that share edge sweeps,
  repacks drained lanes out between round quanta (early-exit
  accounting), and gates admission on per-tenant PSAM edge-read budgets
  (:class:`ServiceConfig` ``budgets`` → ``repro.core.TenantLedgers``).
  Constructed over a :class:`repro.delta.DeltaOverlay` it also serves
  graph EDITS: ``submit_edit`` admits inserts/deletes at the amortized
  compaction price, edits apply between flushes so every drained batch
  sees one consistent base ∪ delta snapshot, and the
  :class:`repro.tuning.OverlayTrigger` schedules ``repro.delta.compact``
  once the overlay surcharge has paid for the ω write.

See ``docs/serving.md`` for the full tier walkthrough and
``docs/mutability.md`` for the edit path.
"""
from .engine import QueryEngine, QueryHandle
from .service import ServiceConfig, ServingService, ServingTicket

__all__ = [
    "QueryEngine",
    "QueryHandle",
    "ServiceConfig",
    "ServingService",
    "ServingTicket",
]
