"""repro.serving — the batched query-serving subsystem.

Turns the planner stack from a one-shot algorithm runner into a serving
system: a :class:`QueryEngine` coalesces concurrent BFS / wBFS / PPR /
PageRank-iteration requests into per-op batch buckets, pads them to
power-of-two widths, and drains each bucket through ONE batched edgeMap
sweep per round — the NVRAM-modeled edge-byte reads are paid once per
sweep instead of once per query (``PSAMCost.charge_edgemap_batched``),
while compiled executables are cached per (backend, mesh, op, B) so
steady-state serving never retraces.
"""
from .engine import QueryEngine, QueryHandle

__all__ = ["QueryEngine", "QueryHandle"]
