"""Batched multi-query serving engine — one NVRAM edge sweep, many queries.

Sage's PSAM makes edge reads the scarce resource: the edges live in
read-only large memory, every query's mutable state is O(n) words.  Serving
Q concurrent requests naively costs Q full sweeps of the edge-block array.
The :class:`QueryEngine` is the throughput lever the semi-external systems
(Graphyti/FlashGraph, the Optane study — PAPERS.md) all converge on:
**share one sequential scan across many concurrent computations**.

    submit() ──► per-(op, params) buckets ──► pad to power-of-two B
                                                     │
                 compiled-executable cache ◄── flush()│
                 keyed (backend, mesh,               ▼
                       tuning, op, B)
                 ┌────────────────────────────────────────────┐
                 │ batched algorithm (bfs_batched, …)         │
                 │   └─ edgemap_reduce_batched: each round    │
                 │      streams every edge-block tile ONCE    │
                 │      and applies it to all B query columns │
                 └────────────────────────────────────────────┘
                                                     │
                 per-handle results (padding dropped)◄┘

Mechanics:

* **Coalescing** — heterogeneous requests (BFS, wBFS, PPR, PageRank
  iterations) bucket by ``(op, scalar params)``; each bucket drains as one
  batched call whose per-round edge sweep is shared by the whole bucket
  (``PSAMCost.charge_edgemap_batched``: edge bytes ÷ B, O(B·n) DRAM state).
* **Padding** — buckets pad to the next power of two (capped at
  ``max_batch``; larger buckets split) by repeating the last request, so
  steady-state serving sees a handful of distinct batch shapes.  Padded
  lanes are real-but-discarded queries; batched ops are bit-identical per
  query, so padding never perturbs a real lane.
* **Executable cache** — compiled callables are keyed by
  ``(backend type, mesh, plan tuning decision, op, B)`` (+ the bucket's scalar params, which are
  trace constants); a repeated ``(op, B)`` bucket re-enters the cached
  executable with zero retraces (``trace_counts`` makes this testable).
* **Planner-native** — the engine drains every bucket through the
  ``ExecutionPlan`` dispatch, so the same engine serves single-device or
  sharded meshes, raw or compressed storage; the mesh context is entered
  per flush when the plan is sharded.
"""
from __future__ import annotations

import contextlib
import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np

from ..algorithms.eigen import pagerank_iteration_batched
from ..algorithms.local import personalized_pagerank_batched
from ..algorithms.traversal import bfs_batched, wbfs_batched
from ..compat import use_mesh
from ..core.psam import PSAMCost
from ..obs import get_registry
from ..tuning.defaults import DEFAULT_MAX_BATCH

# engine batch widths are powers of two capped at max_batch — exact-width
# buckets, so the batch-size histogram is lossless
_BATCH_BUCKETS = tuple(float(1 << i) for i in range(11))


def _bfs_sweeps(res) -> int:
    """Edge sweeps a drained BFS batch executed: deepest level + drain round."""
    _, levels = res
    return int(jnp.max(levels)) + 1


def _wbfs_sweeps(res) -> int:
    """Edge sweeps a drained wBFS batch executed — one relaxation sweep per
    extracted bucket ≈ distinct finite distances of the longest-running
    query (analytic estimate, like Table 1's)."""
    finite = np.asarray(jnp.where(res < jnp.int32(2**31 - 1), res, -1))
    per_q = [len(np.unique(r[r >= 0])) for r in finite]
    return max(max(per_q, default=1), 1)


@dataclasses.dataclass(frozen=True)
class _OpSpec:
    """How one query kind batches: stack requests → run → slice → account."""

    stack: Callable[[list[dict]], tuple]        # requests → batched arrays
    run: Callable                               # (g, plan, args, scalars) → res
    unbatch: Callable[[Any, int], Any]          # batched res → query i's result
    sweeps: Callable[[Any], int]                # res → edge sweeps (analytic)
    scalar_keys: tuple = ()                     # params that are trace constants


def _src_stack(reqs: list[dict]) -> tuple:
    """Stack source-vertex requests into the int32[B] batched argument."""
    return (jnp.asarray([r["src"] for r in reqs], jnp.int32),)


def _pr_stack(reqs: list[dict]) -> tuple:
    """Stack per-request rank vectors into the float32[B, n] argument."""
    return (jnp.stack([jnp.asarray(r["pr"], jnp.float32) for r in reqs]),)


_OPS: dict[str, _OpSpec] = {
    "bfs": _OpSpec(
        stack=_src_stack,
        run=lambda g, plan, args, sc: bfs_batched(g, *args, plan=plan, **sc),
        unbatch=lambda res, i: (res[0][i], res[1][i]),
        sweeps=_bfs_sweeps,
        scalar_keys=("mode",),
    ),
    "wbfs": _OpSpec(
        stack=_src_stack,
        run=lambda g, plan, args, sc: wbfs_batched(g, *args, plan=plan, **sc),
        unbatch=lambda res, i: res[i],
        sweeps=_wbfs_sweeps,
        scalar_keys=("mode",),
    ),
    "ppr": _OpSpec(
        stack=_src_stack,
        run=lambda g, plan, args, sc: personalized_pagerank_batched(
            g, *args, plan=plan, **sc
        ),
        unbatch=lambda res, i: (res[0][i], res[1][i], res[2][i]),
        sweeps=lambda res: max(int(jnp.max(res[2])), 1),
        scalar_keys=("alpha", "eps", "max_rounds", "mode"),
    ),
    "pagerank_iteration": _OpSpec(
        stack=_pr_stack,
        run=lambda g, plan, args, sc: pagerank_iteration_batched(
            g, *args, plan=plan, **sc
        ),
        unbatch=lambda res, i: res[i],
        sweeps=lambda res: 1,
        scalar_keys=("damping",),
    ),
}


def _pow2_batch(k: int, max_batch: int) -> int:
    """Next power-of-two batch width ≥ k, capped at ``max_batch``."""
    b = 1
    while b < k:
        b *= 2
    return min(b, max_batch)


@dataclasses.dataclass(frozen=True)
class QueryHandle:
    """Ticket for a submitted query; resolves in the flush that drains it."""

    id: int
    op: str


class QueryEngine:
    """Coalesce, batch and serve graph queries over one prepared backend.

    Parameters
    ----------
    g         : CSRGraph | CompressedCSR — the read-only large memory
    plan      : ExecutionPlan | None — where the batches run; the graph is
                prepared (sharded + placed) once at construction
    max_batch : cap on the padded batch width B (buckets larger than this
                split into max_batch-wide chunks).  Default (None): the
                plan's tuning decision — the measured knee of the per-query
                cost curve over B (``plan.decisions.max_batch``) — falling
                back to the static ``DEFAULT_MAX_BATCH`` for plan-less
                engines or constants-only plans

    ``stats`` counts submitted/served queries, drained batches, total batch
    columns (``lanes``) and padding columns (``padded``) — so batch
    occupancy is observable, not just throughput; ``cost`` accumulates the
    PSAM model of every drained batch (edge bytes once per sweep, O(B·n)
    small memory).

    ``registry`` (optional) is the metrics registry the engine reports to —
    the process-global default (``repro.obs.get_registry``) when omitted,
    resolved once at construction.  The engine records per-op batch-size
    histograms (``sage_engine_batch_size``), lane/padding counters,
    submitted/served counters, an occupancy gauge, and compile-cache
    hit/miss counters (``sage_engine_cache_{hits,misses}_total`` — the
    zero-steady-state-retrace contract as a live metric, not just a test).
    Inject ``repro.obs.noop_registry()`` to disable at one attribute
    lookup per record.
    """

    def __init__(self, g, *, plan=None, max_batch: int | None = None, registry=None):
        self.graph = g
        self.plan = plan
        self.registry = registry if registry is not None else get_registry()
        self.prepared = g if plan is None else plan.prepare(g)
        if max_batch is None:
            decisions = getattr(plan, "decisions", None)
            max_batch = (
                decisions.max_batch if decisions is not None else DEFAULT_MAX_BATCH
            )
        self.max_batch = int(max_batch)
        self.cost = PSAMCost(registry=self.registry)
        reg = self.registry
        self._m_submitted = reg.counter(
            "sage_engine_submitted_total", "queries submitted", labels=("op",)
        )
        self._m_served = reg.counter(
            "sage_engine_served_total", "queries served (padding excluded)",
            labels=("op",),
        )
        self._m_batches = reg.counter(
            "sage_engine_batches_total", "batch buckets drained", labels=("op",)
        )
        self._m_lanes = reg.counter(
            "sage_engine_lanes_total", "batch columns drained (padding included)"
        )
        self._m_padded = reg.counter(
            "sage_engine_padded_lanes_total", "padding columns drained"
        )
        self._m_batch_size = reg.histogram(
            "sage_engine_batch_size", "padded batch width B per drained bucket",
            labels=("op",), buckets=_BATCH_BUCKETS,
        )
        self._m_cache_hits = reg.counter(
            "sage_engine_cache_hits_total",
            "compiled-executable cache hits", labels=("cache",),
        )
        self._m_cache_misses = reg.counter(
            "sage_engine_cache_misses_total",
            "compiled-executable cache misses (retraces)", labels=("cache",),
        )
        self._m_occupancy = reg.gauge(
            "sage_engine_occupancy", "served / lanes over the engine lifetime"
        )
        self._pending: dict[tuple, list[tuple[int, dict]]] = {}
        self._in_flush = False
        self._reset_deferred = False
        self._compiled: dict[tuple, Callable] = {}
        self.trace_counts: dict[tuple, int] = {}
        self.stats = {
            "submitted": 0,
            "served": 0,
            "batches": 0,
            "lanes": 0,
            "padded": 0,
        }
        self._next_id = 0
        if plan is not None and plan.is_sharded:
            self._mesh_key = tuple(
                (a, plan.mesh.shape[a]) for a in plan.mesh.axis_names
            )
        else:
            self._mesh_key = None
        self._backend_key = type(g).__name__
        # the tuning decisions are trace constants of every compiled
        # executable (strategy, auto_sparse, dense_frac, chunk_blocks) —
        # fold them into the cache key so a recalibrated table recompiles
        # and an unchanged one keeps zero steady-state retraces
        self._tuning_key = plan.tuning_key if plan is not None else None

    # ------------------------------------------------------------------
    def submit(self, op: str, **params) -> QueryHandle:
        """Enqueue one query; returns a handle resolved by ``flush()``."""
        spec = _OPS.get(op)
        if spec is None:
            raise ValueError(f"unknown op {op!r}; serving ops: {sorted(_OPS)}")
        scalars = tuple(
            (k, params.pop(k)) for k in spec.scalar_keys if k in params
        )
        h = QueryHandle(self._next_id, op)
        self._next_id += 1
        self.stats["submitted"] += 1
        self._m_submitted.inc(op=op)
        self._pending.setdefault((op, scalars), []).append((h.id, params))
        return h

    def flush(self) -> dict[QueryHandle, Any]:
        """Drain every bucket; returns {handle: result} for all pending.

        Re-entrant-safe with ``reset_stats``: a reset requested while
        buckets are draining (e.g. from a trace-replay callback) is
        deferred to the end of this flush, so the in-flight buckets'
        lane/served counters land exactly once — in the pre-reset window
        — instead of straddling the reset and double-counting.
        """
        out: dict[QueryHandle, Any] = {}
        pending, self._pending = self._pending, {}
        ctx = (
            use_mesh(self.plan.mesh)
            if self.plan is not None and self.plan.is_sharded
            else contextlib.nullcontext()
        )
        self._in_flush = True
        try:
            with ctx:
                for (op, scalars), reqs in pending.items():
                    for lo in range(0, len(reqs), self.max_batch):
                        chunk = reqs[lo : lo + self.max_batch]
                        out.update(self._run_bucket(op, scalars, chunk))
        finally:
            self._in_flush = False
            if self._reset_deferred:
                self._reset_deferred = False
                self._apply_reset()
        return out

    def serve(self, requests: list[tuple[str, dict]]) -> list[Any]:
        """Convenience: submit all, flush once, return results in order."""
        handles = [self.submit(op, **params) for op, params in requests]
        resolved = self.flush()
        return [resolved[h] for h in handles]

    @property
    def occupancy(self) -> float:
        """Fraction of drained batch columns that carried real queries.

        ``served / lanes`` — the padding waste metric ``table_latency``
        reports: 1.0 means every column was a real request, 0.5 means half
        the batched compute (though NOT half the edge reads — those are
        shared) went to padded lanes.  **NaN before any batch drains** —
        an idle engine has no occupancy, and the old ``1.0`` read as
        perfect utilization on a dashboard; the ``sage_engine_occupancy``
        gauge likewise only materializes once a batch has drained.
        """
        lanes = self.stats["lanes"]
        return self.stats["served"] / lanes if lanes else float("nan")

    def reset_stats(self) -> None:
        """Zero the stats counters AND the engine-scoped registry metrics.

        Rolls every ``stats`` entry back to 0 and resets the attached
        registry's ``sage_engine_*`` families (other families — service,
        PSAM — are untouched), so a bench can measure a warm engine from a
        clean slate without constructing a new one (and losing its
        compiled-executable cache).  ``cost`` and ``trace_counts`` are
        deliberately NOT reset: the PSAM account is a lifetime model and
        the trace counts are the retrace-proof audit trail.

        Safe mid-trace: a reset issued while ``flush`` is draining buckets
        (e.g. from a replay callback observing results) is deferred until
        the flush completes, so the in-flight buckets' ``served``/``lanes``
        counters are either fully inside the old window or fully cleared —
        never split across the reset and double-counted against the
        ``sage_engine_*`` mirror.
        """
        if self._in_flush:
            self._reset_deferred = True
            return
        self._apply_reset()

    def _apply_reset(self) -> None:
        """The actual reset: zero stats + ``sage_engine_`` families, then
        re-count still-pending (un-flushed) submissions into the fresh
        window so ``submitted`` keeps its invariant
        ``submitted == served + pending`` across a reset."""
        for k in self.stats:
            self.stats[k] = 0
        self.registry.reset(prefix="sage_engine_")
        for (op, _), reqs in self._pending.items():
            self.stats["submitted"] += len(reqs)
            self._m_submitted.inc(len(reqs), op=op)

    # ------------------------------------------------------------------
    def _run_bucket(self, op, scalars, chunk) -> dict[QueryHandle, Any]:
        """Pad one (op, scalars) bucket to power-of-two B, run the batched
        algorithm through the compiled cache, account its PSAM cost, and
        slice per-handle results (padding lanes dropped)."""
        spec = _OPS[op]
        k = len(chunk)
        B = _pow2_batch(k, self.max_batch)
        # pad by repeating the last request: padded lanes are real queries
        # whose rows are computed and dropped — batched ops are per-query
        # bit-identical, so they cannot perturb the lanes that matter
        reqs = [r for _, r in chunk] + [chunk[-1][1]] * (B - k)
        args = spec.stack(reqs)
        fn = self._compiled_fn(op, scalars, B, spec)
        res = fn(self.prepared, *args)
        self.stats["batches"] += 1
        self.stats["served"] += k
        self.stats["lanes"] += B
        self.stats["padded"] += B - k
        self._m_batches.inc(op=op)
        self._m_served.inc(k, op=op)
        self._m_lanes.inc(B)
        self._m_padded.inc(B - k)
        self._m_batch_size.observe(float(B), op=op)
        self._m_occupancy.set(self.stats["served"] / self.stats["lanes"])
        self._charge(B, spec.sweeps(res), op=op, scalars=scalars)
        return {
            QueryHandle(hid, op): spec.unbatch(res, i)
            for i, (hid, _) in enumerate(chunk)
        }

    def _compiled_fn(self, op, scalars, B, spec):
        """Fetch or build the jitted executable for one cache key.

        Keyed ``(backend, mesh, tuning, op, B, scalars)``; the traced
        closure bumps ``trace_counts`` so steady-state zero-retrace serving
        is testable.
        """
        key = (self._backend_key, self._mesh_key, self._tuning_key, op, B, scalars)
        fn = self._compiled.get(key)
        if fn is not None:
            self._m_cache_hits.inc(cache="engine")
        else:
            self._m_cache_misses.inc(cache="engine")
            sc = dict(scalars)
            plan = self.plan

            def traced(g, *args):
                # executes only when jax traces: the counter IS the retrace
                # count for this (backend, mesh, op, B) key
                self.trace_counts[key] = self.trace_counts.get(key, 0) + 1
                return spec.run(g, plan, args, sc)

            fn = jax.jit(traced)
            self._compiled[key] = fn
        return fn

    def _streamed_accounting(self, op: str, scalars: tuple) -> bool:
        """True when the drained bucket's rounds really ran the streamed
        frontier-sparse path AND its read model applies.

        Three conditions, mirroring the execution dispatch: the plan's
        strategy is ``sparse_streamed`` and the bucket's ``mode`` scalar
        doesn't override it (explicit mode wins in ``resolve_mode``); the
        backend actually streams (``CompressedCSR``, not exception-dense —
        others fall back to plain sparse and read per lane); and the op is
        BFS, the one traversal whose frontiers are monotone — every vertex
        enters a lane's frontier at most once, so each block streams at
        most ``min(B, sweeps)`` times across the whole drain (the batched
        rounds stream the UNION of the lanes' live blocks, and divergent
        lanes can re-include a block in different rounds).  wBFS re-buckets
        and PPR revisits, so their streamed volume is not bounded this way;
        they keep the dense per-sweep charge as a safe over-estimate.
        """
        if self.plan is None or self.plan.strategy != "sparse_streamed":
            return False
        if op != "bfs":
            return False
        if dict(scalars).get("mode", "auto") not in ("auto", "sparse_streamed"):
            return False
        from ..core.compressed import CompressedCSR, exception_dense

        return isinstance(self.graph, CompressedCSR) and not exception_dense(
            self.graph
        )

    def _charge(self, B: int, sweeps: int, op: str = "", scalars: tuple = ()):
        """PSAM model of one drained batch: ``sweeps`` rounds, each reading
        the edge blocks once for all B lanes (÷B vs sequential serving).

        When ``_streamed_accounting`` certifies the bucket ran the
        frontier-sparse chunked kernel on monotone frontiers, the analytic
        per-round charge is the ``min(B, sweeps) · NB / sweeps`` live share
        (``charge_edgemap_sparse``): per lane each block streams at most
        once, so the whole drained batch costs about ``min(B, sweeps)``
        dense sweeps' edge bytes instead of sweeps × NB (the same
        analytic-estimate discipline as the ``sweeps`` counts themselves;
        at B=1 this is one dense sweep total).
        """
        shards = self.plan.num_shards if self._mesh_key is not None else 1
        sweeps = max(sweeps, 1)
        if hasattr(self.graph, "overlay_small_words"):
            # delta overlay: base blocks at their NVRAM footprint, patch
            # blocks + tombstone words as DRAM small-ops — never the
            # streamed discount (the overlay takes the generic sparse path)
            for _ in range(sweeps):
                self.cost.charge_edgemap_overlay(
                    self.graph, batch=B, num_shards=shards
                )
            return
        if self._streamed_accounting(op, scalars):
            live = -(-self.graph.num_blocks * min(B, sweeps) // sweeps)
            for _ in range(sweeps):
                self.cost.charge_edgemap_sparse(
                    self.graph, live, batch=B, num_shards=shards
                )
            return
        for _ in range(sweeps):
            self.cost.charge_edgemap_batched(self.graph, B, num_shards=shards)
