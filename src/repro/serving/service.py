"""Always-on serving service — deadline-driven drain loop over the engine.

The :class:`~repro.serving.engine.QueryEngine` coalesces and batches, but a
caller still has to invoke ``flush()`` by hand — which no live deployment
does.  :class:`ServingService` is the missing control loop, in virtual
time: requests arrive with a per-request SLO budget, queue until either
trigger fires, and drain through shared NVRAM edge sweeps:

    submit(op, tenant, now) ──► admission control (per-tenant PSAM ledger)
         │                           │ reject / defer when over budget
         ▼                           ▼
       queue ──────────── tick(now) drain loop ──────────► completed
         │        flush when EITHER fires first:              tickets
         │          · deadline:  now ≥ arrival + slo
         │          · depth:     len(queue) ≥ depth_trigger
         ▼
       cross-op cohorts (bfs+wbfs fused, ≤ max_batch lanes)
         └─ quantum of shared sweeps ─ repack drained lanes out ─ repeat

Three properties the engine alone cannot provide:

* **Deadline-driven flushing** — a request is never held past its SLO
  budget waiting for a full batch; a deadline flush drains the WHOLE
  queue, so later arrivals ride the same sweep for free.
* **Cross-op batching** — BFS and wBFS lanes share one edge sweep per
  round (``traversal_cohort_rounds``): both are int32 min-monoid
  traversals, and ``map_lanes`` gives each lane its own per-edge map
  bit-exactly.  Non-traversal ops (PPR, PageRank iterations) drain
  through the wrapped engine in the same flush.
* **Early-exit accounting** — per-lane round counts stop charging a lane
  the round its frontier drains, and between quanta the cohort repacks to
  a narrower power-of-two width so a finished query also stops occupying
  a batch column.  Per-lane results stay bit-identical to single-query
  runs (the locked parity contract).

Admission control prices requests in the PSAM's scarce resource — NVRAM
edge-read words — against per-tenant token buckets
(:class:`repro.core.TenantLedger`): an estimate is reserved at submit and
settled against the drain's actual per-lane attribution, so tenants pay
for what their queries actually read, not for what the scheduler guessed.

**Mutability** (``repro.delta``): construct the service over a
:class:`~repro.delta.DeltaOverlay` and it also serves edits.
``submit_edit`` admits or rejects (never defers — deferral would reorder
the edit log) one insert/delete, priced at the amortized compaction
estimate; admitted edits apply at the next tick/drain boundary — queries
in flight within a flush see one consistent snapshot — and after each
flush the :class:`~repro.tuning.OverlayTrigger` decides whether the
accumulated overlay surcharge justifies folding the overlay into a fresh
compressed base (``repro.delta.compact`` — the subsystem's only NVRAM
write, persisted atomically when ``config.ckpt_dir`` is set).
"""
from __future__ import annotations

import contextlib
import dataclasses
import time
from typing import Any, Callable

import jax
import numpy as np

from ..algorithms.traversal import (
    traversal_cohort_init,
    traversal_cohort_rounds,
)
from ..compat import use_mesh
from ..core.psam import TenantLedgers, edgemap_round_read_words
from ..delta import DeltaOverlay, compact
from ..delta import compact_write_words as _compact_write_words
from ..obs import DEFAULT_LATENCY_BUCKETS, get_registry
from ..tuning.defaults import DEFAULT_EDITS_PER_COMPACT, DEFAULT_EST_ROUNDS
from ..tuning.overlay import constants_overlay_trigger
from .engine import QueryEngine, _pow2_batch

TRAVERSAL_OPS = ("bfs", "wbfs")


@dataclasses.dataclass(frozen=True)
class ServiceConfig:
    """Tuning knobs for one :class:`ServingService`.

    ``slo`` is the per-request latency budget in virtual-time units —
    ``deadline = arrival + slo`` and the drain loop flushes no later than
    that.  ``depth_trigger`` (default ``max_batch``) flushes early once
    the queue can fill a batch, so a saturated service never waits for a
    deadline.  ``round_quantum`` bounds how many fused rounds run per
    jitted call — smaller quanta repack drained lanes out sooner, at more
    dispatch overhead.  ``admission`` is what happens when a tenant's
    ledger cannot cover a request's estimated edge reads: ``"reject"``
    fails it immediately, ``"defer"`` parks it until refills cover it
    (its SLO clock restarts at admission).  ``budgets`` maps tenant name
    → ``(capacity_words, refill_rate)``; unnamed tenants are unlimited.

    ``max_batch`` (default ``None``) resolves like the engine's: the
    plan's measured tuning decision, else the static default — the
    resolved value is ``service.max_batch``.  ``est_rounds`` sizes the
    COLD admission estimate: a request whose (op, backend) pair has never
    drained is priced at ``est_rounds`` shared sweeps split across
    ``max_batch`` lanes.  Once drains complete, the service prices each
    op from its own observed round counts — an EWMA (weight
    ``ewma_alpha`` on the newest drain) settled from the early-exit
    accounting actuals — so admission reflects what this workload's
    queries really read, per op and backend, not one flat guess.

    The mutability knobs only matter for a DeltaOverlay-backed service:
    ``compact_trigger`` is the :class:`repro.tuning.OverlayTrigger`
    deciding when to fold the overlay into a fresh compressed base
    (default: the constants trigger); ``ckpt_dir`` (when set) persists
    each compacted base atomically via ``repro.delta.save_compacted``,
    keeping the newest ``compact_keep`` step directories.
    """

    slo: float = 0.05
    max_batch: int | None = None
    depth_trigger: int | None = None
    round_quantum: int = 4
    admission: str = "reject"
    budgets: dict | None = None
    mode: str = "auto"
    est_rounds: int = DEFAULT_EST_ROUNDS
    ewma_alpha: float = 0.25
    compact_trigger: Any = None
    ckpt_dir: str | None = None
    compact_keep: int = 3

    def __post_init__(self):
        if self.admission not in ("reject", "defer"):
            raise ValueError(f"admission must be 'reject'|'defer', got {self.admission!r}")


@dataclasses.dataclass
class ServingTicket:
    """One submitted request's lifecycle record.

    ``status`` walks ``queued → done`` (or ``rejected``, or
    ``deferred → queued → done``).  ``deadline`` is the flush-by time;
    ``finished_at`` the virtual time of the tick that drained it.
    ``rounds`` / ``words`` are the early-exit accounting actuals: rounds
    this lane was active, and its attributed share of the edge-read words
    those rounds streamed — what the tenant ledger settles against.
    """

    id: int
    op: str
    tenant: str
    params: dict
    arrival: float
    deadline: float
    status: str = "queued"
    result: Any = None
    finished_at: float | None = None
    rounds: int = 0
    words: float = 0.0
    est_words: float = 0.0


class ServingService:
    """Deadline-driven drain loop with admission control over a QueryEngine.

    Parameters
    ----------
    g      : CSRGraph | CompressedCSR — the read-only large memory — or a
             :class:`~repro.delta.DeltaOverlay` for a mutable service:
             queries run over ``overlay.snapshot()`` (base ∪ delta,
             bit-identical to a rebuild) and ``submit_edit`` /
             ``force_compact`` become available
    plan   : ExecutionPlan | None — execution target, as for the engine
    config : ServiceConfig | None — SLO, triggers, budgets (default config
             if omitted)

    The service runs in **virtual time**: callers stamp ``submit`` and
    ``tick`` with ``now`` and the service never looks at a wall clock —
    which is what makes trace replay (``benchmarks/table_latency``) and
    the deadline edge-case tests deterministic.  ``tick(now)`` is the
    drain loop body: refill ledgers, re-admit deferred work, flush if the
    deadline or depth trigger fired, return the completed tickets.

    ``stats`` extends the engine's counters with trigger attribution
    (``deadline_flushes`` / ``depth_flushes``) and round-weighted lane
    occupancy; ``cost`` is the engine's PSAM account — cohort rounds are
    charged there too, so one object models the whole service.

    ``registry`` (optional) is where the service reports: per-(op, tenant)
    end-to-end latency histograms (``sage_service_latency_seconds`` =
    queue wait in virtual time + drain wall time), queue depth, flush
    causes, admission outcomes, occupancy, and the model-vs-reality drift
    gauge ``sage_psam_drift_words_per_second`` (modeled edge-read words
    charged during a flush ÷ the flush's wall seconds — falling drift at
    fixed workload means the analytic PSAM charge is overpricing reads).
    Defaults to the process-global registry; inject
    ``repro.obs.noop_registry()`` and the service takes no wall-clock
    readings at all.
    """

    def __init__(
        self, g, *, plan=None, config: ServiceConfig | None = None, registry=None
    ):
        self.config = config or ServiceConfig()
        self.registry = registry if registry is not None else get_registry()
        self.overlay = g if isinstance(g, DeltaOverlay) else None
        if self.overlay is not None:
            g = self.overlay.snapshot()
        self.engine = QueryEngine(
            g, plan=plan, max_batch=self.config.max_batch, registry=self.registry
        )
        # resolved batch width (explicit config > plan tuning > default) —
        # every width decision below uses this, never the raw config field
        self.max_batch = self.engine.max_batch
        self.plan = plan
        # per-(op, backend) observed rounds-per-request (EWMA, settled at
        # drain) — the admission estimate once warm; est_rounds until then
        self.observed_rounds: dict[tuple, float] = {}
        self.ledgers = TenantLedgers(self.config.budgets)
        if plan is not None:
            self._round_words = plan.edge_read_words_per_round(self.engine.prepared)
        else:
            self._round_words = edgemap_round_read_words(g)
        self._queue: list[ServingTicket] = []
        self._deferred: list[ServingTicket] = []
        self._cohort_compiled: dict[tuple, Callable] = {}
        self.trace_counts: dict[tuple, int] = {}
        self._next_id = 0
        # mutability state (inert unless overlay-backed): pending admitted
        # edits, their (tenant, reserved-estimate) ledger entries (settled
        # pro-rata at compaction), the next checkpoint step, the PSAM
        # read-words mark the trigger measures sweeps against, and the
        # observed edits-per-compaction EWMA that amortizes edit pricing
        self.compact_trigger = self.config.compact_trigger or (
            constants_overlay_trigger() if self.overlay is not None else None
        )
        self._edits: list[tuple] = []
        self._edit_ledger: list[tuple[str, float]] = []
        self._compact_step = 0
        self._reads_at_compact = self.engine.cost.large_reads
        self._edits_per_compact = float(DEFAULT_EDITS_PER_COMPACT)
        self.stats = {
            "submitted": 0,
            "admitted": 0,
            "rejected": 0,
            "deferred": 0,
            "served": 0,
            "ticks": 0,
            "flushes": 0,
            "deadline_flushes": 0,
            "depth_flushes": 0,
            "forced_flushes": 0,
            "cohort_rounds": 0,
            "repacks": 0,
            "lane_rounds_total": 0,
            "active_lane_rounds": 0,
            "edits_submitted": 0,
            "edits_applied": 0,
            "edits_rejected": 0,
            "compactions": 0,
        }
        reg = self.registry
        self._m_submitted = reg.counter(
            "sage_service_submitted_total", "requests submitted",
            labels=("op", "tenant"),
        )
        self._m_admission = reg.counter(
            "sage_service_admission_total",
            "admission outcomes (admitted includes deferred re-admissions)",
            labels=("outcome", "tenant"),
        )
        self._m_flushes = reg.counter(
            "sage_service_flushes_total", "queue flushes by trigger cause",
            labels=("cause",),
        )
        self._m_latency = reg.histogram(
            "sage_service_latency_seconds",
            "end-to-end request latency: virtual queue wait + drain wall time",
            labels=("op", "tenant"), buckets=DEFAULT_LATENCY_BUCKETS,
        )
        self._m_flush_seconds = reg.histogram(
            "sage_service_flush_seconds", "wall seconds per queue flush",
            buckets=DEFAULT_LATENCY_BUCKETS,
        )
        self._m_queue_depth = reg.gauge(
            "sage_service_queue_depth", "admitted, undrained requests"
        )
        self._m_deferred_depth = reg.gauge(
            "sage_service_deferred_depth", "deferred (unadmitted) requests"
        )
        self._m_occupancy = reg.gauge(
            "sage_service_occupancy",
            "round-weighted fraction of cohort lane-slots doing real work",
        )
        self._m_drift = reg.gauge(
            "sage_psam_drift_words_per_second",
            "modeled edge-read words charged per wall second of the last flush",
        )
        self._m_edits = reg.counter(
            "sage_delta_edits_total", "edits applied to the overlay",
            labels=("kind",),
        )
        self._m_patch_edges = reg.gauge(
            "sage_delta_patch_edges", "live inserted edges in the DRAM overlay"
        )
        self._m_tombstones = reg.gauge(
            "sage_delta_tombstones", "base edges masked dead by the overlay"
        )
        self._m_overlay_words = reg.gauge(
            "sage_delta_overlay_small_words",
            "per-sweep DRAM small-op surcharge of the current overlay",
        )

    # ------------------------------------------------------------------
    @property
    def cost(self):
        """The PSAM cost account (shared with the wrapped engine)."""
        return self.engine.cost

    @property
    def depth_trigger(self) -> int:
        """Queue depth that triggers an immediate flush."""
        return self.config.depth_trigger or self.max_batch

    @property
    def queue_depth(self) -> int:
        """Currently queued (admitted, undrained) requests."""
        return len(self._queue)

    @property
    def occupancy(self) -> float:
        """Round-weighted fraction of cohort lane-slots doing real work.

        Each fused round contributes B lane-slots (the packed width) of
        which the active lanes did work — drained-but-not-yet-repacked
        lanes and padding lanes count as waste.  **NaN before any cohort
        round runs** — an idle service has no occupancy to report (the
        old 1.0 read as perfect utilization on a dashboard).
        This is the metric ``round_quantum`` tunes: smaller quanta repack
        sooner and push occupancy up.
        """
        total = self.stats["lane_rounds_total"]
        return self.stats["active_lane_rounds"] / total if total else float("nan")

    # ------------------------------------------------------------------
    def submit(self, op: str, *, tenant: str = "default", now: float = 0.0, **params):
        """Submit one request at virtual time ``now``; returns its ticket.

        Admission control runs here: the request's edge reads are
        estimated from this service's observed rounds for its (op,
        backend) pair — the flat ``est_rounds`` constant while cold —
        split across ``max_batch`` lanes, and if the tenant's token
        bucket cannot cover the estimate the ticket is rejected or
        deferred per ``config.admission``.  Admitted tickets
        reserve the estimate — settled against actuals when drained — and
        get ``deadline = now + slo``.
        """
        self.stats["submitted"] += 1
        self._m_submitted.inc(op=op, tenant=tenant)
        t = ServingTicket(
            id=self._next_id,
            op=op,
            tenant=tenant,
            params=params,
            arrival=now,
            deadline=now + self.config.slo,
            est_words=self._estimate_words(op),
        )
        self._next_id += 1
        self.ledgers.refill(now)
        led = self.ledgers.ledger(tenant)
        if led.can_admit(t.est_words):
            led.reserve(t.est_words)
            t.status = "queued"
            self._queue.append(t)
            self.stats["admitted"] += 1
            self._m_admission.inc(outcome="admitted", tenant=tenant)
        elif self.config.admission == "defer":
            t.status = "deferred"
            self._deferred.append(t)
            self.stats["deferred"] += 1
            self._m_admission.inc(outcome="deferred", tenant=tenant)
        else:
            t.status = "rejected"
            self.stats["rejected"] += 1
            self._m_admission.inc(outcome="rejected", tenant=tenant)
        self._m_queue_depth.set(float(len(self._queue)))
        self._m_deferred_depth.set(float(len(self._deferred)))
        return t

    def submit_edit(
        self, kind: str, u: int, v: int, w: float = 1.0,
        *, tenant: str = "default", now: float = 0.0,
    ) -> bool:
        """Submit one graph edit (``kind`` ∈ {"insert", "delete"}) at
        virtual time ``now``; returns True iff admitted.

        Edits are admit-or-reject only — NEVER deferred, regardless of
        ``config.admission``: a deferred edit would re-enter the log
        after later edits and reorder the upsert semantics the
        differential harness locks.  The admission price is the
        amortized compaction estimate — ``ω × compact_write_words``
        split over the observed edits-per-compaction (EWMA; the
        ``DEFAULT_EDITS_PER_COMPACT`` horizon while cold) — reserved
        against the tenant's ledger and settled pro-rata against the
        actual ω write when the overlay compacts.  Admitted edits are
        buffered and applied at the next tick/drain boundary, so every
        query in a flush sees one consistent snapshot.
        """
        if self.overlay is None:
            raise TypeError(
                "submit_edit requires a DeltaOverlay-backed service "
                "(construct with ServingService(DeltaOverlay(base), ...))"
            )
        if kind not in ("insert", "delete"):
            raise ValueError(f"kind must be 'insert'|'delete', got {kind!r}")
        self.stats["edits_submitted"] += 1
        est = self._estimate_edit_words()
        self.ledgers.refill(now)
        led = self.ledgers.ledger(tenant)
        if not led.can_admit(est):
            self.stats["edits_rejected"] += 1
            self._m_admission.inc(outcome="edit_rejected", tenant=tenant)
            return False
        led.reserve(est)
        self._edit_ledger.append((tenant, est))
        self._edits.append(
            ("insert", int(u), int(v), float(w)) if kind == "insert"
            else ("delete", int(u), int(v))
        )
        self._m_admission.inc(outcome="edit_admitted", tenant=tenant)
        return True

    def force_compact(self, now: float = 0.0):
        """Apply pending edits, then compact the overlay unconditionally
        (ignoring the trigger); returns the new ``CompressedCSR`` base,
        or None when there is no overlay to fold.  The persistence /
        ledger-settlement path is identical to a triggered compaction."""
        if self.overlay is None:
            return None
        self._apply_edits()
        return self._compact(now)

    def tick(self, now: float) -> list[ServingTicket]:
        """One drain-loop iteration at virtual time ``now``.

        Applies buffered edits (the graph steps forward BETWEEN flushes,
        never inside one), refills tenant buckets, re-admits deferred
        work that now fits, and flushes the WHOLE queue when either
        trigger fires — queue depth ≥ ``depth_trigger``, or the earliest
        deadline is due (so a deadline flush pulls later arrivals into
        the same shared sweeps).  Returns the tickets completed by this
        tick (empty on a no-op tick: an empty queue costs nothing).
        """
        self.stats["ticks"] += 1
        self._apply_edits()
        self.ledgers.refill(now)
        self._readmit(now)
        if not self._queue:
            return []
        if len(self._queue) >= self.depth_trigger:
            self.stats["depth_flushes"] += 1
            self._m_flushes.inc(cause="depth")
        elif min(t.deadline for t in self._queue) <= now:
            self.stats["deadline_flushes"] += 1
            self._m_flushes.inc(cause="deadline")
        else:
            return []
        return self._flush(now)

    def drain(self, now: float) -> list[ServingTicket]:
        """Force-flush everything queued, ignoring both triggers."""
        self._apply_edits()
        self.ledgers.refill(now)
        self._readmit(now)
        if not self._queue:
            return []
        self.stats["forced_flushes"] += 1
        self._m_flushes.inc(cause="forced")
        return self._flush(now)

    def next_deadline(self) -> float | None:
        """Earliest queued deadline — when the next tick MUST run; None if
        the queue is empty (trace replay uses this to advance the clock)."""
        return min((t.deadline for t in self._queue), default=None)

    # ------------------------------------------------------------------
    def _estimate_words(self, op: str) -> float:
        """Admission-time price of one ``op`` request: its observed
        rounds-per-request (EWMA over this service's drains of the same
        (op, backend) pair) worth of shared sweeps split across a full
        batch — the flat ``est_rounds`` constant only while that pair is
        still cold."""
        rounds = self.observed_rounds.get(
            (op, self.engine._backend_key), float(self.config.est_rounds)
        )
        return self._round_words * rounds / self.max_batch

    def _observe_rounds(self, t: ServingTicket) -> None:
        """Fold one drained ticket's actual round count into the estimate
        for its (op, backend) pair — EWMA so the estimate tracks workload
        drift without one outlier query repricing admission."""
        key = (t.op, self.engine._backend_key)
        obs = float(max(t.rounds, 1))
        prev = self.observed_rounds.get(key)
        a = self.config.ewma_alpha
        self.observed_rounds[key] = obs if prev is None else (1 - a) * prev + a * obs

    def _readmit(self, now: float) -> None:
        """Move deferred tickets whose tenants can now afford them back
        into the queue (FIFO); their SLO clock restarts at admission."""
        still = []
        for t in self._deferred:
            led = self.ledgers.ledger(t.tenant)
            if led.can_admit(t.est_words):
                led.reserve(t.est_words)
                t.status = "queued"
                t.deadline = now + self.config.slo
                self._queue.append(t)
                self.stats["admitted"] += 1
                self._m_admission.inc(outcome="admitted", tenant=t.tenant)
            else:
                still.append(t)
        self._deferred = still
        self._m_queue_depth.set(float(len(self._queue)))
        self._m_deferred_depth.set(float(len(self._deferred)))

    def _estimate_edit_words(self) -> float:
        """Admission-time price of one edit: the next compaction's ω
        write amortized over the observed edits-per-compaction count
        (EWMA; the static horizon while no compaction has run)."""
        return (
            self.cost.omega
            * float(self.engine.graph.compact_write_words)
            / max(self._edits_per_compact, 1.0)
        )

    def _apply_edits(self) -> None:
        """Fold buffered edits into the overlay and step the served graph
        to the new snapshot.  Runs only at tick/drain boundaries, so a
        flush's queries all see the same base ∪ delta; snapshot patch
        capacity grows in powers of two, so stepping retraces compiled
        executables only at doubling boundaries."""
        if self.overlay is None or not self._edits:
            return
        edits, self._edits = self._edits, []
        self.overlay.apply(edits)
        self.stats["edits_applied"] += len(edits)
        for e in edits:
            self._m_edits.inc(kind=e[0])
        self._set_graph(self.overlay.snapshot())

    def _set_graph(self, dg) -> None:
        """Point the wrapped engine (and the per-round word model) at a
        new snapshot.  The engine's compiled-executable and cohort caches
        key on the backend NAME ("DeltaGraph"), which is stable across
        snapshots — same-shape steps reuse warm executables."""
        eng = self.engine
        eng.graph = dg
        eng.prepared = dg if self.plan is None else self.plan.prepare(dg)
        if self.plan is not None:
            self._round_words = self.plan.edge_read_words_per_round(eng.prepared)
        else:
            self._round_words = edgemap_round_read_words(dg)
        if self.overlay is not None:
            self._m_patch_edges.set(float(self.overlay.num_patch_edges))
            self._m_tombstones.set(float(self.overlay.num_tombstones))
            self._m_overlay_words.set(float(dg.overlay_small_words))

    def _charge_round(self, B: int, shards: int) -> None:
        """One cohort round's PSAM charge, overlay-aware: a DeltaGraph
        prices base blocks at their NVRAM footprint plus the overlay's
        DRAM small-op surcharge; plain backends keep the batched dense
        charge."""
        g = self.engine.graph
        if hasattr(g, "overlay_small_words"):
            self.engine.cost.charge_edgemap_overlay(g, batch=B, num_shards=shards)
        else:
            self.engine.cost.charge_edgemap_batched(g, B, num_shards=shards)

    def _maybe_compact(self, now: float) -> None:
        """Post-flush compaction check: hand the trigger the sweeps of
        edge reads issued since the last compaction (derived from the
        PSAM account — no extra bookkeeping) and fold the overlay when
        the surcharge has paid for the ω write.  A clean overlay (no
        patches, no tombstones) never compacts."""
        if self.overlay is None or self.compact_trigger is None:
            return
        if self.overlay.num_patch_edges == 0 and self.overlay.num_tombstones == 0:
            return
        sweeps = (self.cost.large_reads - self._reads_at_compact) / max(
            self._round_words, 1.0
        )
        if self.compact_trigger.should_compact(
            self.engine.graph, sweeps_since_compact=sweeps, omega=self.cost.omega
        ):
            self._compact(now)

    def _compact(self, now: float):
        """Fold the overlay into a fresh CompressedCSR base — the ONLY
        NVRAM write in the mutable path.  Charges ``ω × write_words`` to
        the PSAM account, persists the step atomically when configured,
        settles the edit ledger pro-rata against the actual write, folds
        the realized edits-per-compaction into the admission EWMA, and
        rebases the overlay (empty) on the new graph."""
        del now
        c = compact(
            self.overlay,
            cost=self.cost,
            ckpt_dir=self.config.ckpt_dir,
            step=self._compact_step,
            keep=self.config.compact_keep,
            registry=self.registry,
        )
        self._compact_step += 1
        self.stats["compactions"] += 1
        ledger, self._edit_ledger = self._edit_ledger, []
        if ledger:
            actual = self.cost.omega * float(_compact_write_words(c))
            share = actual / len(ledger)
            for tenant, est in ledger:
                self.ledgers.ledger(tenant).settle(est, share)
            a = self.config.ewma_alpha
            self._edits_per_compact = (
                (1 - a) * self._edits_per_compact + a * float(len(ledger))
            )
        self.overlay = DeltaOverlay(c)
        self._set_graph(self.overlay.snapshot())
        self._reads_at_compact = self.cost.large_reads
        return c

    def _flush(self, now: float) -> list[ServingTicket]:
        """Drain the full queue: traversal tickets fuse into ≤max_batch
        cohorts (FIFO), the rest delegate to the engine — one flush, one
        mesh context, every ticket settled against its tenant ledger."""
        self.stats["flushes"] += 1
        queue, self._queue = self._queue, []
        trav = [t for t in queue if t.op in TRAVERSAL_OPS]
        other = [t for t in queue if t.op not in TRAVERSAL_OPS]
        done: list[ServingTicket] = []
        ctx = (
            use_mesh(self.plan.mesh)
            if self.plan is not None and self.plan.is_sharded
            else contextlib.nullcontext()
        )
        # wall-clock + modeled-words readings only when a live registry is
        # attached — noop mode flushes without touching the clock at all
        observing = self.registry.enabled
        if observing:
            words_before = self.cost.large_reads
            t0 = time.perf_counter()
        with ctx:
            for lo in range(0, len(trav), self.max_batch):
                done += self._drain_cohort(trav[lo : lo + self.max_batch], now)
            if other:
                done += self._drain_engine_ops(other, now)
        if observing:
            wall = time.perf_counter() - t0
            self._m_flush_seconds.observe(wall)
            if wall > 0.0:
                # the drift gauge: analytic PSAM words ÷ wall seconds for
                # THIS flush — model throughput vs reality, queryable live
                self._m_drift.set((self.cost.large_reads - words_before) / wall)
            for t in done:
                self._m_latency.observe(
                    max(now - t.arrival, 0.0) + wall, op=t.op, tenant=t.tenant
                )
            self._m_queue_depth.set(float(len(self._queue)))
            self._m_occupancy.set(self.occupancy)
        for t in done:
            self.ledgers.ledger(t.tenant).settle(t.est_words, t.words)
            self._observe_rounds(t)
        self.stats["served"] += len(done)
        self._maybe_compact(now)
        return done

    # ------------------------------------------------------------------
    def _drain_cohort(self, tickets: list[ServingTicket], now: float):
        """Run one fused BFS+wBFS cohort to completion.

        Lanes start at the padded power-of-two width (pads are inert
        ``src=-1`` lanes — never active, never charged); each quantum of
        shared rounds is one jitted call, after which drained lanes'
        results are extracted and, when a narrower power of two holds the
        survivors, the state repacks down so finished queries stop
        occupying batch columns.  Edge reads are charged once per
        executed round and attributed equally across that round's active
        lanes — the early-exit accounting: a drained lane is charged for
        exactly the rounds it ran.
        """
        k = len(tickets)
        B = _pow2_batch(k, self.max_batch)
        lane_tickets: list[ServingTicket | None] = list(tickets) + [None] * (B - k)
        ops = [t.op for t in tickets] + ["bfs"] * (B - k)
        srcs = [int(t.params["src"]) for t in tickets] + [-1] * (B - k)
        state, weighted = traversal_cohort_init(self.engine.graph, ops, srcs)
        shards = (
            self.plan.num_shards
            if self.plan is not None and self.plan.is_sharded
            else 1
        )
        done: list[ServingTicket] = []
        while True:
            fn = self._cohort_fn(B, weighted)
            state, lane_rounds, active = fn(self.engine.prepared, state)
            lane_rounds = np.asarray(lane_rounds)
            active_np = np.asarray(active)
            rounds_exec = int(lane_rounds.max(initial=0))
            # PSAM: each executed round streams the edge blocks once for
            # the whole cohort; its words split across that round's active
            # lanes (activity is prefix-monotone, so round r's active set
            # is exactly the lanes with lane_rounds > r).
            for r in range(rounds_exec):
                act = np.flatnonzero(lane_rounds > r)
                self._charge_round(B, shards)
                share = self._round_words / len(act)
                for i in act:
                    lane_tickets[i].words += share
            for i, t in enumerate(lane_tickets):
                if t is not None:
                    t.rounds += int(lane_rounds[i])
            self.stats["cohort_rounds"] += rounds_exec
            self.stats["lane_rounds_total"] += B * rounds_exec
            self.stats["active_lane_rounds"] += int(lane_rounds.sum())
            # extract lanes that drained inside this quantum
            for i in range(B):
                t = lane_tickets[i]
                if t is not None and not active_np[i]:
                    t.result = self._unbatch(state, weighted, i)
                    t.status = "done"
                    t.finished_at = now
                    done.append(t)
                    lane_tickets[i] = None
            if not active_np.any():
                return done
            act_idx = np.flatnonzero(active_np)
            newB = _pow2_batch(len(act_idx), self.max_batch)
            if newB < B:
                # repack: survivors first, drained rows as inert padding
                pads = np.flatnonzero(~active_np)[: newB - len(act_idx)]
                idx = np.concatenate([act_idx, pads]).astype(np.int32)
                state = {
                    key: (v if key == "rnd" else v[idx]) for key, v in state.items()
                }
                weighted = tuple(weighted[i] for i in idx)
                lane_tickets = [lane_tickets[i] for i in idx]
                B = newB
                self.stats["repacks"] += 1

    def _unbatch(self, state, weighted, i: int):
        """Lane i's result in the same shape the engine serves: BFS →
        (parents, levels), wBFS → dist."""
        if weighted[i]:
            return state["dist"][i]
        return state["parents"][i], state["levels"][i]

    def _cohort_fn(self, B: int, weighted: tuple):
        """Fetch or build the jitted cohort step for one lane layout.

        Keyed like the engine's cache — (backend, mesh, B, weighted lane
        pattern, quantum, mode) — with the same observable
        ``trace_counts``, so steady-state serving provably stops
        retracing once the handful of layouts it sees are warm.
        """
        key = (
            self.engine._backend_key,
            self.engine._mesh_key,
            B,
            weighted,
            self.config.round_quantum,
            self.config.mode,
        )
        fn = self._cohort_compiled.get(key)
        if fn is None:
            plan, mode, quantum = self.plan, self.config.mode, self.config.round_quantum

            def traced(g, state):
                self.trace_counts[key] = self.trace_counts.get(key, 0) + 1
                return traversal_cohort_rounds(
                    g, state, weighted, quantum=quantum, mode=mode, plan=plan
                )

            fn = jax.jit(traced)
            self._cohort_compiled[key] = fn
        return fn

    def _drain_engine_ops(self, tickets: list[ServingTicket], now: float):
        """Delegate non-traversal tickets to the wrapped engine in one
        flush; the flush's PSAM edge-read delta is attributed equally
        across its tickets (per-op sweep splits are not observable from
        the batched results, so equal shares keep the total conserved).
        Each ticket's ``rounds`` is the batch-amortized sweep count its
        word share corresponds to (``words ÷ (round_words / max_batch)``),
        so the per-op EWMA admission estimate prices engine ops in the
        same currency as cohort lanes."""
        before = self.engine.cost.large_reads
        handles = [self.engine.submit(t.op, **t.params) for t in tickets]
        results = self.engine.flush()
        share = (self.engine.cost.large_reads - before) / len(tickets)
        lane_words = self._round_words / self.max_batch
        for h, t in zip(handles, tickets):
            t.result = results[h]
            t.status = "done"
            t.finished_at = now
            t.words += share
            t.rounds += max(1, round(share / lane_words)) if lane_words else 1
        return tickets
