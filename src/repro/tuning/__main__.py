"""CLI: ``python -m repro.tuning [--quick] [--out PATH]``.

Runs :func:`repro.tuning.calibrate` on this host and writes the resulting
TuningTable JSON.  This is the nightly-CI entry point (``calibrate --quick``
+ artifact upload) and the way to regenerate the shipped
``default_table.json``.
"""
from __future__ import annotations

import argparse
import time

from .measure import calibrate
from .table import _DEFAULT_PATH


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.tuning", description=__doc__.splitlines()[0]
    )
    ap.add_argument("--quick", action="store_true", help="small grids (CI)")
    ap.add_argument("--out", default="tuning_table.json", help="output path")
    ap.add_argument("--n", type=int, default=2048, help="calibration |V|")
    ap.add_argument("--m", type=int, default=16384, help="calibration |E|")
    ap.add_argument("--reps", type=int, default=3, help="timing repetitions")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument(
        "--shards", action="store_true", help="include the mesh shard sweep"
    )
    ap.add_argument(
        "--default",
        action="store_true",
        help=f"write to the shipped default table path ({_DEFAULT_PATH})",
    )
    args = ap.parse_args(argv)

    t0 = time.perf_counter()
    table = calibrate(
        n=args.n,
        m=args.m,
        quick=args.quick,
        seed=args.seed,
        reps=args.reps,
        shards=args.shards,
    )
    table.to_dict()["created"] = time.strftime("%Y-%m-%dT%H:%M:%SZ", time.gmtime())
    out = _DEFAULT_PATH if args.default else args.out
    table.save(out)
    secs = time.perf_counter() - t0

    print(f"calibrated in {secs:.1f}s on {table.host_key} -> {out}")
    for backend in table.backends():
        d = table.decide(backend)
        print(
            f"  {backend}: crossover d*={d.crossover_density:.4g} "
            f"(dense_frac={d.dense_frac:.3g}), chunk_blocks={d.chunk_blocks}, "
            f"auto_sparse={d.auto_sparse}, max_batch={d.max_batch}, "
            f"tile_blocks={d.tile_blocks}"
        )
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
