"""Compaction policy for delta overlays — when does the ω write pay off?

A :class:`repro.delta.DeltaGraph` taxes every edge sweep with a DRAM
small-op surcharge (patch blocks + tombstone words,
``overlay_small_words``); folding it away costs one batched NVRAM write
(``ω × compact_write_words``).  The break-even rule is the classic
log-structured one — compact once the accumulated surcharge has already
paid for the write:

    cost_scale × overlay_small_words × sweeps  ≥  hysteresis × ω × W

:class:`OverlayTrigger` is that inequality as a frozen policy object.
``constants_overlay_trigger`` builds it from the static defaults
(``cost_scale = 1``: one overlay small-op word priced at one NVRAM read
word — the PSAM's unit-cost assumption).  ``measured_overlay_trigger``
replaces the cost scale with a timed ratio on THIS host: how much slower
a dense sweep over the overlay actually is than over its base, per
overlay word — so a host where DRAM patch gathers are nearly free
compacts lazily, and one where they dominate compacts eagerly.  Same
measured-beats-assumed discipline as the rest of ``repro.tuning``; the
consumer (``repro.serving.ServingService``) only ever calls
``should_compact``.

Import discipline: module load touches nothing heavy; the measured path
lazily imports ``repro.delta`` / ``repro.core`` inside the function.
"""
from __future__ import annotations

import dataclasses

from .defaults import (
    DEFAULT_COMPACT_HYSTERESIS,
    DEFAULT_OVERLAY_COST_SCALE,
)

__all__ = [
    "OverlayTrigger",
    "constants_overlay_trigger",
    "measured_overlay_trigger",
]


@dataclasses.dataclass(frozen=True)
class OverlayTrigger:
    """Break-even compaction policy for one delta overlay.

    ``overlay_cost_scale`` prices one overlay small-op word in NVRAM
    read-word equivalents (1.0 = the analytic PSAM assumption; measured
    triggers replace it).  ``hysteresis`` > 1 delays compaction past
    break-even to batch more edits per ω write; < 1 compacts eagerly.
    ``source`` records where the scale came from (``"constants"`` or
    ``"measured"``) for observability.
    """

    overlay_cost_scale: float = DEFAULT_OVERLAY_COST_SCALE
    hysteresis: float = DEFAULT_COMPACT_HYSTERESIS
    source: str = "constants"

    def should_compact(
        self, dg, *, sweeps_since_compact: float, omega: float = 4.0
    ) -> bool:
        """True once the overlay surcharge paid since the last compaction
        covers the next compaction's ω-weighted write.

        ``dg`` is the live :class:`~repro.delta.DeltaGraph` snapshot;
        ``sweeps_since_compact`` is how many dense-sweep-equivalents of
        edge reads the serving tier has issued against it (the service
        derives this from its PSAM account, so the trigger needs no clock
        and no extra bookkeeping).  An overlay with nothing folded in
        (``overlay_small_words`` only tombstone-mask rent, zero patches
        and tombstones) never triggers — compacting it would be a pure
        write with no surcharge to recover.
        """
        paid = (
            self.overlay_cost_scale
            * float(dg.overlay_small_words)
            * max(float(sweeps_since_compact), 1.0)
        )
        return paid >= self.hysteresis * omega * float(dg.compact_write_words)


def constants_overlay_trigger() -> OverlayTrigger:
    """The static-defaults policy — cold-start path, no measurement."""
    return OverlayTrigger()


def measured_overlay_trigger(
    base, *, edits: int = 256, seed: int = 0, reps: int = 3
) -> OverlayTrigger:
    """Calibrate the overlay cost scale by timing real sweeps on ``base``.

    Applies ``edits`` random inserts+deletes to a throwaway overlay over
    ``base``, times one jitted dense edgeMap sweep over the base and over
    the overlay snapshot (min-of-``reps``, post-warmup — the
    ``repro.tuning.measure`` discipline), and converts the slowdown into
    a per-overlay-word cost scale:

        scale = ((t_overlay − t_base) / t_base) × base_words / overlay_words

    i.e. "the overlay's surcharge words cost this many base-read-word
    equivalents each".  Clamped to [0.05, 20] so one noisy timing cannot
    produce a never-compact or always-compact policy.
    """
    import jax
    import numpy as np

    from ..core.edgemap import edgemap_dense
    from ..core.psam import edgemap_round_read_words
    from ..delta import DeltaOverlay
    from .measure import _time_us

    rng = np.random.default_rng(seed)
    ov = DeltaOverlay(base)
    n = base.n
    dst_np = np.asarray(base.edge_dst)
    src_np = np.asarray(base.edge_src)
    valid = np.asarray(base.edge_valid)
    live = np.flatnonzero(valid)
    for _ in range(edits):
        u = int(rng.integers(0, n))
        v = int(rng.integers(0, n))
        if u != v:
            ov.insert(u, v)
        if live.size:
            j = int(live[rng.integers(0, live.size)])
            ov.delete(int(src_np[j]), int(dst_np[j]))
    dg = ov.snapshot()

    frontier = np.ones(n, dtype=bool)
    x = np.arange(n, dtype=np.float32)
    fn = jax.jit(lambda g, f, xv: edgemap_dense(g, f, xv, monoid="min"))
    t_base = _time_us(fn, base, frontier, x, reps=reps)
    t_over = _time_us(fn, dg, frontier, x, reps=reps)
    base_words = float(edgemap_round_read_words(base))
    over_words = float(max(dg.overlay_small_words, 1))
    raw = max(t_over - t_base, 0.0) / max(t_base, 1e-9) * base_words / over_words
    scale = float(min(max(raw, 0.05), 20.0))
    return OverlayTrigger(overlay_cost_scale=scale, source="measured")
