"""repro.tuning — measured-cost calibration for every hand-tuned threshold.

The PSAM's analytic constants assume a fixed read/write asymmetry; real
devices don't (Optane characterization, arXiv:1904.07162).  This package
replaces assumption with measurement:

  calibrate                — microbenchmark per-strategy edgeMap cost on
                             this host (density grid × backend × chunk /
                             batch / tile knobs) and return a TuningTable
  TuningTable              — versioned, host-keyed, schema-checked JSON
                             store with interpolating density lookups
  TuningDecision           — the knob values one ExecutionPlan executes,
                             recorded on every plan (``plan.decisions``)
  default_table            — the shipped offline table (cold-start path)
  load_table               — load a calibrated table (or the default)
  constants_decision       — the static-defaults decision (un-tuned plans)
  hardware_model           — the one hardware description (peak FLOPs,
                             HBM/ICI bandwidth) roofline + calibration share
  crossover_from_sweep     — density where dense becomes cheaper, from
                             measured sweep rows
  dense_frac_from_crossover— Beamer threshold equivalent of a crossover
  flavor_crossover_from_sweep — density where the batched streamed union
                             stops beating vmapped plain sparse
  OverlayTrigger           — delta-overlay compaction policy (compact once
                             the accumulated sweep surcharge covers the
                             ω write); constants_overlay_trigger is the
                             static-defaults instance,
                             measured_overlay_trigger calibrates the
                             overlay cost scale from timed sweeps
  SCHEMA_VERSION           — current table schema (stale tables rejected)

plus the static defaults (``DEFAULT_DENSE_FRAC``, ``DEFAULT_CHUNK_BLOCKS``,
``DEFAULT_TILE_BLOCKS``, ``DEFAULT_MAX_BATCH``, ``DEFAULT_EST_ROUNDS``,
``DEFAULT_LOWERING``, ``DEFAULT_HARDWARE``) — module-level constants documented in
``repro.tuning.defaults``.

CLI: ``python -m repro.tuning --quick --out table.json`` (the nightly job).

Import discipline: ``repro.core`` reads ``repro.tuning.defaults`` and (at
plan-build time) ``default_table()``; nothing in this package imports
``repro.core`` at module load — ``measure`` pulls it in lazily inside the
calibration functions.
"""
from .defaults import (
    DEFAULT_CHUNK_BLOCKS,
    DEFAULT_DENSE_FRAC,
    DEFAULT_EST_ROUNDS,
    DEFAULT_HARDWARE,
    DEFAULT_LOWERING,
    DEFAULT_MAX_BATCH,
    DEFAULT_TILE_BLOCKS,
)
from .measure import calibrate, host_fingerprint
from .overlay import (
    OverlayTrigger,
    constants_overlay_trigger,
    measured_overlay_trigger,
)
from .table import (
    SCHEMA_VERSION,
    TuningDecision,
    TuningTable,
    constants_decision,
    crossover_from_sweep,
    default_table,
    dense_frac_from_crossover,
    flavor_crossover_from_sweep,
    hardware_model,
    load_table,
)

__all__ = [
    "SCHEMA_VERSION",
    "DEFAULT_DENSE_FRAC",
    "DEFAULT_CHUNK_BLOCKS",
    "DEFAULT_TILE_BLOCKS",
    "DEFAULT_MAX_BATCH",
    "DEFAULT_EST_ROUNDS",
    "DEFAULT_LOWERING",
    "DEFAULT_HARDWARE",
    "OverlayTrigger",
    "TuningTable",
    "TuningDecision",
    "calibrate",
    "constants_decision",
    "constants_overlay_trigger",
    "crossover_from_sweep",
    "default_table",
    "dense_frac_from_crossover",
    "flavor_crossover_from_sweep",
    "hardware_model",
    "host_fingerprint",
    "load_table",
    "measured_overlay_trigger",
]
