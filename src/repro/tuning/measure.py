"""calibrate() — microbenchmark every tunable knob and emit a TuningTable.

One pass, one synthetic R-MAT workload (same generator the benchmark tables
use), both backends:

* **Density sweep** — for a grid of frontier sizes, time one jitted
  ``edgemap_reduce`` round per fixed strategy (``dense``, ``sparse``, and
  ``sparse_streamed`` where the backend has a streaming decoder) and record
  the *measured* edge density ``sum_deg(frontier) / m`` next to each
  sample.  The dense/sparse wall-time crossover of this sweep is what
  replaces the Beamer ``dense_frac = 20`` constant
  (``dense_frac = 1 / d*``).
* **Chunk sweep** — at a mid-grid density, time the sparse path across
  ``chunk_blocks`` candidates; the argmin becomes the plan's chunk size.
* **Batch sweep** — time ``edgemap_reduce_batched`` across widths B and
  take the knee of the per-query cost curve (the smallest B within 10 % of
  the best amortization) as the serving ``max_batch``.
* **Batched density sweep** — the same per-strategy grid at batch width
  B=8 through ``edgemap_reduce_batched``.  Nothing transfers from the
  single-query sweep: the batched dense body is one shared sweep for all
  lanes (its crossover → ``dense_frac_batched``), and the streamed union
  runs one live-block loop shared by all lanes (its streamed/plain flip →
  ``batched_flavor_crossover``, the density where batched auto switches
  sparse flavor at runtime).
* **Lowering sweep** — time one sparse edgeMap round per Pallas lowering
  this host can run (``interpret`` always, ``native`` where Mosaic is
  available); with both measured, the winner becomes the table's
  ``lowering`` and ``make_plan`` pins it instead of the per-backend auto.
* **Tile sweep** (compressed backend, full mode only) — time the Pallas
  ``compressed_spmv_vertex`` kernel across TB tile candidates.
* **Shard sweep** (full mode, multi-device hosts only) — time a mesh plan
  per shard count.

Timing discipline: every variant is jitted, warmed up once (compile time
excluded), then timed as the **minimum** over ``reps`` block-until-ready
calls — min, not mean, because calibration wants the contention-free cost.
Modeled read words ride along with each sample (``edgemap_round_read_words``
scaled by the active-block fraction for the sparse side) so the table can
price NVRAM traffic, not just wall time.

jax / repro.core are imported lazily inside the functions: ``repro.core``
imports ``repro.tuning.defaults`` at module load, and this module must not
close that loop at import time.
"""
from __future__ import annotations

import platform
import sys
import time

from .defaults import (
    DEFAULT_CHUNK_BLOCKS,
    DEFAULT_HARDWARE,
    DEFAULT_LOWERING,
    DEFAULT_MAX_BATCH,
    DEFAULT_TILE_BLOCKS,
)
from .table import (
    SCHEMA_VERSION,
    TuningTable,
    crossover_from_sweep,
    dense_frac_from_crossover,
    flavor_crossover_from_sweep,
)

# Frontier sizes as vertex fractions: spans BFS's first lonely round
# through the saturated mid-traversal rounds.
_DENSITY_GRID = (0.002, 0.01, 0.05, 0.2, 1.0)
_DENSITY_GRID_QUICK = (0.002, 0.05, 1.0)
_CHUNK_GRID = (64, 128, 256, 512)
_CHUNK_GRID_QUICK = (128, 256)
_BATCH_GRID = (1, 2, 4, 8, 16)
_BATCH_GRID_QUICK = (1, 4, 8)
_TILE_GRID = (4, 8, 16)


def host_fingerprint() -> dict:
    """Identity of the machine a table was measured on (keys the table)."""
    import jax

    dev = jax.devices()[0]
    return {
        "platform": jax.default_backend(),
        "device_kind": dev.device_kind,
        "device_count": jax.device_count(),
        "machine": platform.machine(),
        "python": sys.version.split()[0],
    }


def _time_us(fn, *args, reps: int = 3) -> float:
    """Min-of-reps wall time (us) of an already-jitted fn, post-warmup."""
    import jax

    out = fn(*args)
    jax.block_until_ready(out)  # warmup: compile + first run excluded
    best = float("inf")
    for _ in range(reps):
        t0 = time.perf_counter()
        out = fn(*args)
        jax.block_until_ready(out)
        best = min(best, time.perf_counter() - t0)
    return best * 1e6


def _frontier_for_fraction(g, frac: float, seed: int):
    """bool[n] mask selecting ~frac of vertices (deterministic per seed)."""
    import numpy as np

    n = g.n
    k = max(1, min(n, int(round(frac * n))))
    rng = np.random.default_rng(seed)
    idx = rng.choice(n, size=k, replace=False)
    mask = np.zeros(n, dtype=bool)
    mask[idx] = True
    return mask


def _measured_density(g, mask) -> float:
    """The quantity auto's predicate tests: frontier incident edges / m."""
    import numpy as np

    deg = np.asarray(g.degrees)
    return float(np.sum(np.where(mask, deg, 0))) / max(1, int(g.m))


def _active_block_fraction(g, mask) -> float:
    import numpy as np

    src = np.asarray(g.block_src)
    n = g.n
    live = src < n
    if not live.any():
        return 0.0
    return float(np.sum(mask[src[live]])) / float(np.sum(live))


def _has_streaming(g) -> bool:
    from ..core.edgemap import _streaming_decoder

    return _streaming_decoder(g, None) is not None


def _density_sweep(
    g, grid, *, seed: int, reps: int, chunk_blocks: int
) -> list[dict]:
    import jax
    import jax.numpy as jnp

    from ..core import edgemap_reduce, edgemap_round_read_words

    x0 = jnp.arange(g.n, dtype=jnp.float32)
    dense_words = float(edgemap_round_read_words(g))
    modes = ["dense", "sparse"] + (["sparse_streamed"] if _has_streaming(g) else [])
    # measure at the chunk size the plan will actually run (the chunk sweep
    # picks it first) — timing sparse at a different chunk size skews the
    # crossover toward whichever side the mismatch slows down
    fns = {
        mode: jax.jit(
            lambda mask, x, mode=mode: edgemap_reduce(
                g, mask, x, monoid="min", mode=mode, chunk_blocks=chunk_blocks
            )
        )
        for mode in modes
    }
    rows = []
    for frac in grid:
        mask_np = _frontier_for_fraction(g, frac, seed)
        mask = jnp.asarray(mask_np)
        active = _active_block_fraction(g, mask_np)
        row = {
            "density": max(_measured_density(g, mask_np), 1e-6),
            "dense_words": dense_words,
            "sparse_words": dense_words * active,
        }
        for mode in modes:
            row[f"{mode}_us"] = _time_us(fns[mode], mask, x0, reps=reps)
        rows.append(row)
    rows.sort(key=lambda r: r["density"])
    return rows


def _chunk_sweep(g, grid, *, frac: float, seed: int, reps: int) -> list[dict]:
    import jax
    import jax.numpy as jnp

    from ..core import edgemap_reduce

    x0 = jnp.arange(g.n, dtype=jnp.float32)
    mask = jnp.asarray(_frontier_for_fraction(g, frac, seed))
    rows = []
    for cb in grid:
        fn = jax.jit(
            lambda mask, x, cb=cb: edgemap_reduce(
                g, mask, x, monoid="min", mode="sparse", chunk_blocks=cb
            )
        )
        rows.append({"chunk_blocks": int(cb), "us": _time_us(fn, mask, x0, reps=reps)})
    return rows


def _batch_sweep(g, grid, *, frac: float, seed: int, reps: int) -> list[dict]:
    import jax
    import jax.numpy as jnp
    import numpy as np

    from ..core import edgemap_reduce_batched

    rows = []
    for b in grid:
        masks = np.stack(
            [_frontier_for_fraction(g, frac, seed + i) for i in range(b)]
        )
        xb = jnp.broadcast_to(
            jnp.arange(g.n, dtype=jnp.float32)[None, :], (b, g.n)
        )
        fn = jax.jit(
            lambda masks, xb: edgemap_reduce_batched(
                g, masks, xb, monoid="min", mode="auto"
            )
        )
        us = _time_us(fn, jnp.asarray(masks), xb, reps=reps)
        rows.append({"B": int(b), "us_per_query": us / b})
    return rows


def _batched_density_sweep(
    g, grid, *, seed: int, reps: int, chunk_blocks: int, b: int = 8
) -> list[dict]:
    """Per-strategy batched (B-wide) round times across the density grid.

    The single-query crossover does NOT transfer to batched rounds: the
    batched dense body is one shared sweep + one segment reduce for all B
    lanes, while batched sparse vmaps B chunk loops — so dense wins batched
    at far lower densities than single-query.  Likewise the streamed union
    path runs ONE live-block loop shared by all lanes (wins when few
    blocks are live, loses once the union frontier covers most blocks).
    This sweep measures all of it at width ``b``: its dense/sparse sign
    flip becomes ``dense_frac_batched`` and its streamed/plain flip becomes
    ``batched_flavor_crossover``."""
    import jax
    import jax.numpy as jnp
    import numpy as np

    from ..core import edgemap_reduce_batched

    modes = ["dense", "sparse"] + (
        ["sparse_streamed"] if _has_streaming(g) else []
    )
    rows = []
    for frac in grid:
        masks_np = np.stack(
            [_frontier_for_fraction(g, frac, seed + i) for i in range(b)]
        )
        masks = jnp.asarray(masks_np)
        xb = jnp.broadcast_to(
            jnp.arange(g.n, dtype=jnp.float32)[None, :], (b, g.n)
        )
        row = {
            "B": int(b),
            "density": max(
                float(np.mean([_measured_density(g, m) for m in masks_np])), 1e-6
            ),
        }
        for mode in modes:
            fn = jax.jit(
                lambda masks, xb, mode=mode: edgemap_reduce_batched(
                    g, masks, xb, monoid="min", mode=mode,
                    chunk_blocks=chunk_blocks,
                )
            )
            row[f"{mode}_us"] = _time_us(fn, masks, xb, reps=reps)
        rows.append(row)
    rows.sort(key=lambda r: r["density"])
    return rows


def _tile_sweep(g, grid, *, reps: int) -> list[dict]:
    """TB candidates for the streaming kernel (compressed backend only)."""
    import jax
    import jax.numpy as jnp

    from ..kernels.compressed_spmv import compressed_spmv_vertex

    x0 = jnp.arange(g.n, dtype=jnp.float32)
    rows = []
    for tb in grid:
        fn = jax.jit(lambda x, tb=tb: compressed_spmv_vertex(g, x, tile_blocks=tb))
        rows.append({"tile_blocks": int(tb), "us": _time_us(fn, x0, reps=reps)})
    return rows


def _lowering_sweep(g, *, frac: float, seed: int, reps: int) -> list[dict]:
    """Interpret vs native Pallas lowering of one sparse edgeMap round.

    Only lowerings this process can actually run are timed — on hosts
    without Mosaic support the sweep has a single ``interpret`` row and
    the decision stays ``DEFAULT_LOWERING`` (auto)."""
    import jax
    import jax.numpy as jnp

    from ..core.edgemap import edgemap_reduce
    from ..kernels.lowering import native_lowering_supported

    mask = _frontier_for_fraction(g, frac, seed)
    x = jnp.arange(g.n, dtype=jnp.int32)
    cands = ["interpret"] + (["native"] if native_lowering_supported() else [])
    rows = []
    for low in cands:
        fn = jax.jit(
            lambda m, xv, low=low: edgemap_reduce(
                g, m, xv, monoid="min", mode="sparse",
                interpret=low == "interpret",
            )
        )
        rows.append({"lowering": low, "us": _time_us(fn, mask, x, reps=reps)})
    return rows


def _knee(batch_sweep: list[dict], tol: float = 1.10) -> int:
    """Smallest B within ``tol`` of the best per-query amortization."""
    if not batch_sweep:
        return DEFAULT_MAX_BATCH
    best = min(r["us_per_query"] for r in batch_sweep)
    for r in sorted(batch_sweep, key=lambda r: r["B"]):
        if r["us_per_query"] <= tol * best:
            return int(r["B"])
    return int(batch_sweep[-1]["B"])


def _argmin(rows: list[dict], key: str, val: str, default: int) -> int:
    if not rows:
        return default
    return int(min(rows, key=lambda r: r[val])[key])


def _backend_entry(g, *, quick: bool, seed: int, reps: int, tile: bool) -> dict:
    density_grid = _DENSITY_GRID_QUICK if quick else _DENSITY_GRID
    chunk_grid = _CHUNK_GRID_QUICK if quick else _CHUNK_GRID
    batch_grid = _BATCH_GRID_QUICK if quick else _BATCH_GRID
    mid = density_grid[len(density_grid) // 2]

    # chunk size first: every later sweep times the sparse paths at the
    # chunk the plan will actually execute
    chunk_sweep = _chunk_sweep(g, chunk_grid, frac=mid, seed=seed, reps=reps)
    chunk_blocks = _argmin(chunk_sweep, "chunk_blocks", "us", DEFAULT_CHUNK_BLOCKS)

    sweep = _density_sweep(
        g, density_grid, seed=seed, reps=reps, chunk_blocks=chunk_blocks
    )
    crossover = crossover_from_sweep(sweep)
    batch_sweep = _batch_sweep(g, batch_grid, frac=mid, seed=seed, reps=reps)

    # Which sparse flavor auto's sparse branch should run: whichever
    # measured cheaper where sparse wins (the low-density half).
    auto_sparse = "sparse"
    if any("sparse_streamed_us" in r for r in sweep):
        lo = [r for r in sweep if r["density"] <= crossover] or sweep[:1]
        plain = sum(r["sparse_us"] for r in lo)
        streamed = sum(r.get("sparse_streamed_us", float("inf")) for r in lo)
        if streamed < plain:
            auto_sparse = "sparse_streamed"

    # Batched rounds get their OWN density sweep — neither the dense/sparse
    # crossover nor the sparse flavor transfers from the single-query
    # measurements (see _batched_density_sweep).
    batched_sweep = _batched_density_sweep(
        g, density_grid, seed=seed, reps=reps, chunk_blocks=chunk_blocks
    )
    batched_crossover = crossover_from_sweep(batched_sweep)
    flavor_crossover = flavor_crossover_from_sweep(batched_sweep)
    auto_sparse_batched = "sparse"
    if flavor_crossover is not None and flavor_crossover > 0:
        auto_sparse_batched = "sparse_streamed"

    entry = {
        "density_sweep": sweep,
        "crossover_density": crossover,
        "dense_frac": dense_frac_from_crossover(crossover),
        "chunk_sweep": chunk_sweep,
        "chunk_blocks": chunk_blocks,
        "batch_sweep": batch_sweep,
        "max_batch": _knee(batch_sweep),
        "auto_sparse": auto_sparse,
        "batched_density_sweep": batched_sweep,
        "batched_crossover_density": batched_crossover,
        "dense_frac_batched": dense_frac_from_crossover(batched_crossover),
        "auto_sparse_batched": auto_sparse_batched,
        "batched_flavor_crossover": flavor_crossover,
    }
    # Pallas lowering: record the measured winner only when both sides
    # could run here; a single-candidate sweep keeps the portable default.
    lowering_sweep = _lowering_sweep(g, frac=mid, seed=seed, reps=reps)
    entry["lowering_sweep"] = lowering_sweep
    entry["lowering"] = (
        min(lowering_sweep, key=lambda r: r["us"])["lowering"]
        if len(lowering_sweep) > 1
        else DEFAULT_LOWERING
    )
    if tile and _has_streaming(g):
        tile_sweep = _tile_sweep(g, _TILE_GRID, reps=reps)
        entry["tile_sweep"] = tile_sweep
        entry["tile_blocks"] = _argmin(tile_sweep, "tile_blocks", "us", DEFAULT_TILE_BLOCKS)
    return entry


def _shard_sweep(g, *, reps: int) -> list[dict]:
    """Per-shard-count round times — only meaningful on multi-device hosts."""
    import jax
    import jax.numpy as jnp

    from ..core import edgemap_reduce, make_plan

    nd = jax.device_count()
    counts = [s for s in (1, 2, 4, 8) if s <= nd]
    if counts == [1]:
        return []
    x0 = jnp.arange(g.n, dtype=jnp.float32)
    mask = jnp.asarray(_frontier_for_fraction(g, 0.2, 0))
    rows = []
    for s in counts:
        plan = make_plan(g, mesh=s)
        gs = plan.prepare(g)
        fn = jax.jit(
            lambda mask, x: edgemap_reduce(gs, mask, x, monoid="min", plan=plan)
        )
        rows.append({"shards": int(s), "us": _time_us(fn, mask, x0, reps=reps)})
    return rows


def calibrate(
    *,
    n: int = 2048,
    m: int = 16384,
    quick: bool = False,
    seed: int = 0,
    reps: int = 3,
    block_size: int = 128,
    shards: bool = False,
) -> TuningTable:
    """Measure every knob on this host and return the TuningTable.

    ``quick`` shrinks the grids (3 density points, 2 chunk candidates,
    3 batch widths, no tile sweep) for the nightly-CI / cold-start path;
    full mode adds the TB tile sweep on the compressed backend.  ``shards``
    opts into the mesh sweep (needs ``XLA_FLAGS=--xla_force_host_platform_
    device_count=N`` on CPU hosts).  The calibration workload is the same
    R-MAT generator the benchmark tables use, symmetrized, weighted=False.
    """
    from ..core import compress
    from ..data.rmat import rmat_graph

    g = rmat_graph(n, m, seed=seed, block_size=block_size)
    gc = compress(g)
    tile = not quick
    data = {
        "schema_version": SCHEMA_VERSION,
        "created": None,  # stamped by the CLI (host wall clock)
        "quick": bool(quick),
        "host": host_fingerprint(),
        "hardware": dict(DEFAULT_HARDWARE),
        "graph": {"n": int(g.n), "m": int(g.m), "block_size": int(block_size)},
        "backends": {
            "csr": _backend_entry(g, quick=quick, seed=seed, reps=reps, tile=False),
            "compressed": _backend_entry(
                gc, quick=quick, seed=seed, reps=reps, tile=tile
            ),
        },
    }
    if shards:
        data["shard_sweep"] = _shard_sweep(g, reps=reps)
    return TuningTable.from_dict(data)
