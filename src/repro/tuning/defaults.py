"""Tuning-owned constants — the single seam every hand-tuned knob sits behind.

Every threshold in the hot path that used to be a scattered literal lives
here exactly once, so a measured :class:`~repro.tuning.TuningTable` can
override it through one well-known name and the code that consumes the knob
never needs to know whether the value was hand-picked or calibrated:

* ``DEFAULT_DENSE_FRAC``   — the Beamer direction-optimization threshold
  (dense when the frontier's incident edges exceed ``m / dense_frac``).
  Previously defaulted independently in ``core/plan.py`` and twice in
  ``core/edgemap.py``; a calibrated plan replaces it with ``1 / d*`` for
  the measured dense/sparse crossover density ``d*``.
* ``DEFAULT_CHUNK_BLOCKS`` — EDGEMAPCHUNKED chunk-pool size (blocks per
  chunk-loop iteration; the paper's thread-local pool, App. A).
* ``DEFAULT_TILE_BLOCKS``  — TB, the scalar-prefetched live-id tile of the
  frontier-sparse Pallas kernel (blocks per ``PrefetchScalarGridSpec``
  launch, ``repro.kernels.compressed_spmv``).
* ``DEFAULT_MAX_BATCH``    — serving batch width cap (``QueryEngine`` /
  ``ServingService``); calibration replaces it with the knee of the
  measured per-query cost curve over B.
* ``DEFAULT_EST_ROUNDS``   — the cold-start admission estimate (rounds per
  request) the serving ledger prices reservations with until per-op
  observed round counts warm up.
* ``DEFAULT_COMPACT_HYSTERESIS`` / ``DEFAULT_OVERLAY_COST_SCALE`` — the
  constants behind :class:`repro.tuning.OverlayTrigger`: compact a delta
  overlay once its accumulated per-sweep small-op surcharge (scaled by
  the cost-scale calibration) exceeds ``hysteresis × ω × write_words`` —
  i.e. once queries have already paid more in overlay overhead than one
  compaction would cost.  ``measured_overlay_trigger`` replaces the cost
  scale with a timed dense-sweep ratio.
* ``DEFAULT_EDITS_PER_COMPACT`` — the cold-start admission amortization
  horizon for edits: one edit is priced at ``ω × write_words / horizon``
  until the service has observed real edits-per-compaction counts.
* ``DEFAULT_LOWERING``     — how the Pallas kernels lower: ``"auto"``
  resolves per backend at plan time (native Mosaic on TPU, XLA interpret
  mode elsewhere); ``"native"`` / ``"interpret"`` force one side.  A
  calibrated table replaces ``"auto"`` with the measured winner.
* ``DEFAULT_HARDWARE``     — the analytic hardware model (TPU v5e-class):
  peak bf16 FLOP/s, HBM bandwidth, effective per-link ICI bandwidth.  The
  roofline benchmark and the calibration pass both read THIS description,
  so there is one set of hardware constants, not two divergent ones.

This module is import-light on purpose (no jax, no numpy): ``repro.core``
imports it at module load, so it must never import back into core.
"""
from __future__ import annotations

DEFAULT_DENSE_FRAC = 20
DEFAULT_CHUNK_BLOCKS = 256
DEFAULT_TILE_BLOCKS = 8
DEFAULT_MAX_BATCH = 8
DEFAULT_EST_ROUNDS = 8
DEFAULT_LOWERING = "auto"
DEFAULT_COMPACT_HYSTERESIS = 1.0
DEFAULT_OVERLAY_COST_SCALE = 1.0
DEFAULT_EDITS_PER_COMPACT = 1024

# TPU v5e-class per chip: 197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link ICI
# (one effective link per collective hop — conservative).
DEFAULT_HARDWARE = {
    "peak_flops": 197e12,
    "hbm_bw": 819e9,
    "ici_bw": 50e9,
}
