"""Deterministic synthetic LM data pipeline.

``make_batch(step)`` is a pure function of the step index — the property the
fault-tolerant trainer relies on for bit-identical restarts (the data cursor
is just the step in the checkpoint).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def make_lm_batch_fn(vocab: int, batch: int, seq: int, *, structured: bool = True):
    """Returns make_batch(step) → {tokens, targets}.

    ``structured=True`` makes targets a learnable function of the input
    (affine map mod vocab) so smoke-training losses visibly decrease.
    """

    def make_batch(step: int):
        k = jax.random.PRNGKey(step)
        toks = jax.random.randint(k, (batch, seq), 0, vocab)
        if structured:
            targets = (toks * 7 + 3) % vocab
        else:
            targets = jnp.roll(toks, -1, axis=1)
        return {"tokens": toks, "targets": targets}

    return make_batch
