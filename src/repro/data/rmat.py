"""Synthetic graph generation — RMAT power-law graphs (the standard stand-in
for the paper's web/social inputs) plus structured graphs for tests.

Host-side numpy; feeds ``build_csr``.  Weights are drawn uniformly from
[1, log2 n) as in §5.1.3.
"""
from __future__ import annotations

import numpy as np

from ..core.csr import CSRGraph, build_csr


def rmat_edges(
    n: int,
    m: int,
    *,
    a: float = 0.57,
    b: float = 0.19,
    c: float = 0.19,
    seed: int = 0,
) -> tuple[np.ndarray, np.ndarray]:
    """R-MAT edge generator (Chakrabarti et al.); n must be a power of two
    (rounded up internally)."""
    rng = np.random.default_rng(seed)
    levels = max(1, int(np.ceil(np.log2(max(n, 2)))))
    src = np.zeros(m, dtype=np.int64)
    dst = np.zeros(m, dtype=np.int64)
    p = np.array([a, b, c, 1.0 - a - b - c])
    for _ in range(levels):
        q = rng.choice(4, size=m, p=p)
        src = src * 2 + (q >= 2)
        dst = dst * 2 + (q % 2)
    src, dst = src % n, dst % n
    return src, dst


def rmat_graph(
    n: int,
    m: int,
    *,
    weighted: bool = False,
    seed: int = 0,
    block_size: int = 128,
) -> CSRGraph:
    src, dst = rmat_edges(n, m, seed=seed)
    w = None
    if weighted:
        rng = np.random.default_rng(seed + 1)
        hi = max(2, int(np.log2(max(n, 4))))
        w = rng.integers(1, hi, size=src.shape[0]).astype(np.float32)
    return build_csr(n, src, dst, w, symmetrize=True, block_size=block_size)


def structured_graph(kind: str, *, block_size: int = 32, weighted: bool = False) -> CSRGraph:
    """Small deterministic graphs for unit tests."""
    if kind == "path":  # 0-1-2-...-9
        src = np.arange(9)
        dst = np.arange(1, 10)
        n = 10
    elif kind == "star":  # hub 0
        src = np.zeros(8, dtype=np.int64)
        dst = np.arange(1, 9)
        n = 9
    elif kind == "cycle":
        n = 8
        src = np.arange(n)
        dst = (np.arange(n) + 1) % n
    elif kind == "grid":  # 4x4 grid
        n = 16
        ss, dd = [], []
        for r in range(4):
            for cc in range(4):
                v = r * 4 + cc
                if cc < 3:
                    ss.append(v), dd.append(v + 1)
                if r < 3:
                    ss.append(v), dd.append(v + 4)
        src, dst = np.array(ss), np.array(dd)
    elif kind == "two_triangles":  # {0,1,2} and {3,4,5}, disconnected
        src = np.array([0, 1, 2, 3, 4, 5])
        dst = np.array([1, 2, 0, 4, 5, 3])
        n = 6
    elif kind == "barbell":  # two triangles joined by a bridge 2-3
        src = np.array([0, 1, 2, 2, 3, 4, 5])
        dst = np.array([1, 2, 0, 3, 4, 5, 3])
        n = 6
    else:
        raise ValueError(kind)
    w = None
    if weighted:
        rng = np.random.default_rng(0)
        w = rng.integers(1, 5, size=src.shape[0]).astype(np.float32)
    return build_csr(n, src, dst, w, symmetrize=True, block_size=block_size)
