"""Fanout neighbor sampler (GraphSAGE-style) — required by the
``minibatch_lg`` shape (232,965 nodes / 114.6M edges, batch 1024, fanout
15-10).

Host-side numpy over the CSR; emits fixed-shape padded arrays (the
static-shape contract every jitted GNN step expects):

  nodes   : seed + sampled frontier nodes, padded
  edge_src/edge_dst : sampled edges as *local* indices into ``nodes``
"""
from __future__ import annotations

import numpy as np


def sample_fanout(
    offsets: np.ndarray,
    targets: np.ndarray,
    seeds: np.ndarray,
    fanouts: tuple[int, ...],
    *,
    rng: np.random.Generator | None = None,
):
    """Returns (nodes, edge_src_local, edge_dst_local, n_real_nodes,
    n_real_edges), padded to the static maximum implied by fanouts."""
    rng = rng or np.random.default_rng(0)
    seeds = np.asarray(seeds, dtype=np.int64)
    layers = [seeds]
    edges_s, edges_d = [], []
    frontier = seeds
    for f in fanouts:
        samp_src, samp_dst = [], []
        for v in frontier:
            beg, end = int(offsets[v]), int(offsets[v + 1])
            deg = end - beg
            if deg == 0:
                continue
            take = min(f, deg)
            picks = rng.choice(deg, size=take, replace=False)
            nbrs = targets[beg + picks]
            samp_src.append(np.full(take, v))
            samp_dst.append(nbrs)
        if samp_src:
            s = np.concatenate(samp_src)
            d = np.concatenate(samp_dst)
        else:
            s = d = np.zeros(0, dtype=np.int64)
        edges_s.append(s)
        edges_d.append(d)
        frontier = np.unique(d)
        layers.append(frontier)

    nodes = np.unique(np.concatenate(layers))
    remap = {int(v): i for i, v in enumerate(nodes)}
    es = np.concatenate(edges_s) if edges_s else np.zeros(0, np.int64)
    ed = np.concatenate(edges_d) if edges_d else np.zeros(0, np.int64)
    es_l = np.array([remap[int(v)] for v in es], dtype=np.int32)
    ed_l = np.array([remap[int(v)] for v in ed], dtype=np.int32)

    # pad to static shapes
    max_nodes, max_edges = padded_sizes(len(seeds), fanouts)
    n_real, e_real = len(nodes), len(es_l)
    nodes_p = np.full(max_nodes, -1, np.int64)
    nodes_p[:n_real] = nodes
    src_p = np.full(max_edges, max_nodes, np.int32)  # sentinel = max_nodes
    dst_p = np.full(max_edges, max_nodes, np.int32)
    src_p[:e_real] = es_l
    dst_p[:e_real] = ed_l
    return nodes_p, src_p, dst_p, n_real, e_real


def padded_sizes(n_seeds: int, fanouts: tuple[int, ...]) -> tuple[int, int]:
    """Static maxima: nodes = Σ layer sizes; edges = Σ frontier·fanout."""
    nodes = n_seeds
    frontier = n_seeds
    edges = 0
    for f in fanouts:
        edges += frontier * f
        frontier = frontier * f
        nodes += frontier
    return nodes, edges
