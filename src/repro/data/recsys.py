"""Synthetic SASRec data: user interaction sequences with next-item
positives and sampled negatives (the paper's training regime), plus
candidate-list generation for retrieval scoring."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def make_sasrec_batch_fn(vocab: int, batch: int, seq_len: int):
    """Returns make_batch(step) → {seq, pos, neg} (0 = padding item)."""

    def make_batch(step: int):
        k = jax.random.PRNGKey(step)
        k1, k2, k3 = jax.random.split(k, 3)
        seq = jax.random.randint(k1, (batch, seq_len), 1, vocab)
        # next-item target: a deterministic drift in item space (learnable)
        pos = (seq * 31 + 7) % (vocab - 1) + 1
        neg = jax.random.randint(k3, (batch, seq_len), 1, vocab)
        # zero-pad a random prefix per row (variable-length histories)
        cut = jax.random.randint(k2, (batch, 1), 0, seq_len // 2)
        idx = jnp.arange(seq_len)[None, :]
        mask = idx >= cut
        return {
            "seq": jnp.where(mask, seq, 0),
            "pos": jnp.where(mask, pos, 0),
            "neg": jnp.where(mask, neg, 0),
        }

    return make_batch


def make_candidates(key, batch: int, n_candidates: int, vocab: int):
    return jax.random.randint(key, (batch, n_candidates), 0, vocab)
