from .neighbor_sampler import padded_sizes, sample_fanout
from .recsys import make_candidates, make_sasrec_batch_fn
from .rmat import rmat_edges, rmat_graph, structured_graph
from .tokens import make_lm_batch_fn

__all__ = [
    "rmat_edges", "rmat_graph", "structured_graph",
    "sample_fanout", "padded_sizes",
    "make_lm_batch_fn", "make_sasrec_batch_fn", "make_candidates",
]
