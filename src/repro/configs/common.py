"""Shared cell machinery for the assigned architecture × shape grid.

A **Cell** is one (architecture, input-shape) pair: its model config, the
ShapeDtypeStruct stand-ins for every step input, the logical sharding of
those inputs, the step kind, and the sharding rule set.  The dry-run
(launch/dryrun.py) lowers+compiles every cell on the production meshes;
the smoke tests run REDUCED configs of the same families on real arrays.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

S = jax.ShapeDtypeStruct


@dataclasses.dataclass
class Cell:
    arch: str
    shape: str
    kind: str                      # train | prefill | decode | serve | retrieval
    family: str                    # lm | gnn | recsys
    model_cfg: Any
    batch_specs: dict              # name → ShapeDtypeStruct (or pytree thereof)
    batch_logical: dict            # name → logical-axis tuple (or pytree)
    rules: dict                    # logical → mesh axes for this cell
    notes: str = ""
    # model-FLOPs estimate for §Roofline's usefulness ratio (per step, fwd+bwd
    # for train, fwd for serve)
    model_flops: float = 0.0


def i32(*shape):
    return S(tuple(shape), jnp.int32)


def f32(*shape):
    return S(tuple(shape), jnp.float32)


def bf16(*shape):
    return S(tuple(shape), jnp.bfloat16)


# ----------------------------------------------------------------------
# LM cell builders
# ----------------------------------------------------------------------
LM_SHAPES = {
    "train_4k": dict(seq_len=4096, global_batch=256, kind="train"),
    "prefill_32k": dict(seq_len=32768, global_batch=32, kind="prefill"),
    "decode_32k": dict(seq_len=32768, global_batch=128, kind="decode"),
    "long_500k": dict(seq_len=524288, global_batch=1, kind="decode"),
}


def lm_model_flops(cfg, seq: int, batch: int, *, train: bool, decode: bool = False):
    """6·N·D (dense) / 6·N_active·D (MoE) + attention term."""
    d, L, V = cfg.d_model, cfg.n_layers, cfg.vocab
    if cfg.attn == "mla":
        dqk = cfg.nope_head_dim + cfg.rope_head_dim
        attn_p = d * (
            cfg.n_heads * dqk + cfg.kv_lora_rank + cfg.rope_head_dim
        ) + cfg.kv_lora_rank * cfg.n_heads * (
            cfg.nope_head_dim + cfg.v_head_dim
        ) + cfg.n_heads * cfg.v_head_dim * d
    else:
        attn_p = d * cfg.d_head * (cfg.n_heads * 2 + cfg.n_kv_heads * 2)
    if cfg.moe:
        ffn_active = 3 * d * cfg.d_ff_expert * (cfg.top_k + cfg.n_shared)
        dense_layers = cfg.first_dense_layers
        ffn_p = ffn_active * (L - dense_layers) / L + (
            3 * d * cfg.d_ff * dense_layers / L
        )
    else:
        ffn_p = 3 * d * cfg.d_ff
    n_active = L * (attn_p + ffn_p) + V * d  # + embeddings
    tokens = batch * (1 if decode else seq)
    mult = 6 if train else 2
    flops = mult * n_active * tokens
    # attention score/AV FLOPs (per token ~ 2·2·d_attn·context)
    ctx = seq if (decode or not train) else seq / 2
    dh = cfg.n_heads * (
        cfg.nope_head_dim + cfg.rope_head_dim if cfg.attn == "mla" else cfg.d_head
    )
    flops += mult / 3 * 2 * 2 * tokens * ctx * dh * L
    return float(flops)


# ----------------------------------------------------------------------
# GNN shape table
# ----------------------------------------------------------------------
GNN_SHAPES = {
    "full_graph_sm": dict(n_nodes=2708, n_edges=10556, d_feat=1433),
    "minibatch_lg": dict(
        n_nodes=232_965, n_edges=114_615_892, batch_nodes=1024, fanout=(15, 10),
        d_feat=602,
    ),
    "ogb_products": dict(n_nodes=2_449_029, n_edges=61_859_140, d_feat=100),
    "molecule": dict(n_nodes=30, n_edges=64, batch=128, d_feat=16),
}

# triplets-per-edge cap for directional models (exact for molecules, sampled
# for big graphs — DESIGN.md §Arch-applicability)
TRIPLET_CAP = {
    "full_graph_sm": 8,
    "minibatch_lg": 4,
    "ogb_products": 1,
    "molecule": 16,
}


def gnn_graph_specs(shape_name: str, *, with_pos: bool, with_triplets: bool,
                    n_graphs: int | None = None):
    """ShapeDtypeStructs for a GNN batch of the given assigned shape."""
    info = GNN_SHAPES[shape_name]
    if shape_name == "minibatch_lg":
        from ..data.neighbor_sampler import padded_sizes

        n, e = padded_sizes(info["batch_nodes"], info["fanout"])
    elif shape_name == "molecule":
        n = info["n_nodes"] * info["batch"]
        e = info["n_edges"] * info["batch"] * 2  # symmetrized
    else:
        n, e = info["n_nodes"], info["n_edges"]
    specs = {
        "node_feat": f32(n, info["d_feat"]),
        "edge_src": i32(e),
        "edge_dst": i32(e),
    }
    logical = {
        "node_feat": ("nodes", None),
        "edge_src": ("edges",),
        "edge_dst": ("edges",),
    }
    if with_pos:
        specs["pos"] = f32(n, 3)
        logical["pos"] = ("nodes", None)
    if with_triplets:
        t = e * TRIPLET_CAP[shape_name]
        specs["t_kj"] = i32(t)
        specs["t_ji"] = i32(t)
        logical["t_kj"] = ("edges",)
        logical["t_ji"] = ("edges",)
    if n_graphs is not None:
        specs["node_graph"] = i32(n)
        specs["graph_labels"] = i32(n_graphs)
        logical["node_graph"] = ("nodes",)
        logical["graph_labels"] = (None,)
    else:
        specs["labels"] = i32(n)
        logical["labels"] = ("nodes",)
    return specs, logical, n, e
