"""qwen2-1.5b [dense] 28L d_model=1536 12H (GQA kv=2) d_ff=8960
vocab=151936 — GQA, QKV bias  [arXiv:2407.10671; hf]"""
from __future__ import annotations

from ..models import transformer_lm as lm
from .lm_common import lm_cells, lm_smoke_batch

ARCH_ID = "qwen2-1.5b"
FAMILY = "lm"
MODULE = lm


def full_config() -> lm.LMConfig:
    return lm.LMConfig(
        name=ARCH_ID,
        n_layers=28,
        d_model=1536,
        n_heads=12,
        n_kv_heads=2,
        d_head=128,
        d_ff=8960,
        vocab=151936,
        qkv_bias=True,
        rope_theta=1_000_000.0,
        dtype="bfloat16",
    )


def smoke_config() -> lm.LMConfig:
    return lm.LMConfig(
        name=ARCH_ID + "-smoke",
        n_layers=2,
        d_model=48,
        n_heads=6,
        n_kv_heads=2,
        d_head=8,
        d_ff=96,
        vocab=128,
        qkv_bias=True,
        dtype="float32",
        kv_block=16,
    )


def cells():
    return lm_cells(full_config())


def smoke_batch(key):
    return lm_smoke_batch(smoke_config(), key)
