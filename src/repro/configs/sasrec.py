"""sasrec [recsys] embed_dim=50 n_blocks=2 n_heads=1 seq_len=50
interaction=self-attn-seq  [arXiv:1808.09781; paper]

Catalog fixed at 2^20 items (row-shardable by every mesh).  Shapes:
train_batch 65,536 (training) · serve_p99 512 (online) · serve_bulk 262,144
(offline scoring, top-k output) · retrieval_cand 1×1,000,000 (padded to
1,000,448 = 512·1954) — batched dot against the sharded candidate rows."""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from ..distributed.shardings import RECSYS_RETRIEVAL_RULES, RECSYS_RULES
from ..models import sasrec as mod
from .common import Cell, i32

ARCH_ID = "sasrec"
FAMILY = "recsys"
MODULE = mod

VOCAB = 1 << 20
N_CAND = 1_000_448  # 1M padded to ×512


def full_config():
    return mod.SASRecConfig(name=ARCH_ID, vocab=VOCAB, embed_dim=50,
                            n_blocks=2, n_heads=1, seq_len=50)


def smoke_config():
    return mod.SASRecConfig(name=ARCH_ID + "-smoke", vocab=512, embed_dim=16,
                            n_blocks=2, n_heads=1, seq_len=10, kv_block=8)


def _flops(cfg, batch, kind):
    d, L = cfg.embed_dim, cfg.seq_len
    enc = batch * L * d * d * 8 + batch * L * L * d * 2 * cfg.n_blocks
    if kind == "train":
        return 3.0 * (2 * enc + 2 * batch * L * d * 2)
    if kind == "retrieval":
        return 2.0 * enc + 2.0 * batch * N_CAND * d
    return 2.0 * enc + 2.0 * batch * cfg.vocab * d


def cells():
    cfg = full_config()
    L = cfg.seq_len
    out = {}
    out["train_batch"] = Cell(
        arch=ARCH_ID, shape="train_batch", kind="train", family="recsys",
        model_cfg=cfg,
        batch_specs={"seq": i32(65536, L), "pos": i32(65536, L), "neg": i32(65536, L)},
        batch_logical={"seq": ("batch", None), "pos": ("batch", None), "neg": ("batch", None)},
        rules=RECSYS_RULES,
        model_flops=_flops(cfg, 65536, "train"),
    )
    for shape, b in [("serve_p99", 512), ("serve_bulk", 262144)]:
        out[shape] = Cell(
            arch=ARCH_ID, shape=shape, kind="serve", family="recsys",
            model_cfg=cfg,
            batch_specs={"seq": i32(b, L)},
            batch_logical={"seq": ("batch", None)},
            rules=RECSYS_RULES,
            notes="full-catalog scoring; top-100 output (bulk scorers emit top-k)",
            model_flops=_flops(cfg, b, "serve"),
        )
    out["retrieval_cand"] = Cell(
        arch=ARCH_ID, shape="retrieval_cand", kind="retrieval", family="recsys",
        model_cfg=cfg,
        batch_specs={"seq": i32(1, L), "candidates": i32(1, N_CAND)},
        batch_logical={"seq": (None, None), "candidates": (None, "candidates")},
        rules=RECSYS_RETRIEVAL_RULES,
        model_flops=_flops(cfg, 1, "retrieval"),
    )
    return out


def smoke_batch(seed=0):
    rng = np.random.default_rng(seed)
    cfg = smoke_config()
    b = {
        "seq": jnp.asarray(rng.integers(0, cfg.vocab, (4, cfg.seq_len)), jnp.int32),
        "pos": jnp.asarray(rng.integers(1, cfg.vocab, (4, cfg.seq_len)), jnp.int32),
        "neg": jnp.asarray(rng.integers(1, cfg.vocab, (4, cfg.seq_len)), jnp.int32),
        "candidates": jnp.asarray(rng.integers(0, cfg.vocab, (4, 64)), jnp.int32),
    }
    return b
