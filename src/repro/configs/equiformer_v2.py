"""equiformer-v2 [gnn] n_layers=12 d_hidden=128 l_max=6 m_max=2 n_heads=8
equivariance=SO(2)-eSCN  [arXiv:2306.12059; unverified]"""
from __future__ import annotations

from ..models.gnn import equiformer_v2 as mod
from .gnn_common import gnn_cells, gnn_smoke_batch

ARCH_ID = "equiformer-v2"
FAMILY = "gnn"
MODULE = mod


def full_config():
    return mod.EquiformerV2Config(
        name=ARCH_ID, n_layers=12, d_hidden=128, l_max=6, m_max=2, n_heads=8,
    )


def smoke_config():
    return mod.EquiformerV2Config(
        name=ARCH_ID + "-smoke", n_layers=2, d_hidden=16, l_max=2, m_max=1,
        n_heads=2, d_in=16, task="graph", n_graphs=4,
    )


def _flops(cfg, n, e):
    d, Cf = cfg.d_hidden, cfg.n_coef
    per_layer = e * (Cf * d * d * (2 * cfg.m_max + 1) / Cf + Cf * d) + n * (4 * d * d)
    return 3.0 * 2 * cfg.n_layers * per_layer


def cells():
    return gnn_cells(ARCH_ID, mod, full_config(), with_pos=True,
                     with_triplets=False, flops_fn=_flops)


def smoke_batch(seed=0):
    return gnn_smoke_batch(seed, with_pos=True, task="graph", n_graphs=4)
