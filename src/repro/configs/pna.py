"""pna [gnn] n_layers=4 d_hidden=75 aggregators=mean-max-min-std
scalers=id-amp-atten  [arXiv:2004.05718; paper]"""
from __future__ import annotations

from ..models.gnn import pna as mod
from .gnn_common import gnn_cells, gnn_smoke_batch

ARCH_ID = "pna"
FAMILY = "gnn"
MODULE = mod


def full_config():
    return mod.PNAConfig(name=ARCH_ID, n_layers=4, d_hidden=75)


def smoke_config():
    return mod.PNAConfig(name=ARCH_ID + "-smoke", n_layers=2, d_hidden=16,
                         d_in=16, n_classes=8, task="node")


def _flops(cfg, n, e):
    d = cfg.d_hidden
    per_layer = e * (2 * d * d * 2) + n * (12 * d * d + 2 * 2 * d * d)
    return 3.0 * 2 * cfg.n_layers * per_layer  # fwd+bwd


def cells():
    return gnn_cells(ARCH_ID, mod, full_config(), with_pos=False,
                     with_triplets=False, flops_fn=_flops)


def smoke_batch(seed=0):
    return gnn_smoke_batch(seed, task="node", n_classes=8)
