"""dimenet [gnn] n_blocks=6 d_hidden=128 n_bilinear=8 n_spherical=7
n_radial=6  [arXiv:2003.03123; unverified]"""
from __future__ import annotations

from ..models.gnn import dimenet as mod
from .gnn_common import gnn_cells, gnn_smoke_batch

ARCH_ID = "dimenet"
FAMILY = "gnn"
MODULE = mod


def full_config():
    return mod.DimeNetConfig(
        name=ARCH_ID, n_blocks=6, d_hidden=128, n_bilinear=8,
        n_spherical=7, n_radial=6,
    )


def smoke_config():
    return mod.DimeNetConfig(
        name=ARCH_ID + "-smoke", n_blocks=2, d_hidden=16, n_bilinear=4,
        n_spherical=3, n_radial=3, d_in=16, task="graph", n_graphs=4,
    )


def _flops(cfg, n, e):
    d, nb = cfg.d_hidden, cfg.n_bilinear
    t = e * 4  # representative triplet multiplicity
    per_block = t * (d * nb + nb * d) + e * (d * d * 3)
    return 3.0 * 2 * cfg.n_blocks * per_block


def cells():
    return gnn_cells(ARCH_ID, mod, full_config(), with_pos=True,
                     with_triplets=True, flops_fn=_flops)


def smoke_batch(seed=0):
    return gnn_smoke_batch(seed, with_pos=True, with_triplets=True,
                           task="graph", n_graphs=4)
