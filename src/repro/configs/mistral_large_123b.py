"""mistral-large-123b [dense] 88L d_model=12288 96H (GQA kv=8) d_ff=28672
vocab=32768  [hf:mistralai/Mistral-Large-Instruct-2407; unverified]"""
from __future__ import annotations



from ..models import transformer_lm as lm
from .lm_common import lm_cells, lm_smoke_batch

ARCH_ID = "mistral-large-123b"
FAMILY = "lm"
MODULE = lm


def full_config() -> lm.LMConfig:
    return lm.LMConfig(
        name=ARCH_ID,
        n_layers=88,
        d_model=12288,
        n_heads=96,
        n_kv_heads=8,
        d_head=128,
        d_ff=28672,
        vocab=32768,
        rope_theta=1_000_000.0,
        dtype="bfloat16",
    )


def smoke_config() -> lm.LMConfig:
    return lm.LMConfig(
        name=ARCH_ID + "-smoke",
        n_layers=2,
        d_model=64,
        n_heads=8,
        n_kv_heads=2,
        d_head=8,
        d_ff=128,
        vocab=256,
        dtype="float32",
        kv_block=16,
    )


def cells():
    return lm_cells(full_config())


def smoke_batch(key):
    return lm_smoke_batch(smoke_config(), key)
