"""Shared GNN cell factory: every GNN arch × the 4 assigned graph shapes.

Shapes are padded so sharded dims divide both production meshes (nodes →
×32, edges → ×512); sentinel indices point at the padded tail.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from ..distributed.shardings import GNN_RULES
from .common import Cell, GNN_SHAPES, TRIPLET_CAP, f32, i32


def _pad(x: int, mult: int) -> int:
    return -(-x // mult) * mult


# per-shape task info: (task, n_classes, n_graphs)
SHAPE_TASK = {
    "full_graph_sm": ("node", 7, None),        # cora
    "minibatch_lg": ("node", 41, None),        # reddit
    "ogb_products": ("node", 47, None),
    "molecule": ("graph", 1, 128),
}


def gnn_shape_dims(shape: str) -> tuple[int, int, int]:
    """(padded nodes, padded edges, d_feat)."""
    info = GNN_SHAPES[shape]
    if shape == "minibatch_lg":
        from ..data.neighbor_sampler import padded_sizes

        n, e = padded_sizes(info["batch_nodes"], info["fanout"])
    elif shape == "molecule":
        n = info["n_nodes"] * info["batch"]
        e = info["n_edges"] * info["batch"] * 2
    else:
        n, e = info["n_nodes"], info["n_edges"]
    return _pad(n, 32), _pad(e, 512), info["d_feat"]


def gnn_cells(
    arch: str,
    module,
    base_cfg,
    *,
    with_pos: bool,
    with_triplets: bool,
    flops_fn=None,
) -> dict[str, Cell]:
    cells = {}
    for shape in GNN_SHAPES:
        n, e, d_feat = gnn_shape_dims(shape)
        task, n_classes, n_graphs = SHAPE_TASK[shape]
        kwargs = dict(d_in=d_feat, task=task)
        if hasattr(base_cfg, "n_classes"):
            kwargs["n_classes"] = n_classes if task == "node" else 1
        if n_graphs is not None:
            kwargs["n_graphs"] = n_graphs
        cfg = dataclasses.replace(base_cfg, **kwargs)

        specs = {
            "node_feat": f32(n, d_feat),
            "edge_src": i32(e),
            "edge_dst": i32(e),
        }
        logical = {
            "node_feat": ("nodes", None),
            "edge_src": ("edges",),
            "edge_dst": ("edges",),
        }
        if with_pos:
            specs["pos"] = f32(n, 3)
            logical["pos"] = ("nodes", None)
        if with_triplets:
            t = e * TRIPLET_CAP[shape]
            specs["t_kj"] = i32(t)
            specs["t_ji"] = i32(t)
            logical["t_kj"] = ("edges",)
            logical["t_ji"] = ("edges",)
        if task == "graph":
            specs["node_graph"] = i32(n)
            specs["graph_labels"] = f32(n_graphs)
            logical["node_graph"] = ("nodes",)
            logical["graph_labels"] = (None,)
        else:
            specs["labels"] = i32(n)
            logical["labels"] = ("nodes",)

        cells[shape] = Cell(
            arch=arch,
            shape=shape,
            kind="train",
            family="gnn",
            model_cfg=cfg,
            batch_specs=specs,
            batch_logical=logical,
            rules=GNN_RULES,
            model_flops=flops_fn(cfg, n, e) if flops_fn else 0.0,
        )
    return cells


def gnn_smoke_batch(key_seed: int, *, d_in=16, with_pos=False, with_triplets=False,
                    task="node", n_classes=8, n_graphs=4):
    """Tiny real-array batch for CPU smoke tests."""
    import jax.numpy as jnp

    rng = np.random.default_rng(key_seed)
    N, E = 24, 64
    batch = {
        "node_feat": jnp.asarray(rng.normal(size=(N, d_in)), jnp.float32),
        "edge_src": jnp.asarray(rng.integers(0, N, E), jnp.int32),
        "edge_dst": jnp.asarray(rng.integers(0, N, E), jnp.int32),
    }
    if with_pos:
        batch["pos"] = jnp.asarray(rng.normal(size=(N, 3)), jnp.float32)
    if with_triplets:
        T = 96
        batch["t_kj"] = jnp.asarray(rng.integers(0, E, T), jnp.int32)
        batch["t_ji"] = jnp.asarray(rng.integers(0, E, T), jnp.int32)
    if task == "graph":
        batch["node_graph"] = jnp.asarray(rng.integers(0, n_graphs, N), jnp.int32)
        batch["graph_labels"] = jnp.asarray(rng.normal(size=(n_graphs,)), jnp.float32)
    else:
        batch["labels"] = jnp.asarray(rng.integers(0, n_classes, N), jnp.int32)
    return batch
