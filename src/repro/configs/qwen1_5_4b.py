"""qwen1.5-4b [dense] 40L d_model=2560 20H (MHA kv=20) d_ff=6912
vocab=151936 — QKV bias  [hf:Qwen/Qwen1.5-4B; hf]"""
from __future__ import annotations

from ..models import transformer_lm as lm
from .lm_common import lm_cells, lm_smoke_batch

ARCH_ID = "qwen1.5-4b"
FAMILY = "lm"
MODULE = lm


def full_config() -> lm.LMConfig:
    return lm.LMConfig(
        name=ARCH_ID,
        n_layers=40,
        d_model=2560,
        n_heads=20,
        n_kv_heads=20,
        d_head=128,
        d_ff=6912,
        vocab=151936,
        qkv_bias=True,
        dtype="bfloat16",
    )


def smoke_config() -> lm.LMConfig:
    return lm.LMConfig(
        name=ARCH_ID + "-smoke",
        n_layers=2,
        d_model=40,
        n_heads=5,
        n_kv_heads=5,
        d_head=8,
        d_ff=80,
        vocab=128,
        qkv_bias=True,
        dtype="float32",
        kv_block=16,
    )


def cells():
    return lm_cells(full_config())


def smoke_batch(key):
    return lm_smoke_batch(smoke_config(), key)
