"""Registry of the 10 assigned architectures (+ the paper's own graph
workload).  ``get_arch(name)`` → config module; ``all_cells()`` → the full
40-cell (arch × shape) grid."""
from __future__ import annotations

from . import (
    dbrx_132b,
    deepseek_v2_lite_16b,
    dimenet,
    equiformer_v2,
    gin_tu,
    mistral_large_123b,
    pna,
    qwen1_5_4b,
    qwen2_1_5b,
    sage_graph,
    sasrec,
)

ARCHS = {
    m.ARCH_ID: m
    for m in [
        mistral_large_123b,
        qwen2_1_5b,
        qwen1_5_4b,
        dbrx_132b,
        deepseek_v2_lite_16b,
        pna,
        dimenet,
        equiformer_v2,
        gin_tu,
        sasrec,
    ]
}


def get_arch(name: str):
    return ARCHS[name]


def all_cells():
    """The 40 (architecture × shape) cells."""
    out = {}
    for name, m in ARCHS.items():
        for shape, cell in m.cells().items():
            out[(name, shape)] = cell
    return out


__all__ = ["ARCHS", "get_arch", "all_cells", "sage_graph"]
