"""Shared LM cell factory: every LM arch × the 4 assigned LM shapes."""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from ..distributed.shardings import (
    LM_DECODE_LONG_RULES,
    LM_DECODE_RULES,
    LM_PREFILL_RULES,
    LM_RULES,
)
from ..models import transformer_lm as lm
from .common import Cell, LM_SHAPES, i32, lm_model_flops


def lm_cells(cfg: lm.LMConfig) -> dict[str, Cell]:
    cells = {}
    for shape, info in LM_SHAPES.items():
        seq, gb, kind = info["seq_len"], info["global_batch"], info["kind"]
        notes = ""
        if kind == "train":
            ccfg = cfg
            batch_specs = {"tokens": i32(gb, seq), "targets": i32(gb, seq)}
            batch_logical = {
                "tokens": ("batch", "seq"),
                "targets": ("batch", "seq"),
            }
            rules = LM_RULES
        elif kind == "prefill":
            ccfg = cfg
            batch_specs = {"tokens": i32(gb, seq)}
            batch_logical = {"tokens": ("batch", "seq")}
            rules = LM_PREFILL_RULES
        else:  # decode
            # single-block attention for one-token queries (no kv scan)
            ccfg = dataclasses.replace(cfg, kv_block=seq)
            batch_specs = {
                "tokens": i32(gb, 1),
                "pos": jax.ShapeDtypeStruct((), jnp.int32),
            }
            batch_logical = {"tokens": ("batch", None), "pos": ()}
            rules = LM_DECODE_LONG_RULES if shape == "long_500k" else LM_DECODE_RULES
            if shape == "long_500k":
                notes = (
                    "decode-mode attention is linear in cache length (one query "
                    "token), i.e. sub-quadratic; lowered for all LM archs per "
                    "DESIGN.md §Arch-applicability"
                )
        cells[shape] = Cell(
            arch=cfg.name,
            shape=shape,
            kind=kind,
            family="lm",
            model_cfg=ccfg,
            batch_specs=batch_specs,
            batch_logical=batch_logical,
            rules=rules,
            notes=notes,
            model_flops=lm_model_flops(
                cfg, seq, gb, train=(kind == "train"), decode=(kind == "decode")
            ),
        )
    return cells


def lm_smoke_batch(cfg: lm.LMConfig, key, batch=2, seq=16):
    toks = jax.random.randint(key, (batch, seq), 0, cfg.vocab)
    return {"tokens": toks, "targets": jnp.roll(toks, -1, axis=1)}
