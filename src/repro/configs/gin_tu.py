"""gin-tu [gnn] n_layers=5 d_hidden=64 aggregator=sum eps=learnable
[arXiv:1810.00826; paper]"""
from __future__ import annotations

from ..models.gnn import gin as mod
from .gnn_common import gnn_cells, gnn_smoke_batch

ARCH_ID = "gin-tu"
FAMILY = "gnn"
MODULE = mod


def full_config():
    return mod.GINConfig(name=ARCH_ID, n_layers=5, d_hidden=64)


def smoke_config():
    return mod.GINConfig(name=ARCH_ID + "-smoke", n_layers=2, d_hidden=16,
                         d_in=16, n_classes=1, task="graph", n_graphs=4)


def _flops(cfg, n, e):
    d = cfg.d_hidden
    per_layer = e * d + n * (2 * d * 2 * d * 2)
    return 3.0 * 2 * cfg.n_layers * per_layer


def cells():
    return gnn_cells(ARCH_ID, mod, full_config(), with_pos=False,
                     with_triplets=False, flops_fn=_flops)


def smoke_batch(seed=0):
    return gnn_smoke_batch(seed, task="graph", n_graphs=4)
