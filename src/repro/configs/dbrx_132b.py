"""dbrx-132b [moe] 40L d_model=6144 48H (GQA kv=8) d_ff=10752 (per expert)
vocab=100352, MoE 16e top-4 fine-grained  [hf:databricks/dbrx-base; unverified]"""
from __future__ import annotations

from ..models import transformer_lm as lm
from .lm_common import lm_cells, lm_smoke_batch

ARCH_ID = "dbrx-132b"
FAMILY = "lm"
MODULE = lm


def full_config() -> lm.LMConfig:
    return lm.LMConfig(
        name=ARCH_ID,
        n_layers=40,
        d_model=6144,
        n_heads=48,
        n_kv_heads=8,
        d_head=128,
        d_ff=10752,
        vocab=100352,
        moe=True,
        num_experts=16,
        top_k=4,
        d_ff_expert=10752,
        rope_theta=500_000.0,
        dtype="bfloat16",
    )


def smoke_config() -> lm.LMConfig:
    return lm.LMConfig(
        name=ARCH_ID + "-smoke",
        n_layers=2,
        d_model=48,
        n_heads=6,
        n_kv_heads=2,
        d_head=8,
        d_ff=64,
        vocab=128,
        moe=True,
        num_experts=4,
        top_k=2,
        d_ff_expert=64,
        dtype="float32",
        kv_block=16,
    )


def cells():
    return lm_cells(full_config())


def smoke_batch(key):
    return lm_smoke_batch(smoke_config(), key)
