"""deepseek-v2-lite-16b [moe] 27L d_model=2048 16H d_ff=1408 (per routed
expert) vocab=102400, MoE 64e top-6 — MLA kv_lora=512, 2 shared experts,
first layer dense (d_ff 10944)  [arXiv:2405.04434; hf]"""
from __future__ import annotations

from ..models import transformer_lm as lm
from .lm_common import lm_cells, lm_smoke_batch

ARCH_ID = "deepseek-v2-lite-16b"
FAMILY = "lm"
MODULE = lm


def full_config() -> lm.LMConfig:
    return lm.LMConfig(
        name=ARCH_ID,
        n_layers=27,
        d_model=2048,
        n_heads=16,
        n_kv_heads=16,
        d_head=192,          # nope 128 + rope 64 (decomposed below)
        d_ff=10944,          # the first (dense) layer
        vocab=102400,
        attn="mla",
        kv_lora_rank=512,
        rope_head_dim=64,
        nope_head_dim=128,
        v_head_dim=128,
        moe=True,
        num_experts=64,
        top_k=6,
        n_shared=2,
        d_ff_expert=1408,
        first_dense_layers=1,
        rope_theta=10_000.0,
        dtype="bfloat16",
    )


def smoke_config() -> lm.LMConfig:
    return lm.LMConfig(
        name=ARCH_ID + "-smoke",
        n_layers=3,
        d_model=32,
        n_heads=4,
        n_kv_heads=4,
        d_head=16,
        d_ff=96,
        vocab=128,
        attn="mla",
        kv_lora_rank=16,
        rope_head_dim=8,
        nope_head_dim=8,
        v_head_dim=8,
        moe=True,
        num_experts=8,
        top_k=2,
        n_shared=1,
        d_ff_expert=16,
        first_dense_layers=1,
        dtype="float32",
        kv_block=16,
    )


def cells():
    return lm_cells(full_config())


def smoke_batch(key):
    return lm_smoke_batch(smoke_config(), key)
