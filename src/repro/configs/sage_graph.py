"""The paper's own workload: Sage graph analytics over the PSAM engine.

Not part of the assigned 40-cell grid, but the reproduction's native
configs: RMAT graphs standing in for the paper's web/social inputs, and the
distributed (edge-partitioned) engine cells used by the dry-run's graph
section and by benchmarks/fig1_suite.py."""
from __future__ import annotations

import dataclasses

from ..distributed.shardings import GRAPH_ENGINE_RULES

ARCH_ID = "sage-graph"
FAMILY = "graph"


@dataclasses.dataclass(frozen=True)
class SageGraphConfig:
    name: str = ARCH_ID
    n: int = 1 << 20                # vertices
    m: int = 1 << 24                # directed edges (×2 after symmetrize)
    block_size: int = 128           # F_B, = filter block size
    weighted: bool = True


def full_config():
    # stand-in scale for the paper's inputs, shardable by 512 blocks
    return SageGraphConfig()


def smoke_config():
    return SageGraphConfig(name=ARCH_ID + "-smoke", n=128, m=512, block_size=32)


RULES = GRAPH_ENGINE_RULES
