"""Covering problems (§4.3.3) — MIS, maximal matching, graph coloring,
approximate set cover.

Maximal matching and set cover exercise the graphFilter (§4.2): logically
deleted edges are bit-cleared, never rewritten in the read-only CSR.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax

from ..core.csr import CSRGraph
from ..core.edgemap import edgemap_reduce
from ..core.graph_filter import make_filter, pack_vertices, unpack_bits

INF_I32 = jnp.int32(2**31 - 1)
INF_F32 = jnp.float32(jnp.inf)


# ----------------------------------------------------------------------
def mis(g: CSRGraph, key: jax.Array):
    """Maximal independent set (random-priority rounds, [17]).
    Returns in_set bool[n]."""
    n = g.n
    pri = jax.random.permutation(key, jnp.arange(n, dtype=jnp.int32))

    def body(state):
        undecided, in_set = state
        x = jnp.where(undecided, pri, INF_I32)
        nbr_min, _ = edgemap_reduce(g, undecided, x, monoid="min", mode="auto")
        winners = undecided & (pri < nbr_min)
        # remove winners' neighbors
        hit, _ = edgemap_reduce(
            g, winners, jnp.ones(n, jnp.int32), monoid="max", mode="auto"
        )
        losers = undecided & (hit > 0) & ~winners
        return undecided & ~winners & ~losers, in_set | winners

    def cond(state):
        undecided, _ = state
        return jnp.any(undecided)

    _, in_set = lax.while_loop(
        cond, body, (jnp.ones(n, dtype=bool), jnp.zeros(n, dtype=bool))
    )
    return in_set


# ----------------------------------------------------------------------
def maximal_matching(g: CSRGraph, key: jax.Array):
    """Maximal matching via handshake rounds over the graphFilter.

    Returns partner int32[n] (-1 if unmatched).  Each round: every vertex
    proposes to its min-priority live incident edge's other endpoint; mutual
    proposals match; edges touching matched vertices are *filtered* (bits
    cleared) — the CSR is never written (§4.2, Table 1 'Filter' rows).
    """
    n = g.n
    f0 = make_filter(g)
    src, dst = g.edge_src, g.edge_dst

    def body(state):
        rnd, f, partner = state
        active = unpack_bits(f).reshape(-1)
        umin = jnp.minimum(src, dst)
        umax = jnp.maximum(src, dst)
        h = (
            umin.astype(jnp.uint32) * jnp.uint32(2654435761)
            + umax.astype(jnp.uint32) * jnp.uint32(40503)
            + jnp.uint32(rnd) * jnp.uint32(97)
        )
        h = (h ^ (h >> 15)) * jnp.uint32(2246822519)
        pri = (h >> 1).astype(jnp.int32)  # same for both directions

        big = 2**31 - 1
        pv = jnp.where(active, pri, big)
        ids_d = jnp.where(active, dst, n)
        minpri = jax.ops.segment_min(pv, ids_d, num_segments=n + 1)[:n]
        # candidate partner: min other-endpoint among min-priority edges
        at_min = active & (pri == jnp.take(minpri, dst, mode="fill", fill_value=big))
        cand = jax.ops.segment_min(
            jnp.where(at_min, src, n), ids_d, num_segments=n + 1
        )[:n]
        prop = jnp.where(minpri < big, cand, -1)
        mutual = (prop >= 0) & (jnp.take(prop, jnp.maximum(prop, 0)) == jnp.arange(n))
        partner = jnp.where(mutual & (partner < 0), prop, partner)
        matched = partner >= 0
        keep = ~jnp.take(matched, src, mode="fill", fill_value=True) & ~jnp.take(
            matched, dst, mode="fill", fill_value=True
        )
        f = pack_vertices(g, f, jnp.ones(n, dtype=bool), keep)
        return rnd + 1, f, partner

    def cond(state):
        rnd, f, _ = state
        return (f.num_active_edges > 0) & (rnd < n)

    _, _, partner = lax.while_loop(
        cond, body, (jnp.int32(0), f0, jnp.full(n, -1, jnp.int32))
    )
    return partner


# ----------------------------------------------------------------------
def coloring(g: CSRGraph, *, num_colors: int = 256):
    """Greedy (Δ+1)-coloring, Jones–Plassmann with largest-degree-first
    priorities.  Returns color int32[n].

    The smallest-available-color (MEX) search uses the §4.2.3 word-at-a-time
    discipline: forbidden colors are scatter-added into an O(n·C/32)-word
    one-hot table and the MEX is an argmax over free slots.
    """
    n, C = g.n, num_colors
    deg = g.degrees
    src, dst, valid = g.edge_src, g.edge_dst, g.edge_valid
    deg_s = jnp.take(deg, src, mode="fill", fill_value=0)
    deg_d = jnp.take(deg, dst, mode="fill", fill_value=0)
    src_higher = (deg_s > deg_d) | ((deg_s == deg_d) & (src < dst))

    def body(state):
        color, _ = state
        uncolored = color < 0
        unc_s = jnp.take(uncolored, src, mode="fill", fill_value=False)
        blocked_e = valid & unc_s & src_higher
        has_higher = (
            jax.ops.segment_max(
                blocked_e.astype(jnp.int32),
                jnp.where(valid, dst, n),
                num_segments=n + 1,
            )[:n]
            > 0
        )
        ready = uncolored & ~has_higher
        # forbidden one-hot from colored neighbors
        col_s = jnp.take(color, src, mode="fill", fill_value=-1)
        contrib = valid & (col_s >= 0)
        forb = (
            jnp.zeros((n + 1, C), jnp.int32)
            .at[jnp.where(contrib, dst, n), jnp.clip(col_s, 0, C - 1)]
            .add(contrib.astype(jnp.int32))[:n]
        )
        mex = jnp.argmax(forb == 0, axis=-1).astype(jnp.int32)
        color = jnp.where(ready, mex, color)
        return color, jnp.any(color < 0)

    color, _ = lax.while_loop(
        lambda s: s[1], body, (jnp.full(n, -1, jnp.int32), jnp.bool_(True))
    )
    return color


# ----------------------------------------------------------------------
def set_cover(
    g: CSRGraph,
    sets_mask: jnp.ndarray,
    key: jax.Array,
    *,
    eps: float = 0.5,
    plan=None,
):
    """(1+ε)-style parallel greedy set cover over a bipartite graph.

    ``sets_mask[v]`` marks set-vertices; their neighbors are elements.
    Returns in_cover bool[n].  Bucketing by ⌈log_{1+ε} coverage⌉ (App. B);
    winners are resolved MaNIS-style with random priorities; covered
    elements are packed out of the graphFilter.

    The two filtered edgeMaps per round — elements awarding themselves to
    their min-priority candidate neighbor, and chosen sets touching their
    still-active elements — go through the planner dispatch with the
    graphFilter's packed bits as ``edge_active``, so they run single-device
    or sharded (``plan=``), compressed or raw; the per-round filter words
    shard in-trace (``shard_edge_active``).  ``g`` stays the *unsharded*
    backend — the O(m/32)-word filter mutation (``pack_vertices``) and the
    win counting are global small-memory passes.
    """
    n = g.n
    elems = ~sets_mask
    src, dst = g.edge_src, g.edge_dst
    gs = g if plan is None else plan.prepare(g)
    f0 = make_filter(g)
    # only set↔element edges participate: pack the rest out up front
    bip = jnp.take(sets_mask, src, mode="fill", fill_value=False) ^ jnp.take(
        sets_mask, dst, mode="fill", fill_value=True
    )
    f0 = pack_vertices(g, f0, jnp.ones(n, dtype=bool), bip & g.edge_valid)
    pri = jax.random.permutation(key, jnp.arange(n, dtype=jnp.int32))
    log1e = float(jnp.log(1.0 + eps))

    def bucket_of(d):
        return jnp.where(
            d > 0, jnp.ceil(jnp.log(jnp.maximum(d, 1).astype(jnp.float32)) / log1e), -1
        ).astype(jnp.int32)

    def body(state):
        rnd, f, in_cover, covered = state
        cov_deg = jnp.where(sets_mask, f.active_deg, 0)
        b = bucket_of(cov_deg)
        top = jnp.max(b)
        cand = sets_mask & (b == top) & (cov_deg > 0) & ~in_cover
        # elements award themselves to their min-priority candidate neighbor:
        # a filtered edgeMap (min monoid) over the live bits — the planner
        # runs it sharded when a mesh plan is given, the filter words riding
        # packed; dst vertices with no live candidate edge come back at the
        # min identity (INF), which never wins below
        win_pri, _ = edgemap_reduce(
            gs, cand, pri, monoid="min", edge_active=f.bits, mode="dense",
            plan=plan,
        )
        active = unpack_bits(f).reshape(-1)
        cand_s = jnp.take(cand, src, mode="fill", fill_value=False)
        award_e = active & cand_s & jnp.take(
            ~covered, dst, mode="fill", fill_value=False
        )
        pri_s = jnp.take(pri, src, mode="fill", fill_value=2**31 - 1)
        # edge is a win for the set if it holds the element's min priority
        won_e = award_e & (pri_s == jnp.take(win_pri, dst, mode="fill", fill_value=-1))
        wins = jax.ops.segment_sum(
            won_e.astype(jnp.int32), jnp.where(won_e, src, n), num_segments=n + 1
        )[:n]
        thresh = jnp.maximum(
            jnp.floor(jnp.exp((top - 1).astype(jnp.float32) * log1e)), 1.0
        ).astype(jnp.int32)
        chosen = cand & (wins >= jnp.minimum(thresh, cov_deg))
        in_cover = in_cover | chosen
        # chosen sets cover all their currently-active elements: the
        # edgeMap's touched mask *is* "received ≥1 live contribution"
        _, cov_hit = edgemap_reduce(
            gs, chosen, jnp.ones(n, jnp.int32), monoid="max",
            edge_active=f.bits, mode="dense", plan=plan,
        )
        covered = covered | (elems & cov_hit)
        keep = ~jnp.take(covered, src, mode="fill", fill_value=False) & ~jnp.take(
            covered, dst, mode="fill", fill_value=False
        )
        f = pack_vertices(g, f, jnp.ones(n, dtype=bool), keep)
        return rnd + 1, f, in_cover, covered

    def cond(state):
        rnd, f, in_cover, covered = state
        coverable = jnp.any(
            elems & ~covered & (jnp.where(elems, f.active_deg, 0) > 0)
        )
        return coverable & (rnd < 4 * n)

    _, _, in_cover, _ = lax.while_loop(
        cond,
        body,
        (
            jnp.int32(0),
            f0,
            jnp.zeros(n, dtype=bool),
            jnp.zeros(n, dtype=bool),
        ),
    )
    return in_cover
