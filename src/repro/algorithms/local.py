"""Local algorithms (paper §3.2 Applicability): personalized PageRank.

"Other problems, such as local search problems including CoSimRank,
personalized PageRank, and other local clustering problems naturally fit in
the regular PSAM model" — the push state (p, r) is O(n) words, the graph is
read-only, and each push round is an edgeMap over the active frontier.

Forward-push PPR (Andersen–Chung–Lang): maintain estimate p and residual r;
while some r[v] ≥ ε·deg(v): push α·r[v] into p[v] and spread
(1−α)·r[v]/deg(v) to neighbors.  Frontier-synchronous variant below pushes
ALL above-threshold vertices each round (standard parallel ACL).
"""
from __future__ import annotations

import jax.numpy as jnp
from jax import lax

from ..core.backend import GraphLike
from ..core.edgemap import edgemap_reduce, edgemap_reduce_batched


def personalized_pagerank(
    g: GraphLike,
    src: int,
    *,
    alpha: float = 0.15,
    eps: float = 1e-6,
    max_rounds: int = 200,
    mode: str = "auto",
    plan=None,
):
    """Returns (p float32[n], residual float32[n], rounds int32).

    Guarantee (ACL): |p[v] − π(v)| ≤ ε·deg(v) at termination.
    ``plan`` routes each push round through the planner dispatch — the same
    loop runs single-device or sharded over a mesh, compressed or raw.
    """
    n = g.n
    if plan is not None:
        g = plan.prepare(g)
    deg = jnp.maximum(g.degrees, 1).astype(jnp.float32)
    p0 = jnp.zeros(n, jnp.float32)
    r0 = jnp.zeros(n, jnp.float32).at[src].set(1.0)

    def body(state):
        p, r, rounds = state
        active = r >= eps * deg
        pushed = jnp.where(active, r, 0.0)
        p = p + alpha * pushed
        # spread (1-α)·pushed/deg along out-edges
        contrib = jnp.where(active, (1.0 - alpha) * pushed / deg, 0.0)
        s, _ = edgemap_reduce(g, active, contrib, monoid="sum", mode=mode, plan=plan)
        r = jnp.where(active, 0.0, r) + s
        return p, r, rounds + 1

    def cond(state):
        _, r, rounds = state
        return jnp.any(r >= eps * deg) & (rounds < max_rounds)

    p, r, rounds = lax.while_loop(cond, body, (p0, r0, jnp.int32(0)))
    return p, r, rounds


def personalized_pagerank_batched(
    g: GraphLike,
    sources,
    *,
    alpha: float = 0.15,
    eps: float = 1e-6,
    max_rounds: int = 200,
    mode: str = "auto",
    plan=None,
):
    """B concurrent PPR queries through one shared push sweep per round.

    ``sources`` is int[B]; returns (p float32[B, n], residual float32[B, n],
    rounds int32[B]).  Each round pushes every query's above-threshold
    residual mass through ONE batched edgeMap — the edge-block stream is
    read once for the whole batch.  A query that has converged (or hit
    ``max_rounds``) is gated out of the frontier, so its rows freeze and
    its per-query ``rounds`` counter stops: every row is bit-identical to
    the corresponding single-query ``personalized_pagerank`` run on the
    same plan.
    """
    n = g.n
    if plan is not None:
        g = plan.prepare(g)
    srcs = jnp.asarray(sources, jnp.int32)
    B = srcs.shape[0]
    deg = jnp.maximum(g.degrees, 1).astype(jnp.float32)
    ids = jnp.arange(n, dtype=jnp.int32)
    p0 = jnp.zeros((B, n), jnp.float32)
    r0 = jnp.where(ids[None, :] == srcs[:, None], 1.0, 0.0).astype(jnp.float32)

    def body(state):
        p, r, rounds = state
        # per-query run gate: mirrors the single-query loop condition, so a
        # converged or capped query executes "no body" from here on
        run = jnp.any(r >= eps * deg[None, :], axis=1) & (rounds < max_rounds)
        active = (r >= eps * deg[None, :]) & run[:, None]
        pushed = jnp.where(active, r, 0.0)
        p = p + alpha * pushed
        contrib = jnp.where(active, (1.0 - alpha) * pushed / deg[None, :], 0.0)
        s, _ = edgemap_reduce_batched(
            g, active, contrib, monoid="sum", mode=mode, plan=plan
        )
        r = jnp.where(active, 0.0, r) + s
        return p, r, rounds + run.astype(jnp.int32)

    def cond(state):
        _, r, rounds = state
        return jnp.any(
            jnp.any(r >= eps * deg[None, :], axis=1) & (rounds < max_rounds)
        )

    p, r, rounds = lax.while_loop(cond, body, (p0, r0, jnp.zeros(B, jnp.int32)))
    return p, r, rounds


def ppr_matrix_oracle(g: GraphLike, src: int, *, alpha: float = 0.15, iters: int = 2000):
    """Dense power-iteration oracle: π = α·e_s + (1−α)·Wᵀπ (for tests)."""
    import numpy as np

    n = g.n
    s = np.asarray(g.edge_src)
    d = np.asarray(g.edge_dst)
    valid = d < n
    deg = np.maximum(np.bincount(s[valid], minlength=n), 1)
    pi = np.zeros(n)
    pi[src] = 1.0
    e = np.zeros(n)
    e[src] = 1.0
    for _ in range(iters):
        agg = np.zeros(n)
        np.add.at(agg, d[valid], (pi / deg)[s[valid]])
        new = alpha * e + (1 - alpha) * agg
        if np.abs(new - pi).sum() < 1e-12:
            break
        pi = new
    return pi
