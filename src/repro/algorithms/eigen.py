"""Eigenvector problems (§4.3.5) — PageRank.

One iteration is a single dense edgeMap with the sum monoid; the per-vertex
aggregation is a parallel segment-reduce (the paper's depth improvement over
Ligra's sequential neighbor scan).  O(P_it·m) work, O(P_it·log n) depth.
"""
from __future__ import annotations

import jax.numpy as jnp

from ..core.backend import GraphLike
from ..core.edgemap import edgemap_reduce, edgemap_reduce_batched
from ..core.plan import round_loop


def pagerank(
    g: GraphLike,
    *,
    damping: float = 0.85,
    eps: float = 1e-6,
    max_iters: int = 100,
    plan=None,
):
    """Returns (pr float32[n], iters int32).

    ``plan`` (``repro.core.plan``) picks the execution target — the same
    iteration runs single-device or sharded over a mesh, compressed or raw
    (degrees are read off the unsharded graph; they are O(n) vertex state).
    """
    n = g.n
    if plan is not None:
        g = plan.prepare(g)
    deg = jnp.maximum(g.degrees, 1).astype(jnp.float32)
    dangling = g.degrees == 0
    full_mask = jnp.ones(n, dtype=bool)
    pr0 = jnp.full(n, 1.0 / n, jnp.float32)

    def sweep_inputs(state):
        pr, _, _ = state
        contrib = jnp.where(dangling, 0.0, pr / deg)
        return state, full_mask, contrib

    def epilogue(state, s, _touched):
        pr, it, _ = state
        dangling_mass = jnp.sum(jnp.where(dangling, pr, 0.0))
        new = (1.0 - damping) / n + damping * (s + dangling_mass / n)
        err = jnp.sum(jnp.abs(new - pr))
        return new, it + 1, err

    def cond(state):
        _, it, err = state
        return (err > eps) & (it < max_iters)

    pr, iters, _ = round_loop(
        g, (pr0, jnp.int32(0), jnp.float32(jnp.inf)),
        sweep_inputs=sweep_inputs, epilogue=epilogue, cond_fn=cond,
        monoid="sum", plan=plan, mode="dense",
    )
    return pr, iters


def pagerank_iteration(g: GraphLike, pr: jnp.ndarray, *, damping: float = 0.85, plan=None):
    """A single PageRank iteration (Table 1 'PageRank Iteration' row)."""
    n = g.n
    if plan is not None:
        g = plan.prepare(g)
    deg = jnp.maximum(g.degrees, 1).astype(jnp.float32)
    dangling = g.degrees == 0
    contrib = jnp.where(dangling, 0.0, pr / deg)
    s, _ = edgemap_reduce(
        g, jnp.ones(n, dtype=bool), contrib, monoid="sum", mode="dense", plan=plan
    )
    dangling_mass = jnp.sum(jnp.where(dangling, pr, 0.0))
    return (1.0 - damping) / n + damping * (s + dangling_mass / n)


def pagerank_iteration_batched(
    g: GraphLike, prs: jnp.ndarray, *, damping: float = 0.85, plan=None
):
    """B PageRank iterations over B score vectors in one dense edge sweep.

    ``prs`` is float32[B, n] (one tentative PageRank vector per query);
    returns float32[B, n].  The batch shares a single dense sum-monoid
    edgeMap — the whole-graph block stream is read once — and each row is
    bit-identical to ``pagerank_iteration`` on that row alone (same plan).
    """
    n = g.n
    if plan is not None:
        g = plan.prepare(g)
    B = prs.shape[0]
    deg = jnp.maximum(g.degrees, 1).astype(jnp.float32)
    dangling = g.degrees == 0
    contrib = jnp.where(dangling[None, :], 0.0, prs / deg[None, :])
    s, _ = edgemap_reduce_batched(
        g,
        jnp.ones((B, n), dtype=bool),
        contrib,
        monoid="sum",
        mode="dense",
        plan=plan,
    )
    dangling_mass = jnp.sum(jnp.where(dangling[None, :], prs, 0.0), axis=1)
    return (1.0 - damping) / n + damping * (s + dangling_mass[:, None] / n)
