"""Shortest-path problems (§4.3.1) — BFS, wBFS (integral Dijkstra),
Bellman-Ford, single-source widest path, single-source betweenness.

All are frontier loops over EDGEMAPCHUNKED (direction-optimized).  Mutable
state is strictly O(n) words.  CAS-based ``updateAtomic`` from the paper's
BFS (Fig. 4) becomes an idempotent min-reduction over candidate parents —
any in-frontier parent is a valid BFS-tree parent, so priority-min is a
legal determinization.

``bfs_batched`` / ``wbfs_batched`` are the serving-path entry points: B
concurrent queries advance in lockstep through ONE batched edgeMap per
round (``edgemap_reduce_batched``), so the NVRAM edge sweep is shared by
the whole batch.  Finished queries' state is inert in later rounds (empty
frontiers touch nothing; capped/settled rows are gated), which makes every
query's result bit-identical to its own single-query run — the parity
contract the serving test suite locks in.  Mutable state is O(B·n) words.
"""
from __future__ import annotations

import jax.numpy as jnp
from jax import lax

from ..core.backend import GraphLike
from ..core.bucketing import NULL_BUCKET, make_buckets
from ..core.edgemap import edgemap_reduce, edgemap_reduce_batched
from ..core.plan import round_loop

INF_I32 = jnp.int32(2**31 - 1)
UNVISITED = jnp.int32(-1)


def _root_masks(n: int, sources) -> jnp.ndarray:
    """Normalize (B,) int sources or (B, n) root masks to bool[B, n].

    Dispatch is by RANK, never dtype: a 2-D array is always per-query root
    masks (any truthy dtype, like the old multi_source_bfs accepted), a 1-D
    non-bool array is always source ids — so an int 0/1 mask can never be
    misread as vertex ids."""
    roots = jnp.asarray(sources)
    if roots.ndim == 2:
        if roots.shape[1] != n:
            raise ValueError(f"root masks must be (B, {n}), got {roots.shape}")
        return roots.astype(bool)
    if roots.ndim == 1 and roots.dtype != jnp.bool_:
        return (
            jnp.arange(n, dtype=jnp.int32)[None, :]
            == roots.astype(jnp.int32)[:, None]
        )
    raise ValueError(
        f"sources must be int[B] vertex ids or (B, {n}) root masks, got "
        f"{roots.dtype}{list(roots.shape)}"
    )


def bfs(g: GraphLike, src: int, *, mode: str = "auto", plan=None):
    """Breadth-first search.  Returns (parents int32[n], levels int32[n]).

    parents[v] = -1 if unreachable, src for the source itself.
    PSAM: O(m) work, O(d_G log n) depth, O(n) words small memory (Thm 4.2).
    ``plan`` (``repro.core.plan``) picks the execution target — the same
    loop runs single-device or sharded over a mesh, compressed or raw.
    """
    n = g.n
    if plan is not None:
        g = plan.prepare(g)
    src = jnp.asarray(src, jnp.int32)
    parents0 = jnp.full(n, UNVISITED).at[src].set(src)
    levels0 = jnp.full(n, UNVISITED).at[src].set(0)
    frontier0 = jnp.zeros(n, dtype=bool).at[src].set(True)
    ids = jnp.arange(n, dtype=jnp.int32)

    def sweep_inputs(state):
        _, _, _, frontier = state
        return state, frontier, ids

    def epilogue(state, cand, touched):
        rnd, parents, levels, _ = state
        newly = touched & (parents == UNVISITED)
        parents = jnp.where(newly, cand, parents)
        levels = jnp.where(newly, rnd + 1, levels)
        return rnd + 1, parents, levels, newly

    def cond(state):
        rnd, _, _, frontier = state
        return jnp.any(frontier) & (rnd < n)

    _, parents, levels, _ = round_loop(
        g, (jnp.int32(0), parents0, levels0, frontier0),
        sweep_inputs=sweep_inputs, epilogue=epilogue, cond_fn=cond,
        monoid="min", plan=plan, mode=mode,
    )
    return parents, levels


def bfs_batched(g: GraphLike, sources, *, mode: str = "auto", plan=None):
    """B concurrent BFS queries through one shared edge sweep per round.

    ``sources`` is either int[B] source vertices or bool[B, n] per-query
    root masks (a row with several roots runs that query as a BFS forest —
    ``multi_source_bfs`` is the B=1 case).  Returns (parents int32[B, n],
    levels int32[B, n]), each row bit-identical to the corresponding
    single-query ``bfs`` / ``multi_source_bfs`` run on the same plan: the
    lockstep loop runs until the last query's frontier drains, and a
    drained query's empty frontier touches nothing, so its rows are frozen.

    PSAM: the per-round edge-block reads are paid once for the whole batch
    (``PSAMCost.charge_edgemap_batched``); mutable state is O(B·n) words.
    ``plan`` routes every round through the planner dispatch — the same
    loop serves single-device or sharded, compressed or raw.
    """
    n = g.n
    if plan is not None:
        g = plan.prepare(g)
    roots = _root_masks(n, sources)
    B = roots.shape[0]
    ids = jnp.arange(n, dtype=jnp.int32)
    idsb = jnp.broadcast_to(ids, (B, n))
    parents0 = jnp.where(roots, idsb, UNVISITED)
    levels0 = jnp.where(roots, 0, UNVISITED)

    def sweep_inputs(state):
        _, _, _, frontier = state
        return state, frontier, idsb

    def epilogue(state, cand, touched):
        rnd, parents, levels, _ = state
        newly = touched & (parents == UNVISITED)
        parents = jnp.where(newly, cand, parents)
        levels = jnp.where(newly, rnd + 1, levels)
        return rnd + 1, parents, levels, newly

    def cond(state):
        rnd, _, _, frontier = state
        return jnp.any(frontier) & (rnd < n)

    _, parents, levels, _ = round_loop(
        g, (jnp.int32(0), parents0, levels0, roots),
        sweep_inputs=sweep_inputs, epilogue=epilogue, cond_fn=cond,
        monoid="min", plan=plan, mode=mode, batched=True,
    )
    return parents, levels


def wbfs(g: GraphLike, src: int, *, mode: str = "auto", plan=None):
    """Integral-weight SSSP via bucketed Dijkstra (Julienne-style, App. B).

    Weights are read from ``g.edge_w`` and truncated to int32.  Returns
    dist int32[n] (INF for unreachable).  The bucket structure is
    ``repro.core.bucketing.Buckets`` — the dense O(n) semi-eager variant:
    each round rebuilds ``bucket_of`` from the tentative distances (one
    O(n) write), and extracting the next bucket is a min-reduce.  Bucket
    ids clamp at ``NULL_BUCKET - 1`` (the retired marker is 2³⁰), so the
    extracted bucket may span several true distances past 2³⁰; the body
    settles only the exact minimum among its members, keeping Dijkstra's
    invariant over the full int32 range.

    ``plan`` (``repro.core.plan``) picks the execution target: the weighted
    relaxations stream the uncompressed weight tiles per shard while the
    targets move compressed — the same loop runs single-device or sharded,
    either backend.
    """
    n = g.n
    if plan is not None:
        g = plan.prepare(g)
    src = jnp.asarray(src, jnp.int32)
    dist0 = jnp.full(n, INF_I32).at[src].set(0)
    settled0 = jnp.zeros(n, dtype=bool)

    def relax(xs, w):
        wi = w.astype(jnp.int32)
        return jnp.where(xs >= INF_I32 - jnp.int32(1 << 24), INF_I32, xs + wi)

    def buckets(dist, settled):
        return make_buckets(
            jnp.where(
                settled | (dist == INF_I32),
                NULL_BUCKET,
                jnp.minimum(dist, NULL_BUCKET - 1),
            )
        )

    def sweep_inputs(state):
        dist, settled = state
        _, members, _ = buckets(dist, settled).next_bucket()
        members = members & ~settled
        d = jnp.min(jnp.where(members, dist, INF_I32))
        frontier = members & (dist == d)
        settled = settled | frontier
        return (dist, settled), frontier, dist

    def epilogue(state, cand, touched):
        dist, settled = state
        improve = touched & ~settled & (cand < dist)
        dist = jnp.where(improve, cand, dist)
        return dist, settled

    def cond(state):
        dist, settled = state
        return buckets(dist, settled).next_bucket()[2]

    dist, _ = round_loop(
        g, (dist0, settled0),
        sweep_inputs=sweep_inputs, epilogue=epilogue, cond_fn=cond,
        monoid="min", plan=plan, map_fn=relax, mode=mode,
    )
    return dist


def wbfs_batched(g: GraphLike, sources, *, mode: str = "auto", plan=None):
    """B concurrent wBFS (bucketed Dijkstra) queries, one edge sweep each
    round.  ``sources`` is int[B]; returns dist int32[B, n].

    Each row runs the exact single-query ``wbfs`` recurrence — per-row
    bucket extraction is a row-wise min — gated by a per-query ``run`` flag
    so a query whose buckets have drained stops mutating its row while the
    rest of the batch finishes (the bucket-of-the-done-row degenerates to
    NULL for every vertex, which ungated would re-frontier its unreachable
    vertices).  Bit-identical per query to ``wbfs`` on the same plan; the
    weighted relaxations stream one weight tile per round for the whole
    batch.
    """
    n = g.n
    if plan is not None:
        g = plan.prepare(g)
    srcs = jnp.asarray(sources, jnp.int32)
    B = srcs.shape[0]
    ids = jnp.arange(n, dtype=jnp.int32)
    dist0 = jnp.where(ids[None, :] == srcs[:, None], 0, INF_I32)
    settled0 = jnp.zeros((B, n), dtype=bool)

    def relax(xs, w):
        wi = w.astype(jnp.int32)
        return jnp.where(xs >= INF_I32 - jnp.int32(1 << 24), INF_I32, xs + wi)

    def bucket_of(dist, settled):
        return jnp.where(
            settled | (dist == INF_I32),
            NULL_BUCKET,
            jnp.minimum(dist, NULL_BUCKET - 1),
        )

    def sweep_inputs(state):
        dist, settled = state
        bo = bucket_of(dist, settled)
        bid = jnp.min(bo, axis=1)              # per-query next bucket
        run = bid < NULL_BUCKET                # queries with work left
        members = (bo == bid[:, None]) & ~settled & run[:, None]
        d = jnp.min(jnp.where(members, dist, INF_I32), axis=1)
        frontier = members & (dist == d[:, None])
        settled = settled | frontier
        return (dist, settled), frontier, dist

    def epilogue(state, cand, touched):
        dist, settled = state
        improve = touched & ~settled & (cand < dist)
        dist = jnp.where(improve, cand, dist)
        return dist, settled

    def cond(state):
        dist, settled = state
        return jnp.any(bucket_of(dist, settled) < NULL_BUCKET)

    dist, _ = round_loop(
        g, (dist0, settled0),
        sweep_inputs=sweep_inputs, epilogue=epilogue, cond_fn=cond,
        monoid="min", plan=plan, map_fn=relax, mode=mode, batched=True,
    )
    return dist


def _cohort_relax(xs, w):
    """wBFS relaxation for cohort lanes: int32 saturating xs + w."""
    wi = w.astype(jnp.int32)
    return jnp.where(xs >= INF_I32 - jnp.int32(1 << 24), INF_I32, xs + wi)


def _bucket_of(dist, settled):
    """Per-vertex bucket id for the dense semi-eager wBFS bucketing."""
    return jnp.where(
        settled | (dist == INF_I32),
        NULL_BUCKET,
        jnp.minimum(dist, NULL_BUCKET - 1),
    )


def traversal_cohort_init(g: GraphLike, ops, sources):
    """Build the fused BFS+wBFS cohort state for one serving drain.

    ``ops`` is a sequence of ``"bfs"`` / ``"wbfs"`` lane kinds and
    ``sources`` the matching int vertex ids; a source of ``-1`` makes an
    inert padding lane (empty root set — it never frontiers, is never
    active, and costs zero rounds of attribution).  Returns
    ``(state, weighted)``: ``state`` is the pytree that
    :func:`traversal_cohort_rounds` advances — ``parents`` / ``levels``
    int32[B, n] (BFS lanes), ``dist`` int32[B, n] / ``settled`` bool[B, n]
    (wBFS lanes), ``frontier`` bool[B, n] (the BFS "newly" set), and the
    scalar round counter ``rnd`` — and ``weighted`` is the static tuple of
    per-lane bools that selects each lane's per-edge map (the
    ``map_lanes`` argument of the shared sweep).

    The serving scheduler repacks this state between quanta — slicing the
    leading B axis down to the still-active lanes — which is legal because
    every batched edgeMap is per-lane independent (the bit-parity contract
    ``tests/test_serving.py`` locks in).
    """
    n = g.n
    ops = tuple(ops)
    for op in ops:
        if op not in ("bfs", "wbfs"):
            raise ValueError(f"cohort lanes must be 'bfs' or 'wbfs', got {op!r}")
    srcs = jnp.asarray(sources, jnp.int32)
    B = len(ops)
    if srcs.shape != (B,):
        raise ValueError(f"sources must be int[{B}], got shape {srcs.shape}")
    weighted = tuple(op == "wbfs" for op in ops)
    wvec = jnp.asarray(weighted)
    roots = jnp.arange(n, dtype=jnp.int32)[None, :] == srcs[:, None]
    broots = roots & ~wvec[:, None]
    wroots = roots & wvec[:, None]
    ids = jnp.arange(n, dtype=jnp.int32)
    idsb = jnp.broadcast_to(ids, (B, n))
    state = {
        "parents": jnp.where(broots, idsb, UNVISITED),
        "levels": jnp.where(broots, 0, UNVISITED),
        "dist": jnp.where(wroots, 0, INF_I32),
        "settled": jnp.zeros((B, n), dtype=bool),
        "frontier": broots,
        "rnd": jnp.int32(0),
    }
    return state, weighted


def traversal_cohort_active(state, weighted, n: int) -> jnp.ndarray:
    """bool[B]: which cohort lanes still have work left.

    A BFS lane is active while its frontier is nonempty and the round cap
    ``rnd < n`` holds; a wBFS lane while any vertex sits in a non-NULL
    bucket.  Activity is prefix-monotone — a drained lane can never
    reactivate — which is what lets the serving scheduler reconstruct
    round-r active counts from the per-lane round totals.  ``weighted``
    is static, so single-kind cohorts skip the other kind's state scan
    entirely (a pure-BFS cohort costs exactly ``bfs_batched``'s check).
    """
    b_active = jnp.any(state["frontier"], axis=1) & (state["rnd"] < n)
    if not any(weighted):
        return b_active
    wvec = jnp.asarray(weighted)
    bo = _bucket_of(state["dist"], state["settled"])
    w_active = wvec & (jnp.min(bo, axis=1) < NULL_BUCKET)
    if all(weighted):
        return w_active
    return w_active | (~wvec & b_active)


def traversal_cohort_rounds(
    g: GraphLike,
    state,
    weighted,
    *,
    quantum: int = 4,
    mode: str = "auto",
    plan=None,
):
    """Advance a fused BFS+wBFS cohort by up to ``quantum`` shared rounds.

    One call = one jitted ``lax.while_loop`` of at most ``quantum``
    rounds, each round ONE batched edge sweep shared by every lane:
    wBFS lanes relax distances (``map_lanes`` selects the weighted map),
    BFS lanes propagate candidate parent ids through the identity map —
    both int32 min-monoid, so they fuse bit-exactly.  Stops early when
    every lane drains.  Returns ``(state, lane_rounds, active)``:
    ``lane_rounds`` int32[B] counts the rounds each lane was active inside
    this call (the early-exit accounting quantum — a drained lane stops
    being charged), ``active`` bool[B] flags lanes with work remaining.

    The quantum bound is what lets the serving scheduler repack between
    calls — narrowing B to the next power of two once lanes drain, so a
    finished query also stops occupying a batch column.  Each lane's rows
    stay bit-identical to its single-query ``bfs`` / ``wbfs`` run: drained
    BFS frontiers touch nothing, drained wBFS lanes are run-gated, and the
    per-lane independence of the batched edgeMap makes the repack slice
    invisible to the remaining lanes.
    """
    n = g.n
    if plan is not None:
        g = plan.prepare(g)
    weighted = tuple(bool(w) for w in weighted)
    B = len(weighted)
    any_w, all_w = any(weighted), all(weighted)
    wvec = jnp.asarray(weighted)
    ids = jnp.arange(n, dtype=jnp.int32)
    idsb = jnp.broadcast_to(ids, (B, n))
    sweep_kw = {}
    if any_w:
        sweep_kw["map_fn"] = _cohort_relax
        if not all_w:
            sweep_kw["map_lanes"] = wvec

    def body(carry):
        q, st, lane_rounds = carry
        parents, levels = st["parents"], st["levels"]
        dist, settled = st["dist"], st["settled"]
        frontier, rnd = st["frontier"], st["rnd"]
        active = traversal_cohort_active(st, weighted, n)
        bfr = frontier & (rnd < n)
        if any_w:
            bo = _bucket_of(dist, settled)
            bid = jnp.min(bo, axis=1)
            run = wvec & (bid < NULL_BUCKET)
            members = (bo == bid[:, None]) & ~settled & run[:, None]
            d = jnp.min(jnp.where(members, dist, INF_I32), axis=1)
            wfr = members & (dist == d[:, None])
            settled = settled | wfr
            fr = jnp.where(wvec[:, None], wfr, bfr)
            xs = jnp.where(wvec[:, None], dist, idsb)
        else:
            fr, xs = bfr, idsb
        cand, touched = edgemap_reduce_batched(
            g, fr, xs, monoid="min", mode=mode, plan=plan, **sweep_kw
        )
        newly = touched & (parents == UNVISITED) & ~wvec[:, None]
        parents = jnp.where(newly, cand, parents)
        levels = jnp.where(newly, rnd + 1, levels)
        if any_w:
            improve = touched & ~settled & (cand < dist) & wvec[:, None]
            dist = jnp.where(improve, cand, dist)
        st = {
            "parents": parents,
            "levels": levels,
            "dist": dist,
            "settled": settled,
            "frontier": newly,
            "rnd": rnd + 1,
        }
        return q + 1, st, lane_rounds + active.astype(jnp.int32)

    def cond(carry):
        q, st, _ = carry
        return (q < quantum) & jnp.any(traversal_cohort_active(st, weighted, n))

    _, state, lane_rounds = lax.while_loop(
        cond, body, (jnp.int32(0), state, jnp.zeros(B, jnp.int32))
    )
    return state, lane_rounds, traversal_cohort_active(state, weighted, n)


def bellman_ford(g: GraphLike, src: int, *, mode: str = "auto", plan=None):
    """General-weight SSSP.  Returns (dist float32[n], has_neg_cycle bool).

    Vertices reachable from a negative cycle get -inf (App. C.1 spec).
    ``plan`` routes the weighted relaxation rounds through the planner
    dispatch — single-device or sharded mesh, compressed or raw.
    """
    n = g.n
    if plan is not None:
        g = plan.prepare(g)
    src = jnp.asarray(src, jnp.int32)
    dist0 = jnp.full(n, jnp.inf, jnp.float32).at[src].set(0.0)
    frontier0 = jnp.zeros(n, dtype=bool).at[src].set(True)

    def relax(xs, w):
        return xs + w

    def body(state):
        rnd, dist, frontier = state
        cand, touched = edgemap_reduce(
            g, frontier, dist, monoid="min", map_fn=relax, mode=mode, plan=plan
        )
        improve = touched & (cand < dist)
        dist = jnp.where(improve, cand, dist)
        return rnd + 1, dist, improve

    def cond(state):
        rnd, _, frontier = state
        return jnp.any(frontier) & (rnd <= n)

    rnd, dist, frontier = lax.while_loop(
        cond, body, (jnp.int32(0), dist0, frontier0)
    )
    has_neg_cycle = jnp.any(frontier)

    # propagate -inf from the still-improving set (bounded BFS)
    def prop_body(state):
        i, dist, fr = state
        _, touched = edgemap_reduce(g, fr, dist, monoid="min", mode=mode, plan=plan)
        newly = touched & (dist > -jnp.inf)
        dist = jnp.where(fr | newly, -jnp.inf, dist)
        return i + 1, dist, newly

    def prop_cond(state):
        i, _, fr = state
        return jnp.any(fr) & (i < n)

    _, dist, _ = lax.while_loop(
        prop_cond,
        prop_body,
        (jnp.int32(0), jnp.where(frontier, -jnp.inf, dist), frontier),
    )
    return dist, has_neg_cycle


def widest_path(g: GraphLike, src: int, *, mode: str = "auto", plan=None):
    """Single-source widest path (max-min path semiring), Bellman-Ford style.

    Returns width float32[n]; -inf for unreachable, +inf for the source.
    ``plan`` routes the max-monoid relaxations through the planner dispatch
    — single-device or sharded mesh, compressed or raw.
    """
    n = g.n
    if plan is not None:
        g = plan.prepare(g)
    src = jnp.asarray(src, jnp.int32)
    width0 = jnp.full(n, -jnp.inf, jnp.float32).at[src].set(jnp.inf)
    frontier0 = jnp.zeros(n, dtype=bool).at[src].set(True)

    def bottleneck(xs, w):
        return jnp.minimum(xs, w)

    def body(state):
        rnd, width, frontier = state
        cand, touched = edgemap_reduce(
            g, frontier, width, monoid="max", map_fn=bottleneck, mode=mode, plan=plan
        )
        improve = touched & (cand > width)
        width = jnp.where(improve, cand, width)
        return rnd + 1, width, improve

    def cond(state):
        rnd, _, frontier = state
        return jnp.any(frontier) & (rnd <= n)

    _, width, _ = lax.while_loop(cond, body, (jnp.int32(0), width0, frontier0))
    return width


def betweenness(g: GraphLike, src: int, *, mode: str = "auto", plan=None):
    """Single-source betweenness centrality (Brandes forward/backward).

    Returns delta float32[n] — the dependency scores from src.
    Forward: level-synchronous sigma accumulation (edgeMapChunked, sum
    monoid).  Backward: levels replayed in reverse.  O(n) words of state:
    levels, sigma, delta.  ``plan`` routes both passes' sum-monoid edgeMaps
    through the planner dispatch — single-device or sharded, either backend.
    """
    n = g.n
    if plan is not None:
        g = plan.prepare(g)
    src = jnp.asarray(src, jnp.int32)
    level0 = jnp.full(n, UNVISITED).at[src].set(0)
    sigma0 = jnp.zeros(n, jnp.float32).at[src].set(1.0)
    frontier0 = jnp.zeros(n, dtype=bool).at[src].set(True)

    def fwd_body(state):
        lvl, level, sigma, frontier = state
        cand, touched = edgemap_reduce(
            g, frontier, sigma, monoid="sum", mode=mode, plan=plan
        )
        newly = touched & (level == UNVISITED)
        sigma = jnp.where(newly, cand, sigma)
        level = jnp.where(newly, lvl + 1, level)
        return lvl + 1, level, sigma, newly

    def fwd_cond(state):
        lvl, _, _, frontier = state
        return jnp.any(frontier) & (lvl < n)

    max_lvl, level, sigma, _ = lax.while_loop(
        fwd_cond, fwd_body, (jnp.int32(0), level0, sigma0, frontier0)
    )

    delta0 = jnp.zeros(n, jnp.float32)

    def bwd_body(state):
        lvl, delta = state
        upper = level == lvl  # vertices one level deeper
        y = jnp.where(sigma > 0, (1.0 + delta) / jnp.maximum(sigma, 1e-30), 0.0)
        y = jnp.where(upper, y, 0.0)
        s, _ = edgemap_reduce(g, upper, y, monoid="sum", mode=mode, plan=plan)
        delta = jnp.where(level == lvl - 1, sigma * s, delta)
        return lvl - 1, delta

    def bwd_cond(state):
        lvl, _ = state
        return lvl >= 1

    _, delta = lax.while_loop(bwd_cond, bwd_body, (max_lvl, delta0))
    return delta.at[src].set(0.0)
