"""The 18 Sage algorithms (Table 1), grouped as in §4.3."""
from .covering import coloring, maximal_matching, mis, set_cover
from .decomposition import (
    biconnectivity,
    connectivity,
    ldd,
    multi_source_bfs,
    spanner,
    spanning_forest,
)
from .eigen import pagerank, pagerank_iteration, pagerank_iteration_batched
from .local import personalized_pagerank, personalized_pagerank_batched
from .substructure import densest_subgraph, kcore, orientation_filter, triangle_count
from .traversal import (
    bellman_ford,
    betweenness,
    bfs,
    bfs_batched,
    traversal_cohort_active,
    traversal_cohort_init,
    traversal_cohort_rounds,
    wbfs,
    wbfs_batched,
    widest_path,
)

ALL_PROBLEMS = [
    "bfs",
    "wbfs",
    "bellman_ford",
    "widest_path",
    "betweenness",
    "spanner",
    "ldd",
    "connectivity",
    "spanning_forest",
    "biconnectivity",
    "coloring",
    "mis",
    "maximal_matching",
    "set_cover",
    "triangle_count",
    "kcore",
    "densest_subgraph",
    "pagerank",
]

__all__ = ALL_PROBLEMS + [
    "personalized_pagerank",
    "personalized_pagerank_batched",
    "pagerank_iteration",
    "pagerank_iteration_batched",
    "bfs_batched",
    "wbfs_batched",
    "multi_source_bfs",
    "orientation_filter",
    "traversal_cohort_init",
    "traversal_cohort_rounds",
    "traversal_cohort_active",
    "ALL_PROBLEMS",
]
