"""Substructure problems (§4.3.4) — k-core, approximate densest subgraph,
triangle counting.

k-core / densest subgraph use the dense-histogram peeling discipline
(segment-sum of removed-neighbor counts).  Triangle counting orients edges
low→high degree *through a graphFilter* (the CSR itself is never
re-ordered) and intersects adjacency lists in fixed-size chunks, so the
peak intermediate is O(chunk·Δ⁺) words — the §4.2.3 blocked-decode scheme.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

from ..core.bucketing import NULL_BUCKET, make_buckets
from ..core.csr import CSRGraph
from ..core.edgemap import edgemap_reduce
from ..core.graph_filter import GraphFilter, make_filter, pack_bits

INF_I32 = jnp.int32(2**31 - 1)


# ----------------------------------------------------------------------
def kcore(g: CSRGraph, *, plan=None):
    """Coreness of every vertex — Julienne-style bucketed peeling (App. B).

    ``bucket_of[v]`` is v's current induced degree (retired once peeled);
    each round extracts the minimum non-empty bucket, peels every vertex at
    or below the running core number k, and subtracts the removed-neighbor
    histogram (an edgeMap with the sum monoid).  Returns core int32[n].
    ``plan`` routes the histogram edgeMaps through the planner dispatch —
    single-device or sharded mesh, compressed or raw.
    """
    n = g.n
    if plan is not None:
        g = plan.prepare(g)

    def body(state):
        deg, alive, core, k = state
        mn, _, _ = make_buckets(
            jnp.where(alive, deg, NULL_BUCKET)
        ).next_bucket()
        k = jnp.maximum(k, mn)
        peel = alive & (deg <= k)
        core = jnp.where(peel, k, core)
        cnt, _ = edgemap_reduce(
            g, peel, jnp.ones(n, jnp.int32), monoid="sum", mode="auto", plan=plan
        )
        deg = jnp.maximum(deg - cnt, 0)
        return deg, alive & ~peel, core, k

    def cond(state):
        _, alive, _, _ = state
        return jnp.any(alive)

    _, _, core, _ = lax.while_loop(
        cond,
        body,
        (g.degrees, jnp.ones(n, dtype=bool), jnp.zeros(n, jnp.int32), jnp.int32(0)),
    )
    return core


# ----------------------------------------------------------------------
def densest_subgraph(g: CSRGraph, *, eps: float = 0.001):
    """(2+ε)-approximate densest subgraph (Charikar peeling, parallel).
    Returns (best_mask bool[n], best_density float32)."""
    n = g.n
    thresh = 2.0 * (1.0 + eps)

    def body(state):
        alive, deg, best_mask, best_rho, _ = state
        n_act = jnp.sum(alive).astype(jnp.float32)
        m_act = jnp.sum(jnp.where(alive, deg, 0)).astype(jnp.float32)  # 2|E(S)|
        rho = jnp.where(n_act > 0, m_act / 2.0 / jnp.maximum(n_act, 1.0), 0.0)
        better = rho > best_rho
        best_mask = jnp.where(better, alive, best_mask)
        best_rho = jnp.maximum(best_rho, rho)
        remove = alive & (deg.astype(jnp.float32) <= thresh * rho)
        # guard: always remove at least the min-degree vertices
        remove = jnp.where(
            jnp.any(remove),
            remove,
            alive & (deg == jnp.min(jnp.where(alive, deg, INF_I32))),
        )
        cnt, _ = edgemap_reduce(
            g, remove, jnp.ones(n, jnp.int32), monoid="sum", mode="auto"
        )
        deg = jnp.maximum(deg - cnt, 0)
        return alive & ~remove, deg, best_mask, best_rho, jnp.any(alive & ~remove)

    def cond(state):
        return state[4]

    alive0 = jnp.ones(n, dtype=bool)
    _, _, best_mask, best_rho, _ = lax.while_loop(
        cond,
        body,
        (alive0, g.degrees, alive0, jnp.float32(0.0), jnp.bool_(True)),
    )
    return best_mask, best_rho


# ----------------------------------------------------------------------
def orientation_filter(g: CSRGraph) -> tuple[GraphFilter, np.ndarray]:
    """Low→high degree orientation expressed as a graphFilter (§4.3.4):
    the 'directed' graph is the immutable CSR viewed through bits that keep
    only slots with rank(src) < rank(dst)."""
    n = g.n
    deg = np.asarray(g.degrees).astype(np.int64)
    src = np.asarray(g.edge_src).astype(np.int64)
    dst = np.asarray(g.edge_dst).astype(np.int64)
    valid = dst < n
    key = deg * (n + 1)
    key = np.concatenate([key + np.arange(n), [np.iinfo(np.int64).max]])
    keep = valid & (key[np.minimum(src, n)] < key[np.minimum(dst, n)])
    f = make_filter(g)
    bits = pack_bits(jnp.asarray(keep.reshape(g.num_blocks, g.block_size)))
    deg_or = np.bincount(src[keep], minlength=n)
    f = GraphFilter(
        bits=bits,
        active_deg=jnp.asarray(deg_or, jnp.int32),
        dirty=f.dirty,
        n=n,
        num_blocks=f.num_blocks,
        block_size=f.block_size,
    )
    return f, keep


def triangle_count(g: CSRGraph, *, chunk: int = 16384) -> int:
    """Exact global triangle count.  Orients via ``orientation_filter`` and
    intersects N⁺(u)/N⁺(v) per directed edge in chunks (blocked decode)."""
    n = g.n
    _, keep = orientation_filter(g)
    src = np.asarray(g.edge_src).astype(np.int64)
    dst = np.asarray(g.edge_dst).astype(np.int64)
    us, vs = src[keep], dst[keep]
    e = us.shape[0]
    if e == 0:
        return 0
    # oriented padded adjacency, rows sorted ascending
    deg_or = np.bincount(us, minlength=n)
    dmax = max(1, int(deg_or.max()))
    SEN = np.int64(2**31 - 2)
    adj = np.full((n + 1, dmax), SEN, dtype=np.int64)
    order = np.lexsort((vs, us))
    uo, vo = us[order], vs[order]
    starts = np.zeros(n + 1, dtype=np.int64)
    np.cumsum(deg_or, out=starts[1:])
    within = np.arange(e) - starts[uo]
    adj[uo, within] = vo
    adj_j = jnp.asarray(adj, jnp.int32)
    us_j = jnp.asarray(us, jnp.int32)
    vs_j = jnp.asarray(vs, jnp.int32)

    @jax.jit
    def count_chunk(u_idx, v_idx):
        au = jnp.take(adj_j, u_idx, axis=0)  # (C, D)
        av = jnp.take(adj_j, v_idx, axis=0)
        pos = jax.vmap(jnp.searchsorted)(av, au)
        pos = jnp.clip(pos, 0, dmax - 1)
        hit = (jnp.take_along_axis(av, pos, axis=1) == au) & (au < jnp.int32(SEN))
        return jnp.sum(hit, dtype=jnp.int32)

    total = 0
    for s in range(0, e, chunk):
        c = min(chunk, e - s)
        pad = chunk - c
        ui = jnp.pad(us_j[s : s + c], (0, pad), constant_values=n)
        vi = jnp.pad(vs_j[s : s + c], (0, pad), constant_values=n)
        total += int(count_chunk(ui, vi))
    return total
