"""Connectivity problems (§4.3.2) — LDD, connectivity, spanning forest,
O(k)-spanner, biconnectivity.

Biconnectivity follows Tarjan–Vishkin over an arbitrary (BFS) spanning tree:
Euler tour + list ranking by pointer jumping gives preorder/subtree sizes,
low/high are propagated up BFS levels, and the auxiliary-graph connectivity
is evaluated *implicitly* through edge-slot masks on the original graph —
no O(m)-word auxiliary structure is materialized (the relaxed-PSAM variant
the paper uses in practice, Table 1 ¶).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax

from ..core.backend import GraphLike
from ..core.edgemap import edgemap_reduce

INF_I32 = jnp.int32(2**31 - 1)
UNVISITED = jnp.int32(-1)


# ----------------------------------------------------------------------
# Low-diameter decomposition (Miller–Peng–Xu with quantized shifts)
# ----------------------------------------------------------------------
def ldd(g: GraphLike, beta: float, key: jax.Array, *, mode: str = "auto", plan=None):
    """(O(β), O(log n / β)) decomposition.  Returns cluster int32[n]
    (cluster id == center vertex id).

    Shifts δ_v ~ Exp(β); vertex v self-starts a cluster at round ⌊δ_max−δ_v⌋
    if still unclustered; expansion is a BFS with min-cluster-id tie-breaks
    (integer-quantized variant of the fractional-priority rule — same
    O(β·m) expected inter-cluster edge bound up to constants).
    """
    n = g.n
    if plan is not None:
        g = plan.prepare(g)
    shift = jax.random.exponential(key, (n,), dtype=jnp.float32) / beta
    shift = jnp.minimum(shift, jnp.float32(2.0 * jnp.log(n + 1) / beta))
    start_round = jnp.floor(jnp.max(shift) - shift).astype(jnp.int32)
    max_round = jnp.max(start_round)

    cluster0 = jnp.full(n, UNVISITED)
    frontier0 = jnp.zeros(n, dtype=bool)

    def body(state):
        r, cluster, frontier = state
        # expansion of last round's frontier
        cand, touched = edgemap_reduce(
            g, frontier, cluster, monoid="min", mode=mode, plan=plan
        )
        newly = touched & (cluster == UNVISITED)
        cluster = jnp.where(newly, cand, cluster)
        # new centers wake up this round
        wake = (cluster == UNVISITED) & (start_round <= r)
        cluster = jnp.where(wake, jnp.arange(n, dtype=jnp.int32), cluster)
        return r + 1, cluster, newly | wake

    def cond(state):
        r, cluster, frontier = state
        # every vertex self-starts by max_round; + n rounds of expansion
        return (jnp.any(frontier) | jnp.any(cluster == UNVISITED)) & (
            r < max_round + n + 2
        )

    _, cluster, _ = lax.while_loop(cond, body, (jnp.int32(0), cluster0, frontier0))
    return cluster


# ----------------------------------------------------------------------
# Connectivity — LDD seed + min-label propagation with pointer jumping
# ----------------------------------------------------------------------
def _min_label_prop(
    g: GraphLike,
    labels0: jnp.ndarray,
    *,
    edge_active: jnp.ndarray | None = None,
    vertex_mask: jnp.ndarray | None = None,
    plan=None,
):
    """Hook-and-compress min-label fixpoint; labels must be vertex ids."""
    n = g.n
    if plan is not None:
        g = plan.prepare(g)
    full_mask = jnp.ones(n, dtype=bool) if vertex_mask is None else vertex_mask

    def body(state):
        labels, _ = state
        nbr, _ = edgemap_reduce(
            g, full_mask, labels, monoid="min", edge_active=edge_active,
            mode="dense", plan=plan,
        )
        new = jnp.minimum(labels, nbr)
        if vertex_mask is not None:
            new = jnp.where(full_mask, new, labels)
        new = new[new]  # compress (pointer jump)
        new = new[new]
        return new, jnp.any(new != labels)

    labels, _ = lax.while_loop(
        lambda s: s[1], body, (labels0, jnp.bool_(True))
    )
    return labels


def connectivity(
    g: GraphLike, key: jax.Array | None = None, *, use_ldd: bool = True, plan=None
):
    """Connected components; label = min vertex id of the component.

    Paper recipe (§C.2): one LDD round with β=O(1) drops inter-cluster edges
    to O(n) in expectation; the contracted instance is then solved entirely
    in small memory.  Here the contraction is implicit: LDD clusters seed the
    label array and the min-label fixpoint runs on cluster ids.
    """
    n = g.n
    if plan is not None:
        g = plan.prepare(g)
    if use_ldd and key is not None:
        clusters = ldd(g, 0.2, key, plan=plan)
        # cluster ids are center ids; prop below converges to the min center
        # id per component, canonicalized to min vertex id afterwards.
        labels0 = clusters
    else:
        labels0 = jnp.arange(n, dtype=jnp.int32)
    labels = _min_label_prop(g, labels0, plan=plan)
    # canonicalize: component representative = min vertex id
    rep = jax.ops.segment_min(
        jnp.arange(n, dtype=jnp.int32), labels, num_segments=n
    )
    return jnp.take(rep, labels)


def multi_source_bfs(
    g: GraphLike, roots_mask: jnp.ndarray, *, mode: str = "auto", plan=None
):
    """BFS forest from all roots at once.  Returns (parents, levels);
    parents[root]=root.

    This is the B=1 row of the batched BFS: one root *mask* is one query of
    ``bfs_batched`` (``repro.algorithms.traversal``), which runs the shared
    lockstep loop over the batched edgeMap — the bespoke loop this function
    used to carry is gone, so the forest case and the serving path exercise
    the same machinery.
    """
    from .traversal import bfs_batched

    parents, levels = bfs_batched(g, roots_mask[None, :], mode=mode, plan=plan)
    return parents[0], levels[0]


def spanning_forest(g: GraphLike, key: jax.Array | None = None):
    """Spanning forest.  Returns (parents int32[n], labels int32[n]);
    forest edges are {(v, parents[v]) : parents[v] != v}."""
    labels = connectivity(g, key, use_ldd=key is not None)
    roots = labels == jnp.arange(g.n, dtype=jnp.int32)
    parents, _ = multi_source_bfs(g, roots)
    return parents, labels


# ----------------------------------------------------------------------
# O(k)-spanner (Miller et al. [69] construction, §C.1)
# ----------------------------------------------------------------------
def spanner(g: GraphLike, k: int, key: jax.Array, *, inter_cap_factor: int = 8):
    """Returns (edge_mask bool[slots], ok bool).

    Spanner = intra-cluster BFS-tree edges of an LDD with β = log n / (2k)
    ∪ one representative edge per adjacent cluster pair.  The inter-cluster
    pair selection materializes only the compacted inter-cluster edge list
    (expected O(n); capped at ``inter_cap_factor·n`` — ``ok=False`` signals
    the §C.2 restart path when the cap overflows).
    """
    n = g.n
    slots = g.edge_src.shape[0]
    beta = float(jnp.log(n + 1)) / (2.0 * k)
    cluster = ldd(g, beta, key)

    # intra-cluster BFS tree
    same = (
        jnp.take(cluster, g.edge_src, mode="fill", fill_value=-1)
        == jnp.take(cluster, g.edge_dst, mode="fill", fill_value=-2)
    ) & g.edge_valid
    centers = cluster == jnp.arange(n, dtype=jnp.int32)
    ids = jnp.arange(n, dtype=jnp.int32)
    parents0 = jnp.where(centers, ids, UNVISITED)
    frontier0 = centers

    def body(state):
        parents, frontier, r = state
        cand, touched = edgemap_reduce(
            g, frontier, ids, monoid="min", edge_active=same, mode="auto"
        )
        newly = touched & (parents == UNVISITED)
        parents = jnp.where(newly, cand, parents)
        return parents, newly, r + 1

    parents, _, _ = lax.while_loop(
        lambda s: jnp.any(s[1]) & (s[2] < n),
        body,
        (parents0, frontier0, jnp.int32(0)),
    )
    tree_slot = (
        jnp.take(parents, g.edge_dst, mode="fill", fill_value=-1) == g.edge_src
    ) | (jnp.take(parents, g.edge_src, mode="fill", fill_value=-1) == g.edge_dst)
    tree_slot = tree_slot & g.edge_valid

    # one edge per adjacent cluster pair (compact → sort → first-of-run)
    cu = jnp.take(cluster, g.edge_src, mode="fill", fill_value=0)
    cv = jnp.take(cluster, g.edge_dst, mode="fill", fill_value=0)
    inter = g.edge_valid & (cu != cv)
    cap = inter_cap_factor * n
    idx = jnp.nonzero(inter, size=cap, fill_value=g.edge_src.shape[0])[0]
    count = jnp.sum(inter)
    ok = count <= cap
    a = jnp.take(cu, idx, mode="fill", fill_value=n)
    b = jnp.take(cv, idx, mode="fill", fill_value=n)
    lo, hi = jnp.minimum(a, b), jnp.maximum(a, b)
    order = jnp.lexsort((hi, lo))
    lo_s, hi_s, idx_s = lo[order], hi[order], idx[order]
    first = jnp.concatenate(
        [jnp.array([True]), (lo_s[1:] != lo_s[:-1]) | (hi_s[1:] != hi_s[:-1])]
    ) & (lo_s < n)
    pick = jnp.zeros(slots + 1, dtype=bool).at[jnp.where(first, idx_s, slots)].set(
        True
    )[:slots]
    # symmetrize the picked representatives
    mask = tree_slot | pick
    return _symmetrize_slot_mask(g, mask), ok


def _symmetrize_slot_mask(g: GraphLike, mask: jnp.ndarray) -> jnp.ndarray:
    """Ensure (u,v) selected ⟺ (v,u) selected, via a per-target min-slot
    match.  Works because slot lists are sorted by (src, dst)."""
    # mark selected undirected pairs with a segment trick: a slot (u,v) is
    # selected if mask on it OR its reverse.  Reverse lookup: for each slot,
    # find whether (dst, src) is masked — do it with a sorted join.
    slots = g.edge_src.shape[0]
    key_fwd_lo = jnp.minimum(g.edge_src, g.edge_dst)
    key_fwd_hi = jnp.maximum(g.edge_src, g.edge_dst)
    # bucket undirected pairs: use lexsort, then propagate OR within runs
    order = jnp.lexsort((key_fwd_hi, key_fwd_lo))
    lo_s, hi_s, m_s = key_fwd_lo[order], key_fwd_hi[order], mask[order]
    same_prev = jnp.concatenate(
        [jnp.array([False]), (lo_s[1:] == lo_s[:-1]) & (hi_s[1:] == hi_s[:-1])]
    )
    # runs have length ≤ 2 (simple graph, two directions): OR with neighbor
    m_prev = jnp.concatenate([jnp.array([False]), m_s[:-1]])
    m_next = jnp.concatenate([m_s[1:], jnp.array([False])])
    same_next = jnp.concatenate([same_prev[1:], jnp.array([False])])
    m_sym = m_s | (same_prev & m_prev) | (same_next & m_next)
    out = jnp.zeros(slots, dtype=bool).at[order].set(m_sym)
    return out & g.edge_valid


# ----------------------------------------------------------------------
# Biconnectivity (Tarjan–Vishkin)
# ----------------------------------------------------------------------
def _euler_tour_preorder(g: GraphLike, parents: jnp.ndarray, labels: jnp.ndarray):
    """Preorder numbers + subtree sizes for a rooted forest, via Euler tour
    and list ranking (pointer jumping).  All state O(n) words."""
    n = g.n
    ids = jnp.arange(n, dtype=jnp.int32)
    is_root = parents == ids

    # children sorted by id: first_child = min child; next_sibling via sort
    child_parent = jnp.where(is_root, n, parents)  # roots are nobody's child
    first_child = jax.ops.segment_min(
        jnp.where(is_root, INF_I32, ids), child_parent, num_segments=n + 1
    )[:n]
    has_child = first_child < INF_I32

    order = jnp.lexsort((ids, child_parent))  # non-roots grouped by parent
    sp = child_parent[order]
    same_next = jnp.concatenate([(sp[1:] == sp[:-1]) & (sp[1:] < n), jnp.array([False])])
    nxt = jnp.concatenate([order[1:], jnp.array([0], dtype=order.dtype)])
    next_sibling = jnp.full(n, -1, jnp.int32).at[order].set(
        jnp.where(same_next, nxt, -1).astype(jnp.int32)
    )

    # tour nodes: enter(v)=v, exit(v)=n+v, sentinel=2n
    SENT = 2 * n
    enter_succ = jnp.where(has_child, first_child, n + ids)
    has_sib = next_sibling >= 0
    exit_succ = jnp.where(
        has_sib,
        next_sibling,
        jnp.where(is_root, SENT, n + parents),
    )
    succ = jnp.concatenate(
        [enter_succ, exit_succ, jnp.array([SENT], jnp.int32)]
    ).astype(jnp.int32)
    w = jnp.concatenate(
        [jnp.ones(n, jnp.int32), jnp.zeros(n + 1, jnp.int32)]
    )

    rounds = max(1, int(jnp.ceil(jnp.log2(2 * n + 1))))

    def jump(_, state):
        s, suf = state
        suf = suf + jnp.take(suf, s)
        s = jnp.take(s, s)
        return s, suf

    _, suffix = lax.fori_loop(0, rounds, jump, (succ, w))
    suffix_enter, suffix_exit = suffix[:n], suffix[n : 2 * n]

    comp_root = labels  # min-id root per component
    comp_total = jnp.take(suffix_enter, comp_root)
    pre_in_comp = comp_total - suffix_enter
    size = suffix_enter - suffix_exit

    comp_size = jnp.zeros(n, jnp.int32).at[comp_root].max(comp_total)
    base = jnp.cumsum(comp_size) - comp_size
    pre = jnp.take(base, comp_root) + pre_in_comp
    return pre.astype(jnp.int32), size.astype(jnp.int32)


def biconnectivity(g: GraphLike, key: jax.Array | None = None):
    """Per-edge-slot biconnected-component labels (int32[slots], -1 on padding).

    Tarjan–Vishkin over a BFS spanning forest: Euler-tour preorder + subtree
    sizes, low/high via level-wise upward propagation, auxiliary-graph
    connectivity evaluated through edge-slot masks on the original graph.
    """
    n = g.n
    labels = connectivity(g, key, use_ldd=False)
    roots = labels == jnp.arange(n, dtype=jnp.int32)
    parents, levels = multi_source_bfs(g, roots)
    pre, size = _euler_tour_preorder(g, parents, labels)

    src, dst, valid = g.edge_src, g.edge_dst, g.edge_valid
    p_src = jnp.take(parents, src, mode="fill", fill_value=-1)
    p_dst = jnp.take(parents, dst, mode="fill", fill_value=-1)
    tree_sd = valid & (p_dst == src)  # src is dst's parent
    tree_ds = valid & (p_src == dst)
    nontree = valid & ~tree_sd & ~tree_ds

    # low/high: min/max preorder reachable via one nontree edge from subtree
    pre_pad = pre
    minNT, _ = edgemap_reduce(
        g, jnp.ones(n, bool), pre_pad, monoid="min", edge_active=nontree, mode="dense"
    )
    maxNT, _ = edgemap_reduce(
        g, jnp.ones(n, bool), pre_pad, monoid="max", edge_active=nontree, mode="dense"
    )
    low0 = jnp.minimum(pre, minNT)
    high0 = jnp.maximum(pre, maxNT)
    max_level = jnp.max(levels)

    def up_body(state):
        lvl, low, high = state
        at = levels == lvl  # children level
        pids = jnp.where(at & (parents != jnp.arange(n, dtype=jnp.int32)), parents, n)
        cl = jax.ops.segment_min(jnp.where(at, low, INF_I32), pids, num_segments=n + 1)[:n]
        ch = jax.ops.segment_max(jnp.where(at, high, -1), pids, num_segments=n + 1)[:n]
        low = jnp.minimum(low, cl)
        high = jnp.maximum(high, ch)
        return lvl - 1, low, high

    _, low, high = lax.while_loop(
        lambda s: s[0] >= 1, up_body, (max_level, low0, high0)
    )

    # aux-edge masks over original slots
    pre_s = jnp.take(pre, src, mode="fill", fill_value=0)
    pre_d = jnp.take(pre, dst, mode="fill", fill_value=0)
    size_s = jnp.take(size, src, mode="fill", fill_value=0)
    size_d = jnp.take(size, dst, mode="fill", fill_value=0)
    anc_sd = (pre_s <= pre_d) & (pre_d < pre_s + size_s)  # src ancestor of dst
    anc_ds = (pre_d <= pre_s) & (pre_s < pre_d + size_d)
    mask1 = nontree & ~anc_sd & ~anc_ds

    # tree-edge condition: child c with parent u join iff subtree(c) escapes u
    pre_p = jnp.take(pre, parents, mode="fill", fill_value=0)
    size_p = jnp.take(size, parents, mode="fill", fill_value=0)
    esc = (low < pre_p) | (high >= pre_p + size_p)  # per child vertex
    esc = esc & (parents != jnp.arange(n, dtype=jnp.int32))
    parent_is_root = jnp.take(
        parents, parents, mode="fill", fill_value=-1
    ) == parents  # parent is its own parent
    join_up = esc & ~parent_is_root  # aux edge (v, parents[v]) both non-root
    esc_d = jnp.take(join_up, dst, mode="fill", fill_value=False)
    esc_s = jnp.take(join_up, src, mode="fill", fill_value=False)
    mask2 = (tree_sd & esc_d) | (tree_ds & esc_s)

    aux_active = mask1 | mask2
    aux_labels = _min_label_prop(
        g, jnp.arange(n, dtype=jnp.int32), edge_active=aux_active
    )

    # per-slot bicomp labels
    deeper = jnp.where(pre_s > pre_d, src, dst)
    child = jnp.where(tree_sd, dst, jnp.where(tree_ds, src, deeper))
    out = jnp.take(aux_labels, child, mode="fill", fill_value=-1)
    return jnp.where(valid, out, -1)
