from .adamw import AdamWConfig, init as adamw_init, state_logical_specs, update as adamw_update
from .clipping import clip_by_global_norm, global_norm
from .compression import compressed_psum, dequantize_int8, quantize_int8
from .schedules import warmup_cosine

__all__ = [
    "AdamWConfig", "adamw_init", "adamw_update", "state_logical_specs",
    "clip_by_global_norm", "global_norm",
    "compressed_psum", "quantize_int8", "dequantize_int8",
    "warmup_cosine",
]
