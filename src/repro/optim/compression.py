"""Gradient compression for the slow (cross-pod / DCN) axis.

int8 quantization with a per-tensor fp32 scale: quantize → all-reduce in
int32 (summing int8 payloads without overflow) → dequantize.  4× wire-byte
reduction on the pod axis where DCN bandwidth, not ICI, is the scarce
resource.  Used inside shard_map over the 'pod' axis (see
distributed/engine.py and launch/train.py); also exposed raw for tests.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def quantize_int8(x: jnp.ndarray):
    xf = x.astype(jnp.float32)
    scale = jnp.maximum(jnp.max(jnp.abs(xf)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(xf / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_int8(q: jnp.ndarray, scale: jnp.ndarray, dtype=jnp.float32):
    return (q.astype(jnp.float32) * scale).astype(dtype)


def compressed_psum(x: jnp.ndarray, axis_name: str):
    """Inside shard_map/pmap: int8-compressed mean over ``axis_name``.

    The int8 payload is summed in int32 (no overflow for ≤2^23 ranks);
    scales are all-maxed so every rank dequantizes identically.
    """
    scale = jnp.maximum(jnp.max(jnp.abs(x.astype(jnp.float32))), 1e-12) / 127.0
    scale = jax.lax.pmax(scale, axis_name)
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale), -127, 127).astype(jnp.int8)
    total = jax.lax.psum(q.astype(jnp.int32), axis_name)
    n = jax.lax.psum(jnp.ones((), jnp.int32), axis_name)
    return (total.astype(jnp.float32) * scale / n.astype(jnp.float32)).astype(x.dtype)


def compress_tree(grads):
    return jax.tree.map(lambda g: quantize_int8(g), grads)


def decompress_tree(qtree, dtype=jnp.float32):
    return jax.tree.map(
        lambda qs: dequantize_int8(qs[0], qs[1], dtype),
        qtree,
        is_leaf=lambda x: isinstance(x, tuple),
    )
