"""AdamW as pure pytree functions (no optax dependency), sharding-aware.

Optimizer moments are fp32 regardless of param dtype.  ``state_specs``
derives the moments' PartitionSpec tree from the params' logical tree —
by default the moments inherit the param sharding (TP), and the caller can
additionally scatter them over 'data' (ZeRO-1) via ``zero1_specs`` in
launch/train.py.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1


def init(params):
    f32 = lambda p: jnp.zeros(p.shape, jnp.float32)
    return {
        "step": jnp.zeros((), jnp.int32),
        "m": jax.tree.map(f32, params),
        "v": jax.tree.map(f32, params),
    }


def update(params, grads, state, cfg: AdamWConfig, lr_scale=1.0):
    step = state["step"] + 1
    t = step.astype(jnp.float32)
    bc1 = 1.0 - cfg.b1**t
    bc2 = 1.0 - cfg.b2**t

    def upd(p, g, m, v):
        gf = g.astype(jnp.float32)
        m2 = cfg.b1 * m + (1 - cfg.b1) * gf
        v2 = cfg.b2 * v + (1 - cfg.b2) * gf * gf
        mhat = m2 / bc1
        vhat = v2 / bc2
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps) + cfg.weight_decay * p.astype(
            jnp.float32
        )
        newp = p.astype(jnp.float32) - cfg.lr * lr_scale * delta
        return newp.astype(p.dtype), m2, v2

    out = jax.tree.map(upd, params, grads, state["m"], state["v"])
    newp = jax.tree.map(lambda o: o[0], out, is_leaf=lambda x: isinstance(x, tuple))
    newm = jax.tree.map(lambda o: o[1], out, is_leaf=lambda x: isinstance(x, tuple))
    newv = jax.tree.map(lambda o: o[2], out, is_leaf=lambda x: isinstance(x, tuple))
    return newp, {"step": step, "m": newm, "v": newv}


def state_logical_specs(param_logical_specs):
    """Moments share the params' logical axes; step is replicated."""
    return {
        "step": (),
        "m": param_logical_specs,
        "v": param_logical_specs,
    }
