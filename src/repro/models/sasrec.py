"""SASRec — Self-Attentive Sequential Recommendation [arXiv:1808.09781].

Config: embed_dim=50, 2 blocks, 1 head, seq_len=50.  The item-embedding
table is the huge sparse structure (PSAM large memory for serving: scored,
never written); per-request state is O(seq·d).

Entry points: init / loss_fn (BCE with sampled negatives, as in the paper) /
serve_scores (full-catalog or candidate-list scoring — ``retrieval_cand``
is one query against 10⁶ candidates as a sharded batched dot).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from ..distributed.shardings import constrain
from ..nn.attention import gqa_attention
from ..nn.norms import layer_norm


@dataclasses.dataclass(frozen=True)
class SASRecConfig:
    name: str = "sasrec"
    vocab: int = 500_000          # item catalog (row-sharded at scale)
    embed_dim: int = 50
    n_blocks: int = 2
    n_heads: int = 1
    seq_len: int = 50
    dropout: float = 0.0          # inference-style determinism
    kv_block: int = 64


def init(key, cfg: SASRecConfig):
    d = cfg.embed_dim
    ks = jax.random.split(key, 2 + cfg.n_blocks)
    blocks = []
    for i in range(cfg.n_blocks):
        kb = jax.random.split(ks[2 + i], 6)
        blocks.append(
            {
                "ln1_s": jnp.ones((d,)), "ln1_b": jnp.zeros((d,)),
                "wq": jax.random.normal(kb[0], (d, d)) / jnp.sqrt(d),
                "wk": jax.random.normal(kb[1], (d, d)) / jnp.sqrt(d),
                "wv": jax.random.normal(kb[2], (d, d)) / jnp.sqrt(d),
                "wo": jax.random.normal(kb[3], (d, d)) / jnp.sqrt(d),
                "ln2_s": jnp.ones((d,)), "ln2_b": jnp.zeros((d,)),
                "w1": jax.random.normal(kb[4], (d, d)) / jnp.sqrt(d),
                "b1": jnp.zeros((d,)),
                "w2": jax.random.normal(kb[5], (d, d)) / jnp.sqrt(d),
                "b2": jnp.zeros((d,)),
            }
        )
    return {
        # row 0 is the padding item
        "item_emb": jax.random.normal(ks[0], (cfg.vocab, d)) * 0.02,
        "pos_emb": jax.random.normal(ks[1], (cfg.seq_len, d)) * 0.02,
        "final_ln_s": jnp.ones((d,)), "final_ln_b": jnp.zeros((d,)),
        "blocks": blocks,
    }


def encode(params, seq, cfg: SASRecConfig):
    """seq: (B, L) item ids (0 = padding) → user states (B, L, d)."""
    B, L = seq.shape
    d = cfg.embed_dim
    x = jnp.take(params["item_emb"], seq, axis=0, mode="fill", fill_value=0.0)
    x = x * jnp.sqrt(float(d)) + params["pos_emb"][None, :L]
    x = x * (seq > 0)[..., None]
    x = constrain(x, "batch", "seq", "act_embed")
    H = cfg.n_heads
    for bp in params["blocks"]:
        h = layer_norm(x, bp["ln1_s"], bp["ln1_b"])
        q = (h @ bp["wq"]).reshape(B, L, H, d // H)
        k = (h @ bp["wk"]).reshape(B, L, H, d // H)
        v = (h @ bp["wv"]).reshape(B, L, H, d // H)
        a = gqa_attention(q, k, v, causal=True, kv_block=cfg.kv_block)
        x = x + a.reshape(B, L, d) @ bp["wo"]
        h = layer_norm(x, bp["ln2_s"], bp["ln2_b"])
        ff = jax.nn.relu(h @ bp["w1"] + bp["b1"]) @ bp["w2"] + bp["b2"]
        x = (x + ff) * (seq > 0)[..., None]
    return layer_norm(x, params["final_ln_s"], params["final_ln_b"])


def loss_fn(params, batch, cfg: SASRecConfig):
    """batch: seq (B,L), pos (B,L) next-item targets, neg (B,L) sampled
    negatives; 0 = padding.  Paper's binary cross-entropy."""
    h = encode(params, batch["seq"], cfg)  # (B, L, d)
    pe = jnp.take(params["item_emb"], batch["pos"], axis=0, mode="fill", fill_value=0.0)
    ne = jnp.take(params["item_emb"], batch["neg"], axis=0, mode="fill", fill_value=0.0)
    ps = jnp.sum(h * pe, axis=-1).astype(jnp.float32)
    ns = jnp.sum(h * ne, axis=-1).astype(jnp.float32)
    mask = (batch["pos"] > 0).astype(jnp.float32)
    loss = -(jax.nn.log_sigmoid(ps) + jax.nn.log_sigmoid(-ns)) * mask
    return jnp.sum(loss) / jnp.maximum(jnp.sum(mask), 1.0)


def serve_scores(params, batch, cfg: SASRecConfig):
    """Full-catalog scoring: seq (B, L) → scores (B, vocab).
    The catalog matmul shards over 'candidates' (model axis)."""
    h = encode(params, batch["seq"], cfg)[:, -1]  # (B, d)
    scores = h @ params["item_emb"].T
    return constrain(scores, "batch", "candidates")


def retrieval_scores(params, batch, cfg: SASRecConfig):
    """One (or few) queries × explicit candidate list: seq (B, L),
    candidates (B, NC) → (B, NC).  Batched dot, never a loop."""
    h = encode(params, batch["seq"], cfg)[:, -1]  # (B, d)
    ce = jnp.take(
        params["item_emb"], batch["candidates"], axis=0, mode="fill", fill_value=0.0
    )  # (B, NC, d)
    ce = constrain(ce, "batch", "candidates", "embed")
    return jnp.einsum("bd,bcd->bc", h, ce)


def param_specs(cfg: SASRecConfig):
    def block_spec():
        return {
            "ln1_s": (None,), "ln1_b": (None,),
            "wq": (None, None), "wk": (None, None), "wv": (None, None), "wo": (None, None),
            "ln2_s": (None,), "ln2_b": (None,),
            "w1": (None, None), "b1": (None,),
            "w2": (None, None), "b2": (None,),
        }

    return {
        "item_emb": ("vocab_rows", "embed"),
        "pos_emb": (None, None),
        "final_ln_s": (None,), "final_ln_b": (None,),
        "blocks": [block_spec() for _ in range(cfg.n_blocks)],
    }
