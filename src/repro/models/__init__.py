from . import sasrec, transformer_lm
from .gnn import dimenet, equiformer_v2, gin, pna
