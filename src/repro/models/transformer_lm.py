"""Decoder-only transformer LM covering the five assigned LM archs:

* dense GQA (mistral-large-123b, qwen2-1.5b, qwen1.5-4b — optional QKV bias)
* MoE (dbrx-132b: 16e top-4; deepseek-v2-lite: 64e top-6 + 2 shared, MLA)
* MLA latent attention (deepseek-v2-lite)

Layers are scanned (stacked params) so the 88-layer mistral HLO stays
compact; each block is wrapped in jax.checkpoint.  Attention is blockwise
(never materializes S×S).  Exposed entry points:

  init(key, cfg)                     → params
  loss_fn(params, batch, cfg)        → scalar loss          (train_step)
  prefill(params, tokens, cfg)       → (logits_last, cache) (serve prefill)
  decode_step(params, cache, tok, pos, cfg) → (logits, cache)  (serve decode)
  param_specs(cfg)                   → logical-axis tree for sharding
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
from jax import lax

from ..distributed.shardings import constrain
from ..nn.attention import gqa_attention
from ..nn.moe import MoECfg, init_moe, moe_ffn
from ..nn.mlp import init_swiglu, swiglu
from ..nn.norms import rms_norm
from ..nn.rotary import apply_rope


@dataclasses.dataclass(frozen=True)
class LMConfig:
    name: str
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_head: int
    d_ff: int
    vocab: int
    qkv_bias: bool = False
    rope_theta: float = 10000.0
    window: Optional[int] = None
    # attention flavor
    attn: str = "gqa"  # "gqa" | "mla"
    kv_lora_rank: int = 512
    rope_head_dim: int = 64
    nope_head_dim: int = 128
    v_head_dim: int = 128
    # MoE
    moe: bool = False
    num_experts: int = 0
    top_k: int = 0
    n_shared: int = 0
    d_ff_expert: int = 0
    first_dense_layers: int = 0
    capacity_factor: float = 1.25
    # numerics
    dtype: str = "bfloat16"
    # attention kv block for blockwise softmax
    kv_block: int = 1024
    # unroll layers as a Python loop instead of lax.scan — used by the
    # dry-run's FLOP-costing variants (XLA cost_analysis counts while-loop
    # bodies once; an unrolled 1- vs 2-layer pair isolates per-layer cost)
    unroll: bool = False
    # MXU-native attention einsums: bf16 operands, fp32 accumulation
    attn_mixed_precision: bool = False
    # flash-style causal block skipping: only visit visible kv blocks
    attn_causal_skip: bool = False
    # remat policy inside the layer scan: "full" recomputes everything,
    # "dots" saves matmul outputs (checkpoint_dots) — §Perf lever trading
    # HBM bytes for recompute FLOPs
    remat_policy: str = "full"

    @property
    def activation_dtype(self):
        return jnp.dtype(self.dtype)

    @property
    def q_dim(self):
        if self.attn == "mla":
            return self.n_heads * (self.nope_head_dim + self.rope_head_dim)
        return self.n_heads * self.d_head

    def moe_cfg(self) -> MoECfg:
        return MoECfg(
            num_experts=self.num_experts,
            top_k=self.top_k,
            d_model=self.d_model,
            d_ff_expert=self.d_ff_expert,
            n_shared=self.n_shared,
            capacity_factor=self.capacity_factor,
        )


# ----------------------------------------------------------------------
# init
# ----------------------------------------------------------------------
def _init_attn(key, cfg: LMConfig, dtype):
    d = cfg.d_model
    ks = jax.random.split(key, 6)
    s = 0.02
    if cfg.attn == "mla":
        dn, dr, dv, r = (
            cfg.nope_head_dim,
            cfg.rope_head_dim,
            cfg.v_head_dim,
            cfg.kv_lora_rank,
        )
        H = cfg.n_heads
        return {
            "wq": jax.random.normal(ks[0], (d, H * (dn + dr)), dtype) * s,
            "w_dkv": jax.random.normal(ks[1], (d, r + dr), dtype) * s,
            "kv_norm": jnp.ones((r,), dtype),
            "w_uk": jax.random.normal(ks[2], (r, H * dn), dtype) * s,
            "w_uv": jax.random.normal(ks[3], (r, H * dv), dtype) * s,
            "wo": jax.random.normal(ks[4], (H * dv, d), dtype) * s,
        }
    H, Hkv, Dh = cfg.n_heads, cfg.n_kv_heads, cfg.d_head
    p = {
        "wq": jax.random.normal(ks[0], (d, H * Dh), dtype) * s,
        "wk": jax.random.normal(ks[1], (d, Hkv * Dh), dtype) * s,
        "wv": jax.random.normal(ks[2], (d, Hkv * Dh), dtype) * s,
        "wo": jax.random.normal(ks[3], (H * Dh, d), dtype) * s,
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((H * Dh,), dtype)
        p["bk"] = jnp.zeros((Hkv * Dh,), dtype)
        p["bv"] = jnp.zeros((Hkv * Dh,), dtype)
    return p


def _init_block(key, cfg: LMConfig, dtype, moe_block: bool):
    ka, kf = jax.random.split(key)
    p = {
        "pre_attn": jnp.ones((cfg.d_model,), dtype),
        "pre_ffn": jnp.ones((cfg.d_model,), dtype),
        "attn": _init_attn(ka, cfg, dtype),
    }
    if moe_block:
        p["moe"] = init_moe(kf, cfg.moe_cfg(), dtype)
    else:
        p["ffn"] = init_swiglu(kf, cfg.d_model, cfg.d_ff, dtype)
    return p


def init(key, cfg: LMConfig):
    dtype = cfg.activation_dtype
    k_emb, k_layers, k_dense = jax.random.split(key, 3)
    n_moe_layers = cfg.n_layers - cfg.first_dense_layers if cfg.moe else cfg.n_layers
    layer_keys = jax.random.split(k_layers, n_moe_layers)
    layers = jax.vmap(
        lambda k: _init_block(k, cfg, dtype, moe_block=cfg.moe)
    )(layer_keys)
    params = {
        "embed": jax.random.normal(k_emb, (cfg.vocab, cfg.d_model), dtype) * 0.02,
        "final_norm": jnp.ones((cfg.d_model,), dtype),
        "layers": layers,
    }
    if cfg.moe and cfg.first_dense_layers:
        dk = jax.random.split(k_dense, cfg.first_dense_layers)
        params["dense_layers"] = jax.vmap(
            lambda k: _init_block(k, cfg, dtype, moe_block=False)
        )(dk)
    return params


# ----------------------------------------------------------------------
# forward
# ----------------------------------------------------------------------
def _attn_forward(p, x, cfg: LMConfig, positions, cache=None, pos=None):
    """Returns (out, new_cache_entry).  cache entry:
    GQA: {"k": (B,Smax,Hkv,Dh), "v": ...};  MLA: {"ckv": (B,Smax,r), "kr": (B,Smax,dr)}
    """
    B, S, d = x.shape
    if cfg.attn == "mla":
        H = cfg.n_heads
        dn, dr, dv, r = (
            cfg.nope_head_dim,
            cfg.rope_head_dim,
            cfg.v_head_dim,
            cfg.kv_lora_rank,
        )
        q = (x @ p["wq"]).reshape(B, S, H, dn + dr)
        q_nope, q_rope = q[..., :dn], q[..., dn:]
        q_rope = apply_rope(q_rope, positions, cfg.rope_theta)
        ckv_kr = x @ p["w_dkv"]
        ckv, kr = ckv_kr[..., :r], ckv_kr[..., r:]
        ckv = rms_norm(ckv, p["kv_norm"])
        kr = apply_rope(kr, positions, cfg.rope_theta)
        new_entry = {
            "ckv": constrain(ckv, "batch", "cache_seq", "kv_lora"),
            "kr": constrain(kr, "batch", "cache_seq", "head_dim"),
        }
        if cache is not None:
            ckv = lax.dynamic_update_slice(cache["ckv"], ckv, (0, pos, 0))
            kr = lax.dynamic_update_slice(cache["kr"], kr, (0, pos, 0))
            new_entry = {
                "ckv": constrain(ckv, "batch", "cache_seq", "kv_lora"),
                "kr": constrain(kr, "batch", "cache_seq", "head_dim"),
            }
        Skv = ckv.shape[1]
        k_nope = (ckv @ p["w_uk"]).reshape(B, Skv, H, dn)
        v = (ckv @ p["w_uv"]).reshape(B, Skv, H, dv)
        k = jnp.concatenate(
            [k_nope, jnp.broadcast_to(kr[:, :, None, :], (B, Skv, H, dr))], axis=-1
        )
        qh = jnp.concatenate([q_nope, q_rope], axis=-1)
        qh = constrain(qh, "batch", "seq", "heads", "head_dim")
        k = constrain(k, "batch", "seq", "heads", "head_dim")
        v = constrain(v, "batch", "seq", "heads", "head_dim")
        # pad v to match k head_dim for the shared block kernel, then slice
        q_off = 0 if pos is None else pos
        out = gqa_attention(
            qh, k, v, causal=True, q_offset=q_off, kv_block=cfg.kv_block,
            window=cfg.window, mixed=cfg.attn_mixed_precision,
            causal_skip=cfg.attn_causal_skip,
            unroll_kv=cfg.unroll,
        )
        out = out.reshape(B, S, H * dv)
        return out @ p["wo"], new_entry

    H, Hkv, Dh = cfg.n_heads, cfg.n_kv_heads, cfg.d_head
    q = x @ p["wq"] + (p["bq"] if cfg.qkv_bias else 0)
    k = x @ p["wk"] + (p["bk"] if cfg.qkv_bias else 0)
    v = x @ p["wv"] + (p["bv"] if cfg.qkv_bias else 0)
    q = q.reshape(B, S, H, Dh)
    k = k.reshape(B, S, Hkv, Dh)
    v = v.reshape(B, S, Hkv, Dh)
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)
    q = constrain(q, "batch", "seq", "heads", "head_dim")
    new_entry = {
        "k": constrain(k, "batch", "cache_seq", "kv_heads", "head_dim"),
        "v": constrain(v, "batch", "cache_seq", "kv_heads", "head_dim"),
    }
    if cache is not None:
        k = lax.dynamic_update_slice(cache["k"], k, (0, pos, 0, 0))
        v = lax.dynamic_update_slice(cache["v"], v, (0, pos, 0, 0))
        new_entry = {
            "k": constrain(k, "batch", "cache_seq", "kv_heads", "head_dim"),
            "v": constrain(v, "batch", "cache_seq", "kv_heads", "head_dim"),
        }
    q_off = 0 if pos is None else pos
    out = gqa_attention(
        q, k, v, causal=True, q_offset=q_off, kv_block=cfg.kv_block,
        window=cfg.window, mixed=cfg.attn_mixed_precision,
        causal_skip=cfg.attn_causal_skip,
        unroll_kv=cfg.unroll,
    )
    out = out.reshape(B, S, H * Dh)
    return out @ p["wo"], new_entry


def _block(p, x, cfg: LMConfig, positions, moe_block: bool, cache=None, pos=None):
    h = rms_norm(x, p["pre_attn"])
    attn_out, new_entry = _attn_forward(p["attn"], h, cfg, positions, cache, pos)
    x = x + attn_out
    h = rms_norm(x, p["pre_ffn"])
    if moe_block:
        B, S, d = h.shape
        ff = moe_ffn(p["moe"], h.reshape(B * S, d), cfg.moe_cfg()).reshape(B, S, d)
    else:
        ff = swiglu(p["ffn"], h)
    x = x + ff
    x = constrain(x, "batch", "res_seq", "act_embed")
    return x, new_entry


def _scan_blocks(layers, x, cfg: LMConfig, positions, moe_block: bool, caches=None, pos=None):
    """Scan over stacked layer params (and optionally stacked caches)."""
    if cfg.unroll:
        n = jax.tree.leaves(layers)[0].shape[0]
        entries = []
        for i in range(n):
            lp = jax.tree.map(lambda a: a[i], layers)
            cache_l = (
                None if caches is None else jax.tree.map(lambda a: a[i], caches)
            )
            if caches is None:
                # keep remat semantics identical to the scanned path so the
                # costing variants count the same recompute flops
                x, entry = jax.checkpoint(
                    lambda p_, x_: _block(p_, x_, cfg, positions, moe_block)
                )(lp, x)
            else:
                x, entry = _block(lp, x, cfg, positions, moe_block, cache_l, pos)
            entries.append(entry)
        stacked = (
            jax.tree.map(lambda *e: jnp.stack(e), *entries) if entries else None
        )
        return x, stacked

    policy = (
        jax.checkpoint_policies.dots_with_no_batch_dims_saveable
        if cfg.remat_policy == "dots"
        else None
    )

    def body(carry, xs):
        xcur = carry
        if caches is None:
            lp = xs
            out, entry = jax.checkpoint(
                lambda p_, x_: _block(p_, x_, cfg, positions, moe_block),
                policy=policy,
            )(lp, xcur)
        else:
            lp, cache_l = xs
            out, entry = _block(lp, xcur, cfg, positions, moe_block, cache_l, pos)
        return out, entry

    xs = layers if caches is None else (layers, caches)
    x, entries = lax.scan(body, x, xs)
    return x, entries


def forward(params, tokens, cfg: LMConfig, *, caches=None, pos=None, collect_cache=False):
    """tokens (B, S) → hidden (B, S, d); optionally threads KV caches."""
    B, S = tokens.shape
    x = jnp.take(params["embed"], tokens, axis=0)
    x = constrain(x, "batch", "res_seq", "act_embed")
    base = 0 if pos is None else pos
    positions = base + jnp.arange(S)[None, :]

    if cfg.moe and cfg.first_dense_layers:
        dcaches = None if caches is None else caches["dense"]
        x, dense_entries = _scan_blocks(
            params["dense_layers"], x, cfg, positions, False, dcaches, pos
        )
    else:
        dense_entries = None
    mcaches = None if caches is None else caches["moe" if cfg.moe else "main"]
    x, entries = _scan_blocks(
        params["layers"], x, cfg, positions, cfg.moe, mcaches, pos
    )
    x = rms_norm(x, params["final_norm"])
    if not collect_cache and caches is None:
        return x, None
    new_caches = {("moe" if cfg.moe else "main"): entries}
    if dense_entries is not None:
        new_caches["dense"] = dense_entries
    return x, new_caches


def logits_from_hidden(params, x, cfg: LMConfig):
    logits = x @ params["embed"].T  # tied embedding
    return constrain(logits, "batch", "seq", "vocab")


def loss_fn(params, batch, cfg: LMConfig):
    """Causal LM cross-entropy; batch = {tokens, targets} (B, S) int32."""
    x, _ = forward(params, batch["tokens"], cfg)
    logits = logits_from_hidden(params, x, cfg).astype(jnp.float32)
    lse = jax.scipy.special.logsumexp(logits, axis=-1)
    tgt = jnp.take_along_axis(
        logits, batch["targets"][..., None].astype(jnp.int32), axis=-1
    )[..., 0]
    mask = (batch["targets"] >= 0).astype(jnp.float32)
    return jnp.sum((lse - tgt) * mask) / jnp.maximum(jnp.sum(mask), 1.0)


# ----------------------------------------------------------------------
# serving
# ----------------------------------------------------------------------
def make_cache(cfg: LMConfig, batch: int, max_seq: int, dtype=None):
    dtype = dtype or cfg.activation_dtype
    def entry():
        if cfg.attn == "mla":
            return {
                "ckv": jnp.zeros((batch, max_seq, cfg.kv_lora_rank), dtype),
                "kr": jnp.zeros((batch, max_seq, cfg.rope_head_dim), dtype),
            }
        return {
            "k": jnp.zeros((batch, max_seq, cfg.n_kv_heads, cfg.d_head), dtype),
            "v": jnp.zeros((batch, max_seq, cfg.n_kv_heads, cfg.d_head), dtype),
        }

    def stack(n):
        return jax.tree.map(lambda z: jnp.broadcast_to(z[None], (n,) + z.shape), entry())

    caches = {}
    n_main = cfg.n_layers - (cfg.first_dense_layers if cfg.moe else 0)
    caches["moe" if cfg.moe else "main"] = stack(n_main)
    if cfg.moe and cfg.first_dense_layers:
        caches["dense"] = stack(cfg.first_dense_layers)
    return caches


def prefill(params, tokens, cfg: LMConfig, *, max_seq: int | None = None):
    """Prefill: returns (last-position logits (B, V), caches)."""
    B, S = tokens.shape
    max_seq = max_seq or S
    caches = make_cache(cfg, B, max_seq)
    x, caches = forward(params, tokens, cfg, caches=caches, pos=0)
    logits = logits_from_hidden(params, x[:, -1:, :], cfg)
    return logits[:, 0], caches


def decode_step(params, caches, tokens, pos, cfg: LMConfig):
    """One decode step: tokens (B, 1) at absolute position ``pos``.
    Returns (logits (B, V), updated caches)."""
    x, caches = forward(params, tokens, cfg, caches=caches, pos=pos)
    logits = logits_from_hidden(params, x, cfg)
    return logits[:, 0], caches


# ----------------------------------------------------------------------
# sharding specs (logical axis names per parameter)
# ----------------------------------------------------------------------
def param_specs(cfg: LMConfig):
    """Pytree (matching init) of logical-axis-name tuples."""
    def attn_spec():
        if cfg.attn == "mla":
            return {
                "wq": ("embed", "heads"),
                "w_dkv": ("embed", "kv_lora"),
                "kv_norm": ("kv_lora",),
                "w_uk": ("kv_lora", "heads"),
                "w_uv": ("kv_lora", "heads"),
                "wo": ("heads", "embed"),
            }
        p = {
            "wq": ("embed", "heads"),
            "wk": ("embed", "heads"),
            "wv": ("embed", "heads"),
            "wo": ("heads", "embed"),
        }
        if cfg.qkv_bias:
            p.update({"bq": ("heads",), "bk": ("heads",), "bv": ("heads",)})
        return p

    def block_spec(moe_block):
        p = {
            "pre_attn": ("embed",),
            "pre_ffn": ("embed",),
            "attn": attn_spec(),
        }
        if moe_block:
            p["moe"] = {
                "router": ("embed", "experts"),
                "w_gate": ("experts", "embed", "expert_ff"),
                "w_up": ("experts", "embed", "expert_ff"),
                "w_down": ("experts", "expert_ff", "embed"),
            }
            if cfg.n_shared:
                p["moe"]["shared"] = {
                    "w_gate": ("embed", "ff"),
                    "w_up": ("embed", "ff"),
                    "w_down": ("ff", "embed"),
                }
        else:
            p["ffn"] = {
                "w_gate": ("embed", "ff"),
                "w_up": ("embed", "ff"),
                "w_down": ("ff", "embed"),
            }
        return p

    def stacked(tree):
        return jax.tree.map(
            lambda names: ("layers",) + names,
            tree,
            is_leaf=lambda x: isinstance(x, tuple),
        )

    specs = {
        "embed": ("vocab", "embed"),
        "final_norm": ("embed",),
        "layers": stacked(block_spec(cfg.moe)),
    }
    if cfg.moe and cfg.first_dense_layers:
        specs["dense_layers"] = stacked(block_spec(False))
    return specs


def cache_specs(cfg: LMConfig):
    def entry():
        if cfg.attn == "mla":
            return {
                "ckv": ("layers", "batch", "cache_seq", "kv_lora"),
                "kr": ("layers", "batch", "cache_seq", "head_dim"),
            }
        return {
            "k": ("layers", "batch", "cache_seq", "kv_heads", "head_dim"),
            "v": ("layers", "batch", "cache_seq", "kv_heads", "head_dim"),
        }

    caches = {("moe" if cfg.moe else "main"): entry()}
    if cfg.moe and cfg.first_dense_layers:
        caches["dense"] = entry()
    return caches
