"""GIN — Graph Isomorphism Network [arXiv:1810.00826], TU-benchmark config:
5 layers, d=64, sum aggregator, learnable ε."""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from ...nn.mlp import init_mlp2, mlp2
from .aggregate import gather_src, scatter_sum


@dataclasses.dataclass(frozen=True)
class GINConfig:
    name: str = "gin-tu"
    n_layers: int = 5
    d_hidden: int = 64
    d_in: int = 16
    n_classes: int = 8
    task: str = "graph"
    n_graphs: int = 0


def init(key, cfg: GINConfig):
    ks = jax.random.split(key, cfg.n_layers + 2)
    d = cfg.d_hidden
    layers = [
        {
            "mlp": init_mlp2(ks[i], d, 2 * d, d),
            "eps": jnp.zeros(()),
        }
        for i in range(cfg.n_layers)
    ]
    return {
        "encode": init_mlp2(ks[-2], cfg.d_in, d, d),
        "layers": layers,
        "head": init_mlp2(ks[-1], d * (cfg.n_layers + 1), d, cfg.n_classes),
    }


def forward(params, batch, cfg: GINConfig):
    x = batch["node_feat"]
    src, dst = batch["edge_src"], batch["edge_dst"]
    n = x.shape[0]
    h = mlp2(params["encode"], x)
    reps = [h]
    for lp in params["layers"]:
        agg = scatter_sum(gather_src(h, src), dst, n)
        h = mlp2(lp["mlp"], (1.0 + lp["eps"]) * h + agg)
        h = jax.nn.relu(h)
        reps.append(h)
    hcat = jnp.concatenate(reps, axis=-1)
    if cfg.task == "graph":
        gid = batch["node_graph"]
        n_graphs = cfg.n_graphs
        pooled = jax.ops.segment_sum(hcat, gid, num_segments=n_graphs + 1)[:n_graphs]
        return mlp2(params["head"], pooled)
    return mlp2(params["head"], hcat)


def loss_fn(params, batch, cfg: GINConfig):
    logits = forward(params, batch, cfg).astype(jnp.float32)
    if cfg.n_classes == 1:  # regression head (molecule cells)
        tgt = batch["graph_labels" if cfg.task == "graph" else "labels"]
        return jnp.mean((logits[..., 0] - tgt.astype(jnp.float32)) ** 2)
    labels = batch["graph_labels" if cfg.task == "graph" else "labels"]
    mask = (labels >= 0).astype(jnp.float32)
    lse = jax.scipy.special.logsumexp(logits, axis=-1)
    tgt = jnp.take_along_axis(logits, jnp.maximum(labels, 0)[:, None], axis=-1)[:, 0]
    return jnp.sum((lse - tgt) * mask) / jnp.maximum(jnp.sum(mask), 1.0)


def param_specs(cfg: GINConfig):
    def mlp_spec():
        return {"w1": (None, "hidden"), "b1": ("hidden",), "w2": ("hidden", None), "b2": (None,)}

    return {
        "encode": mlp_spec(),
        "layers": [{"mlp": mlp_spec(), "eps": ()} for _ in range(cfg.n_layers)],
        "head": mlp_spec(),
    }
