"""DimeNet — Directional Message Passing [arXiv:2003.03123].

Config: 6 interaction blocks, d=128, n_bilinear=8, n_spherical=7, n_radial=6.
Messages live on *directed edges*; interaction blocks mix incoming messages
m_kj into m_ji weighted by a spherical-radial basis of the angle ∠(kj, ji)
via a bilinear layer.  Triplet lists (t_kj, t_ji index pairs into the edge
list, padded with E) are produced by the data pipeline; for very large
non-molecular graphs the pipeline caps triplets per edge (documented in
DESIGN.md §Arch-applicability) — exact for the molecule shapes.
"""
from __future__ import annotations

import dataclasses
import math

import jax
import jax.numpy as jnp

from ...nn.mlp import init_mlp2, mlp2
from .aggregate import gather_src, scatter_sum


@dataclasses.dataclass(frozen=True)
class DimeNetConfig:
    name: str = "dimenet"
    n_blocks: int = 6
    d_hidden: int = 128
    n_bilinear: int = 8
    n_spherical: int = 7
    n_radial: int = 6
    cutoff: float = 5.0
    d_in: int = 16
    task: str = "graph"  # per-graph energy regression
    n_graphs: int = 0


def _rbf(dist, n_radial, cutoff):
    """Bessel-style radial basis (sin(nπ d/c)/d), DimeNet eq. 7."""
    d = jnp.maximum(dist, 1e-6)[..., None]
    n = jnp.arange(1, n_radial + 1, dtype=jnp.float32)
    env = jnp.where(dist[..., None] < cutoff, 1.0, 0.0)
    return math.sqrt(2.0 / cutoff) * jnp.sin(n * jnp.pi * d / cutoff) / d * env


def _sbf(angle, dist, n_spherical, n_radial, cutoff):
    """Spherical basis: cos(l·θ) ⊗ radial sin basis (simplified Y_l0⊗j_l)."""
    l = jnp.arange(n_spherical, dtype=jnp.float32)
    ang = jnp.cos(angle[..., None] * 1.0) * 0 + jnp.cos(
        l * angle[..., None]
    )  # (T, S)
    rad = _rbf(dist, n_radial, cutoff)  # (T, R)
    return (ang[..., :, None] * rad[..., None, :]).reshape(
        angle.shape + (n_spherical * n_radial,)
    )


def init(key, cfg: DimeNetConfig):
    d, nb = cfg.d_hidden, cfg.n_bilinear
    sbf_dim = cfg.n_spherical * cfg.n_radial
    ks = jax.random.split(key, cfg.n_blocks * 5 + 4)
    blocks = []
    for i in range(cfg.n_blocks):
        k = ks[5 * i : 5 * i + 5]
        blocks.append(
            {
                "w_self": jax.random.normal(k[0], (d, d)) / jnp.sqrt(d),
                "w_kj": jax.random.normal(k[1], (d, nb)) / jnp.sqrt(d),
                "w_sbf": jax.random.normal(k[2], (sbf_dim, nb)) / jnp.sqrt(sbf_dim),
                "w_bil": jax.random.normal(k[3], (nb, d)) / jnp.sqrt(nb),
                "update": init_mlp2(k[4], d, d, d),
            }
        )
    return {
        "embed_node": init_mlp2(ks[-4], cfg.d_in, d, d),
        "embed_edge": init_mlp2(ks[-3], 2 * d + cfg.n_radial, d, d),
        "blocks": blocks,
        "out_edge": jax.random.normal(ks[-2], (d, d)) / jnp.sqrt(d),
        "head": init_mlp2(ks[-1], d, d, 1),
    }


def forward(params, batch, cfg: DimeNetConfig):
    x, pos = batch["node_feat"], batch["pos"]
    src, dst = batch["edge_src"], batch["edge_dst"]
    t_kj, t_ji = batch["t_kj"], batch["t_ji"]  # indices into edges, pad=E
    n = x.shape[0]
    E = src.shape[0]

    h = mlp2(params["embed_node"], x)
    pvalid = jnp.minimum(src, n - 1), jnp.minimum(dst, n - 1)
    vec = jnp.take(pos, pvalid[1], axis=0) - jnp.take(pos, pvalid[0], axis=0)
    dist = jnp.sqrt(jnp.maximum(jnp.sum(vec * vec, axis=-1), 1e-12))
    rbf = _rbf(dist, cfg.n_radial, cfg.cutoff)  # (E, R)
    m = mlp2(
        params["embed_edge"],
        jnp.concatenate([gather_src(h, src), gather_src(h, dst), rbf], axis=-1),
    )  # (E, d)

    # triplet geometry: angle between edge kj and ji
    vkj = jnp.take(vec, jnp.minimum(t_kj, E - 1), axis=0)
    vji = jnp.take(vec, jnp.minimum(t_ji, E - 1), axis=0)
    cosang = jnp.sum(vkj * vji, axis=-1) / jnp.maximum(
        jnp.linalg.norm(vkj, axis=-1) * jnp.linalg.norm(vji, axis=-1), 1e-12
    )
    angle = jnp.arccos(jnp.clip(cosang, -1.0, 1.0))
    dji = jnp.take(dist, jnp.minimum(t_ji, E - 1), axis=0)
    sbf = _sbf(angle, dji, cfg.n_spherical, cfg.n_radial, cfg.cutoff)  # (T, S*R)
    tvalid = (t_kj < E) & (t_ji < E)

    for bp in params["blocks"]:
        m_kj = jnp.take(m, jnp.minimum(t_kj, E - 1), axis=0)  # (T, d)
        a = m_kj @ bp["w_kj"]                                  # (T, nb)
        b = sbf @ bp["w_sbf"]                                  # (T, nb)
        tmsg = jnp.where(tvalid[:, None], a * b, 0.0) @ bp["w_bil"]  # (T, d)
        agg = scatter_sum(tmsg, jnp.where(tvalid, t_ji, E), E)
        m = m + mlp2(bp["update"], jax.nn.silu(m @ bp["w_self"] + agg))

    # per-node output: sum incident directed-edge messages
    node_out = scatter_sum(m @ params["out_edge"], jnp.minimum(dst, n), n)
    per_node = mlp2(params["head"], jax.nn.silu(node_out))[:, 0]
    if cfg.task == "graph":
        gid = batch["node_graph"]
        n_graphs = cfg.n_graphs
        return jax.ops.segment_sum(per_node, gid, num_segments=n_graphs + 1)[:n_graphs]
    return per_node


def loss_fn(params, batch, cfg: DimeNetConfig):
    out = forward(params, batch, cfg)
    tgt = batch["graph_labels" if cfg.task == "graph" else "labels"].astype(jnp.float32)
    return jnp.mean((out - tgt) ** 2)


def param_specs(cfg: DimeNetConfig):
    def mlp_spec():
        return {"w1": (None, "hidden"), "b1": ("hidden",), "w2": ("hidden", None), "b2": (None,)}

    return {
        "embed_node": mlp_spec(),
        "embed_edge": mlp_spec(),
        "blocks": [
            {
                "w_self": (None, "hidden"),
                "w_kj": (None, "hidden"),
                "w_sbf": (None, "hidden"),
                "w_bil": ("hidden", None),
                "update": mlp_spec(),
            }
            for _ in range(cfg.n_blocks)
        ],
        "out_edge": (None, "hidden"),
        "head": mlp_spec(),
    }
