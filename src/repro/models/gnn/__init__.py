from . import dimenet, equiformer_v2, gin, pna
from .aggregate import (
    degrees, gather_src, scatter_max, scatter_mean, scatter_min,
    scatter_std, scatter_sum, segment_softmax,
)
