"""EquiformerV2-style equivariant graph attention [arXiv:2306.12059].

Assigned config: 12 layers, d=128, l_max=6, m_max=2, 8 heads, eSCN SO(2)
convolutions.

Implementation note (DESIGN.md §Arch-applicability): node features are
spherical-tensor stacks (N, (l_max+1)², C).  Messages combine the sender's
coefficients with real spherical harmonics of the edge direction and a
radial MLP, with the eSCN m-truncation (only |m| ≤ m_max coefficients are
mixed across l; higher-m coefficients pass through gated by scalar
attention).  The full Wigner rotation into the edge-aligned frame is
replaced by direct SH modulation — an SEGNN-flavored approximation of eSCN
with the same O((l_max)²·m_max) per-edge mixing cost (the kernel-regime
the roofline analysis cares about), not an exactly-equivariant layer.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from ...nn.mlp import init_mlp2, mlp2
from .aggregate import gather_src, scatter_sum, segment_softmax
from .sh import real_sph_harm, sh_index_table


@dataclasses.dataclass(frozen=True)
class EquiformerV2Config:
    name: str = "equiformer-v2"
    n_layers: int = 12
    d_hidden: int = 128
    l_max: int = 6
    m_max: int = 2
    n_heads: int = 8
    n_radial: int = 8
    d_in: int = 16
    n_classes: int = 1
    task: str = "graph"
    n_graphs: int = 0
    # §Perf: gather only the |m| ≤ m_max coefficients to the edges (the
    # eSCN truncation applied to the *communication*, not just the compute):
    # high-m coefficients evolve node-locally, cutting the node→edge gather
    # and edge→node scatter volume to (Σ_l min(2l+1, 2m_max+1)) / (l_max+1)²
    compact_messages: bool = False

    @property
    def n_coef(self):
        return (self.l_max + 1) ** 2

    @property
    def channels(self):
        return self.d_hidden // self.n_heads  # per-head channels


def init(key, cfg: EquiformerV2Config):
    d, C = cfg.d_hidden, cfg.n_coef
    ks = jax.random.split(key, cfg.n_layers * 6 + 3)
    layers = []
    for i in range(cfg.n_layers):
        k = ks[6 * i : 6 * i + 6]
        layers.append(
            {
                "w_src": jax.random.normal(k[0], (d, d)) / jnp.sqrt(d),
                "w_dst": jax.random.normal(k[1], (d, d)) / jnp.sqrt(d),
                "radial": init_mlp2(k[2], cfg.n_radial, d, (cfg.l_max + 1) * d),
                "attn": init_mlp2(k[3], 2 * d + cfg.n_radial, d, cfg.n_heads),
                "w_m": jax.random.normal(k[4], (2 * cfg.m_max + 1, d, d))
                / jnp.sqrt(d),
                "ffn": init_mlp2(k[5], d, 2 * d, d),
            }
        )
    return {
        "encode": init_mlp2(ks[-3], cfg.d_in, d, d),
        "layers": layers,
        "head": init_mlp2(ks[-1], d, d, cfg.n_classes),
    }


def forward(params, batch, cfg: EquiformerV2Config):
    x, pos = batch["node_feat"], batch["pos"]
    src, dst = batch["edge_src"], batch["edge_dst"]
    n = x.shape[0]
    Cf, d = cfg.n_coef, cfg.d_hidden

    # edge geometry
    sv = jnp.minimum(src, n - 1)
    dv = jnp.minimum(dst, n - 1)
    vec = jnp.take(pos, dv, axis=0) - jnp.take(pos, sv, axis=0)
    dist = jnp.sqrt(jnp.maximum(jnp.sum(vec * vec, axis=-1), 1e-12))
    u = vec / dist[:, None]
    Y = real_sph_harm(cfg.l_max, u)  # (E, Cf)
    rbf = jnp.exp(
        -((dist[:, None] - jnp.linspace(0.0, 5.0, cfg.n_radial)) ** 2)
    )  # (E, R)
    valid = (src < n) & (dst < n)

    tab = sh_index_table(cfg.l_max)
    l_of = jnp.asarray(tab[:, 0], jnp.int32)      # (Cf,)
    m_ok_np = np.abs(tab[:, 1]) <= cfg.m_max      # host-side (static) mask
    m_ok = jnp.asarray(m_ok_np)
    m_idx = jnp.asarray(
        np.clip(tab[:, 1], -cfg.m_max, cfg.m_max) + cfg.m_max, jnp.int32
    )

    # node state: scalar channel h (N, d) + spherical stack f (N, Cf, d)
    h = mlp2(params["encode"], x)
    f = jnp.zeros((n, Cf, d), h.dtype).at[:, 0, :].set(h)

    for lp in params["layers"]:
        hs, hd = gather_src(h, src), gather_src(h, dst)
        # per-edge scalar attention (8 heads)
        logits = mlp2(lp["attn"], jnp.concatenate([hs, hd, rbf], axis=-1))
        logits = jnp.where(valid[:, None], logits, -1e30)
        alpha = segment_softmax(logits, jnp.minimum(dst, n), n)  # (E, H)
        gate = jnp.repeat(alpha, d // cfg.n_heads, axis=-1)      # (E, d)

        # radial per-l gains
        rl = mlp2(lp["radial"], rbf).reshape(-1, cfg.l_max + 1, d)  # (E, L+1, d)
        gain = jnp.take_along_axis(
            rl, jnp.broadcast_to(l_of[None, :, None], (rl.shape[0], Cf, 1)), axis=1
        )  # (E, Cf, d)

        if cfg.compact_messages:
            # gather/scatter only the m-truncated coefficient subset
            sel = jnp.asarray(np.flatnonzero(m_ok_np), jnp.int32)  # (Cs,)
            fs = jnp.take(f[:, sel, :], sv, axis=0)                # (E, Cs, d)
            wm = lp["w_m"][m_idx[sel]]                             # (Cs, d, d)
            fs = jnp.einsum("ecd,cdk->eck", fs, wm)
            msg = fs * gain[:, sel, :] + Y[:, sel, None] * (
                hs @ lp["w_src"]
            )[:, None, :]
            msg = msg * gate[:, None, :]
            msg = jnp.where(valid[:, None, None], msg, 0.0)
            aggC = scatter_sum(msg, jnp.minimum(dst, n), n)        # (N, Cs, d)
            f = f.at[:, sel, :].add(aggC)
        else:
            fs = jnp.take(f, sv, axis=0)                  # (E, Cf, d)
            # eSCN m-truncated channel mixing: coefficients with |m| ≤ m_max
            # get a per-m linear map; higher-m coefficients pass through.
            wm = lp["w_m"][m_idx]                         # (Cf, d, d)
            mixed = jnp.einsum("ecd,cdk->eck", fs, wm)
            fs = jnp.where(m_ok[None, :, None], mixed, fs)
            # SH injection from the scalar channel (creates higher-l content)
            msg = fs * gain + Y[:, :, None] * (hs @ lp["w_src"])[:, None, :]
            msg = msg * gate[:, None, :]
            msg = jnp.where(valid[:, None, None], msg, 0.0)
            aggF = scatter_sum(msg, jnp.minimum(dst, n), n)  # (N, Cf, d)
            f = f + aggF
        # equivariant norm-gated nonlinearity on l>0, MLP on l=0
        norms = jnp.sqrt(jnp.maximum(jnp.sum(f[:, 1:, :] ** 2, axis=1), 1e-12))
        h = h + mlp2(lp["ffn"], f[:, 0, :] + (norms @ lp["w_dst"]) / Cf)
        f = f.at[:, 0, :].set(h)

    if cfg.task == "graph":
        gid = batch["node_graph"]
        n_graphs = cfg.n_graphs
        pooled = jax.ops.segment_sum(h, gid, num_segments=n_graphs + 1)[:n_graphs]
        return mlp2(params["head"], pooled)
    return mlp2(params["head"], h)


def loss_fn(params, batch, cfg: EquiformerV2Config):
    out = forward(params, batch, cfg)
    if cfg.n_classes == 1:
        tgt = batch["graph_labels" if cfg.task == "graph" else "labels"]
        return jnp.mean((out[..., 0] - tgt.astype(jnp.float32)) ** 2)
    logits = out.astype(jnp.float32)
    labels = batch["labels"]
    mask = (labels >= 0).astype(jnp.float32)
    lse = jax.scipy.special.logsumexp(logits, axis=-1)
    tgt = jnp.take_along_axis(logits, jnp.maximum(labels, 0)[:, None], axis=-1)[:, 0]
    return jnp.sum((lse - tgt) * mask) / jnp.maximum(jnp.sum(mask), 1.0)


def param_specs(cfg: EquiformerV2Config):
    def mlp_spec():
        return {"w1": (None, "hidden"), "b1": ("hidden",), "w2": ("hidden", None), "b2": (None,)}

    return {
        "encode": mlp_spec(),
        "layers": [
            {
                "w_src": (None, "hidden"),
                "w_dst": (None, "hidden"),
                "radial": mlp_spec(),
                "attn": mlp_spec(),
                "w_m": (None, None, "hidden"),
                "ffn": mlp_spec(),
            }
            for _ in range(cfg.n_layers)
        ],
        "head": mlp_spec(),
    }
