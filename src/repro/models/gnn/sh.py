"""Real spherical harmonics Y_lm up to l_max via associated-Legendre
recursion — needed by the equiformer-v2 (eSCN) and dimenet configs.

Validated against scipy.special in tests.
"""
from __future__ import annotations

import math

import jax.numpy as jnp
import numpy as np


def _k_norm(l: int, m: int) -> float:
    return math.sqrt(
        (2 * l + 1) / (4 * math.pi) * math.factorial(l - m) / math.factorial(l + m)
    )


def real_sph_harm(lmax: int, u: jnp.ndarray) -> jnp.ndarray:
    """u: (..., 3) unit vectors → (..., (lmax+1)^2) real SH values.

    Ordering: index l*l + (m + l) for m in [-l, l].
    """
    x, y, z = u[..., 0], u[..., 1], u[..., 2]
    rxy = jnp.sqrt(jnp.maximum(x * x + y * y, 1e-30))
    cphi, sphi = x / rxy, y / rxy

    # cos(m φ), sin(m φ) by recurrence
    cos_m = [jnp.ones_like(x), cphi]
    sin_m = [jnp.zeros_like(x), sphi]
    for m in range(2, lmax + 1):
        cos_m.append(2 * cphi * cos_m[-1] - cos_m[-2])
        sin_m.append(2 * cphi * sin_m[-1] - sin_m[-2])

    # associated Legendre P_l^m(z), unnormalized
    P = {}
    somx2 = jnp.sqrt(jnp.maximum(1.0 - z * z, 0.0))
    P[(0, 0)] = jnp.ones_like(z)
    for m in range(1, lmax + 1):
        P[(m, m)] = -(2 * m - 1) * somx2 * P[(m - 1, m - 1)]
    for m in range(0, lmax):
        P[(m + 1, m)] = (2 * m + 1) * z * P[(m, m)]
    for m in range(0, lmax + 1):
        for l in range(m + 2, lmax + 1):
            P[(l, m)] = (
                (2 * l - 1) * z * P[(l - 1, m)] - (l + m - 1) * P[(l - 2, m)]
            ) / (l - m)

    out = []
    sq2 = math.sqrt(2.0)
    for l in range(lmax + 1):
        row = [None] * (2 * l + 1)
        for m in range(0, l + 1):
            k = _k_norm(l, m)
            if m == 0:
                row[l] = k * P[(l, 0)]
            else:
                row[l + m] = sq2 * k * cos_m[m] * P[(l, m)]
                row[l - m] = sq2 * k * sin_m[m] * P[(l, m)]
        out.extend(row)
    return jnp.stack(out, axis=-1)


def sh_index_table(lmax: int) -> np.ndarray:
    """(l, m) per flat index."""
    tab = []
    for l in range(lmax + 1):
        for m in range(-l, l + 1):
            tab.append((l, m))
    return np.array(tab)
