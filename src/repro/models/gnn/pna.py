"""PNA — Principal Neighbourhood Aggregation [arXiv:2004.05718].

4 aggregators (mean/max/min/std) × 3 degree scalers (identity /
amplification / attenuation) → 12·d message concat → linear → update MLP,
with residual.  n_layers=4, d_hidden=75 per the assigned config.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from ...nn.mlp import init_mlp2, mlp2
from .aggregate import (
    degrees,
    gather_src,
    scatter_max,
    scatter_mean,
    scatter_min,
    scatter_std,
)


@dataclasses.dataclass(frozen=True)
class PNAConfig:
    name: str = "pna"
    n_layers: int = 4
    d_hidden: int = 75
    d_in: int = 16
    n_classes: int = 16
    task: str = "node"  # node classification | "graph" regression
    n_graphs: int = 0


def init(key, cfg: PNAConfig):
    ks = jax.random.split(key, cfg.n_layers * 3 + 2)
    d = cfg.d_hidden
    layers = []
    for i in range(cfg.n_layers):
        layers.append(
            {
                "msg": init_mlp2(ks[3 * i], 2 * d, d, d),
                "post": jax.random.normal(ks[3 * i + 1], (12 * d, d)) / jnp.sqrt(12 * d),
                "update": init_mlp2(ks[3 * i + 2], 2 * d, d, d),
            }
        )
    return {
        "encode": init_mlp2(ks[-2], cfg.d_in, d, d),
        "layers": layers,
        "head": init_mlp2(ks[-1], d, d, cfg.n_classes),
    }


def forward(params, batch, cfg: PNAConfig):
    x = batch["node_feat"]
    src, dst = batch["edge_src"], batch["edge_dst"]
    n = x.shape[0]
    h = mlp2(params["encode"], x)
    deg = degrees(jnp.minimum(dst, n), n)
    logd = jnp.log1p(deg)
    delta = jnp.mean(jnp.where(deg > 0, logd, 0.0)) + 1e-6  # batch-estimated δ
    amp = (logd / delta)[:, None]
    att = (delta / jnp.maximum(logd, 1e-6))[:, None]

    for lp in params["layers"]:
        hs = gather_src(h, src)
        hd = gather_src(h, dst)
        m = mlp2(lp["msg"], jnp.concatenate([hs, hd], axis=-1))
        present = (deg > 0)[:, None]
        aggs = [
            scatter_mean(m, dst, n, deg=deg),
            scatter_max(m, dst, n),
            scatter_min(m, dst, n),
            scatter_std(m, dst, n, deg=deg),
        ]
        aggs = [jnp.where(present, a, 0.0) for a in aggs]  # isolated nodes → 0
        scaled = []
        for a in aggs:
            scaled += [a, a * amp, a * att]
        agg = jnp.concatenate(scaled, axis=-1) @ lp["post"]
        h = h + mlp2(lp["update"], jnp.concatenate([h, agg], axis=-1))
    if cfg.task == "graph":
        gid = batch["node_graph"]
        n_graphs = cfg.n_graphs
        pooled = jax.ops.segment_sum(h, gid, num_segments=n_graphs + 1)[:n_graphs]
        return mlp2(params["head"], pooled)
    return mlp2(params["head"], h)


def loss_fn(params, batch, cfg: PNAConfig):
    out = forward(params, batch, cfg)
    if cfg.task == "graph":
        tgt = batch["graph_labels"].astype(jnp.float32)
        return jnp.mean((out[..., 0] - tgt) ** 2)
    logits = out.astype(jnp.float32)
    labels = batch["labels"]
    mask = (labels >= 0).astype(jnp.float32)
    lse = jax.scipy.special.logsumexp(logits, axis=-1)
    tgt = jnp.take_along_axis(logits, jnp.maximum(labels, 0)[:, None], axis=-1)[:, 0]
    return jnp.sum((lse - tgt) * mask) / jnp.maximum(jnp.sum(mask), 1.0)


def param_specs(cfg: PNAConfig):
    def mlp_spec():
        return {"w1": (None, "hidden"), "b1": ("hidden",), "w2": ("hidden", None), "b2": (None,)}

    return {
        "encode": mlp_spec(),
        "layers": [
            {"msg": mlp_spec(), "post": (None, "hidden"), "update": mlp_spec()}
            for _ in range(cfg.n_layers)
        ],
        "head": mlp_spec(),
    }
