"""GNN message-passing primitives — the PSAM edgeMap applied to features.

The edge index arrays are the immutable large-memory structure (padded with
the sentinel node id N); per-node features are the O(n·d) small-memory
state.  JAX has no CSR SpMM: message passing IS ``jnp.take`` +
``jax.ops.segment_*`` over an edge list, exactly the engine's dense edgeMap
with a feature axis.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def gather_src(x: jnp.ndarray, src: jnp.ndarray, fill=0.0) -> jnp.ndarray:
    return jnp.take(x, src, axis=0, mode="fill", fill_value=fill)


def scatter_sum(vals: jnp.ndarray, dst: jnp.ndarray, n: int) -> jnp.ndarray:
    return jax.ops.segment_sum(vals, dst, num_segments=n + 1)[:n]


def scatter_mean(vals, dst, n, *, deg=None):
    s = scatter_sum(vals, dst, n)
    if deg is None:
        deg = scatter_sum(jnp.ones(vals.shape[:1], jnp.float32), dst, n)
    d = jnp.maximum(deg, 1.0)
    return s / d.reshape((-1,) + (1,) * (vals.ndim - 1))


def scatter_max(vals, dst, n, *, neutral=-1e30):
    out = jax.ops.segment_max(vals, dst, num_segments=n + 1)[:n]
    return jnp.maximum(out, neutral)


def scatter_min(vals, dst, n, *, neutral=1e30):
    out = jax.ops.segment_min(vals, dst, num_segments=n + 1)[:n]
    return jnp.minimum(out, neutral)


def scatter_std(vals, dst, n, *, deg=None, eps=1e-5):
    mu = scatter_mean(vals, dst, n, deg=deg)
    mu2 = scatter_mean(vals * vals, dst, n, deg=deg)
    return jnp.sqrt(jnp.maximum(mu2 - mu * mu, 0.0) + eps)


def degrees(dst: jnp.ndarray, n: int) -> jnp.ndarray:
    return scatter_sum(jnp.ones(dst.shape, jnp.float32), dst, n)


def segment_softmax(scores: jnp.ndarray, dst: jnp.ndarray, n: int) -> jnp.ndarray:
    """Edge-softmax by destination (GAT/Equiformer attention)."""
    mx = jax.ops.segment_max(scores, dst, num_segments=n + 1)[:n]
    mx = jnp.take(mx, jnp.minimum(dst, n - 1), axis=0)
    ex = jnp.exp(scores - jax.lax.stop_gradient(mx))
    den = scatter_sum(ex, dst, n)
    den = jnp.take(den, jnp.minimum(dst, n - 1), axis=0)
    return ex / jnp.maximum(den, 1e-30)
