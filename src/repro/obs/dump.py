"""CLI metric dump: replay a small instrumented workload, print the registry.

    PYTHONPATH=src python -m repro.obs.dump                 # Prometheus text
    PYTHONPATH=src python -m repro.obs.dump --format json   # snapshot() dict
    PYTHONPATH=src python -m repro.obs.dump --workload none # current registry
    PYTHONPATH=src python -m repro.obs.dump --out metrics.prom

The default ``--workload serve`` drives a ``ServingService`` Poisson replay
(mixed BFS/wBFS, one budgeted tenant so admission counters populate) against
the process-global registry, then dumps it — one command that shows every
instrumented layer emitting: per-(op, tenant) latency histograms with
p50/p99, queue depth, flush causes, admission outcomes, engine batch shapes
and cache hits, the mirrored PSAM charge counters, and the
words-vs-wall-clock drift gauge.  ``--workload none`` dumps whatever the
process has already recorded (for embedding in other tools).
"""
from __future__ import annotations

import argparse
import json
import sys

from .metrics import get_registry


def run_serve_workload(n: int = 256, m: int = 1024, requests: int = 12) -> None:
    """Drive a small Poisson replay through ``ServingService`` so every
    instrumented layer records into the process-global registry."""
    import numpy as np

    from ..data import rmat_graph
    from ..serving import ServiceConfig, ServingService

    g = rmat_graph(n, m, weighted=True, seed=3, block_size=32)
    svc = ServingService(
        g,
        config=ServiceConfig(
            slo=0.01,
            max_batch=8,
            budgets={"budgeted": (5e5, 1e7)},
        ),
    )
    rng = np.random.default_rng(0)
    t = 0.0
    for i in range(requests):
        t += float(rng.exponential(1 / 300.0))
        # mix of cohort ops (bfs/wbfs) and an engine op (ppr) so both the
        # service AND engine metric families populate
        op = ("bfs", "wbfs", "bfs", "ppr")[i % 4]
        tenant = "budgeted" if i % 2 else "default"
        svc.submit(op, tenant=tenant, src=int(rng.integers(0, g.n)), now=t)
        svc.tick(t)
        nd = svc.next_deadline()
        if nd is not None and (i + 1 == requests or nd < t + 0.01):
            svc.tick(nd)
    svc.drain(t + 1.0)


def main(argv=None) -> int:
    """Entry point: optional workload, then dump the default registry."""
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument(
        "--format", choices=("prom", "json"), default="prom",
        help="Prometheus text exposition (default) or the snapshot() dict",
    )
    ap.add_argument(
        "--workload", choices=("serve", "none"), default="serve",
        help="'serve' replays a small instrumented Poisson trace first; "
        "'none' dumps the registry as-is",
    )
    ap.add_argument("--out", default=None, metavar="PATH",
                    help="write the dump to PATH instead of stdout")
    ap.add_argument("--n", type=int, default=256, help="workload graph vertices")
    ap.add_argument("--m", type=int, default=1024, help="workload graph edges")
    ap.add_argument("--requests", type=int, default=12,
                    help="workload request count")
    args = ap.parse_args(argv)

    if args.workload == "serve":
        run_serve_workload(n=args.n, m=args.m, requests=args.requests)

    reg = get_registry()
    if args.format == "json":
        text = json.dumps(reg.snapshot(), indent=1, sort_keys=True, default=str)
    else:
        text = reg.to_prometheus_text()
    if args.out:
        with open(args.out, "w") as fh:
            fh.write(text)
        print(f"wrote {len(text.splitlines())} lines to {args.out}")
    else:
        sys.stdout.write(text)
    return 0


if __name__ == "__main__":
    sys.exit(main())
