"""repro.obs — the observability layer: metrics registry + tracing.

Every runtime layer reports here (ISSUE 9):

* :class:`Registry` — labeled counters, gauges and fixed-bucket histograms
  with exact p50/p99 extraction; ``snapshot()`` (nested dict) and
  ``to_prometheus_text()`` (text exposition format) for pull-model export.
* :func:`get_registry` / :func:`set_registry` / :func:`use_registry` —
  the process-global default registry plus injectable instances;
  :func:`noop_registry` installs disabled mode (one attribute lookup per
  hot-path record, bit-identical results, no clock reads).
* :func:`trace_session` / :func:`annotate` — ``jax.profiler`` capture as a
  context manager, usable from serving, carrying the planner's
  ``sage.round`` / ``sage.shard_combine`` named scopes.
* ``python -m repro.obs.dump`` — run a small instrumented serving replay
  (or nothing) and print the registry as Prometheus text or JSON.

What reports where:

* ``ServingService`` — per-(op, tenant) latency histograms, queue depth,
  flush causes (deadline/depth/forced), admission outcomes, occupancy,
  and the PSAM-model-vs-wall-clock drift gauge
  (``sage_psam_drift_words_per_second``).
* ``QueryEngine`` — batch-size histograms, lane/padding counters,
  compile-cache hits/misses (steady-state retraces are a *metric*).
* ``repro.core.plan`` — host-side round-loop timings and rounds-per-call.
* ``PSAMCost`` — every ``charge_*`` mirrored into
  ``sage_psam_*_words_total{charge=...}`` counters.
* ``repro.delta`` + the mutable serving path — applied edits by kind
  (``sage_delta_edits_total``), live overlay size gauges
  (``sage_delta_patch_edges`` / ``sage_delta_tombstones`` /
  ``sage_delta_overlay_small_words``), and compaction telemetry
  (``sage_delta_compactions_total``,
  ``sage_delta_last_compact_write_words``).

See ``docs/observability.md`` for the metric catalogue and a scrape
example.
"""
from .metrics import (
    DEFAULT_LATENCY_BUCKETS,
    Counter,
    Gauge,
    Histogram,
    NoopRegistry,
    Registry,
    exp_buckets,
    get_registry,
    noop_registry,
    set_registry,
    use_registry,
)
from .trace import annotate, trace_session

__all__ = [
    "Counter",
    "Gauge",
    "Histogram",
    "Registry",
    "NoopRegistry",
    "exp_buckets",
    "get_registry",
    "set_registry",
    "use_registry",
    "noop_registry",
    "DEFAULT_LATENCY_BUCKETS",
    "trace_session",
    "annotate",
]
