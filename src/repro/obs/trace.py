"""Round-level tracing — the PR-8 profiler plumbing as a reusable session.

``benchmarks/run.py --profile DIR`` showed the shape: start a
``jax.profiler`` trace, annotate spans, stop, and the captured timeline
carries the ``sage.round`` / ``sage.round.sweep`` / ``sage.shard_combine``
named scopes the planner already emits.  This module packages that into a
context manager any layer can use — a serving deployment wraps a window of
``tick`` calls in ``trace_session`` and gets the same per-round timeline
the benches get, without importing profiler internals.

Only one JAX profiler trace can run per process; nested ``trace_session``
blocks therefore no-op (the outer session owns the capture) instead of
crashing the serving loop that asked for a second window.
"""
from __future__ import annotations

import contextlib
import threading

import jax

__all__ = ["trace_session", "annotate"]

_active = threading.local()


@contextlib.contextmanager
def trace_session(trace_dir: str, *, label: str | None = None):
    """Capture a ``jax.profiler`` trace of the enclosed block into ``trace_dir``.

    Everything executed inside the block lands in one TensorBoard-loadable
    trace under ``trace_dir`` — jitted computations with their
    ``jax.named_scope`` spans (the planner's ``sage.round*`` scopes give
    per-round timing), host-side gaps between dispatches, and any nested
    :func:`annotate` spans.  ``label`` wraps the whole session in one
    ``TraceAnnotation`` span so multiple sessions in one trace directory
    stay tellable apart.

    Re-entrant use (a session inside a session) yields without starting a
    second capture — the outer session already records everything — so a
    serving drain loop can be wrapped unconditionally.  View with
    ``tensorboard --logdir trace_dir`` (Profile plugin) or Perfetto.
    """
    if getattr(_active, "on", False):
        with annotate(label) if label else contextlib.nullcontext():
            yield
        return
    _active.on = True
    jax.profiler.start_trace(trace_dir)
    try:
        with annotate(label) if label else contextlib.nullcontext():
            yield
    finally:
        _active.on = False
        jax.profiler.stop_trace()


def annotate(label: str):
    """A named host-side span (``jax.profiler.TraceAnnotation``).

    Visible in the trace timeline only while a :func:`trace_session` (or a
    bench ``--profile`` capture) is active; free otherwise.  The bench
    harness wraps each benchmark in one of these, and a serving loop can
    annotate individual flushes the same way.
    """
    return jax.profiler.TraceAnnotation(label)
