"""Metrics registry — labeled counters, gauges, fixed-bucket histograms.

The observability substrate every layer reports through (ISSUE 9): the
serving tier records per-``(op, tenant)`` latency histograms and admission
outcomes, the engine records batch shapes and compile-cache hits, the
planner's round loops record host-side timings, and ``PSAMCost`` mirrors
every ``charge_*`` into labeled counters — so the paper's analytic read
model streams out of a live service *next to* measured seconds, which is
what makes the PSAM-vs-wall-clock drift observable while serving.

Design constraints, in order:

* **Near-zero overhead, exactly zero when disabled.**  Instruments are
  resolved once (``registry.counter(...)`` is get-or-create) and hot paths
  hold the instrument, so recording is one method call; with the
  :class:`NoopRegistry` installed every instrument is the same inert
  singleton and recording is one attribute lookup + an empty call.  Code
  that must do real work to produce a sample (read a clock, force a
  device sync) gates on ``registry.enabled`` first, so disabled mode is
  indistinguishable from uninstrumented code.
* **Host-side only.**  Nothing here traces: instruments take concrete
  Python/NumPy scalars.  Callers inside ``jit`` skip recording (they
  check for tracers); the planned/batched execution paths are therefore
  bit-identical with instrumentation on or off — the locked contract of
  ``tests/test_obs.py``.
* **Pull-model exposition.**  ``Registry.snapshot()`` returns one nested
  dict (JSON-able); ``Registry.to_prometheus_text()`` renders the
  standard text exposition format, so any Prometheus scraper ingests the
  metrics unchanged.  ``python -m repro.obs.dump`` is the CLI shell
  around both.

Label discipline: an instrument declares its label *names* once
(``registry.counter(name, help, labels=("op", "tenant"))``) and every
record call passes them as keywords (``c.inc(1, op="bfs", tenant="t0")``).
Series are keyed by the label-value tuple in declared order.  Reading
back, ``value()`` / ``percentile()`` aggregate across all series unless a
label filter narrows them — queue-style "p99 over everything" and
"p99 for (bfs, tenant-7)" come from the same histogram.

Histograms use **fixed bucket bounds** (default: log-spaced latency
buckets, ~10% resolution per bucket): observation is O(log #buckets)
(a bisect), memory is O(#buckets) per series, and p50/p99 extraction is
exact bucket-walk arithmetic with linear interpolation inside the landing
bucket — ``tests/test_obs.py`` pins the extraction against
``numpy.quantile`` to within one bucket's width.
"""
from __future__ import annotations

import bisect
import contextlib
import math
import threading

__all__ = [
    "Counter",
    "Gauge",
    "Histogram",
    "Registry",
    "NoopRegistry",
    "exp_buckets",
    "get_registry",
    "set_registry",
    "use_registry",
    "noop_registry",
    "DEFAULT_LATENCY_BUCKETS",
]


def exp_buckets(lo: float, hi: float, per_decade: int = 24) -> tuple[float, ...]:
    """Log-spaced histogram bucket upper bounds covering ``[lo, hi]``.

    ``per_decade`` buckets per factor of 10 — the default 24 gives
    ~10% worst-case relative resolution per bucket (``10^(1/24) ≈ 1.10``),
    tight enough that histogram-extracted p50/p99 reproduce the private
    ``np.percentile`` numbers the latency bench used to compute (the
    one-source-of-truth satellite of ISSUE 9).
    """
    if not (0 < lo < hi):
        raise ValueError(f"need 0 < lo < hi, got {lo}, {hi}")
    n = int(math.ceil(math.log10(hi / lo) * per_decade))
    return tuple(lo * (10.0 ** (i / per_decade)) for i in range(n + 1))


# seconds: 1us .. ~100s, ~10% resolution — wide enough for both virtual-time
# queueing delays and wall-clock drains on a cold CI runner
DEFAULT_LATENCY_BUCKETS = exp_buckets(1e-6, 100.0)


class _Instrument:
    """Shared label plumbing for one named metric family."""

    kind = "untyped"

    def __init__(self, name: str, help: str = "", labels: tuple = ()):
        self.name = name
        self.help = help
        self.label_names = tuple(labels)
        self._series: dict = {}

    def _key(self, labels: dict) -> tuple:
        if len(labels) != len(self.label_names):
            raise ValueError(
                f"{self.name}: expected labels {self.label_names}, "
                f"got {tuple(labels)}"
            )
        try:
            return tuple(str(labels[k]) for k in self.label_names)
        except KeyError as e:
            raise ValueError(
                f"{self.name}: expected labels {self.label_names}, "
                f"got {tuple(labels)}"
            ) from e

    def _select(self, labels: dict) -> list:
        """Every series whose label values match the (partial) filter."""
        idx = [
            (i, str(v))
            for i, k in enumerate(self.label_names)
            for fk, v in labels.items()
            if fk == k
        ]
        unknown = set(labels) - set(self.label_names)
        if unknown:
            raise ValueError(f"{self.name}: unknown labels {sorted(unknown)}")
        return [
            s
            for key, s in self._series.items()
            if all(key[i] == v for i, v in idx)
        ]

    def series(self):
        """(label-value tuple, series-state) pairs, in insertion order."""
        return list(self._series.items())

    def reset(self) -> None:
        """Zero every series (the label sets themselves are kept)."""
        self._series.clear()


class Counter(_Instrument):
    """Monotone counter family: ``inc(value, **labels)``; never decreases."""

    kind = "counter"

    def inc(self, value: float = 1.0, **labels) -> None:
        """Add ``value`` (≥ 0) to the series named by ``labels``."""
        if value < 0:
            raise ValueError(f"{self.name}: counters only increase ({value})")
        key = self._key(labels)
        self._series[key] = self._series.get(key, 0.0) + value

    def value(self, **labels) -> float:
        """Sum over every series matching the (possibly partial) filter."""
        return float(sum(self._select(labels)))


class Gauge(_Instrument):
    """Point-in-time value family: ``set`` / ``add``; last write wins."""

    kind = "gauge"

    def set(self, value: float, **labels) -> None:
        """Set the series named by ``labels`` to ``value``."""
        self._series[self._key(labels)] = float(value)

    def add(self, value: float, **labels) -> None:
        """Adjust the series by ``value`` (negative allowed)."""
        key = self._key(labels)
        self._series[key] = self._series.get(key, 0.0) + float(value)

    def value(self, **labels) -> float:
        """The matching series' value (sum when the filter matches several;
        NaN when none has been set — 'no data' is not 0)."""
        sel = self._select(labels)
        return float(sum(sel)) if sel else float("nan")


class Histogram(_Instrument):
    """Fixed-bucket histogram family with exact p50/p99 bucket arithmetic.

    Each series holds per-bucket counts (``len(bounds)+1`` — the last is
    the +Inf overflow), a running sum and min/max.  ``percentile`` walks
    the cumulative counts and linearly interpolates inside the landing
    bucket (clamped to the observed min/max so single-sample series are
    exact); resolution is therefore one bucket's width.
    """

    kind = "histogram"

    def __init__(self, name, help="", labels=(), buckets=DEFAULT_LATENCY_BUCKETS):
        super().__init__(name, help, labels)
        self.bounds = tuple(float(b) for b in buckets)
        if list(self.bounds) != sorted(set(self.bounds)):
            raise ValueError(f"{name}: bucket bounds must strictly increase")

    def _new_series(self):
        return {
            "counts": [0] * (len(self.bounds) + 1),
            "sum": 0.0,
            "min": math.inf,
            "max": -math.inf,
        }

    def observe(self, value: float, **labels) -> None:
        """Record one sample into the series named by ``labels``."""
        key = self._key(labels)
        s = self._series.get(key)
        if s is None:
            s = self._series[key] = self._new_series()
        v = float(value)
        s["counts"][bisect.bisect_left(self.bounds, v)] += 1
        s["sum"] += v
        s["min"] = min(s["min"], v)
        s["max"] = max(s["max"], v)

    def count(self, **labels) -> int:
        """Total samples across every series matching the filter."""
        return sum(sum(s["counts"]) for s in self._select(labels))

    def sum(self, **labels) -> float:
        """Sum of all samples across every series matching the filter."""
        return float(sum(s["sum"] for s in self._select(labels)))

    def percentile(self, q: float, **labels) -> float:
        """The ``q``-th percentile (0–100) aggregated over matching series.

        Exact bucket-walk arithmetic: find the bucket holding the
        ``q``-percent rank, linearly interpolate inside it, clamp to the
        observed min/max.  NaN when no samples match.
        """
        if not 0 <= q <= 100:
            raise ValueError(f"percentile must be in [0, 100], got {q}")
        sel = self._select(labels)
        counts = [0] * (len(self.bounds) + 1)
        lo_obs, hi_obs = math.inf, -math.inf
        for s in sel:
            for i, c in enumerate(s["counts"]):
                counts[i] += c
            lo_obs = min(lo_obs, s["min"])
            hi_obs = max(hi_obs, s["max"])
        total = sum(counts)
        if total == 0:
            return float("nan")
        rank = q / 100.0 * total
        cum = 0.0
        for i, c in enumerate(counts):
            if c == 0:
                continue
            if cum + c >= rank:
                lo = self.bounds[i - 1] if i > 0 else min(lo_obs, self.bounds[0])
                hi = self.bounds[i] if i < len(self.bounds) else hi_obs
                frac = (rank - cum) / c if c else 0.0
                est = lo + (hi - lo) * max(frac, 0.0)
                return float(min(max(est, lo_obs), hi_obs))
            cum += c
        return float(hi_obs)


class Registry:
    """Named instrument store: get-or-create, snapshot, Prometheus text.

    One registry is the process-global default (``get_registry``); tests
    and benches inject their own so runs never mix.  ``counter`` /
    ``gauge`` / ``histogram`` are idempotent — the first call creates the
    family, later calls return it (and reject a kind or label-name
    mismatch loudly, since two call sites disagreeing about a metric's
    schema is a bug worth failing on).  ``enabled`` is True; hot paths
    that must do real work to produce a sample (clock reads, device
    syncs) check it so a :class:`NoopRegistry` costs nothing.
    """

    enabled = True

    def __init__(self):
        self._metrics: dict[str, _Instrument] = {}
        self._lock = threading.Lock()

    def _get_or_create(self, cls, name, help, labels, **kw):
        m = self._metrics.get(name)
        if m is None:
            with self._lock:
                m = self._metrics.get(name)
                if m is None:
                    m = self._metrics[name] = cls(name, help, labels, **kw)
        if not isinstance(m, cls) or (
            labels and tuple(labels) != m.label_names
        ):
            raise ValueError(
                f"{name}: already registered as {m.kind} with labels "
                f"{m.label_names}"
            )
        return m

    def counter(self, name: str, help: str = "", labels: tuple = ()) -> Counter:
        """Get-or-create the counter family ``name``."""
        return self._get_or_create(Counter, name, help, labels)

    def gauge(self, name: str, help: str = "", labels: tuple = ()) -> Gauge:
        """Get-or-create the gauge family ``name``."""
        return self._get_or_create(Gauge, name, help, labels)

    def histogram(
        self,
        name: str,
        help: str = "",
        labels: tuple = (),
        buckets=DEFAULT_LATENCY_BUCKETS,
    ) -> Histogram:
        """Get-or-create the histogram family ``name`` (fixed ``buckets``)."""
        return self._get_or_create(
            Histogram, name, help, labels, buckets=buckets
        )

    def get(self, name: str):
        """The instrument registered under ``name``, or None."""
        return self._metrics.get(name)

    def reset(self, prefix: str | None = None) -> None:
        """Zero every series; with ``prefix``, only matching families.

        Instruments stay registered (the schema survives); only the data
        clears — what ``QueryEngine.reset_stats`` uses to reset its
        engine-scoped (``sage_engine_*``) metrics without touching the
        service's or another engine's families.
        """
        for name, m in self._metrics.items():
            if prefix is None or name.startswith(prefix):
                m.reset()

    def snapshot(self) -> dict:
        """One nested JSON-able dict of every family and series.

        ``{name: {kind, help, labels, series: {"a|b": value | hist-dict}}}``
        — series keys join label values with ``|`` (empty string for the
        unlabeled series).  Histogram series expose count/sum/min/max and
        the extracted p50/p99, so a dashboard needs no bucket math.
        """
        out: dict = {}
        for name, m in sorted(self._metrics.items()):
            fam: dict = {
                "kind": m.kind,
                "help": m.help,
                "labels": list(m.label_names),
                "series": {},
            }
            for key, s in m.series():
                skey = "|".join(key)
                if m.kind == "histogram":
                    flt = dict(zip(m.label_names, key))
                    fam["series"][skey] = {
                        "count": sum(s["counts"]),
                        "sum": s["sum"],
                        "min": s["min"],
                        "max": s["max"],
                        "p50": m.percentile(50, **flt),
                        "p99": m.percentile(99, **flt),
                    }
                else:
                    fam["series"][skey] = s
            out[name] = fam
        return out

    def to_prometheus_text(self) -> str:
        """The Prometheus text exposition format (0.0.4) for every family.

        Counters/gauges render one sample per series; histograms render
        cumulative ``_bucket{le=...}`` samples plus ``_sum`` / ``_count``
        — directly scrapeable, no exporter shim needed.
        """
        lines: list[str] = []

        def fmt_labels(names, values, extra=()):
            pairs = [
                f'{k}="{_escape(v)}"' for k, v in list(zip(names, values)) + list(extra)
            ]
            return "{" + ",".join(pairs) + "}" if pairs else ""

        for name, m in sorted(self._metrics.items()):
            if m.help:
                lines.append(f"# HELP {name} {m.help}")
            lines.append(f"# TYPE {name} {m.kind}")
            for key, s in m.series():
                if m.kind == "histogram":
                    cum = 0
                    for bound, c in zip(m.bounds, s["counts"]):
                        cum += c
                        lab = fmt_labels(
                            m.label_names, key, [("le", _fmt_float(bound))]
                        )
                        lines.append(f"{name}_bucket{lab} {cum}")
                    cum += s["counts"][-1]
                    lab = fmt_labels(m.label_names, key, [("le", "+Inf")])
                    lines.append(f"{name}_bucket{lab} {cum}")
                    lab = fmt_labels(m.label_names, key)
                    lines.append(f"{name}_sum{lab} {_fmt_float(s['sum'])}")
                    lines.append(f"{name}_count{lab} {cum}")
                else:
                    lab = fmt_labels(m.label_names, key)
                    lines.append(f"{name}{lab} {_fmt_float(s)}")
        return "\n".join(lines) + ("\n" if lines else "")


def _escape(v: str) -> str:
    return str(v).replace("\\", r"\\").replace('"', r"\"").replace("\n", r"\n")


def _fmt_float(v: float) -> str:
    f = float(v)
    return repr(int(f)) if f == int(f) and abs(f) < 1e15 else repr(f)


class _NoopInstrument:
    """The inert instrument every :class:`NoopRegistry` family resolves to.

    Recording (``inc`` / ``set`` / ``add`` / ``observe``) discards its
    arguments; reads return the empty-registry answers (0 counts, NaN
    values) so code that unconditionally reads metrics still works.
    """

    name = "noop"
    label_names = ()

    def inc(self, value=1.0, **labels):
        """Discard the sample (disabled mode)."""

    def set(self, value, **labels):
        """Discard the sample (disabled mode)."""

    def add(self, value, **labels):
        """Discard the sample (disabled mode)."""

    def observe(self, value, **labels):
        """Discard the sample (disabled mode)."""

    def value(self, **labels):
        """NaN — a disabled registry has no data."""
        return float("nan")

    def count(self, **labels):
        """0 samples — a disabled registry has no data."""
        return 0

    def sum(self, **labels):
        """0.0 — a disabled registry has no data."""
        return 0.0

    def percentile(self, q, **labels):
        """NaN — a disabled registry has no data."""
        return float("nan")

    def series(self):
        """No series — a disabled registry has no data."""
        return []

    def reset(self):
        """Nothing to reset."""


_NOOP_INSTRUMENT = _NoopInstrument()


class NoopRegistry:
    """Disabled-mode registry: every family is the same inert singleton.

    Installing this via ``set_registry`` (or constructing components with
    ``registry=noop_registry()``) turns every hot-path record into one
    attribute lookup plus an empty call, and ``enabled=False`` lets code
    skip the work of *producing* samples (clock reads, device syncs) —
    which is what makes no-op mode indistinguishable from the
    uninstrumented baseline (the <3% / bit-exactness acceptance bars).
    """

    enabled = False

    def counter(self, name, help="", labels=()):
        """The shared no-op instrument."""
        return _NOOP_INSTRUMENT

    def gauge(self, name, help="", labels=()):
        """The shared no-op instrument."""
        return _NOOP_INSTRUMENT

    def histogram(self, name, help="", labels=(), buckets=()):
        """The shared no-op instrument."""
        return _NOOP_INSTRUMENT

    def get(self, name):
        """None — nothing is ever registered."""
        return None

    def reset(self, prefix=None):
        """Nothing to reset."""

    def snapshot(self):
        """An empty snapshot."""
        return {}

    def to_prometheus_text(self):
        """An empty exposition."""
        return ""


_NOOP_REGISTRY = NoopRegistry()
_default_registry: Registry | NoopRegistry = Registry()
_default_lock = threading.Lock()


def get_registry():
    """The process-global default registry (enabled unless swapped out).

    Components resolve their registry here when none is injected —
    ``QueryEngine`` / ``ServingService`` at construction, ``PSAMCost`` /
    ``round_loop`` per call — so one ``set_registry(noop_registry())``
    disables the whole process.
    """
    return _default_registry


def set_registry(reg):
    """Install ``reg`` as the process-global default; returns the old one."""
    global _default_registry
    with _default_lock:
        old = _default_registry
        _default_registry = reg
    return old


def noop_registry() -> NoopRegistry:
    """The shared disabled-mode registry singleton."""
    return _NOOP_REGISTRY


@contextlib.contextmanager
def use_registry(reg):
    """Temporarily install ``reg`` as the process default (context manager).

    The enabled-vs-noop parity tests run the same workload under
    ``use_registry(Registry())`` and ``use_registry(noop_registry())``
    and assert bit-identical results; benches use it to scope a
    measurement to a fresh registry without touching global state.
    """
    old = set_registry(reg)
    try:
        yield reg
    finally:
        set_registry(old)
