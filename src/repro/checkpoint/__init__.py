from .ckpt import latest_step, restore, restore_latest, save

__all__ = ["save", "restore", "restore_latest", "latest_step"]
