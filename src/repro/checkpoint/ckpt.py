"""Fault-tolerant checkpointing: atomic step-directory save / restore-latest.

Design for 1000+ nodes:

* every array is pulled to host (as numpy) and written per-process; at real
  multi-host scale each process writes only its addressable shards and the
  restore path re-shards via ``jax.device_put`` with the target
  NamedSharding — the on-disk format (one .npz of leaves + a JSON manifest
  of treedef/shapes) is host-count independent, which is what makes
  *elastic* restarts (restore onto a different mesh) possible.
* writes go to ``<step>.tmp`` then ``os.replace`` → a crash mid-write never
  corrupts the latest checkpoint (restart tests kill the loop mid-run).
* the data-pipeline cursor and RNG key are part of the checkpoint, so a
  restart continues bit-identically.
* ``keep`` trailing checkpoints are retained (default 3).
"""
from __future__ import annotations

import json
import os
import shutil

import jax
import numpy as np


def _flatten(tree):
    leaves, treedef = jax.tree.flatten(tree)
    return leaves, treedef


def save(ckpt_dir: str, step: int, tree, *, keep: int = 3) -> str:
    os.makedirs(ckpt_dir, exist_ok=True)
    final = os.path.join(ckpt_dir, f"step_{step:010d}")
    tmp = final + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)
    leaves, treedef = _flatten(tree)
    arrays = {f"leaf_{i}": np.asarray(x) for i, x in enumerate(leaves)}
    np.savez(os.path.join(tmp, "arrays.npz"), **arrays)
    manifest = {
        "step": step,
        "n_leaves": len(leaves),
        "treedef": str(treedef),
    }
    with open(os.path.join(tmp, "manifest.json"), "w") as fh:
        json.dump(manifest, fh)
    os.replace(tmp, final)  # atomic publish
    _gc(ckpt_dir, keep)
    return final


def _gc(ckpt_dir: str, keep: int):
    steps = sorted(
        d for d in os.listdir(ckpt_dir) if d.startswith("step_") and not d.endswith(".tmp")
    )
    for d in steps[:-keep]:
        shutil.rmtree(os.path.join(ckpt_dir, d), ignore_errors=True)


def latest_step(ckpt_dir: str) -> int | None:
    if not os.path.isdir(ckpt_dir):
        return None
    steps = [
        int(d.split("_")[1])
        for d in os.listdir(ckpt_dir)
        if d.startswith("step_") and not d.endswith(".tmp")
    ]
    return max(steps) if steps else None


def restore(ckpt_dir: str, step: int, example_tree, *, shardings=None):
    """Restore into the structure of ``example_tree``; optionally device_put
    with a matching shardings pytree (elastic restore onto a new mesh)."""
    path = os.path.join(ckpt_dir, f"step_{step:010d}")
    data = np.load(os.path.join(path, "arrays.npz"))
    leaves, treedef = _flatten(example_tree)
    loaded = [data[f"leaf_{i}"] for i in range(len(leaves))]
    tree = jax.tree.unflatten(treedef, loaded)
    if shardings is not None:
        tree = jax.tree.map(jax.device_put, tree, shardings)
    return tree


def restore_latest(ckpt_dir: str, example_tree, *, shardings=None):
    step = latest_step(ckpt_dir)
    if step is None:
        return None, None
    return restore(ckpt_dir, step, example_tree, shardings=shardings), step
