"""vertexSubset (Ligra §2) — a frontier over the vertices.

The canonical representation is a dense bool[n] mask: exactly the paper's
"dense" frontier, O(n) *bits* of small memory.  A sparse (index) view is
derived on demand with ``compact_mask`` and is still O(n) words — the PSAM
budget — never O(m).
"""
from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp

from .primitives import compact_mask


@partial(
    jax.tree_util.register_dataclass,
    data_fields=["mask"],
    meta_fields=["n"],
)
@dataclasses.dataclass(frozen=True)
class VertexSubset:
    mask: jnp.ndarray  # bool[n]
    n: int

    @property
    def size(self) -> jnp.ndarray:
        return jnp.sum(self.mask).astype(jnp.int32)

    def is_empty(self) -> jnp.ndarray:
        return ~jnp.any(self.mask)

    def to_indices(self):
        return compact_mask(self.mask)


def from_indices(n: int, idx) -> VertexSubset:
    """Frontier from a vertex-id list (out-of-range ids drop silently)."""
    idx = jnp.asarray(idx, dtype=jnp.int32).reshape(-1)
    mask = jnp.zeros(n, dtype=bool).at[idx].set(True, mode="drop")
    return VertexSubset(mask=mask, n=n)


def from_mask(mask) -> VertexSubset:
    """Frontier from an existing bool[n] membership mask (no copy of n)."""
    mask = jnp.asarray(mask, dtype=bool)
    return VertexSubset(mask=mask, n=mask.shape[0])


def full(n: int) -> VertexSubset:
    """The all-vertices frontier (dense passes, e.g. PageRank rounds)."""
    return VertexSubset(mask=jnp.ones(n, dtype=bool), n=n)


def empty(n: int) -> VertexSubset:
    """The empty frontier (the loop-termination fixpoint)."""
    return VertexSubset(mask=jnp.zeros(n, dtype=bool), n=n)
