"""Parallel primitives (§2 of the paper): scan, reduce, filter/compact,
histogram — all O(len) work, O(log) depth equivalents in JAX.

These operate on the PSAM *small memory*: every output here is O(n) words.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

INF_I32 = jnp.int32(2**31 - 1)
INF_F32 = jnp.float32(jnp.inf)


def exclusive_scan(x: jnp.ndarray) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Prefix sum: returns (exclusive prefix sums, total)."""
    inc = jnp.cumsum(x)
    total = inc[-1] if x.shape[0] else jnp.zeros((), x.dtype)
    return inc - x, total


def compact_mask(mask: jnp.ndarray, *, fill: int | None = None):
    """Filter primitive: indices where ``mask`` is True, front-packed.

    Returns (idx int32[len(mask)] padded with ``fill`` (default len(mask)),
    count int32).  O(n) small-memory words — never proportional to edges.
    """
    size = mask.shape[0]
    if fill is None:
        fill = size
    idx = jnp.nonzero(mask, size=size, fill_value=fill)[0].astype(jnp.int32)
    return idx, jnp.sum(mask).astype(jnp.int32)


def histogram(ids: jnp.ndarray, num_bins: int, weights=None) -> jnp.ndarray:
    """Dense histogram (the paper's §4.3.4 dense-histogram routine)."""
    if weights is None:
        weights = jnp.ones_like(ids, dtype=jnp.int32)
    return jax.ops.segment_sum(weights, ids, num_segments=num_bins)


def segment_reduce(vals, ids, num_segments, monoid: str):
    """Reduce-by-key with a named monoid; ids == num_segments-1 may be a
    sentinel row (caller drops it)."""
    if monoid == "sum":
        return jax.ops.segment_sum(vals, ids, num_segments=num_segments)
    if monoid == "min":
        return jax.ops.segment_min(vals, ids, num_segments=num_segments)
    if monoid == "max":
        return jax.ops.segment_max(vals, ids, num_segments=num_segments)
    if monoid == "or":
        return (
            jax.ops.segment_max(vals.astype(jnp.int32), ids, num_segments=num_segments)
            > 0
        )
    raise ValueError(f"unknown monoid {monoid}")


def monoid_identity(monoid: str, dtype):
    """Identity element as a *hashable host scalar* (usable as take fill_value)."""
    import numpy as np

    np_dtype = np.dtype(dtype)
    if monoid == "sum":
        return np_dtype.type(0)
    if monoid == "min":
        if np.issubdtype(np_dtype, np.integer):
            return np_dtype.type(np.iinfo(np_dtype).max)
        return np_dtype.type(np.inf)
    if monoid == "max":
        if np.issubdtype(np_dtype, np.integer):
            return np_dtype.type(np.iinfo(np_dtype).min)
        return np_dtype.type(-np.inf)
    if monoid == "or":
        return np.bool_(False)
    raise ValueError(monoid)


# ----------------------------------------------------------------------
# Bit tricks — the TPU-idiomatic stand-in for the paper's TZCNT/BLSR loops
# (§4.2.3): we operate on whole words of forbidden/active bits at once.
# ----------------------------------------------------------------------
def mex_from_forbidden(words: jnp.ndarray) -> jnp.ndarray:
    """Minimum excludant: smallest bit index not set, over uint32 words.

    ``words``: uint32[..., W] little-endian bit blocks; returns int32[...].
    Used by graph coloring (smallest available color ≤ 32*W-1).
    """
    W = words.shape[-1]
    free = ~words  # a set bit in `free` is an available color
    has_free = free != 0
    # index of lowest set bit per word
    low = lowest_set_bit(free)
    first_word = jnp.argmax(has_free, axis=-1)
    any_free = jnp.any(has_free, axis=-1)
    picked = jnp.take_along_axis(low, first_word[..., None], axis=-1)[..., 0]
    mex = first_word.astype(jnp.int32) * 32 + picked
    return jnp.where(any_free, mex, jnp.int32(32 * W))


def lowest_set_bit(x: jnp.ndarray) -> jnp.ndarray:
    """Index of the lowest set bit of each uint32 (undefined→0 when x==0)."""
    x = x.astype(jnp.uint32)
    iso = x & (~x + jnp.uint32(1))  # isolate lowest bit (two's complement)
    # log2 of a power of two via popcount(iso - 1)
    return popcount32(iso - jnp.uint32(iso != 0)).astype(jnp.int32)


def popcount32(x: jnp.ndarray) -> jnp.ndarray:
    x = x.astype(jnp.uint32)
    x = x - ((x >> 1) & jnp.uint32(0x55555555))
    x = (x & jnp.uint32(0x33333333)) + ((x >> 2) & jnp.uint32(0x33333333))
    x = (x + (x >> 4)) & jnp.uint32(0x0F0F0F0F)
    return ((x * jnp.uint32(0x01010101)) >> 24).astype(jnp.int32)
