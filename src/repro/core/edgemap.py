"""edgeMap / edgeMapChunked (§4.1) — PSAM-efficient frontier expansion.

Four execution modes, mirroring the paper:

* ``dense``  — the pull-style pass over *all* edge slots (one masked
  segment-reduce).  Work O(m); the O(n)-words output discipline holds because
  the per-edge intermediates are fused away on TPU (and streamed block-wise by
  the Pallas kernel in ``repro.kernels.edge_block_spmv``).
* ``sparse`` — EDGEMAPCHUNKED: only blocks owned by frontier vertices are
  touched.  The active block list is O(n) words (block size == d_avg ⇒
  #blocks = O(n), App. A), and blocks are processed in fixed-size chunks so
  the peak intermediate is ``chunk_blocks × F_B`` words — the JAX analogue of
  the paper's thread-local chunk pool (count → scan → scatter replaces
  malloc-per-thread).
* ``sparse_streamed`` — the same chunk loop, but on a ``CompressedCSR``
  backend the per-chunk tile view is produced by the frontier-sparse Pallas
  kernel (``repro.kernels.compressed_spmv``, PrefetchScalarGridSpec): the
  compacted live-id list steers the BlockSpec index_maps, so only
  frontier-owned compressed tiles move HBM→VMEM — read volume proportional
  to the live blocks, never NB, which is the PSAM sparse-round claim.
  Backends without a streaming decoder (raw ``CSRGraph``) and
  exception-dense compressed graphs fall back to plain ``sparse`` —
  identical results either way (the streamed tile is exception-patched to
  exactness).
* ``auto``   — Beamer direction optimization: dense when the frontier's
  incident-edge count exceeds ``m / dense_frac``.

Semantics (Ligra): ``out[v] = monoid over {map_fn(x[u], w_uv) : u∈frontier,
(u,v) active}``, plus a ``touched`` mask (v received ≥1 contribution).  The
caller applies the ``cond`` predicate to form the next frontier, exactly like
Ligra's C(v).

Every mode accepts either execution backend (``CSRGraph | CompressedCSR``,
see ``repro.core.backend``): the dense pass reads the backend's block view
(a lazy, fused cumsum decode for compressed graphs) and the chunked pass
decodes block tiles *inside* the chunk loop, so the peak intermediate stays
``chunk_blocks × F_B`` words regardless of storage format.

``edgemap_reduce_batched`` / ``edge_map_batched`` run B concurrent queries
through ONE sweep of the same bodies: the edge stream is read once per
round and fanned across the B frontier/state columns, the throughput lever
the serving subsystem (``repro.serving``) is built on.
"""
from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp
from jax import lax

from ..tuning.defaults import DEFAULT_CHUNK_BLOCKS, DEFAULT_DENSE_FRAC
from .backend import GraphLike, dense_block_view, tile_block_view
from .graph_filter import GraphFilter, edge_active_words, unpack_word_bits
from .primitives import compact_mask, monoid_identity, segment_reduce
from .vertex_subset import VertexSubset


def _identity_map(x_src, w):
    del w
    return x_src


def _edge_active_view(g: GraphLike, edge_active) -> jnp.ndarray | None:
    """Normalize any edge-activity form to a bool (NB, F_B) block view.

    ``edge_active`` is planner-native: a ``GraphFilter``, packed uint32
    (NB, F_B/32) words, or a bool slot mask all mean the same thing at every
    layer (see ``repro.core.graph_filter.edge_active_words``).  Bool masks
    short-circuit (no pack/unpack round trip)."""
    if edge_active is None:
        return None
    if isinstance(edge_active, GraphFilter) or (
        hasattr(edge_active, "dtype") and edge_active.dtype == jnp.uint32
    ):
        return unpack_word_bits(edge_active_words(edge_active, g.block_size))
    return jnp.asarray(edge_active).reshape(g.num_blocks, g.block_size)


def _gather_rows(arr, idx, fill):
    return jnp.take(arr, idx, axis=0, mode="fill", fill_value=fill)


def _streaming_decoder(g: GraphLike, edge_active, interpret: bool | None = None):
    """The kernel-backed tile view for the ``sparse_streamed`` mode, or None.

    Returns ``tile(bids) -> (dst, w)`` streaming ONLY the named blocks
    HBM→VMEM (packed ``edge_active`` words folded into ``dst`` in-VMEM:
    masked slots come back as the sentinel ``n``, so the caller's
    ``dst < n`` activity test subsumes the filter).  None when the backend
    has no streaming decoder — raw ``CSRGraph`` (its block view is already
    uncompressed; the chunk gather IS the stream) or an exception-dense
    ``CompressedCSR`` (the COO patch would stop being a rare path).

    ``interpret`` is the Pallas lowering decision threaded down from the
    plan (``None`` → resolve per backend, ``repro.kernels.lowering``)."""
    from .compressed import CompressedCSR, exception_dense

    if not isinstance(g, CompressedCSR) or exception_dense(g):
        return None
    # lazy import: kernels depend on core, never the other way around
    from ..kernels.compressed_spmv.ops import (
        _exception_row_targets,
        compressed_chunked_stream_tile,
    )

    if edge_active is None:
        words = None
    elif isinstance(edge_active, GraphFilter) or (
        hasattr(edge_active, "dtype") and edge_active.dtype == jnp.uint32
    ):
        words = edge_active_words(edge_active, g.block_size)
    else:  # bool-ish slot mask, flat or (NB, F_B) — pack to canonical words
        words = edge_active_words(jnp.asarray(edge_active).astype(bool), g.block_size)

    # exception rows are id-independent: decode them exactly ONCE here, so
    # the chunk loop's per-iteration patch is a cheap match + scatter (the
    # O(NE·F_B) exact decode becomes a hoisted loop input, not loop body)
    exact = _exception_row_targets(g, words) if g.n_exceptions else None

    def tile(bids):
        return compressed_chunked_stream_tile(
            g, bids, words, exact_rows=exact, interpret=interpret
        )

    return tile


def _combine(monoid, a, b):
    if monoid == "sum":
        return a + b
    if monoid == "min":
        return jnp.minimum(a, b)
    if monoid == "max":
        return jnp.maximum(a, b)
    if monoid == "or":
        return a | b
    raise ValueError(monoid)


def edgemap_dense(
    g: GraphLike,
    frontier_mask: jnp.ndarray,
    x: jnp.ndarray,
    *,
    monoid: str = "min",
    map_fn: Callable = _identity_map,
    edge_active: jnp.ndarray | None = None,
    interpret: bool | None = None,
):
    """Pull-style pass over all edge slots.  Returns (out[n,...], touched[n]).

    Reads the backend's block view: for ``CompressedCSR`` the target decode
    is a lazy cumsum fused into the gather/segment-reduce below.

    ``interpret`` is accepted for call-site symmetry with the chunked /
    streamed paths but is a no-op here: this body is pure jnp (the fused
    decode+reduce IS the lowering), there is no Pallas kernel to steer.
    """
    del interpret
    n, FB = g.n, g.block_size
    ident = monoid_identity(monoid, x.dtype)
    block_dst, block_w = dense_block_view(g)
    edge_dst = block_dst.reshape(-1)
    frontier_blk = _gather_rows(frontier_mask, g.block_src, False)
    act = (frontier_blk[:, None] & (block_dst < jnp.int32(n))).reshape(-1)
    ea = _edge_active_view(g, edge_active)
    if ea is not None:
        act = act & ea.reshape(-1)
    xs_blk = _gather_rows(x, g.block_src, ident)
    xs = jnp.broadcast_to(
        xs_blk[:, None], (g.num_blocks, FB) + x.shape[1:]
    ).reshape((g.num_blocks * FB,) + x.shape[1:])
    edge_w = block_w.reshape(-1)
    w = edge_w if x.ndim == 1 else edge_w[..., None]
    vals = map_fn(xs, w)
    if vals.ndim > act.ndim:
        sel = act.reshape(act.shape + (1,) * (vals.ndim - act.ndim))
    else:
        sel = act
    vals = jnp.where(sel, vals, ident)
    ids = jnp.where(act, edge_dst, jnp.int32(n))
    out = segment_reduce(vals, ids, n + 1, monoid)[:n]
    touched = (
        jax.ops.segment_max(act.astype(jnp.int32), ids, num_segments=n + 1)[:n] > 0
    )
    return out, touched


def edgemap_chunked(
    g: GraphLike,
    frontier_mask: jnp.ndarray,
    x: jnp.ndarray,
    *,
    monoid: str = "min",
    map_fn: Callable = _identity_map,
    edge_active: jnp.ndarray | None = None,
    chunk_blocks: int = DEFAULT_CHUNK_BLOCKS,
    streamed: bool = False,
    interpret: bool | None = None,
):
    """EDGEMAPCHUNKED — only frontier-owned blocks, chunked emission.

    With ``streamed=True`` (the ``sparse_streamed`` mode) a ``CompressedCSR``
    backend swaps the per-chunk jnp decode for the frontier-sparse Pallas
    kernel: the compacted live-id chunk is the kernel's scalar-prefetched
    operand, and only those blocks' compressed tiles stream HBM→VMEM —
    ``ceil(k / chunk_blocks)`` launches of ``chunk_blocks`` blocks each, so
    streamed bytes track the live count ``k``, not NB.  Results are
    bit-identical to the un-streamed path (the kernel tile is
    exception-patched to exactness and the filter folding commutes with the
    activity test); backends without a streaming decoder ignore the flag.
    """
    n, NB, FB = g.n, g.num_blocks, g.block_size
    C = min(chunk_blocks, NB)
    nchunks = -(-NB // C)
    ident = monoid_identity(monoid, x.dtype)

    blk_act = _gather_rows(frontier_mask, g.block_src, False)
    idx, k = compact_mask(blk_act, fill=NB)  # O(n) words: NB = O(n) by F_B=d_avg
    idx = jnp.pad(idx, (0, nchunks * C - NB), constant_values=NB)

    feat_shape = x.shape[1:]
    out0 = jnp.full((n + 1,) + feat_shape, ident, dtype=x.dtype)
    if monoid == "or":
        out0 = jnp.zeros((n + 1,) + feat_shape, dtype=bool)
    touched0 = jnp.zeros(n + 1, dtype=jnp.int32)

    stream_tile = _streaming_decoder(g, edge_active, interpret) if streamed else None
    bits = _edge_active_view(g, edge_active) if stream_tile is None else None

    def body(state):
        i, out, touched = state
        bids = lax.dynamic_slice(idx, (i * C,), (C,))
        if stream_tile is not None:
            # Pallas frontier-sparse decode: ONLY these C blocks' compressed
            # tiles move; filter bits already folded (masked slots → n)
            dsts, ws = stream_tile(bids)                   # (C, FB)
            act = dsts < n
        else:
            # per-backend tile view; compressed backends decode here, inside
            # the chunk loop, so the peak intermediate stays C × F_B words
            dsts, ws = tile_block_view(g, bids)            # (C, FB)
            act = dsts < n
            if bits is not None:
                act = act & _gather_rows(bits, bids, False)
        srcs = _gather_rows(g.block_src, bids, n)          # (C,)
        xs = _gather_rows(x, srcs, ident)                  # (C, ...)
        xs = jnp.broadcast_to(xs[:, None], (C, FB) + feat_shape)
        vals = map_fn(xs, ws if not feat_shape else ws[..., None])
        sel = act if not feat_shape else act[..., None]
        vals = jnp.where(sel, vals, ident)
        ids = jnp.where(act, dsts, n).reshape(-1)
        flat = vals.reshape((C * FB,) + feat_shape)
        out = _combine(monoid, out, segment_reduce(flat, ids, n + 1, monoid))
        touched = jnp.maximum(
            touched,
            jax.ops.segment_max(act.astype(jnp.int32).reshape(-1), ids, num_segments=n + 1),
        )
        return i + 1, out, touched

    def cond(state):
        i, _, _ = state
        return (i * C < k) & (i < nchunks)

    _, out, touched = lax.while_loop(cond, body, (jnp.int32(0), out0, touched0))
    return out[:n], touched[:n] > 0


def edgemap_reduce(
    g: GraphLike,
    frontier_mask: jnp.ndarray,
    x: jnp.ndarray,
    *,
    monoid: str = "min",
    map_fn: Callable = _identity_map,
    edge_active: jnp.ndarray | None = None,
    mode: str = "auto",
    dense_frac: float | None = None,
    chunk_blocks: int | None = None,
    auto_sparse: str | None = None,
    interpret: bool | None = None,
    plan=None,
):
    """Direction-optimized edgeMap (Beamer §4.1.1).

    ``mode`` is ``'dense' | 'sparse' | 'sparse_streamed' | 'auto'`` (see the
    module docstring); ``sparse_streamed`` is ``sparse`` with the
    frontier-sparse Pallas decode on ``CompressedCSR`` backends — only live
    compressed tiles stream — and falls back to ``sparse`` elsewhere.

    With ``plan`` (an ``ExecutionPlan``, see ``repro.core.plan``) the same
    call runs wherever the plan says: a meshless plan resolves the mode /
    chunking knobs and stays on this code path; a mesh plan routes to the
    sharded executor, which runs these very bodies per shard under
    ``shard_map`` (``g`` must then be the plan-prepared ``ShardedGraph``).
    Explicit ``mode`` / ``dense_frac`` / ``chunk_blocks`` arguments win over
    the plan's.

    ``edge_active`` (GraphFilter | packed uint32 words | bool slot mask) is
    plan-native too: on a mesh plan the packed words shard block-range-wise
    alongside the edge blocks and unpack inside each shard's local body; a
    ``ShardedEdgeActive`` from ``plan.prepare(g, edge_active=...)`` skips
    the in-trace split.
    """
    if plan is not None:
        if plan.is_sharded:
            from .plan import sharded_edgemap_reduce

            return sharded_edgemap_reduce(
                plan,
                g,
                frontier_mask,
                x,
                monoid=monoid,
                map_fn=map_fn,
                edge_active=edge_active,
                mode=mode,
                dense_frac=dense_frac,
                chunk_blocks=chunk_blocks,
                auto_sparse=auto_sparse,
                interpret=interpret,
            )
        mode = plan.resolve_mode(mode)
        dense_frac = plan.dense_frac if dense_frac is None else dense_frac
        chunk_blocks = plan.chunk_blocks if chunk_blocks is None else chunk_blocks
        auto_sparse = plan.auto_sparse if auto_sparse is None else auto_sparse
        interpret = plan.interpret if interpret is None else interpret
    dense_frac = DEFAULT_DENSE_FRAC if dense_frac is None else dense_frac
    chunk_blocks = DEFAULT_CHUNK_BLOCKS if chunk_blocks is None else chunk_blocks
    auto_sparse = "sparse" if auto_sparse is None else auto_sparse
    from ..obs import get_registry

    _reg = get_registry()
    if _reg.enabled and not isinstance(frontier_mask, jax.core.Tracer):
        # eager single-device sweep — count by resolved mode ('auto' means
        # the dense/sparse pick happens in-trace per round); jitted rounds
        # show up in round_loop's metrics instead, never double-counted
        _reg.counter(
            "sage_edgemap_calls_total",
            "eager edgemap_reduce dispatches by resolved mode",
            labels=("mode",),
        ).inc(mode=mode)
    if mode == "dense":
        return edgemap_dense(
            g, frontier_mask, x, monoid=monoid, map_fn=map_fn, edge_active=edge_active
        )
    if mode in ("sparse", "sparse_streamed"):
        return edgemap_chunked(
            g,
            frontier_mask,
            x,
            monoid=monoid,
            map_fn=map_fn,
            edge_active=edge_active,
            chunk_blocks=chunk_blocks,
            streamed=mode == "sparse_streamed",
            interpret=interpret,
        )
    sum_deg = jnp.sum(jnp.where(frontier_mask, g.degrees, 0))
    use_dense = sum_deg * dense_frac > g.m
    return lax.cond(
        use_dense,
        lambda: edgemap_dense(
            g, frontier_mask, x, monoid=monoid, map_fn=map_fn, edge_active=edge_active
        ),
        lambda: edgemap_chunked(
            g,
            frontier_mask,
            x,
            monoid=monoid,
            map_fn=map_fn,
            edge_active=edge_active,
            chunk_blocks=chunk_blocks,
            streamed=auto_sparse == "sparse_streamed",
            interpret=interpret,
        ),
    )


def edgemap_dense_batched(
    g: GraphLike,
    frontier_masks: jnp.ndarray,
    xb: jnp.ndarray,
    *,
    monoid: str = "min",
    map_fn: Callable = _identity_map,
    edge_active: jnp.ndarray | None = None,
    map_lanes: jnp.ndarray | None = None,
):
    """Dense pull pass, B queries per sweep.  Returns (out[B,n], touched[B,n]).

    The jnp analogue of the kernels' query-batch dimension: the edge-side
    work — block view (the compressed backend's fused cumsum decode
    included), validity/filter masks, and the scatter routing ``ids`` — is
    computed ONCE, and the monoid reduction runs as a single
    segment-reduce over m edge rows of B-wide value vectors, not B
    separate scatters.  Per-lane inactive slots contribute the monoid
    identity at their real target row (instead of the single-query path's
    sentinel reroute), which reduces to the same value: every lane is
    bit-identical to its own ``edgemap_dense`` run.

    ``map_lanes`` (bool[B], optional) applies ``map_fn`` only on the
    selected lanes; the rest take the identity map (``xs`` pass through
    bit-exactly).  This is what lets heterogeneous query kinds — e.g. BFS
    lanes (identity over candidate parents) and wBFS lanes (weighted
    relaxation over distances) — share ONE edge sweep while each lane runs
    its own recurrence.
    """
    n, NB, FB = g.n, g.num_blocks, g.block_size
    B = xb.shape[0]
    ident = monoid_identity(monoid, xb.dtype)
    block_dst, block_w = dense_block_view(g)        # shared: decoded once
    edge_dst = block_dst.reshape(-1)
    valid = edge_dst < jnp.int32(n)
    ids = jnp.where(valid, edge_dst, jnp.int32(n))  # shared scatter routing
    ea = _edge_active_view(g, edge_active)
    if ea is not None:
        valid = valid & ea.reshape(-1)
    frontier_blk = jnp.take(
        frontier_masks, g.block_src, axis=1, mode="fill", fill_value=False
    )                                               # (B, NB)
    act = (frontier_blk[:, :, None] & valid.reshape(NB, FB)[None]).reshape(B, -1)
    xs_blk = jnp.take(xb, g.block_src, axis=1, mode="fill", fill_value=ident)
    xs = jnp.broadcast_to(xs_blk[:, :, None], (B, NB, FB)).reshape(B, -1)
    vals = map_fn(xs, block_w.reshape(-1)[None, :])
    if map_lanes is not None:
        vals = jnp.where(map_lanes[:, None], vals, xs)
    vals = jnp.where(act, vals, ident)
    out = segment_reduce(vals.T, ids, n + 1, monoid)[:n]          # (n, B)
    touched = (
        jax.ops.segment_max(act.T.astype(jnp.int32), ids, num_segments=n + 1)[:n]
        > 0
    )
    return out.T, touched.T


def edgemap_chunked_batched_streamed(
    g: GraphLike,
    frontier_masks: jnp.ndarray,
    xb: jnp.ndarray,
    *,
    monoid: str = "min",
    map_fn: Callable = _identity_map,
    edge_active: jnp.ndarray | None = None,
    chunk_blocks: int = DEFAULT_CHUNK_BLOCKS,
    map_lanes: jnp.ndarray | None = None,
    interpret: bool | None = None,
):
    """Batched EDGEMAPCHUNKED over the streaming kernel: B queries, one
    compressed-tile read per live block.

    ``map_lanes`` (bool[B], optional) applies ``map_fn`` only on the
    selected lanes (the rest pass ``xs`` through bit-exactly), exactly as
    in ``edgemap_dense_batched`` — the cross-op serving rounds ride it.

    The live set is the UNION of the per-lane frontiers' blocks (any lane
    owning a block keeps it live), compacted once; each chunk is decoded by
    the frontier-sparse Pallas kernel exactly once and fanned across the B
    lanes — lanes for which a block is dead contribute the monoid identity
    at its real target rows, the same identity-contribution discipline as
    ``edgemap_dense_batched``.  Per-lane results equal the single-query
    ``edgemap_chunked(streamed=True)`` runs exactly for int/min/max/or
    state; float sums may differ in association order (allclose), exactly
    like the dense batched path's segment-reduce.  NVRAM-side reads are the
    union live blocks, once — not B times, and never NB.
    """
    n, NB, FB = g.n, g.num_blocks, g.block_size
    B = xb.shape[0]
    C = min(chunk_blocks, NB)
    nchunks = -(-NB // C)
    ident = monoid_identity(monoid, xb.dtype)

    frontier_blk = jnp.take(
        frontier_masks, g.block_src, axis=1, mode="fill", fill_value=False
    )                                                   # (B, NB)
    blk_any = jnp.any(frontier_blk, axis=0)             # union live set
    idx, k = compact_mask(blk_any, fill=NB)
    idx = jnp.pad(idx, (0, nchunks * C - NB), constant_values=NB)

    stream_tile = _streaming_decoder(g, edge_active, interpret)
    assert stream_tile is not None, "caller guards on _streaming_decoder"

    out0 = jnp.full((n + 1, B), ident, dtype=xb.dtype)
    if monoid == "or":
        out0 = jnp.zeros((n + 1, B), dtype=bool)
    touched0 = jnp.zeros((n + 1, B), dtype=jnp.int32)

    def body(state):
        i, out, touched = state
        bids = lax.dynamic_slice(idx, (i * C,), (C,))
        dsts, ws = stream_tile(bids)                    # decoded ONCE for all B
        srcs = _gather_rows(g.block_src, bids, n)       # (C,)
        act_sh = dsts < n                               # shared: filter folded
        lane_blk = jnp.take(
            frontier_masks, srcs, axis=1, mode="fill", fill_value=False
        )                                               # (B, C) — per-lane live
        xs = jnp.take(xb, srcs, axis=1, mode="fill", fill_value=ident)  # (B, C)
        xs = jnp.broadcast_to(xs[:, :, None], (B, C, FB))
        vals = map_fn(xs, ws[None])
        if map_lanes is not None:
            vals = jnp.where(map_lanes[:, None, None], vals, xs)
        act = lane_blk[:, :, None] & act_sh[None]       # (B, C, FB)
        vals = jnp.where(act, vals, ident).reshape(B, C * FB)
        ids = jnp.where(act_sh, dsts, n).reshape(-1)    # shared scatter routing
        out = _combine(monoid, out, segment_reduce(vals.T, ids, n + 1, monoid))
        touched = jnp.maximum(
            touched,
            jax.ops.segment_max(
                act.reshape(B, -1).T.astype(jnp.int32), ids, num_segments=n + 1
            ),
        )
        return i + 1, out, touched

    def cond(state):
        i, _, _ = state
        return (i * C < k) & (i < nchunks)

    _, out, touched = lax.while_loop(cond, body, (jnp.int32(0), out0, touched0))
    return out[:n].T, touched[:n].T > 0


def edgemap_reduce_batched(
    g: GraphLike,
    frontier_masks: jnp.ndarray,
    xb: jnp.ndarray,
    *,
    monoid: str = "min",
    map_fn: Callable = _identity_map,
    edge_active: jnp.ndarray | None = None,
    mode: str = "auto",
    dense_frac: float | None = None,
    chunk_blocks: int | None = None,
    auto_sparse: str | None = None,
    flavor_crossover: float | None = None,
    interpret: bool | None = None,
    plan=None,
    map_lanes: jnp.ndarray | None = None,
):
    """Batched edgeMap: B concurrent queries share ONE edge sweep.

    ``frontier_masks`` is bool[B, n], ``xb`` is [B, n] (per-query vertex
    state); returns ``(out[B, n], touched[B, n])``.  The edge blocks — the
    scarce read-only NVRAM resource in the PSAM — are streamed once per
    round and applied against all B state columns, so the edge-byte reads
    amortize ÷B (``PSAMCost.charge_edgemap_batched``) while the mutable
    state stays O(B·n) words of small memory.

    ``map_lanes`` (bool[B], optional) applies ``map_fn`` only on the
    selected lanes; unselected lanes take the identity map, bit-exactly.
    This is the cross-op batching hook: lanes running different query
    kinds (BFS candidate-parent propagation, wBFS weighted relaxation)
    share the same sweep, each with its own per-edge map — see
    ``repro.algorithms.traversal.traversal_cohort_rounds`` and the
    ``ServingService`` drain loop built on it.

    Execution: the dense strategy runs ``edgemap_dense_batched`` — one
    shared edge sweep, one m-row × B-column segment reduction.  The sparse
    strategy vmaps ``edgemap_chunked`` (per-lane active-block lists differ;
    the chunk loop masks finished lanes' carries).  ``auto`` evaluates the
    per-lane Beamer predicate and selects per lane between the two
    shared-sweep branches — exactly what a vmapped ``lax.cond`` lowers to.
    Every query's result is bit-identical to its own single-query
    ``edgemap_reduce`` run — the property the serving parity suite locks
    in.

    ``plan`` routes the batch exactly like ``edgemap_reduce``: a meshless
    plan resolves the mode/chunking knobs here; a mesh plan runs the
    batched local body per shard and monoid-combines the O(B·n) output
    (``g`` must then be the plan-prepared ``ShardedGraph``).
    """
    if plan is not None:
        if plan.is_sharded:
            from .plan import sharded_edgemap_reduce_batched

            return sharded_edgemap_reduce_batched(
                plan,
                g,
                frontier_masks,
                xb,
                monoid=monoid,
                map_fn=map_fn,
                edge_active=edge_active,
                mode=mode,
                dense_frac=dense_frac,
                chunk_blocks=chunk_blocks,
                auto_sparse=auto_sparse,
                interpret=interpret,
                map_lanes=map_lanes,
            )
        mode = plan.resolve_mode(mode)
        # batched rounds take the BATCHED knobs: their own Beamer threshold
        # (the batched dense body amortizes one shared sweep over all B
        # lanes) and their own sparse flavor (one shared live-block loop vs
        # B vmapped chunk loops) — neither crossover transfers from the
        # single-query calibration
        dense_frac = plan.dense_frac_batched if dense_frac is None else dense_frac
        chunk_blocks = plan.chunk_blocks if chunk_blocks is None else chunk_blocks
        auto_sparse = plan.auto_sparse_batched if auto_sparse is None else auto_sparse
        interpret = plan.interpret if interpret is None else interpret
        if flavor_crossover is None:
            flavor_crossover = plan.batched_flavor_crossover
    dense_frac = DEFAULT_DENSE_FRAC if dense_frac is None else dense_frac
    chunk_blocks = DEFAULT_CHUNK_BLOCKS if chunk_blocks is None else chunk_blocks
    auto_sparse = "sparse" if auto_sparse is None else auto_sparse

    def lane_map(ml):
        # per-lane map selection under vmap: ml is this lane's scalar flag,
        # so the select is a broadcast where — identity lanes pass xs
        # through bit-exactly
        if map_lanes is None:
            return map_fn
        return lambda xs, w: jnp.where(ml, map_fn(xs, w), xs)

    if xb.ndim != 2:
        # feature-dim vertex state: fall back to the vmapped bodies (the
        # streamed kernel path is not vmapped — plain sparse instead)
        vmode = "sparse" if mode == "sparse_streamed" else mode
        ml_axis = None if map_lanes is None else 0
        ml0 = jnp.zeros(xb.shape[0], bool) if map_lanes is None else map_lanes
        return jax.vmap(
            lambda fm, xv, ml: edgemap_reduce(
                g, fm, xv, monoid=monoid, map_fn=lane_map(ml),
                edge_active=edge_active,
                mode=vmode, dense_frac=dense_frac, chunk_blocks=chunk_blocks,
                interpret=interpret,
            ),
            in_axes=(0, 0, ml_axis),
        )(frontier_masks, xb, ml0)
    if mode == "dense":
        return edgemap_dense_batched(
            g, frontier_masks, xb, monoid=monoid, map_fn=map_fn,
            edge_active=edge_active, map_lanes=map_lanes,
        )

    def sparse_one(fm, xv, ml):
        return edgemap_chunked(
            g, fm, xv, monoid=monoid, map_fn=lane_map(ml),
            edge_active=edge_active, chunk_blocks=chunk_blocks,
            interpret=interpret,
        )

    ml_axis = None if map_lanes is None else 0
    ml0 = jnp.zeros(xb.shape[0], bool) if map_lanes is None else map_lanes

    def sparse_vmap(fm, xv):
        return jax.vmap(sparse_one, in_axes=(0, 0, ml_axis))(fm, xv, ml0)

    if mode == "sparse_streamed":
        if _streaming_decoder(g, edge_active) is not None:
            return edgemap_chunked_batched_streamed(
                g, frontier_masks, xb, monoid=monoid, map_fn=map_fn,
                edge_active=edge_active, chunk_blocks=chunk_blocks,
                map_lanes=map_lanes, interpret=interpret,
            )
        return sparse_vmap(frontier_masks, xb)
    if mode == "sparse":
        return sparse_vmap(frontier_masks, xb)
    # auto: ONE Beamer predicate for the whole batch, on the aggregate
    # density.  Per-lane selection can't win here: the batched dense body is
    # one shared sweep regardless of density, and the batched sparse body's
    # chunk loop is paced by the densest lane — so a straddling batch that
    # ran both and picked per lane (what vmap(lax.cond) lowers to) would pay
    # dense + sparse for a result bit-identical to either branch alone.  At
    # B=1 the aggregate IS the lane predicate, matching single-query auto.
    sum_deg = jnp.sum(jnp.where(frontier_masks, g.degrees[None, :], 0), axis=1)
    use_dense = jnp.sum(sum_deg) * dense_frac > frontier_masks.shape[0] * g.m

    def dense_all():
        return edgemap_dense_batched(
            g, frontier_masks, xb, monoid=monoid, map_fn=map_fn,
            edge_active=edge_active, map_lanes=map_lanes,
        )

    def sparse_all():
        # the calibrated sparse flavor: the streamed union path when the
        # table picked it AND the backend can stream, plain vmapped chunks
        # otherwise — per-lane results are bit-identical either way
        if (
            auto_sparse == "sparse_streamed"
            and _streaming_decoder(g, edge_active) is not None
        ):
            def streamed():
                return edgemap_chunked_batched_streamed(
                    g, frontier_masks, xb, monoid=monoid, map_fn=map_fn,
                    edge_active=edge_active, chunk_blocks=chunk_blocks,
                    map_lanes=map_lanes, interpret=interpret,
                )

            if flavor_crossover is None or flavor_crossover >= 1.0:
                return streamed()
            # measured flavor crossover: the shared live-block loop wins
            # only while the union frontier is sparse enough — switch to
            # the vmapped chunk loops above it, at the batch's mean lane
            # density (the quantity the calibration sweep varied)
            mean_density = jnp.sum(sum_deg) / (xb.shape[0] * g.m)
            return lax.cond(
                mean_density < flavor_crossover,
                streamed,
                lambda: sparse_vmap(frontier_masks, xb),
            )
        return sparse_vmap(frontier_masks, xb)

    return lax.cond(use_dense, dense_all, sparse_all)


def edge_map_batched(
    g: GraphLike,
    frontier_masks: jnp.ndarray,
    xb: jnp.ndarray,
    *,
    monoid: str = "min",
    map_fn: Callable = _identity_map,
    cond_masks: jnp.ndarray | None = None,
    update: str = "min",
    edge_active: jnp.ndarray | None = None,
    mode: str = "auto",
    plan=None,
    map_lanes: jnp.ndarray | None = None,
):
    """Batched Ligra-style EDGEMAP: returns (new_x[B, n], next_masks[B, n]).

    The batched analogue of ``edge_map``, with bool masks in place of
    ``VertexSubset`` (frontiers are per-query rows).  ``cond_masks[q, v]``
    plays C(v) for query q; ``update`` merges per-query contributions
    exactly as in ``edge_map``; ``map_lanes`` restricts ``map_fn`` to the
    selected lanes exactly as in ``edgemap_reduce_batched``."""
    out, touched = edgemap_reduce_batched(
        g, frontier_masks, xb, monoid=monoid, map_fn=map_fn,
        edge_active=edge_active, mode=mode, plan=plan, map_lanes=map_lanes,
    )
    ok = touched if cond_masks is None else (touched & cond_masks)
    if update == "min":
        new_x = jnp.where(ok, jnp.minimum(xb, out), xb)
        changed = ok & (out < xb)
    elif update == "max":
        new_x = jnp.where(ok, jnp.maximum(xb, out), xb)
        changed = ok & (out > xb)
    elif update == "sum":
        new_x = jnp.where(ok, xb + out, xb)
        changed = ok
    elif update == "replace":
        new_x = jnp.where(ok, out, xb)
        changed = ok
    else:
        raise ValueError(update)
    return new_x, changed


def edge_map(
    g: GraphLike,
    frontier: VertexSubset,
    x: jnp.ndarray,
    *,
    monoid: str = "min",
    map_fn: Callable = _identity_map,
    cond_mask: jnp.ndarray | None = None,
    update: str = "min",
    edge_active: jnp.ndarray | None = None,
    mode: str = "auto",
    plan=None,
):
    """Full Ligra-style EDGEMAP: returns (new_x, next_frontier).

    ``cond_mask[v]`` plays C(v); ``update`` decides how reduced contributions
    merge into x ('min'|'max'|'sum'|'replace').  ``plan`` routes execution
    (single-device or sharded) exactly as in ``edgemap_reduce``.
    """
    out, touched = edgemap_reduce(
        g, frontier.mask, x, monoid=monoid, map_fn=map_fn, edge_active=edge_active,
        mode=mode, plan=plan,
    )
    ok = touched if cond_mask is None else (touched & cond_mask)
    if update == "min":
        new_x = jnp.where(ok, jnp.minimum(x, out), x)
        changed = ok & (out < x)
    elif update == "max":
        new_x = jnp.where(ok, jnp.maximum(x, out), x)
        changed = ok & (out > x)
    elif update == "sum":
        new_x = jnp.where(ok, x + out, x)
        changed = ok
    elif update == "replace":
        new_x = jnp.where(ok, out, x)
        changed = ok
    else:
        raise ValueError(update)
    return new_x, VertexSubset(mask=changed, n=g.n)
