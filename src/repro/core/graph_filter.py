"""graphFilter (§4.2) — a bit-packed, mutable *view* over the immutable CSR.

The CSR edge arrays (large memory) are never written.  All mutation happens
in this structure, which costs ``m`` bits + O(n) words — the relaxed PSAM
small-memory budget of O(n + m/log n) words:

* ``bits``        uint32[NB, F_B/32] — one bit per edge slot (1 = active)
* ``active_deg``  int32[n]           — live degree per vertex
* ``block_live``  derived             — block has ≥1 active edge (the paper's
  empty-block compaction: dead blocks are skipped by chunked traversal, which
  is the static-shape analogue of physically packing them out)
* ``dirty``       bool[n]            — vertices whose edges changed this round

The paper's per-block ``offset``/``block-id`` metadata exists to support CPU
pointer compaction; under XLA static shapes the same role is played by the
compacted live-block index list produced on demand (O(n) words).

TPU adaptation of §4.2.3: the TZCNT/BLSR word loop becomes vectorized
popcount/mask arithmetic over whole VMEM tiles (see kernels/filter_pack).

The filter composes with either execution backend (``CSRGraph`` or
``CompressedCSR``): the block size is the compression block size (§4.2.1),
so the bits line up 1:1 with decoded compressed blocks.
"""
from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp

from .backend import GraphLike
from .primitives import popcount32

WORD = 32


@partial(
    jax.tree_util.register_dataclass,
    data_fields=["bits", "active_deg", "dirty"],
    meta_fields=["n", "num_blocks", "block_size"],
)
@dataclasses.dataclass(frozen=True)
class GraphFilter:
    bits: jnp.ndarray        # uint32[NB, F_B//32]
    active_deg: jnp.ndarray  # int32[n]
    dirty: jnp.ndarray       # bool[n]
    n: int
    num_blocks: int
    block_size: int

    @property
    def num_active_edges(self) -> jnp.ndarray:
        return jnp.sum(self.active_deg)

    @property
    def block_live(self) -> jnp.ndarray:
        return jnp.any(self.bits != 0, axis=-1)

    def shard(self, num_shards: int) -> list["GraphFilter"]:
        """Partition the filter words alongside the edge blocks.

        The bit words are block-aligned (one row per block), so filter ∘
        shard composes exactly like ``GraphBackend.shard``: the same
        ``ceil(NB / num_shards)`` block-range split, with the padded tail
        rows all-zero (padding blocks carry no active edges).  The O(n)
        vertex state (``active_deg``, ``dirty``) stays replicated per shard,
        mirroring the graph's replicated ``degrees``.  Shard s's bits line
        up 1:1 with shard s of the graph, so a shard-local edgeMap consumes
        them unchanged.
        """
        if num_shards < 1:
            raise ValueError(f"num_shards must be >= 1, got {num_shards}")
        from .csr import sharded_block_counts

        per, padded_total = sharded_block_counts(self.num_blocks, num_shards)
        pad = padded_total - self.num_blocks
        bits = self.bits
        if pad:
            bits = jnp.pad(bits, ((0, pad), (0, 0)))
        return [
            dataclasses.replace(
                self, bits=bits[s * per : (s + 1) * per], num_blocks=per
            )
            for s in range(num_shards)
        ]


def make_filter(g: GraphLike) -> GraphFilter:
    """makeFilter (§4.2.2): all real edges start active."""
    words = g.block_size // WORD
    mask = g.edge_valid.reshape(g.num_blocks, words, WORD)
    weights = (jnp.uint32(1) << jnp.arange(WORD, dtype=jnp.uint32))
    bits = jnp.sum(jnp.where(mask, weights, jnp.uint32(0)), axis=-1, dtype=jnp.uint32)
    return GraphFilter(
        bits=bits,
        active_deg=g.degrees,
        dirty=jnp.zeros(g.n, dtype=bool),
        n=g.n,
        num_blocks=g.num_blocks,
        block_size=g.block_size,
    )


def unpack_word_bits(bits: jnp.ndarray) -> jnp.ndarray:
    """uint32[..., W] → bool[..., W*32], little-endian within each word.

    The canonical bit order for every graphFilter consumer (edgeMap, the
    Pallas kernels and their oracles) — change the packing here and in
    ``pack_bits`` together.
    """
    shifts = jnp.arange(WORD, dtype=jnp.uint32)
    opened = ((bits[..., :, None] >> shifts) & jnp.uint32(1)).astype(bool)
    return opened.reshape(bits.shape[:-1] + (bits.shape[-1] * WORD,))


def unpack_bits(f: GraphFilter) -> jnp.ndarray:
    """bool[NB, F_B] active-edge mask (the dense working view)."""
    return unpack_word_bits(f.bits)


def pack_bits(mask: jnp.ndarray) -> jnp.ndarray:
    """bool[NB, F_B] → uint32[NB, F_B//32]."""
    nb, fb = mask.shape
    m3 = mask.reshape(nb, fb // WORD, WORD)
    weights = (jnp.uint32(1) << jnp.arange(WORD, dtype=jnp.uint32))
    return jnp.sum(jnp.where(m3, weights, jnp.uint32(0)), axis=-1, dtype=jnp.uint32)


def edge_active_flat(f: GraphFilter) -> jnp.ndarray:
    """bool[NB*F_B] — flat edge-slot activity mask."""
    return unpack_bits(f).reshape(-1)


def edge_active_words(edge_active, block_size: int) -> jnp.ndarray:
    """Normalize any edge-activity form to packed uint32[NB, F_B/32] words.

    The one canonical on-wire/in-kernel filter representation (one bit per
    edge slot, block-aligned rows, little-endian within each word — see
    ``unpack_word_bits``).  Accepts:

    * a ``GraphFilter``              — its ``bits`` verbatim
    * packed uint32 (NB, F_B/32)     — passed through
    * a bool edge-slot mask          — flat [NB*F_B] or [NB, F_B], packed here

    jit-traceable (pure reshape/pack), so per-round masks normalize inside
    algorithm loops without leaving the trace.
    """
    if isinstance(edge_active, GraphFilter):
        return edge_active.bits
    a = jnp.asarray(edge_active)
    if a.dtype == jnp.uint32:
        if a.ndim != 2 or a.shape[-1] != block_size // WORD:
            raise ValueError(
                f"packed edge_active must be (NB, {block_size // WORD}) uint32, "
                f"got {a.shape}"
            )
        return a
    if a.dtype == jnp.bool_:
        return pack_bits(a.reshape(-1, block_size))
    raise TypeError(
        f"edge_active must be a GraphFilter, packed uint32 words, or a bool "
        f"slot mask, got dtype {a.dtype}"
    )


def _recount(g: GraphLike, bits: jnp.ndarray) -> jnp.ndarray:
    """active_deg from bits via per-block popcount + segment-sum (PackVertex)."""
    per_block = jnp.sum(popcount32(bits), axis=-1)  # int32[NB]
    return jax.ops.segment_sum(per_block, g.block_src, num_segments=g.n + 1)[: g.n]


def pack_vertices(
    g: GraphLike,
    f: GraphFilter,
    subset_mask: jnp.ndarray,
    keep_pred: jnp.ndarray,
) -> GraphFilter:
    """edgeMapPack (§4.2.2): for vertices in ``subset_mask``, clear bits of
    edges failing ``keep_pred`` (bool[NB*F_B] or bool[NB, F_B]).

    Marks destination vertices of deleted edges dirty.
    """
    keep = keep_pred.reshape(g.num_blocks, g.block_size)
    active = unpack_bits(f)
    in_subset = jnp.take(subset_mask, g.block_src, mode="fill", fill_value=False)[:, None]
    new_active = jnp.where(in_subset, active & keep, active)
    deleted = active & ~new_active
    # dirty: destinations of deleted edges
    ddst = jnp.where(deleted, g.block_dst, jnp.int32(g.n)).reshape(-1)
    dirty_hits = jax.ops.segment_max(
        deleted.astype(jnp.int32).reshape(-1), ddst, num_segments=g.n + 1
    )[: g.n]
    bits = pack_bits(new_active)
    return GraphFilter(
        bits=bits,
        active_deg=_recount(g, bits),
        dirty=f.dirty | (dirty_hits > 0),
        n=f.n,
        num_blocks=f.num_blocks,
        block_size=f.block_size,
    )


def filter_edges(g: GraphLike, f: GraphFilter, keep_pred: jnp.ndarray):
    """filterEdges (§4.2): pack every vertex; returns (filter', remaining)."""
    all_v = jnp.ones(g.n, dtype=bool)
    f2 = pack_vertices(g, f, all_v, keep_pred)
    return f2, f2.num_active_edges


def filter_edges_pred(g: GraphLike, f: GraphFilter, pred_fn):
    """Convenience: ``pred_fn(src, dst, w) -> keep?`` evaluated on all slots."""
    keep = pred_fn(g.edge_src, g.edge_dst, g.edge_w)
    return filter_edges(g, f, keep)


def live_block_indices(f: GraphFilter):
    """Compacted indices of non-empty blocks (the paper's block compaction,
    expressed as an O(n)-word index list instead of a physical move)."""
    from .primitives import compact_mask

    return compact_mask(f.block_live, fill=f.num_blocks)
