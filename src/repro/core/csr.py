"""Blocked, read-only CSR graph structure — the PSAM "large memory".

The graph is built once on the host (numpy) and never mutated afterwards.
Edges are laid out in fixed-size *blocks* of ``F_B`` slots (the paper's filter
block size, §4.2.1); every block belongs to exactly one source vertex, and a
vertex with degree d owns ``ceil(d / F_B)`` blocks.  Padding slots carry the
sentinel target ``n`` so that gathers/segment-reductions can route them to a
dead row.

Two views of the same storage are kept (both derived, both read-only):

* flat view   — ``edge_src/edge_dst/edge_w`` of length ``NB * F_B``
* block view  — ``block_src[NB]`` plus the flat arrays reshaped ``(NB, F_B)``

On a real TPU the flat/block arrays live in HBM and are streamed block-wise
into VMEM by the Pallas kernels; all mutable per-vertex state is ``O(n)``
words (the PSAM "small memory").
"""
from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

DEFAULT_BLOCK_SIZE = 128  # lanes; multiple of 32 so the filter bitset packs into words


def sharded_block_counts(num_blocks: int, num_shards: int) -> tuple[int, int]:
    """(blocks per shard, total blocks incl. padding) for a planner split.

    The single source of truth for the shard partitioning arithmetic:
    ``GraphBackend.shard``, the cost model, the dry-run specs and
    ``shard_blocks_for_mesh`` all derive from it.  Non-dividing counts
    round *up* — the tail shard pads with empty sentinel blocks, it is
    never truncated."""
    per = -(-num_blocks // max(num_shards, 1))
    return per, per * num_shards


@partial(
    jax.tree_util.register_dataclass,
    data_fields=[
        "offsets",
        "block_offsets",
        "block_src",
        "edge_src",
        "edge_dst",
        "edge_w",
        "degrees",
    ],
    meta_fields=["n", "m", "num_blocks", "block_size", "weighted"],
)
@dataclasses.dataclass(frozen=True)
class CSRGraph:
    """Immutable blocked-CSR graph (PSAM large memory)."""

    # --- data (device arrays, read-only after build) ---
    offsets: jnp.ndarray        # int32[n+1]   — into flat edge slots (block-padded)
    block_offsets: jnp.ndarray  # int32[n+1]   — into blocks
    block_src: jnp.ndarray      # int32[NB]    — owner vertex of each block
    edge_src: jnp.ndarray       # int32[NB*F_B] (sentinel n on padding)
    edge_dst: jnp.ndarray       # int32[NB*F_B] (sentinel n on padding)
    edge_w: jnp.ndarray         # float32[NB*F_B]
    degrees: jnp.ndarray        # int32[n]     — true degrees
    # --- static metadata ---
    n: int
    m: int                      # true (unpadded) number of directed edge slots
    num_blocks: int
    block_size: int
    weighted: bool

    # ------------------------------------------------------------------
    @property
    def block_dst(self) -> jnp.ndarray:
        return self.edge_dst.reshape(self.num_blocks, self.block_size)

    @property
    def block_w(self) -> jnp.ndarray:
        return self.edge_w.reshape(self.num_blocks, self.block_size)

    @property
    def edge_valid(self) -> jnp.ndarray:
        """bool[NB*F_B] — True on real (non-padding) edge slots."""
        return self.edge_dst < jnp.int32(self.n)

    @property
    def avg_degree(self) -> float:
        return self.m / max(self.n, 1)

    def out_degree(self, v):
        return self.degrees[v]

    def shard(self, num_shards: int) -> list["CSRGraph"]:
        """Partition the block set into ``num_shards`` contiguous ranges.

        Block counts that don't divide ``num_shards`` are padded with *empty*
        blocks (owner = sentinel n, all targets = n, zero weights) so every
        shard carries the same ``ceil(NB / num_shards)`` blocks and the tail
        shard is never truncated.  Each shard keeps the full O(n) vertex
        metadata (``degrees``, ``offsets``) replicated — only the O(m) edge
        blocks split — so a shard is itself a valid ``GraphBackend`` over the
        *global* vertex space: same ``n``, same sentinel, same frontier
        semantics.  The planner (``repro.core.plan``) stacks shards into one
        pytree and runs the ordinary edgeMap bodies per shard inside
        ``shard_map``.
        """
        if num_shards < 1:
            raise ValueError(f"num_shards must be >= 1, got {num_shards}")
        NB, FB = self.num_blocks, self.block_size
        per, padded_total = sharded_block_counts(NB, num_shards)
        pad = padded_total - NB
        bsrc = np.asarray(self.block_src)
        edst = np.asarray(self.edge_dst).reshape(NB, FB)
        esrc = np.asarray(self.edge_src).reshape(NB, FB)
        ew = np.asarray(self.edge_w).reshape(NB, FB)
        if pad:
            bsrc = np.concatenate([bsrc, np.full(pad, self.n, np.int32)])
            edst = np.concatenate([edst, np.full((pad, FB), self.n, np.int32)])
            esrc = np.concatenate([esrc, np.full((pad, FB), self.n, np.int32)])
            ew = np.concatenate([ew, np.zeros((pad, FB), np.float32)])
        shards = []
        for s in range(num_shards):
            lo, hi = s * per, (s + 1) * per
            shards.append(
                dataclasses.replace(
                    self,
                    block_src=jnp.asarray(bsrc[lo:hi]),
                    edge_src=jnp.asarray(esrc[lo:hi].reshape(-1)),
                    edge_dst=jnp.asarray(edst[lo:hi].reshape(-1)),
                    edge_w=jnp.asarray(ew[lo:hi].reshape(-1)),
                    num_blocks=per,
                )
            )
        return shards


def build_csr(
    n: int,
    src: np.ndarray,
    dst: np.ndarray,
    w: np.ndarray | None = None,
    *,
    block_size: int = DEFAULT_BLOCK_SIZE,
    symmetrize: bool = False,
) -> CSRGraph:
    """Build a blocked CSR graph on the host.

    ``src``/``dst`` are directed edge endpoints.  With ``symmetrize=True`` the
    reverse edges are added (and exact duplicates removed), matching the
    paper's symmetrized inputs.
    """
    src = np.asarray(src, dtype=np.int64)
    dst = np.asarray(dst, dtype=np.int64)
    if w is None:
        weighted = False
        w = np.ones_like(src, dtype=np.float32)
    else:
        weighted = True
        w = np.asarray(w, dtype=np.float32)

    if symmetrize:
        src, dst, w = (
            np.concatenate([src, dst]),
            np.concatenate([dst, src]),
            np.concatenate([w, w]),
        )
    # drop self loops, dedupe
    keep = src != dst
    src, dst, w = src[keep], dst[keep], w[keep]
    key = src * n + dst
    _, uniq = np.unique(key, return_index=True)
    src, dst, w = src[uniq], dst[uniq], w[uniq]

    order = np.lexsort((dst, src))
    src, dst, w = src[order], dst[order], w[order]
    m = int(src.shape[0])

    deg = np.bincount(src, minlength=n).astype(np.int64)
    nblk = np.maximum((deg + block_size - 1) // block_size, 0)
    block_offsets = np.zeros(n + 1, dtype=np.int64)
    np.cumsum(nblk, out=block_offsets[1:])
    num_blocks = int(block_offsets[-1])
    num_blocks = max(num_blocks, 1)  # keep shapes non-degenerate
    if int(block_offsets[-1]) == 0:
        block_offsets[-1] = 1  # single dummy block owned by sentinel

    slots = num_blocks * block_size
    edge_src = np.full(slots, n, dtype=np.int32)
    edge_dst = np.full(slots, n, dtype=np.int32)
    edge_w = np.zeros(slots, dtype=np.float32)

    offsets = np.zeros(n + 1, dtype=np.int64)
    np.cumsum(nblk * block_size, out=offsets[1:])
    # scatter edges into their padded slots
    starts = offsets[src]
    within = np.zeros(m, dtype=np.int64)
    if m:
        # position of each edge within its vertex's run (src-sorted)
        first_of_run = np.concatenate([[True], src[1:] != src[:-1]])
        run_ids = np.cumsum(first_of_run) - 1
        run_starts = np.flatnonzero(first_of_run)
        within = np.arange(m) - run_starts[run_ids]
    pos = starts + within
    edge_src[pos] = src.astype(np.int32)
    edge_dst[pos] = dst.astype(np.int32)
    edge_w[pos] = w

    block_src = np.full(num_blocks, n, dtype=np.int32)
    for_v = np.repeat(np.arange(n, dtype=np.int32), nblk)
    block_src[: for_v.shape[0]] = for_v

    return CSRGraph(
        offsets=jnp.asarray(offsets, dtype=jnp.int32),
        block_offsets=jnp.asarray(block_offsets, dtype=jnp.int32),
        block_src=jnp.asarray(block_src),
        edge_src=jnp.asarray(edge_src),
        edge_dst=jnp.asarray(edge_dst),
        edge_w=jnp.asarray(edge_w),
        degrees=jnp.asarray(deg, dtype=jnp.int32),
        n=int(n),
        m=m,
        num_blocks=num_blocks,
        block_size=int(block_size),
        weighted=weighted,
    )


def graph_spec(n: int, num_blocks: int, block_size: int, weighted: bool = False):
    """ShapeDtypeStruct stand-in for a CSRGraph (used by the dry-run)."""
    s = jax.ShapeDtypeStruct
    slots = num_blocks * block_size
    return CSRGraph(
        offsets=s((n + 1,), jnp.int32),
        block_offsets=s((n + 1,), jnp.int32),
        block_src=s((num_blocks,), jnp.int32),
        edge_src=s((slots,), jnp.int32),
        edge_dst=s((slots,), jnp.int32),
        edge_w=s((slots,), jnp.float32),
        degrees=s((n,), jnp.int32),
        n=n,
        m=slots,
        num_blocks=num_blocks,
        block_size=block_size,
        weighted=weighted,
    )
