"""Compressed blocked CSR — the Ligra+ byte-code format (§5.1.3) adapted to
TPU fixed-width decoding.

The paper's web-graph inputs are stored with per-block difference encoding
and decoded block-at-a-time; the graphFilter block size is tied to the
compression block size (§4.2.1).  Byte-aligned varints are a sequential
CPU format, so the TPU-idiomatic equivalent is **fixed-width delta
packing**: per block we store the first target (int32) and uint16 deltas
between consecutive sorted targets; the rare deltas ≥ 2¹⁶ go to a COO
exception list.  Decoding a block is a vectorized cumsum over the lane
dimension — exactly the "decode the whole block to fetch one edge"
discipline the paper's filter iterator uses (App. D.1) — and the
graphFilter bits apply unchanged on top of the decoded block.

Compression ratio: 32-bit targets → ~16.25 bits/edge + exceptions, i.e.
~2× on locality-friendly orderings (the paper reports 2.7–2.9× with
byte codes on web graphs).
"""
from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from .csr import CSRGraph

ESCAPE = np.uint16(0xFFFF)


@partial(
    jax.tree_util.register_dataclass,
    data_fields=[
        "block_first",
        "deltas",
        "exc_block",
        "exc_slot",
        "exc_value",
        "block_src",
        "degrees",
    ],
    meta_fields=["n", "m", "num_blocks", "block_size", "n_exceptions"],
)
@dataclasses.dataclass(frozen=True)
class CompressedCSR:
    """Read-only difference-encoded blocked CSR (PSAM large memory)."""

    block_first: jnp.ndarray  # int32[NB]       — first target per block
    deltas: jnp.ndarray       # uint16[NB, FB]  — deltas[:, 0] unused (=0)
    exc_block: jnp.ndarray    # int32[NE]       — exception coordinates
    exc_slot: jnp.ndarray     # int32[NE]
    exc_value: jnp.ndarray    # int32[NE]       — true delta value
    block_src: jnp.ndarray    # int32[NB]
    degrees: jnp.ndarray      # int32[n]
    n: int
    m: int
    num_blocks: int
    block_size: int
    n_exceptions: int

    @property
    def compressed_bytes(self) -> int:
        return int(
            self.block_first.size * 4
            + self.deltas.size * 2
            + self.n_exceptions * 12
        )

    @property
    def uncompressed_bytes(self) -> int:
        return int(self.deltas.size * 4)


def compress(g: CSRGraph) -> CompressedCSR:
    """Host-side encoder (runs once at load, like the paper's preprocessing)."""
    NB, FB = g.num_blocks, g.block_size
    dst = np.asarray(g.edge_dst).reshape(NB, FB).astype(np.int64)
    # padding slots carry the sentinel n; treat them as repeats of the last
    # real target so deltas stay small, and rely on the CSR valid mask later
    first = dst[:, 0].astype(np.int32)
    prev = dst[:, :-1]
    cur = dst[:, 1:]
    raw = cur - prev
    raw = np.concatenate([np.zeros((NB, 1), np.int64), raw], axis=1)
    over = (raw >= int(ESCAPE)) | (raw < 0)
    deltas = np.where(over, int(ESCAPE), raw).astype(np.uint16)
    eb, es = np.nonzero(over)
    return CompressedCSR(
        block_first=jnp.asarray(first),
        deltas=jnp.asarray(deltas),
        exc_block=jnp.asarray(eb.astype(np.int32)),
        exc_slot=jnp.asarray(es.astype(np.int32)),
        exc_value=jnp.asarray(raw[eb, es].astype(np.int32)),
        block_src=g.block_src,
        degrees=g.degrees,
        n=g.n,
        m=g.m,
        num_blocks=NB,
        block_size=FB,
        n_exceptions=int(eb.shape[0]),
    )


def decode_blocks(c: CompressedCSR) -> jnp.ndarray:
    """Decode ALL blocks → int32[NB, FB] targets (vectorized cumsum).

    O(m) work / O(log F_B) depth per block, matching the paper's block
    decode cost; used by edgeMap over compressed graphs.
    """
    d = c.deltas.astype(jnp.int32)
    # patch exceptions (escaped wide deltas)
    if c.n_exceptions:
        d = d.at[c.exc_block, c.exc_slot].set(c.exc_value, mode="drop")
    d = d.at[:, 0].set(0)
    return c.block_first[:, None] + jnp.cumsum(d, axis=1, dtype=jnp.int32)


def decode_block(c: CompressedCSR, bid) -> jnp.ndarray:
    """Decode a single block (the filter-iterator path, App. D.1)."""
    d = jnp.take(c.deltas, bid, axis=0).astype(jnp.int32)
    if c.n_exceptions:
        hit = c.exc_block == bid
        d = d.at[jnp.where(hit, c.exc_slot, c.block_size)].set(
            jnp.where(hit, c.exc_value, 0), mode="drop"
        )
    d = d.at[0].set(0)
    return jnp.take(c.block_first, bid) + jnp.cumsum(d, dtype=jnp.int32)


def edgemap_sum_compressed(
    c: CompressedCSR, x: jnp.ndarray, *, edge_active: jnp.ndarray | None = None
) -> jnp.ndarray:
    """out[v] = Σ over decoded active edges (v,u) of x[u] — PageRank-style
    aggregation straight off the compressed representation (with optional
    graphFilter bits), proving filter ∘ compression composes as in §4.2.1."""
    n = c.n
    dst = decode_blocks(c)
    valid = dst < n
    if edge_active is not None:
        valid = valid & edge_active.reshape(dst.shape)
    safe = jnp.where(valid, dst, 0)
    xv = jnp.take(x, safe.reshape(-1), axis=0).reshape(dst.shape)
    contrib = jnp.where(valid, xv, 0.0)
    per_block = jnp.sum(contrib, axis=1)
    return jax.ops.segment_sum(per_block, c.block_src, num_segments=n + 1)[:n]
