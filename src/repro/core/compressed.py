"""Compressed blocked CSR — the Ligra+ byte-code format (§5.1.3) adapted to
TPU fixed-width decoding.

The paper's web-graph inputs are stored with per-block difference encoding
and decoded block-at-a-time; the graphFilter block size is tied to the
compression block size (§4.2.1).  Byte-aligned varints are a sequential
CPU format, so the TPU-idiomatic equivalent is **fixed-width delta
packing**: per block we store the first target (int32) and uint16 deltas
between consecutive sorted targets; the rare deltas ≥ 2¹⁶ go to a COO
exception list.  Decoding a block is a vectorized cumsum over the lane
dimension — exactly the "decode the whole block to fetch one edge"
discipline the paper's filter iterator uses (App. D.1) — and the
graphFilter bits apply unchanged on top of the decoded block.

``CompressedCSR`` is a first-class execution backend: it exposes the same
block view (``block_src`` / ``block_dst`` / ``block_w`` / ``edge_valid``)
that ``edge_map`` and the graphFilter consume, with the decoded arrays
produced lazily (XLA fuses the cumsum decode into the consumer, so nothing
int32-wide is ever materialized in HBM on the jit path; the Pallas kernel in
``repro.kernels.compressed_spmv`` streams the raw uint16 deltas directly).

Compression ratio: 32-bit targets → ~16.25 bits/edge + exceptions, i.e.
~2× on locality-friendly orderings (the paper reports 2.7–2.9× with
byte codes on web graphs).  Weights (when present) do not delta-compress
and are carried uncompressed.
"""
from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from .csr import CSRGraph, sharded_block_counts

ESCAPE = np.uint16(0xFFFF)


@partial(
    jax.tree_util.register_dataclass,
    data_fields=[
        "block_first",
        "deltas",
        "valid_count",
        "exc_block",
        "exc_slot",
        "exc_value",
        "block_src",
        "degrees",
        "block_weights",
    ],
    meta_fields=[
        "n",
        "m",
        "num_blocks",
        "block_size",
        "n_exceptions",
        "weighted",
        "exception_dense_hint",
    ],
)
@dataclasses.dataclass(frozen=True)
class CompressedCSR:
    """Read-only difference-encoded blocked CSR (PSAM large memory)."""

    block_first: jnp.ndarray  # int32[NB]       — first target per block
    deltas: jnp.ndarray       # uint16[NB, FB]  — deltas[:, 0] unused (=0)
    valid_count: jnp.ndarray  # uint16[NB]      — real (non-padding) slots, front-packed
    exc_block: jnp.ndarray    # int32[NE]       — exception coordinates
    exc_slot: jnp.ndarray     # int32[NE]
    exc_value: jnp.ndarray    # int32[NE]       — true delta value
    block_src: jnp.ndarray    # int32[NB]
    degrees: jnp.ndarray      # int32[n]
    n: int
    m: int
    num_blocks: int
    block_size: int
    n_exceptions: int
    block_weights: jnp.ndarray | None = None  # float32[NB, FB] when weighted
    weighted: bool = False
    # set by shard(): the whole-graph exception-density verdict, so every
    # shard keeps the original decode-strategy choice (a shard's padded
    # exception list and shrunken block count would skew the ratio test)
    exception_dense_hint: bool | None = None

    @property
    def compressed_bytes(self) -> int:
        return int(
            self.block_first.size * 4
            + self.deltas.size * 2
            + self.valid_count.size * 2
            + self.n_exceptions * 12
        )

    @property
    def uncompressed_bytes(self) -> int:
        return int(self.deltas.size * 4)

    @property
    def compression_ratio(self) -> float:
        return self.uncompressed_bytes / max(self.compressed_bytes, 1)

    @property
    def avg_degree(self) -> float:
        return self.m / max(self.n, 1)

    def out_degree(self, v):
        return self.degrees[v]

    # ------------------------------------------------------------------
    # Backend view — same surface the uncompressed CSRGraph offers.  The
    # decoded arrays are *lazy*: under jit the cumsum decode fuses into
    # whatever consumes it (edgeMap's gather/segment-reduce), so the wide
    # int32 targets never round-trip through HBM.
    # ------------------------------------------------------------------
    @property
    def block_dst(self) -> jnp.ndarray:
        """Decoded int32[NB, FB] targets (sentinel n on padding slots)."""
        return decode_blocks(self)

    @property
    def block_w(self) -> jnp.ndarray:
        if self.block_weights is not None:
            return self.block_weights
        return jnp.ones((self.num_blocks, self.block_size), jnp.float32)

    @property
    def edge_dst(self) -> jnp.ndarray:
        return decode_blocks(self).reshape(-1)

    @property
    def edge_src(self) -> jnp.ndarray:
        """int32[NB*F_B] — owner per slot, sentinel n on padding (the exact
        CSRGraph padding contract, so src == n neutralizes padding for any
        consumer keyed on out-of-range sources)."""
        src = jnp.broadcast_to(
            self.block_src[:, None], (self.num_blocks, self.block_size)
        ).reshape(-1)
        return jnp.where(self.edge_valid, src, jnp.int32(self.n))

    @property
    def edge_w(self) -> jnp.ndarray:
        return self.block_w.reshape(-1)

    @property
    def edge_valid(self) -> jnp.ndarray:
        """bool[NB*F_B] — True on real (non-padding) edge slots.

        Structural: read straight off ``valid_count``, no decode needed —
        makeFilter on a compressed graph never touches the delta stream.
        """
        lane = jnp.arange(self.block_size, dtype=jnp.int32)
        vc = self.valid_count.astype(jnp.int32)
        return (lane[None, :] < vc[:, None]).reshape(-1)

    def shard(self, num_shards: int) -> list["CompressedCSR"]:
        """Partition the compressed block set into ``num_shards`` ranges.

        Compressed blocks are independently decodable (per-block first target
        + deltas + valid count), so sharding is a block-range split of the
        delta stream plus a *per-shard exception list*: each COO exception is
        routed to the shard owning its block, with the block coordinate
        rebased to the shard-local range.  Exception lists are padded to the
        max count across shards (padding rows use the out-of-range block id
        ``per``, which every decoder drops) so shards stack into one pytree
        with identical meta.  Block counts that don't divide pad with empty
        blocks (valid_count 0, owner = sentinel n) — the tail shard is never
        truncated.  Vertex metadata (``degrees``) stays replicated per shard.
        """
        if num_shards < 1:
            raise ValueError(f"num_shards must be >= 1, got {num_shards}")
        NB, FB = self.num_blocks, self.block_size
        per, padded_total = sharded_block_counts(NB, num_shards)
        pad = padded_total - NB
        first = np.asarray(self.block_first)
        deltas = np.asarray(self.deltas)
        vc = np.asarray(self.valid_count)
        bsrc = np.asarray(self.block_src)
        bw = None if self.block_weights is None else np.asarray(self.block_weights)
        if pad:
            first = np.concatenate([first, np.zeros(pad, np.int32)])
            deltas = np.concatenate([deltas, np.zeros((pad, FB), np.uint16)])
            vc = np.concatenate([vc, np.zeros(pad, np.uint16)])
            bsrc = np.concatenate([bsrc, np.full(pad, self.n, np.int32)])
            if bw is not None:
                bw = np.concatenate([bw, np.zeros((pad, FB), np.float32)])
        eb = np.asarray(self.exc_block)
        es = np.asarray(self.exc_slot)
        ev = np.asarray(self.exc_value)
        sel = [(eb >= s * per) & (eb < (s + 1) * per) for s in range(num_shards)]
        ne_max = max((int(m.sum()) for m in sel), default=0)
        shards = []
        for s in range(num_shards):
            lo, hi = s * per, (s + 1) * per
            m = sel[s]
            k = int(m.sum())
            # pad rows target block id ``per`` (out of the shard's range):
            # decode_blocks scatter-drops them, decode_block_tile patches a
            # delta of 0 into lane 0 of the fill row, which is zeroed anyway
            leb = np.full(ne_max, per, np.int32)
            les = np.zeros(ne_max, np.int32)
            lev = np.zeros(ne_max, np.int32)
            leb[:k] = eb[m] - lo
            les[:k] = es[m]
            lev[:k] = ev[m]
            shards.append(
                dataclasses.replace(
                    self,
                    block_first=jnp.asarray(first[lo:hi]),
                    deltas=jnp.asarray(deltas[lo:hi]),
                    valid_count=jnp.asarray(vc[lo:hi]),
                    exc_block=jnp.asarray(leb),
                    exc_slot=jnp.asarray(les),
                    exc_value=jnp.asarray(lev),
                    block_src=jnp.asarray(bsrc[lo:hi]),
                    num_blocks=per,
                    n_exceptions=ne_max,
                    block_weights=None if bw is None else jnp.asarray(bw[lo:hi]),
                    exception_dense_hint=exception_dense(self),
                )
            )
        return shards


def compress(g: CSRGraph) -> CompressedCSR:
    """Host-side encoder (runs once at load, like the paper's preprocessing).

    Padding slots (sentinel n in the CSR) are encoded as *repeats of the
    last real target* — delta 0 — and validity is carried structurally as a
    per-block count (slots are front-packed by build_csr).  The decoders
    re-insert the sentinel on padding slots, so
    ``decode_blocks(compress(g)) == g.block_dst`` bit for bit while the
    exception list stays tied to true ≥2¹⁶ adjacency gaps — without this,
    every padded block on a graph with n > 2¹⁶ would land on the exception
    list and the "rare path" would stop being rare.  Weighted graphs keep
    their weights uncompressed alongside the delta-packed targets.
    """
    NB, FB = g.num_blocks, g.block_size
    dst = np.asarray(g.edge_dst).reshape(NB, FB).astype(np.int64)
    vc = (dst < g.n).sum(axis=1).astype(np.int64)  # front-packed real slots
    last = np.where(vc > 0, dst[np.arange(NB), np.maximum(vc - 1, 0)], 0)
    lane = np.arange(FB)[None, :]
    dst_enc = np.where(lane < vc[:, None], dst, last[:, None])
    first = dst_enc[:, 0].astype(np.int32)
    prev = dst_enc[:, :-1]
    cur = dst_enc[:, 1:]
    raw = cur - prev
    raw = np.concatenate([np.zeros((NB, 1), np.int64), raw], axis=1)
    over = (raw >= int(ESCAPE)) | (raw < 0)
    deltas = np.where(over, int(ESCAPE), raw).astype(np.uint16)
    eb, es = np.nonzero(over)
    return CompressedCSR(
        block_first=jnp.asarray(first),
        deltas=jnp.asarray(deltas),
        valid_count=jnp.asarray(vc.astype(np.uint16)),
        exc_block=jnp.asarray(eb.astype(np.int32)),
        exc_slot=jnp.asarray(es.astype(np.int32)),
        exc_value=jnp.asarray(raw[eb, es].astype(np.int32)),
        block_src=g.block_src,
        degrees=g.degrees,
        n=g.n,
        m=g.m,
        num_blocks=NB,
        block_size=FB,
        n_exceptions=int(eb.shape[0]),
        block_weights=g.block_w if g.weighted else None,
        weighted=g.weighted,
    )


def _lane_iota(c: CompressedCSR) -> jnp.ndarray:
    return jnp.arange(c.block_size, dtype=jnp.int32)


def decode_blocks(c: CompressedCSR) -> jnp.ndarray:
    """Decode ALL blocks → int32[NB, FB] targets (vectorized cumsum).

    Padding slots come back as the sentinel n (structural ``valid_count``
    mask), bit-identical to the uncompressed ``block_dst``.  O(m) work /
    O(log F_B) depth per block, matching the paper's block decode cost;
    used by edgeMap over compressed graphs.
    """
    d = c.deltas.astype(jnp.int32)
    # patch exceptions (escaped wide deltas)
    if c.n_exceptions:
        d = d.at[c.exc_block, c.exc_slot].set(c.exc_value, mode="drop")
    d = d.at[:, 0].set(0)
    raw = c.block_first[:, None] + jnp.cumsum(d, axis=1, dtype=jnp.int32)
    valid = _lane_iota(c)[None, :] < c.valid_count.astype(jnp.int32)[:, None]
    return jnp.where(valid, raw, jnp.int32(c.n))


def decode_block(c: CompressedCSR, bid) -> jnp.ndarray:
    """Decode a single block (the filter-iterator path, App. D.1)."""
    d = jnp.take(c.deltas, bid, axis=0).astype(jnp.int32)
    if c.n_exceptions:
        hit = c.exc_block == bid
        d = d.at[jnp.where(hit, c.exc_slot, c.block_size)].set(
            jnp.where(hit, c.exc_value, 0), mode="drop"
        )
    d = d.at[0].set(0)
    raw = jnp.take(c.block_first, bid) + jnp.cumsum(d, dtype=jnp.int32)
    vc = jnp.take(c.valid_count, bid).astype(jnp.int32)
    return jnp.where(_lane_iota(c) < vc, raw, jnp.int32(c.n))


def exception_dense(c: CompressedCSR) -> bool:
    """Static (metadata-only) test: is the exception list too dense for the
    per-tile COO patch to stay a rare path?  Past this point consumers
    should decode exactly instead (the compression is doing little on such
    id-locality-free graphs anyway).  Shards carry the whole-graph verdict
    as a hint — their padded exception lists and shrunken block counts
    would otherwise inflate the ratio."""
    if c.exception_dense_hint is not None:
        return c.exception_dense_hint
    return c.n_exceptions > max(16, min(c.num_blocks // 4, 4096))


def decode_block_tile(c: CompressedCSR, bids: jnp.ndarray) -> jnp.ndarray:
    """Decode a tile of blocks → int32[C, FB] (the chunk-loop path, §4.1).

    ``bids`` may contain the fill value ``num_blocks`` (or anything out of
    range): those rows decode to all-sentinel (target == n), matching the
    uncompressed chunk gather with ``fill_value=n``.  Peak intermediate is
    ``C × F_B`` words — never proportional to the whole edge set.

    Precondition: real block ids in ``bids`` must be unique (chunk tiles are
    compacted indices, so this always holds there) — a duplicated id would
    get its exceptions patched only into its first row.  For decoding the
    exception list itself (which can repeat a block), vmap ``decode_block``.
    The patch is O(C · NE) boolean compares + an O(NE) scatter per tile.
    """
    C = bids.shape[0]
    d = jnp.take(c.deltas, bids, axis=0, mode="fill", fill_value=0).astype(jnp.int32)
    if c.n_exceptions:
        # route each exception to the (unique) tile row holding its block;
        # exceptions whose block is not in the tile scatter-drop at row C
        match = bids[:, None] == c.exc_block[None, :]                      # (C, NE)
        hit = jnp.any(match, axis=0)
        row = jnp.where(hit, jnp.argmax(match, axis=0), jnp.int32(C))
        d = d.at[row, c.exc_slot].set(c.exc_value, mode="drop")
    d = d.at[:, 0].set(0)
    first = jnp.take(c.block_first, bids, mode="fill", fill_value=c.n)
    raw = first[:, None] + jnp.cumsum(d, axis=1, dtype=jnp.int32)
    vc = jnp.take(c.valid_count, bids, mode="fill", fill_value=0).astype(jnp.int32)
    return jnp.where(_lane_iota(c)[None, :] < vc[:, None], raw, jnp.int32(c.n))


def edgemap_sum_compressed(
    c: CompressedCSR, x: jnp.ndarray, *, edge_active: jnp.ndarray | None = None
) -> jnp.ndarray:
    """out[v] = Σ over decoded active edges (v,u) of x[u] — PageRank-style
    aggregation straight off the compressed representation (with optional
    graphFilter bits), proving filter ∘ compression composes as in §4.2.1."""
    n = c.n
    dst = decode_blocks(c)
    valid = dst < n
    if edge_active is not None:
        valid = valid & edge_active.reshape(dst.shape)
    safe = jnp.where(valid, dst, 0)
    xv = jnp.take(x, safe.reshape(-1), axis=0).reshape(dst.shape)
    contrib = jnp.where(valid, xv, 0.0)
    per_block = jnp.sum(contrib, axis=1)
    return jax.ops.segment_sum(per_block, c.block_src, num_segments=n + 1)[:n]
