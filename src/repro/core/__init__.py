"""repro.core — the Parallel Semi-Asymmetric Model (PSAM) graph engine.

Public surface:
  CSRGraph / build_csr / graph_spec       — immutable blocked CSR (large memory)
  CompressedCSR / compress                — delta-packed execution backend (§5.1.3)
  GraphBackend / GraphLike                — the protocol both backends satisfy
  ExecutionPlan / make_plan / ShardedGraph— unified planner: one edgeMap,
                                            single-device or sharded mesh
  compact_live_blocks                     — drop filter-dead blocks before
                                            the shard split (PSAM streaming)
  VertexSubset / from_indices / from_mask — frontiers (O(n) small memory)
  edgemap_reduce / edge_map               — direction-optimized edgeMapChunked
  GraphFilter / make_filter / pack_vertices / filter_edges — §4.2 bitset filter
  Buckets / make_buckets                  — semi-eager bucketing (App. B)
  PSAMCost                                — §3 cost accounting
  TenantLedger / TenantLedgers            — per-tenant edge-read token buckets
  edgemap_round_read_words                — one dense round's read quantum
"""
from .backend import GraphBackend, GraphLike, dense_block_view, tile_block_view
from .bucketing import NULL_BUCKET, Buckets, make_buckets
from .compressed import (
    CompressedCSR,
    compress,
    decode_block,
    decode_block_tile,
    decode_blocks,
    edgemap_sum_compressed,
)
from .csr import DEFAULT_BLOCK_SIZE, CSRGraph, build_csr, graph_spec
from .edgemap import (
    edge_map,
    edge_map_batched,
    edgemap_chunked,
    edgemap_dense,
    edgemap_dense_batched,
    edgemap_reduce,
    edgemap_reduce_batched,
)
from .graph_filter import (
    GraphFilter,
    edge_active_flat,
    edge_active_words,
    filter_edges,
    filter_edges_pred,
    live_block_indices,
    make_filter,
    pack_bits,
    pack_vertices,
    unpack_bits,
    unpack_word_bits,
)
from .plan import (
    ExecutionPlan,
    ShardedEdgeActive,
    ShardedGraph,
    compact_live_blocks,
    make_plan,
    round_loop,
    shard_edge_active,
    sharded_edgemap_reduce,
    sharded_edgemap_reduce_batched,
    sharded_graph_spec,
)
from .psam import PSAMCost, TenantLedger, TenantLedgers, edgemap_round_read_words
from .vertex_subset import VertexSubset, empty, from_indices, from_mask, full

__all__ = [
    "CompressedCSR",
    "ExecutionPlan",
    "ShardedEdgeActive",
    "ShardedGraph",
    "compact_live_blocks",
    "make_plan",
    "round_loop",
    "shard_edge_active",
    "sharded_edgemap_reduce",
    "sharded_graph_spec",
    "GraphBackend",
    "GraphLike",
    "compress",
    "decode_blocks",
    "decode_block",
    "decode_block_tile",
    "dense_block_view",
    "tile_block_view",
    "edgemap_sum_compressed",
    "CSRGraph",
    "build_csr",
    "graph_spec",
    "DEFAULT_BLOCK_SIZE",
    "VertexSubset",
    "from_indices",
    "from_mask",
    "full",
    "empty",
    "edge_map",
    "edge_map_batched",
    "edgemap_reduce",
    "edgemap_reduce_batched",
    "edgemap_dense",
    "edgemap_dense_batched",
    "edgemap_chunked",
    "sharded_edgemap_reduce_batched",
    "GraphFilter",
    "make_filter",
    "pack_vertices",
    "filter_edges",
    "filter_edges_pred",
    "unpack_bits",
    "unpack_word_bits",
    "pack_bits",
    "edge_active_flat",
    "edge_active_words",
    "live_block_indices",
    "Buckets",
    "make_buckets",
    "NULL_BUCKET",
    "PSAMCost",
    "TenantLedger",
    "TenantLedgers",
    "edgemap_round_read_words",
]
