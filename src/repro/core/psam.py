"""PSAM cost accounting (§3) — analytic work/IO counters.

The PSAM charges: unit for small-memory ops and large-memory reads, ω for
large-memory writes.  Sage algorithms perform **zero** large-memory writes;
these counters let the benchmark harness report the paper's Table-1 contrast
(GBBS O(ω·m) vs Sage O(m)) for a given graph and a chosen ω.  The one
sanctioned write is the mutable-graph subsystem's batched compaction
(``repro.delta.compact`` → ``charge_large_write``); queries over a delta
overlay charge base reads + DRAM patch small-ops via
``charge_edgemap_overlay``.

These are analytic (host-side) counters, not traced values — they model the
cost of the algorithm as specified, which is what the paper's Table 1 does.

Every ``charge_*`` additionally mirrors its deltas into the observability
registry (``repro.obs``) as labeled counters —
``sage_psam_large_read_words_total{charge=...}`` /
``sage_psam_small_ops_words_total{charge=...}`` — so the modeled edge-read
words stream out of a live service next to measured seconds, and the
PSAM-model-vs-wall-clock drift becomes a queryable gauge
(``ServingService`` sets ``sage_psam_drift_words_per_second`` per flush).
The mirror is exact: per charge label, counter totals equal the field
deltas word for word (locked by ``tests/test_obs.py``).  A ``PSAMCost``
constructed with ``registry=`` mirrors there; otherwise each charge
resolves the process-global default, so ``set_registry(noop_registry())``
silences every account at one attribute lookup per charge.
"""
from __future__ import annotations

import dataclasses
from typing import Any

from ..obs import get_registry
from .csr import sharded_block_counts


def _compressed_target_words(g, blocks: int) -> int:
    """Words read to stream ``blocks`` compressed target blocks: int32 first
    + uint16 valid count + packed uint16 deltas per block, plus the
    amortized COO exception triples (§5.1.3 / App. D.1)."""
    per_block = -(-(4 + 2 + 2 * g.block_size) // 4)  # bytes → words, rounded up
    exc = 3 * g.n_exceptions * blocks // max(g.num_blocks, 1)
    return per_block * blocks + exc


def _block_read_words(g, blocks: int) -> int:
    """Words of large memory read to stream ``blocks`` edge blocks.

    Compressed backends (anything exposing ``compressed_bytes``) are charged
    the *compressed* footprint, which is how the paper's byte-decoded blocks
    hit NVRAM at a fraction of the uncompressed bytes (§5.1.3); weights
    (when present) ride along uncompressed.  Uncompressed blocks are charged
    the flat dst + w words.
    """
    if hasattr(g, "compressed_bytes"):
        words = _compressed_target_words(g, blocks)
        if getattr(g, "weighted", False):
            words += g.block_size * blocks
        return words
    return 2 * g.block_size * blocks  # dst + w


def edgemap_round_read_words(g, num_shards: int = 1) -> int:
    """Large-memory words one dense edgeMap round reads over ``num_shards``.

    The per-round read quantum every planner charge is built from: per-shard
    block reads including the empty blocks that pad a non-dividing count
    (``charge_edgemap_planned``'s dense case, B-invariant by construction —
    a batched round reads exactly the same words).  The serving scheduler
    prices admission control and per-lane drain attribution in this unit,
    via :meth:`repro.core.plan.ExecutionPlan.edge_read_words_per_round`.

    Delta-overlay backends (``repro.delta.DeltaGraph``, duck-typed on
    ``overlay_small_words`` — core never imports delta) price as their
    BASE: only the base blocks live in large memory; the patch blocks and
    tombstone words are DRAM-side and belong to small_ops
    (``charge_edgemap_overlay``), never to the read quantum.
    """
    if hasattr(g, "overlay_small_words"):
        g = g.base
    _, padded_total = sharded_block_counts(g.num_blocks, num_shards)
    return _block_read_words(g, padded_total)


@dataclasses.dataclass
class TenantLedger:
    """One tenant's PSAM edge-read account: a token-bucket byte budget.

    ``capacity`` is the tenant's edge-read allowance in large-memory words
    (None = unlimited); ``refill_rate`` replenishes ``available`` at that
    many words per unit of service time, capped at ``capacity`` — the
    token-bucket shape every rate limiter converges on, priced in the PSAM's
    scarce resource (NVRAM reads) instead of requests.  ``charged`` is the
    lifetime attribution (never reset); ``available`` may go negative when a
    drain's actual cost exceeds its admission estimate — the tenant repays
    the overdraft out of future refills before new work admits.
    """

    capacity: float | None = None
    refill_rate: float = 0.0
    available: float = 0.0
    charged: float = 0.0
    last_refill: float = 0.0

    def refill(self, now: float) -> None:
        """Advance the token bucket to ``now`` (monotone; no-op backwards)."""
        if now > self.last_refill:
            if self.capacity is not None and self.refill_rate > 0:
                self.available = min(
                    self.capacity,
                    self.available + (now - self.last_refill) * self.refill_rate,
                )
            self.last_refill = now

    def can_admit(self, est_words: float) -> bool:
        """True when ``est_words`` of estimated edge reads fit the allowance."""
        return self.capacity is None or self.available >= est_words

    def reserve(self, est_words: float) -> None:
        """Deduct an admission estimate; settled against actuals at drain."""
        if self.capacity is not None:
            self.available -= est_words

    def settle(self, est_words: float, actual_words: float) -> None:
        """Replace the reserved estimate with the drain's actual attribution.

        Refunds ``est - actual`` (or charges the shortfall) so the bucket
        always reflects words actually read; ``charged`` accrues the actual.
        """
        if self.capacity is not None:
            self.available += est_words - actual_words
        self.charged += actual_words


class TenantLedgers:
    """Per-tenant PSAM edge-read ledgers, keyed by tenant name.

    ``budgets`` maps tenant → (capacity_words, refill_rate) — tenants not
    named run unlimited (accounting only, never throttled).  The serving
    admission controller reserves an estimate at submit, settles it against
    the drain's per-lane attribution, and consults ``can_admit`` to reject
    or defer work — see ``repro.serving.ServingService``.
    """

    def __init__(self, budgets: dict | None = None):
        self._ledgers: dict[str, TenantLedger] = {}
        for tenant, spec in (budgets or {}).items():
            cap, rate = spec if isinstance(spec, tuple) else (spec, 0.0)
            self._ledgers[tenant] = TenantLedger(
                capacity=float(cap), refill_rate=float(rate), available=float(cap)
            )

    def ledger(self, tenant: str) -> TenantLedger:
        """This tenant's ledger (created unlimited on first touch)."""
        led = self._ledgers.get(tenant)
        if led is None:
            led = self._ledgers[tenant] = TenantLedger()
        return led

    def refill(self, now: float) -> None:
        """Advance every tenant's token bucket to ``now``."""
        for led in self._ledgers.values():
            led.refill(now)

    def charge(self, tenant: str, words: float) -> None:
        """Attribute ``words`` of edge reads to ``tenant`` (no reservation)."""
        self.ledger(tenant).charged += words

    def items(self):
        """(tenant, ledger) pairs, for reporting."""
        return self._ledgers.items()

    def total_charged(self) -> float:
        """Sum of every tenant's lifetime attribution (conservation checks)."""
        return sum(led.charged for led in self._ledgers.values())


@dataclasses.dataclass
class PSAMCost:
    large_reads: int = 0      # words read from the read-only graph
    large_writes: int = 0     # words written to large memory (Sage: always 0)
    small_ops: int = 0        # small-memory reads+writes
    omega: float = 4.0        # NVRAM write/read cost ratio (paper: ~4x)
    # where charge_* mirrors its deltas (None = the process-global default
    # at each charge); excluded from repr/eq so cost comparisons stay
    # purely about the modeled words
    registry: Any = dataclasses.field(default=None, repr=False, compare=False)

    def _charge(self, label: str, reads: int = 0, small: int = 0, writes: int = 0):
        """Apply one charge's deltas and mirror them into labeled counters.

        The single bottleneck every ``charge_*`` funnels through: fields
        move by exactly what the counters record, so per-label counter
        totals reconcile with ``large_reads`` / ``small_ops`` /
        ``large_writes`` word for word.
        """
        self.large_reads += reads
        self.small_ops += small
        self.large_writes += writes
        reg = self.registry if self.registry is not None else get_registry()
        if not reg.enabled:
            return
        if reads:
            reg.counter(
                "sage_psam_large_read_words_total",
                "modeled large-memory (NVRAM) words read, by charge kind",
                labels=("charge",),
            ).inc(reads, charge=label)
        if small:
            reg.counter(
                "sage_psam_small_ops_words_total",
                "modeled small-memory (DRAM) words touched, by charge kind",
                labels=("charge",),
            ).inc(small, charge=label)
        if writes:
            reg.counter(
                "sage_psam_large_write_words_total",
                "modeled large-memory words written (Sage: always 0)",
                labels=("charge",),
            ).inc(writes, charge=label)

    def charge_edgemap_dense(self, g):
        self._charge(
            "edgemap_dense", reads=_block_read_words(g, g.num_blocks), small=3 * g.n
        )

    def charge_edgemap_chunked(self, g, active_blocks: int):
        self._charge(
            "edgemap_chunked",
            reads=_block_read_words(g, active_blocks),
            small=3 * g.n,
        )

    def charge_edgemap_planned(
        self, g, num_shards: int = 1, active_blocks=None, filter_live_blocks=None
    ):
        """One planner-dispatched edgeMap round over ``num_shards`` shards.

        Large-memory reads are charged *per shard* — compressed backends at
        their compressed byte footprint (amortized COO exceptions included),
        raw CSR at the flat dst+w words — counting the empty blocks that pad
        a non-dividing block count (they are streamed like any other, see
        ``GraphBackend.shard``).  The cross-shard monoid combine
        moves the O(n) output vector once per shard boundary: that traffic
        lands in small_ops, which keeps the distributed path inside the
        PSAM small-memory bound (communication is O(n), never O(m)).

        ``active_blocks``: total active blocks across shards for the sparse
        strategy; None charges the dense pass (every block, padding
        included).

        ``filter_live_blocks``: present when the round ran with a
        graphFilter / ``edge_active`` mask — either the live-block count
        (int) or the ``GraphFilter`` itself (its ``block_live`` popcount is
        taken).  Filtered rounds charge only the live blocks (dead blocks
        are skipped — the paper's empty-block compaction, §4.2.2), rounded
        up to whole shards so a shard with any live block still streams one,
        plus the packed filter words themselves: one uint32 word per 32 edge
        slots, the relaxed-PSAM O(n + m/64)-words filter state read once
        per round.
        """
        self._charge_batched(
            g,
            1,
            num_shards=num_shards,
            active_blocks=active_blocks,
            filter_live_blocks=filter_live_blocks,
            label="edgemap_planned",
        )

    def charge_edgemap_batched(
        self,
        g,
        batch: int,
        num_shards: int = 1,
        active_blocks=None,
        filter_live_blocks=None,
    ):
        """One BATCHED edgeMap round serving ``batch`` concurrent queries.

        This is the serving subsystem's amortization expressed in the PSAM:
        the read-only edge blocks (large memory) are streamed exactly once
        per round — the same charge as a single-query
        ``charge_edgemap_planned`` round, independent of ``batch`` — while
        the mutable vertex state costs O(batch·n) small-memory words (B
        frontier/value columns per shard, plus the O(batch·n) cross-shard
        combine).  Relative to ``batch`` sequential rounds the edge-byte
        reads divide by ``batch``, which is the whole throughput lever of
        ``repro.serving`` (cf. Graphyti/FlashGraph's shared sequential
        scans).  ``active_blocks`` / ``filter_live_blocks`` behave exactly
        as in ``charge_edgemap_planned`` (the batch shares one traversal
        mask per round).
        """
        self._charge_batched(
            g,
            batch,
            num_shards=num_shards,
            active_blocks=active_blocks,
            filter_live_blocks=filter_live_blocks,
            label="edgemap_batched",
        )

    def _charge_batched(
        self,
        g,
        batch: int,
        *,
        num_shards: int,
        active_blocks,
        filter_live_blocks,
        label: str,
    ):
        """Shared arithmetic behind the planned/batched charges; ``label``
        names the mirror counter series so the two stay distinguishable."""
        _, padded_total = sharded_block_counts(g.num_blocks, num_shards)
        blocks = padded_total if active_blocks is None else active_blocks
        reads = 0
        if filter_live_blocks is not None:
            live = filter_live_blocks
            if hasattr(live, "block_live"):  # a GraphFilter
                live = int(live.block_live.sum())
            else:
                live = int(live)  # python/numpy integer count
            per = -(-live // max(num_shards, 1))  # live blocks, whole shards
            blocks = min(blocks, per * num_shards)
            # the filter words stream alongside the blocks they mask
            reads += padded_total * (g.block_size // 32)
        reads += _block_read_words(g, blocks)
        # O(batch·n) local state per shard + one O(batch·n)-word combine per
        # shard boundary — the DRAM side scales with the batch, the NVRAM
        # side does not
        self._charge(
            label, reads=reads, small=batch * (3 * g.n + (num_shards - 1) * g.n)
        )

    def charge_edgemap_sparse(
        self,
        g,
        live_blocks: int,
        *,
        batch: int = 1,
        num_shards: int = 1,
        tile_blocks: int = 1,
    ):
        """One frontier-sparse STREAMED edgeMap round (``sparse_streamed``).

        This is the PSAM read model the chunked-mode kernel implements:
        large-memory bytes are charged for the **streamed (live) blocks
        only** — the ``ceil(live / TB)`` scalar-prefetched chunk launches of
        ``tile_blocks`` blocks each (the last chunk's pad rows land on the
        all-sentinel row, which is one block's worth of bytes total, charged
        here as part of the rounding) — never for the dead blocks, and never
        proportional to NB.  ``live_blocks`` is the frontier-owned block
        count (sparse frontier) or the filter's live-block popcount
        (``compact_live_blocks`` sharding): whichever produced the compacted
        id list the kernel's ``PrefetchScalarGridSpec`` walks.

        Sharded rounds split the live list block-range-wise, so each shard
        rounds its own chunk count up (a shard with any live block streams
        at least one chunk).  The compacted id list itself is O(n) words of
        small memory (``compact_mask``), charged alongside the per-round
        O(batch·n) vertex state and the O(batch·n)-per-boundary combine —
        the small-memory side is identical to the dense batched round; only
        the NVRAM side shrinks with the frontier.
        """
        tb = max(tile_blocks, 1)
        per_shard_live = -(-int(live_blocks) // max(num_shards, 1))
        per_shard_streamed = -(-per_shard_live // tb) * tb
        self._charge(
            "edgemap_sparse",
            reads=_block_read_words(g, per_shard_streamed * num_shards),
            # the compacted live-id list (compact_mask over NB block slots)
            # + per-round vertex state + per-boundary combine
            small=g.num_blocks + batch * (3 * g.n + (num_shards - 1) * g.n),
        )

    def charge_edgemap_overlay(self, dg, batch: int = 1, num_shards: int = 1):
        """One edgeMap round over a delta-overlay backend (``repro.delta``).

        The semi-asymmetric split, priced exactly: large-memory reads are
        the BASE blocks only — the same per-shard padded count and
        compressed-footprint arithmetic a round over the base alone would
        charge (``sharded_block_counts`` over ``num_base_blocks``, through
        ``_block_read_words``) — while everything the overlay adds is
        DRAM-resident and lands in small_ops: the patch blocks' dst+w
        words plus one tombstone word per 32 base slots
        (``dg.overlay_small_words``), on top of the usual O(batch·n)
        vertex state and per-shard-boundary combine.  ``dg`` duck-types:
        anything with ``overlay_small_words`` / ``num_base_blocks`` /
        ``base`` qualifies, so core never imports the delta package.
        """
        _, base_padded = sharded_block_counts(dg.num_base_blocks, num_shards)
        self._charge(
            "edgemap_overlay",
            reads=_block_read_words(dg.base, base_padded),
            small=dg.overlay_small_words
            + batch * (3 * dg.n + (num_shards - 1) * dg.n),
        )

    def charge_large_write(self, words: int, label: str = "large_write"):
        """Charge ``words`` of large-memory (NVRAM) writes at the ω premium.

        Sage query paths NEVER call this — the whole point of Table 1 is
        ``large_writes == 0`` for every algorithm.  The single legitimate
        caller is ``repro.delta.compact``: folding the DRAM overlay into a
        fresh compressed base is the one batched write the log-structured
        design budgets for, and routing it through here makes the
        amortization auditable (``work`` prices it at ``omega`` per word;
        the mirror lands in ``sage_psam_large_write_words_total``).
        """
        self._charge(label, writes=int(words))

    def charge_filter_pack(self, g, touched_blocks: int):
        # filter bits live in small memory: reads edge ids from large memory,
        # writes only bits + degrees (small memory)
        if hasattr(g, "compressed_bytes"):
            reads = _compressed_target_words(g, touched_blocks)
        else:
            reads = touched_blocks * g.block_size
        self._charge(
            "filter_pack",
            reads=reads,
            small=touched_blocks * (g.block_size // 32) + g.n,
        )

    def charge_small(self, words: int):
        self._charge("small", small=words)

    @property
    def work(self) -> float:
        """PSAM work: reads unit cost, large writes cost ω."""
        return self.large_reads + self.small_ops + self.omega * self.large_writes

    def gbbs_equivalent_work(self, mutated_words: int) -> float:
        """What the same algorithm would cost if, like GBBS, it wrote
        ``mutated_words`` words to large memory (e.g. in-place edge packing)."""
        return self.large_reads + self.small_ops + self.omega * mutated_words
