"""Semi-eager bucketing (Appendix B) — Julienne's bucket structure in O(n).

Each vertex sits in at most one bucket; ``bucket_of[v]`` is its current
bucket id (NULL_BUCKET when retired).  ``next_bucket`` extracts the minimum
non-empty bucket.  Because the map is a dense int32[n] vector, the
live/dead-counter machinery of the paper's semi-eager variant is subsumed:
moving a vertex is a single O(1) small-memory write and extraction is one
O(n)-work / O(log n)-depth min-reduce — within the PSAM budget by
construction.
"""
from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp

NULL_BUCKET = jnp.int32(2**30)


@partial(
    jax.tree_util.register_dataclass,
    data_fields=["bucket_of"],
    meta_fields=["n"],
)
@dataclasses.dataclass(frozen=True)
class Buckets:
    bucket_of: jnp.ndarray  # int32[n]
    n: int

    def next_bucket(self):
        """Returns (bucket_id, member_mask, any_left)."""
        bid = jnp.min(self.bucket_of)
        mask = self.bucket_of == bid
        return bid, mask, bid < NULL_BUCKET

    def update(self, ids_mask: jnp.ndarray, new_buckets: jnp.ndarray) -> "Buckets":
        """updateBuckets: vertices in ``ids_mask`` move to ``new_buckets[v]``."""
        nb = jnp.where(ids_mask, new_buckets.astype(jnp.int32), self.bucket_of)
        return Buckets(bucket_of=nb, n=self.n)

    def retire(self, ids_mask: jnp.ndarray) -> "Buckets":
        return self.update(ids_mask, jnp.full(self.n, NULL_BUCKET))


def make_buckets(initial: jnp.ndarray) -> Buckets:
    """initial: int32[n] bucket ids (NULL_BUCKET to start retired)."""
    return Buckets(bucket_of=initial.astype(jnp.int32), n=initial.shape[0])
