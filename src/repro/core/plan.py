"""Unified execution planner — one edgeMap, any device count, any backend.

Sage's central claim (§3) is that a single semi-asymmetric engine serves
every graph kernel: edges are read-only "large memory", all mutation stays
in O(n) words of "small memory".  This module is that claim at the
execution layer.  An :class:`ExecutionPlan` names *where* and *how* an
edgeMap runs — device mesh (or none), storage backend (raw or compressed
CSR), dense/sparse/auto strategy, cross-shard reduce shape — and
``edgemap_reduce`` / ``edge_map`` accept one via their ``plan=`` keyword,
so algorithm code never picks an engine:

        vertex state (O(n), replicated) ──┐
                                          ▼
    CSRGraph ────────┐          ┌── edgemap_dense ──┐
                     ├─ shard ──┤                   ├─ psum/pmin/pmax ─► out
    CompressedCSR ───┘  (plan)  └── edgemap_chunked ┘   (per round,
                                                         O(n) words)

Sharded execution reuses the *same* ``edgemap_dense`` / ``edgemap_chunked``
bodies as the single-device path: each shard is a valid ``GraphBackend``
over the global vertex space (``GraphBackend.shard`` splits the block set;
compressed blocks are independently decodable, so sharding the delta stream
is a block-range split plus per-shard exception lists), and ``shard_map``
runs the local body with the frontier and vertex state replicated.  The
only cross-shard traffic is the monoid combine of the O(n) output — never
O(m) — which is the PSAM small-memory bound expressed as a communication
bound (§5.2).

Batched serving rides the same dispatch: ``sharded_edgemap_reduce_batched``
runs B queries through each shard's one local edge sweep and combines the
O(B·n) output — the ``QueryEngine`` (``repro.serving``) drains its batch
buckets through this path unchanged, single-device or sharded.

GraphFilter bits and per-call traversal masks (``edge_active``) are
planner-native: the packed uint32 filter words are block-aligned, so they
partition exactly like the edge blocks (``shard_edge_active`` — the same
ceil(NB/k) block-range split, zero-padded tail) and travel the mesh at one
bit per edge slot.  Each shard unpacks its own words locally inside the
``shard_map`` body, so filtered edgeMaps run sharded with no fallback and
no O(m)-word mask traffic.  ``filter ∘ shard == shard ∘ filter`` by
construction (tested property).
"""
from __future__ import annotations

import dataclasses
import time
from functools import partial
from typing import Any

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental.shard_map import shard_map
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

import numpy as np

from ..obs import DEFAULT_LATENCY_BUCKETS, exp_buckets, get_registry
from ..tuning.defaults import DEFAULT_CHUNK_BLOCKS, DEFAULT_DENSE_FRAC
from .compressed import CompressedCSR, exception_dense
from .csr import CSRGraph, graph_spec, sharded_block_counts
from .graph_filter import edge_active_words


@partial(
    jax.tree_util.register_dataclass,
    data_fields=["shards"],
    meta_fields=["num_shards", "orig_num_blocks"],
)
@dataclasses.dataclass(frozen=True)
class ShardedGraph:
    """A graph backend split into per-shard block sets, stacked leaf-wise.

    ``shards`` is a single ``CSRGraph`` / ``CompressedCSR`` pytree whose
    array leaves carry a leading ``num_shards`` dimension (shard s of leaf
    ``a`` is ``a[s]``); its static meta describes one shard (``num_blocks``
    is the per-shard block count; ``n``/``m`` stay global).
    ``orig_num_blocks`` records the pre-split global block count so filter
    words can be validated exactly against the graph they were built for.
    Produced by :meth:`ExecutionPlan.prepare`; consumed by the sharded
    edgeMap executor, which partitions the leading dimension across the
    mesh.
    """

    shards: Any
    num_shards: int
    orig_num_blocks: int | None = None

    @property
    def n(self) -> int:
        return self.shards.n

    @property
    def m(self) -> int:
        return self.shards.m

    @property
    def block_size(self) -> int:
        return self.shards.block_size

    @property
    def blocks_per_shard(self) -> int:
        return self.shards.num_blocks

    @property
    def degrees(self) -> jnp.ndarray:
        """int32[n] — O(n) vertex state, replicated per shard (shard 0's copy)."""
        return self.shards.degrees[0]


@partial(
    jax.tree_util.register_dataclass,
    data_fields=["words", "live_ids"],
    meta_fields=["num_shards"],
)
@dataclasses.dataclass(frozen=True)
class ShardedEdgeActive:
    """Shard-local filter state: packed uint32 words, stacked leaf-wise.

    ``words`` is uint32[num_shards, blocks_per_shard, F_B/32] — shard s's
    rows line up 1:1 with shard s of the matching ``ShardedGraph`` (same
    block-range split, zero-padded tail).  Produced by
    :func:`shard_edge_active` / :meth:`ExecutionPlan.prepare`; consumed by
    the sharded edgeMap executor, which partitions the leading dimension
    across the mesh and unpacks locally in each ``shard_map`` body.

    ``live_ids`` (optional) records a live-block compaction
    (``ExecutionPlan.prepare(..., compact_live=True)`` /
    :func:`compact_live_blocks`): int32[num_shards, blocks_per_shard] of
    *original* block ids, padded with the pre-compaction block count — row
    j of shard s's words masks original block ``live_ids[s, j]``.  The
    executor never needs it (a compacted graph is just a smaller block
    set); it exists so cost models and tests can audit exactly which NVRAM
    blocks each shard streams.
    """

    words: jnp.ndarray
    num_shards: int
    live_ids: jnp.ndarray | None = None

    @property
    def blocks_per_shard(self) -> int:
        return self.words.shape[1]


def compact_live_blocks(g, edge_active):
    """Drop dead blocks from a backend under a long-lived filter (host-side).

    The paper's empty-block compaction (§4.2.2), applied *physically* and —
    crucially for the sharded path — **before the shard split**: a block
    none of whose edge slots is active under ``edge_active`` can never
    contribute to any edgeMap that carries this filter, so it should not
    occupy a slot in any shard's block range, let alone stream.  Returns
    ``(g_live, words_live, live_ids)``:

    * ``g_live``     — the same backend type over only the live blocks
      (vertex space untouched: ``n``/``m``/``degrees`` stay global, exactly
      like ``GraphBackend.shard``'s contract).  ``CompressedCSR`` keeps its
      per-block independence — the delta rows gather by live id and the COO
      exception list is filtered to live blocks and re-keyed to compacted
      positions; the whole-graph ``exception_dense`` verdict is pinned as
      the hint, as in ``shard``.
    * ``words_live`` — the packed filter words for the surviving rows
      (uint32[k, F_B/32], aligned 1:1 with ``g_live``'s blocks).
    * ``live_ids``   — int32[k] original block ids, the audit trail that
      ``ShardedEdgeActive.live_ids`` carries through the shard split.

    A filter with no live blocks degenerates to one all-dead block (shapes
    stay non-degenerate, nothing real streams).  Host-side only (concrete
    arrays), like every other prepare-time step.
    """
    words = np.asarray(edge_active_words(edge_active, g.block_size))
    if words.shape[0] != g.num_blocks:
        raise ValueError(
            f"edge_active covers {words.shape[0]} blocks, graph has "
            f"{g.num_blocks} — was the filter built for a different graph?"
        )
    live = np.nonzero(words.any(axis=1))[0].astype(np.int32)
    if live.size == 0:
        # keep shapes non-degenerate: one block, fully masked off
        live = np.zeros(1, np.int32)
        words = np.zeros_like(words)
    live_ids = jnp.asarray(live)
    words_live = jnp.asarray(words[live])
    if isinstance(g, CompressedCSR):
        eb = np.asarray(g.exc_block)
        keep = np.isin(eb, live)
        keep_idx = jnp.asarray(np.nonzero(keep)[0])
        pos = np.full(g.num_blocks + 1, -1, np.int32)
        pos[live] = np.arange(live.size, dtype=np.int32)
        g_live = dataclasses.replace(
            g,
            block_first=g.block_first[live_ids],
            deltas=g.deltas[live_ids],
            valid_count=g.valid_count[live_ids],
            exc_block=jnp.asarray(pos[eb[keep]]),
            exc_slot=g.exc_slot[keep_idx],
            exc_value=g.exc_value[keep_idx],
            block_src=g.block_src[live_ids],
            num_blocks=int(live.size),
            n_exceptions=int(keep.sum()),
            block_weights=(
                None if g.block_weights is None else g.block_weights[live_ids]
            ),
            exception_dense_hint=exception_dense(g),
        )
    elif isinstance(g, CSRGraph):
        NB, FB = g.num_blocks, g.block_size
        g_live = dataclasses.replace(
            g,
            block_src=g.block_src[live_ids],
            edge_src=g.edge_src.reshape(NB, FB)[live_ids].reshape(-1),
            edge_dst=g.edge_dst.reshape(NB, FB)[live_ids].reshape(-1),
            edge_w=g.edge_w.reshape(NB, FB)[live_ids].reshape(-1),
            num_blocks=int(live.size),
        )
    else:
        raise TypeError(f"cannot compact {type(g).__name__}")
    return g_live, words_live, live_ids


def shard_edge_active(
    edge_active,
    *,
    block_size: int,
    blocks_per_shard: int,
    num_shards: int,
    num_blocks: int | None = None,
) -> ShardedEdgeActive:
    """Partition filter words alongside the edge blocks (block-range split).

    ``edge_active`` is any form ``edge_active_words`` accepts (GraphFilter,
    packed uint32 words, bool slot mask) over the *global* block set; the
    result stacks per-shard word tiles whose rows align with
    ``GraphBackend.shard``'s block ranges.  The zero-padded tail rows mask
    the empty sentinel blocks that pad a non-dividing block count (an
    all-zero word deactivates nothing real).  Pure pad+reshape — traceable,
    so per-round filter snapshots shard inside jit'd algorithm loops.

    ``num_blocks``: the graph's true (pre-split) block count, when the
    caller knows it (``ShardedGraph.orig_num_blocks``) — validated exactly.
    Without it, a pad of a whole shard's worth or more is still rejected
    (a filter for this graph pads < num_shards rows).  Zero-filling a
    too-short filter would silently deactivate real blocks, so both checks
    fail as loudly as the single-device reshape does.
    """
    words = edge_active_words(edge_active, block_size)
    total = blocks_per_shard * num_shards
    pad = total - words.shape[0]
    if (
        num_blocks is not None and words.shape[0] != num_blocks
    ) or pad < 0 or pad >= num_shards:
        raise ValueError(
            f"edge_active covers {words.shape[0]} blocks but the plan "
            f"carries {total} ({num_shards} shards x {blocks_per_shard}"
            + (f", graph has {num_blocks}" if num_blocks is not None else "")
            + ") — was the filter built for a different graph?"
        )
    if pad:
        words = jnp.pad(words, ((0, pad), (0, 0)))
    return ShardedEdgeActive(
        words=words.reshape(num_shards, blocks_per_shard, words.shape[-1]),
        num_shards=num_shards,
    )


@dataclasses.dataclass(frozen=True)
class ExecutionPlan:
    """Static description of how an edgeMap executes.

    mesh        — jax Mesh, or None for plain single-device execution
    shard_axes  — mesh axes the edge blocks shard over (() → all axes);
                  vertex state is replicated over every axis either way
    backend     — 'csr' | 'compressed' | 'delta' | 'auto' (informational;
                  recorded by make_plan from the graph so cost models /
                  benchmarks can report what actually ran — 'delta' is the
                  repro.delta overlay backend, whose base alone counts as
                  NVRAM)
    strategy    — default edgeMap mode when the call site doesn't pass one:
                  'dense' (pull over all blocks), 'sparse' (chunked over
                  frontier-owned blocks), 'sparse_streamed' (chunked with
                  the frontier-sparse Pallas decode: only live compressed
                  tiles stream HBM→VMEM; non-compressed backends fall back
                  to 'sparse'), 'auto' (Beamer direction opt.)
    reduce_mode — cross-shard combine for the sum monoid: 'flat' psums the
                  O(n) vector over every shard axis; 'hierarchical'
                  reduce-scatters along the fastest axis first (wire bytes
                  on slow axes drop by the fast-axis width, §5.2)
    state_dtype — reduce in a narrower dtype (e.g. bf16), the graph-engine
                  analogue of gradient compression
    chunk_blocks— chunk size for the sparse strategy
    dense_frac  — Beamer threshold: dense when frontier degree > m/dense_frac
                  (measured plans carry 1/d* for the calibrated dense/sparse
                  crossover density d* instead of the hand-picked constant)
    auto_sparse — which sparse flavor the 'auto' strategy's sparse branch
                  runs: 'sparse' | 'sparse_streamed' (calibration picks the
                  one that measured cheaper; non-streaming backends fall
                  back inside edgemap_chunked either way)
    dense_frac_batched — Beamer threshold for BATCHED rounds, from the
                  batched density sweep's own crossover: the batched dense
                  body amortizes one shared sweep over all B lanes, so
                  dense wins batched at far lower densities than
                  single-query and the single-query crossover does not
                  transfer
    auto_sparse_batched — the sparse flavor for BATCHED auto rounds,
                  calibrated separately because the crossover is
                  B-dependent: the streamed union path runs one live-block
                  loop shared by all B lanes while plain sparse vmaps B
                  chunk loops, so streaming can win batched while losing
                  single-query
    batched_flavor_crossover — measured density below which the batched
                  streamed union actually wins: when set (and
                  auto_sparse_batched is 'sparse_streamed'), batched auto's
                  sparse branch picks its flavor at runtime from the
                  batch's mean lane density; None runs the static flavor
                  unconditionally
    interpret   — the resolved Pallas lowering every kernel under this plan
                  runs with: False = native Mosaic, True = interpret mode,
                  None = defer to the per-backend default at the call site.
                  ``make_plan`` resolves it from the ``lowering`` knob
                  (explicit arg → calibrated table → DEFAULT_LOWERING) and
                  folds it into ``tuning_key`` so the serving executable
                  cache never aliases lowerings
    pipeline_rounds — sharded round loops run software-pipelined: the O(n)
                  cross-shard combine of round r is issued at the head of
                  round r+1's loop body, next to the (frontier-independent)
                  block decode of the next local sweep, so the collective
                  and the VMEM stream can overlap (one-round epilogue
                  drain).  Bit-identical per lane — only scheduling moves.
                  Algorithms opt in via ``repro.core.plan.round_loop``
    decisions   — the TuningDecision behind this plan's knobs (source
                  'measured' | 'constants', crossover density, table host,
                  resolved ``lowering``) — recorded by make_plan so tests /
                  PSAM accounting can see exactly what ran and why
    """

    mesh: Any = None
    shard_axes: tuple = ()
    backend: str = "auto"
    strategy: str = "auto"
    reduce_mode: str = "flat"
    state_dtype: Any = None
    chunk_blocks: int = DEFAULT_CHUNK_BLOCKS
    dense_frac: float = DEFAULT_DENSE_FRAC
    auto_sparse: str = "sparse"
    dense_frac_batched: float = DEFAULT_DENSE_FRAC
    auto_sparse_batched: str = "sparse"
    batched_flavor_crossover: float | None = None
    interpret: bool | None = None
    pipeline_rounds: bool = False
    decisions: Any = None

    @property
    def axes(self) -> tuple:
        if self.mesh is None:
            return ()
        return tuple(self.shard_axes) or tuple(self.mesh.axis_names)

    @property
    def tuning_key(self) -> tuple:
        """Hashable summary of the knobs that change compiled executables.

        The piece of a compiled-callable cache key that must vary when a
        calibrated table changes a decision — recompiling is correct when
        the strategy / sparse flavor / thresholds changed, and a cache hit
        is correct when they didn't (zero steady-state retraces either
        way).  ``QueryEngine`` and ``ServingService`` fold this into their
        executable cache keys."""
        return (
            self.strategy,
            self.auto_sparse,
            self.auto_sparse_batched,
            None
            if self.batched_flavor_crossover is None
            else float(self.batched_flavor_crossover),
            float(self.dense_frac),
            float(self.dense_frac_batched),
            int(self.chunk_blocks),
            self.interpret,
            bool(self.pipeline_rounds),
        )

    @property
    def num_shards(self) -> int:
        k = 1
        for ax in self.axes:
            k *= self.mesh.shape[ax]
        return k

    @property
    def is_sharded(self) -> bool:
        return self.mesh is not None

    def resolve_mode(self, mode: str | None) -> str:
        """Explicit call-site mode wins; otherwise the plan's strategy."""
        if mode is not None and mode != "auto":
            return mode
        return self.strategy

    def edge_read_words_per_round(self, g) -> int:
        """Large-memory words one dense edgeMap round reads under this plan.

        The planner-owned read quantum the serving scheduler prices
        admission and per-lane drain accounting in: per-shard block reads
        (empty-padding included, compressed backends at compressed byte
        width), summed over this plan's shards — exactly what
        ``PSAMCost.charge_edgemap_planned`` charges for one round.  ``g``
        may be the raw backend or its plan-prepared ``ShardedGraph`` (the
        block split is deterministic, so both price identically).  Delta
        overlays price as their base (``edgemap_round_read_words``'s
        dispatch): patch blocks are DRAM, never part of the read quantum."""
        from .psam import edgemap_round_read_words

        if isinstance(g, ShardedGraph):
            per_shard = edgemap_round_read_words(g.shards, num_shards=1)
            return per_shard * g.num_shards
        return edgemap_round_read_words(g, num_shards=self.num_shards)

    def prepare(self, g, edge_active=None, *, compact_live: bool = False):
        """Shard + stack + place a graph for this plan (identity off-mesh).

        Host-side (concrete arrays only): call once per graph, outside jit,
        like the paper's preprocessing step.  Idempotent on ShardedGraph.

        ``edge_active`` (optional) carries a filter along: any form
        ``edge_active_words`` accepts (GraphFilter, packed words, bool slot
        mask).  When given, returns ``(graph, active)`` with the filter
        words partitioned block-range-wise (``shard_edge_active``) and
        placed next to the edge blocks — off-mesh the pair comes back
        unchanged.  Filters that mutate per round don't need this: the
        sharded executor normalizes raw masks in-trace; ``prepare`` is the
        ahead-of-time placement path for long-lived filters.

        ``compact_live=True`` (requires ``edge_active``) applies
        :func:`compact_live_blocks` **before the shard split**: blocks with
        no active edge under this filter are dropped from the block set
        entirely, so they never occupy a slot in any shard's range and
        never stream — the shards partition the *live* blocks, and the
        returned ``ShardedEdgeActive.live_ids`` records which original
        block each shard row came from.  Off-mesh it returns the compacted
        ``(graph, words)`` pair, the single-device form of the same read
        saving.  Every edgeMap result is unchanged (a dead block only ever
        contributed masked-off slots); only the filter baked in here must
        be the one the rounds run with.
        """
        if compact_live:
            if edge_active is None:
                raise ValueError("compact_live=True requires edge_active")
            if isinstance(g, (ShardedGraph, ShardedEdgeActive)) or isinstance(
                edge_active, ShardedEdgeActive
            ):
                raise ValueError(
                    "compact_live must run before the shard split — pass the "
                    "un-sharded graph and filter"
                )
            orig_nb = g.num_blocks
            g, words, live_ids = compact_live_blocks(g, edge_active)
            edge_active = words
        if not self.is_sharded:
            return g if edge_active is None else (g, edge_active)
        if isinstance(g, ShardedGraph):
            if g.num_shards != self.num_shards:
                raise ValueError(
                    f"graph prepared for {g.num_shards} shards, plan has "
                    f"{self.num_shards}"
                )
            gs = g
        else:
            shards = g.shard(self.num_shards)
            stacked = jax.tree.map(lambda *ls: jnp.stack(ls), *shards)
            sharding = NamedSharding(self.mesh, P(self.axes))
            stacked = jax.tree.map(lambda a: jax.device_put(a, sharding), stacked)
            gs = ShardedGraph(
                shards=stacked,
                num_shards=self.num_shards,
                orig_num_blocks=g.num_blocks,
            )
        if edge_active is None:
            return gs
        if not isinstance(edge_active, ShardedEdgeActive):
            edge_active = shard_edge_active(
                edge_active,
                block_size=gs.block_size,
                blocks_per_shard=gs.blocks_per_shard,
                num_shards=self.num_shards,
                num_blocks=gs.orig_num_blocks,
            )
        if compact_live:
            # audit trail: original block id behind each shard row (pad rows
            # carry the pre-compaction block count, an always-dead sentinel)
            per = gs.blocks_per_shard
            lid = jnp.pad(
                live_ids,
                (0, per * self.num_shards - live_ids.shape[0]),
                constant_values=orig_nb,
            ).reshape(self.num_shards, per)
            edge_active = dataclasses.replace(edge_active, live_ids=lid)
        sharding = NamedSharding(self.mesh, P(self.axes))
        edge_active = ShardedEdgeActive(
            words=jax.device_put(edge_active.words, sharding),
            num_shards=edge_active.num_shards,
            live_ids=edge_active.live_ids,
        )
        return gs, edge_active

    def describe(self) -> str:
        where = (
            f"mesh{tuple(self.mesh.shape[a] for a in self.axes)}"
            if self.is_sharded
            else "single-device"
        )
        return (
            f"plan[{where} backend={self.backend} strategy={self.strategy} "
            f"reduce={self.reduce_mode} shards={self.num_shards}]"
        )


def _resolve_decision(backend: str, strategy: str, tuning):
    """The TuningDecision behind a plan's knobs.

    ``tuning`` is a :class:`repro.tuning.TuningTable` (always consulted),
    ``"default"`` (the shipped table, consulted for ``strategy="auto"``
    plans only — fixed-strategy plans keep the documented constants unless
    a table is passed explicitly), or ``None``/``"off"`` (static constants).
    Backends the table has no measurements for — including ``"auto"`` when
    no graph was passed — fall back to the constants decision.
    """
    from ..tuning.table import TuningTable, constants_decision, default_table

    if tuning is None or tuning == "off":
        return constants_decision(backend, strategy)
    if isinstance(tuning, TuningTable):
        return tuning.decide(backend, strategy)
    if tuning == "default":
        if strategy == "auto":
            try:
                return default_table().decide(backend, strategy)
            except (OSError, ValueError):  # missing/stale shipped table
                return constants_decision(backend, strategy)
        return constants_decision(backend, strategy)
    raise ValueError(
        f"tuning must be a TuningTable, 'default', 'off' or None; got {tuning!r}"
    )


def make_plan(
    g=None,
    *,
    mesh=None,
    strategy: str = "auto",
    shard_axes: tuple = (),
    reduce_mode: str = "flat",
    state_dtype=None,
    chunk_blocks: int | None = None,
    dense_frac: float | None = None,
    lowering: str | None = None,
    pipeline_rounds: bool = False,
    tuning="default",
) -> ExecutionPlan:
    """Build an :class:`ExecutionPlan`, recording the backend from ``g``.

    Knob resolution, most-specific wins: explicit ``chunk_blocks`` /
    ``dense_frac`` arguments → the ``tuning`` source (a calibrated
    :class:`~repro.tuning.TuningTable`, or the shipped default table for
    ``strategy="auto"`` plans) → the static constants in
    ``repro.tuning.defaults``.  The resolved :class:`TuningDecision` —
    including where each value came from (``source='measured'`` vs
    ``'constants'``) and the measured crossover density behind a calibrated
    ``dense_frac`` — is recorded on ``plan.decisions``.  Pass
    ``tuning=None`` (or ``"off"``) to pin the historical constant behavior.

    ``lowering`` picks how every Pallas kernel under the plan lowers:
    ``"native"`` (Mosaic), ``"interpret"`` (XLA interpret mode), or
    ``"auto"`` (per-backend default — native where supported).  ``None``
    defers to the tuning decision's calibrated winner, then to
    ``repro.tuning.defaults.DEFAULT_LOWERING``.  The resolved value lands
    on ``plan.decisions.lowering`` and in ``plan.tuning_key``.

    ``pipeline_rounds=True`` opts sharded round loops into the
    software-pipelined schedule (see :class:`ExecutionPlan` and
    :func:`round_loop`); bit-identical per lane, so it is purely a
    performance knob.
    """
    # kernels depend on core, never the reverse — resolve lazily, exactly
    # like the tuning-table import below
    from ..kernels.lowering import resolve_lowering
    backend = "auto"
    if isinstance(g, ShardedGraph):
        g = g.shards
    if isinstance(g, CompressedCSR):
        backend = "compressed"
    elif isinstance(g, CSRGraph):
        backend = "csr"
    elif hasattr(g, "overlay_small_words"):
        # delta-overlay backend (repro.delta.DeltaGraph) — duck-typed, core
        # never imports delta.  The tuning table has no overlay
        # measurements, so the decision falls back to constants; the
        # recorded backend keeps cost models / benchmarks honest about
        # what ran.  Sharding needs no planner support: DeltaGraph.shard
        # splits base and patch blocks along the same ceil(NB/k) ranges,
        # so prepare()'s stack/device_put path applies unchanged.
        backend = "delta"
    decision = _resolve_decision(backend, strategy, tuning)
    if dense_frac is not None:
        # an explicit threshold pins BOTH predicates — the caller is
        # overriding the crossover, not just the single-query one
        dense_frac_batched = float(dense_frac)
    else:
        dense_frac = decision.dense_frac
        dense_frac_batched = (
            float(decision.dense_frac_batched)
            if decision.dense_frac_batched is not None
            else float(dense_frac)
        )
    if chunk_blocks is None:
        chunk_blocks = decision.chunk_blocks
    resolved_lowering = resolve_lowering(
        lowering if lowering is not None else decision.lowering
    )
    decision = dataclasses.replace(
        decision,
        strategy=strategy,
        dense_frac=float(dense_frac),
        dense_frac_batched=dense_frac_batched,
        chunk_blocks=int(chunk_blocks),
        lowering=resolved_lowering,
    )
    return ExecutionPlan(
        mesh=mesh,
        shard_axes=tuple(shard_axes),
        backend=backend,
        strategy=strategy,
        reduce_mode=reduce_mode,
        state_dtype=state_dtype,
        chunk_blocks=int(chunk_blocks),
        dense_frac=float(dense_frac),
        auto_sparse=decision.auto_sparse,
        dense_frac_batched=dense_frac_batched,
        auto_sparse_batched=decision.auto_sparse_batched,
        batched_flavor_crossover=decision.batched_flavor_crossover,
        interpret=resolved_lowering == "interpret",
        pipeline_rounds=bool(pipeline_rounds),
        decisions=decision,
    )


def sharded_graph_spec(
    n: int,
    num_blocks: int,
    block_size: int,
    num_shards: int,
    weighted: bool = False,
) -> ShardedGraph:
    """ShapeDtypeStruct stand-in for a prepared ShardedGraph (dry-run/AOT)."""
    per, _ = sharded_block_counts(num_blocks, num_shards)
    base = graph_spec(n, per, block_size, weighted)
    stacked = jax.tree.map(
        lambda s: jax.ShapeDtypeStruct((num_shards,) + s.shape, s.dtype), base
    )
    return ShardedGraph(
        shards=stacked, num_shards=num_shards, orig_num_blocks=num_blocks
    )


# ----------------------------------------------------------------------
# Sharded executor — the same edgeMap bodies, inside shard_map
# ----------------------------------------------------------------------
def _combine_shards(plan: ExecutionPlan, out, touched, monoid: str, n: int, out_dtype):
    """Monoid-combine per-shard edgeMap outputs: O(n) words per round."""
    with jax.named_scope("sage.shard_combine"):
        return _combine_shards_body(plan, out, touched, monoid, n, out_dtype)


def _combine_shards_body(plan, out, touched, monoid, n, out_dtype):
    axes = plan.axes
    if plan.state_dtype is not None and monoid == "sum":
        out = out.astype(plan.state_dtype)
    if monoid == "sum" and plan.reduce_mode == "hierarchical" and len(axes) > 1:
        if out.ndim > 2:
            raise NotImplementedError("hierarchical reduce: 1-D or (B, n) only")
        # scatter/gather along the VERTEX dim (last axis) — a batched (B, n)
        # output reduce-scatters each lane's row exactly like the 1-D path,
        # so per-lane sums keep the single-query combine order bit for bit
        dim = out.ndim - 1
        fast, slow = axes[-1], axes[:-1]
        k = plan.mesh.shape[fast]
        pad = [(0, 0)] * out.ndim
        pad[dim] = (0, (-n) % k)
        shard = lax.psum_scatter(
            jnp.pad(out, pad), fast, scatter_dimension=dim, tiled=True
        )
        for ax in slow:
            shard = lax.psum(shard, ax)
        out = lax.all_gather(shard, fast, axis=dim, tiled=True)[..., :n]
    elif monoid == "sum":
        for ax in axes:
            out = lax.psum(out, ax)
    elif monoid == "min":
        for ax in axes:
            out = lax.pmin(out, ax)
    elif monoid == "max":
        for ax in axes:
            out = lax.pmax(out, ax)
    elif monoid == "or":
        o = out.astype(jnp.int32)
        for ax in axes:
            o = lax.psum(o, ax)
        out = o > 0
    else:
        raise ValueError(monoid)
    t = touched.astype(jnp.int32)
    for ax in axes:
        t = lax.psum(t, ax)
    if monoid != "or":
        out = out.astype(out_dtype)
    return out, t > 0


def _sharded_edgemap_call(
    plan: ExecutionPlan,
    g,
    frontier,
    x,
    *,
    local_reduce,
    monoid,
    map_fn,
    edge_active,
    mode,
    dense_frac,
    chunk_blocks,
    auto_sparse=None,
    flavor_crossover=None,
    map_lanes=None,
    interpret=None,
):
    """Shared shard/filter plumbing for both sharded executors.

    ``local_reduce`` is the per-shard body — ``edgemap_reduce`` for the
    single-query executor, ``edgemap_reduce_batched`` for the serving path;
    everything else (ShardedEdgeActive validation, in-trace filter-word
    partitioning, shard_map wiring, the monoid combine) is identical and
    lives here exactly once.  ``map_lanes`` (batched executor only) is a
    replicated bool[B] operand selecting which lanes apply ``map_fn`` —
    the cross-op serving rounds carry it through the mesh unchanged."""
    if not isinstance(g, ShardedGraph):
        g = plan.prepare(g)
    mode = plan.resolve_mode(mode)
    dense_frac = plan.dense_frac if dense_frac is None else dense_frac
    chunk_blocks = plan.chunk_blocks if chunk_blocks is None else chunk_blocks
    auto_sparse = plan.auto_sparse if auto_sparse is None else auto_sparse
    interpret = plan.interpret if interpret is None else interpret
    n = g.n
    out_dtype = x.dtype

    active = None
    if edge_active is not None:
        if isinstance(edge_active, ShardedEdgeActive):
            if edge_active.num_shards != plan.num_shards:
                raise ValueError(
                    f"edge_active prepared for {edge_active.num_shards} "
                    f"shards, plan has {plan.num_shards}"
                )
            active = edge_active
        else:
            active = shard_edge_active(
                edge_active,
                block_size=g.block_size,
                blocks_per_shard=g.blocks_per_shard,
                num_shards=plan.num_shards,
                num_blocks=g.orig_num_blocks,
            )

    has_active = active is not None
    has_lanes = map_lanes is not None

    def local(sg, fm, xv, *rest):
        g_local = jax.tree.map(lambda a: a[0], sg.shards)
        kwargs = {} if map_fn is None else {"map_fn": map_fn}
        if flavor_crossover is not None:
            # batched-executor-only knob (edgemap_reduce has no such param)
            kwargs["flavor_crossover"] = flavor_crossover
        rest = list(rest)
        if has_active:
            # shard-local packed filter words, passed through verbatim:
            # every edgeMap consumer normalizes (dense/sparse unpack once,
            # the streamed kernel wants exactly these words — no
            # unpack→repack round trip)
            kwargs["edge_active"] = rest.pop(0).words[0]
        if has_lanes:
            # replicated per-lane map selection (cross-op batching)
            kwargs["map_lanes"] = rest.pop(0)
        out, touched = local_reduce(
            g_local,
            fm,
            xv,
            monoid=monoid,
            mode=mode,
            dense_frac=dense_frac,
            chunk_blocks=chunk_blocks,
            auto_sparse=auto_sparse,
            interpret=interpret,
            **kwargs,
        )
        return _combine_shards(plan, out, touched, monoid, n, out_dtype)

    in_specs = [P(plan.axes), P(), P()]
    operands = [g, frontier, x]
    if has_active:
        in_specs.append(P(plan.axes))
        operands.append(active)
    if has_lanes:
        in_specs.append(P())
        operands.append(map_lanes)
    fn = shard_map(
        local,
        mesh=plan.mesh,
        in_specs=tuple(in_specs),
        out_specs=(P(), P()),
        # the hierarchical all_gather(psum_scatter(...)) is replicated over
        # the fast axis but the static replication check can't prove it
        check_rep=False,
    )
    out = fn(*operands)
    reg = get_registry()
    if reg.enabled and not any(
        isinstance(leaf, jax.core.Tracer) for leaf in jax.tree.leaves(out)
    ):
        # eager sharded round: count it (cheap) — wall timing lives in
        # round_loop / trace_session, not per-edgeMap
        reg.counter(
            "sage_sharded_edgemap_calls_total",
            "eager sharded edgeMap rounds dispatched",
            labels=("batched",),
        ).inc(batched=str(local_reduce.__name__.endswith("batched")).lower())
    return out


def sharded_edgemap_reduce(
    plan: ExecutionPlan,
    g,
    frontier_mask: jnp.ndarray,
    x: jnp.ndarray,
    *,
    monoid: str = "min",
    map_fn=None,
    edge_active=None,
    mode: str | None = None,
    dense_frac: float | None = None,
    chunk_blocks: int | None = None,
    auto_sparse: str | None = None,
    interpret: bool | None = None,
):
    """Direction-optimized edgeMap over a mesh: per-shard local pass through
    the ordinary ``edgemap_dense`` / ``edgemap_chunked`` bodies, then one
    monoid combine of the O(n) output.  ``g`` must be a ShardedGraph
    (``plan.prepare``); frontier and vertex state are replicated.

    ``edge_active`` runs plan-native: a ``ShardedEdgeActive`` (from
    ``plan.prepare(g, edge_active=...)``) is consumed as-is; any raw form
    (GraphFilter, packed uint32 words, bool slot mask over the global block
    set) is partitioned in-trace by ``shard_edge_active``.  Each shard's
    packed words ride the mesh at one bit per edge slot and unpack locally
    inside the ``shard_map`` body, so the filtered path shares every line of
    the unfiltered executor."""
    # the executor reuses the single-device bodies; import here so edgemap.py
    # can lazily import this module without a cycle
    from .edgemap import edgemap_reduce

    return _sharded_edgemap_call(
        plan, g, frontier_mask, x,
        local_reduce=edgemap_reduce,
        monoid=monoid, map_fn=map_fn, edge_active=edge_active,
        mode=mode, dense_frac=dense_frac, chunk_blocks=chunk_blocks,
        auto_sparse=auto_sparse, interpret=interpret,
    )


def sharded_edgemap_reduce_batched(
    plan: ExecutionPlan,
    g,
    frontier_masks: jnp.ndarray,
    xb: jnp.ndarray,
    *,
    monoid: str = "min",
    map_fn=None,
    edge_active=None,
    mode: str | None = None,
    dense_frac: float | None = None,
    chunk_blocks: int | None = None,
    auto_sparse: str | None = None,
    map_lanes=None,
    interpret: bool | None = None,
):
    """Batched edgeMap over a mesh: B queries share each shard's one local
    edge sweep, then a single monoid combine moves the O(B·n) output.

    The local body is the single-device ``edgemap_reduce_batched`` run on
    the shard's block set (dense: one shared sweep, one m-row × B-column
    segment reduce; sparse: vmapped chunk loops); frontier rows and vertex
    state are replicated, only the edge blocks (and their packed filter
    words) are partitioned — the same plumbing as the single-query executor
    (``_sharded_edgemap_call``), so cross-shard traffic is O(B·n) words per
    round, never O(m).  ``map_lanes`` (bool[B], replicated) restricts
    ``map_fn`` to the selected lanes exactly as in the single-device
    batched body — heterogeneous (cross-op) serving cohorts run sharded
    with no fallback."""
    from .edgemap import edgemap_reduce_batched

    if auto_sparse is None:
        # batched rounds have their own calibrated sparse flavor (the
        # streamed/plain crossover is B-dependent — see ExecutionPlan)
        auto_sparse = plan.auto_sparse_batched
    if dense_frac is None:
        # ...and their own calibrated Beamer threshold (the batched dense
        # body amortizes one shared sweep over all B lanes)
        dense_frac = plan.dense_frac_batched
    return _sharded_edgemap_call(
        plan, g, frontier_masks, xb,
        local_reduce=edgemap_reduce_batched,
        monoid=monoid, map_fn=map_fn, edge_active=edge_active,
        mode=mode, dense_frac=dense_frac, chunk_blocks=chunk_blocks,
        auto_sparse=auto_sparse,
        flavor_crossover=plan.batched_flavor_crossover,
        map_lanes=map_lanes,
        interpret=interpret,
    )


# ----------------------------------------------------------------------
# Round-pipelined loop driver — overlap combine(r) with sweep(r+1)
# ----------------------------------------------------------------------
# rounds-per-call buckets: traversal diameters on the tested graphs run
# 1..~100; log-spaced to 10k covers pathological chains
_ROUND_BUCKETS = exp_buckets(1.0, 10_000.0, per_decade=6)


def _eager_round_loop_observer(state):
    """Host-side timing hook for one ``round_loop`` call, or ``None``.

    Timing a loop of ``lax.while_loop`` rounds from the host is only
    meaningful (and only *possible*) when the call executes eagerly — under
    ``jax.jit`` / ``eval_shape`` the state leaves are tracers and the
    "call" is a trace, not an execution.  And it is only *wanted* when the
    active registry is live.  Returns a ``finish(final, path)`` callable
    that blocks on the result, records wall seconds into
    ``sage_round_loop_seconds{path=}`` and — when the final state carries a
    scalar integer leaf (BFS's ``rnd``, PageRank's ``iters``) — the round
    count into ``sage_round_loop_rounds{path=}``.  Per-round GPU-accurate
    timing comes from ``trace_session`` + the ``sage.round*`` scopes; this
    is the always-on cheap aggregate.
    """
    reg = get_registry()
    if not reg.enabled or any(
        isinstance(leaf, jax.core.Tracer) for leaf in jax.tree.leaves(state)
    ):
        return None
    t0 = time.perf_counter()

    def finish(final, path):
        final = jax.block_until_ready(final)
        reg.histogram(
            "sage_round_loop_seconds",
            "wall seconds per eager round_loop call",
            labels=("path",), buckets=DEFAULT_LATENCY_BUCKETS,
        ).observe(time.perf_counter() - t0, path=path)
        rounds = [
            int(leaf)
            for leaf in jax.tree.leaves(final)
            if getattr(leaf, "ndim", None) == 0
            and jnp.issubdtype(leaf.dtype, jnp.integer)
        ]
        if rounds:
            reg.histogram(
                "sage_round_loop_rounds",
                "rounds executed per eager round_loop call",
                labels=("path",), buckets=_ROUND_BUCKETS,
            ).observe(float(max(rounds)), path=path)
        return final

    return finish


def round_loop(
    g,
    state,
    *,
    sweep_inputs,
    epilogue,
    cond_fn,
    monoid: str,
    plan: ExecutionPlan | None = None,
    map_fn=None,
    edge_active=None,
    mode: str = "auto",
    batched: bool = False,
):
    """Run a frontier round loop, software-pipelined when the plan asks.

    Every Sage traversal is the same recurrence::

        while cond_fn(state):
            state, frontier, x = sweep_inputs(state)   # pre-sweep mutation
            out, touched = edgeMap(g, frontier, x)     # sweep + combine
            state = epilogue(state, out, touched)

    This driver owns that loop.  For single-device plans (or
    ``plan.pipeline_rounds=False``) it runs the literal sequential
    recurrence above — one ``edgemap_reduce`` (or the batched variant) per
    round, bit-for-bit what the open-coded algorithm loops did.

    For sharded plans with ``pipeline_rounds=True`` the whole loop moves
    inside ONE ``shard_map`` and the schedule is skewed: round ``r``'s
    O(n) cross-shard monoid combine is issued at the *head* of the loop
    body, adjacent to round ``r+1``'s local sweep, so the collective and
    the next block stream overlap (a one-round software pipeline with an
    epilogue drain).  Only scheduling moves — each round still runs
    ``sweep → combine → epilogue`` on the same values in the same order,
    so results are bit-identical per lane to the sequential path (locked
    by ``tests/test_pipeline.py``).

    ``sweep_inputs(state) -> (state', frontier, x)`` may mutate state
    before the sweep (wBFS settles its extracted bucket); ``epilogue(state,
    out, touched) -> state`` applies the combined sweep; ``cond_fn(state)``
    is the loop predicate.  All three must be collective-free — the driver
    owns every cross-shard word.
    """
    pipelined = (
        plan is not None and plan.is_sharded and plan.pipeline_rounds
    )
    observe = _eager_round_loop_observer(state)
    if not pipelined:
        from .edgemap import edgemap_reduce, edgemap_reduce_batched

        local_reduce = edgemap_reduce_batched if batched else edgemap_reduce
        kwargs = {} if map_fn is None else {"map_fn": map_fn}
        if edge_active is not None:
            kwargs["edge_active"] = edge_active

        def body(st):
            st, frontier, x = sweep_inputs(st)
            with jax.named_scope("sage.round"):
                out, touched = local_reduce(
                    g, frontier, x, monoid=monoid, mode=mode, plan=plan,
                    **kwargs,
                )
            return epilogue(st, out, touched)

        final = lax.while_loop(cond_fn, body, state)
        return final if observe is None else observe(final, "sequential")

    # ---- pipelined sharded path: the whole loop in one shard_map ----
    if not isinstance(g, ShardedGraph):
        g = plan.prepare(g)
    rmode = plan.resolve_mode(mode)
    chunk_blocks = plan.chunk_blocks
    interpret = plan.interpret
    if batched:
        dense_frac = plan.dense_frac_batched
        auto_sparse = plan.auto_sparse_batched
        flavor_crossover = plan.batched_flavor_crossover
    else:
        dense_frac = plan.dense_frac
        auto_sparse = plan.auto_sparse
        flavor_crossover = None
    n = g.n

    active = None
    if edge_active is not None:
        if isinstance(edge_active, ShardedEdgeActive):
            if edge_active.num_shards != plan.num_shards:
                raise ValueError(
                    f"edge_active prepared for {edge_active.num_shards} "
                    f"shards, plan has {plan.num_shards}"
                )
            active = edge_active
        else:
            active = shard_edge_active(
                edge_active,
                block_size=g.block_size,
                blocks_per_shard=g.blocks_per_shard,
                num_shards=plan.num_shards,
                num_blocks=g.orig_num_blocks,
            )
    has_active = active is not None

    from .edgemap import edgemap_reduce, edgemap_reduce_batched

    local_reduce = edgemap_reduce_batched if batched else edgemap_reduce

    def whole(sg, st0, *rest):
        g_local = jax.tree.map(lambda a: a[0], sg.shards)
        kwargs = {} if map_fn is None else {"map_fn": map_fn}
        if batched and flavor_crossover is not None:
            kwargs["flavor_crossover"] = flavor_crossover
        if has_active:
            kwargs["edge_active"] = rest[0].words[0]
        out_dtype = jax.eval_shape(lambda s: sweep_inputs(s)[2], st0).dtype

        def sweep(frontier, x):
            # local (uncombined) sweep — same body the sequential sharded
            # executor runs per shard, resolved from the same plan knobs
            with jax.named_scope("sage.round.sweep"):
                return local_reduce(
                    g_local, frontier, x, monoid=monoid, mode=rmode,
                    dense_frac=dense_frac, chunk_blocks=chunk_blocks,
                    auto_sparse=auto_sparse, interpret=interpret, **kwargs,
                )

        def combine(pending):
            out, touched = pending
            return _combine_shards(plan, out, touched, monoid, n, out_dtype)

        def maybe_sweep(st, pending, flag):
            # the local sweep is collective-free, so it is legal under
            # lax.cond inside shard_map; the combine is NOT conditional
            def do(st, pending):
                st2, frontier, x = sweep_inputs(st)
                return st2, sweep(frontier, x)

            def dont(st, pending):
                return st, pending

            return lax.cond(flag, do, dont, st, pending)

        shapes = jax.eval_shape(lambda s: sweep(*sweep_inputs(s)[1:]), st0)
        zeros = jax.tree.map(lambda sd: jnp.zeros(sd.shape, sd.dtype), shapes)

        flag0 = cond_fn(st0)
        st1, pending0 = maybe_sweep(st0, zeros, flag0)

        def body(carry):
            st, pending, flag = carry
            # head-of-round: combine round r while the hardware can overlap
            # it with round r+1's (already issued) local block stream
            out, touched = combine(pending)
            st = epilogue(st, out, touched)
            flag = cond_fn(st)
            st, pending = maybe_sweep(st, pending, flag)
            return st, pending, flag

        final, _, _ = lax.while_loop(lambda c: c[2], body, (st1, pending0, flag0))
        return final

    in_specs = [P(plan.axes), P()]
    operands = [g, state]
    if has_active:
        in_specs.append(P(plan.axes))
        operands.append(active)
    fn = shard_map(
        whole,
        mesh=plan.mesh,
        in_specs=tuple(in_specs),
        out_specs=P(),
        # every shard computes the same replicated state (the combine is
        # replicated by construction) but the static check can't prove it
        check_rep=False,
    )
    final = fn(*operands)
    return final if observe is None else observe(final, "pipelined")
