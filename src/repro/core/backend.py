"""Graph-backend protocol — one edgeMap engine over three storage formats.

``edge_map`` / ``edgemap_dense`` / ``edgemap_chunked`` / ``edgemap_reduce``
(and everything layered on them: graphFilter, vertexSubset composition, the
algorithm suite) accept any object satisfying ``GraphBackend``:

* ``CSRGraph``       — uncompressed blocked CSR (the seed format)
* ``CompressedCSR``  — Ligra+-style delta-packed blocks (§5.1.3)
* ``DeltaGraph``     — mutable ``base ∪ delta`` overlay (``repro.delta``):
  one of the two formats above as the read-only NVRAM base, plus DRAM
  patch blocks appended after the base block range and tombstone bits
  folded into the block view.  It satisfies the protocol structurally —
  this module never imports it (delta layers ON core) — and takes the
  generic paths below: lazy ``block_dst`` for the dense pass, the
  block-gather tile for the sparse pass (``sparse_streamed`` falls back
  to plain sparse, the documented non-CompressedCSR behavior), so every
  consumer serves a mutated graph unmodified.

The two structural hooks that differ per backend live here:

* ``dense_block_view``  — the full (NB, F_B) target/weight view for the
  dense (pull) pass.  For the compressed backend this is the lazy cumsum
  decode, which XLA fuses into the consuming gather/segment-reduce; the
  Pallas ``compressed_spmv`` kernel is the explicitly streamed variant.
* ``tile_block_view``   — a C-block tile for the chunked (sparse) pass.
  For the compressed backend this decodes *inside the chunk loop*
  (App. D.1's "decode the whole block to fetch one edge" discipline), so
  peak intermediates stay ``chunk_blocks × F_B`` words for both formats.
"""
from __future__ import annotations

from typing import Protocol, Union, runtime_checkable

import jax.numpy as jnp

from .compressed import (
    CompressedCSR,
    decode_block_tile,
    decode_blocks,
    exception_dense,
)
from .csr import CSRGraph


@runtime_checkable
class GraphBackend(Protocol):
    """Structural surface every graph execution backend provides."""

    n: int
    m: int
    num_blocks: int
    block_size: int
    block_src: jnp.ndarray  # int32[NB] — owner vertex per block
    degrees: jnp.ndarray    # int32[n]

    @property
    def block_dst(self) -> jnp.ndarray: ...  # int32[NB, FB], sentinel n pads

    @property
    def block_w(self) -> jnp.ndarray: ...    # float32[NB, FB]

    @property
    def edge_valid(self) -> jnp.ndarray: ...  # bool[NB*FB]

    def shard(self, num_shards: int) -> list["GraphBackend"]: ...
    # block-range partition: each shard is a valid backend over the global
    # vertex space (n, degrees replicated; blocks split; non-dividing counts
    # pad with empty blocks).  Consumed by the planner (repro.core.plan).


GraphLike = Union[CSRGraph, CompressedCSR]


def dense_block_view(g: GraphBackend) -> tuple[jnp.ndarray, jnp.ndarray]:
    """(block_dst, block_w), both (NB, F_B) — the dense-pass edge view."""
    return g.block_dst, g.block_w


def tile_block_view(
    g: GraphBackend, bids: jnp.ndarray
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """(dst, w), both (C, F_B), for a tile of block ids.

    ``bids`` rows equal to ``num_blocks`` (the compact_mask fill) yield
    all-sentinel targets / zero weights for both backends.  Anything that
    satisfies ``GraphBackend`` without being a ``CompressedCSR`` takes the
    generic block-gather path.
    """
    if isinstance(g, CompressedCSR):
        if exception_dense(g):
            # COO patching would cost O(C·NE) per chunk; decode exactly
            # instead — loop-invariant, so XLA hoists it out of the chunk
            # loop and the tile is a plain row gather
            dst = jnp.take(decode_blocks(g), bids, axis=0, mode="fill", fill_value=g.n)
        else:
            dst = decode_block_tile(g, bids)
        if g.block_weights is not None:
            w = jnp.take(g.block_weights, bids, axis=0, mode="fill", fill_value=0.0)
        else:
            w = jnp.ones(dst.shape, jnp.float32)
        return dst, w
    dst = jnp.take(g.block_dst, bids, axis=0, mode="fill", fill_value=g.n)
    w = jnp.take(g.block_w, bids, axis=0, mode="fill", fill_value=0.0)
    return dst, w
