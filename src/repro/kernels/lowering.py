"""Backend-aware Pallas lowering resolution.

Every Pallas kernel entry point takes ``interpret: bool | None``.  ``True``
runs the kernel in interpret mode (pure XLA emulation of the grid — the
only mode that works on CPU), ``False`` lowers natively through Mosaic.
``None`` — the default everywhere — defers the decision to this module:
the plan's calibrated ``lowering`` knob if one is threaded through, else
:data:`repro.tuning.defaults.DEFAULT_LOWERING` resolved per backend.

This is the one place that inspects ``jax.default_backend()``, so the
kernels, the core edgeMap, and the planner all agree on what ``None``
means and the serving cache can key executables by the *resolved* value.
"""
from __future__ import annotations

import jax

from ..tuning.defaults import DEFAULT_LOWERING

LOWERINGS = ("auto", "native", "interpret")


def native_lowering_supported() -> bool:
    """True when this process can lower Pallas kernels natively (Mosaic).

    Native lowering needs a TPU backend; on CPU/GPU hosts the kernels run
    in interpret mode.  (Pallas-on-GPU Triton lowering is not wired into
    these kernels' BlockSpecs, so GPU counts as unsupported here.)
    """
    return jax.default_backend() == "tpu"


def resolve_lowering(lowering: str | None = None) -> str:
    """Collapse a lowering knob to ``"native"`` or ``"interpret"``.

    ``None`` and ``"auto"`` pick natively-lowered kernels exactly when
    :func:`native_lowering_supported` says the backend can compile them;
    explicit ``"native"`` / ``"interpret"`` pass through (a forced
    ``"native"`` on CPU will fail loudly at compile time, which is the
    right behavior for an explicit override).
    """
    if lowering is None:
        lowering = DEFAULT_LOWERING
    if lowering not in LOWERINGS:
        raise ValueError(f"lowering must be one of {LOWERINGS}, got {lowering!r}")
    if lowering == "auto":
        return "native" if native_lowering_supported() else "interpret"
    return lowering


def resolve_interpret(interpret: bool | None = None,
                      lowering: str | None = None) -> bool:
    """The ``interpret=`` flag a ``pl.pallas_call`` should actually get.

    An explicit bool wins (call sites that already decided); otherwise the
    ``lowering`` knob (``"auto"``/``"native"``/``"interpret"``, default
    :data:`~repro.tuning.defaults.DEFAULT_LOWERING`) is resolved against
    the current backend.
    """
    if interpret is not None:
        return bool(interpret)
    return resolve_lowering(lowering) == "interpret"
