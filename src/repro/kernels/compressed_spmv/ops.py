"""jit'd public wrappers around the compressed_spmv Pallas kernel.

Three entry points, one per streaming discipline:

* ``compressed_spmv_vertex`` (+``_batched``) — the dense grid: every block's
  compressed tile streams HBM→VMEM once, fused decode + masked SpMV, with
  the rare ESCAPE blocks recomputed exactly and patched afterwards.
* ``compressed_spmv_vertex_chunked`` — the frontier-sparse chunked mode:
  only blocks owned by ``frontier`` vertices stream, driven by the
  scalar-prefetched compacted live-id list (``PrefetchScalarGridSpec``);
  handles single and (B, n)-batched vertex state.
* ``compressed_chunked_stream_tile`` — the chunk-pool decoder behind the
  core ``edgemap_chunked`` streamed path: one chunk of live ids in, exact
  masked targets + aligned weights out, exceptions patched by gathered id.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax

from ...core.compressed import CompressedCSR, decode_block, exception_dense
from ...core.graph_filter import (
    GraphFilter,
    edge_active_words,
    make_filter,
    unpack_word_bits,
)
from ...core.primitives import compact_mask
from ...tuning.defaults import DEFAULT_TILE_BLOCKS
from .compressed_spmv import (
    compressed_block_spmv_pallas,
    compressed_chunked_spmv_pallas,
)
from .ref import compressed_block_spmv_ref, compressed_chunked_spmv_ref


def compressed_block_spmv(
    x,
    block_first,
    deltas,
    valid_count,
    bits,
    edge_active=None,
    block_weights=None,
    *,
    n: int,
    interpret: bool | None = None,
    tile_blocks: int = DEFAULT_TILE_BLOCKS,
):
    """Raw kernel entry: per-block partial sums off the compressed stream.

    The array-level form of ``compressed_spmv_vertex`` without the owner
    reduction or the exception fixup — callers holding the delta-packed
    arrays directly (benchmarks, tests) get the fused decode+SpMV exactly
    as the kernel computes it, ESCAPE blocks decoded wrong on purpose.
    ``x`` may be (n_pad,) or a (B, n_pad) query batch (→ out (NB, B)).
    """
    return compressed_block_spmv_pallas(
        x,
        block_first,
        deltas,
        valid_count,
        bits,
        edge_active,
        block_weights,
        n=n,
        interpret=interpret,
        tile_blocks=tile_blocks,
    )


def _exception_block_sums(c: CompressedCSR, x, bits, weights=None, active=None):
    """Exact per-block partial sums for the blocks on the exception list.

    ``exc_block`` may repeat a block (several wide gaps in one block), so
    each row is decoded with ``decode_block``, which patches *every*
    exception matching its block id — O(NE² ) integer compares plus
    O(NE · F_B) decode work, no NE×NE×F_B intermediates (App. D.1's rare
    path; the ops-level fallback caps NE before this could dominate).
    ``weights`` rides along as the uncompressed (NB, FB) stream and
    ``active`` as the packed (NB, F_B/32) traversal mask: the exception rows
    gather their aligned weight/mask tiles by block id, so the fixup masks
    exactly what the kernel masked.

    Batched queries (x of shape (B, n_pad)) return (NE, B): the exception
    rows are decoded once and applied across the batch, matching the
    kernel's amortization contract slot for slot.
    """
    ebids = c.exc_block
    dst = jax.vmap(lambda b: decode_block(c, b))(ebids)    # exact decode
    act = unpack_word_bits(jnp.take(bits, ebids, axis=0))
    if active is not None:
        act = act & unpack_word_bits(jnp.take(active, ebids, axis=0))
    mask = (dst < jnp.int32(c.n)) & act
    safe = jnp.where(mask, dst, 0)
    if x.ndim == 2:
        xv = jnp.take(x, safe.reshape(-1), axis=1).reshape(
            x.shape[0], *dst.shape
        )                                                  # (B, NE, FB)
        if weights is not None:
            xv = xv * jnp.take(weights, ebids, axis=0)[None]
        contrib = jnp.where(mask[None], xv, jnp.zeros((), x.dtype))
        return jnp.sum(contrib, axis=2).T                  # (NE, B)
    xv = jnp.take(x, safe.reshape(-1), axis=0).reshape(dst.shape)
    if weights is not None:
        xv = xv * jnp.take(weights, ebids, axis=0)
    contrib = jnp.where(mask, xv, jnp.zeros((), x.dtype))
    return jnp.sum(contrib, axis=1)                        # (NE,)


def compressed_spmv_vertex(
    c: CompressedCSR,
    x: jnp.ndarray,
    f: GraphFilter | None = None,
    *,
    edge_active=None,
    interpret: bool | None = None,
    tile_blocks: int = DEFAULT_TILE_BLOCKS,
) -> jnp.ndarray:
    """out[v] = Σ_{(v,u) active} w_vu · x[u], straight off the compressed
    stream.

    The Pallas kernel fuses the uint16-delta decode with the masked SpMV; the
    rare ESCAPE blocks are then recomputed exactly and patched into the
    per-block sums before the cheap O(#blocks) owner reduction.

    ``edge_active`` is the per-call traversal mask (a GraphFilter, a packed
    uint32 (NB, F_B/32) word array, or a bool edge-slot mask — see
    ``repro.core.graph_filter.edge_active_words``).  It streams into the
    kernel as a second packed bitmask tile and is ANDed with the filter bits
    in VMEM — the filtered fast path never falls back to a full decode, and
    the exception fixup applies the identical mask.

    Weighted graphs keep their weights as a parallel *uncompressed* stream
    (weights don't difference-encode, §5.1.3): the kernel streams the
    aligned (TB, FB) weight tile next to the delta tile and applies it after
    the in-VMEM decode, so the target stream still moves at compressed
    width.  (A fused weight-compression scheme is future work; this is the
    minimal correct fast path.)

    Graphs whose neighbor lists lack id-locality (many true ≥2¹⁶ gaps) make
    the exception list dense; past num_blocks/4 exceptions — or past the
    absolute cap where the O(NE²) tile fixup would dominate — the fused
    stream saves nothing and the exact jnp decode is used instead, a static
    (trace-time) choice since n_exceptions is metadata.  That choice depends
    only on the exception density, never on whether a filter is present.
    """
    bits = f.bits if f is not None else make_filter(c).bits
    active = (
        None
        if edge_active is None
        else edge_active_words(edge_active, c.block_size)
    )
    w = c.block_weights if c.weighted else None
    if exception_dense(c):
        per_block = compressed_block_spmv_ref(c, x, bits, w, active)
    else:
        per_block = compressed_block_spmv_pallas(
            x,
            c.block_first,
            c.deltas,
            c.valid_count,
            bits,
            active,
            w,
            n=c.n,
            interpret=interpret,
            tile_blocks=tile_blocks,
        )
        if c.n_exceptions:
            fixed = _exception_block_sums(c, x, bits, w, active)
            per_block = per_block.at[c.exc_block].set(fixed)
    return jax.ops.segment_sum(per_block, c.block_src, num_segments=c.n + 1)[: c.n]


def _exception_row_targets(c: CompressedCSR, active=None):
    """Exact decoded targets for every exception-list block, active-masked.

    (NE, FB) int32 with inactive slots already at the sentinel ``n`` — the
    same folding the chunked kernel applies in-VMEM, so a patched row is
    indistinguishable from a correctly decoded one."""
    exact = jax.vmap(lambda b: decode_block(c, b))(c.exc_block)
    if active is not None:
        abits = unpack_word_bits(jnp.take(active, c.exc_block, axis=0))
        exact = jnp.where(abits, exact, jnp.int32(c.n))
    return exact


def _rows_for_ids(ids: jnp.ndarray, exc_block: jnp.ndarray, num_blocks: int):
    """For each exception, the row of ``ids`` holding its block (drop: len).

    ``ids`` rows are unique real block ids (compacted indices) plus sentinel
    pad, so argmax-over-match routes each exception to at most one row —
    the existing per-block patch discipline, keyed on the gathered ids.
    Exception rows with ``exc_block >= num_blocks`` are the padding of a
    sharded graph's stacked exception list; without the in-range guard they
    would match the chunk's own sentinel pad (both use the block count as
    fill) and ghost-patch the all-sentinel row."""
    match = (ids[:, None] == exc_block[None, :]) & (
        exc_block[None, :] < jnp.int32(num_blocks)
    )                                                          # (C, NE)
    hit = jnp.any(match, axis=0)
    return jnp.where(hit, jnp.argmax(match, axis=0), ids.shape[0])


def compressed_chunked_stream_tile(
    c: CompressedCSR,
    ids: jnp.ndarray,
    edge_active=None,
    *,
    interpret: bool | None = None,
    exact_rows: jnp.ndarray | None = None,
    gather_tiles: bool = True,
    tile_blocks: int = DEFAULT_TILE_BLOCKS,
):
    """Stream + decode ONE chunk of live blocks: (dst (C, FB), w (C, FB)).

    The Pallas kernel moves only the delta/bitmask/weight tiles named by
    ``ids`` (ids ≥ num_blocks decode to all-sentinel rows), fusing the
    cumsum decode and the packed ``edge_active`` masking in-VMEM; ESCAPE
    blocks are then recomputed exactly and patched keyed on the gathered
    ids.  This is the tile view the core ``edgemap_chunked`` streamed path
    consumes in place of ``tile_block_view`` — same contract, but the dead
    blocks' compressed bytes are never read.

    ``exact_rows``: optionally the precomputed
    ``_exception_row_targets(c, words)`` — it is id-independent, so a
    chunk-loop caller computes it ONCE outside the loop and passes it per
    chunk instead of re-decoding every exception block per iteration
    (``_streaming_decoder`` in ``repro.core.edgemap`` does exactly this).

    ``gather_tiles`` (default) batches the live rows into DMA-sized
    ``(tile_blocks, FB)`` kernel tiles instead of the row-steered
    ``(1, FB)`` grid; shapes and results are identical either way.
    """
    active = (
        None
        if edge_active is None
        else edge_active_words(edge_active, c.block_size)
    )
    w = c.block_weights if c.weighted else None
    dst, ws = compressed_chunked_spmv_pallas(
        None,
        ids,
        c.block_first,
        c.deltas,
        c.valid_count,
        None,
        active,
        w,
        n=c.n,
        emit="decode",
        interpret=interpret,
        gather_tiles=gather_tiles,
        tile_blocks=tile_blocks,
    )
    if c.n_exceptions:
        exact = (
            _exception_row_targets(c, active) if exact_rows is None else exact_rows
        )
        rows = _rows_for_ids(ids, c.exc_block, c.num_blocks)
        dst = dst.at[rows].set(exact, mode="drop")
    return dst, ws


def compressed_spmv_vertex_chunked(
    c: CompressedCSR,
    x: jnp.ndarray,
    frontier: jnp.ndarray,
    f: GraphFilter | None = None,
    *,
    edge_active=None,
    tile_blocks: int = DEFAULT_TILE_BLOCKS,
    interpret: bool | None = None,
    gather_tiles: bool = True,
) -> jnp.ndarray:
    """Frontier-sparse SpMV: sums over ONLY the frontier-owned blocks.

    ``out[v] = Σ_{(v,u) active} w_vu · x[u]`` for frontier vertices v, 0
    elsewhere — the per-vertex pull restricted to the blocks the frontier
    touches, which is the PSAM read-volume claim: bytes streamed off the
    compressed array are proportional to the live blocks, not to NB.

    Execution: the live block ids are compacted once (``compact_mask`` over
    ``frontier[block_src]``, an O(n)-word list) and walked in chunks of
    ``tile_blocks``; each chunk is one ``PrefetchScalarGridSpec`` launch of
    ``compressed_chunked_spmv_pallas`` (so the streamed volume is the
    padded chunk count, ``ceil(k / TB) · TB`` blocks), and the chunk loop
    is a dynamic-trip-count ``while_loop`` — chunks past the live count
    never execute.  Exception blocks are patched with the exact per-block
    sums keyed on the gathered ids; exception-dense graphs fall back to the
    masked exact decode (a function of exception density only, as ever).

    ``x`` may be (n,) or a (B, n) query batch — the batch shares each
    chunk's single delta-stream read, returning (B, n).  ``f`` /
    ``edge_active`` behave exactly as in ``compressed_spmv_vertex``.
    """
    bits = f.bits if f is not None else make_filter(c).bits
    active = (
        None
        if edge_active is None
        else edge_active_words(edge_active, c.block_size)
    )
    w = c.block_weights if c.weighted else None
    batched = x.ndim == 2
    if exception_dense(c):
        return compressed_chunked_spmv_ref(c, x, frontier, bits, w, active)

    NB = c.num_blocks
    TB = min(tile_blocks, NB)
    nchunks = -(-NB // TB)
    blk_live = jnp.take(frontier, c.block_src, mode="fill", fill_value=False)
    idx, k = compact_mask(blk_live, fill=NB)
    idx = jnp.pad(idx, (0, nchunks * TB - NB), constant_values=NB)

    fixed = (
        _exception_block_sums(c, x, bits, w, active) if c.n_exceptions else None
    )  # (NE,) or (NE, B): exact sums, same masks as the kernel

    out0 = jnp.zeros(
        (c.n + 1, x.shape[0]) if batched else (c.n + 1,), x.dtype
    )

    def body(state):
        i, out = state
        ids = lax.dynamic_slice(idx, (i * TB,), (TB,))
        sums = compressed_chunked_spmv_pallas(
            x,
            ids,
            c.block_first,
            c.deltas,
            c.valid_count,
            bits,
            active,
            w,
            n=c.n,
            emit="sums",
            interpret=interpret,
            gather_tiles=gather_tiles,
            tile_blocks=TB,
        )  # (TB,) or (TB, B) — only these ids' tiles were streamed
        if fixed is not None:
            rows = _rows_for_ids(ids, c.exc_block, c.num_blocks)
            sums = sums.at[rows].set(fixed, mode="drop")
        srcs = jnp.take(c.block_src, ids, mode="fill", fill_value=c.n)
        out = out + jax.ops.segment_sum(sums, srcs, num_segments=c.n + 1)
        return i + 1, out

    def cond(state):
        i, _ = state
        return (i * TB < k) & (i < nchunks)

    _, out = lax.while_loop(cond, body, (jnp.int32(0), out0))
    out = out[: c.n]
    return out.T if batched else out


def compressed_spmv_vertex_batched(
    c: CompressedCSR,
    xb: jnp.ndarray,
    f: GraphFilter | None = None,
    *,
    edge_active=None,
    interpret: bool | None = None,
    tile_blocks: int = DEFAULT_TILE_BLOCKS,
) -> jnp.ndarray:
    """Batched ``compressed_spmv_vertex``: ``xb`` is (B, n); returns (B, n).

    One sweep of the compressed stream serves all B queries: each grid step
    streams the delta tile (plus masks/weights) into VMEM and runs the fused
    cumsum decode once, fanning only the gather across the B columns — the
    compressed edge-byte reads amortize ÷B.  The ESCAPE-block fixup and the
    exact-decode fallback are vectorized to match, so every query's result
    is bit-identical to its own single-query run."""
    bits = f.bits if f is not None else make_filter(c).bits
    active = (
        None
        if edge_active is None
        else edge_active_words(edge_active, c.block_size)
    )
    w = c.block_weights if c.weighted else None
    if exception_dense(c):
        per_block = compressed_block_spmv_ref(c, xb, bits, w, active)  # (NB, B)
    else:
        per_block = compressed_block_spmv_pallas(
            xb,
            c.block_first,
            c.deltas,
            c.valid_count,
            bits,
            active,
            w,
            n=c.n,
            interpret=interpret,
            tile_blocks=tile_blocks,
        )  # (NB, B)
        if c.n_exceptions:
            fixed = _exception_block_sums(c, xb, bits, w, active)  # (NE, B)
            per_block = per_block.at[c.exc_block].set(fixed)
    return jax.ops.segment_sum(per_block, c.block_src, num_segments=c.n + 1)[: c.n].T
