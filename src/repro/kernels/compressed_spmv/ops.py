"""jit'd public wrappers around the compressed_spmv Pallas kernel."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from ...core.compressed import CompressedCSR, decode_block, exception_dense
from ...core.graph_filter import (
    GraphFilter,
    edge_active_words,
    make_filter,
    unpack_word_bits,
)
from .compressed_spmv import compressed_block_spmv_pallas
from .ref import compressed_block_spmv_ref


def compressed_block_spmv(
    x,
    block_first,
    deltas,
    valid_count,
    bits,
    edge_active=None,
    block_weights=None,
    *,
    n: int,
    interpret: bool = True,
    tile_blocks: int = 8,
):
    return compressed_block_spmv_pallas(
        x,
        block_first,
        deltas,
        valid_count,
        bits,
        edge_active,
        block_weights,
        n=n,
        interpret=interpret,
        tile_blocks=tile_blocks,
    )


def _exception_block_sums(c: CompressedCSR, x, bits, weights=None, active=None):
    """Exact per-block partial sums for the blocks on the exception list.

    ``exc_block`` may repeat a block (several wide gaps in one block), so
    each row is decoded with ``decode_block``, which patches *every*
    exception matching its block id — O(NE² ) integer compares plus
    O(NE · F_B) decode work, no NE×NE×F_B intermediates (App. D.1's rare
    path; the ops-level fallback caps NE before this could dominate).
    ``weights`` rides along as the uncompressed (NB, FB) stream and
    ``active`` as the packed (NB, F_B/32) traversal mask: the exception rows
    gather their aligned weight/mask tiles by block id, so the fixup masks
    exactly what the kernel masked.

    Batched queries (x of shape (B, n_pad)) return (NE, B): the exception
    rows are decoded once and applied across the batch, matching the
    kernel's amortization contract slot for slot.
    """
    ebids = c.exc_block
    dst = jax.vmap(lambda b: decode_block(c, b))(ebids)    # exact decode
    act = unpack_word_bits(jnp.take(bits, ebids, axis=0))
    if active is not None:
        act = act & unpack_word_bits(jnp.take(active, ebids, axis=0))
    mask = (dst < jnp.int32(c.n)) & act
    safe = jnp.where(mask, dst, 0)
    if x.ndim == 2:
        xv = jnp.take(x, safe.reshape(-1), axis=1).reshape(
            x.shape[0], *dst.shape
        )                                                  # (B, NE, FB)
        if weights is not None:
            xv = xv * jnp.take(weights, ebids, axis=0)[None]
        contrib = jnp.where(mask[None], xv, jnp.zeros((), x.dtype))
        return jnp.sum(contrib, axis=2).T                  # (NE, B)
    xv = jnp.take(x, safe.reshape(-1), axis=0).reshape(dst.shape)
    if weights is not None:
        xv = xv * jnp.take(weights, ebids, axis=0)
    contrib = jnp.where(mask, xv, jnp.zeros((), x.dtype))
    return jnp.sum(contrib, axis=1)                        # (NE,)


def compressed_spmv_vertex(
    c: CompressedCSR,
    x: jnp.ndarray,
    f: GraphFilter | None = None,
    *,
    edge_active=None,
    interpret: bool = True,
    tile_blocks: int = 8,
) -> jnp.ndarray:
    """out[v] = Σ_{(v,u) active} w_vu · x[u], straight off the compressed
    stream.

    The Pallas kernel fuses the uint16-delta decode with the masked SpMV; the
    rare ESCAPE blocks are then recomputed exactly and patched into the
    per-block sums before the cheap O(#blocks) owner reduction.

    ``edge_active`` is the per-call traversal mask (a GraphFilter, a packed
    uint32 (NB, F_B/32) word array, or a bool edge-slot mask — see
    ``repro.core.graph_filter.edge_active_words``).  It streams into the
    kernel as a second packed bitmask tile and is ANDed with the filter bits
    in VMEM — the filtered fast path never falls back to a full decode, and
    the exception fixup applies the identical mask.

    Weighted graphs keep their weights as a parallel *uncompressed* stream
    (weights don't difference-encode, §5.1.3): the kernel streams the
    aligned (TB, FB) weight tile next to the delta tile and applies it after
    the in-VMEM decode, so the target stream still moves at compressed
    width.  (A fused weight-compression scheme is future work; this is the
    minimal correct fast path.)

    Graphs whose neighbor lists lack id-locality (many true ≥2¹⁶ gaps) make
    the exception list dense; past num_blocks/4 exceptions — or past the
    absolute cap where the O(NE²) tile fixup would dominate — the fused
    stream saves nothing and the exact jnp decode is used instead, a static
    (trace-time) choice since n_exceptions is metadata.  That choice depends
    only on the exception density, never on whether a filter is present.
    """
    bits = f.bits if f is not None else make_filter(c).bits
    active = (
        None
        if edge_active is None
        else edge_active_words(edge_active, c.block_size)
    )
    w = c.block_weights if c.weighted else None
    if exception_dense(c):
        per_block = compressed_block_spmv_ref(c, x, bits, w, active)
    else:
        per_block = compressed_block_spmv_pallas(
            x,
            c.block_first,
            c.deltas,
            c.valid_count,
            bits,
            active,
            w,
            n=c.n,
            interpret=interpret,
            tile_blocks=tile_blocks,
        )
        if c.n_exceptions:
            fixed = _exception_block_sums(c, x, bits, w, active)
            per_block = per_block.at[c.exc_block].set(fixed)
    return jax.ops.segment_sum(per_block, c.block_src, num_segments=c.n + 1)[: c.n]


def compressed_spmv_vertex_batched(
    c: CompressedCSR,
    xb: jnp.ndarray,
    f: GraphFilter | None = None,
    *,
    edge_active=None,
    interpret: bool = True,
    tile_blocks: int = 8,
) -> jnp.ndarray:
    """Batched ``compressed_spmv_vertex``: ``xb`` is (B, n); returns (B, n).

    One sweep of the compressed stream serves all B queries: each grid step
    streams the delta tile (plus masks/weights) into VMEM and runs the fused
    cumsum decode once, fanning only the gather across the B columns — the
    compressed edge-byte reads amortize ÷B.  The ESCAPE-block fixup and the
    exact-decode fallback are vectorized to match, so every query's result
    is bit-identical to its own single-query run."""
    bits = f.bits if f is not None else make_filter(c).bits
    active = (
        None
        if edge_active is None
        else edge_active_words(edge_active, c.block_size)
    )
    w = c.block_weights if c.weighted else None
    if exception_dense(c):
        per_block = compressed_block_spmv_ref(c, xb, bits, w, active)  # (NB, B)
    else:
        per_block = compressed_block_spmv_pallas(
            xb,
            c.block_first,
            c.deltas,
            c.valid_count,
            bits,
            active,
            w,
            n=c.n,
            interpret=interpret,
            tile_blocks=tile_blocks,
        )  # (NB, B)
        if c.n_exceptions:
            fixed = _exception_block_sums(c, xb, bits, w, active)  # (NE, B)
            per_block = per_block.at[c.exc_block].set(fixed)
    return jax.ops.segment_sum(per_block, c.block_src, num_segments=c.n + 1)[: c.n].T
