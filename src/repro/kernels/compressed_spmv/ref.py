"""Pure-jnp oracle for the compressed_spmv kernel.

Uses the exact block decode (exception list included), so it is the ground
truth both for the fused kernel and for the exception-patching wrapper.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from ...core.compressed import CompressedCSR, decode_blocks
from ...core.graph_filter import unpack_word_bits


def compressed_block_spmv_ref(c: CompressedCSR, x, bits, weights=None, active=None):
    """Per-block partial sums, computed with plain jnp ops (exact decode).

    ``weights``: optional (NB, FB) uncompressed stream aligned slot-for-slot
    with the decoded block tiles (``CompressedCSR.block_weights``).
    ``active``: optional packed uint32 (NB, F_B/32) traversal mask, ANDed
    with the graphFilter ``bits`` exactly as the kernel does.
    Batched queries (x of shape (B, n_pad)) return (NB, B), mirroring the
    kernel's decode-once-apply-B-columns contract."""
    dst = decode_blocks(c)
    act = unpack_word_bits(bits)
    if active is not None:
        act = act & unpack_word_bits(active)
    mask = (dst < jnp.int32(c.n)) & act
    safe = jnp.where(mask, dst, 0)
    if x.ndim == 2:
        xv = jnp.take(x, safe.reshape(-1), axis=1).reshape(
            x.shape[0], *dst.shape
        )
        if weights is not None:
            xv = xv * weights[None]
        contrib = jnp.where(mask[None], xv, jnp.zeros((), x.dtype))
        return jnp.sum(contrib, axis=2).T
    xv = jnp.take(x, safe.reshape(-1), axis=0).reshape(dst.shape)
    if weights is not None:
        xv = xv * weights
    contrib = jnp.where(mask, xv, jnp.zeros((), x.dtype))
    return jnp.sum(contrib, axis=1)


def compressed_spmv_vertex_ref(c: CompressedCSR, x, bits, weights=None, active=None):
    per_block = compressed_block_spmv_ref(c, x, bits, weights, active)
    out = jax.ops.segment_sum(per_block, c.block_src, num_segments=c.n + 1)[: c.n]
    return out.T if x.ndim == 2 else out


def compressed_chunked_spmv_ref(
    c: CompressedCSR, x, frontier, bits, weights=None, active=None
):
    """Oracle for the frontier-sparse chunked mode: the masked-full-stream
    equivalent of streaming only the compacted live blocks.

    ``frontier`` is the bool[n] vertex mask; a block is live iff its owner
    is in the frontier.  Dead blocks' partial sums are zeroed — which is
    exactly what never streaming them produces — so
    ``compressed_spmv_vertex_chunked`` must match this bit for bit (ints)
    on any frontier, filter, and exception pattern.  Batched ``x`` of shape
    (B, n) returns (B, n)."""
    per_block = compressed_block_spmv_ref(c, x, bits, weights, active)
    blk_live = jnp.take(frontier, c.block_src, mode="fill", fill_value=False)
    sel = blk_live[:, None] if x.ndim == 2 else blk_live
    per_block = jnp.where(sel, per_block, jnp.zeros((), x.dtype))
    out = jax.ops.segment_sum(per_block, c.block_src, num_segments=c.n + 1)[: c.n]
    return out.T if x.ndim == 2 else out
