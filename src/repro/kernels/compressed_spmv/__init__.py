from .ops import (
    compressed_block_spmv,
    compressed_spmv_vertex,
    compressed_spmv_vertex_batched,
)
from .ref import compressed_block_spmv_ref, compressed_spmv_vertex_ref

__all__ = [
    "compressed_block_spmv",
    "compressed_spmv_vertex",
    "compressed_spmv_vertex_batched",
    "compressed_block_spmv_ref",
    "compressed_spmv_vertex_ref",
]
