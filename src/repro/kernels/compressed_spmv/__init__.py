from .ops import (
    compressed_block_spmv,
    compressed_chunked_stream_tile,
    compressed_spmv_vertex,
    compressed_spmv_vertex_batched,
    compressed_spmv_vertex_chunked,
)
from .ref import (
    compressed_block_spmv_ref,
    compressed_chunked_spmv_ref,
    compressed_spmv_vertex_ref,
)

__all__ = [
    "compressed_block_spmv",
    "compressed_chunked_stream_tile",
    "compressed_spmv_vertex",
    "compressed_spmv_vertex_batched",
    "compressed_spmv_vertex_chunked",
    "compressed_block_spmv_ref",
    "compressed_chunked_spmv_ref",
    "compressed_spmv_vertex_ref",
]
