"""Pallas TPU kernel: fused delta-decode + blocked masked SpMV — the dense
edgeMap hot loop over the **compressed** graph backend (§5.1.3, App. D.1).

PSAM → TPU mapping: the vertex state ``x`` (small memory) is VMEM-resident
across the whole grid; the *compressed* edge blocks — one int32 first-target
plus uint16 deltas per block — are streamed HBM→VMEM at roughly half the
bytes of the int32 target stream the uncompressed kernel reads.  The decode
(a lane-dimension cumsum) happens in VMEM, fused with the gather and the
masked reduction: the int32 targets are never materialized in HBM, which is
the TPU analogue of the paper's "decode the whole block to fetch one edge"
filter-iterator discipline.  The graphFilter bits ride along as one uint32
word per 32 edges, exactly as in ``edge_block_spmv``.

Filtered traversals stream a *second* packed bitmask, ``edge_active`` — the
per-call traversal mask (spanner's intra-cluster edges, biconnectivity's
non-tree edges, a graphFilter snapshot) — as its own aligned (TB, F_B/32)
uint32 tile per program.  Both masks are unpacked with vector shifts and
ANDed into the validity mask *in-kernel*, so a filtered edgeMap never
round-trips a combined mask (or worse, decoded targets) through HBM.

Exception handling: deltas ≥ 2¹⁶ are stored as the ESCAPE sentinel and the
kernel decodes those blocks *incorrectly on purpose* — patching a COO
exception list inside a tiled kernel would serialize the pipeline.  The
(rare) exception blocks are recomputed exactly by the wrapper in ops.py
(with the same edge_active masking) and overwritten in the per-block
output; see ``compressed_spmv_vertex``.

Grid: one program per tile of TB edge-blocks, mirroring edge_block_spmv.

Query batching (the serving subsystem's amortization lever): ``x`` may carry
a leading query dimension, ``(B, n_pad)``.  The compressed tile — first
targets, uint16 deltas, valid counts, both packed bitmasks and the optional
weight tile — is streamed into VMEM **once per grid step** and the fused
delta decode runs once; only the gather and masked reduction fan out across
the B vertex-state columns.  The compressed edge-byte reads (the scarce
NVRAM resource) are thus paid once per sweep instead of once per query.
Output grows a trailing query axis: ``(NB, B)``.

Chunked (frontier-sparse) mode: ``compressed_chunked_spmv_pallas`` is the
EDGEMAPCHUNKED analogue of the dense grid above.  Instead of walking every
block, the grid is driven by ``pltpu.PrefetchScalarGridSpec`` whose
scalar-prefetched operand is the *compacted live block-id list* (the
``compact_mask`` of frontier-owned blocks): every BlockSpec ``index_map``
indexes through it (``lambda i, ids: (ids[i], 0)``), so only live delta /
bitmask / weight tiles move HBM→VMEM.  One launch covers one chunk of
``TB`` ids; the caller's chunk loop sizes the launch count to
``ceil(k / TB)`` (k = live blocks), and out-of-range ids (the pad of the
last chunk) land on an all-sentinel row appended behind the real blocks —
streamed bytes are proportional to the live blocks, never to NB.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from ...core.graph_filter import unpack_word_bits
from ...tuning.defaults import DEFAULT_TILE_BLOCKS  # TB: edge-blocks per program
from ..lowering import resolve_interpret


def _kernel(
    x_ref,
    first_ref,
    deltas_ref,
    vc_ref,
    bits_ref,
    *rest,
    n: int,
    has_active: bool,
    has_weights: bool,
    batched: bool,
):
    refs = list(rest)
    out_ref = refs.pop()
    act_ref = refs.pop(0) if has_active else None  # rides right after bits
    w_ref = refs.pop(0) if has_weights else None
    first = first_ref[...]        # (TB,)   int32 — first target per block
    deltas = deltas_ref[...]      # (TB, FB) uint16 — streamed compressed tile
    vc = vc_ref[...]              # (TB,)   int32 — valid (front-packed) slots
    x = x_ref[...]                # (n_pad,) or (B, n_pad) — PSAM small memory
    bits = bits_ref[...]          # (TB, FB//32) uint32 — graphFilter view

    # fused decode: zero the unused lane-0 delta, cumsum along lanes.
    # Decoded ONCE per tile regardless of the query-batch width.
    d = deltas.astype(jnp.int32)
    lane = jax.lax.broadcasted_iota(jnp.int32, d.shape, 1)
    d = jnp.where(lane == 0, 0, d)
    dst = first[:, None] + jnp.cumsum(d, axis=1)

    act = unpack_word_bits(bits)  # (TB, FB) bool, canonical graphFilter order
    if act_ref is not None:
        # per-call traversal mask: same packed layout, ANDed in VMEM
        act = act & unpack_word_bits(act_ref[...])

    mask = (lane < vc[:, None]) & act  # structural padding mask ∧ filter bits
    safe = jnp.where(mask & (dst < jnp.int32(n)), dst, 0)
    if batched:
        # one compressed tile, B query columns: gather fans the decoded
        # targets across the batch; the delta stream was read exactly once
        xv = jnp.take(x, safe.reshape(-1), axis=1).reshape(
            x.shape[0], *safe.shape
        )                         # (B, TB, FB)
        if w_ref is not None:
            xv = xv * w_ref[...][None]
        contrib = jnp.where(mask[None], xv, jnp.zeros((), x.dtype))
        out_ref[...] = jnp.sum(contrib, axis=2).T  # (TB, B)
        return
    xv = x[safe]                  # gather from VMEM-resident vertex state
    if w_ref is not None:
        # weights don't delta-compress (§5.1.3): they stream uncompressed as
        # a (TB, FB) tile aligned slot-for-slot with the decoded targets
        xv = xv * w_ref[...]
    contrib = jnp.where(mask, xv, jnp.zeros((), x.dtype))
    out_ref[...] = jnp.sum(contrib, axis=1)


@functools.partial(jax.jit, static_argnames=("n", "tile_blocks", "interpret"))
def compressed_block_spmv_pallas(
    x: jnp.ndarray,            # (n_pad,) vertex values, or (B, n_pad) batch
    block_first: jnp.ndarray,  # (NB,) int32
    deltas: jnp.ndarray,       # (NB, FB) uint16
    valid_count: jnp.ndarray,  # (NB,) uint16/int32 — real slots per block
    bits: jnp.ndarray,         # (NB, FB//32) uint32
    edge_active: jnp.ndarray | None = None,    # (NB, FB//32) uint32, packed
    block_weights: jnp.ndarray | None = None,  # (NB, FB) f32, uncompressed
    *,
    n: int,
    tile_blocks: int = DEFAULT_TILE_BLOCKS,
    interpret: bool | None = None,
) -> jnp.ndarray:
    """Per-block partial sums off the compressed stream:
    out[b] = Σ_slot active(b,slot)·w(b,slot)·x[decode(b)[slot]].

    ``edge_active`` (optional) is the packed per-call traversal mask, one
    uint32 word per 32 edge slots in the same block-aligned layout as the
    graphFilter ``bits``; it streams as its own (TB, F_B/32) tile and is
    ANDed into the validity mask in-kernel.  ``block_weights`` (optional) is
    the parallel *uncompressed* weight stream: weights don't
    difference-encode, so they ride as a plain (TB, FB) VMEM tile per
    program, aligned slot-for-slot with the decoded targets.  Blocks
    containing ESCAPE deltas decode wrong here and must be patched by the
    caller (ops.compressed_spmv_vertex does this).

    Batched queries: ``x`` of shape (B, n_pad) returns (NB, B) — each grid
    step streams the compressed tile and decodes it once, then applies it
    to all B columns.

    ``interpret=None`` (the default) resolves the Pallas lowering per
    backend — native Mosaic on TPU, interpret mode elsewhere
    (:mod:`repro.kernels.lowering`).
    """
    interpret = resolve_interpret(interpret)
    batched = x.ndim == 2
    NB, FB = deltas.shape
    vc = valid_count.astype(jnp.int32)
    TB = min(tile_blocks, NB)
    pad = (-NB) % TB
    if pad:
        block_first = jnp.pad(block_first, (0, pad), constant_values=n)
        deltas = jnp.pad(deltas, ((0, pad), (0, 0)))
        vc = jnp.pad(vc, (0, pad))
        bits = jnp.pad(bits, ((0, pad), (0, 0)))
        if edge_active is not None:
            edge_active = jnp.pad(edge_active, ((0, pad), (0, 0)))
        if block_weights is not None:
            block_weights = jnp.pad(block_weights, ((0, pad), (0, 0)))
    nb_pad = NB + pad
    grid = (nb_pad // TB,)
    W = FB // 32

    x_spec = (
        pl.BlockSpec(x.shape, lambda i: (0, 0))       # (B, n_pad) resident
        if batched
        else pl.BlockSpec((x.shape[0],), lambda i: (0,))  # x stays resident
    )
    in_specs = [
        x_spec,
        pl.BlockSpec((TB,), lambda i: (i,)),          # compressed stream:
        pl.BlockSpec((TB, FB), lambda i: (i, 0)),     #   first + deltas
        pl.BlockSpec((TB,), lambda i: (i,)),          #   + valid counts
        pl.BlockSpec((TB, W), lambda i: (i, 0)),
    ]
    operands = [x, block_first, deltas, vc, bits]
    if edge_active is not None:
        in_specs.append(pl.BlockSpec((TB, W), lambda i: (i, 0)))
        operands.append(edge_active)
    if block_weights is not None:
        in_specs.append(pl.BlockSpec((TB, FB), lambda i: (i, 0)))
        operands.append(block_weights)

    if batched:
        out_specs = pl.BlockSpec((TB, x.shape[0]), lambda i: (i, 0))
        out_shape = jax.ShapeDtypeStruct((nb_pad, x.shape[0]), x.dtype)
    else:
        out_specs = pl.BlockSpec((TB,), lambda i: (i,))
        out_shape = jax.ShapeDtypeStruct((nb_pad,), x.dtype)

    out = pl.pallas_call(
        functools.partial(
            _kernel,
            n=n,
            has_active=edge_active is not None,
            has_weights=block_weights is not None,
            batched=batched,
        ),
        grid=grid,
        in_specs=in_specs,
        out_specs=out_specs,
        out_shape=out_shape,
        interpret=interpret,
    )(*operands)
    return out[:NB]


def _chunked_kernel(
    ids_ref,
    *refs,
    n: int,
    emit: str,
    has_x: bool,
    has_bits: bool,
    has_active: bool,
    has_weights: bool,
    batched: bool,
):
    """One live block per program.  ``ids_ref`` is the scalar-prefetched
    compacted block-id list — the BlockSpec index_maps have already steered
    this program's delta/bitmask/weight tiles to row ``ids[i]``, so the body
    is the same fused decode as ``_kernel``, minus any knowledge of NB."""
    del ids_ref  # consumed entirely by the index_maps
    refs = list(refs)
    x_ref = refs.pop(0) if has_x else None
    first_ref = refs.pop(0)
    deltas_ref = refs.pop(0)
    vc_ref = refs.pop(0)
    bits_ref = refs.pop(0) if has_bits else None
    act_ref = refs.pop(0) if has_active else None
    w_ref = refs.pop(0) if has_weights else None

    first = first_ref[...]        # (1,)    int32
    deltas = deltas_ref[...]      # (1, FB) uint16 — ONE live block's tile
    vc = vc_ref[...]              # (1,)    int32

    d = deltas.astype(jnp.int32)
    lane = jax.lax.broadcasted_iota(jnp.int32, d.shape, 1)
    d = jnp.where(lane == 0, 0, d)
    dst = first[:, None] + jnp.cumsum(d, axis=1)

    mask = lane < vc[:, None]
    if bits_ref is not None:
        mask = mask & unpack_word_bits(bits_ref[...])
    if act_ref is not None:
        mask = mask & unpack_word_bits(act_ref[...])

    if emit == "decode":
        dst_out_ref, w_out_ref = refs
        dst_out_ref[...] = jnp.where(mask & (dst < jnp.int32(n)), dst, jnp.int32(n))
        w_out_ref[...] = (
            w_ref[...] if w_ref is not None else jnp.ones(deltas.shape, jnp.float32)
        )
        return

    out_ref = refs[-1]
    x = x_ref[...]
    safe = jnp.where(mask & (dst < jnp.int32(n)), dst, 0)
    if batched:
        xv = jnp.take(x, safe.reshape(-1), axis=1).reshape(
            x.shape[0], *safe.shape
        )                         # (B, 1, FB)
        if w_ref is not None:
            xv = xv * w_ref[...][None]
        contrib = jnp.where(mask[None], xv, jnp.zeros((), x.dtype))
        out_ref[...] = jnp.sum(contrib, axis=2).T  # (1, B)
        return
    xv = x[safe]
    if w_ref is not None:
        xv = xv * w_ref[...]
    contrib = jnp.where(mask, xv, jnp.zeros((), x.dtype))
    out_ref[...] = jnp.sum(contrib, axis=1)


@functools.partial(
    jax.jit,
    static_argnames=("n", "emit", "interpret", "gather_tiles", "tile_blocks"),
)
def compressed_chunked_spmv_pallas(
    x: jnp.ndarray | None,         # (n_pad,) / (B, n_pad) for "sums"; None for "decode"
    ids: jnp.ndarray,              # (C,) int32 — compacted live block ids (pad: >= NB)
    block_first: jnp.ndarray,      # (NB,) int32
    deltas: jnp.ndarray,           # (NB, FB) uint16
    valid_count: jnp.ndarray,      # (NB,) uint16/int32
    bits: jnp.ndarray | None = None,          # (NB, FB//32) uint32 graphFilter
    edge_active: jnp.ndarray | None = None,   # (NB, FB//32) uint32 traversal mask
    block_weights: jnp.ndarray | None = None,  # (NB, FB) f32, uncompressed
    *,
    n: int,
    emit: str = "sums",
    interpret: bool | None = None,
    gather_tiles: bool = True,
    tile_blocks: int = DEFAULT_TILE_BLOCKS,
):
    """Frontier-sparse chunked mode: stream ONLY the blocks named by ``ids``.

    The grid is one program per entry of ``ids`` (one chunk of a compacted
    live-block list, ``compact_mask`` of the frontier-owned blocks) under a
    ``pltpu.PrefetchScalarGridSpec``: ``ids`` is the scalar-prefetched
    operand and every edge-side BlockSpec indexes through it
    (``lambda i, ids: (ids[i], 0)``), so the delta / bitmask / weight tiles
    of dead blocks are never moved HBM→VMEM.  Ids ≥ NB (the ``compact_mask``
    fill of the last chunk's pad) are clamped onto an all-sentinel row
    appended behind the real blocks: ``valid_count`` 0, first target ``n`` —
    it decodes to nothing, in either emit mode.

    ``emit``:

    * ``"sums"``   — per-live-block partial SpMV sums, ``(C,)`` (or ``(C, B)``
      when ``x`` is a ``(B, n_pad)`` query batch: the tile streams and
      decodes once, the gather fans across B — the serving amortization,
      chunked).
    * ``"decode"`` — the chunk pool of EDGEMAPCHUNKED: masked decoded
      targets ``(C, FB)`` int32 (inactive slots = sentinel ``n``) plus the
      aligned weight tile ``(C, FB)`` f32.  This is the variant the core
      ``edgemap_chunked`` streamed path consumes — decode in-kernel, monoid
      scatter outside, peak intermediate C × F_B small-memory words.

    Exception blocks (ESCAPE deltas) decode wrong here, exactly as in the
    dense-grid kernel; the wrapper patches them keyed on the gathered ids
    (``ops._patch_exception_tile`` / the per-block sum fixup).

    Tiling (``gather_tiles``, the default): BlockSpec index_maps are
    block-granular, so the id-steered grid above can only fetch ``(1, FB)``
    rows — DMA-granularity-pessimal.  The tiled mode instead pre-gathers
    the live rows (an XLA gather of exactly the ``ids`` rows — the NVRAM
    reads are unchanged) into contiguous ``(C, FB)`` buffers and runs a
    plain ``(ceil(C/TB),)`` grid of ``(TB, FB)`` tiles: each HBM→VMEM
    transfer is TB rows wide and the grid pipeline double-buffers tile
    ``i+1``'s DMA against tile ``i``'s decode.  ``gather_tiles=False``
    keeps the row-steered ``PrefetchScalarGridSpec`` grid (the
    microbenchmark baseline).  Emit shapes are identical either way.

    ``interpret=None`` resolves the lowering per backend — native Mosaic
    on TPU, interpret elsewhere (:mod:`repro.kernels.lowering`).
    """
    if emit not in ("sums", "decode"):
        raise ValueError(f"emit must be 'sums' or 'decode', got {emit!r}")
    interpret = resolve_interpret(interpret)
    NB, FB = deltas.shape
    C = ids.shape[0]
    W = FB // 32
    batched = emit == "sums" and x.ndim == 2

    # the all-sentinel row: out-of-range ids (chunk pad) land here and
    # decode to nothing (valid_count 0; first target = n for belt-and-braces)
    first_s = jnp.pad(block_first, (0, 1), constant_values=n)
    deltas_s = jnp.pad(deltas, ((0, 1), (0, 0)))
    vc_s = jnp.pad(valid_count.astype(jnp.int32), (0, 1))
    ids = jnp.minimum(ids.astype(jnp.int32), jnp.int32(NB))

    if gather_tiles:
        return _chunked_tiled_call(
            x, ids, first_s, deltas_s, vc_s, bits, edge_active, block_weights,
            n=n, emit=emit, batched=batched, C=C, NB=NB, FB=FB, W=W,
            tile_blocks=tile_blocks, interpret=interpret,
        )

    in_specs = []
    operands = []
    if emit == "sums":
        in_specs.append(
            pl.BlockSpec(x.shape, lambda i, ids: (0, 0))
            if batched
            else pl.BlockSpec((x.shape[0],), lambda i, ids: (0,))
        )
        operands.append(x)
    in_specs += [
        pl.BlockSpec((1,), lambda i, ids: (ids[i],)),       # first targets
        pl.BlockSpec((1, FB), lambda i, ids: (ids[i], 0)),  # delta stream
        pl.BlockSpec((1,), lambda i, ids: (ids[i],)),       # valid counts
    ]
    operands += [first_s, deltas_s, vc_s]
    if bits is not None:
        in_specs.append(pl.BlockSpec((1, W), lambda i, ids: (ids[i], 0)))
        operands.append(jnp.pad(bits, ((0, 1), (0, 0))))
    if edge_active is not None:
        in_specs.append(pl.BlockSpec((1, W), lambda i, ids: (ids[i], 0)))
        operands.append(jnp.pad(edge_active, ((0, 1), (0, 0))))
    if block_weights is not None:
        in_specs.append(pl.BlockSpec((1, FB), lambda i, ids: (ids[i], 0)))
        operands.append(jnp.pad(block_weights, ((0, 1), (0, 0))))

    if emit == "decode":
        out_specs = (
            pl.BlockSpec((1, FB), lambda i, ids: (i, 0)),
            pl.BlockSpec((1, FB), lambda i, ids: (i, 0)),
        )
        out_shape = (
            jax.ShapeDtypeStruct((C, FB), jnp.int32),
            jax.ShapeDtypeStruct((C, FB), jnp.float32),
        )
    elif batched:
        out_specs = pl.BlockSpec((1, x.shape[0]), lambda i, ids: (i, 0))
        out_shape = jax.ShapeDtypeStruct((C, x.shape[0]), x.dtype)
    else:
        out_specs = pl.BlockSpec((1,), lambda i, ids: (i,))
        out_shape = jax.ShapeDtypeStruct((C,), x.dtype)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(C,),
        in_specs=in_specs,
        out_specs=out_specs,
    )
    return pl.pallas_call(
        functools.partial(
            _chunked_kernel,
            n=n,
            emit=emit,
            has_x=emit == "sums",
            has_bits=bits is not None,
            has_active=edge_active is not None,
            has_weights=block_weights is not None,
            batched=batched,
        ),
        grid_spec=grid_spec,
        out_shape=out_shape,
        interpret=interpret,
    )(ids, *operands)


def _chunked_tiled_call(
    x, ids, first_s, deltas_s, vc_s, bits, edge_active, block_weights,
    *, n, emit, batched, C, NB, FB, W, tile_blocks, interpret,
):
    """The ``gather_tiles`` grid: pre-gathered live rows, (TB, FB) tiles.

    ``ids`` is already clamped onto the all-sentinel row (index NB), so the
    pad extending C to a TB multiple just names more sentinel rows — they
    decode to nothing.  The gather reads exactly the live rows (+ sentinel)
    out of the compressed arrays; the kernel then walks contiguous (TB, FB)
    tiles, so every HBM→VMEM transfer is DMA-sized and the grid pipeline
    overlaps tile i+1's fetch with tile i's decode.
    """
    TB = max(1, min(tile_blocks, C))
    pad = (-C) % TB
    if pad:
        ids = jnp.pad(ids, (0, pad), constant_values=NB)
    c_pad = C + pad

    first_g = jnp.take(first_s, ids)               # (C_pad,)
    deltas_g = jnp.take(deltas_s, ids, axis=0)     # (C_pad, FB) — live rows only
    vc_g = jnp.take(vc_s, ids)

    in_specs = []
    operands = []
    if emit == "sums":
        in_specs.append(
            pl.BlockSpec(x.shape, lambda i: (0, 0))
            if batched
            else pl.BlockSpec((x.shape[0],), lambda i: (0,))
        )
        operands.append(x)
    in_specs += [
        pl.BlockSpec((TB,), lambda i: (i,)),
        pl.BlockSpec((TB, FB), lambda i: (i, 0)),
        pl.BlockSpec((TB,), lambda i: (i,)),
    ]
    operands += [first_g, deltas_g, vc_g]
    if bits is not None:
        in_specs.append(pl.BlockSpec((TB, W), lambda i: (i, 0)))
        operands.append(jnp.take(jnp.pad(bits, ((0, 1), (0, 0))), ids, axis=0))
    if edge_active is not None:
        in_specs.append(pl.BlockSpec((TB, W), lambda i: (i, 0)))
        operands.append(
            jnp.take(jnp.pad(edge_active, ((0, 1), (0, 0))), ids, axis=0)
        )
    if block_weights is not None:
        in_specs.append(pl.BlockSpec((TB, FB), lambda i: (i, 0)))
        operands.append(
            jnp.take(jnp.pad(block_weights, ((0, 1), (0, 0))), ids, axis=0)
        )

    if emit == "decode":
        out_specs = (
            pl.BlockSpec((TB, FB), lambda i: (i, 0)),
            pl.BlockSpec((TB, FB), lambda i: (i, 0)),
        )
        out_shape = (
            jax.ShapeDtypeStruct((c_pad, FB), jnp.int32),
            jax.ShapeDtypeStruct((c_pad, FB), jnp.float32),
        )
    elif batched:
        out_specs = pl.BlockSpec((TB, x.shape[0]), lambda i: (i, 0))
        out_shape = jax.ShapeDtypeStruct((c_pad, x.shape[0]), x.dtype)
    else:
        out_specs = pl.BlockSpec((TB,), lambda i: (i,))
        out_shape = jax.ShapeDtypeStruct((c_pad,), x.dtype)

    out = pl.pallas_call(
        functools.partial(
            _chunked_kernel,
            None,  # no scalar-prefetch operand on the plain grid
            n=n,
            emit=emit,
            has_x=emit == "sums",
            has_bits=bits is not None,
            has_active=edge_active is not None,
            has_weights=block_weights is not None,
            batched=batched,
        ),
        grid=(c_pad // TB,),
        in_specs=in_specs,
        out_specs=out_specs,
        out_shape=out_shape,
        interpret=interpret,
    )(*operands)
    if emit == "decode":
        return out[0][:C], out[1][:C]
    return out[:C]
