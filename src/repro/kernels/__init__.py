"""Pallas TPU kernels for the PSAM engine's compute hot-spots.

Each kernel directory contains:
  <name>.py — pl.pallas_call + explicit BlockSpec VMEM tiling
  ops.py    — jit'd public wrapper
  ref.py    — pure-jnp oracle (tests assert allclose against it)
"""
from .compressed_spmv import compressed_block_spmv, compressed_spmv_vertex
from .decode_attention import decode_attention
from .edge_block_spmv import edge_block_spmv, spmv_vertex
from .embedding_bag import embedding_bag
from .filter_pack import filter_pack

__all__ = [
    "edge_block_spmv",
    "spmv_vertex",
    "compressed_block_spmv",
    "compressed_spmv_vertex",
    "embedding_bag",
    "filter_pack",
    "decode_attention",
]
