"""Pallas TPU kernels for the PSAM engine's compute hot-spots.

Each kernel directory contains:
  <name>.py — pl.pallas_call + explicit BlockSpec VMEM tiling
  ops.py    — jit'd public wrapper
  ref.py    — pure-jnp oracle (tests assert allclose against it)
"""
from .compressed_spmv import (
    compressed_block_spmv,
    compressed_chunked_stream_tile,
    compressed_spmv_vertex,
    compressed_spmv_vertex_batched,
    compressed_spmv_vertex_chunked,
)
from .decode_attention import decode_attention
from .edge_block_spmv import edge_block_spmv, spmv_vertex, spmv_vertex_batched
from .embedding_bag import embedding_bag
from .filter_pack import filter_pack

__all__ = [
    "edge_block_spmv",
    "spmv_vertex",
    "spmv_vertex_batched",
    "compressed_block_spmv",
    "compressed_chunked_stream_tile",
    "compressed_spmv_vertex",
    "compressed_spmv_vertex_batched",
    "compressed_spmv_vertex_chunked",
    "embedding_bag",
    "filter_pack",
    "decode_attention",
]
