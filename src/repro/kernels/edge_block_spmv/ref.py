"""Pure-jnp oracle for the edge_block_spmv kernel."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def edge_block_spmv_ref(x, block_dst, block_w, bits, *, n: int):
    """Per-block partial sums, computed with plain jnp ops."""
    NB, FB = block_dst.shape
    shifts = jnp.arange(32, dtype=jnp.uint32)
    act = ((bits[:, :, None] >> shifts[None, None, :]) & jnp.uint32(1)) != 0
    act = act.reshape(NB, FB)
    mask = (block_dst < jnp.int32(n)) & act
    safe = jnp.where(mask, block_dst, 0)
    xv = jnp.take(x, safe.reshape(-1), axis=0).reshape(NB, FB)
    contrib = jnp.where(mask, xv * block_w, jnp.zeros((), x.dtype))
    return jnp.sum(contrib, axis=1)


def spmv_vertex_ref(x, block_dst, block_w, bits, block_src, *, n: int):
    per_block = edge_block_spmv_ref(x, block_dst, block_w, bits, n=n)
    return jax.ops.segment_sum(per_block, block_src, num_segments=n + 1)[:n]
