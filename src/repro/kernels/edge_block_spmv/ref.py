"""Pure-jnp oracle for the edge_block_spmv kernel."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from ...core.graph_filter import unpack_word_bits


def edge_block_spmv_ref(x, block_dst, block_w, bits, edge_active=None, *, n: int):
    """Per-block partial sums, computed with plain jnp ops.

    ``edge_active``: optional packed uint32 (NB, F_B/32) traversal mask,
    ANDed with the graphFilter ``bits`` exactly as the kernel does.
    Batched queries (x of shape (B, n_pad)) return (NB, B), mirroring the
    kernel's one-tile-load-per-batch contract."""
    NB, FB = block_dst.shape
    act = unpack_word_bits(bits)
    if edge_active is not None:
        act = act & unpack_word_bits(edge_active)
    mask = (block_dst < jnp.int32(n)) & act
    safe = jnp.where(mask, block_dst, 0)
    if x.ndim == 2:
        xv = jnp.take(x, safe.reshape(-1), axis=1).reshape(x.shape[0], NB, FB)
        contrib = jnp.where(mask[None], xv * block_w[None], jnp.zeros((), x.dtype))
        return jnp.sum(contrib, axis=2).T
    xv = jnp.take(x, safe.reshape(-1), axis=0).reshape(NB, FB)
    contrib = jnp.where(mask, xv * block_w, jnp.zeros((), x.dtype))
    return jnp.sum(contrib, axis=1)


def spmv_vertex_ref(x, block_dst, block_w, bits, block_src, edge_active=None, *, n: int):
    per_block = edge_block_spmv_ref(x, block_dst, block_w, bits, edge_active, n=n)
    out = jax.ops.segment_sum(per_block, block_src, num_segments=n + 1)[:n]
    return out.T if x.ndim == 2 else out
