from .ops import edge_block_spmv, spmv_vertex, spmv_vertex_batched
from .ref import edge_block_spmv_ref, spmv_vertex_ref

__all__ = [
    "edge_block_spmv",
    "spmv_vertex",
    "spmv_vertex_batched",
    "edge_block_spmv_ref",
    "spmv_vertex_ref",
]
