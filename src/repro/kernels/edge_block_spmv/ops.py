"""jit'd public wrappers around the edge_block_spmv Pallas kernel."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from ...core.csr import CSRGraph
from ...core.graph_filter import GraphFilter, edge_active_words
from ...tuning.defaults import DEFAULT_TILE_BLOCKS
from .edge_block_spmv import edge_block_spmv_pallas


def edge_block_spmv(
    x,
    block_dst,
    block_w,
    bits,
    edge_active=None,
    *,
    n: int,
    interpret: bool | None = None,
    tile_blocks: int = DEFAULT_TILE_BLOCKS,
):
    """Raw kernel entry: per-block partial sums off the uncompressed stream.

    ``out[b] = Σ_slot active(b,slot) · w(b,slot) · x[dst(b,slot)]`` — the
    array-level form of ``spmv_vertex`` without the owner reduction, for
    callers that hold the block arrays directly (benchmarks, tests).  ``x``
    may be (n_pad,) or a (B, n_pad) query batch (→ out (NB, B));
    ``edge_active`` is the optional packed traversal mask, ANDed in-VMEM.
    """
    return edge_block_spmv_pallas(
        x,
        block_dst,
        block_w,
        bits,
        edge_active,
        n=n,
        interpret=interpret,
        tile_blocks=tile_blocks,
    )


def spmv_vertex(
    g: CSRGraph,
    x: jnp.ndarray,
    f: GraphFilter | None = None,
    *,
    edge_active=None,
    interpret: bool | None = None,
    tile_blocks: int = DEFAULT_TILE_BLOCKS,
) -> jnp.ndarray:
    """out[v] = Σ_{(v,u) active} w_vu · x[u] — PageRank/GNN aggregation step.

    Uses the Pallas kernel for the gather-heavy per-block sums, then a cheap
    O(#blocks) segment reduction by block owner.  ``edge_active`` is the
    per-call traversal mask (GraphFilter | packed uint32 words | bool slot
    mask); it streams into the kernel as a second packed bitmask tile.
    """
    if f is not None:
        bits = f.bits
    else:
        # all valid edges active
        from ...core.graph_filter import make_filter

        bits = make_filter(g).bits
    active = (
        None
        if edge_active is None
        else edge_active_words(edge_active, g.block_size)
    )
    per_block = edge_block_spmv_pallas(
        x,
        g.block_dst,
        g.block_w,
        bits,
        active,
        n=g.n,
        interpret=interpret,
        tile_blocks=tile_blocks,
    )
    return jax.ops.segment_sum(per_block, g.block_src, num_segments=g.n + 1)[: g.n]


def spmv_vertex_batched(
    g: CSRGraph,
    xb: jnp.ndarray,
    f: GraphFilter | None = None,
    *,
    edge_active=None,
    interpret: bool | None = None,
    tile_blocks: int = DEFAULT_TILE_BLOCKS,
) -> jnp.ndarray:
    """Batched ``spmv_vertex``: ``xb`` is (B, n); returns (B, n).

    One edge sweep serves all B queries — the kernel streams each edge-block
    tile (and its packed masks) into VMEM once and applies it against the B
    vertex-state columns, so the NVRAM-modeled edge-byte reads amortize ÷B
    (see ``PSAMCost.charge_edgemap_batched``)."""
    if f is not None:
        bits = f.bits
    else:
        from ...core.graph_filter import make_filter

        bits = make_filter(g).bits
    active = (
        None
        if edge_active is None
        else edge_active_words(edge_active, g.block_size)
    )
    per_block = edge_block_spmv_pallas(
        xb,
        g.block_dst,
        g.block_w,
        bits,
        active,
        n=g.n,
        interpret=interpret,
        tile_blocks=tile_blocks,
    )  # (NB, B)
    return jax.ops.segment_sum(per_block, g.block_src, num_segments=g.n + 1)[: g.n].T
