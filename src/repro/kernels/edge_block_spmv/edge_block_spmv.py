"""Pallas TPU kernel: blocked-CSR masked SpMV — the dense edgeMap hot loop.

PSAM → TPU mapping: the vertex state ``x`` (small memory) is VMEM-resident
across the whole grid; the edge blocks (large memory) are streamed
HBM→VMEM tile by tile and *never written*.  The graphFilter bits ride along
as one uint32 word per 32 edges and are unpacked with vector shifts —
the TPU-idiomatic equivalent of the paper's TZCNT/BLSR word loop (§4.2.3).
Filtered traversals stream a second packed bitmask (``edge_active``, the
per-call traversal mask) as its own aligned (TB, F_B/32) tile; both masks
are ANDed into the validity mask in-kernel, so no combined mask is ever
materialized in HBM.

Grid: one program per tile of TB edge-blocks.  Each program produces the
per-block partial sums; the (cheap, O(#blocks)) reduction onto vertices by
``block_src`` happens outside the kernel (see ops.py) — scatter-free kernel
bodies keep the MXU/VPU pipeline free of serializing accumulations.

Query batching (the serving subsystem's amortization lever): ``x`` may carry
a leading query dimension, ``(B, n_pad)``.  The edge tile, its weights and
both packed bitmasks are loaded into VMEM **once per grid step** and applied
against all ``B`` vertex-state columns, so the NVRAM-modeled edge-byte reads
are paid once per sweep instead of once per query; only the O(B·n) vertex
state (PSAM small memory) scales with the batch.  Output grows a trailing
query axis: ``(NB, B)``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from ...core.graph_filter import unpack_word_bits
from ...tuning.defaults import DEFAULT_TILE_BLOCKS  # TB: edge-blocks per program
from ..lowering import resolve_interpret


def _kernel(
    x_ref, dst_ref, w_ref, bits_ref, *rest, n: int, has_active: bool, batched: bool
):
    refs = list(rest)
    out_ref = refs.pop()
    dst = dst_ref[...]            # (TB, FB) int32 — streamed edge block tile
    w = w_ref[...]                # (TB, FB)
    x = x_ref[...]                # (n_pad,) or (B, n_pad) — PSAM small memory
    bits = bits_ref[...]          # (TB, FB//32) uint32 — graphFilter view

    act = unpack_word_bits(bits)  # (TB, FB) bool, canonical graphFilter order
    if has_active:
        act = act & unpack_word_bits(refs[0][...])  # traversal mask, in VMEM

    mask = (dst < jnp.int32(n)) & act
    safe = jnp.where(mask, dst, 0)
    if batched:
        # one edge tile, B query columns: the gather fans the (TB, FB) tile
        # out across the batch while the tile itself is loaded exactly once
        xv = jnp.take(x, safe.reshape(-1), axis=1).reshape(
            x.shape[0], *safe.shape
        )                         # (B, TB, FB)
        contrib = jnp.where(mask[None], xv * w[None], jnp.zeros((), x.dtype))
        out_ref[...] = jnp.sum(contrib, axis=2).T  # (TB, B)
    else:
        xv = x[safe]              # gather from VMEM-resident vertex state
        contrib = jnp.where(mask, xv * w, jnp.zeros((), x.dtype))
        out_ref[...] = jnp.sum(contrib, axis=1)


@functools.partial(
    jax.jit, static_argnames=("n", "tile_blocks", "interpret")
)
def edge_block_spmv_pallas(
    x: jnp.ndarray,        # (n_pad,) vertex values, or (B, n_pad) query batch
    block_dst: jnp.ndarray,  # (NB, FB) int32
    block_w: jnp.ndarray,    # (NB, FB)
    bits: jnp.ndarray,       # (NB, FB//32) uint32
    edge_active: jnp.ndarray | None = None,  # (NB, FB//32) uint32, packed
    *,
    n: int,
    tile_blocks: int = DEFAULT_TILE_BLOCKS,
    interpret: bool | None = None,
) -> jnp.ndarray:
    """Per-block partial sums: out[b] = Σ_slot active(b,slot)·w·x[dst].

    ``edge_active`` (optional) is the packed per-call traversal mask in the
    same block-aligned uint32 layout as the graphFilter ``bits``; it streams
    as its own (TB, F_B/32) tile and is ANDed in-kernel.

    Batched queries: ``x`` of shape (B, n_pad) returns (NB, B) — each grid
    step streams the edge tile once and applies it to all B columns.

    ``interpret=None`` (the default) resolves the lowering per backend —
    native Mosaic on TPU, interpret mode elsewhere."""
    interpret = resolve_interpret(interpret)
    batched = x.ndim == 2
    NB, FB = block_dst.shape
    TB = min(tile_blocks, NB)
    pad = (-NB) % TB
    if pad:
        block_dst = jnp.pad(block_dst, ((0, pad), (0, 0)), constant_values=n)
        block_w = jnp.pad(block_w, ((0, pad), (0, 0)))
        bits = jnp.pad(bits, ((0, pad), (0, 0)))
        if edge_active is not None:
            edge_active = jnp.pad(edge_active, ((0, pad), (0, 0)))
    nb_pad = NB + pad
    grid = (nb_pad // TB,)
    W = FB // 32

    x_spec = (
        pl.BlockSpec(x.shape, lambda i: (0, 0))            # (B, n_pad) resident
        if batched
        else pl.BlockSpec((x.shape[0],), lambda i: (0,))   # x stays resident
    )
    in_specs = [
        x_spec,
        pl.BlockSpec((TB, FB), lambda i: (i, 0)),           # edge tile stream
        pl.BlockSpec((TB, FB), lambda i: (i, 0)),
        pl.BlockSpec((TB, W), lambda i: (i, 0)),
    ]
    operands = [x, block_dst, block_w, bits]
    if edge_active is not None:
        in_specs.append(pl.BlockSpec((TB, W), lambda i: (i, 0)))
        operands.append(edge_active)

    if batched:
        out_specs = pl.BlockSpec((TB, x.shape[0]), lambda i: (i, 0))
        out_shape = jax.ShapeDtypeStruct((nb_pad, x.shape[0]), x.dtype)
    else:
        out_specs = pl.BlockSpec((TB,), lambda i: (i,))
        out_shape = jax.ShapeDtypeStruct((nb_pad,), x.dtype)

    out = pl.pallas_call(
        functools.partial(
            _kernel, n=n, has_active=edge_active is not None, batched=batched
        ),
        grid=grid,
        in_specs=in_specs,
        out_specs=out_specs,
        out_shape=out_shape,
        interpret=interpret,
    )(*operands)
    return out[:NB]
