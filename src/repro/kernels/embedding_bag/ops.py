"""jit'd public wrapper for the EmbeddingBag kernel."""
from __future__ import annotations

import jax.numpy as jnp

from .embedding_bag import embedding_bag_pallas


def embedding_bag(
    table: jnp.ndarray,
    indices: jnp.ndarray,
    weights: jnp.ndarray | None = None,
    *,
    mode: str = "sum",
    interpret: bool | None = None,
    tile_batch: int = 64,
) -> jnp.ndarray:
    """EmbeddingBag with sum/mean modes over fixed-width (-1 padded) bags."""
    if weights is None:
        weights = jnp.ones(indices.shape, table.dtype)
    out = embedding_bag_pallas(
        table, indices, weights, interpret=interpret, tile_batch=tile_batch
    )
    if mode == "mean":
        cnt = jnp.sum((indices >= 0).astype(table.dtype), axis=1, keepdims=True)
        out = out / jnp.maximum(cnt, 1)
    elif mode != "sum":
        raise ValueError(mode)
    return out
