"""Pallas TPU kernel: EmbeddingBag (gather + weighted segment-sum).

JAX has no native EmbeddingBag; this is the recsys hot path (SASRec item
lookups, retrieval scoring).  The kernel tiles the *batch* dimension; the
embedding-table shard stays VMEM-resident across the grid (it is the
read-mostly "large" operand — at pod scale each device holds a row shard
and this kernel runs on the local shard, see distributed/shardings.py).

Bags are fixed-width (L slots) with -1 padding — the static-shape analogue
of torch's ragged offsets, produced by the data pipeline.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from ..lowering import resolve_interpret

DEFAULT_TILE_BATCH = 64


def _kernel(table_ref, idx_ref, wgt_ref, out_ref, *, V: int):
    idx = idx_ref[...]            # (TB, L) int32, -1 padding
    wgt = wgt_ref[...]            # (TB, L)
    table = table_ref[...]        # (V, D) — resident shard

    valid = (idx >= 0) & (idx < jnp.int32(V))
    safe = jnp.where(valid, idx, 0)
    vecs = table[safe]            # (TB, L, D) gather
    w = jnp.where(valid, wgt, jnp.zeros((), wgt.dtype))
    out_ref[...] = jnp.sum(vecs * w[..., None].astype(vecs.dtype), axis=1)


@functools.partial(jax.jit, static_argnames=("tile_batch", "interpret"))
def embedding_bag_pallas(
    table: jnp.ndarray,   # (V, D)
    indices: jnp.ndarray,  # (B, L) int32, -1 = empty slot
    weights: jnp.ndarray,  # (B, L)
    *,
    tile_batch: int = DEFAULT_TILE_BATCH,
    interpret: bool | None = None,
) -> jnp.ndarray:
    """Returns (B, D) weighted bag sums."""
    interpret = resolve_interpret(interpret)
    V, D = table.shape
    B, L = indices.shape
    TB = min(tile_batch, B)
    pad = (-B) % TB
    if pad:
        indices = jnp.pad(indices, ((0, pad), (0, 0)), constant_values=-1)
        weights = jnp.pad(weights, ((0, pad), (0, 0)))
    b_pad = B + pad
    grid = (b_pad // TB,)

    out = pl.pallas_call(
        functools.partial(_kernel, V=V),
        grid=grid,
        in_specs=[
            pl.BlockSpec((V, D), lambda i: (0, 0)),   # table resident
            pl.BlockSpec((TB, L), lambda i: (i, 0)),
            pl.BlockSpec((TB, L), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((TB, D), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((b_pad, D), table.dtype),
        interpret=interpret,
    )(table, indices, weights)
    return out[:B]
