"""Pure-jnp oracle for embedding_bag: take + masked weighted sum."""
from __future__ import annotations

import jax.numpy as jnp


def embedding_bag_ref(table, indices, weights):
    V, D = table.shape
    valid = (indices >= 0) & (indices < V)
    safe = jnp.where(valid, indices, 0)
    vecs = jnp.take(table, safe.reshape(-1), axis=0).reshape(*indices.shape, D)
    w = jnp.where(valid, weights, jnp.zeros((), weights.dtype))
    return jnp.sum(vecs * w[..., None].astype(vecs.dtype), axis=1)
