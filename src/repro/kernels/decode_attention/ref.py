"""Pure-jnp oracle for decode attention."""
from __future__ import annotations

import jax.numpy as jnp


def decode_attention_ref(q, k, v, pos):
    B, H, D = q.shape
    S = k.shape[1]
    scale = 1.0 / jnp.sqrt(D).astype(jnp.float32)
    s = jnp.einsum(
        "bhd,bshd->bhs", q.astype(jnp.float32) * scale, k.astype(jnp.float32)
    )
    mask = jnp.arange(S)[None, None, :] < pos[:, None, None]
    s = jnp.where(mask, s, -1e30)
    p = jnp.exp(s - jnp.max(s, axis=-1, keepdims=True))
    p = p / jnp.maximum(jnp.sum(p, axis=-1, keepdims=True), 1e-30)
    return jnp.einsum("bhs,bshd->bhd", p, v.astype(jnp.float32)).astype(q.dtype)
