"""Pallas TPU kernel: single-token decode attention (flash-decoding style).

The decode hot spot: one query token per sequence against a long KV cache.
The kernel tiles the batch across the grid; within a program the cache is
streamed in fixed-size sequence tiles with a running (max, denominator,
accumulator) carry — scores never materialize beyond one (H, T) tile in
VMEM.  The causal/length mask comes from a per-sequence ``pos`` scalar.

PSAM framing: the KV cache is the read-only large structure (written once
per step elsewhere, streamed here); the O(B·H·D) attention state is the
small memory.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl

from ..lowering import resolve_interpret

NEG_INF = -1e30
DEFAULT_TILE_BATCH = 4
DEFAULT_SEQ_TILE = 128


def _kernel(q_ref, k_ref, v_ref, pos_ref, out_ref, *, seq_tile: int):
    q = q_ref[...]            # (TB, H, D)
    pos = pos_ref[...]        # (TB,) int32 — #valid cache entries per seq
    TB, H, D = q.shape
    S = k_ref.shape[1]
    scale = 1.0 / jnp.sqrt(D).astype(jnp.float32)
    qf = q.astype(jnp.float32) * scale

    nt = S // seq_tile

    def body(t, carry):
        m, l, acc = carry
        kt = k_ref[:, pl.dslice(t * seq_tile, seq_tile)]  # (TB, T, H, D)
        vt = v_ref[:, pl.dslice(t * seq_tile, seq_tile)]
        s = jnp.einsum("bhd,bthd->bht", qf, kt.astype(jnp.float32))
        kv_pos = t * seq_tile + jnp.arange(seq_tile)
        mask = kv_pos[None, None, :] < pos[:, None, None]
        s = jnp.where(mask, s, NEG_INF)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m - m_new)
        l = l * corr + jnp.sum(p, axis=-1)
        pv = jnp.einsum("bht,bthd->bhd", p, vt.astype(jnp.float32))
        acc = acc * corr[..., None] + pv
        return m_new, l, acc

    m0 = jnp.full((TB, H), NEG_INF, jnp.float32)
    l0 = jnp.zeros((TB, H), jnp.float32)
    a0 = jnp.zeros((TB, H, D), jnp.float32)
    m, l, acc = lax.fori_loop(0, nt, body, (m0, l0, a0))
    out_ref[...] = (acc / jnp.maximum(l[..., None], 1e-30)).astype(out_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("tile_batch", "seq_tile", "interpret")
)
def decode_attention_pallas(
    q: jnp.ndarray,    # (B, H, D)
    k: jnp.ndarray,    # (B, S, H, D)
    v: jnp.ndarray,    # (B, S, H, D)
    pos: jnp.ndarray,  # (B,) int32 — valid cache length per sequence
    *,
    tile_batch: int = DEFAULT_TILE_BATCH,
    seq_tile: int = DEFAULT_SEQ_TILE,
    interpret: bool | None = None,
) -> jnp.ndarray:
    interpret = resolve_interpret(interpret)
    B, H, D = q.shape
    S = k.shape[1]
    st = min(seq_tile, S)
    pad_s = (-S) % st
    if pad_s:
        k = jnp.pad(k, ((0, 0), (0, pad_s), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad_s), (0, 0), (0, 0)))
    TB = min(tile_batch, B)
    pad_b = (-B) % TB
    if pad_b:
        q = jnp.pad(q, ((0, pad_b), (0, 0), (0, 0)))
        k = jnp.pad(k, ((0, pad_b), (0, 0), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, pad_b), (0, 0), (0, 0), (0, 0)))
        pos = jnp.pad(pos, (0, pad_b), constant_values=1)
    Bp, Sp = B + pad_b, S + pad_s
    grid = (Bp // TB,)

    out = pl.pallas_call(
        functools.partial(_kernel, seq_tile=st),
        grid=grid,
        in_specs=[
            pl.BlockSpec((TB, H, D), lambda i: (i, 0, 0)),
            pl.BlockSpec((TB, Sp, H, D), lambda i: (i, 0, 0, 0)),
            pl.BlockSpec((TB, Sp, H, D), lambda i: (i, 0, 0, 0)),
            pl.BlockSpec((TB,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec((TB, H, D), lambda i: (i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((Bp, H, D), q.dtype),
        interpret=interpret,
    )(q, k, v, pos)
    return out[:B]
