"""jit'd public wrapper for the decode-attention kernel (GQA-aware)."""
from __future__ import annotations

import jax.numpy as jnp

from .decode_attention import decode_attention_pallas


def decode_attention(
    q: jnp.ndarray,    # (B, Hq, D)
    k: jnp.ndarray,    # (B, S, Hkv, D)
    v: jnp.ndarray,
    pos: jnp.ndarray,  # (B,) valid cache lengths
    *,
    interpret: bool | None = None,
    tile_batch: int = 4,
    seq_tile: int = 128,
) -> jnp.ndarray:
    """GQA: q heads grouped onto kv heads by repetition before the kernel."""
    B, Hq, D = q.shape
    Hkv = k.shape[2]
    if Hq != Hkv:
        assert Hq % Hkv == 0
        rep = Hq // Hkv
        k = jnp.repeat(k, rep, axis=2)
        v = jnp.repeat(v, rep, axis=2)
    return decode_attention_pallas(
        q, k, v, pos, tile_batch=tile_batch, seq_tile=seq_tile, interpret=interpret
    )
