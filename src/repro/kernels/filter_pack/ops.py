"""jit'd public wrapper: graphFilter pack through the Pallas kernel."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from ...core.csr import CSRGraph
from ...core.graph_filter import GraphFilter
from .filter_pack import filter_pack_pallas


def filter_pack(
    g: CSRGraph,
    f: GraphFilter,
    subset_mask: jnp.ndarray,
    keep_pred: jnp.ndarray,
    *,
    interpret: bool | None = None,
    tile_blocks: int = 8,
) -> GraphFilter:
    """Kernel-backed equivalent of ``core.graph_filter.pack_vertices``
    (without dirty-bit tracking, which callers that use this path manage
    themselves)."""
    keep = keep_pred.reshape(g.num_blocks, g.block_size)
    subset_blk = jnp.take(subset_mask, g.block_src, mode="fill", fill_value=False)
    new_bits, cnt = filter_pack_pallas(
        f.bits, keep, subset_blk, interpret=interpret, tile_blocks=tile_blocks
    )
    active_deg = jax.ops.segment_sum(cnt, g.block_src, num_segments=g.n + 1)[: g.n]
    return GraphFilter(
        bits=new_bits,
        active_deg=active_deg,
        dirty=f.dirty,
        n=f.n,
        num_blocks=f.num_blocks,
        block_size=f.block_size,
    )
