"""Pallas TPU kernel: graphFilter PackVertex (§4.2.2) — predicate → bit
clear → popcount, one fused pass over the filter blocks.

The paper processes a block word-by-word with TZCNT/BLSR; on TPU the whole
(TB, F_B) tile is handled with vectorized shift/mask arithmetic and a
SWAR popcount — same O(q + k) word-work, lane-parallel.

All writes are to the bitset and the per-block counts (PSAM small memory);
the edge data that the predicate consumed was read-only.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from ...tuning.defaults import DEFAULT_TILE_BLOCKS
from ..lowering import resolve_interpret


def _popcount32(x):
    x = x.astype(jnp.uint32)
    x = x - ((x >> 1) & jnp.uint32(0x55555555))
    x = (x & jnp.uint32(0x33333333)) + ((x >> 2) & jnp.uint32(0x33333333))
    x = (x + (x >> 4)) & jnp.uint32(0x0F0F0F0F)
    return ((x * jnp.uint32(0x01010101)) >> 24).astype(jnp.int32)


def _kernel(bits_ref, keep_ref, subset_ref, bits_out_ref, cnt_ref):
    bits = bits_ref[...]          # (TB, W) uint32
    keep = keep_ref[...]          # (TB, FB) bool
    sub = subset_ref[...]         # (TB,) bool — block owner in the subset
    TB, W = bits.shape
    FB = keep.shape[1]

    # pack the keep predicate into words (vectorized, no per-bit loop)
    k3 = keep.reshape(TB, W, FB // W)
    weights = jnp.uint32(1) << jnp.arange(32, dtype=jnp.uint32)
    keep_words = jnp.sum(
        jnp.where(k3, weights[None, None, :], jnp.uint32(0)),
        axis=-1,
        dtype=jnp.uint32,
    )
    new_bits = jnp.where(sub[:, None], bits & keep_words, bits)
    bits_out_ref[...] = new_bits
    cnt_ref[...] = jnp.sum(_popcount32(new_bits), axis=1)


@functools.partial(jax.jit, static_argnames=("tile_blocks", "interpret"))
def filter_pack_pallas(
    bits: jnp.ndarray,     # (NB, W) uint32
    keep: jnp.ndarray,     # (NB, FB) bool
    subset: jnp.ndarray,   # (NB,) bool
    *,
    tile_blocks: int = DEFAULT_TILE_BLOCKS,
    interpret: bool | None = None,
):
    """Returns (new_bits (NB, W) uint32, active_count (NB,) int32)."""
    interpret = resolve_interpret(interpret)
    NB, W = bits.shape
    FB = keep.shape[1]
    TB = min(tile_blocks, NB)
    pad = (-NB) % TB
    if pad:
        bits = jnp.pad(bits, ((0, pad), (0, 0)))
        keep = jnp.pad(keep, ((0, pad), (0, 0)))
        subset = jnp.pad(subset, (0, pad))
    nb_pad = NB + pad
    grid = (nb_pad // TB,)

    new_bits, cnt = pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((TB, W), lambda i: (i, 0)),
            pl.BlockSpec((TB, FB), lambda i: (i, 0)),
            pl.BlockSpec((TB,), lambda i: (i,)),
        ],
        out_specs=[
            pl.BlockSpec((TB, W), lambda i: (i, 0)),
            pl.BlockSpec((TB,), lambda i: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((nb_pad, W), jnp.uint32),
            jax.ShapeDtypeStruct((nb_pad,), jnp.int32),
        ],
        interpret=interpret,
    )(bits, keep, subset)
    return new_bits[:NB], cnt[:NB]
