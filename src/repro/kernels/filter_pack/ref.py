"""Pure-jnp oracle for the filter_pack kernel."""
from __future__ import annotations

import jax.numpy as jnp

from ...core.primitives import popcount32


def filter_pack_ref(bits, keep, subset):
    NB, W = bits.shape
    FB = keep.shape[1]
    k3 = keep.reshape(NB, W, FB // W)
    weights = jnp.uint32(1) << jnp.arange(32, dtype=jnp.uint32)
    keep_words = jnp.sum(
        jnp.where(k3, weights[None, None, :], jnp.uint32(0)), axis=-1, dtype=jnp.uint32
    )
    new_bits = jnp.where(subset[:, None], bits & keep_words, bits)
    cnt = jnp.sum(popcount32(new_bits), axis=1)
    return new_bits, cnt
