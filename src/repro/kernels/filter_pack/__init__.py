from .ops import filter_pack
from .ref import filter_pack_ref

__all__ = ["filter_pack", "filter_pack_ref"]
