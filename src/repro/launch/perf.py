from . import dryrun  # noqa: F401  — sets XLA_FLAGS=512 devices FIRST

"""§Perf hillclimb driver: compile named optimization variants of the three
chosen (arch × shape) pairs and record their roofline terms next to the
baselines.

    PYTHONPATH=src python -m repro.launch.perf --variant all

Variants (hypothesis → change; before/after lands in EXPERIMENTS.md §Perf):

A. mistral-large-123b × train_4k  (memory-dominated, peak 9.55 GB/dev)
   a_sp       — sequence-parallel residual (LM_RULES_SP): per-layer saved
                activations shard 16× over 'model'
   a_sp_dots  — + remat policy 'dots': save matmul outputs, recompute only
                cheap elementwise ops (trades HBM bytes for fewer flops)
   a_dots     — remat policy alone (ablation)

B. equiformer-v2 × ogb_products  (collective-dominated, 59.6 s)
   b_tp       — GNN_RULES_TP: edges shard (pod,data) only, hidden dim shards
                'model' → node-aggregation all-reduce bytes ÷16, message
                tensors never cross the model axis

C. sage-graph engine  (the paper's own workload; collective-bound rounds)
   c_hier     — hierarchical reduction: reduce-scatter on 'model', psum the
                1/16 shard on 'data'/'pod', all-gather back
   c_hier_bf16— + bf16 vertex state on the wire (graph-engine analogue of
                gradient compression)

D. runnability fix (long_500k peak 24–27 GB > 16 GB HBM)
   d_long_v2  — LM_DECODE_LONG_RULES_V2: cache sharded on head_dim/kv_lora
                instead of cache_seq, so dynamic_update_slice stays local
"""
import argparse
import dataclasses
import json
import os

import jax

from ..compat import use_mesh
from ..configs import all_cells
from ..distributed.shardings import (
    GNN_RULES_TP,
    LM_DECODE_LONG_RULES_V2,
    LM_RULES_SP,
)
from .dryrun import RESULTS_DIR, run_cell, run_graph_engine
from .mesh import make_production_mesh

MESHES = {
    "single_pod_16x16": lambda: make_production_mesh(multi_pod=False),
    "multi_pod_2x16x16": lambda: make_production_mesh(multi_pod=True),
}


def _variant_cell(cell, *, rules=None, shape_suffix="", **cfg_updates):
    cfg = (
        dataclasses.replace(cell.model_cfg, **cfg_updates)
        if cfg_updates
        else cell.model_cfg
    )
    return dataclasses.replace(
        cell,
        model_cfg=cfg,
        rules=rules or cell.rules,
        shape=cell.shape + shape_suffix,
    )


def variants():
    cells = all_cells()
    out = {}
    mt = cells[("mistral-large-123b", "train_4k")]
    out["a_sp"] = _variant_cell(mt, rules=LM_RULES_SP, shape_suffix="+sp")
    out["a_dots"] = _variant_cell(mt, shape_suffix="+dots", remat_policy="dots")
    out["a_sp_dots"] = _variant_cell(
        mt, rules=LM_RULES_SP, shape_suffix="+sp_dots", remat_policy="dots"
    )
    out["a_mp"] = _variant_cell(mt, shape_suffix="+mp", attn_mixed_precision=True)
    out["a_mp_sp"] = _variant_cell(
        mt, rules=LM_RULES_SP, shape_suffix="+mp_sp", attn_mixed_precision=True
    )
    out["a_cbs"] = _variant_cell(mt, shape_suffix="+cbs", attn_causal_skip=True)
    out["a_cbs_mp"] = _variant_cell(
        mt, shape_suffix="+cbs_mp", attn_causal_skip=True,
        attn_mixed_precision=True,
    )
    mp32 = cells[("mistral-large-123b", "prefill_32k")]
    out["a_prefill_cbs_mp"] = _variant_cell(
        mp32, shape_suffix="+cbs_mp", attn_causal_skip=True,
        attn_mixed_precision=True,
    )
    eq = cells[("equiformer-v2", "ogb_products")]
    out["b_tp"] = _variant_cell(eq, rules=GNN_RULES_TP, shape_suffix="+tp")
    out["b_compact"] = _variant_cell(
        eq, shape_suffix="+compact", compact_messages=True
    )
    for arch in ["qwen1.5-4b", "mistral-large-123b"]:
        lc = cells[(arch, "long_500k")]
        out[f"d_long_v2_{arch}"] = _variant_cell(
            lc, rules=LM_DECODE_LONG_RULES_V2, shape_suffix="+v2"
        )
    return out


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--variant", default="all")
    ap.add_argument("--mesh", default="single_pod_16x16", choices=list(MESHES) + ["both"])
    ap.add_argument("--out", default=RESULTS_DIR)
    args = ap.parse_args()

    mesh_names = list(MESHES) if args.mesh == "both" else [args.mesh]
    want = None if args.variant == "all" else set(args.variant.split(","))

    for mesh_name in mesh_names:
        mesh = MESHES[mesh_name]()
        for name, cell in variants().items():
            if want and name not in want:
                continue
            run_cell(cell, mesh, mesh_name, args.out)
        if want is None or "c_hier" in want or "c_hier_bf16" in want:
            _run_engine_variants(mesh, mesh_name, args.out)


def _run_engine_variants(mesh, mesh_name, out_dir):
    import time

    import jax.numpy as jnp

    from ..core.plan import sharded_graph_spec
    from ..distributed.engine import distributed_pagerank_step
    from .dryrun import collective_bytes_from_hlo, cost_dict

    n, NB, FB = 1 << 20, 1 << 18, 128
    S = jax.ShapeDtypeStruct
    specs = (
        sharded_graph_spec(n, NB, FB, int(mesh.devices.size)),
        S((n,), jnp.float32),
        S((n,), jnp.float32),
    )
    for vname, kwargs in [
        ("hier", dict(mode="hierarchical")),
        ("hier_bf16", dict(mode="hierarchical", state_dtype=jnp.bfloat16)),
        ("flat_bf16", dict(state_dtype=jnp.bfloat16)),
    ]:
        key = f"sage-graph__pagerank_round_{vname}__{mesh_name}"
        path = os.path.join(out_dir, key + ".json")
        if os.path.exists(path):
            continue
        t0 = time.time()
        rec = {"arch": "sage-graph", "shape": f"pagerank_round_{vname}",
               "mesh": mesh_name, "kind": "graph", "family": "graph",
               "notes": str(kwargs), "model_flops": 2.0 * NB * FB}
        try:
            fn = distributed_pagerank_step(mesh, n=n, **kwargs)
            with use_mesh(mesh):
                compiled = jax.jit(fn).lower(*specs).compile()
            cost = cost_dict(compiled)
            mem = compiled.memory_analysis()
            coll = collective_bytes_from_hlo(compiled.as_text(), 1)
            rec.update(
                ok=True, n_devices=int(mesh.devices.size),
                flops_per_device=float(cost.get("flops", -1)),
                flops_raw_per_device=float(cost.get("flops", -1)),
                bytes_per_device=float(cost.get("bytes accessed", -1)),
                bytes_raw_per_device=float(cost.get("bytes accessed", -1)),
                cost_debug={}, collective_bytes=coll,
                memory={"peak_bytes": getattr(mem, "peak_memory_in_bytes", None),
                        "argument_bytes": getattr(mem, "argument_size_in_bytes", None),
                        "output_bytes": getattr(mem, "output_size_in_bytes", None),
                        "temp_bytes": getattr(mem, "temp_size_in_bytes", None)},
            )
        except Exception as e:  # noqa: BLE001
            rec.update(ok=False, error=f"{type(e).__name__}: {e}")
        with open(path, "w") as fh:
            json.dump(rec, fh, indent=1)
        print(f"[{'OK ' if rec.get('ok') else 'FAIL'}] {key} ({time.time()-t0:.1f}s)",
              flush=True)


if __name__ == "__main__":
    main()
