"""Production mesh construction.

A FUNCTION, not a module-level constant: importing this module never touches
jax device state (the dry-run sets XLA_FLAGS before any jax import; smoke
tests see the real single CPU device).
"""
from __future__ import annotations

import jax
from jax.sharding import AxisType


def _auto(n):
    return (AxisType.Auto,) * n


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes, axis_types=_auto(len(axes)))


def make_mesh(shape, axes):
    """Explicit mesh for elastic re-carves and tests."""
    return jax.make_mesh(tuple(shape), tuple(axes), axis_types=_auto(len(axes)))


def single_device_mesh():
    """1×1 mesh over the local device — lets the same pjit code paths run in
    CPU tests."""
    return jax.make_mesh((1, 1), ("data", "model"), axis_types=_auto(2))
