"""Production mesh construction.

A FUNCTION, not a module-level constant: importing this module never touches
jax device state (the dry-run sets XLA_FLAGS before any jax import; smoke
tests see the real single CPU device).

Mesh construction goes through ``repro.compat`` so the same code runs on
JAX versions with and without ``jax.sharding.AxisType``.
"""
from __future__ import annotations

from ..compat import make_mesh as _make_mesh
from ..compat import use_mesh  # noqa: F401  (re-export: the mesh entry point)


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _make_mesh(shape, axes)


def make_mesh(shape, axes):
    """Explicit mesh for elastic re-carves and tests."""
    return _make_mesh(shape, axes)


def single_device_mesh():
    """1×1 mesh over the local device — lets the same pjit code paths run in
    CPU tests."""
    return _make_mesh((1, 1), ("data", "model"))
