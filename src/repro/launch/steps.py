"""Per-cell step builders: turn a Cell into (step_fn, input_specs,
input_shardings) ready for ``jax.jit(...).lower(...).compile()``.

Train cells lower the FULL train step (loss → grad → clip → AdamW), not just
the forward pass; serve cells lower prefill/decode/scoring exactly as the
serving path runs them.
"""
from __future__ import annotations


import jax
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from ..configs.common import Cell
from ..distributed.shardings import axis_rules, spec_tree
from ..optim import AdamWConfig, adamw_init, adamw_update, clip_by_global_norm
from ..optim.adamw import state_logical_specs


def _named(mesh, spec_pytree):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), spec_pytree)


def build_step(cell: Cell, mesh):
    """Returns (fn, arg_specs tuple, in_shardings tuple, out_shardings).

    out_shardings is pinned explicitly: without it XLA's propagation may
    REPLICATE large outputs (observed: decode caches materializing at full
    size per device, 27 GB > HBM on long_500k) — §Perf iteration D2."""
    mod = _module_for(cell)
    cfg = cell.model_cfg

    with axis_rules(cell.rules, mesh):
        p_logical = mod.param_specs(cfg)
        p_spec = spec_tree(p_logical)
        batch_spec = jax.tree.map(
            lambda names: spec_tree(names) if isinstance(names, tuple) else names,
            cell.batch_logical,
            is_leaf=lambda x: isinstance(x, tuple),
        )
    params_shape = jax.eval_shape(lambda: mod.init(jax.random.PRNGKey(0), cfg))

    if cell.kind == "train":
        opt_cfg = AdamWConfig()
        with axis_rules(cell.rules, mesh):
            o_spec = spec_tree(state_logical_specs(p_logical))
        opt_shape = jax.eval_shape(adamw_init, params_shape)

        def fn(params, opt_state, batch):
            loss, grads = jax.value_and_grad(
                lambda p: mod.loss_fn(p, batch, cfg)
            )(params)
            grads, gn = clip_by_global_norm(grads, 1.0)
            params, opt_state = adamw_update(params, grads, opt_state, opt_cfg)
            return params, opt_state, {"loss": loss, "grad_norm": gn}

        specs = (params_shape, opt_shape, cell.batch_specs)
        shardings = (
            _named(mesh, p_spec),
            _named(mesh, o_spec),
            _named(mesh, batch_spec),
        )
        rep = NamedSharding(mesh, P())
        out_sh = (
            _named(mesh, p_spec),
            _named(mesh, o_spec),
            {"loss": rep, "grad_norm": rep},
        )
        return fn, specs, shardings, out_sh

    if cell.kind == "prefill":
        def fn(params, batch):
            return mod.prefill(params, batch["tokens"], cfg)

        with axis_rules(cell.rules, mesh):
            c_spec = spec_tree(mod.cache_specs(cfg))
            logits_spec = spec_tree({"l": ("batch", "vocab")})["l"]
        return (
            fn,
            (params_shape, cell.batch_specs),
            (_named(mesh, p_spec), _named(mesh, batch_spec)),
            (NamedSharding(mesh, logits_spec), _named(mesh, c_spec)),
        )

    if cell.kind == "decode":
        seq = cell.batch_specs["tokens"].shape  # (B, 1)
        B = seq[0]
        max_seq = cfg.kv_block  # decode cells set kv_block = cache length
        cache_shape = jax.eval_shape(
            lambda: mod.make_cache(cfg, B, max_seq)
        )
        with axis_rules(cell.rules, mesh):
            c_spec = spec_tree(mod.cache_specs(cfg))

        def fn(params, caches, batch):
            return mod.decode_step(params, caches, batch["tokens"], batch["pos"], cfg)

        with axis_rules(cell.rules, mesh):
            logits_spec = spec_tree({"l": ("batch", "vocab")})["l"]
        return (
            fn,
            (params_shape, cache_shape, cell.batch_specs),
            (_named(mesh, p_spec), _named(mesh, c_spec), _named(mesh, batch_spec)),
            (NamedSharding(mesh, logits_spec), _named(mesh, c_spec)),
        )

    if cell.kind == "serve":  # sasrec full-catalog top-k
        def fn(params, batch):
            scores = mod.serve_scores(params, batch, cfg)
            v, i = jax.lax.top_k(scores, 100)
            return {"values": v, "indices": i}

        with axis_rules(cell.rules, mesh):
            out_spec = spec_tree({"o": ("batch", None)})["o"]
        osh = NamedSharding(mesh, out_spec)
        return (
            fn,
            (params_shape, cell.batch_specs),
            (_named(mesh, p_spec), _named(mesh, batch_spec)),
            {"values": osh, "indices": osh},
        )

    if cell.kind == "retrieval":
        def fn(params, batch):
            return mod.retrieval_scores(params, batch, cfg)

        with axis_rules(cell.rules, mesh):
            out_spec = spec_tree({"o": ("batch", "candidates")})["o"]
        return (
            fn,
            (params_shape, cell.batch_specs),
            (_named(mesh, p_spec), _named(mesh, batch_spec)),
            NamedSharding(mesh, out_spec),
        )

    raise ValueError(cell.kind)


def _module_for(cell: Cell):
    if cell.family == "lm":
        from ..models import transformer_lm

        return transformer_lm
    if cell.family == "recsys":
        from ..models import sasrec

        return sasrec
    # gnn
    from ..models.gnn import dimenet, equiformer_v2, gin, pna

    return {
        "pna": pna,
        "dimenet": dimenet,
        "equiformer-v2": equiformer_v2,
        "gin-tu": gin,
    }[cell.arch]
