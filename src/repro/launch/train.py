"""Fault-tolerant training driver.

Builds a pjit train_step for any model module exposing
(init, loss_fn, param_specs), runs the loop with:

* deterministic data (batch = f(step)) → bit-identical restart
* checkpoint every K steps (atomic publish, keep 3) + restore_latest
* global-norm clipping, warmup-cosine LR, AdamW
* optional microbatch gradient accumulation (activation-memory lever)
* optional int8 gradient compression on the pod axis
* failure injection (``fail_at_step``) for the restart tests
* straggler posture: the step is a single pjit program — load balance is
  static (sharded batch), and per-step wall-clock is logged so a driver at
  fleet scale can flag outlier hosts.

Usage::

    trainer = Trainer(model_module, model_cfg, mesh=mesh, rules=LM_RULES)
    trainer.fit(make_batch, steps=500, ckpt_dir=...)
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from ..checkpoint import restore_latest, save
from ..distributed.shardings import axis_rules, spec_tree
from ..optim import (
    AdamWConfig,
    adamw_init,
    adamw_update,
    clip_by_global_norm,
    state_logical_specs,
    warmup_cosine,
)


@dataclasses.dataclass
class TrainConfig:
    steps: int = 100
    ckpt_every: int = 50
    ckpt_dir: str | None = None
    grad_clip: float = 1.0
    warmup: int = 20
    adamw: AdamWConfig = dataclasses.field(default_factory=AdamWConfig)
    accum: int = 1                 # microbatch gradient accumulation
    fail_at_step: int | None = None  # failure injection for restart tests
    log_every: int = 10


class Trainer:
    def __init__(self, model, model_cfg, *, mesh=None, rules=None, train_cfg=None):
        self.model = model
        self.model_cfg = model_cfg
        self.mesh = mesh
        self.rules = rules
        self.cfg = train_cfg or TrainConfig()
        self._build()

    # ------------------------------------------------------------------
    def _shardings(self, logical_tree):
        if self.mesh is None or self.rules is None:
            return None
        with axis_rules(self.rules, self.mesh):
            specs = spec_tree(logical_tree)
        return jax.tree.map(lambda s: NamedSharding(self.mesh, s), specs)

    def _build(self):
        model, cfg = self.model, self.model_cfg
        tc = self.cfg

        def loss(params, batch):
            return model.loss_fn(params, batch, cfg)

        def step_fn(params, opt_state, batch):
            if tc.accum > 1:
                # microbatch accumulation: split the leading batch dim
                def micro(i, acc):
                    g_acc, l_acc = acc
                    mb = jax.tree.map(
                        lambda x: jax.lax.dynamic_slice_in_dim(
                            x, i * (x.shape[0] // tc.accum), x.shape[0] // tc.accum, 0
                        ),
                        batch,
                    )
                    l, g = jax.value_and_grad(loss)(params, mb)
                    return (
                        jax.tree.map(lambda a, b: a + b, g_acc, g),
                        l_acc + l,
                    )

                g0 = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
                grads, lsum = jax.lax.fori_loop(0, tc.accum, micro, (g0, 0.0))
                grads = jax.tree.map(lambda g: g / tc.accum, grads)
                lval = lsum / tc.accum
            else:
                lval, grads = jax.value_and_grad(loss)(params, batch)
            grads, gn = clip_by_global_norm(grads, tc.grad_clip)
            lr_scale = warmup_cosine(
                opt_state["step"], warmup=tc.warmup, total=max(tc.steps, 2)
            )
            params, opt_state = adamw_update(
                params, grads, opt_state, tc.adamw, lr_scale=lr_scale
            )
            metrics = {"loss": lval, "grad_norm": gn, "lr_scale": lr_scale}
            return params, opt_state, metrics

        self._loss = loss
        p_logical = model.param_specs(cfg)
        o_logical = state_logical_specs(p_logical)
        self.param_shardings = self._shardings(p_logical)
        self.opt_shardings = self._shardings(o_logical)

        if self.mesh is not None:
            rep = NamedSharding(self.mesh, P())
            self.train_step = jax.jit(
                step_fn,
                in_shardings=(self.param_shardings, self.opt_shardings, None),
                out_shardings=(self.param_shardings, self.opt_shardings, rep),
                donate_argnums=(0, 1),
            )
        else:
            self.train_step = jax.jit(step_fn, donate_argnums=(0, 1))

    # ------------------------------------------------------------------
    def init_state(self, key):
        def make():
            params = self.model.init(key, self.model_cfg)
            return params, adamw_init(params)

        if self.mesh is not None:
            params, opt = jax.jit(
                make, out_shardings=(self.param_shardings, self.opt_shardings)
            )()
        else:
            params, opt = jax.jit(make)()
        return params, opt

    def fit(
        self,
        make_batch: Callable[[int], Any],
        *,
        key=None,
        steps: int | None = None,
        ckpt_dir: str | None = None,
        params=None,
        opt_state=None,
    ):
        """Run (or resume) the training loop.  ``make_batch(step)`` must be
        deterministic in step — that is what makes restart bit-identical."""
        tc = self.cfg
        steps = steps or tc.steps
        ckpt_dir = ckpt_dir or tc.ckpt_dir
        key = key if key is not None else jax.random.PRNGKey(0)

        start = 0
        if params is None:
            params, opt_state = self.init_state(key)
            if ckpt_dir:
                shardings = (
                    {"params": self.param_shardings, "opt": self.opt_shardings}
                    if self.mesh is not None
                    else None
                )
                restored, rstep = restore_latest(
                    ckpt_dir, {"params": params, "opt": opt_state}, shardings=shardings
                )
                if restored is not None:
                    params, opt_state = restored["params"], restored["opt"]
                    start = rstep
        history = []
        for step in range(start, steps):
            if tc.fail_at_step is not None and step == tc.fail_at_step:
                raise RuntimeError(f"injected failure at step {step}")
            t0 = time.perf_counter()
            batch = make_batch(step)
            params, opt_state, metrics = self.train_step(params, opt_state, batch)
            if ckpt_dir and (step + 1) % tc.ckpt_every == 0:
                save(ckpt_dir, step + 1, {"params": params, "opt": opt_state})
            if (step + 1) % tc.log_every == 0 or step == start:
                dt = time.perf_counter() - t0
                history.append(
                    {
                        "step": step + 1,
                        "loss": float(metrics["loss"]),
                        "grad_norm": float(metrics["grad_norm"]),
                        "sec_per_step": dt,
                    }
                )
        return params, opt_state, history
