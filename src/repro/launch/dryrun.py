import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# ^ MUST precede every other import (jax locks device count on first init).

"""Multi-pod dry-run: lower + compile every (architecture × input-shape)
cell on the production meshes, and record memory/cost/collective statistics
for §Dry-run / §Roofline of EXPERIMENTS.md.

Usage:
    PYTHONPATH=src python -m repro.launch.dryrun --mesh single --arch qwen2-1.5b
    PYTHONPATH=src python -m repro.launch.dryrun --mesh multi            # all
    PYTHONPATH=src python -m repro.launch.dryrun --list

Results are cached as JSON under results/dryrun/ (one file per cell×mesh);
re-runs skip completed cells, so the sweep is resumable.
"""
import argparse
import json
import re
import time
import traceback

import jax

from ..compat import use_mesh
from ..configs import all_cells
from ..distributed.shardings import axis_rules
from .mesh import make_production_mesh
from .steps import build_step

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..", "results", "dryrun")

_COLLECTIVES = (
    "all-gather",
    "all-reduce",
    "reduce-scatter",
    "all-to-all",
    "collective-permute",
)

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "bf16": 2, "f16": 2, "f8": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def cost_dict(compiled) -> dict:
    """``Compiled.cost_analysis()`` normalized across jax versions (some
    return the per-computation dict, 0.4.x returns a one-element list)."""
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):
        cost = cost[0] if cost else {}
    return cost or {}


def _shape_bytes(shape_str: str) -> int:
    total = 0
    for m in _SHAPE_RE.finditer(shape_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                if d:
                    n *= int(d)
        total += n * _DTYPE_BYTES[dt]
        break  # first shape on the line = result shape
    return total


def collective_bytes_from_hlo(hlo_text: str, scan_trip_hint: int = 1) -> dict:
    """Sum result-shape bytes of every collective op in the HLO.

    Ops inside while-loop bodies (layer scans) are multiplied by
    ``scan_trip_hint`` — XLA prints the body once but executes it per layer.
    """
    per_op = {c: 0 for c in _COLLECTIVES}
    in_while = False
    depth = 0
    for line in hlo_text.splitlines():
        s = line.strip()
        if s.startswith(("%while", "while_body", "%body", "body")) and s.endswith("{"):
            in_while = True
            depth = 0
        if in_while:
            depth += s.count("{") - s.count("}")
            if depth <= 0 and "}" in s:
                in_while = False
        mult = scan_trip_hint if in_while else 1
        for c in _COLLECTIVES:
            if f" {c}(" in s or f"= {c}" in s or re.search(rf"\b{c}(\.\d+)?\(", s):
                per_op[c] += _shape_bytes(s) * mult
                break
    per_op["total"] = sum(per_op[c] for c in _COLLECTIVES)
    return per_op


def scan_trips_for(cell) -> int:
    cfg = cell.model_cfg
    return getattr(cfg, "n_layers", None) or getattr(cfg, "n_blocks", 1) or 1


_DEF_RE = re.compile(r"^\s*(%[\w.\-]+)\s*=\s*(\w+)\[([\d,]*)\]")
_DOT_RE = re.compile(r"^\s*(%[\w.\-]+)\s*=\s*(\w+)\[([\d,]*)\][^=]*\bdot\(([^)]*)\)")
_LHS_C_RE = re.compile(r"lhs_contracting_dims=\{([\d,]*)\}")


def dot_flops_from_hlo(hlo_text: str) -> float:
    """Sum 2·|result|·contract_size over every dot op in the module.

    XLA:CPU's aggregate cost_analysis drops some SPMD-partitioned batched
    dots (observed: the attention einsums vanish from the total); parsing
    the dots directly is exact on fully-unrolled modules (the costing
    variants contain no while loops, so no trip-count ambiguity).
    """
    shape_of = {}
    for line in hlo_text.splitlines():
        m = _DEF_RE.match(line)
        if m:
            shape_of[m.group(1)] = tuple(
                int(d) for d in m.group(3).split(",") if d
            )
    flops = 0.0
    for line in hlo_text.splitlines():
        m = _DOT_RE.match(line)
        if not m:
            continue
        result_dims = tuple(int(d) for d in m.group(3).split(",") if d)
        result_elems = 1.0
        for d in result_dims:
            result_elems *= d
        ops = [o.strip().split(" ")[-1] for o in m.group(4).split(",")]
        lhs = shape_of.get(ops[0], ()) if ops else ()
        mc = _LHS_C_RE.search(line)
        cdims = [int(d) for d in mc.group(1).split(",") if d] if mc else []
        contract = 1.0
        for d in cdims:
            if d < len(lhs):
                contract *= lhs[d]
        flops += 2.0 * result_elems * contract
    return flops


def _compile_cost_variant(cell, mesh, n_layers: int):
    """Compile a small FULLY-UNROLLED variant of an LM cell and return
    (per-device flops, bytes).  Two corrections vs the scanned main compile:
    scan bodies are counted once by cost_analysis (fixed by unrolling +
    F(L+1)−F(L) extrapolation), and SPMD-partitioned batched dots are
    dropped from the aggregate (fixed by dot_flops_from_hlo — we take the
    max of XLA's aggregate and the parsed dot flops)."""
    import dataclasses

    # single-block attention so the kv scan doesn't hide FLOPs; decode cells
    # already use kv_block == cache length (the cache is sized from it), and
    # causal-skip variants must keep their blocking (unroll_kv makes the kv
    # loop visible either way)
    kv_block = (
        cell.model_cfg.kv_block
        if (cell.kind == "decode" or getattr(cell.model_cfg, "attn_causal_skip", False))
        else max(cell.model_cfg.kv_block, 1 << 30)
    )
    cfg = dataclasses.replace(
        cell.model_cfg,
        n_layers=n_layers,
        unroll=True,
        kv_block=kv_block,
    )
    cc = dataclasses.replace(cell, model_cfg=cfg)
    fn, specs, shardings, out_shardings = build_step(cc, mesh)
    with use_mesh(mesh), axis_rules(cell.rules, mesh):
        compiled = jax.jit(
            fn, in_shardings=shardings, out_shardings=out_shardings
        ).lower(*specs).compile()
    cost = cost_dict(compiled)
    xla_flops = float(cost.get("flops", 0))
    parsed = dot_flops_from_hlo(compiled.as_text())
    return max(xla_flops, parsed), float(cost.get("bytes accessed", 0))


def corrected_lm_cost(cell, mesh):
    """Extrapolate total per-device flops/bytes: F(Lmin) + (L−Lmin)·ΔF."""
    cfg = cell.model_cfg
    lmin = (cfg.first_dense_layers if cfg.moe else 0) + 1
    f1, b1 = _compile_cost_variant(cell, mesh, lmin)
    f2, b2 = _compile_cost_variant(cell, mesh, lmin + 1)
    L = cfg.n_layers
    flops = f1 + (L - lmin) * (f2 - f1)
    byts = b1 + (L - lmin) * (b2 - b1)
    return flops, byts, {"f_lmin": f1, "f_lmin1": f2, "lmin": lmin}


def run_cell(cell, mesh, mesh_name: str, out_dir: str):
    key = f"{cell.arch}__{cell.shape}__{mesh_name}".replace("/", "_")
    out_path = os.path.join(out_dir, key + ".json")
    if os.path.exists(out_path):
        with open(out_path) as fh:
            return json.load(fh)
    t0 = time.time()
    rec = {
        "arch": cell.arch, "shape": cell.shape, "mesh": mesh_name,
        "kind": cell.kind, "family": cell.family, "notes": cell.notes,
        "model_flops": cell.model_flops,
    }
    try:
        fn, specs, shardings, out_shardings = build_step(cell, mesh)
        with use_mesh(mesh), axis_rules(cell.rules, mesh):
            jitted = jax.jit(fn, in_shardings=shardings, out_shardings=out_shardings)
            lowered = jitted.lower(*specs)
            t_lower = time.time() - t0
            compiled = lowered.compile()
            t_compile = time.time() - t0 - t_lower
        mem = compiled.memory_analysis()
        cost = cost_dict(compiled)
        hlo = compiled.as_text()
        coll = collective_bytes_from_hlo(hlo, scan_trips_for(cell))
        flops_raw = float(cost.get("flops", -1)) if cost else -1
        bytes_raw = float(cost.get("bytes accessed", -1)) if cost else -1
        if cell.family == "lm":
            flops_dev, bytes_dev, cost_dbg = corrected_lm_cost(cell, mesh)
        else:
            # GNN/recsys models are python-loop (no scans): the main module
            # is exact; still recover SPMD-dropped batched dots by parsing
            flops_dev = max(flops_raw, dot_flops_from_hlo(hlo))
            bytes_dev, cost_dbg = bytes_raw, {}
        rec.update(
            ok=True,
            lower_s=round(t_lower, 2),
            compile_s=round(t_compile, 2),
            n_devices=int(mesh.devices.size),
            flops_raw_per_device=flops_raw,
            flops_per_device=flops_dev,
            bytes_per_device=bytes_dev,
            bytes_raw_per_device=bytes_raw,
            cost_debug=cost_dbg,
            collective_bytes=coll,
            memory={
                "argument_bytes": getattr(mem, "argument_size_in_bytes", None),
                "output_bytes": getattr(mem, "output_size_in_bytes", None),
                "temp_bytes": getattr(mem, "temp_size_in_bytes", None),
                "peak_bytes": getattr(mem, "peak_memory_in_bytes", None),
            },
        )
    except Exception as e:  # noqa: BLE001 — record the failure, keep sweeping
        rec.update(ok=False, error=f"{type(e).__name__}: {e}",
                   traceback=traceback.format_exc()[-2000:])
    os.makedirs(out_dir, exist_ok=True)
    with open(out_path, "w") as fh:
        json.dump(rec, fh, indent=1)
    status = "OK " if rec.get("ok") else "FAIL"
    print(f"[{status}] {key}  ({time.time() - t0:.1f}s)", flush=True)
    return rec


def run_graph_engine(mesh, mesh_name: str, out_dir: str, *, rules_name: str = "baseline"):
    """Dry-run the Sage engine itself on the production mesh: one
    edge-partitioned PageRank round + one BFS/label-prop round over a
    production-scale RMAT stand-in (n=2^20 vertices, NB=2^18 blocks of 128).
    """
    import jax.numpy as jnp

    from ..core.plan import sharded_graph_spec
    from ..distributed.engine import (
        distributed_frontier_min,
        distributed_pagerank_step,
    )

    n, NB, FB = 1 << 20, 1 << 18, 128
    S = jax.ShapeDtypeStruct
    gs = sharded_graph_spec(n, NB, FB, int(mesh.devices.size))
    x = S((n,), jnp.float32)
    xi = S((n,), jnp.int32)
    fr = S((n,), jnp.bool_)

    for name, build, specs in [
        ("pagerank_round", lambda: distributed_pagerank_step(mesh, n=n), (gs, x, x)),
        ("frontier_min", lambda: distributed_frontier_min(mesh, n=n), (gs, xi, fr)),
    ]:
        key = f"sage-graph__{name}_{rules_name}__{mesh_name}"
        out_path = os.path.join(out_dir, key + ".json")
        if os.path.exists(out_path):
            continue
        t0 = time.time()
        rec = {"arch": "sage-graph", "shape": f"{name}_{rules_name}",
               "mesh": mesh_name, "kind": "graph", "family": "graph",
               "notes": f"n={n} NB={NB} FB={FB}",
               "model_flops": 2.0 * NB * FB}
        try:
            fn = build()
            with use_mesh(mesh):
                compiled = jax.jit(fn).lower(*specs).compile()
            cost = cost_dict(compiled)
            mem = compiled.memory_analysis()
            coll = collective_bytes_from_hlo(compiled.as_text(), 1)
            rec.update(
                ok=True,
                n_devices=int(mesh.devices.size),
                flops_per_device=float(cost.get("flops", -1)),
                flops_raw_per_device=float(cost.get("flops", -1)),
                bytes_per_device=float(cost.get("bytes accessed", -1)),
                bytes_raw_per_device=float(cost.get("bytes accessed", -1)),
                cost_debug={},
                collective_bytes=coll,
                memory={
                    "argument_bytes": getattr(mem, "argument_size_in_bytes", None),
                    "output_bytes": getattr(mem, "output_size_in_bytes", None),
                    "temp_bytes": getattr(mem, "temp_size_in_bytes", None),
                    "peak_bytes": getattr(mem, "peak_memory_in_bytes", None),
                },
            )
        except Exception as e:  # noqa: BLE001
            rec.update(ok=False, error=f"{type(e).__name__}: {e}")
        os.makedirs(out_dir, exist_ok=True)
        with open(out_path, "w") as fh:
            json.dump(rec, fh, indent=1)
        print(f"[{'OK ' if rec.get('ok') else 'FAIL'}] {key} ({time.time()-t0:.1f}s)",
              flush=True)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--mesh", choices=["single", "multi", "both"], default="both")
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--graph-engine", action="store_true")
    ap.add_argument("--list", action="store_true")
    ap.add_argument("--out", default=RESULTS_DIR)
    args = ap.parse_args()

    cells = all_cells()
    if args.list:
        for (a, s) in sorted(cells):
            print(a, s, cells[(a, s)].kind)
        return

    meshes = []
    if args.mesh in ("single", "both"):
        meshes.append(("single_pod_16x16", make_production_mesh(multi_pod=False)))
    if args.mesh in ("multi", "both"):
        meshes.append(("multi_pod_2x16x16", make_production_mesh(multi_pod=True)))

    if args.graph_engine:
        for mesh_name, mesh in meshes:
            run_graph_engine(mesh, mesh_name, args.out)
        return

    n_ok = n_fail = 0
    for mesh_name, mesh in meshes:
        for (arch, shape), cell in sorted(cells.items()):
            if args.arch and arch != args.arch:
                continue
            if args.shape and shape != args.shape:
                continue
            rec = run_cell(cell, mesh, mesh_name, args.out)
            n_ok += bool(rec.get("ok"))
            n_fail += not rec.get("ok")
    print(f"done: {n_ok} ok, {n_fail} failed")


if __name__ == "__main__":
    main()
