"""DRAM delta overlay — log-structured mutable graphs under the PSAM.

Sage's semi-asymmetric contract (edges read-only in NVRAM, O(n) mutable
DRAM) is exactly a log-structured storage design: accept edge insertions
and deletions into a DRAM-resident overlay, serve queries over
``base ∪ delta``, and fold the overlay into a fresh base only in rare,
batched compactions (``repro.delta.compact`` — the ONLY large-memory
write this subsystem ever performs).

Two pieces:

* :class:`DeltaOverlay` — the host-side mutable edit log.  Deletions of
  base edges become **tombstone bits** in a packed uint32 mask aligned
  1:1 with the base's edge-block slots (the same little-endian word
  layout the ``edge_active`` filter operand uses, so kernels already
  know how to AND it in).  Insertions become **patch edges**, grouped
  per source vertex.  Edit semantics are upsert/delete over the directed
  edge set, chosen to be *exactly* what ``build_csr`` would produce from
  the final edge list — the contract the differential test harness
  locks (``tests/test_delta.py``).
* :class:`DeltaGraph` — an immutable snapshot of ``base ∪ delta`` that
  implements the ``GraphBackend`` protocol.  The base blocks keep their
  NVRAM layout with tombstoned slots masked to the sentinel ``n``; the
  inserted edges ride in dense *patch blocks* appended after the base
  blocks (same ``F_B`` width, same sentinel padding, ``block_src``
  naming the owner), so ``edge_map`` / filters / algorithms /
  ``QueryEngine`` reduce base and patch through the **same monoid in the
  same block sweep** — no special-cased side pass, and results are
  bit-identical to a from-scratch graph for every order-insensitive
  monoid (int32 min/max/or; float32 sums of sub-2²⁴ integer totals).

PSAM accounting: only the base blocks live in large memory.  The patch
blocks and tombstone words are DRAM-resident and are charged as
small-memory ops (``PSAMCost.charge_edgemap_overlay``); cost-model
consumers duck-type the backend on the ``overlay_small_words``
attribute (``repro.core`` cannot import this package — layering).
"""
from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from ..core.compressed import CompressedCSR
from ..core.csr import CSRGraph, sharded_block_counts
from ..core.graph_filter import unpack_word_bits

__all__ = ["DeltaGraph", "DeltaOverlay"]


def _live_words_per_block(block_size: int) -> int:
    """Tombstone-mask words per block: ceil(F_B / 32)."""
    return -(-block_size // 32)


def _pack_live_words(live: np.ndarray, num_blocks: int, block_size: int) -> np.ndarray:
    """Pack a bool[NB*F_B] liveness mask into uint32[NB, ceil(F_B/32)].

    Little-endian within each word — bit ``i`` of word ``w`` is slot
    ``32*w + i`` — matching ``repro.core.graph_filter.pack_bits`` so the
    tombstone mask and the ``edge_active`` operand share one layout.
    Blocks narrower than a word multiple pad with dead (zero) bits.
    """
    W = _live_words_per_block(block_size)
    padded = np.zeros((num_blocks, W * 32), dtype=bool)
    padded[:, :block_size] = live.reshape(num_blocks, block_size)
    bits = padded.reshape(num_blocks, W, 32).astype(np.uint32)
    return (bits << np.arange(32, dtype=np.uint32)).sum(axis=-1).astype(np.uint32)


def _next_pow2(k: int) -> int:
    b = 1
    while b < k:
        b *= 2
    return b


@partial(
    jax.tree_util.register_dataclass,
    data_fields=[
        "base",
        "patch_src",
        "patch_dst",
        "patch_w",
        "live_words",
        "degrees",
    ],
    meta_fields=["n", "m", "num_blocks", "num_base_blocks", "block_size", "weighted"],
)
@dataclasses.dataclass(frozen=True)
class DeltaGraph:
    """Immutable ``base ∪ delta`` snapshot implementing ``GraphBackend``.

    ``base`` is the read-only NVRAM graph (``CSRGraph`` or
    ``CompressedCSR``, nested as a sub-pytree).  ``live_words`` is the
    packed tombstone mask over the base's slots (bit set = slot live; a
    bit is only ever set where the base slot held a real edge, so the
    mask subsumes the base's own padding).  ``patch_*`` are the inserted
    edges laid out in dense blocks of the base's ``F_B`` width, appended
    after the base blocks in every block-view property — consumers see
    one contiguous block array of ``num_blocks = num_base_blocks + PB``
    blocks and never dispatch on which side a block came from.

    ``degrees`` / ``m`` describe the LIVE edge set (base minus
    tombstones plus patch), so auto-strategy density heuristics price
    the graph being served, not the stale base.
    """

    base: CSRGraph | CompressedCSR
    patch_src: jnp.ndarray   # int32[PB]      — owner vertex (sentinel n on pads)
    patch_dst: jnp.ndarray   # int32[PB, F_B] — targets (sentinel n on pads)
    patch_w: jnp.ndarray     # float32[PB, F_B]
    live_words: jnp.ndarray  # uint32[NB_base, F_B/32] — 1 = live base slot
    degrees: jnp.ndarray     # int32[n] — live out-degrees
    n: int
    m: int
    num_blocks: int
    num_base_blocks: int
    block_size: int
    weighted: bool

    # -- GraphBackend block view: base (tombstones folded in) ++ patch --
    @property
    def num_patch_blocks(self) -> int:
        """Patch blocks appended after the base's block range."""
        return self.num_blocks - self.num_base_blocks

    @property
    def _base_live(self) -> jnp.ndarray:
        """bool[NB_base, F_B] — unpacked tombstone mask (lazy, fuses);
        word-padding bits beyond F_B are sliced away."""
        return unpack_word_bits(self.live_words)[:, : self.block_size]

    @property
    def block_src(self) -> jnp.ndarray:
        """int32[NB] owner per block: base owners then patch owners."""
        return jnp.concatenate([self.base.block_src, self.patch_src])

    @property
    def block_dst(self) -> jnp.ndarray:
        """int32[NB, F_B] targets with tombstoned base slots already
        masked to the sentinel ``n`` — deletions are invisible to every
        consumer without any ``edge_active`` operand."""
        masked = jnp.where(self._base_live, self.base.block_dst, jnp.int32(self.n))
        return jnp.concatenate([masked, self.patch_dst])

    @property
    def block_w(self) -> jnp.ndarray:
        """float32[NB, F_B] weights (zeros on tombstoned/padding slots)."""
        masked = jnp.where(self._base_live, self.base.block_w, 0.0)
        return jnp.concatenate([masked, self.patch_w])

    @property
    def edge_valid(self) -> jnp.ndarray:
        """bool[NB*F_B] — live base slots ++ real patch slots."""
        patch_valid = (self.patch_dst < jnp.int32(self.n)).reshape(-1)
        return jnp.concatenate([self._base_live.reshape(-1), patch_valid])

    @property
    def edge_dst(self) -> jnp.ndarray:
        return self.block_dst.reshape(-1)

    @property
    def edge_src(self) -> jnp.ndarray:
        """int32[NB*F_B] — owner per slot, sentinel n on dead slots."""
        src = jnp.broadcast_to(
            self.block_src[:, None], (self.num_blocks, self.block_size)
        ).reshape(-1)
        return jnp.where(self.edge_valid, src, jnp.int32(self.n))

    @property
    def edge_w(self) -> jnp.ndarray:
        return self.block_w.reshape(-1)

    @property
    def avg_degree(self) -> float:
        return self.m / max(self.n, 1)

    def out_degree(self, v):
        return self.degrees[v]

    # -- PSAM surface (meta-only arithmetic: usable under a tracer) -----
    @property
    def overlay_small_words(self) -> int:
        """DRAM words one full sweep touches beyond the base blocks: the
        patch blocks' dst+w words plus one tombstone word per 32 base
        slots.  The duck-typing key every cost-model consumer dispatches
        on — ``PSAMCost.charge_edgemap_overlay`` charges exactly this
        into ``small_ops`` while the base keeps its NVRAM read charge."""
        return (
            self.num_patch_blocks * 2 * self.block_size
            + self.num_base_blocks * _live_words_per_block(self.block_size)
        )

    @property
    def compact_write_words(self) -> int:
        """Estimated NVRAM words ``compact()`` would write now: the live
        edge set re-encoded as a fresh ``CompressedCSR`` (per-block
        first+count+deltas words, weights uncompressed when weighted).
        Meta-only arithmetic — an estimate for the compaction *trigger*
        (``repro.tuning.OverlayTrigger``); the actual charge uses the
        compacted graph's real footprint."""
        blocks = max(-(-self.m // self.block_size), 1)
        per_block = -(-(4 + 2 + 2 * self.block_size) // 4)
        words = per_block * blocks
        if self.weighted:
            words += self.block_size * blocks
        return words

    # -- sharding -------------------------------------------------------
    def shard(self, num_shards: int) -> list["DeltaGraph"]:
        """Partition base AND patch blocks into ``num_shards`` ranges.

        The base splits through its own ``shard`` (empty sentinel-block
        padding, per-shard exception lists — unchanged); ``live_words``
        splits along the identical block ranges with all-dead (zero)
        padding rows, so shard s's tombstone rows line up 1:1 with shard
        s's base blocks.  The patch blocks range-split independently
        with sentinel padding rows.  Each shard is itself a valid
        ``DeltaGraph`` over the global vertex space with identical meta,
        so the planner stacks shards into one pytree exactly as for the
        pure backends.
        """
        if num_shards < 1:
            raise ValueError(f"num_shards must be >= 1, got {num_shards}")
        FB = self.block_size
        base_shards = self.base.shard(num_shards)
        per_b, padded_b = sharded_block_counts(self.num_base_blocks, num_shards)
        lw = np.asarray(self.live_words)
        if padded_b > self.num_base_blocks:
            lw = np.concatenate(
                [lw, np.zeros((padded_b - self.num_base_blocks, FB // 32), np.uint32)]
            )
        PB = self.num_patch_blocks
        per_p, padded_p = sharded_block_counts(PB, num_shards)
        psrc = np.asarray(self.patch_src)
        pdst = np.asarray(self.patch_dst)
        pw = np.asarray(self.patch_w)
        if padded_p > PB:
            pad = padded_p - PB
            psrc = np.concatenate([psrc, np.full(pad, self.n, np.int32)])
            pdst = np.concatenate([pdst, np.full((pad, FB), self.n, np.int32)])
            pw = np.concatenate([pw, np.zeros((pad, FB), np.float32)])
        shards = []
        for s in range(num_shards):
            bl, bh = s * per_b, (s + 1) * per_b
            pl, ph = s * per_p, (s + 1) * per_p
            shards.append(
                dataclasses.replace(
                    self,
                    base=base_shards[s],
                    patch_src=jnp.asarray(psrc[pl:ph]),
                    patch_dst=jnp.asarray(pdst[pl:ph]),
                    patch_w=jnp.asarray(pw[pl:ph]),
                    live_words=jnp.asarray(lw[bl:bh]),
                    num_base_blocks=per_b,
                    num_blocks=per_b + per_p,
                )
            )
        return shards


class DeltaOverlay:
    """Host-side mutable edit log over a read-only base graph.

    Accepts directed-edge ``insert`` / ``delete`` edits (upsert
    semantics: inserting an existing edge replaces its weight; deleting
    a missing edge is a no-op; self-loops are dropped, exactly as
    ``build_csr`` drops them) and snapshots the current
    ``base ∪ delta`` state as an immutable :class:`DeltaGraph`.

    Storage, per the PSAM: O(base slots / 32 + inserted edges) words of
    DRAM — a tombstone bit per base slot plus a patch dict — and ZERO
    large-memory writes; the base arrays are never touched.  Folding the
    log back into NVRAM is :func:`repro.delta.compact`, the one batched
    ω-cost write.

    Edit-to-rebuild equivalence (the differential contract): after any
    edit script, ``overlay.snapshot()`` serves every order-insensitive
    query bit-identically to ``build_csr`` over the final edge set.  The
    one subtlety is re-inserting a tombstoned base edge with a *new*
    weight: the base slot's weight is immutable, so the slot stays
    tombstoned and the edge moves to the patch side (same live edge set,
    same weights, different physical slot — invisible to any monoid).
    """

    def __init__(self, base: CSRGraph | CompressedCSR):
        if not isinstance(base, (CSRGraph, CompressedCSR)):
            raise TypeError(
                f"DeltaOverlay base must be CSRGraph | CompressedCSR, "
                f"got {type(base).__name__}"
            )
        self.base = base
        self.n = int(base.n)
        self.block_size = int(base.block_size)
        self.weighted = bool(base.weighted)
        # host copies of the base's slot layout (decoded once; O(m) DRAM
        # in the PSAM's small-memory budget, like every per-edge bit)
        self._base_src = np.asarray(base.edge_src)
        self._base_dst = np.asarray(base.edge_dst)
        self._base_w = np.asarray(base.edge_w)
        valid = np.asarray(base.edge_valid)
        self._base_valid = valid
        self._live = valid.copy()
        slots = np.flatnonzero(valid)
        self._slot = {
            (int(self._base_src[i]), int(self._base_dst[i])): int(i) for i in slots
        }
        self._patch: dict[tuple[int, int], float] = {}
        self.edits_applied = 0

    # -- sizes ----------------------------------------------------------
    @property
    def num_patch_edges(self) -> int:
        """Inserted edges currently living on the DRAM patch side."""
        return len(self._patch)

    @property
    def num_tombstones(self) -> int:
        """Base slots masked dead (deleted, or re-weighted to the patch)."""
        return int((self._base_valid & ~self._live).sum())

    @property
    def num_live_edges(self) -> int:
        """Edges the current snapshot serves: live base + patch."""
        return int(self._live.sum()) + len(self._patch)

    # -- edits ----------------------------------------------------------
    def _check(self, u: int, v: int) -> None:
        if not (0 <= u < self.n and 0 <= v < self.n):
            raise ValueError(f"edge ({u}, {v}) out of range for n={self.n}")

    def insert(self, u: int, v: int, w: float = 1.0) -> bool:
        """Upsert directed edge ``(u, v)``; True if the edge set changed.

        Self-loops are dropped (``build_csr`` parity).  Unweighted bases
        ignore ``w`` (every edge weighs 1.0 on rebuild).  A tombstoned
        base edge re-inserted with its original weight just clears its
        tombstone bit — zero DRAM growth; with a different weight the
        edge moves to the patch side instead (see the class docstring).
        """
        u, v = int(u), int(v)
        self._check(u, v)
        self.edits_applied += 1
        if u == v:
            return False
        w = 1.0 if not self.weighted else float(w)
        key = (u, v)
        slot = self._slot.get(key)
        if slot is not None and float(self._base_w[slot]) == w:
            changed = not bool(self._live[slot]) or key in self._patch
            self._live[slot] = True
            self._patch.pop(key, None)
            return changed
        if slot is not None:
            # weight differs from the immutable base slot: tombstone it
            # and carry the edge (with its new weight) on the patch side
            self._live[slot] = False
        changed = self._patch.get(key) != w
        self._patch[key] = w
        return changed

    def delete(self, u: int, v: int) -> bool:
        """Delete directed edge ``(u, v)``; True if it existed."""
        u, v = int(u), int(v)
        self._check(u, v)
        self.edits_applied += 1
        key = (u, v)
        existed = self._patch.pop(key, None) is not None
        slot = self._slot.get(key)
        if slot is not None and self._live[slot]:
            self._live[slot] = False
            existed = True
        return existed

    def apply(self, edits) -> int:
        """Apply an edit script: iterable of ``("insert", u, v[, w])`` /
        ``("delete", u, v)`` tuples.  Returns how many edits changed the
        edge set."""
        changed = 0
        for e in edits:
            kind = e[0]
            if kind == "insert":
                changed += bool(self.insert(*e[1:]))
            elif kind == "delete":
                changed += bool(self.delete(e[1], e[2]))
            else:
                raise ValueError(f"unknown edit kind {kind!r}")
        return changed

    # -- live edge set (host) ------------------------------------------
    def live_edges(self) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
        """The current directed edge set as host ``(src, dst, w)`` arrays
        — live base slots plus patch edges, the exact input a
        from-scratch ``build_csr`` (and :func:`repro.delta.compact`)
        consumes."""
        idx = np.flatnonzero(self._live)
        src = self._base_src[idx]
        dst = self._base_dst[idx]
        w = self._base_w[idx]
        if self._patch:
            items = sorted(self._patch.items())
            psrc = np.asarray([k[0] for k, _ in items], np.int64)
            pdst = np.asarray([k[1] for k, _ in items], np.int64)
            pw = np.asarray([wt for _, wt in items], np.float32)
            src = np.concatenate([src.astype(np.int64), psrc])
            dst = np.concatenate([dst.astype(np.int64), pdst])
            w = np.concatenate([w.astype(np.float32), pw])
        return src, dst, w

    # -- snapshot -------------------------------------------------------
    def snapshot(self) -> DeltaGraph:
        """Freeze the current ``base ∪ delta`` state as a DeltaGraph.

        Patch edges pack per source vertex (sorted by ``(src, dst)``,
        front-packed into ``F_B``-wide blocks, sentinel padding) and the
        block count rounds up to a power of two — a growing patch only
        retraces compiled executables at doubling boundaries, not per
        edit batch.
        """
        n, FB = self.n, self.block_size
        NB = self.base.num_blocks
        live_words = _pack_live_words(self._live, NB, FB)
        items = sorted(self._patch.items())
        pdeg = np.zeros(n, np.int64)
        for (u, _), _w in items:
            pdeg[u] += 1
        nblk = -(-pdeg // FB)
        PB = max(int(nblk.sum()), 1)
        PB_cap = _next_pow2(PB)
        patch_src = np.full(PB_cap, n, np.int32)
        patch_dst = np.full((PB_cap, FB), n, np.int32)
        patch_w = np.zeros((PB_cap, FB), np.float32)
        blk = 0
        i = 0
        while i < len(items):
            u = items[i][0][0]
            j = i
            while j < len(items) and items[j][0][0] == u:
                j += 1
            for lo in range(i, j, FB):
                run = items[lo : min(lo + FB, j)]
                patch_src[blk] = u
                for s, ((_, v), wt) in enumerate(run):
                    patch_dst[blk, s] = v
                    patch_w[blk, s] = wt
                blk += 1
            i = j
        live_deg = np.bincount(
            self._base_src[self._live], minlength=n + 1
        )[:n].astype(np.int64)
        degrees = live_deg + pdeg
        m = int(self._live.sum()) + len(items)
        return DeltaGraph(
            base=self.base,
            patch_src=jnp.asarray(patch_src),
            patch_dst=jnp.asarray(patch_dst),
            patch_w=jnp.asarray(patch_w),
            live_words=jnp.asarray(live_words),
            degrees=jnp.asarray(degrees, jnp.int32),
            n=n,
            m=m,
            num_blocks=NB + PB_cap,
            num_base_blocks=NB,
            block_size=FB,
            weighted=self.weighted,
        )
