"""Compaction — fold the DRAM overlay into a fresh ``CompressedCSR``.

The ONE large-memory write in the whole mutable-graph subsystem: the
overlay's live edge set (base minus tombstones plus patch) re-encodes as
a fresh compressed base in a single batched pass, charged at the PSAM's
ω write premium (``PSAMCost.charge_large_write``) so the ``ω·W / edits``
amortization the asymmetric-building-blocks line of work argues for
(arXiv:1806.10370) is visible in the model, not just asserted.

Persistence rides ``repro.checkpoint.ckpt``'s atomic step-directory save
(write to ``step_N.tmp``, ``os.replace`` to publish): a crash at ANY
point during a compaction save leaves the previous published step as the
restore target — recovery loads the pre- or post-compaction graph, never
a torn state (locked by the subprocess kill tests in
``tests/test_delta.py``).  The checkpoint tree is a plain dict of named
leaves with the static meta serialized as a JSON byte leaf, so
``restore`` can rebuild its treedef without an example graph.
"""
from __future__ import annotations

import json

import jax.numpy as jnp
import numpy as np

from ..checkpoint import ckpt
from ..core.compressed import CompressedCSR, compress
from ..core.csr import build_csr
from ..obs import get_registry
from .overlay import DeltaOverlay

__all__ = [
    "compact",
    "compact_write_words",
    "load_compacted",
    "save_compacted",
]

# static key set: every save carries every field (zero-size arrays when a
# field is empty/absent) so the checkpoint treedef never varies and
# ``restore`` can always rebuild it from the key list alone
_ARRAY_KEYS = (
    "block_first",
    "deltas",
    "valid_count",
    "exc_block",
    "exc_slot",
    "exc_value",
    "block_src",
    "degrees",
    "block_weights",
)


def compact_write_words(c: CompressedCSR) -> int:
    """NVRAM words one compaction writes: the compressed footprint
    (first + valid count + deltas + COO exceptions, bytes rounded up to
    words) plus the uncompressed weight blocks when weighted — the exact
    mirror of what ``_block_read_words`` charges to *read* this graph."""
    words = -(-c.compressed_bytes // 4)
    if c.weighted:
        words += c.block_size * c.num_blocks
    return words


def compact(
    overlay: DeltaOverlay,
    *,
    cost=None,
    ckpt_dir: str | None = None,
    step: int = 0,
    keep: int = 3,
    registry=None,
) -> CompressedCSR:
    """Fold ``overlay`` into a fresh ``CompressedCSR`` base.

    Gathers the live edge set host-side, rebuilds through the same
    ``build_csr`` → ``compress`` pipeline a cold load uses (so the
    result is bit-identical to a from-scratch graph over the same
    edges), and — when ``cost`` is a ``PSAMCost`` — charges the
    compacted footprint as the subsystem's ONLY ``charge_large_write``.
    ``ckpt_dir`` persists the result atomically via
    :func:`save_compacted`.  The overlay itself is left untouched;
    callers rebase by constructing ``DeltaOverlay(new_base)``.
    """
    src, dst, w = overlay.live_edges()
    rebuilt = build_csr(
        overlay.n,
        src,
        dst,
        w if overlay.weighted else None,
        block_size=overlay.block_size,
        symmetrize=False,
    )
    c = compress(rebuilt)
    words = compact_write_words(c)
    if cost is not None:
        cost.charge_large_write(words, label="compact")
    reg = registry if registry is not None else get_registry()
    if reg.enabled:
        reg.counter(
            "sage_delta_compactions_total", "overlay compactions executed"
        ).inc()
        reg.gauge(
            "sage_delta_last_compact_write_words",
            "NVRAM words written by the most recent compaction",
        ).set(float(words))
    if ckpt_dir is not None:
        save_compacted(ckpt_dir, step, c, keep=keep)
    return c


def _ckpt_tree(c: CompressedCSR) -> dict:
    meta = {
        "n": c.n,
        "m": c.m,
        "num_blocks": c.num_blocks,
        "block_size": c.block_size,
        "n_exceptions": c.n_exceptions,
        "weighted": c.weighted,
    }
    tree = {}
    for k in _ARRAY_KEYS:
        v = getattr(c, k)
        tree[k] = (
            np.zeros((0, 0), np.float32) if v is None else np.asarray(v)
        )
    tree["meta"] = np.frombuffer(json.dumps(meta).encode(), np.uint8).copy()
    return tree


def save_compacted(ckpt_dir: str, step: int, c: CompressedCSR, *, keep: int = 3) -> str:
    """Persist one compacted base atomically (ckpt step-directory save).

    All-or-nothing by construction: arrays + manifest land in
    ``step_N.tmp`` and one ``os.replace`` publishes the directory, so a
    reader never observes a half-written step."""
    return ckpt.save(ckpt_dir, step, _ckpt_tree(c), keep=keep)


def load_compacted(
    ckpt_dir: str, step: int | None = None
) -> tuple[CompressedCSR | None, int | None]:
    """Load a persisted compacted base; ``(None, None)`` when none exists.

    ``step=None`` loads the latest *published* step — unpublished
    ``.tmp`` directories from a crashed save are invisible, which is the
    crash-safety contract: recovery sees the pre-compaction graph until
    the moment the post-compaction save's ``os.replace`` lands.
    """
    if step is None:
        step = ckpt.latest_step(ckpt_dir)
        if step is None:
            return None, None
    example = {k: 0 for k in (*_ARRAY_KEYS, "meta")}
    tree = ckpt.restore(ckpt_dir, step, example)
    meta = json.loads(bytes(tree["meta"]))
    weighted = bool(meta["weighted"])
    c = CompressedCSR(
        block_first=jnp.asarray(tree["block_first"], jnp.int32),
        deltas=jnp.asarray(tree["deltas"], jnp.uint16),
        valid_count=jnp.asarray(tree["valid_count"], jnp.uint16),
        exc_block=jnp.asarray(tree["exc_block"], jnp.int32),
        exc_slot=jnp.asarray(tree["exc_slot"], jnp.int32),
        exc_value=jnp.asarray(tree["exc_value"], jnp.int32),
        block_src=jnp.asarray(tree["block_src"], jnp.int32),
        degrees=jnp.asarray(tree["degrees"], jnp.int32),
        n=int(meta["n"]),
        m=int(meta["m"]),
        num_blocks=int(meta["num_blocks"]),
        block_size=int(meta["block_size"]),
        n_exceptions=int(meta["n_exceptions"]),
        block_weights=(
            jnp.asarray(tree["block_weights"], jnp.float32) if weighted else None
        ),
        weighted=weighted,
    )
    return c, step
