"""repro.delta — mutable graphs under the semi-asymmetric contract.

The paper's PSAM (edges read-only in NVRAM, O(n) mutable DRAM) is a
log-structured storage design; this package is that design made
executable:

  DeltaOverlay         — host-side mutable edit log: per-vertex DRAM
                         patch lists for inserted edges + packed
                         tombstone bitmasks (the ``edge_active`` word
                         layout) for deleted base edges
  DeltaGraph           — immutable ``base ∪ delta`` snapshot that
                         implements the ``GraphBackend`` protocol, so
                         edge_map / filters / algorithms / QueryEngine /
                         ServingService serve the mutated graph
                         UNMODIFIED, bit-identical to a from-scratch
                         rebuild (locked by ``tests/test_delta.py``)
  compact              — fold the overlay into a fresh CompressedCSR:
                         the subsystem's ONLY large-memory write
                         (``PSAMCost.charge_large_write``), batched and
                         amortized over the edits since the last fold
  compact_write_words  — the ω-charged footprint of one compaction
  save_compacted       — atomic persistence via checkpoint/ckpt.py's
                         step-directory save (crash-safe by os.replace)
  load_compacted       — restore the latest published compacted base

PSAM pricing for queries over an overlay lives in
``PSAMCost.charge_edgemap_overlay`` (base blocks read at their NVRAM
footprint; patch blocks + tombstone words as DRAM small-ops); the
compaction *policy* — when the accumulated overlay surcharge justifies
the ω write — is ``repro.tuning.OverlayTrigger``.  Serving-tier edit
admission and compaction scheduling live in
``repro.serving.ServingService`` (``submit_edit`` / between-flush
compaction); ``docs/mutability.md`` documents the whole contract.
"""
from .compact import compact, compact_write_words, load_compacted, save_compacted
from .overlay import DeltaGraph, DeltaOverlay

__all__ = [
    "DeltaGraph",
    "DeltaOverlay",
    "compact",
    "compact_write_words",
    "load_compacted",
    "save_compacted",
]
