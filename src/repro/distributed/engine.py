"""Edge-partitioned distributed graph engine — thin planner specializations.

The Sage NUMA insight at pod scale, inverted for HBM capacity: the immutable
edge blocks are *sharded* as contiguous ranges across every chip; the O(n)
vertex state is *replicated* and combined with one psum/pmax/pmin per
edgeMap round.  Cross-chip traffic per round is O(n) words — never O(m) —
which is the PSAM small-memory bound expressed as a communication bound.

Since the unified planner (``repro.core.plan``) this module owns **no**
edge-iteration bodies: every function below builds an ``ExecutionPlan`` and
delegates to the same ``edgemap_dense`` / ``edgemap_chunked`` code the
single-device path runs — which is also how the compressed backend flows
through ``shard_map`` for free (a ``CompressedCSR`` shards its delta stream
block-range-wise, see ``CompressedCSR.shard``).  Callers prepare a graph
once with ``prepare_sharded`` (or ``ExecutionPlan.prepare``) and pass the
resulting ``ShardedGraph`` to the returned functions.
"""
from __future__ import annotations

import jax.numpy as jnp

from ..core.csr import sharded_block_counts
from ..core.edgemap import edgemap_reduce
from ..core.plan import ExecutionPlan, make_plan


def _weighted(xs, w):
    return xs * w


def prepare_sharded(mesh, g, *, shard_axes: tuple = ()):
    """Shard + stack + place ``g`` (CSRGraph | CompressedCSR) for ``mesh``."""
    return make_plan(g, mesh=mesh, shard_axes=shard_axes).prepare(g)


def distributed_vertex_reduce(
    mesh, *, n: int, monoid: str = "sum", mode: str = "flat", state_dtype=None
):
    """Build ``fn(gs, x) -> out``: one full-frontier weighted edgeMap round,
    out[v] = monoid over active edges (u, v) of x[u] * w_uv.

    ``gs`` is a plan-prepared ``ShardedGraph`` (blocks sharded over every
    mesh axis); x and the output are replicated.

    ``mode``:
      flat         — psum the full O(n) vector over every axis (baseline)
      hierarchical — reduce-scatter along the fast axis first, psum the 1/k
                     shard across the remaining axes, then all-gather: wire
                     bytes on the slow (data/pod) axes drop by the fast-axis
                     width (§Perf hillclimb C)
    ``state_dtype``: reduce in a narrower dtype (e.g. bf16) — the graph-engine
    analogue of gradient compression.
    """
    plan = ExecutionPlan(
        mesh=mesh, strategy="dense", reduce_mode=mode, state_dtype=state_dtype
    )

    def fn(gs, x):
        out, _ = edgemap_reduce(
            gs,
            jnp.ones(n, dtype=bool),
            x,
            monoid=monoid,
            map_fn=_weighted,
            mode="dense",
            plan=plan,
        )
        return out.astype(x.dtype)

    return fn


def distributed_pagerank_step(
    mesh, *, n: int, damping: float = 0.85, mode: str = "flat", state_dtype=None
):
    """One PageRank iteration over pod-scale sharded edges."""
    reduce_fn = distributed_vertex_reduce(mesh, n=n, mode=mode, state_dtype=state_dtype)

    def step(gs, pr, inv_deg):
        s = reduce_fn(gs, pr * inv_deg)
        return (1.0 - damping) / n + damping * s

    return step


def distributed_frontier_min(mesh, *, n: int):
    """BFS/label-prop round: out[v] = min over incoming active edges of
    x[src]; frontier-masked.  Blocks sharded, state replicated, pmin.
    Untouched vertices come back as the min-monoid identity (int32 max)."""
    plan = ExecutionPlan(mesh=mesh, strategy="dense")

    def fn(gs, x, frontier):
        out, _ = edgemap_reduce(
            gs, frontier, x, monoid="min", mode="dense", plan=plan
        )
        return out

    return fn


def shard_blocks_for_mesh(mesh, num_blocks: int, shard_axes: tuple = ()) -> int:
    """Padded per-mesh block count: the least multiple of the sharded-axis
    product ≥ ``num_blocks``.

    Non-dividing block counts round *up* — the remainder pads with empty
    sentinel blocks (``GraphBackend.shard`` emits them) — so the tail shard
    is never silently truncated.  ``shard_axes`` selects the mesh axes the
    blocks split over (default: all of them).
    """
    total = 1
    for ax in tuple(shard_axes) or tuple(mesh.axis_names):
        total *= mesh.shape[ax]
    return sharded_block_counts(num_blocks, total)[1]
