"""Edge-partitioned distributed graph engine (shard_map).

The Sage NUMA insight at pod scale, inverted for HBM capacity: the immutable
edge blocks are *sharded* as contiguous ranges across every chip; the O(n)
vertex state is *replicated* and combined with one psum/pmax/pmin per
edgeMap round.  Cross-chip traffic per round is O(n) words — never O(m) —
which is the PSAM small-memory bound expressed as a communication bound.

The pod axis adds a second tier: each pod holds a full copy of its edge
shard range assignment, so cross-pod traffic is also only the O(n) vertex
reduction (the paper's "no cross-socket edge reads" rule, §5.2).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P
from jax.experimental.shard_map import shard_map


def _all_axes(mesh):
    return tuple(mesh.axis_names)


def distributed_vertex_reduce(
    mesh, *, n: int, monoid: str = "sum", mode: str = "flat", state_dtype=None
):
    """Build a shard_map'd function: (block_dst (NB,FB), block_w, block_src,
    x (n,)) → out (n,) — out[v] = monoid over active slots with src-owner v.

    Blocks are sharded over every mesh axis; x and the output are replicated.

    ``mode``:
      flat         — psum the full O(n) vector over every axis (baseline)
      hierarchical — reduce-scatter along the fast axis first, psum the 1/k
                     shard across the remaining axes, then all-gather: wire
                     bytes on the slow (data/pod) axes drop by the fast-axis
                     width (§Perf hillclimb C)
    ``state_dtype``: reduce in a narrower dtype (e.g. bf16) — the graph-engine
    analogue of gradient compression.
    """
    axes = _all_axes(mesh)
    spec_blocks = P(axes)
    spec_rep = P()
    fast = axes[-1]
    slow = axes[:-1]

    def local(block_dst, block_w, block_src, x):
        mask = block_dst < n
        safe = jnp.where(mask, block_dst, 0)
        xv = jnp.take(x, safe.reshape(-1), axis=0).reshape(block_dst.shape)
        contrib = jnp.where(mask, xv * block_w, 0.0)
        per_block = jnp.sum(contrib, axis=1)
        out = jax.ops.segment_sum(per_block, block_src, num_segments=n + 1)[:n]
        if state_dtype is not None:
            out = out.astype(state_dtype)
        if mode == "hierarchical" and len(axes) > 1:
            k = mesh.shape[fast]
            pad = (-n) % k
            shard = jax.lax.psum_scatter(
                jnp.pad(out, (0, pad)), fast, scatter_dimension=0, tiled=True
            )
            for ax in slow:
                shard = jax.lax.psum(shard, ax)
            out = jax.lax.all_gather(shard, fast, axis=0, tiled=True)[:n]
        else:
            for ax in axes:
                out = jax.lax.psum(out, ax)
        return out.astype(x.dtype)

    return shard_map(
        local,
        mesh=mesh,
        in_specs=(spec_blocks, spec_blocks, spec_blocks, spec_rep),
        out_specs=spec_rep,
        # the hierarchical path's all_gather(psum_scatter(...)) is replicated
        # over the fast axis but the static replication check can't prove it
        check_rep=False,
    )


def distributed_pagerank_step(
    mesh, *, n: int, damping: float = 0.85, mode: str = "flat", state_dtype=None
):
    """One PageRank iteration over pod-scale sharded edges."""
    reduce_fn = distributed_vertex_reduce(mesh, n=n, mode=mode, state_dtype=state_dtype)

    def step(block_dst, block_w, block_src, pr, inv_deg):
        contrib = pr * inv_deg
        s = reduce_fn(block_dst, block_w, block_src, contrib)
        return (1.0 - damping) / n + damping * s

    return step


def distributed_frontier_min(mesh, *, n: int):
    """BFS/label-prop round: out[v] = min over incoming active edges of
    x[src]; frontier-masked.  Blocks sharded, state replicated, pmin."""
    axes = _all_axes(mesh)

    def local(block_dst, block_src, x, frontier):
        big = jnp.int32(2**31 - 1)
        in_f = jnp.take(frontier, jnp.minimum(block_src, n - 1)) & (block_src < n)
        xv = jnp.take(x, jnp.minimum(block_src, n - 1))
        vals = jnp.where(in_f, xv, big)[:, None]
        vals = jnp.broadcast_to(vals, block_dst.shape)
        ids = jnp.where(block_dst < n, block_dst, n).reshape(-1)
        out = jax.ops.segment_min(
            jnp.where(block_dst < n, vals, big).reshape(-1), ids, num_segments=n + 1
        )[:n]
        for ax in axes:
            out = jax.lax.pmin(out, ax)
        return out

    return shard_map(
        local,
        mesh=mesh,
        in_specs=(P(_all_axes(mesh)), P(_all_axes(mesh)), P(), P()),
        out_specs=P(),
    )


def shard_blocks_for_mesh(mesh, num_blocks: int) -> int:
    """Blocks must divide the total mesh size; returns padded block count."""
    total = mesh.devices.size
    return -(-num_blocks // total) * total
