"""Elastic scaling: re-carve the mesh from the live device set and restore
state onto it.

Because every sharding in the system is a PartitionSpec over *named* axes
(never device ids), shrinking 512 → 448 chips is: carve a new mesh, rebuild
NamedShardings from the same logical rules, restore the latest checkpoint
with device_put.  The checkpoint format is host-count independent
(see checkpoint/ckpt.py).
"""
from __future__ import annotations

import jax

from ..checkpoint.ckpt import restore_latest
from ..compat import make_mesh as _make_mesh
from .shardings import axis_rules, spec_tree


def carve_mesh(n_devices: int | None = None, *, max_model: int = 16, devices=None):
    """Pick a (data, model) factorization for the live device count: model =
    largest power-of-two divisor ≤ max_model (TP wants the fast axis),
    data = rest."""
    devices = devices if devices is not None else jax.devices()
    n = n_devices or len(devices)
    model = 1
    while model * 2 <= max_model and n % (model * 2) == 0:
        model *= 2
    data = n // model
    return _make_mesh((data, model), ("data", "model"), devices=devices[:n])


def elastic_restore(ckpt_dir: str, example_tree, logical_tree, rules, mesh):
    """Restore the latest checkpoint onto a (possibly different) mesh."""
    from jax.sharding import NamedSharding

    with axis_rules(rules, mesh):
        specs = spec_tree(logical_tree)
    shardings = jax.tree.map(lambda s: NamedSharding(mesh, s), specs)
    return restore_latest(ckpt_dir, example_tree, shardings=shardings)
