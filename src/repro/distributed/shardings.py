"""Logical-axis sharding rules (MaxText-style).

Models annotate tensors with *logical* axis names; a rule set maps logical
names → mesh axis names for the active mesh.  The same model code therefore
runs on the single-pod (data, model) mesh, the multi-pod (pod, data, model)
mesh, on one CPU device (rules inactive → no-op), or on a re-carved elastic
mesh — nothing in the model mentions device counts.

Rule sets per family:

* LM_RULES      — Megatron TP: heads/ff/vocab/experts → 'model';
                  batch → ('pod','data'); residual activations replicated
                  over 'model'.
* LM_RULES_SP   — + sequence parallelism: the residual stream's 'seq' axis
                  is sharded over 'model' between blocks (the §Perf lever
                  for activation memory).
* GNN_RULES     — edge/node arrays sharded over the flattened data×model
                  axes (edge partitioning); feature dims replicated.
* RECSYS_RULES  — embedding-table rows → 'model' (EP), batch → data axes.
* GRAPH_ENGINE_RULES — Sage engine: blocks → ('data','model'), vertex state
                  replicated (the paper's NUMA replication, inverted: shard
                  the big immutable thing, replicate the small mutable one).
"""
from __future__ import annotations

import threading
from contextlib import contextmanager

import jax
from jax.sharding import PartitionSpec as P

_state = threading.local()


LM_RULES = {
    # activations
    "batch": ("pod", "data"),
    "seq": None,
    "res_seq": None,   # residual stream between blocks (SP shards this one)
    "act_embed": None,
    "cache_seq": None,
    # params: FSDP over 'data' on the embed dim + Megatron TP over 'model'
    "embed": "data",
    "heads": "model",
    "kv_heads": None,
    "head_dim": None,
    "ff": "model",
    "vocab": "model",
    "experts": "model",
    "expert_ff": None,
    "expert_cap": None,
    "layers": None,
    "kv_lora": None,
}

# sequence parallelism: residual stream sharded over 'model' between blocks
LM_RULES_SP = dict(LM_RULES, res_seq="model")

# serving: KV cache sharded along its sequence axis over 'model'
LM_PREFILL_RULES = dict(LM_RULES, cache_seq="model")
LM_DECODE_RULES = dict(LM_RULES, cache_seq="model")
# batch=1 long-context decode: cache over 'data', single query replicated
LM_DECODE_LONG_RULES = dict(LM_RULES, batch=None, cache_seq="data")

# §Perf variant: a 500k MHA cache is ~215 GB global (qwen1.5-4b) — 16-way
# seq sharding leaves 13.4 GB/device.  Shard BOTH cache_seq (data) and
# head_dim/kv_lora (model) for 256-way placement (~0.9 GB/device); the
# attention einsum contracts the sharded head_dim with one small psum and
# the softmax reduces over the sharded seq axis.
LM_DECODE_LONG_RULES_V2 = dict(
    LM_RULES, batch=None, cache_seq="data", heads=None, head_dim="model",
    kv_lora="model",
)

GNN_RULES = {
    "nodes": ("pod", "data"),
    "edges": ("pod", "data", "model"),
    "feat": None,
    "batch": ("pod", "data"),
    "layers": None,
    "hidden": None,
}

# §Perf variant (hillclimb B): tensor-parallel channels instead of 512-way
# edge sharding — edge tensors shard (pod,data), hidden dim shards 'model',
# so the per-layer node-aggregation all-reduce carries 1/16 of the bytes and
# the (E, coef, d) message tensors never cross the model axis.
GNN_RULES_TP = dict(GNN_RULES, edges=("pod", "data"), hidden="model")

RECSYS_RULES = {
    "batch": ("pod", "data"),
    "vocab_rows": "model",
    "embed": None,
    "seq": None,
    "act_embed": None,
    "heads": None,
    "ff": None,
    "candidates": "model",
    "layers": None,
}

# retrieval_cand: one query, 10⁶ candidates sharded across the whole mesh
RECSYS_RETRIEVAL_RULES = dict(
    RECSYS_RULES, batch=None, candidates=("pod", "data", "model")
)

GRAPH_ENGINE_RULES = {
    "blocks": ("pod", "data", "model"),
    "slots": None,
    "vertices": None,
}


@contextmanager
def axis_rules(rules: dict | None, mesh=None):
    """Activate a logical→mesh rule set (and optionally a mesh filter)."""
    prev = getattr(_state, "rules", None)
    prev_axes = getattr(_state, "mesh_axes", None)
    _state.rules = rules
    _state.mesh_axes = tuple(mesh.axis_names) if mesh is not None else None
    try:
        yield
    finally:
        _state.rules = prev
        _state.mesh_axes = prev_axes


def _resolve(name):
    rules = getattr(_state, "rules", None)
    if rules is None or name is None:
        return None
    target = rules.get(name)
    mesh_axes = getattr(_state, "mesh_axes", None)
    if target is None:
        return None
    if isinstance(target, str):
        if mesh_axes is not None and target not in mesh_axes:
            return None
        return target
    # tuple of axes: keep only those present in the mesh
    kept = tuple(a for a in target if mesh_axes is None or a in mesh_axes)
    return kept if kept else None


def logical_to_spec(*names) -> P:
    """Map logical axis names (or None) to a PartitionSpec under the active
    rules.  Inactive rules → fully-replicated spec."""
    return P(*[_resolve(nm) for nm in names])


def constrain(x, *names):
    """with_sharding_constraint on logical names; no-op when rules inactive
    (CPU unit tests) or when x is a ShapeDtypeStruct."""
    rules = getattr(_state, "rules", None)
    if rules is None:
        return x
    return jax.lax.with_sharding_constraint(x, logical_to_spec(*names))


def spec_tree(logical_tree):
    """Map a pytree of logical-name tuples to a pytree of PartitionSpecs."""
    return jax.tree.map(
        lambda names: logical_to_spec(*names),
        logical_tree,
        is_leaf=lambda x: isinstance(x, tuple),
    )
