"""Docs gate for CI: executable snippets + public-docstring audit.

Two checks, both fail-loud (exit 1):

1. **Snippets execute** — every ```python fenced block in ``docs/*.md`` (and
   any extra files passed on the command line) runs top-to-bottom in one
   fresh namespace per file, in file order, so later blocks may use earlier
   blocks' variables — the doctest-extraction discipline, without requiring
   >>> prompts.  Blocks whose first line is ``# doctest: skip`` are
   illustrative only (pseudo-code, mesh-requiring examples) and are not
   executed.

2. **Public symbols are documented** — every name exported via ``__all__``
   from ``repro.core``, ``repro.serving`` and ``repro.tuning`` that is a
   class or function
   must have a non-empty docstring.  Data constants (e.g. ``NULL_BUCKET``)
   and typing aliases (``GraphLike``) carry their documentation in the
   module docstring instead and are exempt.  For the serving API
   (``MEMBER_AUDITED``) the audit descends INTO exported classes: every
   public method and property defined on ``QueryEngine``,
   ``ServingService`` etc. must be documented too — the serving tier is
   driven through its methods (``submit`` / ``tick`` / ``flush``), so a
   class-level docstring alone is not a usable API reference.

Usage (from the repo root, CPU JAX):

    PYTHONPATH=src python tools/check_docs.py            # both checks
    PYTHONPATH=src python tools/check_docs.py --docstrings-only
    PYTHONPATH=src python tools/check_docs.py docs/kernels.md
"""
from __future__ import annotations

import argparse
import glob
import importlib
import inspect
import os
import re
import sys

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
AUDITED_MODULES = (
    "repro.core",
    "repro.serving",
    "repro.tuning",
    "repro.obs",
    "repro.delta",
)
MEMBER_AUDITED = ("repro.serving",)  # classes audited method-by-method
FENCE = re.compile(r"^```python\s*$(.*?)^```\s*$", re.MULTILINE | re.DOTALL)


def extract_blocks(path: str) -> list[tuple[int, str]]:
    """(starting line number, source) for each executable ```python block."""
    text = open(path).read()
    blocks = []
    for m in FENCE.finditer(text):
        body = m.group(1)
        first = body.lstrip().splitlines()[0] if body.strip() else ""
        if first.startswith("# doctest: skip"):
            continue
        line = text[: m.start(1)].count("\n") + 1
        blocks.append((line, body))
    return blocks


def run_snippets(paths: list[str]) -> list[str]:
    failures = []
    for path in paths:
        ns: dict = {"__name__": f"docsnippet:{os.path.basename(path)}"}
        for line, src in extract_blocks(path):
            try:
                exec(compile(src, f"{path}:{line}", "exec"), ns)  # noqa: S102
            except Exception as e:  # noqa: BLE001 — report, don't crash
                failures.append(f"{path}:{line}: {type(e).__name__}: {e}")
                break  # later blocks in this file may depend on this one
        else:
            n = len(extract_blocks(path))
            print(f"  {path}: {n} snippet(s) OK")
    return failures


def audit_members(modname: str, clsname: str, cls) -> tuple[int, list[str]]:
    """Audit a class's own public methods and properties for docstrings."""
    checked, failures = 0, []
    for mname, member in vars(cls).items():
        if mname.startswith("_"):
            continue
        if isinstance(member, property):
            target = member
        elif isinstance(member, (staticmethod, classmethod)):
            target = member.__func__
        elif inspect.isroutine(member):
            target = member
        else:
            continue  # dataclass fields, class attrs: class doc covers them
        checked += 1
        if not (inspect.getdoc(target) or "").strip():
            failures.append(
                f"{modname}.{clsname}.{mname}: public but undocumented"
            )
    return checked, failures


def run_docstring_audit() -> list[str]:
    failures = []
    for modname in AUDITED_MODULES:
        mod = importlib.import_module(modname)
        names = getattr(mod, "__all__", None)
        if not names:
            failures.append(f"{modname}: no __all__ to audit")
            continue
        checked = 0
        for name in names:
            obj = getattr(mod, name, None)
            if obj is None:
                failures.append(f"{modname}.{name}: exported but missing")
                continue
            if not (inspect.isclass(obj) or inspect.isroutine(obj)):
                continue  # constants / aliases: documented in the module doc
            checked += 1
            if not (inspect.getdoc(obj) or "").strip():
                failures.append(f"{modname}.{name}: public but undocumented")
            if inspect.isclass(obj) and modname in MEMBER_AUDITED:
                n, fails = audit_members(modname, name, obj)
                checked += n
                failures += fails
        print(f"  {modname}: {checked} documented symbols audited")
    return failures


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("files", nargs="*", help="markdown files (default docs/*.md)")
    ap.add_argument("--docstrings-only", action="store_true")
    ap.add_argument("--snippets-only", action="store_true")
    args = ap.parse_args(argv)

    failures = []
    if not args.docstrings_only:
        paths = args.files or sorted(glob.glob(os.path.join(ROOT, "docs", "*.md")))
        if not paths:
            failures.append("no docs/*.md found to check")
        else:
            print("snippets:")
            failures += run_snippets(paths)
    if not args.snippets_only:
        print("docstrings:")
        failures += run_docstring_audit()

    if failures:
        print("\nFAILURES:")
        for f in failures:
            print(f"  {f}")
        return 1
    print("docs checks passed")
    return 0


if __name__ == "__main__":
    sys.exit(main())
